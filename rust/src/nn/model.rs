//! The full acoustic model: stacked LSTMP layers + softmax output,
//! loaded from `.qam`, streaming per-timestep execution.
//!
//! [`ExecMode`] reproduces the paper's Table-1 conditions:
//! - `Float`           — everything f32 ('match'; also recovers quantized
//!                        models to their float grid for cross-checks).
//! - `Quant`           — every matrix through the §3.1 integer path except
//!                        the softmax ('mismatch' for float-trained models,
//!                        'quant' for QAT models).
//! - `QuantAll`        — softmax quantized too ('quant-all').
//!
//! Models exported by QAT already store u8 grids; `Quant`/`QuantAll` uses
//! them untouched.  Float-trained models get post-hoc quantization
//! (`Linear::quantize_now`) — exactly the paper's mismatch condition.
//!
//! **In-situ requantization** ([`crate::quant::QuantScheme`], selected via
//! `--isq` / `QUANTASR_ISQ`): under the seed `PerMatrixU8` scheme the
//! behavior above is unchanged, bit for bit.  The per-channel schemes
//! (`PerChannelU8`, `PerChannelI4`) requantize every weight matrix from
//! its recovered f32 view at load time — the `.qam` artifact is never
//! touched, so one file serves at any width per deployment.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::io::model_fmt::{ModelHeader, QamFile, Tensor};
use crate::nn::activation::log_softmax_rows;
use crate::nn::linear::Linear;
use crate::nn::lstm::{LayerState, LstmLayer, LstmScratch};
use crate::quant::gemm::{Kernel, QActRows, QScratch};
use crate::quant::QuantScheme;

/// Execution numerics (Table-1 column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Float,
    Quant,
    QuantAll,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float" | "match" => ExecMode::Float,
            "quant" | "mismatch" => ExecMode::Quant,
            "quant-all" | "quant_all" => ExecMode::QuantAll,
            other => anyhow::bail!("unknown exec mode '{other}'"),
        })
    }
}

/// Streaming state + scratch for a fixed batch size.  Everything here is
/// sized once at construction ([`AcousticModel::new_state`]); stepping
/// never allocates.
pub struct ModelState {
    pub batch: usize,
    pub layers: Vec<LayerState>,
    pub scratch: LstmScratch,
    pub qout: QScratch,
    /// Per-layer quantization cache of that layer's `h` output: filled
    /// lazily by its consumers (the layer's own `Wh` next step, the next
    /// layer's `Wx` this tick), invalidated by whoever rewrites the rows.
    h_caches: Vec<QActRows>,
}

impl ModelState {
    /// Reset one stream's recurrent state to zero (utterance boundary).
    pub fn reset_stream(&mut self, model: &AcousticModel, stream: usize) {
        for ((l, st), hc) in model
            .layers
            .iter()
            .zip(self.layers.iter_mut())
            .zip(self.h_caches.iter_mut())
        {
            let n = l.cell_dim;
            let r = l.rec_dim();
            st.c[stream * n..(stream + 1) * n].fill(0.0);
            st.h[stream * r..(stream + 1) * r].fill(0.0);
            hc.invalidate_row(stream);
        }
    }

    /// Copy one stream's state from another `ModelState` (used by the
    /// batcher when migrating streams between batch slots).
    pub fn copy_stream_from(
        &mut self,
        model: &AcousticModel,
        dst: usize,
        src_state: &ModelState,
        src: usize,
    ) {
        for ((l, (d, s)), hc) in model
            .layers
            .iter()
            .zip(self.layers.iter_mut().zip(src_state.layers.iter()))
            .zip(self.h_caches.iter_mut())
        {
            let n = l.cell_dim;
            let r = l.rec_dim();
            d.c[dst * n..(dst + 1) * n].copy_from_slice(&s.c[src * n..(src + 1) * n]);
            d.h[dst * r..(dst + 1) * r].copy_from_slice(&s.h[src * r..(src + 1) * r]);
            hc.invalidate_row(dst);
        }
    }
}

/// Persistent lane-resident batch state for the serving engine.
///
/// Streams are assigned stable **lanes** in pre-allocated `[max_lanes, …]`
/// recurrent buffers for the engine's lifetime; the engine steps the
/// active lanes in place ([`AcousticModel::arena_step`]) instead of
/// gathering per-stream states into a fresh batch and scattering them back
/// every tick.  Lane numerics are bit-identical to running the stream
/// alone (per-row quantization contract in [`crate::quant::gemm`]), so
/// lane residency is invisible to results.
pub struct BatchArena {
    pub max_lanes: usize,
    /// Per layer: `[max_lanes, N]` cell + `[max_lanes, rec]` output state.
    pub layers: Vec<LayerState>,
    scratch: LstmScratch,
    qout: QScratch,
    /// Per-layer quantization cache of `h` rows (see [`ModelState`]);
    /// lane-indexed, invalidated on reset/load and after each step.
    h_caches: Vec<QActRows>,
}

/// One stream's recurrent state parked outside the arena (lane eviction:
/// the engine saves an idle stream's lane so a waiting stream can use it,
/// and restores it when the stream is scheduled again).
pub struct ParkedLane {
    /// Per layer: (cell row, output row).
    layers: Vec<(Vec<f32>, Vec<f32>)>,
}

impl ParkedLane {
    /// Heap bytes held by this parked state (budget-ledger accounting).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|(c, h)| (c.len() + h.len()) * 4).sum()
    }
}

impl BatchArena {
    /// Zero one lane's recurrent state (fresh stream / utterance boundary).
    pub fn reset_lane(&mut self, lane: usize) {
        debug_assert!(lane < self.max_lanes);
        for (st, hc) in self.layers.iter_mut().zip(self.h_caches.iter_mut()) {
            let n = st.c.len() / self.max_lanes;
            let r = st.h.len() / self.max_lanes;
            st.c[lane * n..(lane + 1) * n].fill(0.0);
            st.h[lane * r..(lane + 1) * r].fill(0.0);
            hc.invalidate_row(lane);
        }
    }

    /// Copy one lane's state out of the arena (eviction).
    pub fn save_lane(&self, lane: usize) -> ParkedLane {
        debug_assert!(lane < self.max_lanes);
        ParkedLane {
            layers: self
                .layers
                .iter()
                .map(|st| {
                    let n = st.c.len() / self.max_lanes;
                    let r = st.h.len() / self.max_lanes;
                    (
                        st.c[lane * n..(lane + 1) * n].to_vec(),
                        st.h[lane * r..(lane + 1) * r].to_vec(),
                    )
                })
                .collect(),
        }
    }

    /// Restore a parked state into a lane (re-admission after eviction).
    pub fn load_lane(&mut self, lane: usize, parked: &ParkedLane) {
        debug_assert!(lane < self.max_lanes);
        debug_assert_eq!(parked.layers.len(), self.layers.len());
        for ((st, (c, h)), hc) in self
            .layers
            .iter_mut()
            .zip(parked.layers.iter())
            .zip(self.h_caches.iter_mut())
        {
            let n = st.c.len() / self.max_lanes;
            let r = st.h.len() / self.max_lanes;
            st.c[lane * n..(lane + 1) * n].copy_from_slice(c);
            st.h[lane * r..(lane + 1) * r].copy_from_slice(h);
            hc.invalidate_row(lane);
        }
    }
}

/// The stacked acoustic model.
pub struct AcousticModel {
    pub header: ModelHeader,
    pub layers: Vec<LstmLayer>,
    pub out: Linear,
    pub out_bias: Vec<f32>,
    pub mode: ExecMode,
    pub kernel: Kernel,
    /// The in-situ requantization scheme the quantized matrices were built
    /// under (reported by the serving registry; `PerMatrixU8` is the seed
    /// behavior).
    pub scheme: QuantScheme,
}

impl AcousticModel {
    /// Load a `.qam` and prepare it under the given execution mode, with
    /// the requantization scheme taken from `QUANTASR_ISQ` (default:
    /// `PerMatrixU8`, the seed behavior).
    pub fn load(path: impl AsRef<Path>, mode: ExecMode) -> Result<Self> {
        Self::load_with_scheme(path, mode, QuantScheme::from_env_or_default())
    }

    /// Load a `.qam` with an explicit requantization scheme (`--isq`).
    pub fn load_with_scheme(
        path: impl AsRef<Path>,
        mode: ExecMode,
        scheme: QuantScheme,
    ) -> Result<Self> {
        let qam = QamFile::load(path)?;
        Self::from_qam_scheme(&qam, mode, scheme)
    }

    pub fn from_qam(qam: &QamFile, mode: ExecMode) -> Result<Self> {
        Self::from_qam_scheme(qam, mode, QuantScheme::from_env_or_default())
    }

    /// Build from an in-memory `.qam` under an explicit requantization
    /// scheme.  `PerMatrixU8` preserves the seed behavior exactly: stored
    /// U8Q grids serve untouched (bit-faithful to QAT) and float tensors
    /// go through [`Linear::quantize_now`].  The per-channel schemes
    /// requantize **every** quantized matrix from its recovered f32 view
    /// (mistral.rs-style ISQ — the artifact is read-only).
    pub fn from_qam_scheme(qam: &QamFile, mode: ExecMode, scheme: QuantScheme) -> Result<Self> {
        let h = &qam.header;
        // A zero-layer header is corruption, not a model — and the step
        // path indexes the top layer's cache unconditionally, so admit
        // it here with a reason instead of panicking there.
        anyhow::ensure!(
            h.num_layers >= 1,
            "qam header declares {} layers; a model needs at least one",
            h.num_layers
        );
        let adapt = |t: &Tensor, want_quant: bool| -> Result<Linear> {
            let l = Linear::from_tensor(t)?;
            Ok(match (want_quant, l.is_quant()) {
                (true, false) => match scheme {
                    QuantScheme::PerMatrixU8 => l.quantize_now(), // mismatch path
                    s => l.quantize_scheme(s),
                },
                (true, true) => match scheme {
                    QuantScheme::PerMatrixU8 => l, // stored QAT grid, untouched
                    s => l.quantize_scheme(s),     // ISQ from the recovered floats
                },
                (false, true) => l.to_float(), // float view of QAT model
                _ => l,
            })
        };
        let quant_inner = mode != ExecMode::Float;
        let quant_out = mode == ExecMode::QuantAll;

        let mut layers = Vec::with_capacity(h.num_layers);
        for l in 0..h.num_layers {
            let wx = adapt(qam.tensor(&format!("l{l}.wx"))?, quant_inner)?;
            let wh = adapt(qam.tensor(&format!("l{l}.wh"))?, quant_inner)?;
            let bias = qam.tensor(&format!("l{l}.b"))?.to_f32();
            let wp = match h.proj_dim {
                Some(_) => Some(adapt(qam.tensor(&format!("l{l}.wp"))?, quant_inner)?),
                None => None,
            };
            let layer = LstmLayer { wx, wh, bias, wp, cell_dim: h.cell_dim };
            layer.validate().with_context(|| format!("layer {l}"))?;
            layers.push(layer);
        }
        let out = adapt(qam.tensor("out.w")?, quant_out)?;
        let out_bias = qam.tensor("out.b")?.to_f32();
        ensure!(out.out_dim() == h.num_labels, "output dim mismatch");
        ensure!(out_bias.len() == h.num_labels, "output bias mismatch");
        ensure!(layers[0].in_dim() == h.input_dim, "input dim mismatch");
        Ok(AcousticModel {
            header: h.clone(),
            layers,
            out,
            out_bias,
            mode,
            kernel: Kernel::Auto,
            scheme,
        })
    }

    /// Re-quantize every weight matrix at the given bit width (from the
    /// float view) — the E5 bit-width ablation path.
    pub fn requantize_bits(&mut self, bits: u32, include_output: bool) {
        for l in self.layers.iter_mut() {
            l.wx = l.wx.to_float().quantize_bits(bits);
            l.wh = l.wh.to_float().quantize_bits(bits);
            if let Some(wp) = &l.wp {
                l.wp = Some(wp.to_float().quantize_bits(bits));
            }
        }
        if include_output {
            self.out = self.out.to_float().quantize_bits(bits);
        }
    }

    /// Re-quantize every quantized weight matrix under a different
    /// requantization scheme, in place (hot-requant path; goes through
    /// each layer's recovered f32 view, see [`Linear::quantize_scheme`]).
    /// Float-mode models are left untouched.
    pub fn requantize_scheme(&mut self, scheme: QuantScheme) {
        if self.mode == ExecMode::Float {
            return;
        }
        for l in self.layers.iter_mut() {
            l.wx = l.wx.quantize_scheme(scheme);
            l.wh = l.wh.quantize_scheme(scheme);
            if let Some(wp) = &l.wp {
                l.wp = Some(wp.quantize_scheme(scheme));
            }
        }
        if self.mode == ExecMode::QuantAll {
            self.out = self.out.quantize_scheme(scheme);
        }
        self.scheme = scheme;
    }

    /// The scheme tag the serving registry reports for this model:
    /// `"float"` for float-mode models (no quantizer in the path),
    /// otherwise the requantization scheme's name.
    pub fn scheme_name(&self) -> &'static str {
        if self.mode == ExecMode::Float {
            "float"
        } else {
            self.scheme.name()
        }
    }

    pub fn num_labels(&self) -> usize {
        self.header.num_labels
    }

    pub fn input_dim(&self) -> usize {
        self.header.input_dim
    }

    /// Weight storage under the current mode (paper's memory claim).
    pub fn storage_bytes(&self) -> usize {
        self.layers.iter().map(LstmLayer::storage_bytes).sum::<usize>()
            + self.out.storage_bytes()
            + self.out_bias.len() * 4
    }

    /// Bytes held by the packed-panel serving mirrors across all layers —
    /// built once at load (`Linear::from_tensor` / `quantize_now` pack
    /// every PerMatrix matrix eagerly), so the serving hot path never
    /// repacks.  Reported separately from [`Self::storage_bytes`]: the
    /// mirrors are derived runtime state, not part of the model file.
    pub fn packed_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.wx.packed_bytes()
                    + l.wh.packed_bytes()
                    + l.wp.as_ref().map_or(0, Linear::packed_bytes)
            })
            .sum::<usize>()
            + self.out.packed_bytes()
    }

    /// Bytes of recurrent state one stream carries: per layer a cell row
    /// (`cell_dim` f32) plus an output row (`rec_dim` f32).  This is both
    /// the per-lane arena share and the size of a [`ParkedLane`], so the
    /// budget ledger charges parked and resident lanes identically.
    pub fn lane_state_bytes(&self) -> usize {
        self.layers.iter().map(|l| (l.cell_dim + l.rec_dim()) * 4).sum()
    }

    /// Resident bytes of a [`BatchArena`] sized for `max_lanes` lanes:
    /// lane-resident recurrent state plus the per-lane activation caches
    /// (`QActRows`: one u8 row of `rec_dim` per layer plus a 4-byte
    /// scale).  Step scratch is excluded — it is shared per worker, not
    /// per model, and bounded by the widest layer.  Deterministic and
    /// derivable from the header alone, so admission can price a model
    /// before allocating anything.
    pub fn arena_bytes(&self, max_lanes: usize) -> usize {
        let caches: usize = self.layers.iter().map(|l| l.rec_dim() + 4).sum();
        max_lanes * (self.lane_state_bytes() + caches)
    }

    /// Scratch + caches sized for stepping `rows` rows — everything the
    /// hot loop touches is allocated here, once.
    fn sized_scratch(&self, rows: usize) -> (LstmScratch, Vec<QActRows>) {
        let mut scratch = LstmScratch::default();
        let max_cell = self.layers.iter().map(|l| l.cell_dim).max().unwrap_or(0);
        scratch.ensure(rows, max_cell);
        let caches =
            self.layers.iter().map(|l| QActRows::sized(rows, l.rec_dim())).collect();
        (scratch, caches)
    }

    pub fn new_state(&self, batch: usize) -> ModelState {
        let (scratch, h_caches) = self.sized_scratch(batch);
        ModelState {
            batch,
            layers: self.layers.iter().map(|l| l.zero_state(batch)).collect(),
            scratch,
            qout: QScratch::default(),
            h_caches,
        }
    }

    /// One timestep for the whole batch: `x [batch, input_dim]` →
    /// `log_probs [batch, num_labels]` written into `out`.
    ///
    /// Each layer's `h` is quantized **once** per tick via the per-layer
    /// [`QActRows`] caches: the next layer's `Wx` fills the cache, and
    /// the layer's own `Wh` reuses it on the next step (the cache never
    /// changes results — see `quant::gemm`).
    pub fn step(&self, x: &[f32], state: &mut ModelState, out: &mut [f32]) {
        let batch = state.batch;
        debug_assert_eq!(x.len(), batch * self.input_dim());
        debug_assert_eq!(out.len(), batch * self.num_labels());

        // Layer 0 reads x; layer li reads layer li−1's (already updated)
        // h — disjoint LayerState entries, so no staging copy is needed.
        for (li, layer) in self.layers.iter().enumerate() {
            let (prev_s, cur_s) = state.layers.split_at_mut(li);
            let (prev_c, cur_c) = state.h_caches.split_at_mut(li);
            if li == 0 {
                layer.step_cached(
                    x,
                    None,
                    batch,
                    &mut cur_s[0],
                    &mut state.scratch,
                    Some(&mut cur_c[0]),
                    self.kernel,
                );
            } else {
                layer.step_cached(
                    &prev_s[li - 1].h,
                    Some(&mut prev_c[li - 1]),
                    batch,
                    &mut cur_s[0],
                    &mut state.scratch,
                    Some(&mut cur_c[0]),
                    self.kernel,
                );
            }
        }
        let h_top = &state.layers[self.layers.len() - 1].h;
        let top_cache = state.h_caches.last_mut().expect("model has layers");
        self.out.forward_cached(
            h_top,
            Some(top_cache),
            batch,
            Some(&self.out_bias),
            out,
            &mut state.qout,
            self.kernel,
            false,
        );
        log_softmax_rows(out, batch, self.num_labels());
    }

    /// Allocate a lane-resident [`BatchArena`] for `max_lanes` concurrent
    /// streams (all lanes start zeroed; scratch and activation caches are
    /// pre-sized so stepping never allocates).
    pub fn new_arena(&self, max_lanes: usize) -> BatchArena {
        let (scratch, h_caches) = self.sized_scratch(max_lanes);
        BatchArena {
            max_lanes,
            layers: self.layers.iter().map(|l| l.zero_state(max_lanes)).collect(),
            scratch,
            qout: QScratch::default(),
            h_caches,
        }
    }

    /// One timestep over the arena's **active lanes, in place**: `x` and
    /// `out` are lane-resident `[max_lanes, input_dim]` / `[max_lanes,
    /// num_labels]` buffers of which only the rows listed in `lanes` are
    /// read/written; recurrent state updates inside the arena.  Inactive
    /// lanes cost nothing.  Per lane this computes exactly what
    /// [`AcousticModel::step`] computes for that stream alone —
    /// bit-identical, by the per-row quantization contract.
    pub fn arena_step(
        &self,
        arena: &mut BatchArena,
        lanes: &[usize],
        x: &[f32],
        out: &mut [f32],
    ) {
        let ml = arena.max_lanes;
        debug_assert_eq!(x.len(), ml * self.input_dim());
        debug_assert_eq!(out.len(), ml * self.num_labels());
        let BatchArena { layers: states, scratch, qout, h_caches, .. } = arena;
        for (li, layer) in self.layers.iter().enumerate() {
            // Layer li reads the previous layer's (already-updated)
            // lane-resident h and updates its own state in place; each
            // layer's h quantization is cached per lane (see `step`).
            let (prev_s, cur_s) = states.split_at_mut(li);
            let (prev_c, cur_c) = h_caches.split_at_mut(li);
            if li == 0 {
                layer.step_lanes_cached(
                    x,
                    None,
                    ml,
                    lanes,
                    &mut cur_s[0],
                    scratch,
                    Some(&mut cur_c[0]),
                    self.kernel,
                );
            } else {
                layer.step_lanes_cached(
                    &prev_s[li - 1].h,
                    Some(&mut prev_c[li - 1]),
                    ml,
                    lanes,
                    &mut cur_s[0],
                    scratch,
                    Some(&mut cur_c[0]),
                    self.kernel,
                );
            }
        }
        let h_top = &states[self.layers.len() - 1].h;
        let top_cache = h_caches.last_mut().expect("model has layers");
        let l = self.num_labels();
        self.out.forward_lanes_cached(
            h_top,
            Some(top_cache),
            ml,
            lanes,
            Some(&self.out_bias),
            out,
            qout,
            self.kernel,
            false,
        );
        for &lane in lanes {
            log_softmax_rows(&mut out[lane * l..(lane + 1) * l], 1, l);
        }
    }

    /// Run a full utterance (batch 1) and return `[T, num_labels]`
    /// log-posteriors — the evaluation path.
    pub fn forward_utt(&self, feats: &[f32], num_frames: usize) -> Vec<f32> {
        let d = self.input_dim();
        debug_assert_eq!(feats.len(), num_frames * d);
        let mut state = self.new_state(1);
        let l = self.num_labels();
        let mut out = vec![0f32; num_frames * l];
        for t in 0..num_frames {
            let (x, y) = (&feats[t * d..(t + 1) * d], &mut out[t * l..(t + 1) * l]);
            self.step(x, &mut state, y);
        }
        out
    }
}

#[cfg(test)]
pub use tests::random_qam;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::model_fmt::{ModelHeader, QamFile};
    use crate::util::prop::Gen;
    use std::collections::BTreeMap;

    /// Construct a random float .qam in memory.
    pub fn random_qam(
        num_layers: usize,
        cell: usize,
        proj: Option<usize>,
        input_dim: usize,
        labels: usize,
        g: &mut Gen,
    ) -> QamFile {
        let rec = proj.unwrap_or(cell);
        let mut tensors = BTreeMap::new();
        fn mk(
            tensors: &mut BTreeMap<String, Tensor>,
            name: String,
            i: usize,
            o: usize,
            g: &mut Gen,
        ) {
            let scale = 1.0 / (i as f32).sqrt();
            tensors.insert(
                name,
                Tensor::F32 { shape: vec![i, o], data: g.vec_normal(i * o, scale) },
            );
        }
        for l in 0..num_layers {
            let ind = if l == 0 { input_dim } else { rec };
            mk(&mut tensors, format!("l{l}.wx"), ind, 4 * cell, g);
            mk(&mut tensors, format!("l{l}.wh"), rec, 4 * cell, g);
            tensors.insert(
                format!("l{l}.b"),
                Tensor::F32 { shape: vec![4 * cell], data: vec![0.0; 4 * cell] },
            );
            if let Some(p) = proj {
                mk(&mut tensors, format!("l{l}.wp"), cell, p, g);
            }
        }
        mk(&mut tensors, "out.w".into(), rec, labels, g);
        tensors.insert(
            "out.b".into(),
            Tensor::F32 { shape: vec![labels], data: vec![0.0; labels] },
        );
        QamFile {
            header: ModelHeader {
                name: "rand".into(),
                num_layers,
                cell_dim: cell,
                proj_dim: proj,
                input_dim,
                num_labels: labels,
                quantized: false,
                quantize_output: false,
                param_count: 0,
            },
            tensors,
        }
    }

    #[test]
    fn step_output_is_log_distribution() {
        let mut g = Gen::new(5);
        let qam = random_qam(2, 8, Some(4), 10, 7, &mut g);
        for mode in [ExecMode::Float, ExecMode::Quant, ExecMode::QuantAll] {
            let m = AcousticModel::from_qam(&qam, mode).unwrap();
            let mut st = m.new_state(3);
            let x = g.vec_normal(3 * 10, 1.0);
            let mut out = vec![0f32; 3 * 7];
            m.step(&x, &mut st, &mut out);
            for b in 0..3 {
                let s: f32 = out[b * 7..(b + 1) * 7].iter().map(|v| v.exp()).sum();
                assert!((s - 1.0).abs() < 1e-4, "mode {mode:?}");
            }
        }
    }

    #[test]
    fn quant_close_to_float_on_sequence() {
        let mut g = Gen::new(6);
        let qam = random_qam(2, 12, None, 8, 5, &mut g);
        let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        // Pinned to the seed scheme: the 0.5 ceiling is an 8-bit bound and
        // must hold regardless of any QUANTASR_ISQ set by the CI matrix.
        let mq =
            AcousticModel::from_qam_scheme(&qam, ExecMode::Quant, QuantScheme::PerMatrixU8)
                .unwrap();
        let feats = g.vec_normal(20 * 8, 1.0);
        let of = mf.forward_utt(&feats, 20);
        let oq = mq.forward_utt(&feats, 20);
        let mut max_err = 0.0f32;
        for (a, b) in of.iter().zip(&oq) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 0.5, "quantized log-probs drifted: {max_err}");
    }

    #[test]
    fn schemes_close_to_float_on_sequence() {
        // Scheme-aware tolerance: per-channel u8 must be at least as close
        // to float as the seed scheme's documented bound; int4 is coarser
        // (4-bit weight grid) and gets a wider, still-bounded ceiling.
        let mut g = Gen::new(0x5CE);
        let qam = random_qam(2, 12, None, 8, 5, &mut g);
        let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        let feats = g.vec_normal(20 * 8, 1.0);
        let of = mf.forward_utt(&feats, 20);
        for (scheme, bound) in [
            (QuantScheme::PerMatrixU8, 0.5f32),
            (QuantScheme::PerChannelU8, 0.5),
            (QuantScheme::PerChannelI4, 2.0),
        ] {
            let mq = AcousticModel::from_qam_scheme(&qam, ExecMode::Quant, scheme).unwrap();
            let oq = mq.forward_utt(&feats, 20);
            let mut max_err = 0.0f32;
            for (a, b) in of.iter().zip(&oq) {
                max_err = max_err.max((a - b).abs());
            }
            assert!(max_err < bound, "{scheme:?} log-probs drifted: {max_err} (bound {bound})");
        }
    }

    #[test]
    fn scheme_rungs_bit_identical_through_lstm_step() {
        // The (scheme × rung) contract at full-model depth: for every
        // requantization scheme, every kernel rung this host can run must
        // produce bit-identical posteriors through the LSTM step path
        // (fused x·Wx + h·Wh, projection, softmax input).
        fn rungs() -> Vec<Kernel> {
            let mut ks = vec![Kernel::Scalar, Kernel::Unrolled, Kernel::PackedScalar];
            #[cfg(target_arch = "x86_64")]
            if crate::quant::gemm::avx2_available() {
                ks.push(Kernel::Avx2);
                ks.push(Kernel::PackedAvx2);
            }
            #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
            if crate::quant::gemm::vnni_available() {
                ks.push(Kernel::PackedVnni);
            }
            #[cfg(target_arch = "aarch64")]
            if crate::quant::gemm::neon_dot_available() {
                ks.push(Kernel::PackedNeonDot);
            }
            ks
        }
        let mut g = Gen::new(0x5B17);
        let qam = random_qam(2, 10, Some(5), 6, 9, &mut g);
        let feats = g.vec_normal(7 * 6, 1.0);
        for scheme in
            [QuantScheme::PerMatrixU8, QuantScheme::PerChannelU8, QuantScheme::PerChannelI4]
        {
            let mut m = AcousticModel::from_qam_scheme(&qam, ExecMode::QuantAll, scheme).unwrap();
            m.kernel = Kernel::Scalar;
            let want = m.forward_utt(&feats, 7);
            for kern in rungs() {
                m.kernel = kern;
                let got = m.forward_utt(&feats, 7);
                assert!(
                    got == want,
                    "{scheme:?} kernel {kern:?}: posteriors not bit-identical to Scalar"
                );
            }
        }
    }

    #[test]
    fn requantize_scheme_round_trips_widths() {
        // u8 → i4 → u8 in place: the scheme tag follows, every inner
        // matrix stays packed at the new width, and the model still steps.
        let mut g = Gen::new(0x4E0);
        let qam = random_qam(2, 8, Some(4), 6, 7, &mut g);
        let mut m =
            AcousticModel::from_qam_scheme(&qam, ExecMode::Quant, QuantScheme::PerMatrixU8)
                .unwrap();
        assert_eq!(m.scheme_name(), "per-matrix-u8");
        m.requantize_scheme(QuantScheme::PerChannelI4);
        assert_eq!(m.scheme_name(), "per-channel-i4");
        for l in &m.layers {
            let Linear::Quant(q) = &l.wx else { panic!() };
            assert_eq!(q.packed.as_ref().unwrap().bits, 4);
        }
        let mut st = m.new_state(1);
        let x = g.vec_normal(6, 1.0);
        let mut out = vec![0f32; 7];
        m.step(&x, &mut st, &mut out);
        let s: f32 = out.iter().map(|v| v.exp()).sum();
        assert!((s - 1.0).abs() < 1e-4);
        m.requantize_scheme(QuantScheme::PerChannelU8);
        assert_eq!(m.scheme_name(), "per-channel-u8");
        let Linear::Quant(q) = &m.layers[0].wx else { panic!() };
        assert_eq!(q.packed.as_ref().unwrap().bits, 8);
    }

    #[test]
    fn batch_and_single_stream_agree() {
        // Running 2 streams batched must equal running them separately.
        let mut g = Gen::new(8);
        let qam = random_qam(2, 10, Some(5), 6, 9, &mut g);
        let m = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        let xa = g.vec_normal(5 * 6, 1.0);
        let xb = g.vec_normal(5 * 6, 1.0);
        let oa = m.forward_utt(&xa, 5);
        let ob = m.forward_utt(&xb, 5);
        let mut st = m.new_state(2);
        let mut out = vec![0f32; 2 * 9];
        for t in 0..5 {
            let mut x = Vec::new();
            x.extend_from_slice(&xa[t * 6..(t + 1) * 6]);
            x.extend_from_slice(&xb[t * 6..(t + 1) * 6]);
            m.step(&x, &mut st, &mut out);
            for j in 0..9 {
                assert!((out[j] - oa[t * 9 + j]).abs() < 2e-4, "t={t} j={j}");
                assert!((out[9 + j] - ob[t * 9 + j]).abs() < 2e-4, "t={t} j={j}");
            }
        }
    }

    #[test]
    fn arena_lane_bit_identical_to_solo_utterance() {
        // A stream stepped in a shared arena lane, packed with random
        // co-rider lanes, must produce *bit-identical* posteriors to the
        // same stream run alone through the batch-1 path — the per-row
        // quantization contract that makes lane residency invisible.
        for mode in [ExecMode::Float, ExecMode::Quant, ExecMode::QuantAll] {
            let mut g = Gen::new(31);
            let qam = random_qam(2, 10, Some(5), 6, 9, &mut g);
            let m = AcousticModel::from_qam(&qam, mode).unwrap();
            let (t_steps, ml, lane) = (7usize, 4usize, 2usize);
            let feats = g.vec_normal(t_steps * 6, 1.0);
            let solo = m.forward_utt(&feats, t_steps);

            let mut arena = m.new_arena(ml);
            let lanes: Vec<usize> = (0..ml).collect();
            let mut x = vec![0f32; ml * 6];
            let mut out = vec![0f32; ml * 9];
            for t in 0..t_steps {
                // co-riders get fresh random frames each tick
                for co in 0..ml {
                    let frame = g.vec_normal(6, 1.0);
                    x[co * 6..(co + 1) * 6].copy_from_slice(&frame);
                }
                x[lane * 6..(lane + 1) * 6].copy_from_slice(&feats[t * 6..(t + 1) * 6]);
                m.arena_step(&mut arena, &lanes, &x, &mut out);
                for j in 0..9 {
                    assert!(
                        out[lane * 9 + j] == solo[t * 9 + j],
                        "mode {mode:?} t={t} j={j}: {} != {} (not bit-identical)",
                        out[lane * 9 + j],
                        solo[t * 9 + j]
                    );
                }
            }
        }
    }

    #[test]
    fn arena_save_load_roundtrips_lane_state() {
        let mut g = Gen::new(32);
        let qam = random_qam(2, 8, Some(4), 6, 7, &mut g);
        let m = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
        let ml = 3;
        let mut arena = m.new_arena(ml);
        let lanes: Vec<usize> = (0..ml).collect();
        let mut out = vec![0f32; ml * 7];
        // Advance all lanes a few steps.
        for _ in 0..4 {
            let x = g.vec_normal(ml * 6, 1.0);
            m.arena_step(&mut arena, &lanes, &x, &mut out);
        }
        // Park lane 1, trash it with another stream, restore, and check the
        // next step matches what an untouched lane would produce.
        let mut reference = m.new_arena(ml);
        reference.load_lane(1, &arena.save_lane(1));
        let parked = arena.save_lane(1);
        arena.reset_lane(1);
        for _ in 0..3 {
            let x = g.vec_normal(ml * 6, 1.0);
            m.arena_step(&mut arena, &[1], &x, &mut out);
        }
        arena.load_lane(1, &parked);
        let x = g.vec_normal(ml * 6, 1.0);
        let mut out_ref = vec![0f32; ml * 7];
        m.arena_step(&mut arena, &[1], &x, &mut out);
        m.arena_step(&mut reference, &[1], &x, &mut out_ref);
        assert_eq!(out[7..14], out_ref[7..14], "save/load must round-trip exactly");
    }

    #[test]
    fn arena_reset_lane_zeroes_state() {
        let mut g = Gen::new(33);
        let qam = random_qam(1, 6, None, 4, 5, &mut g);
        let m = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        let mut arena = m.new_arena(2);
        let x = g.vec_normal(2 * 4, 1.0);
        let mut out = vec![0f32; 2 * 5];
        m.arena_step(&mut arena, &[0, 1], &x, &mut out);
        arena.reset_lane(0);
        assert!(arena.layers[0].c[..6].iter().all(|&v| v == 0.0));
        assert!(arena.layers[0].c[6..].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn reset_stream_isolates_state() {
        let mut g = Gen::new(9);
        let qam = random_qam(1, 6, None, 4, 5, &mut g);
        let m = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        let mut st = m.new_state(2);
        let x = g.vec_normal(2 * 4, 1.0);
        let mut out = vec![0f32; 2 * 5];
        m.step(&x, &mut st, &mut out);
        st.reset_stream(&m, 0);
        assert!(st.layers[0].c[..6].iter().all(|&v| v == 0.0));
        assert!(st.layers[0].c[6..].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn quant_model_packs_every_matrix_at_load() {
        // Pack-once-at-load: every inner matrix of a Quant-mode model owns
        // a packed mirror before the first step (the GEMM never repacks),
        // and a QuantAll model packs the softmax too.
        let mut g = Gen::new(34);
        let qam = random_qam(2, 10, Some(5), 6, 9, &mut g);
        let mq = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
        for l in &mq.layers {
            assert!(l.wx.is_packed() && l.wh.is_packed());
            assert!(l.wp.as_ref().unwrap().is_packed());
        }
        assert!(!mq.out.is_packed(), "Quant mode keeps the softmax float");
        assert!(mq.packed_bytes() > 0);
        let mall = AcousticModel::from_qam(&qam, ExecMode::QuantAll).unwrap();
        assert!(mall.out.is_packed());
        assert!(mall.packed_bytes() > mq.packed_bytes());
        let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        assert_eq!(mf.packed_bytes(), 0);
    }

    #[test]
    fn quant_storage_smaller_than_float() {
        let mut g = Gen::new(10);
        let qam = random_qam(3, 32, Some(16), 64, 41, &mut g);
        let mf = AcousticModel::from_qam(&qam, ExecMode::Float).unwrap();
        let mq = AcousticModel::from_qam(&qam, ExecMode::QuantAll).unwrap();
        assert!(
            (mq.storage_bytes() as f64) < mf.storage_bytes() as f64 / 3.0,
            "{} vs {}",
            mq.storage_bytes(),
            mf.storage_bytes()
        );
    }
}
