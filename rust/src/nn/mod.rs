//! The native inference engine: float and 8-bit-quantized execution of the
//! paper's LSTM acoustic models (§3.1), loaded from `.qam` files.
//!
//! - [`activation`] — sigmoid/tanh/softmax primitives (libm-based; the
//!   LSTM hot path uses the fused SIMD kernels in
//!   [`crate::quant::elementwise`] instead).
//! - [`linear`]     — a dense layer that is either f32 or quantized
//!   (Figure 1: quantize input → integer GEMM → recover → bias → F).
//! - [`lstm`]       — the LSTMP cell (Sak et al. 2014) on top of `linear`.
//! - [`model`]      — the full stacked acoustic model + streaming state:
//!   per-stream [`ModelState`] (batch-contiguous, evaluation path) and the
//!   lane-resident [`BatchArena`] the serving engine steps in place.

pub mod activation;
pub mod linear;
pub mod lstm;
pub mod model;

pub use linear::Linear;
pub use model::{AcousticModel, BatchArena, ExecMode, ModelState, ParkedLane};
