//! Activation functions.  Plain f32 libm math — the paper recovers to
//! float before activations precisely so these stay simple ("this
//! simplifies the implementation of complex activation functions", §3.1).
//!
//! These are the *cold-path* definitions (decoder scores, tests, the
//! softmax).  The LSTM cell's per-tick gate loop runs on the fused SIMD
//! kernels in [`crate::quant::elementwise`] instead, whose polynomial
//! sigmoid/tanh are their own bit-exact scalar reference and stay within
//! a documented 1e-6 absolute of the functions here.

/// Numerically-stable logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        let z = (-x).exp();
        1.0 / (1.0 + z)
    } else {
        let z = x.exp();
        z / (1.0 + z)
    }
}

#[inline]
pub fn tanh(x: f32) -> f32 {
    x.tanh()
}

pub fn sigmoid_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = sigmoid(*v);
    }
}

pub fn tanh_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// In-place log-softmax over each row of an `[batch, n]` buffer.
pub fn log_softmax_rows(x: &mut [f32], batch: usize, n: usize) {
    debug_assert_eq!(x.len(), batch * n);
    for b in 0..batch {
        let row = &mut x[b * n..(b + 1) * n];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v -= mx;
            sum += v.exp();
        }
        let ln = sum.ln();
        for v in row.iter_mut() {
            *v -= ln;
        }
    }
}

/// In-place softmax over each row.
pub fn softmax_rows(x: &mut [f32], batch: usize, n: usize) {
    log_softmax_rows(x, batch, n);
    for v in x.iter_mut() {
        *v = v.exp();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_matches_definition() {
        for &x in &[-50.0f32, -5.0, -0.5, 0.0, 0.5, 5.0, 50.0] {
            let want = 1.0 / (1.0 + (-x as f64).exp());
            assert!((sigmoid(x) as f64 - want).abs() < 1e-6, "x={x}");
        }
    }

    #[test]
    fn sigmoid_extremes_are_finite() {
        assert_eq!(sigmoid(1e10), 1.0);
        assert_eq!(sigmoid(-1e10), 0.0);
    }

    #[test]
    fn log_softmax_normalizes() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        log_softmax_rows(&mut x, 2, 3);
        for b in 0..2 {
            let s: f32 = x[b * 3..(b + 1) * 3].iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // monotone: bigger logits → bigger log-probs
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![0.5f32; 8];
        softmax_rows(&mut x, 2, 4);
        for b in 0..2 {
            let s: f32 = x[b * 4..(b + 1) * 4].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!((x[b * 4] - 0.25).abs() < 1e-6);
        }
    }
}
