//! The LSTMP cell (Sak et al. 2014): standard LSTM with an optional linear
//! recurrent projection, executing over [`Linear`] layers so each weight
//! matrix is independently float or §3.1-quantized (the paper's per-matrix
//! granularity: Wx, Wh, Wp are separate quantization groups).
//!
//! Gate block layout is `[i | f | g | o]`, matching `model.py`,
//! `kernels/lstm_step.py` and the `.qam` export.

use anyhow::{ensure, Result};

use crate::nn::linear::Linear;
use crate::quant::elementwise::{self, EwKernel};
use crate::quant::gemm::{Kernel, QActRows, QScratch};

/// One LSTM(P) layer.
#[derive(Clone, Debug)]
pub struct LstmLayer {
    /// Input weights `[in, 4N]`.
    pub wx: Linear,
    /// Recurrent weights `[rec, 4N]`.
    pub wh: Linear,
    /// Gate bias `[4N]` (always f32; applied after recovery, Figure 1).
    pub bias: Vec<f32>,
    /// Projection `[N, P]` (None ⇒ plain LSTM, rec = N).
    pub wp: Option<Linear>,
    pub cell_dim: usize,
}

/// Recurrent state for one layer at a fixed batch size.
///
/// **Invariant (quantized models):** `h` rows may be consumed through a
/// [`QActRows`] quantization cache (`ModelState`/`BatchArena` hold one
/// per layer).  Whoever rewrites an `h` row outside the step functions
/// must invalidate the matching cache row — go through the provided
/// helpers (`reset_stream`/`copy_stream_from`/`reset_lane`/`load_lane`),
/// which do this; writing `h` directly would leave a stale quantization
/// behind and silently break the cached-equals-uncached contract.
#[derive(Clone, Debug)]
pub struct LayerState {
    /// Cell state `[batch, N]`.
    pub c: Vec<f32>,
    /// Output/recurrent state `[batch, rec]`.
    pub h: Vec<f32>,
}

/// Reusable per-step scratch.  Size it **once** with
/// [`LstmScratch::ensure`] (the model/arena constructors do) — the hot
/// loop then only `debug_assert`s, never resizes or allocates.
#[derive(Default, Clone)]
pub struct LstmScratch {
    pub gates: Vec<f32>,
    pub h_raw: Vec<f32>,
    pub q: QScratch,
}

impl LstmScratch {
    /// Grow the buffers to cover stepping `rows` rows of a layer with
    /// `cell_dim` cells.  Call at state/arena construction (or before the
    /// first step); a no-op once sized.
    pub fn ensure(&mut self, rows: usize, cell_dim: usize) {
        let g = rows * 4 * cell_dim;
        if self.gates.len() < g {
            self.gates.resize(g, 0.0);
        }
        let h = rows * cell_dim;
        if self.h_raw.len() < h {
            self.h_raw.resize(h, 0.0);
        }
    }
}

impl LstmLayer {
    pub fn rec_dim(&self) -> usize {
        self.wp.as_ref().map_or(self.cell_dim, Linear::out_dim)
    }

    pub fn in_dim(&self) -> usize {
        self.wx.in_dim()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.wx.out_dim() == 4 * self.cell_dim, "wx out != 4N");
        ensure!(self.wh.out_dim() == 4 * self.cell_dim, "wh out != 4N");
        ensure!(self.wh.in_dim() == self.rec_dim(), "wh in != rec");
        ensure!(self.bias.len() == 4 * self.cell_dim, "bias != 4N");
        if let Some(wp) = &self.wp {
            ensure!(wp.in_dim() == self.cell_dim, "wp in != N");
        }
        Ok(())
    }

    pub fn zero_state(&self, batch: usize) -> LayerState {
        LayerState {
            c: vec![0.0; batch * self.cell_dim],
            h: vec![0.0; batch * self.rec_dim()],
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.wx.storage_bytes()
            + self.wh.storage_bytes()
            + self.bias.len() * 4
            + self.wp.as_ref().map_or(0, Linear::storage_bytes)
    }

    /// One timestep: `x [batch, in]` + state → state updated in place.
    /// After the call `state.h` holds the layer output (projected if P).
    /// Convenience wrapper over [`LstmLayer::step_cached`] with no
    /// activation caches (sizes the scratch on first use).
    pub fn step(
        &self,
        x: &[f32],
        batch: usize,
        state: &mut LayerState,
        s: &mut LstmScratch,
        kernel: Kernel,
    ) {
        s.ensure(batch, self.cell_dim);
        self.step_cached(x, None, batch, state, s, None, kernel);
    }

    /// One timestep with optional quantized-activation caches:
    /// `x_cache` holds prequantized rows of `x` (filled by whoever wrote
    /// `x` — in the model stack, the previous layer's output cache), and
    /// `h_cache` caches this layer's own `state.h` quantization (consumed
    /// here by `Wh`, re-consumed by the next layer's `Wx`, and
    /// invalidated for the rows this step rewrites).  Caches only change
    /// *when* quantization happens, never its result — outputs are
    /// bit-identical with any combination of caches present.
    ///
    /// The elementwise cell update runs on the fused SIMD kernel
    /// ([`crate::quant::elementwise`]) and writes the pre-projection
    /// output straight into `state.h` (plain LSTM) or the projection
    /// input buffer (LSTMP) — the gate buffer is only read.
    ///
    /// The scratch must already be sized ([`LstmScratch::ensure`]); this
    /// hot path never allocates.
    #[allow(clippy::too_many_arguments)]
    pub fn step_cached(
        &self,
        x: &[f32],
        x_cache: Option<&mut QActRows>,
        batch: usize,
        state: &mut LayerState,
        s: &mut LstmScratch,
        mut h_cache: Option<&mut QActRows>,
        kernel: Kernel,
    ) {
        let n = self.cell_dim;
        debug_assert_eq!(x.len(), batch * self.in_dim());
        debug_assert_eq!(state.c.len(), batch * n);
        debug_assert_eq!(state.h.len(), batch * self.rec_dim());
        let LstmScratch { gates, h_raw, q } = s;
        debug_assert!(gates.len() >= batch * 4 * n, "LstmScratch::ensure not called");
        let gates = &mut gates[..batch * 4 * n];

        // gates = x·Wx + h·Wh + b   (two GEMMs fused via accumulate)
        self.wx.forward_cached(x, x_cache, batch, Some(&self.bias), gates, q, kernel, false);
        self.wh.forward_cached(
            &state.h,
            h_cache.as_deref_mut(),
            batch,
            None,
            gates,
            q,
            kernel,
            true,
        );

        // Fused elementwise cell update (layout [i | f | g | o]):
        // c = f·c + i·g and h = o·tanh(c) in one pass over the gates.
        let ewk = EwKernel::for_gemm(kernel);
        match &self.wp {
            None => {
                elementwise::lstm_cell_batch(gates, &mut state.c, &mut state.h, batch, n, ewk);
            }
            Some(wp) => {
                debug_assert!(h_raw.len() >= batch * n, "LstmScratch::ensure not called");
                let h_raw = &mut h_raw[..batch * n];
                elementwise::lstm_cell_batch(gates, &mut state.c, h_raw, batch, n, ewk);
                wp.forward(h_raw, batch, None, &mut state.h, q, kernel, false);
            }
        }
        if let Some(hc) = h_cache {
            hc.invalidate_prefix(batch);
        }
    }

    /// Lane-masked timestep over **lane-resident** buffers: `x` is
    /// `[max_lanes, in]` and `state` holds `[max_lanes, N]` / `[max_lanes,
    /// rec]`; only the rows listed in `lanes` are read and updated, in
    /// place.  This is the [`crate::nn::model::BatchArena`] hot path — a
    /// stream's recurrent state never leaves its lane, so the serving
    /// engine does no per-tick gather/scatter.  Numerics per lane are
    /// bit-identical to [`LstmLayer::step`] on that lane's row alone (the
    /// per-row quantization contract in `quant::gemm`).
    pub fn step_lanes(
        &self,
        x: &[f32],
        max_lanes: usize,
        lanes: &[usize],
        state: &mut LayerState,
        s: &mut LstmScratch,
        kernel: Kernel,
    ) {
        s.ensure(max_lanes, self.cell_dim);
        self.step_lanes_cached(x, None, max_lanes, lanes, state, s, None, kernel);
    }

    /// Lane-masked timestep with optional activation caches — the cached
    /// twin of [`LstmLayer::step_lanes`]; cache semantics as in
    /// [`LstmLayer::step_cached`] (per listed lane).  The scratch must
    /// already be sized; this hot path never allocates.
    #[allow(clippy::too_many_arguments)]
    pub fn step_lanes_cached(
        &self,
        x: &[f32],
        x_cache: Option<&mut QActRows>,
        max_lanes: usize,
        lanes: &[usize],
        state: &mut LayerState,
        s: &mut LstmScratch,
        mut h_cache: Option<&mut QActRows>,
        kernel: Kernel,
    ) {
        let n = self.cell_dim;
        debug_assert_eq!(x.len(), max_lanes * self.in_dim());
        debug_assert_eq!(state.c.len(), max_lanes * n);
        debug_assert_eq!(state.h.len(), max_lanes * self.rec_dim());
        let LstmScratch { gates, h_raw, q } = s;
        debug_assert!(gates.len() >= max_lanes * 4 * n, "LstmScratch::ensure not called");
        let gates = &mut gates[..max_lanes * 4 * n];

        // gates = x·Wx + h·Wh + b, active lanes only.
        self.wx.forward_lanes_cached(
            x,
            x_cache,
            max_lanes,
            lanes,
            Some(&self.bias),
            gates,
            q,
            kernel,
            false,
        );
        self.wh.forward_lanes_cached(
            &state.h,
            h_cache.as_deref_mut(),
            max_lanes,
            lanes,
            None,
            gates,
            q,
            kernel,
            true,
        );

        // Fused elementwise cell update per active lane.
        let ewk = EwKernel::for_gemm(kernel);
        match &self.wp {
            None => {
                elementwise::lstm_cell_lanes(
                    gates,
                    &mut state.c,
                    &mut state.h,
                    max_lanes,
                    lanes,
                    n,
                    ewk,
                );
            }
            Some(wp) => {
                debug_assert!(h_raw.len() >= max_lanes * n, "LstmScratch::ensure not called");
                let h_raw = &mut h_raw[..max_lanes * n];
                elementwise::lstm_cell_lanes(gates, &mut state.c, h_raw, max_lanes, lanes, n, ewk);
                wp.forward_lanes(h_raw, max_lanes, lanes, None, &mut state.h, q, kernel, false);
            }
        }
        if let Some(hc) = h_cache {
            for &lane in lanes {
                hc.invalidate_row(lane);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::model_fmt::Tensor;
    use crate::util::prop::Gen;

    fn layer(in_dim: usize, n: usize, p: Option<usize>, g: &mut Gen) -> LstmLayer {
        let t = |i: usize, o: usize, g: &mut Gen| {
            Linear::from_tensor(&Tensor::F32 {
                shape: vec![i, o],
                data: g.vec_normal(i * o, (1.0 / (i as f32).sqrt()) * 1.7),
            })
            .unwrap()
        };
        let rec = p.unwrap_or(n);
        LstmLayer {
            wx: t(in_dim, 4 * n, g),
            wh: t(rec, 4 * n, g),
            bias: g.vec_normal(4 * n, 0.1),
            wp: p.map(|pp| t(n, pp, g)),
            cell_dim: n,
        }
    }

    /// Direct (unfused, f64) reference implementation of one step.
    fn reference_step(
        l: &LstmLayer,
        x: &[f32],
        batch: usize,
        c: &mut Vec<f32>,
        h: &mut Vec<f32>,
    ) {
        let n = l.cell_dim;
        let in_dim = l.in_dim();
        let rec = l.rec_dim();
        let wx = match &l.wx { Linear::Float(f) => f, _ => panic!() };
        let wh = match &l.wh { Linear::Float(f) => f, _ => panic!() };
        let mut new_h = vec![0f32; batch * rec];
        for bi in 0..batch {
            let mut gates = vec![0f64; 4 * n];
            for o in 0..4 * n {
                let mut acc = l.bias[o] as f64;
                for k in 0..in_dim {
                    acc += x[bi * in_dim + k] as f64 * wx.data[o * in_dim + k] as f64;
                }
                for k in 0..rec {
                    acc += h[bi * rec + k] as f64 * wh.data[o * rec + k] as f64;
                }
                gates[o] = acc;
            }
            let sig = |v: f64| 1.0 / (1.0 + (-v).exp());
            let mut pre = vec![0f32; n];
            for j in 0..n {
                let i_g = sig(gates[j]);
                let f_g = sig(gates[n + j]);
                let g_g = gates[2 * n + j].tanh();
                let o_g = sig(gates[3 * n + j]);
                let c_new = f_g * c[bi * n + j] as f64 + i_g * g_g;
                c[bi * n + j] = c_new as f32;
                pre[j] = (o_g * c_new.tanh()) as f32;
            }
            match &l.wp {
                None => new_h[bi * rec..(bi + 1) * rec].copy_from_slice(&pre),
                Some(Linear::Float(wp)) => {
                    for o in 0..rec {
                        let mut acc = 0f64;
                        for k in 0..n {
                            acc += pre[k] as f64 * wp.data[o * n + k] as f64;
                        }
                        new_h[bi * rec + o] = acc as f32;
                    }
                }
                _ => panic!(),
            }
        }
        *h = new_h;
    }

    #[test]
    fn step_matches_reference_plain_and_projected() {
        for p in [None, Some(5)] {
            let mut g = Gen::new(42);
            let l = layer(12, 8, p, &mut g);
            l.validate().unwrap();
            let batch = 3;
            let mut st = l.zero_state(batch);
            let mut c_ref = st.c.clone();
            let mut h_ref = st.h.clone();
            let mut s = LstmScratch::default();
            for _t in 0..6 {
                let x = g.vec_normal(batch * 12, 1.0);
                l.step(&x, batch, &mut st, &mut s, Kernel::Auto);
                reference_step(&l, &x, batch, &mut c_ref, &mut h_ref);
            }
            for (a, b) in st.c.iter().zip(&c_ref) {
                assert!((a - b).abs() < 1e-4, "c: {a} vs {b} (p={p:?})");
            }
            for (a, b) in st.h.iter().zip(&h_ref) {
                assert!((a - b).abs() < 1e-4, "h: {a} vs {b} (p={p:?})");
            }
        }
    }

    #[test]
    fn quantized_step_close_to_float() {
        let mut g = Gen::new(7);
        let l = layer(16, 12, Some(6), &mut g);
        let lq = LstmLayer {
            wx: l.wx.quantize_now(),
            wh: l.wh.quantize_now(),
            bias: l.bias.clone(),
            wp: l.wp.as_ref().map(Linear::quantize_now),
            cell_dim: l.cell_dim,
        };
        let batch = 2;
        let mut st_f = l.zero_state(batch);
        let mut st_q = lq.zero_state(batch);
        let mut s = LstmScratch::default();
        for _t in 0..10 {
            let x = g.vec_normal(batch * 16, 1.0);
            l.step(&x, batch, &mut st_f, &mut s, Kernel::Auto);
            lq.step(&x, batch, &mut st_q, &mut s, Kernel::Auto);
        }
        // States drift slowly; must stay within a small absolute envelope.
        for (a, b) in st_f.h.iter().zip(&st_q.h) {
            assert!((a - b).abs() < 0.15, "{a} vs {b}");
        }
    }

    #[test]
    fn step_lanes_full_set_matches_step_bitwise() {
        // Stepping every lane of a lane-resident state must equal the
        // contiguous batch step bit-for-bit (same per-row arithmetic).
        for p in [None, Some(5)] {
            for quant in [false, true] {
                let mut g = Gen::new(77);
                let mut l = layer(12, 8, p, &mut g);
                if quant {
                    l = LstmLayer {
                        wx: l.wx.quantize_now(),
                        wh: l.wh.quantize_now(),
                        bias: l.bias.clone(),
                        wp: l.wp.as_ref().map(Linear::quantize_now),
                        cell_dim: l.cell_dim,
                    };
                }
                let batch = 4;
                let mut st_a = l.zero_state(batch);
                let mut st_b = l.zero_state(batch);
                let mut sa = LstmScratch::default();
                let mut sb = LstmScratch::default();
                let lanes: Vec<usize> = (0..batch).collect();
                for _t in 0..5 {
                    let x = g.vec_normal(batch * 12, 1.0);
                    l.step(&x, batch, &mut st_a, &mut sa, Kernel::Auto);
                    l.step_lanes(&x, batch, &lanes, &mut st_b, &mut sb, Kernel::Auto);
                    assert_eq!(st_a.c, st_b.c, "p={p:?} quant={quant}");
                    assert_eq!(st_a.h, st_b.h, "p={p:?} quant={quant}");
                }
            }
        }
    }

    #[test]
    fn quantized_step_bit_identical_across_kernel_ladder() {
        // The packed-panel rungs (and their panel-parallel splits) must
        // reproduce the scalar rung bit-for-bit through a full recurrent
        // step — gates, cell update and projection included — so kernel
        // dispatch can never perturb a served stream.
        let mut kernels = vec![Kernel::Unrolled, Kernel::PackedScalar, Kernel::Auto];
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            kernels.push(Kernel::Avx2);
            kernels.push(Kernel::PackedAvx2);
        }
        let mut g = Gen::new(91);
        let l = layer(18, 10, Some(6), &mut g);
        let lq = LstmLayer {
            wx: l.wx.quantize_now(),
            wh: l.wh.quantize_now(),
            bias: l.bias.clone(),
            wp: l.wp.as_ref().map(Linear::quantize_now),
            cell_dim: l.cell_dim,
        };
        let batch = 3;
        let xs: Vec<Vec<f32>> = (0..4).map(|_| g.vec_normal(batch * 18, 1.0)).collect();
        let mut st_ref = lq.zero_state(batch);
        let mut s_ref = LstmScratch::default();
        for x in &xs {
            lq.step(x, batch, &mut st_ref, &mut s_ref, Kernel::Scalar);
        }
        for &kern in &kernels {
            let mut st = lq.zero_state(batch);
            let mut s = LstmScratch::default();
            for x in &xs {
                lq.step(x, batch, &mut st, &mut s, kern);
            }
            assert_eq!(st.c, st_ref.c, "kernel {kern:?} drifted (c)");
            assert_eq!(st.h, st_ref.h, "kernel {kern:?} drifted (h)");
        }
    }

    #[test]
    fn cached_step_bit_identical_to_uncached() {
        // Running a sequence with a persistent h-quantization cache must
        // equal the cache-free path bit for bit (the cache only changes
        // *when* rows are quantized, never the result), for plain and
        // projected layers, float and quantized.
        for p in [None, Some(5)] {
            for quant in [false, true] {
                let mut g = Gen::new(0xCAC);
                let mut l = layer(12, 9, p, &mut g);
                if quant {
                    l = LstmLayer {
                        wx: l.wx.quantize_now(),
                        wh: l.wh.quantize_now(),
                        bias: l.bias.clone(),
                        wp: l.wp.as_ref().map(Linear::quantize_now),
                        cell_dim: l.cell_dim,
                    };
                }
                let batch = 3;
                let mut st_a = l.zero_state(batch);
                let mut st_b = l.zero_state(batch);
                let mut sa = LstmScratch::default();
                let mut sb = LstmScratch::default();
                sb.ensure(batch, l.cell_dim);
                let mut h_cache = QActRows::sized(batch, l.rec_dim());
                for _t in 0..6 {
                    let x = g.vec_normal(batch * 12, 1.0);
                    l.step(&x, batch, &mut st_a, &mut sa, Kernel::Auto);
                    l.step_cached(
                        &x,
                        None,
                        batch,
                        &mut st_b,
                        &mut sb,
                        Some(&mut h_cache),
                        Kernel::Auto,
                    );
                    assert_eq!(st_a.c, st_b.c, "p={p:?} quant={quant}");
                    assert_eq!(st_a.h, st_b.h, "p={p:?} quant={quant}");
                }
            }
        }
    }

    #[test]
    fn step_lanes_leaves_inactive_lanes_untouched() {
        let mut g = Gen::new(78);
        let l = layer(10, 6, Some(3), &mut g);
        let max_lanes = 3;
        let mut st = l.zero_state(max_lanes);
        let mut s = LstmScratch::default();
        // Warm every lane with one full step so state is nonzero.
        let x = g.vec_normal(max_lanes * 10, 1.0);
        let all: Vec<usize> = (0..max_lanes).collect();
        l.step_lanes(&x, max_lanes, &all, &mut st, &mut s, Kernel::Auto);
        let c_before = st.c.clone();
        let h_before = st.h.clone();
        // Step lane 1 only.
        let x2 = g.vec_normal(max_lanes * 10, 1.0);
        l.step_lanes(&x2, max_lanes, &[1], &mut st, &mut s, Kernel::Auto);
        for lane in [0, 2] {
            assert_eq!(st.c[lane * 6..(lane + 1) * 6], c_before[lane * 6..(lane + 1) * 6]);
            assert_eq!(st.h[lane * 3..(lane + 1) * 3], h_before[lane * 3..(lane + 1) * 3]);
        }
        assert_ne!(st.c[6..12], c_before[6..12], "active lane must advance");
    }

    #[test]
    fn state_shapes() {
        let mut g = Gen::new(1);
        let l = layer(10, 6, Some(3), &mut g);
        let st = l.zero_state(4);
        assert_eq!(st.c.len(), 24);
        assert_eq!(st.h.len(), 12);
        assert_eq!(l.rec_dim(), 3);
    }

    #[test]
    fn validate_catches_shape_bugs() {
        let mut g = Gen::new(2);
        let mut l = layer(10, 6, None, &mut g);
        l.bias = vec![0.0; 3];
        assert!(l.validate().is_err());
    }
}
