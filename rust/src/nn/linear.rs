//! A dense layer executing as either f32 or §3.1-quantized arithmetic.
//!
//! Built from a `.qam` tensor:
//! - stored **U8Q** → [`Linear::Quant`] uses the stored V' grid directly
//!   (no re-quantization — bit-faithful to what QAT trained);
//! - stored **F32** → [`Linear::Float`], or [`Linear::quantize_now`]
//!   converts it post-hoc (the paper's 'mismatch' condition).

use anyhow::{bail, Result};

use crate::io::model_fmt::Tensor;
use crate::quant::elementwise::EwKernel;
use crate::quant::gemm::{
    fgemm, fgemm_lanes, qgemm, qgemm_cached, qgemm_lanes, qgemm_lanes_cached, FMatrix, Kernel,
    QActRows, QScratch,
};
use crate::quant::{Granularity, QMatrix, QuantScheme};

/// A `y = x·W (+ b)` layer; weights `[in, out]` in math terms.
#[derive(Clone, Debug)]
pub enum Linear {
    Float(FMatrix),
    Quant(QMatrix),
}

impl Linear {
    /// Build from a `.qam` tensor (shape must be `[in, out]`).
    pub fn from_tensor(t: &Tensor) -> Result<Self> {
        let shape = t.shape();
        if shape.len() != 2 {
            bail!("linear weight must be 2-D, got {shape:?}");
        }
        let (in_dim, out_dim) = (shape[0], shape[1]);
        Ok(match t {
            Tensor::F32 { data, .. } => {
                Linear::Float(FMatrix::from_math_layout(data, in_dim, out_dim))
            }
            Tensor::U8Q { data, .. } => {
                let p = t.qparams().unwrap();
                Linear::Quant(QMatrix::from_stored(data, in_dim, out_dim, p))
            }
        })
    }

    /// Post-training quantization of a float layer (the 'mismatch' path).
    pub fn quantize_now(&self) -> Linear {
        self.quantize_bits(8)
    }

    /// In-situ requantization under a [`QuantScheme`] (mistral.rs-style
    /// ISQ): a quantized layer first recovers its f32 weights, then
    /// requantizes under the requested scheme — the `.qam` grid is the
    /// source of truth, never mutated.  `PerMatrixU8` on a float layer is
    /// identical to [`Linear::quantize_now`].
    pub fn quantize_scheme(&self, scheme: QuantScheme) -> Linear {
        let recovered;
        let f = match self {
            Linear::Float(f) => f,
            Linear::Quant(_) => {
                let Linear::Float(f) = self.to_float() else { unreachable!() };
                recovered = f;
                &recovered
            }
        };
        Linear::Quant(QMatrix::from_f32_transposed_scheme(
            &f.data, f.in_dim, f.out_dim, scheme,
        ))
    }

    /// Post-training quantization with `bits` ∈ 2..=8 resolution (E5
    /// ablation; the paper cites Dündar & Rose finding 10 bits necessary
    /// pre-QAT — this knob reproduces that degradation curve).
    pub fn quantize_bits(&self, bits: u32) -> Linear {
        let scale = ((1u32 << bits) - 1) as f32;
        match self {
            Linear::Quant(q) => Linear::Quant(q.clone()),
            Linear::Float(f) => Linear::Quant(QMatrix::from_f32_transposed_scaled(
                &f.data,
                f.in_dim,
                f.out_dim,
                Granularity::PerMatrix,
                scale,
            )),
        }
    }

    /// Recover a float view (for cross-checks / the PJRT comparison).
    pub fn to_float(&self) -> Linear {
        match self {
            Linear::Float(f) => Linear::Float(f.clone()),
            Linear::Quant(q) => {
                let w = q.recover_math_layout();
                Linear::Float(FMatrix::from_math_layout(&w, q.in_dim, q.out_dim))
            }
        }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            Linear::Float(f) => f.in_dim,
            Linear::Quant(q) => q.in_dim,
        }
    }

    pub fn out_dim(&self) -> usize {
        match self {
            Linear::Float(f) => f.out_dim,
            Linear::Quant(q) => q.out_dim,
        }
    }

    pub fn is_quant(&self) -> bool {
        matches!(self, Linear::Quant(_))
    }

    /// Whether this layer carries a packed-panel mirror — every
    /// PerMatrix-quantized layer does (built once at load/quantization),
    /// which is what routes its GEMMs onto the packed microkernels.
    pub fn is_packed(&self) -> bool {
        matches!(self, Linear::Quant(q) if q.packed.is_some())
    }

    pub fn storage_bytes(&self) -> usize {
        match self {
            Linear::Float(f) => f.storage_bytes(),
            Linear::Quant(q) => q.storage_bytes(),
        }
    }

    /// Bytes held by the packed-panel serving mirror (0 for float layers).
    pub fn packed_bytes(&self) -> usize {
        match self {
            Linear::Float(_) => 0,
            Linear::Quant(q) => q.packed_bytes(),
        }
    }

    /// Lane-masked `y (+)= x·W + b` over lane-resident `[max_lanes, in]` /
    /// `[max_lanes, out]` buffers: only rows listed in `lanes` are read and
    /// written (the serving arena's in-place hot path).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_lanes(
        &self,
        x: &[f32],
        max_lanes: usize,
        lanes: &[usize],
        bias: Option<&[f32]>,
        y: &mut [f32],
        scratch: &mut QScratch,
        kernel: Kernel,
        accumulate: bool,
    ) {
        match self {
            Linear::Float(f) => fgemm_lanes(x, max_lanes, lanes, f, bias, y, accumulate),
            Linear::Quant(q) => {
                qgemm_lanes(x, max_lanes, lanes, q, bias, y, scratch, kernel, accumulate)
            }
        }
    }

    /// `y (+)= x·W + b` for a `[batch, in]` input.
    pub fn forward(
        &self,
        x: &[f32],
        batch: usize,
        bias: Option<&[f32]>,
        y: &mut [f32],
        scratch: &mut QScratch,
        kernel: Kernel,
        accumulate: bool,
    ) {
        match self {
            Linear::Float(f) => fgemm(x, batch, f, bias, y, accumulate),
            Linear::Quant(q) => qgemm(x, batch, q, bias, y, scratch, kernel, accumulate),
        }
    }

    /// [`Linear::forward`] with an optional quantized-activation cache
    /// for `x`: a quantized layer re-quantizes only the cache's stale
    /// rows (bit-identical to the uncached path — see
    /// [`QActRows`]); float layers ignore the cache.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_cached(
        &self,
        x: &[f32],
        cache: Option<&mut QActRows>,
        batch: usize,
        bias: Option<&[f32]>,
        y: &mut [f32],
        scratch: &mut QScratch,
        kernel: Kernel,
        accumulate: bool,
    ) {
        match (self, cache) {
            (Linear::Quant(q), Some(c)) => {
                c.ensure_batch(x, batch, q.in_dim, EwKernel::for_gemm(kernel));
                qgemm_cached(c, batch, q, bias, y, scratch, kernel, accumulate);
            }
            _ => self.forward(x, batch, bias, y, scratch, kernel, accumulate),
        }
    }

    /// [`Linear::forward_lanes`] with an optional activation cache for
    /// `x` (per listed lane; see [`Linear::forward_cached`]).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_lanes_cached(
        &self,
        x: &[f32],
        cache: Option<&mut QActRows>,
        max_lanes: usize,
        lanes: &[usize],
        bias: Option<&[f32]>,
        y: &mut [f32],
        scratch: &mut QScratch,
        kernel: Kernel,
        accumulate: bool,
    ) {
        match (self, cache) {
            (Linear::Quant(q), Some(c)) => {
                c.ensure_lanes(x, max_lanes, lanes, q.in_dim, EwKernel::for_gemm(kernel));
                qgemm_lanes_cached(c, max_lanes, lanes, q, bias, y, scratch, kernel, accumulate);
            }
            _ => self.forward_lanes(x, max_lanes, lanes, bias, y, scratch, kernel, accumulate),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Gen;

    fn tensor_f32(in_dim: usize, out_dim: usize, g: &mut Gen) -> Tensor {
        Tensor::F32 { shape: vec![in_dim, out_dim], data: g.vec_normal(in_dim * out_dim, 0.5) }
    }

    #[test]
    fn float_and_mismatch_agree_approximately() {
        let mut g = Gen::new(10);
        let (i, o, b) = (40, 24, 3);
        let t = tensor_f32(i, o, &mut g);
        let lf = Linear::from_tensor(&t).unwrap();
        let lq = lf.quantize_now();
        assert!(!lf.is_quant() && lq.is_quant());
        let x = g.vec_normal(b * i, 1.0);
        let mut yf = vec![0f32; b * o];
        let mut yq = vec![0f32; b * o];
        let mut s = QScratch::default();
        lf.forward(&x, b, None, &mut yf, &mut s, Kernel::Auto, false);
        lq.forward(&x, b, None, &mut yq, &mut s, Kernel::Auto, false);
        let scale = yf.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1.0);
        for (a, b_) in yf.iter().zip(&yq) {
            assert!((a - b_).abs() < 0.03 * scale, "{a} vs {b_}");
        }
    }

    #[test]
    fn stored_u8q_roundtrips_through_to_float() {
        let mut g = Gen::new(11);
        let (i, o) = (16, 8);
        let t = tensor_f32(i, o, &mut g);
        let lq = Linear::from_tensor(&t).unwrap().quantize_now();
        // to_float of quant == recovered grid; re-quantizing that is stable
        let lf = lq.to_float();
        let lq2 = lf.quantize_now();
        let (Linear::Quant(a), Linear::Quant(b)) = (&lq, &lq2) else { panic!() };
        // same grid up to possible ±1 from re-deriving range off grid ends
        let diff = a.data.iter().zip(&b.data).filter(|(x, y)| x != y).count();
        assert!(diff <= a.data.len() / 50, "grid drifted: {diff}");
    }

    #[test]
    fn quantized_layers_are_packed_at_load() {
        let mut g = Gen::new(13);
        let t = tensor_f32(20, 12, &mut g);
        let lf = Linear::from_tensor(&t).unwrap();
        assert!(!lf.is_packed() && lf.packed_bytes() == 0);
        // Both the post-hoc path and the stored-u8 path pack eagerly.
        let lq = lf.quantize_now();
        assert!(lq.is_packed() && lq.packed_bytes() > 0);
        let Linear::Quant(q) = &lq else { panic!() };
        let mut vq_math = vec![0u8; q.data.len()];
        for o in 0..q.out_dim {
            for i in 0..q.in_dim {
                vq_math[i * q.out_dim + o] = q.data[o * q.in_dim + i];
            }
        }
        let stored = Linear::Quant(crate::quant::QMatrix::from_stored(
            &vq_math,
            q.in_dim,
            q.out_dim,
            q.params[0],
        ));
        assert!(stored.is_packed());
    }

    #[test]
    fn quantize_scheme_paths() {
        let mut g = Gen::new(0x15C);
        let t = tensor_f32(33, 14, &mut g);
        let lf = Linear::from_tensor(&t).unwrap();
        // PerMatrixU8 over a float layer == the seed quantize_now grid.
        let (Linear::Quant(a), Linear::Quant(b)) = (
            &lf.quantize_scheme(QuantScheme::PerMatrixU8),
            &lf.quantize_now(),
        ) else {
            panic!()
        };
        assert_eq!(a.data, b.data);
        assert_eq!(a.row_sums, b.row_sums);
        // Per-channel schemes build packed per-row matrices of the right
        // width; requantizing an already-quantized layer goes through the
        // recovered floats (artifact untouched).
        for (scheme, bits) in
            [(QuantScheme::PerChannelU8, 8u32), (QuantScheme::PerChannelI4, 4u32)]
        {
            for src in [&lf, &lf.quantize_now()] {
                let lq = src.quantize_scheme(scheme);
                assert!(lq.is_packed());
                let Linear::Quant(q) = &lq else { panic!() };
                assert_eq!(q.granularity, Granularity::PerRow);
                assert_eq!(q.params.len(), q.out_dim);
                assert_eq!(q.packed.as_ref().unwrap().bits, bits);
            }
        }
    }

    #[test]
    fn rejects_non_2d() {
        let t = Tensor::F32 { shape: vec![8], data: vec![0.0; 8] };
        assert!(Linear::from_tensor(&t).is_err());
    }

    #[test]
    fn quant_storage_smaller() {
        let mut g = Gen::new(12);
        let t = tensor_f32(128, 128, &mut g);
        let lf = Linear::from_tensor(&t).unwrap();
        let lq = lf.quantize_now();
        assert!(lq.storage_bytes() * 3 < lf.storage_bytes());
    }
}
