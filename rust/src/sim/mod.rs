//! The synthetic speech world — rust mirror of `python/compile/data.py` +
//! `spec.py` (see DESIGN.md §2 for why this replaces the paper's corpora).
//!
//! Structural randomness (lexicon, phones, bigram, sentences, durations)
//! comes from the shared [`crate::util::rng::SplitMix64`] stream and is
//! **bit-identical** with python; waveform noise uses xoshiro and is
//! distribution-identical.
//!
//! - [`world`]   — phones, lexicon, bigram text model.
//! - [`synth`]   — formant waveform synthesis.
//! - [`noise`]   — multistyle distortion (colored noise, babble, reverb).
//! - [`dataset`] — utterance generation for serving demos and tests.

pub mod dataset;
pub mod noise;
pub mod synth;
pub mod world;

pub use world::World;
