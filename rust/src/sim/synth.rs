//! Formant waveform synthesis (mirrors `data.py::synth_phone/synth_utterance`).
//!
//! Discrete structure (durations, pauses) draws from the shared SplitMix64
//! stream in the same order as python; float noise/phases use xoshiro
//! (distribution-identical, not bit-identical — see sim/mod.rs).

use crate::frontend::spec;
use crate::sim::world::{Phone, World};
use crate::util::rng::{SplitMix64, Xoshiro256};

/// One phone: 3 formant sinusoids (3 Hz vibrato, raised-cosine edges) + noise.
pub fn synth_phone(phone: &Phone, dur_samples: usize, nrng: &mut Xoshiro256) -> Vec<f32> {
    let sr = spec::SAMPLE_RATE as f64;
    let mut sig = vec![0f64; dur_samples];
    let phases: Vec<f64> = (0..3).map(|_| nrng.uniform(0.0, 2.0 * std::f64::consts::PI)).collect();
    for i in 0..dur_samples {
        let t = i as f64 / sr;
        let vib = 1.0 + 0.01 * (2.0 * std::f64::consts::PI * 3.0 * t).sin();
        let mut v = 0.0;
        for (fi, &(f_hz, amp)) in phone.formants.iter().enumerate() {
            v += amp * (2.0 * std::f64::consts::PI * f_hz * vib * t + phases[fi]).sin();
        }
        sig[i] = v;
    }
    if !phone.voiced {
        for v in sig.iter_mut() {
            *v *= 0.2;
        }
    }
    for v in sig.iter_mut() {
        *v += phone.noise_amp * nrng.normal();
    }
    // Raised-cosine attack/decay over 10 ms.
    let edge = ((0.010 * sr) as usize).min(dur_samples / 2);
    let mut out = vec![0f32; dur_samples];
    for i in 0..dur_samples {
        let env = if edge == 0 {
            1.0
        } else if i < edge {
            0.5 - 0.5 * (std::f64::consts::PI * i as f64 / edge as f64).cos()
        } else if i >= dur_samples - edge {
            let j = dur_samples - 1 - i;
            0.5 - 0.5 * (std::f64::consts::PI * j as f64 / edge as f64).cos()
        } else {
            1.0
        };
        out[i] = (0.3 * sig[i] * env) as f32;
    }
    out
}

/// A synthesized utterance with its supervision.
pub struct SynthUtt {
    pub wave: Vec<f32>,
    pub phones: Vec<u32>,
    pub words: Vec<u32>,
    /// Phone id active at each raw frame center (0 = silence).
    pub raw_align: Vec<u32>,
}

/// Words → waveform + labels (mirrors `data.py::synth_utterance`).
pub fn synth_utterance(
    words: &[u32],
    world: &World,
    rng: &mut SplitMix64,
    nrng: &mut Xoshiro256,
) -> SynthUtt {
    let sr = spec::SAMPLE_RATE as f64;
    let sil = (0.050 * sr) as usize;
    let mut wave: Vec<f32> = vec![0.0; sil];
    let mut spans: Vec<(u32, usize)> = vec![(0, sil)];
    let mut phones = Vec::new();
    for (wi, &w) in words.iter().enumerate() {
        if wi > 0 && rng.next_f64() < 0.3 {
            let pause = ((0.020 + 0.040 * rng.next_f64()) * sr) as usize;
            wave.extend(std::iter::repeat(0f32).take(pause));
            spans.push((0, pause));
        }
        for &pid in world.word_phones(w) {
            let dur_ms = rng.next_range(spec::PHONE_DUR_MIN_MS, spec::PHONE_DUR_MAX_MS);
            let n = (dur_ms as f64 * sr / 1000.0) as usize;
            wave.extend(synth_phone(&world.phones[(pid - 1) as usize], n, nrng));
            spans.push((pid, n));
            phones.push(pid);
        }
    }
    wave.extend(std::iter::repeat(0f32).take(sil));
    spans.push((0, sil));
    for v in wave.iter_mut() {
        *v += spec::SYNTH_NOISE_FLOOR as f32 * nrng.normal() as f32;
    }

    // Per-raw-frame phone alignment at frame centers.
    let mut sample_phone = vec![0u32; wave.len()];
    let mut pos = 0;
    for (pid, n) in spans {
        for s in sample_phone.iter_mut().skip(pos).take(n) {
            *s = pid;
        }
        pos += n;
    }
    let n_frames = if wave.len() >= spec::FRAME_LEN {
        1 + (wave.len() - spec::FRAME_LEN) / spec::FRAME_HOP
    } else {
        0
    };
    let raw_align = (0..n_frames)
        .map(|t| {
            let c = (spec::FRAME_HOP * t + spec::FRAME_LEN / 2).min(wave.len() - 1);
            sample_phone[c]
        })
        .collect();
    SynthUtt { wave, phones, words: words.to_vec(), raw_align }
}

/// Raw-frame alignment → output-frame alignment (`data.py::decimate_align`).
pub fn decimate_align(raw_align: &[u32]) -> Vec<u32> {
    let t_raw = raw_align.len();
    if t_raw < spec::STACK {
        return Vec::new();
    }
    let n_out = (t_raw - spec::STACK) / spec::DECIMATE + 1;
    (0..n_out).map(|t| raw_align[t * spec::DECIMATE]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::world::sample_sentence;

    #[test]
    fn utterance_has_reasonable_shape() {
        let world = World::new();
        let mut rng = SplitMix64::new(1);
        let mut nrng = Xoshiro256::new(2);
        let words = sample_sentence(&mut rng, &world);
        let u = synth_utterance(&words, &world, &mut rng, &mut nrng);
        // ≥ 2×50ms silence + phones
        assert!(u.wave.len() > 800);
        assert_eq!(
            u.phones.len(),
            words.iter().map(|&w| world.word_phones(w).len()).sum::<usize>()
        );
        assert!(!u.raw_align.is_empty());
        // amplitude bounded
        assert!(u.wave.iter().all(|v| v.abs() < 4.0));
    }

    #[test]
    fn alignment_covers_phone_sequence() {
        let world = World::new();
        let mut rng = SplitMix64::new(3);
        let mut nrng = Xoshiro256::new(4);
        let u = synth_utterance(&[5, 17], &world, &mut rng, &mut nrng);
        // collapse the alignment: should equal the phone sequence
        let mut collapsed = Vec::new();
        let mut prev = u32::MAX;
        for &a in &u.raw_align {
            if a != 0 && a != prev {
                collapsed.push(a);
            }
            prev = a;
        }
        assert_eq!(collapsed, u.phones, "align {:?}", u.raw_align);
    }

    #[test]
    fn phone_energy_concentrates_at_formants() {
        let world = World::new();
        let p = &world.phones[9];
        let mut nrng = Xoshiro256::new(5);
        let wav = synth_phone(p, 1600, &mut nrng);
        // energy present
        let rms: f32 =
            (wav.iter().map(|v| v * v).sum::<f32>() / wav.len() as f32).sqrt();
        assert!(rms > 0.01, "rms {rms}");
        // envelope edges near zero
        assert!(wav[0].abs() < 0.2 && wav[wav.len() - 1].abs() < 0.2);
    }

    #[test]
    fn decimate_align_matches_formula() {
        let align: Vec<u32> = (0..20).collect();
        let d = decimate_align(&align);
        assert_eq!(d.len(), (20 - spec::STACK) / spec::DECIMATE + 1);
        assert_eq!(d[0], 0);
        assert_eq!(d[1], 2);
    }
}
