//! Derived world: phone inventory, lexicon, bigram sentence model.
//! Bit-identical mirror of `spec.py::derive_phones/derive_lexicon/
//! derive_bigram/sample_sentence` (same SplitMix64 draws inted same order).

use std::collections::HashSet;

use crate::frontend::spec;
use crate::util::rng::SplitMix64;

/// Formant-like description of a synthetic phone (`spec.py::Phone`).
#[derive(Clone, Debug)]
pub struct Phone {
    pub id: u32,
    /// Three (freq_hz, amplitude) pairs.
    pub formants: [(f64, f64); 3],
    pub noise_amp: f64,
    pub voiced: bool,
}

/// The full derived world.
pub struct World {
    pub phones: Vec<Phone>,
    /// word id → phone-id sequence
    pub lexicon: Vec<Vec<u32>>,
    /// word id → 8 (successor, weight) rows, weights sum to 1
    pub bigram: Vec<Vec<(u32, f64)>>,
}

pub fn derive_phones(rng: &mut SplitMix64) -> Vec<Phone> {
    let mut phones = Vec::with_capacity(spec::N_PHONES);
    for pid in 1..=spec::N_PHONES as u32 {
        let f1 = 220.0 + 1000.0 * rng.next_f64();
        let mut f2 = f1 + 300.0 + 1200.0 * rng.next_f64();
        let mut f3 = f2 + 400.0 + 1000.0 * rng.next_f64();
        let a1 = 0.5 + 0.5 * rng.next_f64();
        let a2 = 0.25 + 0.45 * rng.next_f64();
        let a3 = 0.1 + 0.3 * rng.next_f64();
        let mut noise = 0.02 + 0.1 * rng.next_f64();
        let voiced_draw = rng.next_f64();
        let voiced = voiced_draw > 0.25;
        if !voiced {
            noise += 0.35;
        }
        f3 = f3.min(3600.0);
        f2 = f2.min(f3 - 100.0);
        phones.push(Phone {
            id: pid,
            formants: [(f1, a1), (f2, a2), (f3, a3)],
            noise_amp: noise,
            voiced,
        });
    }
    phones
}

pub fn derive_lexicon(rng: &mut SplitMix64) -> Vec<Vec<u32>> {
    let mut seen: HashSet<Vec<u32>> = HashSet::new();
    let mut lex = Vec::with_capacity(spec::N_WORDS);
    for _ in 0..spec::N_WORDS {
        let n = rng.next_range(spec::WORD_MIN_PHONES, spec::WORD_MAX_PHONES) as usize;
        let mut seq: Vec<u32> =
            (0..n).map(|_| rng.next_range(1, spec::N_PHONES as i64) as u32).collect();
        while seen.contains(&seq) {
            let last = seq.len() - 1;
            seq[last] = rng.next_range(1, spec::N_PHONES as i64) as u32;
        }
        seen.insert(seq.clone());
        lex.push(seq);
    }
    lex
}

pub fn derive_bigram(rng: &mut SplitMix64) -> Vec<Vec<(u32, f64)>> {
    let mut table = Vec::with_capacity(spec::N_WORDS);
    for _ in 0..spec::N_WORDS {
        let mut succ = Vec::with_capacity(8);
        let mut total = 0.0;
        for _ in 0..8 {
            let s = rng.next_range(0, spec::N_WORDS as i64 - 1) as u32;
            let w = 0.1 + rng.next_f64();
            succ.push((s, w));
            total += w;
        }
        for e in succ.iter_mut() {
            e.1 /= total;
        }
        table.push(succ);
    }
    table
}

impl World {
    pub fn new() -> Self {
        Self::with_seed(spec::WORLD_SEED)
    }

    pub fn with_seed(seed: u64) -> Self {
        World {
            phones: derive_phones(&mut SplitMix64::new(seed ^ 0x01)),
            lexicon: derive_lexicon(&mut SplitMix64::new(seed ^ 0x02)),
            bigram: derive_bigram(&mut SplitMix64::new(seed ^ 0x03)),
        }
    }

    pub fn word_phones(&self, word: u32) -> &[u32] {
        &self.lexicon[word as usize]
    }
}

impl Default for World {
    fn default() -> Self {
        Self::new()
    }
}

fn harmonic() -> f64 {
    (0..spec::N_WORDS).map(|w| 1.0 / (w as f64 + 1.0)).sum()
}

/// Zipf-ish unigram draw (mirrors `spec.py::zipf_word`).
pub fn zipf_word(rng: &mut SplitMix64) -> u32 {
    let h = harmonic();
    let u = rng.next_f64() * h;
    let mut acc = 0.0;
    for w in 0..spec::N_WORDS {
        acc += 1.0 / (w as f64 + 1.0);
        if u <= acc {
            return w as u32;
        }
    }
    spec::N_WORDS as u32 - 1
}

/// Sample a sentence (mirrors `spec.py::sample_sentence`).
pub fn sample_sentence(rng: &mut SplitMix64, world: &World) -> Vec<u32> {
    let n = rng.next_range(spec::SENT_MIN_WORDS, spec::SENT_MAX_WORDS) as usize;
    let mut words = vec![zipf_word(rng)];
    while words.len() < n {
        let use_bigram = rng.next_f64() < 0.8;
        if use_bigram {
            let row = &world.bigram[*words.last().unwrap() as usize];
            let u = rng.next_f64();
            let mut acc = 0.0;
            let mut nxt = row.last().unwrap().0;
            for &(s, w) in row {
                acc += w;
                if u <= acc {
                    nxt = s;
                    break;
                }
            }
            words.push(nxt);
        } else {
            words.push(zipf_word(rng));
        }
    }
    words
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_shapes() {
        let w = World::new();
        assert_eq!(w.phones.len(), spec::N_PHONES);
        assert_eq!(w.lexicon.len(), spec::N_WORDS);
        assert_eq!(w.bigram.len(), spec::N_WORDS);
        for p in &w.phones {
            assert!(p.formants[0].0 < p.formants[1].0);
            assert!(p.formants[2].0 <= 3600.0);
        }
        for seq in &w.lexicon {
            assert!((2..=6).contains(&seq.len()));
            assert!(seq.iter().all(|&p| (1..=40).contains(&p)));
        }
    }

    #[test]
    fn lexicon_pronunciations_unique() {
        let w = World::new();
        let set: HashSet<_> = w.lexicon.iter().collect();
        assert_eq!(set.len(), w.lexicon.len());
    }

    #[test]
    fn bigram_rows_normalized() {
        let w = World::new();
        for row in &w.bigram {
            let s: f64 = row.iter().map(|e| e.1).sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn sentences_deterministic_and_in_range() {
        let w = World::new();
        let mut r1 = SplitMix64::new(99);
        let mut r2 = SplitMix64::new(99);
        for _ in 0..50 {
            let a = sample_sentence(&mut r1, &w);
            let b = sample_sentence(&mut r2, &w);
            assert_eq!(a, b);
            assert!((1..=4).contains(&a.len()));
            assert!(a.iter().all(|&x| (x as usize) < spec::N_WORDS));
        }
    }

    #[test]
    fn zipf_head_is_heavier() {
        let mut r = SplitMix64::new(5);
        let mut lo = 0;
        let mut hi = 0;
        for _ in 0..5000 {
            let w = zipf_word(&mut r);
            if w < 20 {
                lo += 1;
            }
            if w >= 180 {
                hi += 1;
            }
        }
        assert!(lo > hi * 3, "lo={lo} hi={hi}");
    }
}
