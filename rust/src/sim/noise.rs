//! Multistyle distortion (mirrors `data.py`): colored noise + babble at a
//! target SNR, optional exponential-decay reverb.

use crate::frontend::spec;
use crate::sim::synth::synth_phone;
use crate::sim::world::World;
use crate::util::rng::{SplitMix64, Xoshiro256};

/// One-pole low-passed white noise (pink-ish), `data.py::colored_noise_fast`.
pub fn colored_noise(n: usize, nrng: &mut Xoshiro256) -> Vec<f32> {
    let a = 0.85f64;
    let mut acc = 0f64;
    (0..n)
        .map(|_| {
            acc = a * acc + (1.0 - a) * nrng.normal();
            acc as f32
        })
        .collect()
}

/// Background babble: 3 superposed random phone streams.
pub fn babble(n: usize, world: &World, rng: &mut SplitMix64, nrng: &mut Xoshiro256) -> Vec<f32> {
    let mut out = vec![0f32; n];
    for _ in 0..3 {
        let mut pos = 0usize;
        while pos < n {
            let pid = rng.next_range(1, spec::N_PHONES as i64) as usize;
            let dur = (rng.next_range(spec::PHONE_DUR_MIN_MS, spec::PHONE_DUR_MAX_MS) as f64
                * spec::SAMPLE_RATE as f64
                / 1000.0) as usize;
            let seg = synth_phone(&world.phones[pid - 1], dur, nrng);
            let end = (pos + dur).min(n);
            for i in pos..end {
                out[i] += seg[i - pos];
            }
            pos = end;
        }
    }
    for v in out.iter_mut() {
        *v /= 3.0;
    }
    out
}

/// Cheap 3-tap exponential-decay reverb (11/19/31 ms).
pub fn reverb(wave: &[f32]) -> Vec<f32> {
    let taps = [
        ((0.011 * spec::SAMPLE_RATE as f64) as usize, 0.35f32),
        ((0.019 * spec::SAMPLE_RATE as f64) as usize, 0.20),
        ((0.031 * spec::SAMPLE_RATE as f64) as usize, 0.10),
    ];
    let mut out = wave.to_vec();
    for (d, g) in taps {
        for i in d..wave.len() {
            out[i] += g * wave[i - d];
        }
    }
    out
}

/// Additive colored noise + babble at a sampled SNR, 30% chance of reverb.
/// Consumes the same SplitMix64 draws as `data.py::distort`.
pub fn distort(
    wave: &[f32],
    world: &World,
    rng: &mut SplitMix64,
    nrng: &mut Xoshiro256,
    snr_db_range: (f64, f64),
) -> Vec<f32> {
    let snr_db = snr_db_range.0 + (snr_db_range.1 - snr_db_range.0) * rng.next_f64();
    let base = if rng.next_f64() < 0.3 { reverb(wave) } else { wave.to_vec() };
    let cn = colored_noise(base.len(), nrng);
    let bb = babble(base.len(), world, rng, nrng);
    let mix: Vec<f32> = cn.iter().zip(&bb).map(|(a, b)| 0.5 * a + 0.5 * b).collect();
    let p_sig = base.iter().map(|v| (v * v) as f64).sum::<f64>() / base.len() as f64 + 1e-12;
    let p_noise = mix.iter().map(|v| (v * v) as f64).sum::<f64>() / mix.len() as f64 + 1e-12;
    let gain = (p_sig / (p_noise * 10f64.powf(snr_db / 10.0))).sqrt() as f32;
    base.iter().zip(&mix).map(|(s, m)| s + gain * m).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snr_db(sig: &[f32], noisy: &[f32]) -> f64 {
        let p_sig = sig.iter().map(|v| (v * v) as f64).sum::<f64>();
        let p_noise: f64 =
            sig.iter().zip(noisy).map(|(s, n)| ((n - s) * (n - s)) as f64).sum();
        10.0 * (p_sig / p_noise.max(1e-12)).log10()
    }

    #[test]
    fn distort_hits_target_snr_band() {
        let world = World::new();
        let mut rng = SplitMix64::new(11);
        let mut nrng = Xoshiro256::new(12);
        // deterministic signal with real energy
        let sig: Vec<f32> = (0..8000)
            .map(|i| (2.0 * std::f64::consts::PI * 500.0 * i as f64 / 8000.0).sin() as f32 * 0.3)
            .collect();
        for _ in 0..5 {
            let noisy = distort(&sig, &world, &mut rng, &mut nrng, (10.0, 10.0));
            let s = snr_db(&sig, &noisy);
            // Reverb (30% of draws) perturbs the "signal" itself and counts
            // as noise in this crude measurement; accept a generous band
            // around the 10 dB target.
            assert!((2.5..=17.0).contains(&s), "snr {s}");
        }
    }

    #[test]
    fn colored_noise_is_lowpassed() {
        let mut nrng = Xoshiro256::new(1);
        let n = colored_noise(8192, &mut nrng);
        // lag-1 autocorrelation should be strongly positive (~0.85)
        let mean = n.iter().map(|v| *v as f64).sum::<f64>() / n.len() as f64;
        let var: f64 = n.iter().map(|v| (*v as f64 - mean).powi(2)).sum();
        let cov: f64 = n
            .windows(2)
            .map(|w| (w[0] as f64 - mean) * (w[1] as f64 - mean))
            .sum();
        let rho = cov / var;
        assert!(rho > 0.7, "rho {rho}");
    }

    #[test]
    fn reverb_preserves_length_and_adds_tail_energy() {
        let mut w = vec![0f32; 1000];
        w[0] = 1.0;
        let r = reverb(&w);
        assert_eq!(r.len(), 1000);
        assert!(r[(0.011 * 8000.0) as usize] > 0.3);
    }
}
