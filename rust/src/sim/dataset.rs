//! Utterance generation (serving demos, benches, tests).
//!
//! Mirrors `data.py::gen_utt`'s seed chain, so the *word/phone content* of
//! utterance `uid` in split `seed` matches the python dataset exactly
//! (waveform noise differs — see sim/mod.rs).

use crate::frontend::{self, spec};
use crate::io::feat_fmt::Utt;
use crate::sim::noise::distort;
use crate::sim::synth::{decimate_align, synth_utterance, SynthUtt};
use crate::sim::world::{sample_sentence, World};
use crate::util::rng::{SplitMix64, Xoshiro256};

/// Distortion style per split (mirrors `data.py` styles).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    Clean,
    Noisy,
    /// 50% of utterances distorted at 10–20 dB.
    Multistyle,
}

/// Generate the waveform + supervision for one utterance id.
pub fn gen_wave(uid: u32, split_seed: u64, world: &World, style: Style) -> SynthUtt {
    let mut mix = SplitMix64::new((split_seed << 20) ^ (uid as u64 * 0x9E37));
    let seed64 = mix.next_u64();
    let mut rng = SplitMix64::new(seed64);
    let mut nrng = Xoshiro256::new(seed64 ^ 0xF00D);
    let words = sample_sentence(&mut rng, world);
    let mut u = synth_utterance(&words, world, &mut rng, &mut nrng);
    let distorted = match style {
        Style::Noisy => true,
        Style::Multistyle => rng.next_f64() < 0.5,
        Style::Clean => false,
    };
    if distorted {
        let band = if style == Style::Noisy { spec::NOISY_SNR_DB } else { (10.0, 20.0) };
        u.wave = distort(&u.wave, world, &mut rng, &mut nrng, band);
    }
    u
}

/// Full utterance record: waveform → rust frontend → features + labels.
pub fn gen_utt(uid: u32, split_seed: u64, world: &World, style: Style) -> (Utt, Vec<f32>) {
    let s = gen_wave(uid, split_seed, world, style);
    let feats = frontend::features(&s.wave);
    let t = feats.len() / spec::FEAT_DIM;
    let mut align = decimate_align(&s.raw_align);
    align.truncate(t);
    align.resize(t, 0);
    (
        Utt {
            uid,
            feats,
            num_frames: t,
            dim: spec::FEAT_DIM,
            phones: s.phones.clone(),
            words: s.words.clone(),
            align,
        },
        s.wave,
    )
}

/// Generate a split of utterances (features only).
pub fn generate_split(n: usize, seed: u64, world: &World, style: Style) -> Vec<Utt> {
    (0..n).map(|i| gen_utt(i as u32, seed, world, style).0).collect()
}

/// Sample a large body of sentences for LM training (text side only).
pub fn text_corpus(n_sentences: usize, seed: u64, world: &World) -> Vec<Vec<u32>> {
    let mut rng = SplitMix64::new(seed);
    (0..n_sentences).map(|_| sample_sentence(&mut rng, world)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let w = World::new();
        let (a, _) = gen_utt(3, 101, &w, Style::Clean);
        let (b, _) = gen_utt(3, 101, &w, Style::Clean);
        assert_eq!(a.feats, b.feats);
        assert_eq!(a.phones, b.phones);
        assert_eq!(a.words, b.words);
    }

    #[test]
    fn different_uid_different_content() {
        let w = World::new();
        let (a, _) = gen_utt(0, 101, &w, Style::Clean);
        let (b, _) = gen_utt(1, 101, &w, Style::Clean);
        assert!(a.words != b.words || a.feats != b.feats);
    }

    #[test]
    fn features_and_align_consistent() {
        let w = World::new();
        let (u, wave) = gen_utt(7, 202, &w, Style::Clean);
        assert_eq!(u.feats.len(), u.num_frames * spec::FEAT_DIM);
        assert_eq!(u.align.len(), u.num_frames);
        assert!(!u.phones.is_empty());
        assert!(wave.len() > spec::FRAME_LEN);
        // phones referenced by align ⊆ utterance phones ∪ {0}
        for &a in &u.align {
            assert!(a == 0 || u.phones.contains(&a));
        }
    }

    #[test]
    fn noisy_differs_from_clean() {
        let w = World::new();
        let (c, _) = gen_utt(5, 303, &w, Style::Clean);
        let (n, _) = gen_utt(5, 303, &w, Style::Noisy);
        assert_eq!(c.phones, n.phones); // same content
        assert!(c.feats != n.feats); // different acoustics
    }

    #[test]
    fn corpus_sizes() {
        let w = World::new();
        let c = text_corpus(100, 9, &w);
        assert_eq!(c.len(), 100);
        assert!(c.iter().all(|s| !s.is_empty()));
    }
}
