//! Mini-criterion: a self-contained micro-benchmark harness.
//!
//! The image has no network access and `criterion` is not in the vendored
//! snapshot, so `cargo bench` targets use this instead (Cargo.toml sets
//! `harness = false`).  It does what we need from criterion: warmup,
//! calibrated iteration counts, mean/σ/p50/p99, throughput, and a
//! machine-greppable one-line report per benchmark.

use std::time::{Duration, Instant};

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Optional work-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// items/second if `items_per_iter` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.mean_ns * 1e-9))
    }

    pub fn report(&self) -> String {
        let thr = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        format!(
            "bench {:<44} {:>12} mean {:>10} p50 {:>10} p99 ±{:>4.1}%{}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            100.0 * self.std_ns / self.mean_ns.max(1e-9),
            thr
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with criterion-like calibration.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(1),
            min_samples: 20,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(250),
            min_samples: 10,
        }
    }

    /// Run `f` repeatedly; `f` should perform one logical iteration and
    /// return a value that is consumed via [`black_box`].
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        // Aim for ~min_samples..1000 samples, batching fast iterations.
        let target_samples =
            ((self.measure.as_secs_f64() / per_iter) as usize).clamp(self.min_samples, 1000);
        let batch =
            ((self.measure.as_secs_f64() / per_iter / target_samples as f64) as u64).max(1);

        let mut samples = Vec::with_capacity(target_samples);
        for _ in 0..target_samples {
            let s = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(s.elapsed().as_nanos() as f64 / batch as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Measurement {
            name: name.to_string(),
            iters: batch * n as u64,
            mean_ns: mean,
            std_ns: var.sqrt(),
            p50_ns: samples[n / 2],
            p99_ns: samples[(n * 99 / 100).min(n - 1)],
            items_per_iter: None,
        }
    }

    /// Like [`run`] but annotates items-per-iteration (throughput).
    pub fn run_with_items<T>(
        &self,
        name: &str,
        items: f64,
        f: impl FnMut() -> T,
    ) -> Measurement {
        let mut m = self.run(name, f);
        m.items_per_iter = Some(items);
        println!("{}", m.report());
        m
    }

    /// Run + print.
    pub fn bench<T>(&self, name: &str, f: impl FnMut() -> T) -> Measurement {
        let m = self.run(name, f);
        println!("{}", m.report());
        m
    }
}

/// Opaque value sink (stable alternative to `std::hint::black_box` that also
/// works for non-Copy types by reference).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.iters > 0);
        assert!(m.p99_ns >= m.p50_ns * 0.5);
    }

    #[test]
    fn throughput_reported() {
        let b = Bench::quick();
        let mut m = b.run("noop", || 1u64);
        m.items_per_iter = Some(100.0);
        assert!(m.throughput().unwrap() > 0.0);
        assert!(m.report().contains("item/s"));
    }
}
