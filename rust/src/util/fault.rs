//! Deterministic fault injection for the serving plane.
//!
//! A [`FaultPlan`] is a seeded schedule of faults parsed from a compact
//! spec (`QUANTASR_FAULTS=seed:spec`, or built directly in tests).  The
//! serving code calls [`FaultPlan::fire`] at named injection points
//! ([`FaultPoint`]); the plan decides — purely from its seed, its rules,
//! and the call's key/arrival index — whether the fault triggers.  The
//! same plan therefore produces the same schedule on every run, which is
//! what lets `tests/chaos_integration.rs` assert engine invariants under
//! faults *and* replay a failing schedule from its seed.
//!
//! **Zero cost when disabled.**  Every injection point goes through an
//! `Option<Arc<FaultPlan>>`; the disabled path is a `None` check and
//! nothing else — no atomics, no hashing, no logging.  Production builds
//! carry the hooks but never pay for them unless `QUANTASR_FAULTS` is
//! set.
//!
//! ## Spec grammar
//!
//! ```text
//! QUANTASR_FAULTS = seed ':' rule (',' rule)*
//! rule            = point ['@' nth] ['#' key] ['~' rate]
//! point           = decode_panic | backend_panic | slow_tick
//!                 | client_stall | corrupt_frame | mem_pressure
//!                 | canary_fail | overload_tick
//! ```
//!
//! - `point@N` — fire exactly once, on the Nth matching arrival at that
//!   point (1-based).
//! - `point#K` — the rule only matches arrivals whose key is `K` (e.g.
//!   a stream id for `decode_panic`, a model id for `backend_panic`).
//! - `point~R` — fire with probability `R`, decided by hashing
//!   `(seed, point, key)` — key-stable, so a batch retry that re-asks
//!   about the same stream gets the same answer.
//! - A rule with neither `@` nor `~` fires on every matching arrival.
//!
//! Examples: `7:decode_panic@1` (panic the first decode job),
//! `42:backend_panic@1#1,slow_tick~0.25` (panic model 1's first step,
//! stretch a quarter of ticks).
//!
//! A malformed `QUANTASR_FAULTS` warns and disables injection — the
//! knob grammar must never panic a serving process (the same contract as
//! every other `QUANTASR_*` knob).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Named injection points wired into the serving plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// Panic inside a decode-pool job (keyed by stream id).
    DecodePanic,
    /// Panic inside a model's batched AM step (keyed by model id).
    BackendPanic,
    /// Stretch one AM tick by [`SLOW_TICK_MS`] (keyed by tick parity).
    SlowTick,
    /// Client-side send stall of [`CLIENT_STALL_MS`] (keyed by chunk
    /// index).
    ClientStall,
    /// Corrupt the tag byte of an outbound server frame (keyed by stream
    /// id).
    CorruptFrame,
    /// Pretend the budget ledger is full: admission/load sees memory
    /// pressure regardless of actual residency (keyed by model id).
    MemPressure,
    /// Fail the canary health check during `swap_model` so the swap rolls
    /// back (keyed by the replacement model's slot id).
    CanaryFail,
    /// Force the AM worker to treat a flush as a deadline overrun — the
    /// deterministic way to drive the brownout EWMA past its threshold
    /// without real load (keyed by tick number).
    OverloadTick,
}

/// Injected tick stretch (ms) when [`FaultPoint::SlowTick`] fires.
pub const SLOW_TICK_MS: u64 = 25;
/// Injected send stall (ms) when [`FaultPoint::ClientStall`] fires.
pub const CLIENT_STALL_MS: u64 = 250;

const NUM_POINTS: usize = 8;

impl FaultPoint {
    fn index(self) -> usize {
        match self {
            FaultPoint::DecodePanic => 0,
            FaultPoint::BackendPanic => 1,
            FaultPoint::SlowTick => 2,
            FaultPoint::ClientStall => 3,
            FaultPoint::CorruptFrame => 4,
            FaultPoint::MemPressure => 5,
            FaultPoint::CanaryFail => 6,
            FaultPoint::OverloadTick => 7,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::DecodePanic => "decode_panic",
            FaultPoint::BackendPanic => "backend_panic",
            FaultPoint::SlowTick => "slow_tick",
            FaultPoint::ClientStall => "client_stall",
            FaultPoint::CorruptFrame => "corrupt_frame",
            FaultPoint::MemPressure => "mem_pressure",
            FaultPoint::CanaryFail => "canary_fail",
            FaultPoint::OverloadTick => "overload_tick",
        }
    }

    fn parse(s: &str) -> Option<FaultPoint> {
        match s {
            "decode_panic" => Some(FaultPoint::DecodePanic),
            "backend_panic" => Some(FaultPoint::BackendPanic),
            "slow_tick" => Some(FaultPoint::SlowTick),
            "client_stall" => Some(FaultPoint::ClientStall),
            "corrupt_frame" => Some(FaultPoint::CorruptFrame),
            "mem_pressure" => Some(FaultPoint::MemPressure),
            "canary_fail" => Some(FaultPoint::CanaryFail),
            "overload_tick" => Some(FaultPoint::OverloadTick),
            _ => None,
        }
    }
}

/// One parsed rule: when an arrival at `point` fires.
#[derive(Clone, Debug, PartialEq)]
struct Rule {
    point: FaultPoint,
    /// Fire only on the Nth matching arrival (1-based), then never again.
    nth: Option<u64>,
    /// Match only arrivals with this key.
    key: Option<u64>,
    /// Fire with this probability, hashed from `(seed, point, key)`.
    rate: Option<f64>,
}

/// A seeded, deterministic fault schedule.  Cheap to share
/// (`Arc<FaultPlan>`); every decision is logged so tests can dump the
/// realized schedule as an artifact.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<Rule>,
    /// Per-point arrival counters (shared across threads; arrival order
    /// at a single-threaded point — e.g. the AM worker — is
    /// deterministic, which is what `@N` rules rely on).
    arrivals: [AtomicU64; NUM_POINTS],
    log: Mutex<Vec<String>>,
}

impl FaultPlan {
    /// Parse `seed:spec` (see the module docs for the grammar).
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let (seed_s, spec) = s
            .split_once(':')
            .ok_or_else(|| format!("'{s}': expected 'seed:rule,rule,…'"))?;
        let seed = seed_s
            .trim()
            .parse::<u64>()
            .map_err(|_| format!("'{seed_s}' is not a u64 seed"))?;
        let mut rules = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            rules.push(Self::parse_rule(part)?);
        }
        if rules.is_empty() {
            return Err(format!("'{s}': no rules"));
        }
        Ok(FaultPlan::new(seed, rules))
    }

    fn parse_rule(part: &str) -> Result<Rule, String> {
        // point [@nth] [#key] [~rate], markers in any order after point.
        let end = part
            .find(|c| c == '@' || c == '#' || c == '~')
            .unwrap_or(part.len());
        let point = FaultPoint::parse(&part[..end])
            .ok_or_else(|| format!("unknown fault point '{}'", &part[..end]))?;
        let mut rule = Rule { point, nth: None, key: None, rate: None };
        let mut rest = &part[end..];
        while !rest.is_empty() {
            let marker = rest.as_bytes()[0];
            let body = &rest[1..];
            let stop = body
                .find(|c| c == '@' || c == '#' || c == '~')
                .unwrap_or(body.len());
            let (val, tail) = body.split_at(stop);
            match marker {
                b'@' => {
                    rule.nth = Some(
                        val.parse::<u64>()
                            .ok()
                            .filter(|&n| n >= 1)
                            .ok_or_else(|| format!("'@{val}' is not a 1-based count"))?,
                    )
                }
                b'#' => {
                    rule.key = Some(
                        val.parse::<u64>()
                            .map_err(|_| format!("'#{val}' is not a u64 key"))?,
                    )
                }
                b'~' => {
                    rule.rate = Some(
                        val.parse::<f64>()
                            .ok()
                            .filter(|r| (0.0..=1.0).contains(r))
                            .ok_or_else(|| format!("'~{val}' is not a rate in [0,1]"))?,
                    )
                }
                _ => unreachable!("find matched a marker"),
            }
            rest = tail;
        }
        Ok(rule)
    }

    fn new(seed: u64, rules: Vec<Rule>) -> FaultPlan {
        FaultPlan {
            seed,
            rules,
            arrivals: Default::default(),
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Should the fault at `point` trigger for this arrival?  `key`
    /// identifies the subject (stream id, model id, …).  Deterministic:
    /// `@N` rules count arrivals at the point, `~R` rules hash
    /// `(seed, point, key)` — both independent of wall clock.
    pub fn fire(&self, point: FaultPoint, key: u64) -> bool {
        let n = self.arrivals[point.index()].fetch_add(1, Ordering::Relaxed) + 1;
        let mut fired = false;
        for rule in &self.rules {
            if rule.point != point {
                continue;
            }
            if let Some(k) = rule.key {
                if k != key {
                    continue;
                }
            }
            if let Some(nth) = rule.nth {
                if n != nth {
                    continue;
                }
            }
            if let Some(rate) = rule.rate {
                if self.unit_hash(point, key) >= rate {
                    continue;
                }
            }
            fired = true;
            break;
        }
        if fired {
            self.log
                .lock()
                .unwrap()
                .push(format!("{} arrival={} key={}", point.name(), n, key));
        }
        fired
    }

    /// Key-stable unit-interval hash of `(seed, point, key)` (splitmix64
    /// finalizer).
    fn unit_hash(&self, point: FaultPoint, key: u64) -> f64 {
        let mut z = self
            .seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(point.index() as u64 + 1))
            .wrapping_add(key.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// The realized schedule so far: one line per fired fault, in firing
    /// order.  Chaos CI uploads this as the run artifact.
    pub fn schedule_log(&self) -> Vec<String> {
        self.log.lock().unwrap().clone()
    }
}

/// Convenience for injection points holding an `Option<Arc<FaultPlan>>`:
/// `None` is a branch and nothing else.
#[inline]
pub fn fire(plan: &Option<Arc<FaultPlan>>, point: FaultPoint, key: u64) -> bool {
    match plan {
        None => false,
        Some(p) => p.fire(point, key),
    }
}

/// The process-wide plan from `QUANTASR_FAULTS`, parsed once.  Malformed
/// specs warn and disable injection (knobs never panic a server).
pub fn env_fault_plan() -> Option<Arc<FaultPlan>> {
    static ONCE: OnceLock<Option<Arc<FaultPlan>>> = OnceLock::new();
    ONCE.get_or_init(|| {
        let v = std::env::var("QUANTASR_FAULTS").ok()?;
        match FaultPlan::parse(&v) {
            Ok(p) => Some(Arc::new(p)),
            Err(e) => {
                eprintln!("QUANTASR_FAULTS={v}: {e}; fault injection disabled");
                None
            }
        }
    })
    .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse("42:decode_panic@1,backend_panic@2#1,slow_tick~0.5").unwrap();
        assert_eq!(p.seed(), 42);
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[0], Rule {
            point: FaultPoint::DecodePanic,
            nth: Some(1),
            key: None,
            rate: None
        });
        assert_eq!(p.rules[1], Rule {
            point: FaultPoint::BackendPanic,
            nth: Some(2),
            key: Some(1),
            rate: None
        });
        assert_eq!(p.rules[2].rate, Some(0.5));
    }

    #[test]
    fn overload_points_parse_and_fire_independently() {
        let p =
            FaultPlan::parse("9:mem_pressure@1,canary_fail#3,overload_tick~1.0").unwrap();
        assert!(p.fire(FaultPoint::MemPressure, 0));
        assert!(!p.fire(FaultPoint::MemPressure, 0), "@1 fires once");
        assert!(!p.fire(FaultPoint::CanaryFail, 1));
        assert!(p.fire(FaultPoint::CanaryFail, 3));
        assert!(p.fire(FaultPoint::OverloadTick, 17), "~1.0 always fires");
        assert_eq!(p.schedule_log().len(), 3);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "no-colon",
            "x:decode_panic",
            "1:unknown_point",
            "1:decode_panic@0",
            "1:decode_panic@x",
            "1:slow_tick~1.5",
            "1:",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn nth_rule_fires_exactly_once() {
        let p = FaultPlan::parse("7:decode_panic@3").unwrap();
        let fired: Vec<bool> = (0..6).map(|i| p.fire(FaultPoint::DecodePanic, i)).collect();
        assert_eq!(fired, vec![false, false, true, false, false, false]);
        assert_eq!(p.schedule_log().len(), 1);
        assert!(p.schedule_log()[0].contains("decode_panic arrival=3"));
    }

    #[test]
    fn key_filter_matches_only_its_key() {
        let p = FaultPlan::parse("7:backend_panic#2").unwrap();
        assert!(!p.fire(FaultPoint::BackendPanic, 0));
        assert!(p.fire(FaultPoint::BackendPanic, 2));
        assert!(!p.fire(FaultPoint::BackendPanic, 1));
        assert!(p.fire(FaultPoint::BackendPanic, 2), "no-@ rules keep firing");
        // Other points are untouched.
        assert!(!p.fire(FaultPoint::DecodePanic, 2));
    }

    #[test]
    fn rate_rules_are_key_stable_and_seed_sensitive() {
        let a = FaultPlan::parse("1:slow_tick~0.5").unwrap();
        let b = FaultPlan::parse("1:slow_tick~0.5").unwrap();
        let seq_a: Vec<bool> = (0..64).map(|k| a.fire(FaultPoint::SlowTick, k)).collect();
        let seq_b: Vec<bool> = (0..64).map(|k| b.fire(FaultPoint::SlowTick, k)).collect();
        assert_eq!(seq_a, seq_b, "same seed, same schedule");
        assert!(seq_a.iter().any(|&f| f) && seq_a.iter().any(|&f| !f));
        let c = FaultPlan::parse("2:slow_tick~0.5").unwrap();
        let seq_c: Vec<bool> = (0..64).map(|k| c.fire(FaultPoint::SlowTick, k)).collect();
        assert_ne!(seq_a, seq_c, "different seed, different schedule");
    }

    #[test]
    fn disabled_plan_is_inert() {
        let none: Option<Arc<FaultPlan>> = None;
        assert!(!fire(&none, FaultPoint::DecodePanic, 0));
        let some = Some(Arc::new(FaultPlan::parse("1:decode_panic@1").unwrap()));
        assert!(fire(&some, FaultPoint::DecodePanic, 9));
    }
}
