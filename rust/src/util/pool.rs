//! A persistent worker pool for the packed GEMM's panel fan-out.
//!
//! The packed-panel GEMM used to spawn scoped threads per call; at tens
//! of µs per spawn that forced a 2M-MAC serial threshold, so batch-1
//! single-stream GEMVs could never use a second core.  This pool keeps
//! workers **parked on a condvar** between calls: dispatching a job is a
//! mutex publish + `notify_all` (a few µs), so the parallel threshold in
//! `quant::gemm` drops by an order of magnitude.
//!
//! ## Execution model
//!
//! A job is a `Fn(usize)` over `chunks` indices.  Chunks are claimed
//! dynamically from a shared atomic counter — the **submitter
//! participates** (it is always one of the executors), and up to
//! `nthreads − 1` pool workers join it.  Dynamic claiming load-balances
//! uneven chunks; because chunk *assignment* never affects chunk
//! *results* (GEMM panels own disjoint output columns and apply identical
//! arithmetic wherever they run), results are bit-identical at any
//! thread count — the same guarantee the scoped-thread version gave.
//!
//! One job runs at a time (`submit` mutex); concurrent submitters queue.
//! `run` returns only after every participating worker has deregistered,
//! which is what makes the lifetime-erased task pointer sound: no worker
//! can touch the closure after `run` returns.
//!
//! The global pool ([`WorkerPool::global`]) is created lazily on the
//! first parallel GEMM and sized from `available_parallelism` (or
//! `QUANTASR_GEMM_THREADS` when that forces a larger count), capped at
//! [`MAX_POOL_THREADS`].  Workers park between jobs; dropping a
//! non-global pool shuts its workers down and joins them (the global
//! pool lives in a static and dies with the process).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Upper bound on pool threads (parked threads are cheap, but there is no
/// point outnumbering the panel count of the largest layer).
pub const MAX_POOL_THREADS: usize = 16;

/// Lifetime-erased task pointer (see the module docs for why this is
/// sound: `run` does not return while any worker holds it).
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is Sync (shared &-calls from many threads are fine)
// and the pool's completion protocol bounds its use to the `run` call.
unsafe impl Send for TaskPtr {}

struct Slot {
    /// Current job, `None` when idle.  Workers only join while `Some`.
    task: Option<TaskPtr>,
    /// Total chunk count of the current job.
    chunks: usize,
    /// Cap on concurrently registered workers (honors the caller's
    /// requested thread count; the submitter is participant #max+1).
    max_workers: usize,
    /// Workers currently registered on the job.
    running: usize,
    /// Pool is being dropped: parked workers exit instead of waiting.
    shutdown: bool,
    /// First panic payload captured on a pool thread during the current
    /// job; `run` re-raises it on the submitting thread after the job is
    /// fully drained.  This is what lets a serving layer above quarantine
    /// a poisoned model with `catch_unwind` instead of losing the whole
    /// process.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct Shared {
    m: Mutex<Slot>,
    /// Parks idle workers.
    work: Condvar,
    /// Wakes the submitter when the last worker deregisters.
    done: Condvar,
    /// Next unclaimed chunk index of the current job.
    next: AtomicUsize,
}

/// The pool. `workers` is the number of spawned threads (the submitting
/// thread always participates on top of these).
pub struct WorkerPool {
    shared: Arc<Shared>,
    submit: Mutex<()>,
    workers: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `workers` parked threads (0 is valid: every
    /// `run` then executes inline on the caller).  Dropping the pool
    /// shuts the workers down and joins them (the global pool lives in a
    /// static and is never dropped).
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            m: Mutex::new(Slot {
                task: None,
                chunks: 0,
                max_workers: 0,
                running: 0,
                shutdown: false,
                panic: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let s = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gemm-pool-{i}"))
                    .spawn(move || worker_loop(&s))
                    .expect("spawn gemm pool worker"),
            );
        }
        WorkerPool { shared, submit: Mutex::new(()), workers, handles }
    }

    /// The process-global pool, created on first use.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| WorkerPool::new(default_pool_workers()))
    }

    /// Spawned worker-thread count (the caller adds one more executor).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute `task(0..chunks)` across up to `nthreads` executors (the
    /// calling thread plus at most `nthreads − 1` pool workers; clamped
    /// to the spawned worker count).  Blocks until every chunk has run
    /// and every worker has left the job.  A panic in `task` — on any
    /// executor — drains the job (remaining chunks are abandoned, every
    /// worker deregisters) and then resumes on the **submitting** thread,
    /// so callers can `catch_unwind` a poisoned kernel and quarantine the
    /// model instead of losing the process.  The task borrow never
    /// escapes this call either way.
    pub fn run(&self, nthreads: usize, chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        if nthreads <= 1 || self.workers == 0 || chunks == 1 {
            for c in 0..chunks {
                task(c);
            }
            return;
        }
        // A panicking task on the submitting thread unwinds through this
        // guard; the `()` payload carries no state, so recover from the
        // poison instead of failing every later GEMM with a PoisonError.
        let _submit = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        // SAFETY (lifetime erasure): the JobGuard below blocks — on the
        // normal path *and* on unwind — until `running == 0` with `task`
        // cleared, so no worker dereferences the pointer after this frame
        // ends.
        let task_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(task)
        };
        {
            let mut s = self.shared.m.lock().unwrap();
            debug_assert!(s.task.is_none() && s.running == 0);
            self.shared.next.store(0, Ordering::Relaxed);
            s.chunks = chunks;
            s.max_workers = (nthreads - 1).min(self.workers);
            s.panic = None;
            s.task = Some(TaskPtr(task_static));
            self.shared.work.notify_all();
        }
        {
            let _drain = JobGuard { shared: &self.shared, chunks };
            // The submitter is always an executor.
            loop {
                let c = self.shared.next.fetch_add(1, Ordering::Relaxed);
                if c >= chunks {
                    break;
                }
                task(c);
            }
            // JobGuard's drop closes the job and waits for stragglers
            // (their chunk writes are ordered before its re-acquisition
            // of the mutex).
        }
        // With the job fully drained, re-raise a pool-thread panic here on
        // the submitting thread.  (If the submitter's own chunk panicked,
        // we never get here — it unwinds through the guard directly, and
        // the next `run` clears any concurrently captured payload.)
        let payload = self.shared.m.lock().unwrap().panic.take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// Like [`run`](Self::run), but chunk `c` gets exclusive `&mut`
    /// access to `items[c]` — the fan-out shape multi-stream frontend
    /// batches use (one independently mutated `Frontend` per stream).
    ///
    /// SAFETY argument: `run` dispatches every chunk index exactly once
    /// (`runs_every_chunk_exactly_once` below), indices are in bounds by
    /// construction, and `run` does not return while any executor is
    /// still inside the task — so no two executors ever alias an
    /// element and no borrow outlives this call.
    pub fn run_mut<T: Send>(
        &self,
        nthreads: usize,
        items: &mut [T],
        task: &(dyn Fn(usize, &mut T) + Sync),
    ) {
        struct SendPtr<U>(*mut U);
        unsafe impl<U> Send for SendPtr<U> {}
        unsafe impl<U> Sync for SendPtr<U> {}
        let base = SendPtr(items.as_mut_ptr());
        let n = items.len();
        self.run(nthreads, n, &move |c| {
            let item = unsafe { &mut *base.0.add(c) };
            task(c, item);
        });
    }
}

/// Closes the current job on drop — including when the submitting
/// thread unwinds out of its chunk loop — and waits until every worker
/// has deregistered, so the lifetime-erased task pointer is dead before
/// `run`'s frame (and the closure it borrows) goes away.
struct JobGuard<'a> {
    shared: &'a Shared,
    chunks: usize,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        let mut s = self.shared.m.lock().unwrap();
        s.task = None;
        // Exhaust the chunk counter so registered workers stop claiming
        // new chunks (relevant on the unwind path; a no-op afterwards).
        self.shared.next.fetch_max(self.chunks, Ordering::Relaxed);
        while s.running > 0 {
            s = self.shared.done.wait(s).unwrap();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Exclusive access here means no `run` is in flight: every worker
        // is parked (or about to park) and will observe the flag.
        {
            let mut s = self.shared.m.lock().unwrap();
            s.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut s = shared.m.lock().unwrap();
    loop {
        // Park until a live job still has unclaimed chunks and a free
        // executor slot — or the pool is shutting down.
        loop {
            if s.shutdown {
                return;
            }
            let joinable = s.task.is_some()
                && s.running < s.max_workers
                && shared.next.load(Ordering::Relaxed) < s.chunks;
            if joinable {
                break;
            }
            s = shared.work.wait(s).unwrap();
        }
        let task = s.task.expect("checked Some above");
        let chunks = s.chunks;
        s.running += 1;
        drop(s);
        let mut captured: Option<Box<dyn std::any::Any + Send>> = None;
        loop {
            let c = shared.next.fetch_add(1, Ordering::Relaxed);
            if c >= chunks {
                break;
            }
            // SAFETY: registered on the job (running > 0), so the
            // submitter cannot return and invalidate the pointer.  A
            // panicking chunk is caught, the chunk counter exhausted (no
            // executor claims more work for this job), and the payload
            // handed to the submitter, which resumes the unwind once the
            // job is drained — the pool thread itself stays alive.
            let f: &(dyn Fn(usize) + Sync) = unsafe { &*task.0 };
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(c))) {
                Ok(()) => {}
                Err(p) => {
                    shared.next.fetch_max(chunks, Ordering::Relaxed);
                    captured = Some(p);
                    break;
                }
            }
        }
        s = shared.m.lock().unwrap();
        if let Some(p) = captured {
            // Keep the first payload if several workers panicked.
            s.panic.get_or_insert(p);
        }
        s.running -= 1;
        if s.running == 0 {
            shared.done.notify_all();
        }
    }
}

/// `QUANTASR_GEMM_THREADS` override (parsed once): 0/unset = auto — the
/// **single** parser of this env var, shared with `quant::gemm`'s
/// thread-count policy so the contract cannot drift.  Unparseable values
/// warn — a silent fallback here would quietly turn a "pinned serial"
/// bench into a threaded one.  Values above [`MAX_POOL_THREADS`] warn
/// and are honored only up to the pool cap.
pub fn forced_gemm_threads() -> Option<usize> {
    static FORCED: OnceLock<Option<usize>> = OnceLock::new();
    *FORCED.get_or_init(|| {
        let v = std::env::var("QUANTASR_GEMM_THREADS").ok()?;
        match v.trim().parse::<usize>() {
            Ok(0) => None,
            Ok(n) => {
                if n > MAX_POOL_THREADS {
                    eprintln!(
                        "QUANTASR_GEMM_THREADS={n} exceeds the pool cap of \
                         {MAX_POOL_THREADS}; GEMMs will use at most {MAX_POOL_THREADS} threads"
                    );
                }
                Some(n)
            }
            Err(_) => {
                eprintln!(
                    "QUANTASR_GEMM_THREADS='{}' is not a thread count; using auto",
                    v.trim()
                );
                None
            }
        }
    })
}

/// Pool size: `available_parallelism` (or the forced
/// `QUANTASR_GEMM_THREADS` when larger), minus the submitting thread,
/// capped at [`MAX_POOL_THREADS`].
fn default_pool_workers() -> usize {
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let forced = forced_gemm_threads().unwrap_or(0);
    cpus.max(forced).min(MAX_POOL_THREADS).saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = WorkerPool::new(3);
        for &chunks in &[1usize, 2, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..chunks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(4, chunks, &|c| {
                hits[c].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} of {chunks}");
            }
        }
    }

    #[test]
    fn run_mut_gives_each_item_exclusive_access() {
        let pool = WorkerPool::new(3);
        for &n in &[0usize, 1, 7, 97] {
            let mut items: Vec<u64> = (0..n as u64).collect();
            pool.run_mut(4, &mut items, &|i, v| {
                *v = v.wrapping_mul(3).wrapping_add(i as u64);
            });
            for (i, v) in items.iter().enumerate() {
                assert_eq!(*v, (i as u64).wrapping_mul(3).wrapping_add(i as u64), "item {i}/{n}");
            }
        }
    }

    #[test]
    fn run_mut_serial_path_matches() {
        let pool = WorkerPool::new(0);
        let mut items = vec![1u32; 12];
        pool.run_mut(1, &mut items, &|i, v| *v += i as u32);
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, 1 + i as u32);
        }
    }

    #[test]
    fn zero_workers_runs_inline() {
        let pool = WorkerPool::new(0);
        let sum = AtomicU64::new(0);
        pool.run(8, 100, &|c| {
            sum.fetch_add(c as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn serial_request_stays_on_caller() {
        let pool = WorkerPool::new(2);
        let main_id = std::thread::current().id();
        pool.run(1, 16, &|_| {
            assert_eq!(std::thread::current().id(), main_id, "nthreads=1 must stay serial");
        });
    }

    #[test]
    fn back_to_back_jobs_reuse_workers() {
        let pool = WorkerPool::new(2);
        let sum = AtomicU64::new(0);
        for round in 0..50u64 {
            pool.run(3, 17, &|c| {
                sum.fetch_add(round * 1000 + c as u64, Ordering::Relaxed);
            });
        }
        let per_round: u64 = (0..17).sum();
        let want: u64 = (0..50u64).map(|r| r * 1000 * 17 + per_round).sum();
        assert_eq!(sum.load(Ordering::Relaxed), want);
    }

    #[test]
    fn concurrent_submitters_serialize_safely() {
        let pool = Arc::new(WorkerPool::new(3));
        let total = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let pool = pool.clone();
            let total = total.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    pool.run(3, 11, &|c| {
                        total.fetch_add(t + c as u64, Ordering::Relaxed);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let per_run: u64 = (0..11u64).sum();
        let want: u64 = (0..4u64).map(|t| 20 * (t * 11 + per_run)).sum();
        assert_eq!(total.load(Ordering::Relaxed), want);
    }

    #[test]
    fn drop_joins_workers() {
        // Dropping a pool must terminate and reclaim its threads — not
        // hang on parked workers, including right after a job.
        let pool = WorkerPool::new(2);
        let sum = AtomicU64::new(0);
        pool.run(3, 9, &|c| {
            sum.fetch_add(c as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 36);
        drop(pool); // joins; a hang here fails the test via timeout
    }

    #[test]
    fn pool_thread_panic_resumes_on_submitter_and_pool_survives() {
        let pool = WorkerPool::new(3);
        // Chunk 5 panics no matter which executor claims it; the panic
        // must surface on the submitting thread (catchable), and the pool
        // must keep working afterwards.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, 64, &|c| {
                if c == 5 {
                    panic!("poisoned chunk");
                }
            });
        }));
        assert!(caught.is_err(), "panic must propagate out of run()");
        for round in 0..5u64 {
            let sum = AtomicU64::new(0);
            pool.run(4, 33, &|c| {
                sum.fetch_add(round + c as u64, Ordering::Relaxed);
            });
            let want: u64 = (0..33u64).map(|c| round + c).sum();
            assert_eq!(sum.load(Ordering::Relaxed), want, "pool reusable after panic");
        }
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = WorkerPool::global() as *const _;
        let b = WorkerPool::global() as *const _;
        assert_eq!(a, b);
    }
}
