//! Infrastructure the offline image forces us to own: RNG, bench harness,
//! property-testing helpers, and CLI parsing.

pub mod bench;
pub mod cli;
pub mod prop;
pub mod rng;
