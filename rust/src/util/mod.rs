//! Infrastructure the offline image forces us to own: RNG, bench harness,
//! property-testing helpers, CLI parsing, the persistent GEMM worker
//! pool, and the deterministic fault-injection plan.

pub mod bench;
pub mod cli;
pub mod fault;
pub mod pool;
pub mod prop;
pub mod rng;
