//! Infrastructure the offline image forces us to own: RNG, bench harness,
//! property-testing helpers, CLI parsing, and the persistent GEMM worker
//! pool.

pub mod bench;
pub mod cli;
pub mod pool;
pub mod prop;
pub mod rng;
