//! Deterministic PRNGs.
//!
//! [`SplitMix64`] is bit-identical to `python/compile/spec.py::SplitMix64` —
//! it derives every *structural* quantity shared between the two languages
//! (phone inventory, lexicon, bigram table, sentence sampling), so the
//! synthetic worlds match exactly.  [`Xoshiro256`] (seeded from SplitMix64,
//! as its authors recommend) supplies bulk float noise where only the
//! distribution has to match.

/// SplitMix64 (Steele, Lea, Flood 2014). Mirrors `spec.SplitMix64`.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits of precision (same as python).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Mirrors python
    /// `next_range` (modulo method, same bias characteristics by design).
    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }
}

/// xoshiro256** 1.0 — fast bulk generator for noise/dithers.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second draw omitted for
    /// simplicity; throughput is not a bottleneck here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, 1) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 1234567 — cross-checked against the
        // canonical C implementation (and the python mirror).
        let mut r = SplitMix64::new(1234567);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(a, r2.next_u64());
        assert_eq!(b, r2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = SplitMix64::new(7);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let x = r.next_range(2, 5);
            assert!((2..=5).contains(&x));
            saw_lo |= x == 2;
            saw_hi |= x == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn xoshiro_normal_moments() {
        let mut r = Xoshiro256::new(9);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
