//! proptest-lite: seeded randomized property testing.
//!
//! The vendored crate snapshot has no `proptest`, so tests use this tiny
//! harness: a deterministic generator seeded per case, a fixed case count,
//! and on failure a report of the failing case seed so it can be replayed
//! by constructing `Gen::new(seed)` directly.  No shrinking — cases are
//! kept small instead.

use crate::util::rng::Xoshiro256;

/// Random-value source handed to properties.
pub struct Gen {
    pub rng: Xoshiro256,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo as f64, hi as f64) as f32
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vec of f32 drawn uniformly from [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vec of normal f32 with the given std.
    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() as f32 * std).collect()
    }

    /// Vec of u32 ids below `max`.
    pub fn vec_ids(&mut self, n: usize, max: u32) -> Vec<u32> {
        (0..n).map(|_| self.rng.below(max as usize) as u32).collect()
    }
}

/// Run `prop` for `cases` deterministic cases derived from `seed`.
/// Panics with the failing case seed on the first failure.
pub fn forall(name: &str, cases: u32, seed: u64, mut prop: impl FnMut(&mut Gen)) {
    for i in 0..cases {
        let case_seed = seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1));
        let mut g = Gen::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed on case {i} (replay: Gen::new({case_seed:#x}))"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counter", 25, 1, |_g| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        assert_eq!(a.vec_f32(8, -1.0, 1.0), b.vec_f32(8, -1.0, 1.0));
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fails", 10, 2, |g| {
            let x = g.usize_in(0, 9);
            assert!(x < 100, "unreachable");
            if x >= 0 {
                // always fail after a few cases
                assert!(g.usize_in(0, 3) != 1);
            }
        });
    }
}
