//! Minimal CLI argument parsing (no `clap` in the offline snapshot).
//!
//! Supports `command --flag value --switch positional` style:
//! ```text
//! quantasr table1 --artifacts artifacts --backend native
//! ```

use std::collections::HashMap;

/// Parsed command line: subcommand, `--key value` options, bare switches,
/// and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub options: HashMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(name) = item.strip_prefix("--") {
                // `--key value` unless next token is another flag / absent.
                match iter.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = iter.next().unwrap();
                        out.options.insert(name.to_string(), v);
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else if out.command.is_none() {
                out.command = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Like [`Args::get_usize`], but a present-and-malformed value warns
    /// on stderr instead of being silently replaced — serving knobs must
    /// neither panic nor vanish without a trace.  (Richer flag values
    /// have their own validated warn-don't-panic grammars: durations via
    /// `coordinator::batcher::parse_deadline_ms`, comma-separated share
    /// lists like `--model-weights 4,1` via
    /// `sched::weights::parse_share_list`.)
    pub fn get_usize_warn(&self, key: &str, default: usize) -> usize {
        match self.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("--{key} '{v}' is not an integer; using {default}");
                default
            }),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basic_command_and_options() {
        let a = parse("table1 --artifacts art --batch 8 --verbose");
        assert_eq!(a.command.as_deref(), Some("table1"));
        assert_eq!(a.get("artifacts"), Some("art"));
        assert_eq!(a.get_usize("batch", 1), 8);
        assert!(a.has("verbose"));
    }

    #[test]
    fn positionals_after_command() {
        let a = parse("decode file1 file2 --beam 8");
        assert_eq!(a.positional, vec!["file1", "file2"]);
        assert_eq!(a.get_usize("beam", 0), 8);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_or("port", "7700"), "7700");
        assert_eq!(a.get_f64("deadline-ms", 5.0), 5.0);
        assert!(!a.has("quiet"));
    }

    #[test]
    fn warn_variant_falls_back_without_panicking() {
        let a = parse("serve --quantum 7 --streams many");
        assert_eq!(a.get_usize_warn("quantum", 25), 7);
        assert_eq!(a.get_usize_warn("streams", 8), 8);
        assert_eq!(a.get_usize_warn("absent", 3), 3);
    }

    #[test]
    fn trailing_switch_then_option() {
        let a = parse("x --quiet --n 3");
        assert!(a.has("quiet"));
        assert_eq!(a.get_usize("n", 0), 3);
    }
}
