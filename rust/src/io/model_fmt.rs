//! `.qam` acoustic-model files (written by `python/compile/export.py`).
//!
//! See export.py for the byte layout.  The loader keeps quantized tensors
//! in their stored u8 form (plus `(vmin, q)`), so the native engine computes
//! on exactly the grid QAT trained — no re-quantization drift.  This module
//! can also *write* `.qam` files (used by the `quantize_model` example and
//! round-trip tests).
//!
//! In-situ requantization ([`crate::quant::QuantScheme`]) never changes
//! this format: the per-channel schemes recover a stored `U8Q` tensor to
//! f32 (`Tensor::to_f32`) and rebuild the serving matrices at load, so one
//! artifact serves under any scheme and the file stays the QAT record.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::io::json::Json;
use crate::quant::scheme::QuantParams;

pub const MAGIC: &[u8; 4] = b"QAM1";

/// Hard cap on a stored tensor's rank — nothing the exporter writes
/// exceeds 2-D today; a bigger value in the file is corruption, and
/// bounding it keeps a hostile `ndim` from sizing an allocation.
pub const MAX_NDIM: usize = 8;

/// One stored tensor: f32 or u8-quantized (eq. 2 values).
#[derive(Clone, Debug)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    U8Q { shape: Vec<usize>, data: Vec<u8>, vmin: f32, q: f32 },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::U8Q { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quant params for a U8Q tensor (zp derived as in export.py).
    pub fn qparams(&self) -> Option<QuantParams> {
        match self {
            Tensor::U8Q { vmin, q, .. } => {
                Some(QuantParams {
                    vmin: *vmin,
                    q: *q,
                    zp: (*q as f64 * *vmin as f64).round() as i64,
                    scale: crate::quant::scheme::SCALE,
                })
            }
            Tensor::F32 { .. } => None,
        }
    }

    /// Recover to f32 (row-major, original shape).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            Tensor::F32 { data, .. } => data.clone(),
            Tensor::U8Q { data, .. } => {
                let p = self.qparams().unwrap();
                let mut out = vec![0f32; data.len()];
                p.recover_slice(data, &mut out);
                out
            }
        }
    }

    pub fn storage_bytes(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len() * 4,
            Tensor::U8Q { data, .. } => data.len() + 8,
        }
    }
}

/// Model architecture parsed from the `.qam` header (one Table-1 row).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelHeader {
    pub name: String,
    pub num_layers: usize,
    pub cell_dim: usize,
    /// `None` ⇒ no projection layer.
    pub proj_dim: Option<usize>,
    pub input_dim: usize,
    pub num_labels: usize,
    pub quantized: bool,
    pub quantize_output: bool,
    pub param_count: usize,
}

impl ModelHeader {
    /// Recurrent/inter-layer width (P if projected else N).
    pub fn rec_dim(&self) -> usize {
        self.proj_dim.unwrap_or(self.cell_dim)
    }

    pub fn layer_in_dim(&self, layer: usize) -> usize {
        if layer == 0 {
            self.input_dim
        } else {
            self.rec_dim()
        }
    }

    fn from_json(j: &Json) -> Result<Self> {
        let need = |k: &str| {
            j.int(k).with_context(|| format!("qam header missing int field '{k}'"))
        };
        let proj = need("proj_dim")?;
        Ok(ModelHeader {
            name: j.str_field("name").unwrap_or("?").to_string(),
            num_layers: need("num_layers")? as usize,
            cell_dim: need("cell_dim")? as usize,
            proj_dim: if proj < 0 { None } else { Some(proj as usize) },
            input_dim: need("input_dim")? as usize,
            num_labels: need("num_labels")? as usize,
            quantized: j.get("quantized").and_then(Json::as_bool).unwrap_or(false),
            quantize_output: j.get("quantize_output").and_then(Json::as_bool).unwrap_or(false),
            param_count: j.int("param_count").unwrap_or(0) as usize,
        })
    }

    fn to_json_string(&self) -> String {
        format!(
            concat!(
                "{{\"name\": \"{}\", \"num_layers\": {}, \"cell_dim\": {}, ",
                "\"proj_dim\": {}, \"input_dim\": {}, \"num_labels\": {}, ",
                "\"quantized\": {}, \"quantize_output\": {}, \"param_count\": {}}}"
            ),
            self.name,
            self.num_layers,
            self.cell_dim,
            self.proj_dim.map(|p| p as i64).unwrap_or(-1),
            self.input_dim,
            self.num_labels,
            self.quantized,
            self.quantize_output,
            self.param_count,
        )
    }
}

/// A loaded `.qam` file.
#[derive(Clone, Debug)]
pub struct QamFile {
    pub header: ModelHeader,
    pub tensors: BTreeMap<String, Tensor>,
}

impl QamFile {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading qam file {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse an in-memory `.qam` image.  Total on untrusted input: a
    /// truncated, bit-flipped, or hostile byte stream yields `Err`,
    /// never a panic or an attacker-sized allocation — every length
    /// field is bounds-checked against the remaining bytes (and checked
    /// for arithmetic overflow) *before* any buffer is sized from it.
    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut r = Cursor { b, i: 0 };
        if r.take(4)? != MAGIC.as_slice() {
            bail!("bad magic");
        }
        let version = r.u32()?;
        if version != 1 {
            bail!("unsupported qam version {version}");
        }
        let hlen = r.u32()? as usize;
        let hdr_bytes = r.take(hlen)?;
        let hdr_json = Json::parse(std::str::from_utf8(hdr_bytes)?)
            .map_err(|e| anyhow::anyhow!("header json: {e}"))?;
        let header = ModelHeader::from_json(&hdr_json)?;
        let n = r.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = r.u32()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())?;
            let dtype = r.u8()?;
            let ndim = r.u32()? as usize;
            if ndim > MAX_NDIM {
                bail!("tensor {name}: {ndim} dimensions exceeds the {MAX_NDIM} limit");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            // A corrupt shape can overflow the element product (or its
            // byte size): refuse it instead of wrapping into a bogus —
            // possibly huge — allocation request.
            let count: usize = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .with_context(|| format!("tensor {name}: shape {shape:?} overflows"))?;
            let t = match dtype {
                0 => {
                    let nbytes = count
                        .checked_mul(4)
                        .with_context(|| format!("tensor {name}: byte size overflows"))?;
                    // Bounds-check against the remaining input *before*
                    // allocating `count` floats from a corrupt prefix.
                    let raw = r.take(nbytes)?;
                    let mut data = vec![0f32; count];
                    for (i, c) in raw.chunks_exact(4).enumerate() {
                        data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                    }
                    Tensor::F32 { shape, data }
                }
                1 => {
                    let vmin = r.f32()?;
                    let q = r.f32()?;
                    let data = r.take(count)?.to_vec();
                    Tensor::U8Q { shape, data, vmin, q }
                }
                other => bail!("unknown dtype {other} for tensor {name}"),
            };
            tensors.insert(name, t);
        }
        Ok(QamFile { header, tensors })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&1u32.to_le_bytes())?;
        let hdr = self.header.to_json_string();
        f.write_all(&(hdr.len() as u32).to_le_bytes())?;
        f.write_all(hdr.as_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            match t {
                Tensor::F32 { shape, data } => {
                    f.write_all(&[0u8])?;
                    f.write_all(&(shape.len() as u32).to_le_bytes())?;
                    for d in shape {
                        f.write_all(&(*d as u32).to_le_bytes())?;
                    }
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Tensor::U8Q { shape, data, vmin, q } => {
                    f.write_all(&[1u8])?;
                    f.write_all(&(shape.len() as u32).to_le_bytes())?;
                    for d in shape {
                        f.write_all(&(*d as u32).to_le_bytes())?;
                    }
                    f.write_all(&vmin.to_le_bytes())?;
                    f.write_all(&q.to_le_bytes())?;
                    f.write_all(data)?;
                }
            }
        }
        Ok(())
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("model is missing tensor '{name}'"))
    }

    /// Total parameter storage (the paper's memory-reduction metric).
    pub fn storage_bytes(&self) -> usize {
        self.tensors.values().map(Tensor::storage_bytes).sum()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // `i + n` could overflow for a hostile n — compare against the
        // remaining length instead (i ≤ len is an invariant).
        if n > self.b.len() - self.i {
            bail!("truncated file at byte {} (want {n})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn f32(&mut self) -> Result<f32> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

/// Read a raw little-endian f32 file (golden waveforms/features).
pub fn read_f32_file(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?
        .read_to_end(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> QamFile {
        let mut tensors = BTreeMap::new();
        tensors.insert(
            "l0.wx".to_string(),
            Tensor::U8Q {
                shape: vec![3, 8],
                data: (0..24).map(|i| (i * 10) as u8).collect(),
                vmin: -1.25,
                q: 100.0,
            },
        );
        tensors.insert(
            "l0.b".to_string(),
            Tensor::F32 { shape: vec![8], data: (0..8).map(|i| i as f32 * 0.5).collect() },
        );
        QamFile {
            header: ModelHeader {
                name: "t".into(),
                num_layers: 1,
                cell_dim: 2,
                proj_dim: Some(4),
                input_dim: 3,
                num_labels: 5,
                quantized: true,
                quantize_output: false,
                param_count: 32,
            },
            tensors,
        }
    }

    #[test]
    fn roundtrip_save_load() {
        let q = sample();
        let dir = std::env::temp_dir().join("quantasr_test_qam");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.qam");
        q.save(&p).unwrap();
        let back = QamFile::load(&p).unwrap();
        assert_eq!(back.header, q.header);
        assert_eq!(back.tensors.len(), 2);
        match (back.tensor("l0.wx").unwrap(), q.tensor("l0.wx").unwrap()) {
            (
                Tensor::U8Q { data: d1, vmin: v1, q: q1, shape: s1 },
                Tensor::U8Q { data: d2, vmin: v2, q: q2, shape: s2 },
            ) => {
                assert_eq!(d1, d2);
                assert_eq!(v1, v2);
                assert_eq!(q1, q2);
                assert_eq!(s1, s2);
            }
            _ => panic!("dtype changed"),
        }
    }

    #[test]
    fn recover_matches_eq3() {
        let q = sample();
        let t = q.tensor("l0.wx").unwrap();
        let p = t.qparams().unwrap();
        let f = t.to_f32();
        if let Tensor::U8Q { data, .. } = t {
            for (i, &vq) in data.iter().enumerate() {
                assert_eq!(f[i], p.recover(vq));
            }
        }
        // zp = round(q*vmin) = round(-125) = -125
        assert_eq!(p.zp, -125);
    }

    #[test]
    fn rejects_corrupt() {
        assert!(QamFile::from_bytes(b"NOPE").is_err());
        let q = sample();
        let dir = std::env::temp_dir().join("quantasr_test_qam");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.qam");
        q.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(QamFile::from_bytes(&bytes[..bytes.len() - 5]).is_err());
    }

    #[test]
    fn missing_tensor_is_error() {
        let q = sample();
        assert!(q.tensor("does.not.exist").is_err());
    }

    fn sample_bytes() -> Vec<u8> {
        let q = sample();
        let dir = std::env::temp_dir().join("quantasr_test_qam");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sweep.qam");
        q.save(&p).unwrap();
        std::fs::read(&p).unwrap()
    }

    /// Every byte-truncation of a valid file is a clean error — the
    /// parser consumes the whole image, so a strict prefix is always
    /// missing something, and it must say so rather than panic.
    #[test]
    fn every_truncation_errors_cleanly() {
        let bytes = sample_bytes();
        for cut in 0..bytes.len() {
            let prefix = &bytes[..cut];
            let parsed = std::panic::catch_unwind(|| QamFile::from_bytes(prefix))
                .unwrap_or_else(|_| panic!("parser panicked on a {cut}-byte truncation"));
            assert!(parsed.is_err(), "a {cut}-byte prefix of a {}-byte file parsed", bytes.len());
        }
    }

    /// Single-bit corruption anywhere in the file either still parses
    /// (the flip landed in payload data) or errors cleanly — never a
    /// panic, never a wild allocation.
    #[test]
    fn every_bit_flip_parses_or_errors_cleanly() {
        let bytes = sample_bytes();
        for byte in 0..bytes.len() {
            for bit in 0..8 {
                let mut mutated = bytes.clone();
                mutated[byte] ^= 1 << bit;
                std::panic::catch_unwind(|| {
                    let _ = QamFile::from_bytes(&mutated);
                })
                .unwrap_or_else(|_| panic!("parser panicked with bit {bit} of byte {byte} flipped"));
            }
        }
    }

    /// Hostile length fields (rank, shape product, element byte size)
    /// are refused before they can size an allocation.
    #[test]
    fn hostile_lengths_are_refused() {
        // Minimal valid prelude: magic, version, tiny header, 1 tensor.
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&1u32.to_le_bytes());
        let hdr = br#"{"name": "x", "num_layers": 1, "cell_dim": 1, "proj_dim": -1, "input_dim": 1, "num_labels": 1}"#;
        b.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        b.extend_from_slice(hdr);
        b.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        b.extend_from_slice(&1u32.to_le_bytes()); // name_len
        b.push(b't');
        b.push(0u8); // dtype f32

        // Rank past MAX_NDIM.
        let mut huge_rank = b.clone();
        huge_rank.extend_from_slice(&(u32::MAX).to_le_bytes());
        let e = QamFile::from_bytes(&huge_rank).unwrap_err();
        assert!(format!("{e:#}").contains("dimensions"), "{e:#}");

        // Shape whose element product overflows usize.
        let mut overflow = b.clone();
        overflow.extend_from_slice(&4u32.to_le_bytes()); // ndim = 4
        for _ in 0..4 {
            overflow.extend_from_slice(&(u32::MAX).to_le_bytes());
        }
        let e = QamFile::from_bytes(&overflow).unwrap_err();
        assert!(format!("{e:#}").contains("overflow"), "{e:#}");

        // A plausible-looking huge tensor must fail the bounds check
        // against the remaining bytes, not allocate gigabytes.
        let mut huge = b.clone();
        huge.extend_from_slice(&2u32.to_le_bytes()); // ndim = 2
        huge.extend_from_slice(&65_535u32.to_le_bytes());
        huge.extend_from_slice(&65_535u32.to_le_bytes());
        assert!(QamFile::from_bytes(&huge).is_err());
    }
}
