//! File formats shared with the python build path, plus a minimal JSON
//! reader (the vendored snapshot has no serde).
//!
//! - [`json`]     — tiny JSON parser (objects/arrays/strings/numbers/bools).
//! - [`model_fmt`] — `.qam` acoustic-model files written by
//!   `python/compile/export.py`.
//! - [`feat_fmt`] — `.feats` dataset files written by
//!   `python/compile/data.py`.

pub mod feat_fmt;
pub mod json;
pub mod model_fmt;
