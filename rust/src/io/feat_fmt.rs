//! `.feats` dataset files (written by `python/compile/data.py`).
//!
//! Layout (LE): magic `FEA1`, u32 version, u32 count; per utterance:
//! u32 uid, u32 T, u32 dim, u32 U, u32 W; f32 feats [T·dim];
//! u32 phones [U]; u32 words [W]; u32 align [T].

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"FEA1";

/// One evaluation/training utterance.
#[derive(Clone, Debug, Default)]
pub struct Utt {
    pub uid: u32,
    /// [T, dim] row-major features.
    pub feats: Vec<f32>,
    pub num_frames: usize,
    pub dim: usize,
    /// Reference phone sequence (no blanks).
    pub phones: Vec<u32>,
    /// Reference word-id sequence.
    pub words: Vec<u32>,
    /// Per-frame phone alignment (0 = silence).
    pub align: Vec<u32>,
}

impl Utt {
    pub fn frame(&self, t: usize) -> &[f32] {
        &self.feats[t * self.dim..(t + 1) * self.dim]
    }
}

pub fn read_feats(path: impl AsRef<Path>) -> Result<Vec<Utt>> {
    let path = path.as_ref();
    let b = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut i = 0usize;
    let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
        if *i + n > b.len() {
            bail!("truncated feats file at {}", *i);
        }
        let s = &b[*i..*i + n];
        *i += n;
        Ok(s)
    };
    let u32le = |i: &mut usize| -> Result<u32> {
        let s = take(i, 4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    };
    if take(&mut i, 4)? != MAGIC.as_slice() {
        bail!("bad feats magic in {}", path.display());
    }
    let _version = u32le(&mut i)?;
    let count = u32le(&mut i)? as usize;
    let mut utts = Vec::with_capacity(count);
    for _ in 0..count {
        let uid = u32le(&mut i)?;
        let t = u32le(&mut i)? as usize;
        let dim = u32le(&mut i)? as usize;
        let nu = u32le(&mut i)? as usize;
        let nw = u32le(&mut i)? as usize;
        let raw = take(&mut i, 4 * t * dim)?;
        let feats = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let read_u32s = |i: &mut usize, n: usize| -> Result<Vec<u32>> {
            Ok(take(i, 4 * n)?
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let phones = read_u32s(&mut i, nu)?;
        let words = read_u32s(&mut i, nw)?;
        let align = read_u32s(&mut i, t)?;
        utts.push(Utt { uid, feats, num_frames: t, dim, phones, words, align });
    }
    Ok(utts)
}

pub fn write_feats(path: impl AsRef<Path>, utts: &[Utt]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&(utts.len() as u32).to_le_bytes())?;
    for u in utts {
        f.write_all(&u.uid.to_le_bytes())?;
        f.write_all(&(u.num_frames as u32).to_le_bytes())?;
        f.write_all(&(u.dim as u32).to_le_bytes())?;
        f.write_all(&(u.phones.len() as u32).to_le_bytes())?;
        f.write_all(&(u.words.len() as u32).to_le_bytes())?;
        for v in &u.feats {
            f.write_all(&v.to_le_bytes())?;
        }
        for v in &u.phones {
            f.write_all(&v.to_le_bytes())?;
        }
        for v in &u.words {
            f.write_all(&v.to_le_bytes())?;
        }
        for v in &u.align {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let utts = vec![
            Utt {
                uid: 7,
                feats: (0..3 * 4).map(|i| i as f32 * 0.25).collect(),
                num_frames: 3,
                dim: 4,
                phones: vec![5, 9],
                words: vec![1],
                align: vec![0, 5, 9],
            },
            Utt {
                uid: 8,
                feats: vec![1.5; 8],
                num_frames: 2,
                dim: 4,
                phones: vec![],
                words: vec![],
                align: vec![0, 0],
            },
        ];
        let dir = std::env::temp_dir().join("quantasr_test_feats");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.feats");
        write_feats(&p, &utts).unwrap();
        let back = read_feats(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].uid, 7);
        assert_eq!(back[0].feats, utts[0].feats);
        assert_eq!(back[0].phones, utts[0].phones);
        assert_eq!(back[0].align, utts[0].align);
        assert_eq!(back[1].num_frames, 2);
        assert_eq!(back[0].frame(1), &[1.0, 1.25, 1.5, 1.75]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("quantasr_test_feats");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.feats");
        std::fs::write(&p, b"XXXX0000").unwrap();
        assert!(read_feats(&p).is_err());
    }
}
