//! Frame stacking + decimation (paper §4: stack 8 / every 3rd; here 4/2).
//!
//! Output frame `t` concatenates raw frames `[D·t .. D·t+STACK-1]`
//! (1 current + 3 right-context) and is emitted only when all of them
//! exist — identical to `data.py::stack_frames`.

use crate::frontend::spec;

/// Raw-frame cursor depth at which the pending buffer is compacted —
/// one memmove per ~64 frames instead of one per emitted output.
const COMPACT_FRAMES: usize = 64;

/// Streaming stacker: push raw mel frames, pop stacked feature frames.
#[derive(Default)]
pub struct Stacker {
    /// Raw frames seen so far, pending stacking.  Consumed frames stay
    /// at the front until `head` reaches [`COMPACT_FRAMES`].
    pending: Vec<f32>,
    /// Raw-frame index (global) of the first *live* frame.
    base: usize,
    /// Consumed frames still physically present at the front of `pending`.
    head: usize,
    /// Next output index to emit.
    next_out: usize,
}

impl Stacker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Push one raw mel frame; append any completed stacked frames
    /// (already FEAT_SCALE-scaled) to `out`.
    pub fn push(&mut self, frame: &[f32], out: &mut Vec<f32>) -> usize {
        debug_assert_eq!(frame.len(), spec::N_MEL);
        self.pending.extend_from_slice(frame);
        let mut emitted = 0;
        loop {
            let start_raw = self.next_out * spec::DECIMATE;
            let end_raw = start_raw + spec::STACK;
            let have = self.base + (self.pending.len() / spec::N_MEL - self.head);
            if end_raw > have {
                break;
            }
            for k in 0..spec::STACK {
                let idx = (self.head + (start_raw + k - self.base)) * spec::N_MEL;
                for j in 0..spec::N_MEL {
                    out.push(self.pending[idx + j] * spec::FEAT_SCALE);
                }
            }
            self.next_out += 1;
            emitted += 1;
            // Advance the cursor past raw frames no longer needed
            // (keep_from ≤ have because DECIMATE ≤ STACK, so the cursor
            // never passes the end of `pending`).
            let keep_from = self.next_out * spec::DECIMATE;
            if keep_from > self.base {
                self.head += keep_from - self.base;
                self.base = keep_from;
            }
        }
        // Compact the consumed prefix occasionally — one memmove per
        // COMPACT_FRAMES outputs instead of one drain per output.
        if self.head >= COMPACT_FRAMES {
            let off = self.head * spec::N_MEL;
            self.pending.copy_within(off.., 0);
            let live = self.pending.len() - off;
            self.pending.truncate(live);
            self.head = 0;
        }
        emitted
    }

    pub fn reset(&mut self) {
        self.pending.clear();
        self.base = 0;
        self.head = 0;
        self.next_out = 0;
    }
}

/// Batch stacking of a whole `[t_raw, N_MEL]` buffer (oracle for the
/// streaming version; mirrors `data.py::stack_frames` + FEAT_SCALE).
pub fn stack_all(frames: &[f32]) -> Vec<f32> {
    let t_raw = frames.len() / spec::N_MEL;
    if t_raw < spec::STACK {
        return Vec::new();
    }
    let n_out = (t_raw - spec::STACK) / spec::DECIMATE + 1;
    let mut out = Vec::with_capacity(n_out * spec::FEAT_DIM);
    for t in 0..n_out {
        for k in 0..spec::STACK {
            let r = t * spec::DECIMATE + k;
            for j in 0..spec::N_MEL {
                out.push(frames[r * spec::N_MEL + j] * spec::FEAT_SCALE);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn streaming_matches_batch() {
        forall("stacker stream==batch", 40, 0x57AC, |g: &mut Gen| {
            let t_raw = g.usize_in(0, 50);
            let frames = g.vec_normal(t_raw * spec::N_MEL, 1.0);
            let want = stack_all(&frames);
            let mut s = Stacker::new();
            let mut got = Vec::new();
            for t in 0..t_raw {
                s.push(&frames[t * spec::N_MEL..(t + 1) * spec::N_MEL], &mut got);
            }
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn long_stream_compaction_matches_batch() {
        // Runs well past COMPACT_FRAMES so the cursor compaction path
        // executes several times; outputs must stay bit-identical.
        let mut g = Gen::new(0x57AD);
        let t_raw = 700;
        let frames = g.vec_normal(t_raw * spec::N_MEL, 1.0);
        let want = stack_all(&frames);
        let mut s = Stacker::new();
        let mut got = Vec::new();
        for t in 0..t_raw {
            s.push(&frames[t * spec::N_MEL..(t + 1) * spec::N_MEL], &mut got);
        }
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn output_count_formula() {
        for t_raw in 0..30 {
            let frames = vec![0.5f32; t_raw * spec::N_MEL];
            let out = stack_all(&frames);
            let want = if t_raw < spec::STACK {
                0
            } else {
                (t_raw - spec::STACK) / spec::DECIMATE + 1
            };
            assert_eq!(out.len() / spec::FEAT_DIM, want, "t_raw={t_raw}");
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut s = Stacker::new();
        let mut out = Vec::new();
        for _ in 0..10 {
            s.push(&[1.0; spec::N_MEL], &mut out);
        }
        s.reset();
        out.clear();
        let n = s.push(&[2.0; spec::N_MEL], &mut out);
        assert_eq!(n, 0); // needs STACK frames again
    }
}
