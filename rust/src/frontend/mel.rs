//! Triangular mel filterbank (HTK-style), mirroring `data.py::mel_filterbank`.
//!
//! Besides the dense reference matmul ([`MelBank::apply_log`]) the bank
//! precomputes each filter's nonzero band so the fused path
//! ([`MelBank::apply_log_fused`]) dots only the triangular support —
//! ~16 bins instead of 129 per filter — and takes the log in the same
//! sweep, on the kernel ladder of [`crate::frontend::kernel`].

use crate::frontend::kernel::{dot8, FrontendKernel};
use crate::frontend::spec;

pub fn mel_scale(f: f64) -> f64 {
    2595.0 * (1.0 + f / 700.0).log10()
}

pub fn mel_inv(m: f64) -> f64 {
    700.0 * (10f64.powf(m / 2595.0) - 1.0)
}

/// Filterbank matrix `[N_MEL, FFT/2+1]` row-major.
pub struct MelBank {
    pub n_mel: usize,
    pub n_bins: usize,
    pub weights: Vec<f32>,
    /// Nonzero support of each filter row: (first bin, length).
    /// Triangular filters are contiguous, so this is exact sparsity.
    bands: Vec<(u32, u32)>,
}

impl Default for MelBank {
    fn default() -> Self {
        Self::new()
    }
}

impl MelBank {
    pub fn new() -> Self {
        let n_bins = spec::FFT_SIZE / 2 + 1;
        let n_mel = spec::N_MEL;
        let mut weights = vec![0f32; n_mel * n_bins];
        let m_lo = mel_scale(spec::MEL_FMIN);
        let m_hi = mel_scale(spec::MEL_FMAX);
        let pts: Vec<f64> = (0..n_mel + 2)
            .map(|i| mel_inv(m_lo + (m_hi - m_lo) * i as f64 / (n_mel + 1) as f64))
            .collect();
        for m in 0..n_mel {
            let (lo, ctr, hi) = (pts[m], pts[m + 1], pts[m + 2]);
            for b in 0..n_bins {
                let f = b as f64 * spec::SAMPLE_RATE as f64 / spec::FFT_SIZE as f64;
                let up = (f - lo) / (ctr - lo);
                let down = (hi - f) / (hi - ctr);
                weights[m * n_bins + b] = up.min(down).max(0.0) as f32;
            }
        }
        let bands = (0..n_mel)
            .map(|m| {
                let row = &weights[m * n_bins..(m + 1) * n_bins];
                let first = row.iter().position(|&w| w != 0.0).unwrap_or(0);
                let last = row.iter().rposition(|&w| w != 0.0).map_or(first, |l| l + 1);
                (first as u32, (last - first) as u32)
            })
            .collect();
        MelBank { n_mel, n_bins, weights, bands }
    }

    /// Apply: log(max(power·Wᵀ, floor)) into `out [n_mel]`.  Dense
    /// reference — accumulates over every bin in index order; the
    /// `reference` frontend rung (and the seed pipeline) run this.
    pub fn apply_log(&self, power: &[f32], out: &mut [f32]) {
        debug_assert_eq!(power.len(), self.n_bins);
        debug_assert_eq!(out.len(), self.n_mel);
        for m in 0..self.n_mel {
            let row = &self.weights[m * self.n_bins..(m + 1) * self.n_bins];
            let mut acc = 0f32;
            for (w, p) in row.iter().zip(power) {
                acc += w * p;
            }
            out[m] = acc.max(spec::LOG_FLOOR).ln();
        }
    }

    /// Fused sparse mel+log: one pass per filter over its nonzero band
    /// only, dot on the [`dot8`] ladder, log applied in the same sweep.
    /// Bit-identical across fused rungs; differs from [`apply_log`] by
    /// reassociation of the filter sum (≤1e-3 relative, see
    /// `frontend/kernel.rs`).
    pub fn apply_log_fused(&self, power: &[f32], out: &mut [f32], kernel: FrontendKernel) {
        debug_assert_eq!(power.len(), self.n_bins);
        debug_assert_eq!(out.len(), self.n_mel);
        let kernel = kernel.resolve();
        for m in 0..self.n_mel {
            let (start, len) = self.bands[m];
            let (start, len) = (start as usize, len as usize);
            let row = &self.weights[m * self.n_bins + start..m * self.n_bins + start + len];
            let acc = dot8(kernel, row, &power[start..start + len]);
            out[m] = acc.max(spec::LOG_FLOOR).ln();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mel_scale_roundtrip() {
        for f in [125.0, 500.0, 1000.0, 3800.0] {
            assert!((mel_inv(mel_scale(f)) - f).abs() < 1e-6);
        }
    }

    #[test]
    fn filters_are_triangular_and_cover_band() {
        let fb = MelBank::new();
        // every filter has positive mass and a single peak
        for m in 0..fb.n_mel {
            let row = &fb.weights[m * fb.n_bins..(m + 1) * fb.n_bins];
            let mass: f32 = row.iter().sum();
            assert!(mass > 0.0, "filter {m} empty");
            let peak = row.iter().cloned().fold(0.0f32, f32::max);
            assert!(peak <= 1.0 + 1e-6);
            // unimodal: rises then falls
            let peak_idx = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            for w in row[..peak_idx].windows(2) {
                assert!(w[0] <= w[1] + 1e-6);
            }
            for w in row[peak_idx..].windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
        }
    }

    #[test]
    fn out_of_band_bins_are_zero() {
        let fb = MelBank::new();
        // bin 0 = 0 Hz < fmin, last bin = 4000 Hz > fmax
        for m in 0..fb.n_mel {
            assert_eq!(fb.weights[m * fb.n_bins], 0.0);
            assert_eq!(fb.weights[m * fb.n_bins + fb.n_bins - 1], 0.0);
        }
    }

    #[test]
    fn log_floor_applies() {
        let fb = MelBank::new();
        let power = vec![0f32; fb.n_bins];
        let mut out = vec![0f32; fb.n_mel];
        fb.apply_log(&power, &mut out);
        for &v in &out {
            assert!((v - spec::LOG_FLOOR.ln()).abs() < 1e-6);
        }
        // fused path honors the floor identically
        let mut fused = vec![0f32; fb.n_mel];
        fb.apply_log_fused(&power, &mut fused, FrontendKernel::Scalar);
        assert_eq!(out, fused);
    }

    #[test]
    fn bands_cover_exactly_the_nonzero_support() {
        let fb = MelBank::new();
        for m in 0..fb.n_mel {
            let row = &fb.weights[m * fb.n_bins..(m + 1) * fb.n_bins];
            let (start, len) = fb.bands[m];
            let (start, len) = (start as usize, len as usize);
            for (b, &w) in row.iter().enumerate() {
                let inside = b >= start && b < start + len;
                assert!(inside || w == 0.0, "filter {m} bin {b} outside band but nonzero");
            }
            assert!(len == 0 || (row[start] != 0.0 && row[start + len - 1] != 0.0));
        }
    }

    #[test]
    fn fused_matches_dense_within_tolerance() {
        use crate::util::prop::{forall, Gen};
        let fb = MelBank::new();
        forall("fused mel vs dense", 100, 0x3E1, |g: &mut Gen| {
            let power = g.vec_f32(fb.n_bins, 0.0, 50.0);
            let mut dense = vec![0f32; fb.n_mel];
            let mut fused = vec![0f32; fb.n_mel];
            fb.apply_log(&power, &mut dense);
            fb.apply_log_fused(&power, &mut fused, FrontendKernel::Scalar);
            for m in 0..fb.n_mel {
                assert!(
                    (dense[m] - fused[m]).abs() <= 1e-3,
                    "filter {m}: {} vs {}",
                    dense[m],
                    fused[m]
                );
            }
        });
    }

    #[test]
    fn fused_rungs_are_bit_identical() {
        use crate::util::prop::{forall, Gen};
        let fb = MelBank::new();
        let mut rungs = vec![FrontendKernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            rungs.push(FrontendKernel::Avx2);
        }
        #[cfg(target_arch = "aarch64")]
        rungs.push(FrontendKernel::Neon);
        forall("fused mel ladder", 50, 0x3E2, |g: &mut Gen| {
            let power = g.vec_f32(fb.n_bins, 0.0, 50.0);
            let mut base = vec![0f32; fb.n_mel];
            fb.apply_log_fused(&power, &mut base, FrontendKernel::Scalar);
            for &k in &rungs {
                let mut got = vec![0f32; fb.n_mel];
                fb.apply_log_fused(&power, &mut got, k);
                for m in 0..fb.n_mel {
                    assert_eq!(got[m].to_bits(), base[m].to_bits(), "{k:?} filter {m}");
                }
            }
        });
    }
}
