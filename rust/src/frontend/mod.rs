//! Audio frontend: PCM → stacked log-mel features.
//!
//! Mirrors `python/compile/data.py` exactly (constants in [`spec`] =
//! `python/compile/spec.py`); the cross-language golden test
//! (`rust/tests/golden_frontend.rs`) asserts agreement on exported
//! waveform/feature pairs.  Pipeline (paper §4, scaled):
//!
//! ```text
//! preemphasis(0.97) → 25ms Hann frames @10ms → |rFFT₂₅₆|² → 16 mel → log
//!   → stack 4 / decimate 2 → ×FEAT_SCALE → 64-d @ 20ms
//! ```
//!
//! [`pipeline::Frontend`] is the *streaming* version used by the serving
//! coordinator: it accepts arbitrary PCM chunks and emits feature frames
//! incrementally with the same output as the batch path.

pub mod fft;
pub mod kernel;
pub mod mel;
pub mod pipeline;
pub mod spec;
pub mod stacker;

pub use kernel::FrontendKernel;
pub use pipeline::{features, push_batch, BatchStream, Frontend};
