//! Shared world/frontend constants — the rust mirror of
//! `python/compile/spec.py`.  Any change must be made in both files; the
//! golden tests catch drift.

pub const SAMPLE_RATE: usize = 8000;
pub const FRAME_LEN: usize = 200; // 25 ms
pub const FRAME_HOP: usize = 80; // 10 ms
pub const FFT_SIZE: usize = 256;
pub const N_MEL: usize = 16;
pub const MEL_FMIN: f64 = 125.0;
pub const MEL_FMAX: f64 = 3800.0;
pub const PREEMPHASIS: f32 = 0.97;
pub const LOG_FLOOR: f32 = 1e-7;

pub const STACK: usize = 4;
pub const DECIMATE: usize = 2;
pub const FEAT_DIM: usize = N_MEL * STACK;
pub const FEAT_SCALE: f32 = 1.0 / 3.0;

pub const N_PHONES: usize = 40;
pub const BLANK: u32 = 0;
pub const N_LABELS: usize = N_PHONES + 1;

pub const N_WORDS: usize = 200;
pub const WORD_MIN_PHONES: i64 = 2;
pub const WORD_MAX_PHONES: i64 = 6;
pub const SENT_MIN_WORDS: i64 = 1;
pub const SENT_MAX_WORDS: i64 = 4;

pub const PHONE_DUR_MIN_MS: i64 = 40;
pub const PHONE_DUR_MAX_MS: i64 = 100;

pub const WORLD_SEED: u64 = 0x5EED_2016;
pub const NOISY_SNR_DB: (f64, f64) = (0.0, 10.0);
pub const SYNTH_NOISE_FLOOR: f64 = 0.02;

/// Seconds of audio represented by one output feature frame.
pub const FRAME_SECONDS: f64 = (FRAME_HOP * DECIMATE) as f64 / SAMPLE_RATE as f64;
