//! The assembled frontend: batch ([`features`]) and streaming
//! ([`Frontend`]) versions with identical output.
//!
//! The streaming frontend runs on the kernel ladder of
//! [`crate::frontend::kernel`]: the `reference` rung reproduces the seed
//! pipeline bit-for-bit (complex FFT + dense mel matmul); the fused rungs
//! swap in the real-input FFT and the sparse fused mel+log pass, within
//! the documented ≤1e-3 bound.  [`push_batch`] fans independent streams
//! out over the shared [`WorkerPool`](crate::util::pool::WorkerPool).

use crate::frontend::fft::{Complex, FftPlan, RealFftPlan};
use crate::frontend::kernel::FrontendKernel;
use crate::frontend::mel::MelBank;
use crate::frontend::spec;
use crate::frontend::stacker::{stack_all, Stacker};

/// Consumed-sample prefix beyond which the streaming buffer is compacted.
const COMPACT_AT: usize = 8192;

/// Hann window (symmetric, N−1 denominator — matches numpy/data.py).
fn hann() -> Vec<f32> {
    (0..spec::FRAME_LEN)
        .map(|n| {
            0.5 - 0.5
                * (2.0 * std::f64::consts::PI * n as f64 / (spec::FRAME_LEN - 1) as f64).cos()
                    as f32
        })
        .collect()
}

/// Batch path: whole waveform → `[T, FEAT_DIM]` features (row-major).
/// Mirrors `data.py::features` (preemphasis → log-mel → stack → scale).
pub fn features(wave: &[f32]) -> Vec<f32> {
    let mut fe = Frontend::new();
    let mut out = Vec::new();
    fe.push(wave, &mut out);
    // Batch semantics == streaming semantics by construction; the python
    // batch code also never flushes a partial final frame.
    out
}

/// Raw (unstacked) log-mel of a whole waveform — `[t_raw, N_MEL]`.
/// Always the reference path (complex FFT + dense mel); the golden tests
/// pin Python parity through this function.
pub fn log_mel(wave: &[f32]) -> Vec<f32> {
    let win = hann();
    let plan = FftPlan::new(spec::FFT_SIZE);
    let bank = MelBank::new();
    let mut pre = vec![0f32; wave.len()];
    if !wave.is_empty() {
        pre[0] = wave[0];
        for i in 1..wave.len() {
            pre[i] = wave[i] - spec::PREEMPHASIS * wave[i - 1];
        }
    }
    if pre.len() < spec::FRAME_LEN {
        return Vec::new();
    }
    let t_raw = 1 + (pre.len() - spec::FRAME_LEN) / spec::FRAME_HOP;
    let mut out = Vec::with_capacity(t_raw * spec::N_MEL);
    let mut frame = vec![0f32; spec::FRAME_LEN];
    let mut scratch = vec![Complex::default(); spec::FFT_SIZE];
    let mut power = vec![0f32; spec::FFT_SIZE / 2 + 1];
    let mut mel = vec![0f32; spec::N_MEL];
    for t in 0..t_raw {
        let s = t * spec::FRAME_HOP;
        for i in 0..spec::FRAME_LEN {
            frame[i] = pre[s + i] * win[i];
        }
        plan.power_spectrum(&frame, &mut scratch, &mut power);
        bank.apply_log(&power, &mut mel);
        out.extend_from_slice(&mel);
    }
    out
}

/// Streaming frontend: push PCM chunks of any size, feature frames come out.
pub struct Frontend {
    win: Vec<f32>,
    plan: FftPlan,
    rplan: RealFftPlan,
    bank: MelBank,
    stacker: Stacker,
    /// Resolved at construction so every frame of a stream runs one rung.
    kernel: FrontendKernel,
    /// Pre-emphasized samples not yet consumed by framing.
    buf: Vec<f32>,
    /// Read cursor into `buf` (compacted periodically, not per frame).
    pos: usize,
    /// Last raw sample seen (for preemphasis across chunk boundaries).
    prev_sample: f32,
    started: bool,
    // reusable scratch
    frame: Vec<f32>,
    fft_scratch: Vec<Complex>,
    rfft_scratch: Vec<Complex>,
    power: Vec<f32>,
    mel: Vec<f32>,
}

impl Default for Frontend {
    fn default() -> Self {
        Self::new()
    }
}

impl Frontend {
    pub fn new() -> Self {
        Self::with_kernel(FrontendKernel::Auto)
    }

    /// Frontend pinned to a specific kernel rung (resolved immediately;
    /// `Auto` honors `QUANTASR_FRONTEND_KERNEL`).
    pub fn with_kernel(kernel: FrontendKernel) -> Self {
        Frontend {
            win: hann(),
            plan: FftPlan::new(spec::FFT_SIZE),
            rplan: RealFftPlan::new(spec::FFT_SIZE),
            bank: MelBank::new(),
            stacker: Stacker::new(),
            kernel: kernel.resolve(),
            buf: Vec::new(),
            pos: 0,
            prev_sample: 0.0,
            started: false,
            frame: vec![0f32; spec::FRAME_LEN],
            fft_scratch: vec![Complex::default(); spec::FFT_SIZE],
            rfft_scratch: vec![Complex::default(); spec::FFT_SIZE / 2],
            power: vec![0f32; spec::FFT_SIZE / 2 + 1],
            mel: vec![0f32; spec::N_MEL],
        }
    }

    /// The resolved kernel rung this stream runs.
    pub fn kernel(&self) -> FrontendKernel {
        self.kernel
    }

    /// Push PCM samples; completed feature frames (FEAT_DIM each) are
    /// appended to `out`.  Returns the number of frames emitted.
    pub fn push(&mut self, pcm: &[f32], out: &mut Vec<f32>) -> usize {
        let t_obs = crate::obs::span_begin();
        // Preemphasis with cross-chunk memory; x'[0] = x[0] like python.
        for &s in pcm {
            let p = if self.started { s - spec::PREEMPHASIS * self.prev_sample } else { s };
            self.started = true;
            self.buf.push(p);
            self.prev_sample = s;
        }
        let mut emitted = 0;
        while self.buf.len() - self.pos >= spec::FRAME_LEN {
            let src = &self.buf[self.pos..self.pos + spec::FRAME_LEN];
            for i in 0..spec::FRAME_LEN {
                self.frame[i] = src[i] * self.win[i];
            }
            if self.kernel == FrontendKernel::Reference {
                self.plan.power_spectrum(&self.frame, &mut self.fft_scratch, &mut self.power);
                self.bank.apply_log(&self.power, &mut self.mel);
            } else {
                self.rplan.power_spectrum(&self.frame, &mut self.rfft_scratch, &mut self.power);
                self.bank.apply_log_fused(&self.power, &mut self.mel, self.kernel);
            }
            emitted += self.stacker.push(&self.mel, out);
            self.pos += spec::FRAME_HOP;
        }
        // Compact the consumed prefix occasionally — O(1) amortized per
        // sample instead of a memmove per frame (the seed drained per
        // frame, which at 10ms hop is 100 memmoves/second/stream).
        if self.pos >= COMPACT_AT {
            self.buf.copy_within(self.pos.., 0);
            let live = self.buf.len() - self.pos;
            self.buf.truncate(live);
            self.pos = 0;
        }
        // The engine brackets this call with the stream's trace context
        // (`obs::set_ctx`); standalone callers record under engine 0.
        crate::obs::span_end_ctx(crate::obs::EventKind::FrontendPush, t_obs, emitted as u64);
        emitted
    }

    /// Reset all streaming state (utterance boundary).
    pub fn reset(&mut self) {
        self.buf.clear();
        self.pos = 0;
        self.prev_sample = 0.0;
        self.started = false;
        self.stacker.reset();
    }
}

/// One stream's slice of a multi-stream frontend batch.
pub struct BatchStream<'a> {
    pub fe: &'a mut Frontend,
    pub pcm: &'a [f32],
    pub out: &'a mut Vec<f32>,
    /// Frames emitted for this stream (filled in by [`push_batch`]).
    pub emitted: usize,
}

/// Push PCM into many independent streams at once, fanned out over the
/// shared worker pool.  Exactly equivalent to calling
/// [`Frontend::push`] per stream in a loop — streams share no state.
pub fn push_batch(streams: &mut [BatchStream]) {
    let n = streams.len();
    crate::util::pool::WorkerPool::global().run_mut(n, streams, &|_i, s| {
        s.emitted = s.fe.push(s.pcm, s.out);
    });
}

/// Batch oracle built from parts (used in tests against the streaming path).
pub fn features_batch_oracle(wave: &[f32]) -> Vec<f32> {
    stack_all(&log_mel(wave))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};
    use crate::util::rng::Xoshiro256;

    fn tone(n: usize, f: f64, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256::new(seed);
        (0..n)
            .map(|i| {
                let t = i as f64 / spec::SAMPLE_RATE as f64;
                ((2.0 * std::f64::consts::PI * f * t).sin() * 0.3 + r.normal() * 0.01) as f32
            })
            .collect()
    }

    #[test]
    fn streaming_equals_batch_any_chunking() {
        // Reference rung: bit-compatible with the seed pipeline, so the
        // tight seed tolerance holds against the batch oracle.
        forall("frontend stream==batch", 12, 0xFE, |g: &mut Gen| {
            let n = g.usize_in(0, 6000);
            let wave = tone(n, 440.0 + g.f64_in(0.0, 1000.0), g.seed);
            let want = features_batch_oracle(&wave);
            let mut fe = Frontend::with_kernel(FrontendKernel::Reference);
            let mut got = Vec::new();
            let mut i = 0;
            while i < wave.len() {
                let chunk = g.usize_in(1, 700).min(wave.len() - i);
                fe.push(&wave[i..i + chunk], &mut got);
                i += chunk;
            }
            assert_eq!(got.len(), want.len(), "n={n}");
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn fused_streaming_matches_reference_batch() {
        // Fused rungs (real FFT + sparse mel) hold the documented ≤1e-3
        // bound against the reference oracle on log-mel features; the
        // FEAT_SCALE multiply tracks through linearly.
        forall("fused frontend vs reference", 10, 0xFEF, |g: &mut Gen| {
            let n = g.usize_in(0, 6000);
            let wave = tone(n, 300.0 + g.f64_in(0.0, 1500.0), g.seed);
            let want = features_batch_oracle(&wave);
            let mut fe = Frontend::with_kernel(FrontendKernel::Scalar);
            let mut got = Vec::new();
            fe.push(&wave, &mut got);
            assert_eq!(got.len(), want.len(), "n={n}");
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn fused_is_chunking_invariant() {
        // Within one rung the stream is exactly deterministic: frame
        // contents never depend on how the PCM was chunked.
        forall("fused chunking invariance", 8, 0xFEC, |g: &mut Gen| {
            let n = g.usize_in(0, 5000);
            let wave = tone(n, 800.0, g.seed);
            let mut whole = Frontend::new();
            let mut want = Vec::new();
            whole.push(&wave, &mut want);
            let mut fe = Frontend::new();
            let mut got = Vec::new();
            let mut i = 0;
            while i < wave.len() {
                let chunk = g.usize_in(1, 900).min(wave.len() - i);
                fe.push(&wave[i..i + chunk], &mut got);
                i += chunk;
            }
            assert_eq!(got.len(), want.len(), "n={n}");
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn push_batch_matches_sequential_push() {
        let waves: Vec<Vec<f32>> =
            (0..7).map(|i| tone(1500 + 700 * i, 350.0 + 90.0 * i as f64, 7 + i as u64)).collect();
        // sequential
        let mut seq: Vec<Vec<f32>> = Vec::new();
        for w in &waves {
            let mut fe = Frontend::new();
            let mut out = Vec::new();
            fe.push(w, &mut out);
            seq.push(out);
        }
        // batched over the pool
        let mut fes: Vec<Frontend> = (0..waves.len()).map(|_| Frontend::new()).collect();
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); waves.len()];
        {
            let mut streams: Vec<BatchStream> = fes
                .iter_mut()
                .zip(waves.iter())
                .zip(outs.iter_mut())
                .map(|((fe, w), out)| BatchStream { fe, pcm: w, out, emitted: 0 })
                .collect();
            push_batch(&mut streams);
            for s in &streams {
                assert_eq!(s.emitted * spec::FEAT_DIM, s.out.len());
            }
        }
        for (a, b) in outs.iter().zip(&seq) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn long_stream_compaction_is_transparent() {
        // Push far past COMPACT_AT and interleave odd chunk sizes; the
        // cursor+compaction bookkeeping must never skew framing.
        let wave = tone(40_000, 600.0, 11); // 2.5s @16k → well past 8192
        let mut whole = Frontend::with_kernel(FrontendKernel::Reference);
        let mut want = Vec::new();
        whole.push(&wave, &mut want);
        let mut fe = Frontend::with_kernel(FrontendKernel::Reference);
        let mut got = Vec::new();
        for chunk in wave.chunks(611) {
            fe.push(chunk, &mut got);
        }
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn feature_dim_and_count() {
        let wave = tone(8000, 700.0, 1); // 1s → 98 raw frames → 48 stacked
        let f = features(&wave);
        assert_eq!(f.len() % spec::FEAT_DIM, 0);
        let t_raw = 1 + (8000 - spec::FRAME_LEN) / spec::FRAME_HOP;
        let want = (t_raw - spec::STACK) / spec::DECIMATE + 1;
        assert_eq!(f.len() / spec::FEAT_DIM, want);
    }

    #[test]
    fn tone_lights_up_expected_mel_bin() {
        // 1 kHz tone: energy concentrates in the mel bin containing 1 kHz.
        let wave = tone(4000, 1000.0, 2);
        let mel = log_mel(&wave);
        let t = mel.len() / spec::N_MEL;
        // average over frames
        let mut avg = vec![0f32; spec::N_MEL];
        for i in 0..t {
            for j in 0..spec::N_MEL {
                avg[j] += mel[i * spec::N_MEL + j] / t as f32;
            }
        }
        let peak = avg
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        // 1 kHz lies in the middle third of the 125..3800 Hz mel range.
        assert!((4..=11).contains(&peak), "peak bin {peak}: {avg:?}");
    }

    #[test]
    fn reset_gives_fresh_stream() {
        let wave = tone(3000, 500.0, 3);
        let mut fe = Frontend::new();
        let mut a = Vec::new();
        fe.push(&wave, &mut a);
        fe.reset();
        let mut b = Vec::new();
        fe.push(&wave, &mut b);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_and_short_inputs() {
        assert!(features(&[]).is_empty());
        assert!(features(&vec![0.1; 100]).is_empty()); // < one frame
    }
}
