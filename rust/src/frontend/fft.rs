//! Radix-2 FFT and the real-input power spectrum used by the frontend.
//!
//! Iterative in-place Cooley–Tukey over `Complex` pairs; sizes are powers
//! of two (the frontend uses 256).  A precomputed twiddle table makes the
//! per-frame cost ~O(N log N) with no allocation.
//!
//! [`RealFftPlan`] exploits that frontend frames are real-valued: a
//! length-N real FFT is computed as one length-N/2 *complex* FFT (even
//! samples packed into the real lane, odd into the imaginary lane) plus
//! an O(N) untangle pass — half the butterfly work of the complex plan.
//! [`FftPlan`] remains the reference implementation the frontend's
//! `reference` kernel rung runs.

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Complex {
    pub re: f32,
    pub im: f32,
}

impl Complex {
    #[inline]
    pub fn new(re: f32, im: f32) -> Self {
        Complex { re, im }
    }

    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }

    #[inline]
    pub fn norm_sq(self) -> f32 {
        self.re * self.re + self.im * self.im
    }
}

/// Precomputed-twiddle FFT plan for a fixed power-of-two size.
pub struct FftPlan {
    pub n: usize,
    twiddles: Vec<Complex>,
    /// bit-reversal permutation
    rev: Vec<u32>,
}

impl FftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "FFT size must be a power of two");
        let mut twiddles = Vec::with_capacity(n / 2);
        for k in 0..n / 2 {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            twiddles.push(Complex::new(ang.cos() as f32, ang.sin() as f32));
        }
        let bits = n.trailing_zeros();
        let rev = (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect();
        FftPlan { n, twiddles, rev }
    }

    /// In-place forward FFT.
    pub fn forward(&self, buf: &mut [Complex]) {
        let n = self.n;
        debug_assert_eq!(buf.len(), n);
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            let mut start = 0;
            while start < n {
                for k in 0..half {
                    let w = self.twiddles[k * step];
                    let a = buf[start + k];
                    let b = buf[start + k + half].mul(w);
                    buf[start + k] = a.add(b);
                    buf[start + k + half] = a.sub(b);
                }
                start += len;
            }
            len <<= 1;
        }
    }

    /// Power spectrum of a real signal: returns `n/2 + 1` values
    /// `|FFT(x)|²` (zero-padding `x` to n).  `scratch` must be length n.
    pub fn power_spectrum(&self, x: &[f32], scratch: &mut [Complex], out: &mut [f32]) {
        let n = self.n;
        debug_assert!(x.len() <= n);
        debug_assert_eq!(scratch.len(), n);
        debug_assert_eq!(out.len(), n / 2 + 1);
        for (i, s) in scratch.iter_mut().enumerate() {
            *s = Complex::new(if i < x.len() { x[i] } else { 0.0 }, 0.0);
        }
        self.forward(scratch);
        for (k, o) in out.iter_mut().enumerate() {
            *o = scratch[k].norm_sq();
        }
    }
}

/// Real-input FFT plan: the length-`n` real transform via one length-`n/2`
/// complex FFT plus an O(n) untangle, for the power spectrum only.
///
/// Packing: `z[m] = x[2m] + i·x[2m+1]`.  With `Z = FFT_{n/2}(z)`,
///
/// ```text
/// Xe[k] = (Z[k] + conj(Z[n/2−k])) / 2          (spectrum of even samples)
/// Xo[k] = (Z[k] − conj(Z[n/2−k])) / 2i         (spectrum of odd samples)
/// X[k]  = Xe[k] + e^{−2πik/n}·Xo[k]
/// ```
///
/// DC and Nyquist are real: `X[0] = Re(Z[0]) + Im(Z[0])`,
/// `X[n/2] = Re(Z[0]) − Im(Z[0])`.
///
/// Not bit-identical to [`FftPlan::power_spectrum`] — the butterflies are
/// reassociated — but within the frontend's documented ≤1e-3 relative
/// bound (same contract as the Python-parity golden tests).
pub struct RealFftPlan {
    half: FftPlan,
    n: usize,
    /// untangle twiddles e^{-2πik/n}, k in 0..n/2.
    twiddles: Vec<Complex>,
}

impl RealFftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 4, "real FFT size must be a power of two ≥ 4");
        let mut twiddles = Vec::with_capacity(n / 2);
        for k in 0..n / 2 {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            twiddles.push(Complex::new(ang.cos() as f32, ang.sin() as f32));
        }
        RealFftPlan { half: FftPlan::new(n / 2), n, twiddles }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Power spectrum of a real signal: `n/2 + 1` values `|FFT(x)|²`
    /// (zero-padding `x` to n).  `scratch` must be length `n/2`.
    pub fn power_spectrum(&self, x: &[f32], scratch: &mut [Complex], out: &mut [f32]) {
        let n = self.n;
        let h = n / 2;
        debug_assert!(x.len() <= n);
        debug_assert_eq!(scratch.len(), h);
        debug_assert_eq!(out.len(), h + 1);
        for (m, s) in scratch.iter_mut().enumerate() {
            let re = if 2 * m < x.len() { x[2 * m] } else { 0.0 };
            let im = if 2 * m + 1 < x.len() { x[2 * m + 1] } else { 0.0 };
            *s = Complex::new(re, im);
        }
        self.half.forward(scratch);
        let z0 = scratch[0];
        let dc = z0.re + z0.im;
        let nyq = z0.re - z0.im;
        out[0] = dc * dc;
        out[h] = nyq * nyq;
        for k in 1..h {
            let zk = scratch[k];
            let zc = scratch[h - k];
            let xe = Complex::new((zk.re + zc.re) * 0.5, (zk.im - zc.im) * 0.5);
            let xo = Complex::new((zk.im + zc.im) * 0.5, (zc.re - zk.re) * 0.5);
            out[k] = xe.add(self.twiddles[k].mul(xo)).norm_sq();
        }
    }
}

/// Naive O(N²) DFT — correctness oracle for tests.
pub fn dft_power(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0f32; n / 2 + 1];
    for (k, o) in out.iter_mut().enumerate() {
        let (mut re, mut im) = (0f64, 0f64);
        for (i, &v) in x.iter().enumerate() {
            let ang = -2.0 * std::f64::consts::PI * k as f64 * i as f64 / n as f64;
            re += v as f64 * ang.cos();
            im += v as f64 * ang.sin();
        }
        *o = (re * re + im * im) as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn fft_matches_dft_power() {
        forall("fft vs dft", 20, 0xFF7, |g: &mut Gen| {
            let n = 1 << g.usize_in(3, 8); // 8..256
            let len = g.usize_in(1, n);
            let x = g.vec_normal(len, 1.0);
            let plan = FftPlan::new(n);
            let mut scratch = vec![Complex::default(); n];
            let mut got = vec![0f32; n / 2 + 1];
            plan.power_spectrum(&x, &mut scratch, &mut got);
            let want = dft_power(&x, n);
            for (a, b) in got.iter().zip(&want) {
                let tol = 1e-3 * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "{a} vs {b} (n={n})");
            }
        });
    }

    #[test]
    fn real_fft_matches_complex_fft() {
        // The fused frontend rungs swap FftPlan for RealFftPlan; this is
        // the documented ≤1e-3 relative bound of that swap.
        forall("real vs complex fft", 30, 0x2EA1, |g: &mut Gen| {
            let n = 1 << g.usize_in(3, 8); // 8..256
            let len = g.usize_in(1, n);
            let x = g.vec_normal(len, 1.0);
            let plan = FftPlan::new(n);
            let rplan = RealFftPlan::new(n);
            let mut scratch = vec![Complex::default(); n];
            let mut want = vec![0f32; n / 2 + 1];
            plan.power_spectrum(&x, &mut scratch, &mut want);
            let mut rscratch = vec![Complex::default(); n / 2];
            let mut got = vec![0f32; n / 2 + 1];
            rplan.power_spectrum(&x, &mut rscratch, &mut got);
            for (k, (a, b)) in got.iter().zip(&want).enumerate() {
                let tol = 1e-3 * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "bin {k}: {a} vs {b} (n={n})");
            }
        });
    }

    #[test]
    fn real_fft_matches_dft_power() {
        forall("real fft vs dft", 20, 0x2EA2, |g: &mut Gen| {
            let n = 1 << g.usize_in(3, 8);
            let len = g.usize_in(1, n);
            let x = g.vec_normal(len, 1.0);
            let rplan = RealFftPlan::new(n);
            let mut scratch = vec![Complex::default(); n / 2];
            let mut got = vec![0f32; n / 2 + 1];
            rplan.power_spectrum(&x, &mut scratch, &mut got);
            let want = dft_power(&x, n);
            for (a, b) in got.iter().zip(&want) {
                let tol = 1e-3 * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "{a} vs {b} (n={n})");
            }
        });
    }

    #[test]
    fn real_fft_impulse_is_flat() {
        let plan = RealFftPlan::new(64);
        let mut scratch = vec![Complex::default(); 32];
        let mut out = vec![0f32; 33];
        plan.power_spectrum(&[1.0], &mut scratch, &mut out);
        for &v in &out {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn impulse_is_flat() {
        let plan = FftPlan::new(64);
        let mut scratch = vec![Complex::default(); 64];
        let mut out = vec![0f32; 33];
        plan.power_spectrum(&[1.0], &mut scratch, &mut out);
        for &v in &out {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sine_peaks_at_bin() {
        let n = 256;
        let k = 17;
        let x: Vec<f32> = (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * k as f64 * i as f64 / n as f64).sin() as f32)
            .collect();
        let plan = FftPlan::new(n);
        let mut scratch = vec![Complex::default(); n];
        let mut out = vec![0f32; n / 2 + 1];
        plan.power_spectrum(&x, &mut scratch, &mut out);
        let max_bin = out
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_bin, k);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        FftPlan::new(100);
    }
}
