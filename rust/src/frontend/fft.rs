//! Radix-2 FFT and the real-input power spectrum used by the frontend.
//!
//! Iterative in-place Cooley–Tukey over `Complex` pairs; sizes are powers
//! of two (the frontend uses 256).  A precomputed twiddle table makes the
//! per-frame cost ~O(N log N) with no allocation.

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Complex {
    pub re: f32,
    pub im: f32,
}

impl Complex {
    #[inline]
    pub fn new(re: f32, im: f32) -> Self {
        Complex { re, im }
    }

    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }

    #[inline]
    pub fn norm_sq(self) -> f32 {
        self.re * self.re + self.im * self.im
    }
}

/// Precomputed-twiddle FFT plan for a fixed power-of-two size.
pub struct FftPlan {
    pub n: usize,
    twiddles: Vec<Complex>,
    /// bit-reversal permutation
    rev: Vec<u32>,
}

impl FftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "FFT size must be a power of two");
        let mut twiddles = Vec::with_capacity(n / 2);
        for k in 0..n / 2 {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            twiddles.push(Complex::new(ang.cos() as f32, ang.sin() as f32));
        }
        let bits = n.trailing_zeros();
        let rev = (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits)).collect();
        FftPlan { n, twiddles, rev }
    }

    /// In-place forward FFT.
    pub fn forward(&self, buf: &mut [Complex]) {
        let n = self.n;
        debug_assert_eq!(buf.len(), n);
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            let mut start = 0;
            while start < n {
                for k in 0..half {
                    let w = self.twiddles[k * step];
                    let a = buf[start + k];
                    let b = buf[start + k + half].mul(w);
                    buf[start + k] = a.add(b);
                    buf[start + k + half] = a.sub(b);
                }
                start += len;
            }
            len <<= 1;
        }
    }

    /// Power spectrum of a real signal: returns `n/2 + 1` values
    /// `|FFT(x)|²` (zero-padding `x` to n).  `scratch` must be length n.
    pub fn power_spectrum(&self, x: &[f32], scratch: &mut [Complex], out: &mut [f32]) {
        let n = self.n;
        debug_assert!(x.len() <= n);
        debug_assert_eq!(scratch.len(), n);
        debug_assert_eq!(out.len(), n / 2 + 1);
        for (i, s) in scratch.iter_mut().enumerate() {
            *s = Complex::new(if i < x.len() { x[i] } else { 0.0 }, 0.0);
        }
        self.forward(scratch);
        for (k, o) in out.iter_mut().enumerate() {
            *o = scratch[k].norm_sq();
        }
    }
}

/// Naive O(N²) DFT — correctness oracle for tests.
pub fn dft_power(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0f32; n / 2 + 1];
    for (k, o) in out.iter_mut().enumerate() {
        let (mut re, mut im) = (0f64, 0f64);
        for (i, &v) in x.iter().enumerate() {
            let ang = -2.0 * std::f64::consts::PI * k as f64 * i as f64 / n as f64;
            re += v as f64 * ang.cos();
            im += v as f64 * ang.sin();
        }
        *o = (re * re + im * im) as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn fft_matches_dft_power() {
        forall("fft vs dft", 20, 0xFF7, |g: &mut Gen| {
            let n = 1 << g.usize_in(3, 8); // 8..256
            let len = g.usize_in(1, n);
            let x = g.vec_normal(len, 1.0);
            let plan = FftPlan::new(n);
            let mut scratch = vec![Complex::default(); n];
            let mut got = vec![0f32; n / 2 + 1];
            plan.power_spectrum(&x, &mut scratch, &mut got);
            let want = dft_power(&x, n);
            for (a, b) in got.iter().zip(&want) {
                let tol = 1e-3 * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "{a} vs {b} (n={n})");
            }
        });
    }

    #[test]
    fn impulse_is_flat() {
        let plan = FftPlan::new(64);
        let mut scratch = vec![Complex::default(); 64];
        let mut out = vec![0f32; 33];
        plan.power_spectrum(&[1.0], &mut scratch, &mut out);
        for &v in &out {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sine_peaks_at_bin() {
        let n = 256;
        let k = 17;
        let x: Vec<f32> = (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * k as f64 * i as f64 / n as f64).sin() as f32)
            .collect();
        let plan = FftPlan::new(n);
        let mut scratch = vec![Complex::default(); n];
        let mut out = vec![0f32; n / 2 + 1];
        plan.power_spectrum(&x, &mut scratch, &mut out);
        let max_bin = out
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_bin, k);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        FftPlan::new(100);
    }
}
