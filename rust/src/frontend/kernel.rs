//! Frontend kernel ladder: rung selection + the fused mel-dot kernel.
//!
//! Same shape as the decode ladder (`decoder/kernel.rs`):
//!
//! - `Reference` — the seed frontend path: complex [`FftPlan`]
//!   power spectrum + dense `MelBank::apply_log` matmul-then-log.
//!   Bit-identical to the seed frontend; defines the semantics.
//! - `Scalar` — fused path, scalar arithmetic: real-input FFT
//!   ([`crate::frontend::fft::RealFftPlan`], half the butterfly work) +
//!   one fused pass over the *sparse* triangular mel rows (each filter
//!   only touches its nonzero band) with the log applied in the same
//!   sweep.
//! - `Avx2` / `Neon` — the fused path with the band dot product
//!   vectorized.
//!
//! **Bit-exactness contract.**  All *fused* rungs are bit-identical to
//! each other: the scalar fused dot keeps eight partial accumulators and
//! reduces them in exactly the horizontal-sum order of the vector rungs
//! (no FMA — multiplies and adds stay separate ops everywhere).  The
//! fused path as a whole matches `Reference` to the frontend's documented
//! ≤1e-3 relative bound (the same tolerance the Python-parity golden
//! tests use): the real FFT reassociates butterflies and the sparse dot
//! reassociates the filter sum.
//!
//! `QUANTASR_FRONTEND_KERNEL` forces a rung
//! (`reference|scalar|avx2|neon|auto`), mirroring the other kernel knobs.
//! Unknown or unavailable values warn and fall back to auto.
//!
//! [`FftPlan`]: crate::frontend::fft::FftPlan

/// Which frontend implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontendKernel {
    /// Seed complex-FFT + dense mel path — the semantic reference.
    Reference,
    /// Real-input FFT + fused sparse mel+log, scalar arithmetic.
    Scalar,
    #[cfg(target_arch = "x86_64")]
    /// Fused path with the AVX2 band dot.
    Avx2,
    #[cfg(target_arch = "aarch64")]
    /// Fused path with the NEON band dot.
    Neon,
    /// Resolve at runtime: forced rung if set, else best available.
    Auto,
}

impl FrontendKernel {
    /// Concrete rung this resolves to at runtime.  Clamps a forced SIMD
    /// rung back to `Scalar` when the CPU lacks the feature.
    pub fn resolve(self) -> FrontendKernel {
        let k = match self {
            FrontendKernel::Auto => {
                forced_frontend_kernel().unwrap_or_else(Self::best_available)
            }
            other => other,
        };
        #[cfg(target_arch = "x86_64")]
        if k == FrontendKernel::Avx2 && !crate::quant::gemm::avx2_available() {
            return FrontendKernel::Scalar;
        }
        k
    }

    fn best_available() -> FrontendKernel {
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            return FrontendKernel::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        return FrontendKernel::Neon;
        #[allow(unreachable_code)]
        FrontendKernel::Scalar
    }
}

/// `QUANTASR_FRONTEND_KERNEL` forcing, parsed once per process.
pub fn forced_frontend_kernel() -> Option<FrontendKernel> {
    static ONCE: std::sync::OnceLock<Option<FrontendKernel>> = std::sync::OnceLock::new();
    *ONCE.get_or_init(|| {
        let v = std::env::var("QUANTASR_FRONTEND_KERNEL").ok()?;
        match v.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => None,
            "reference" => Some(FrontendKernel::Reference),
            "scalar" => Some(FrontendKernel::Scalar),
            #[cfg(target_arch = "x86_64")]
            "avx2" if crate::quant::gemm::avx2_available() => Some(FrontendKernel::Avx2),
            #[cfg(target_arch = "aarch64")]
            "neon" => Some(FrontendKernel::Neon),
            other => {
                eprintln!(
                    "QUANTASR_FRONTEND_KERNEL='{other}' unknown or unavailable \
                     on this CPU; using auto"
                );
                None
            }
        }
    })
}

/// Dot product with eight partial accumulators and a fixed reduction
/// order `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))` — the order the AVX2 /
/// NEON horizontal sums produce, mirrored exactly by the scalar rung so
/// every fused rung is bit-identical.  Tail elements (len % 8) are added
/// sequentially after the reduction on every rung.
pub fn dot8(kernel: FrontendKernel, w: &[f32], p: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), p.len());
    match kernel.resolve() {
        #[cfg(target_arch = "x86_64")]
        FrontendKernel::Avx2 => unsafe { dot8_avx2(w, p) },
        #[cfg(target_arch = "aarch64")]
        FrontendKernel::Neon => unsafe { dot8_neon(w, p) },
        _ => dot8_scalar(w, p),
    }
}

fn dot8_scalar(w: &[f32], p: &[f32]) -> f32 {
    let n = w.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let base = c * 8;
        for j in 0..8 {
            acc[j] += w[base + j] * p[base + j];
        }
    }
    let s0 = acc[0] + acc[4];
    let s1 = acc[1] + acc[5];
    let s2 = acc[2] + acc[6];
    let s3 = acc[3] + acc[7];
    let mut sum = (s0 + s2) + (s1 + s3);
    for i in chunks * 8..n {
        sum += w[i] * p[i];
    }
    sum
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot8_avx2(w: &[f32], p: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = w.len();
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let a = _mm256_loadu_ps(w.as_ptr().add(c * 8));
        let b = _mm256_loadu_ps(p.as_ptr().add(c * 8));
        // mul + add kept separate (no FMA) so rungs stay bit-identical.
        acc = _mm256_add_ps(acc, _mm256_mul_ps(a, b));
    }
    let lo = _mm256_castps256_ps128(acc);
    let hi = _mm256_extractf128_ps::<1>(acc);
    let s = _mm_add_ps(lo, hi); // (a0+a4, a1+a5, a2+a6, a3+a7)
    let t = _mm_add_ps(s, _mm_movehl_ps(s, s)); // (s0+s2, s1+s3, ..)
    let r = _mm_add_ss(t, _mm_shuffle_ps::<1>(t, t));
    let mut sum = _mm_cvtss_f32(r);
    for i in chunks * 8..n {
        sum += w[i] * p[i];
    }
    sum
}

#[cfg(target_arch = "aarch64")]
unsafe fn dot8_neon(w: &[f32], p: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = w.len();
    let chunks = n / 8;
    let mut acc_lo = vdupq_n_f32(0.0);
    let mut acc_hi = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let base = c * 8;
        let a0 = vld1q_f32(w.as_ptr().add(base));
        let b0 = vld1q_f32(p.as_ptr().add(base));
        // vaddq(vmulq) rather than vmlaq: FMLA would fuse the rounding.
        acc_lo = vaddq_f32(acc_lo, vmulq_f32(a0, b0));
        let a1 = vld1q_f32(w.as_ptr().add(base + 4));
        let b1 = vld1q_f32(p.as_ptr().add(base + 4));
        acc_hi = vaddq_f32(acc_hi, vmulq_f32(a1, b1));
    }
    let s = vaddq_f32(acc_lo, acc_hi); // (a0+a4, a1+a5, a2+a6, a3+a7)
    let t0 = vgetq_lane_f32::<0>(s) + vgetq_lane_f32::<2>(s);
    let t1 = vgetq_lane_f32::<1>(s) + vgetq_lane_f32::<3>(s);
    let mut sum = t0 + t1;
    for i in chunks * 8..n {
        sum += w[i] * p[i];
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    fn fused_rungs() -> Vec<FrontendKernel> {
        let mut r = vec![FrontendKernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            r.push(FrontendKernel::Avx2);
        }
        #[cfg(target_arch = "aarch64")]
        r.push(FrontendKernel::Neon);
        r
    }

    #[test]
    fn dot8_rungs_are_bit_identical() {
        forall("dot8 ladder", 300, 0xD078, |g: &mut Gen| {
            let n = g.usize_in(0, 70); // hits empty, sub-chunk, and tails
            let w = g.vec_normal(n, 1.0);
            let p = g.vec_normal(n, 2.0);
            let base = dot8_scalar(&w, &p);
            for k in fused_rungs() {
                let got = dot8(k, &w, &p);
                assert_eq!(got.to_bits(), base.to_bits(), "{k:?} n={n}");
            }
        });
    }

    #[test]
    fn dot8_matches_plain_dot_within_tolerance() {
        forall("dot8 vs naive", 100, 0xD079, |g: &mut Gen| {
            let n = g.usize_in(1, 129);
            let w = g.vec_f32(n, 0.0, 1.0);
            let p = g.vec_f32(n, 0.0, 10.0);
            let naive: f32 = w.iter().zip(&p).map(|(a, b)| a * b).sum();
            let got = dot8_scalar(&w, &p);
            assert!((got - naive).abs() <= 1e-3 * (1.0 + naive.abs()), "{got} vs {naive}");
        });
    }

    #[test]
    fn resolve_never_yields_auto() {
        assert_ne!(FrontendKernel::Auto.resolve(), FrontendKernel::Auto);
        assert_eq!(FrontendKernel::Reference.resolve(), FrontendKernel::Reference);
    }
}
