//! GEMM kernels: the inference hot path.
//!
//! Quantized layer contract (paper Figure 1): `y = F(R(Q(x)·W') + b)` with
//! activation `F` applied by the caller.  The integer product uses the
//! offset algebra of eq. (1): with `V'' = V' + zp`,
//!
//! ```text
//! Σ_k (x'+zpx)(w'+zpw) = Σ x'w' + zpx·Σw'[o] + zpw·Σx'[i] + K·zpx·zpw
//! ```
//!
//! so the kernel only computes the u8·u8 dot `Σ x'w'`; `Σw'[o]` is
//! precomputed per weight row ([`QMatrix::row_sums`]) and `Σx'[i]` once per
//! input row.  Recovery divides by `qx·qw` (eq. 1) and adds the f32 bias.
//!
//! Three integer kernels (perf-pass ladder, EXPERIMENTS.md §Perf-L3):
//!   - `Scalar`   — straight loop (baseline)
//!   - `Unrolled` — 4-way unrolled u32 accumulation
//!   - `Avx2`     — `cvtepu8→madd_epi16` 16-lane dot (runtime-detected)
//!
//! plus f32 baselines (`f32` scalar / FMA) for the paper's int8-vs-float
//! speedup claim (experiment E1).

use crate::quant::qmatrix::QMatrix;
use crate::quant::scheme::QuantParams;

/// Kernel selection for the integer GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    Scalar,
    Unrolled,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// Best available on this CPU.
    Auto,
}

impl Kernel {
    pub fn resolve(self) -> Kernel {
        match self {
            Kernel::Auto => {
                #[cfg(target_arch = "x86_64")]
                {
                    if std::arch::is_x86_feature_detected!("avx2") {
                        return Kernel::Avx2;
                    }
                }
                Kernel::Unrolled
            }
            k => k,
        }
    }
}

/// Reusable scratch buffers — keeps the hot loop allocation-free.
#[derive(Default, Clone)]
pub struct QScratch {
    pub xq: Vec<u8>,
    pub xrow_sums: Vec<i32>,
    /// Per-input-row quantization params.
    pub xparams: Vec<QuantParams>,
}

/// Quantize the input batch on the fly (eq. 2), **per row**: each batch row
/// (= each stream in cross-stream serving) gets its own (Q, zp), so results
/// are independent of batch composition — running a stream alone or packed
/// with co-riders yields identical numerics.  At batch 1 this coincides
/// with the per-tensor quantization of the JAX reference.
pub fn quantize_input(x: &[f32], batch: usize, in_dim: usize, s: &mut QScratch) {
    debug_assert_eq!(x.len(), batch * in_dim);
    s.xq.resize(x.len(), 0);
    s.xrow_sums.clear();
    s.xparams.clear();
    for i in 0..batch {
        let (p, sum) = quantize_row(
            &x[i * in_dim..(i + 1) * in_dim],
            &mut s.xq[i * in_dim..(i + 1) * in_dim],
        );
        s.xrow_sums.push(sum);
        s.xparams.push(p);
    }
}

/// Quantize one input row (eq. 2) and return its (params, integer row sum)
/// — the single definition of per-row input quantization shared by the
/// batch-contiguous and lane-strided entry points, so they cannot drift.
fn quantize_row(row: &[f32], out: &mut [u8]) -> (QuantParams, i32) {
    let p = QuantParams::from_slice(row);
    p.quantize_slice(row, out);
    let sum = out.iter().map(|&v| v as i32).sum::<i32>();
    (p, sum)
}

/// Lane-masked input quantization over a **lane-resident** buffer
/// `x [max_lanes, in_dim]`: only the rows listed in `lanes` are quantized
/// (scratch entries are lane-indexed; inactive lanes keep stale data that
/// is never read).  The per-row contract of [`quantize_input`] holds
/// unchanged — a lane's (Q, zp) depends on its own row only, so posteriors
/// are bit-identical whether a stream runs alone or packed with co-riders.
pub fn quantize_input_lanes(
    x: &[f32],
    max_lanes: usize,
    lanes: &[usize],
    in_dim: usize,
    s: &mut QScratch,
) {
    debug_assert_eq!(x.len(), max_lanes * in_dim);
    s.xq.resize(x.len(), 0);
    s.xrow_sums.resize(max_lanes, 0);
    s.xparams.resize(max_lanes, QuantParams::from_range(0.0, 1.0));
    for &lane in lanes {
        debug_assert!(lane < max_lanes);
        let (p, sum) = quantize_row(
            &x[lane * in_dim..(lane + 1) * in_dim],
            &mut s.xq[lane * in_dim..(lane + 1) * in_dim],
        );
        s.xrow_sums[lane] = sum;
        s.xparams[lane] = p;
    }
}

/// Integer GEMM: `y[b, o] (+)= recover(Q(x)·Wᵀ) + bias[o]`.
///
/// `accumulate` adds into `y` instead of overwriting — used by the LSTM
/// step to fuse `x·Wx + h·Wh` without an intermediate buffer.
/// Only `Granularity::PerMatrix` weight matrices are accepted here (the
/// paper's deployment choice); finer granularities go through
/// [`qgemm_any_granularity`] (ablation path).
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    x: &[f32],
    batch: usize,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &mut QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    assert_eq!(x.len(), batch * w.in_dim);
    assert_eq!(y.len(), batch * w.out_dim);
    assert_eq!(w.params.len(), 1, "qgemm requires per-matrix granularity");
    quantize_input(x, batch, w.in_dim, scratch);
    qgemm_prequantized(batch, w, bias, y, scratch, kernel, accumulate);
}

/// Integer GEMM on an already-quantized input (scratch holds xq/row sums/
/// per-row params).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_prequantized(
    batch: usize,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    let k = w.in_dim;
    let kernel = kernel.resolve();
    for i in 0..batch {
        qgemm_input_row(
            w,
            bias,
            &scratch.xq[i * k..(i + 1) * k],
            &scratch.xparams[i],
            scratch.xrow_sums[i] as i64,
            &mut y[i * w.out_dim..(i + 1) * w.out_dim],
            kernel,
            accumulate,
        );
    }
}

/// Lane-masked integer GEMM over a lane-resident `x [max_lanes, in_dim]`
/// buffer: only rows listed in `lanes` are quantized, multiplied and
/// written into the matching rows of `y [max_lanes, out_dim]`.  Inactive
/// lanes cost nothing — this is the serving engine's in-place hot path
/// (no gather into a packed batch, no scatter back).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_lanes(
    x: &[f32],
    max_lanes: usize,
    lanes: &[usize],
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &mut QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    assert_eq!(x.len(), max_lanes * w.in_dim);
    assert_eq!(y.len(), max_lanes * w.out_dim);
    assert_eq!(w.params.len(), 1, "qgemm requires per-matrix granularity");
    quantize_input_lanes(x, max_lanes, lanes, w.in_dim, scratch);
    let k = w.in_dim;
    let kernel = kernel.resolve();
    for &lane in lanes {
        qgemm_input_row(
            w,
            bias,
            &scratch.xq[lane * k..(lane + 1) * k],
            &scratch.xparams[lane],
            scratch.xrow_sums[lane] as i64,
            &mut y[lane * w.out_dim..(lane + 1) * w.out_dim],
            kernel,
            accumulate,
        );
    }
}

/// One quantized input row × every weight row → one output row.  Shared by
/// the batch-contiguous and lane-strided entry points; `kernel` must
/// already be resolved (never `Auto`).
#[allow(clippy::too_many_arguments)]
fn qgemm_input_row(
    w: &QMatrix,
    bias: Option<&[f32]>,
    xrow: &[u8],
    xp: &QuantParams,
    xsum: i64,
    yrow: &mut [f32],
    kernel: Kernel,
    accumulate: bool,
) {
    let wp = w.params[0];
    let k = w.in_dim;
    let inv = 1.0 / (xp.q as f64 * wp.q as f64);
    let kzz = k as i64 * xp.zp * wp.zp;
    let finish = |o: usize, raw: i64, yrow: &mut [f32]| {
        let full = raw + xp.zp * w.row_sums[o] as i64 + wp.zp * xsum + kzz;
        let v = (full as f64 * inv) as f32 + bias.map_or(0.0, |b| b[o]);
        if accumulate {
            yrow[o] += v;
        } else {
            yrow[o] = v;
        }
    };
    let mut o = 0;
    // 4-row blocked AVX2 path: x is loaded/widened once per 4 rows.
    #[cfg(target_arch = "x86_64")]
    if kernel == Kernel::Avx2 {
        while o + 4 <= w.out_dim {
            let raws = unsafe {
                dot4_u8_avx2(
                    xrow,
                    [
                        &w.data[o * k..(o + 1) * k],
                        &w.data[(o + 1) * k..(o + 2) * k],
                        &w.data[(o + 2) * k..(o + 3) * k],
                        &w.data[(o + 3) * k..(o + 4) * k],
                    ],
                )
            };
            for (d, &raw) in raws.iter().enumerate() {
                finish(o + d, raw as i64, yrow);
            }
            o += 4;
        }
    }
    while o < w.out_dim {
        let wrow = &w.data[o * k..(o + 1) * k];
        let raw = match kernel {
            Kernel::Scalar => dot_u8_scalar(xrow, wrow),
            Kernel::Unrolled => dot_u8_unrolled(xrow, wrow),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { dot_u8_avx2(xrow, wrow) },
            Kernel::Auto => unreachable!("resolved above"),
        } as i64;
        finish(o, raw, yrow);
        o += 1;
    }
}

/// Granularity-generic (slow) integer matmul for the E3 ablation: honors
/// per-row / sub-block params by recovering per element group.
pub fn qgemm_any_granularity(
    x: &[f32],
    batch: usize,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
) {
    let k = w.in_dim;
    let mut xq = vec![0u8; x.len()];
    let xps: Vec<QuantParams> = (0..batch)
        .map(|i| {
            let p = QuantParams::from_slice(&x[i * k..(i + 1) * k]);
            p.quantize_slice(&x[i * k..(i + 1) * k], &mut xq[i * k..(i + 1) * k]);
            p
        })
        .collect();
    for i in 0..batch {
        let xp = &xps[i];
        for o in 0..w.out_dim {
            let mut acc = 0.0f64;
            // Group by parameter region so the integer dot stays exact
            // within each region (same structure as the paper's
            // sub-matrix granularity).
            match w.granularity {
                crate::quant::Granularity::PerMatrix | crate::quant::Granularity::PerRow => {
                    let wp = w.param_for(o, 0);
                    let mut raw: i64 = 0;
                    let mut wsum: i64 = 0;
                    let mut xsum: i64 = 0;
                    for c in 0..k {
                        let xv = xq[i * k + c] as i64;
                        let wv = w.data[o * k + c] as i64;
                        raw += xv * wv;
                        wsum += wv;
                        xsum += xv;
                    }
                    let full = raw
                        + xp.zp * wsum
                        + wp.zp * xsum
                        + k as i64 * xp.zp * wp.zp;
                    acc = full as f64 / (xp.q as f64 * wp.q as f64);
                }
                crate::quant::Granularity::SubBlock { size } => {
                    let mut c0 = 0;
                    while c0 < k {
                        let c1 = (c0 + size).min(k);
                        let wp = w.param_for(o, c0);
                        let mut raw: i64 = 0;
                        let mut wsum: i64 = 0;
                        let mut xsum: i64 = 0;
                        for c in c0..c1 {
                            let xv = xq[i * k + c] as i64;
                            let wv = w.data[o * k + c] as i64;
                            raw += xv * wv;
                            wsum += wv;
                            xsum += xv;
                        }
                        let full = raw
                            + xp.zp * wsum
                            + wp.zp * xsum
                            + (c1 - c0) as i64 * xp.zp * wp.zp;
                        acc += full as f64 / (xp.q as f64 * wp.q as f64);
                        c0 = c1;
                    }
                }
            }
            y[i * w.out_dim + o] = acc as f32 + bias.map_or(0.0, |b| b[o]);
        }
    }
}

// ---------------------------------------------------------------------------
// u8·u8 → i32 dot kernels
// ---------------------------------------------------------------------------

#[inline]
pub fn dot_u8_scalar(a: &[u8], b: &[u8]) -> i32 {
    let mut acc: i32 = 0;
    for (&x, &w) in a.iter().zip(b) {
        acc += x as i32 * w as i32;
    }
    acc
}

/// 4-way unrolled variant — helps older LLVM autovectorize.
#[inline]
pub fn dot_u8_unrolled(a: &[u8], b: &[u8]) -> i32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as i32 * b[i] as i32;
        s1 += a[i + 1] as i32 * b[i + 1] as i32;
        s2 += a[i + 2] as i32 * b[i + 2] as i32;
        s3 += a[i + 3] as i32 * b[i + 3] as i32;
    }
    let mut acc = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        acc += a[i] as i32 * b[i] as i32;
    }
    acc
}

/// AVX2: 32 u8 lanes per step (2 × `cvtepu8_epi16` + `madd_epi16`, two
/// independent accumulators for ILP).  Exact: u8×u8 products fit
/// i16×i16→i32 madd without saturation.
///
/// # Safety
/// Caller must ensure AVX2 is available (see [`Kernel::resolve`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot_u8_avx2(a: &[u8], b: &[u8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    let mut i = 0;
    while i + 32 <= n {
        let a0 = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let b0 = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        acc0 = _mm256_add_epi32(
            acc0,
            _mm256_madd_epi16(_mm256_cvtepu8_epi16(a0), _mm256_cvtepu8_epi16(b0)),
        );
        let a1 = _mm_loadu_si128(a.as_ptr().add(i + 16) as *const __m128i);
        let b1 = _mm_loadu_si128(b.as_ptr().add(i + 16) as *const __m128i);
        acc1 = _mm256_add_epi32(
            acc1,
            _mm256_madd_epi16(_mm256_cvtepu8_epi16(a1), _mm256_cvtepu8_epi16(b1)),
        );
        i += 32;
    }
    while i + 16 <= n {
        let av = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let bv = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        acc0 = _mm256_add_epi32(
            acc0,
            _mm256_madd_epi16(_mm256_cvtepu8_epi16(av), _mm256_cvtepu8_epi16(bv)),
        );
        i += 16;
    }
    let acc = _mm256_add_epi32(acc0, acc1);
    // Horizontal sum of 8 × i32.
    let hi = _mm256_extracti128_si256(acc, 1);
    let lo = _mm256_castsi256_si128(acc);
    let s = _mm_add_epi32(hi, lo);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
    let mut total = _mm_cvtsi128_si32(s);
    while i < n {
        total += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    total
}

/// AVX2, 4 weight rows at once sharing the x loads/widening — the GEMV hot
/// path (perf pass L3.2): loading + widening x is half of the 1-row
/// kernel's work, so amortizing it over 4 output rows raises throughput.
///
/// # Safety
/// Caller must ensure AVX2 is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot4_u8_avx2(x: &[u8], w: [&[u8]; 4]) -> [i32; 4] {
    use std::arch::x86_64::*;
    let n = x.len();
    let mut acc = [_mm256_setzero_si256(); 4];
    let mut i = 0;
    while i + 16 <= n {
        let xv =
            _mm256_cvtepu8_epi16(_mm_loadu_si128(x.as_ptr().add(i) as *const __m128i));
        for r in 0..4 {
            let wv = _mm256_cvtepu8_epi16(_mm_loadu_si128(
                w[r].as_ptr().add(i) as *const __m128i
            ));
            acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(xv, wv));
        }
        i += 16;
    }
    let mut out = [0i32; 4];
    for r in 0..4 {
        let hi = _mm256_extracti128_si256(acc[r], 1);
        let lo = _mm256_castsi256_si128(acc[r]);
        let s = _mm_add_epi32(hi, lo);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        out[r] = _mm_cvtsi128_si32(s);
        for j in i..n {
            out[r] += x[j] as i32 * w[r][j] as i32;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// f32 baseline (the 'match' path and the E1 comparison target)
// ---------------------------------------------------------------------------

/// Dense f32 matrix in the same transposed `[out, in]` layout.
#[derive(Clone, Debug)]
pub struct FMatrix {
    pub out_dim: usize,
    pub in_dim: usize,
    pub data: Vec<f32>,
}

impl FMatrix {
    /// From math layout `[in, out]` row-major.
    pub fn from_math_layout(w: &[f32], in_dim: usize, out_dim: usize) -> Self {
        assert_eq!(w.len(), in_dim * out_dim);
        let mut t = vec![0f32; w.len()];
        for i in 0..in_dim {
            for o in 0..out_dim {
                t[o * in_dim + i] = w[i * out_dim + o];
            }
        }
        FMatrix { out_dim, in_dim, data: t }
    }

    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// f32 GEMM `y = x·Wᵀ + b`, with optional accumulate (see [`qgemm`]).
pub fn fgemm(
    x: &[f32],
    batch: usize,
    w: &FMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(x.len(), batch * w.in_dim);
    assert_eq!(y.len(), batch * w.out_dim);
    let k = w.in_dim;
    let use_fma = f32_fma_available();
    for i in 0..batch {
        fgemm_input_row(
            w,
            bias,
            &x[i * k..(i + 1) * k],
            &mut y[i * w.out_dim..(i + 1) * w.out_dim],
            use_fma,
            accumulate,
        );
    }
}

/// Lane-masked f32 GEMM over a lane-resident `x [max_lanes, in_dim]`
/// buffer (the float twin of [`qgemm_lanes`]).
pub fn fgemm_lanes(
    x: &[f32],
    max_lanes: usize,
    lanes: &[usize],
    w: &FMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(x.len(), max_lanes * w.in_dim);
    assert_eq!(y.len(), max_lanes * w.out_dim);
    let k = w.in_dim;
    let use_fma = f32_fma_available();
    for &lane in lanes {
        debug_assert!(lane < max_lanes);
        fgemm_input_row(
            w,
            bias,
            &x[lane * k..(lane + 1) * k],
            &mut y[lane * w.out_dim..(lane + 1) * w.out_dim],
            use_fma,
            accumulate,
        );
    }
}

#[inline]
fn f32_fma_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("fma")
            && std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// One f32 input row × every weight row → one output row (shared by the
/// batch-contiguous and lane-strided entry points).
fn fgemm_input_row(
    w: &FMatrix,
    bias: Option<&[f32]>,
    xrow: &[f32],
    yrow: &mut [f32],
    use_fma: bool,
    accumulate: bool,
) {
    #[cfg(not(target_arch = "x86_64"))]
    let _ = use_fma;
    let k = w.in_dim;
    for o in 0..w.out_dim {
        let wrow = &w.data[o * k..(o + 1) * k];
        #[cfg(target_arch = "x86_64")]
        let raw = if use_fma {
            unsafe { dot_f32_fma(xrow, wrow) }
        } else {
            dot_f32_scalar(xrow, wrow)
        };
        #[cfg(not(target_arch = "x86_64"))]
        let raw = dot_f32_scalar(xrow, wrow);
        let v = raw + bias.map_or(0.0, |b| b[o]);
        if accumulate {
            yrow[o] += v;
        } else {
            yrow[o] = v;
        }
    }
}

#[inline]
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut acc = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        acc += a[i] * b[i];
    }
    acc
}

/// # Safety
/// Requires AVX2 + FMA.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_f32_fma(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0;
    while i + 16 <= n {
        let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
        let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
        acc0 = _mm256_fmadd_ps(a0, b0, acc0);
        let a1 = _mm256_loadu_ps(a.as_ptr().add(i + 8));
        let b1 = _mm256_loadu_ps(b.as_ptr().add(i + 8));
        acc1 = _mm256_fmadd_ps(a1, b1, acc1);
        i += 16;
    }
    while i + 8 <= n {
        let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
        let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
        acc0 = _mm256_fmadd_ps(a0, b0, acc0);
        i += 8;
    }
    let acc = _mm256_add_ps(acc0, acc1);
    let hi = _mm256_extractf128_ps(acc, 1);
    let lo = _mm256_castps256_ps128(acc);
    let s = _mm_add_ps(hi, lo);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
    let mut total = _mm_cvtss_f32(s);
    while i < n {
        total += a[i] * b[i];
        i += 1;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Granularity;
    use crate::util::prop::{forall, Gen};

    /// Float reference of the full quantized pipeline: recover weights and
    /// recovered-quantized inputs, multiply in f64.
    fn reference(x: &[f32], batch: usize, w: &QMatrix, bias: Option<&[f32]>) -> Vec<f32> {
        let k = w.in_dim;
        let mut y = vec![0f32; batch * w.out_dim];
        for i in 0..batch {
            let xp = QuantParams::from_slice(&x[i * k..(i + 1) * k]);
            for o in 0..w.out_dim {
                let mut acc = 0f64;
                for c in 0..k {
                    let xr = xp.shifted(xp.quantize(x[i * k + c])) as f64 / xp.q as f64;
                    let wr = w.param_for(o, c).recover(w.data[o * k + c]) as f64;
                    acc += xr * wr;
                }
                y[i * w.out_dim + o] = acc as f32 + bias.map_or(0.0, |b| b[o]);
            }
        }
        y
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn qgemm_matches_reference_all_kernels() {
        forall("qgemm vs ref", 40, 0xD07, |g: &mut Gen| {
            let batch = g.usize_in(1, 6);
            let in_dim = g.usize_in(1, 70);
            let out_dim = g.usize_in(1, 40);
            let x = g.vec_normal(batch * in_dim, 1.0);
            let wf = g.vec_normal(in_dim * out_dim, 0.5);
            let bias = g.vec_normal(out_dim, 0.2);
            let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
            let want = reference(&x, batch, &w, Some(&bias));
            let mut scratch = QScratch::default();
            let kernels: &[Kernel] = {
                #[cfg(target_arch = "x86_64")]
                {
                    &[Kernel::Scalar, Kernel::Unrolled, Kernel::Avx2]
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    &[Kernel::Scalar, Kernel::Unrolled]
                }
            };
            for &kern in kernels {
                #[cfg(target_arch = "x86_64")]
                if kern == Kernel::Avx2 && !std::arch::is_x86_feature_detected!("avx2") {
                    continue;
                }
                let mut y = vec![0f32; batch * out_dim];
                qgemm(&x, batch, &w, Some(&bias), &mut y, &mut scratch, kern, false);
                assert_close(&y, &want, 1e-4);
            }
        });
    }

    #[test]
    fn qgemm_approximates_float_matmul() {
        // End-to-end quantization error must stay small relative to range.
        let mut g = Gen::new(1);
        let (batch, in_dim, out_dim) = (4, 128, 64);
        let x = g.vec_normal(batch * in_dim, 1.0);
        let wf = g.vec_normal(in_dim * out_dim, 0.3);
        let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
        let fw = FMatrix::from_math_layout(&wf, in_dim, out_dim);
        let mut yq = vec![0f32; batch * out_dim];
        let mut yf = vec![0f32; batch * out_dim];
        let mut s = QScratch::default();
        qgemm(&x, batch, &w, None, &mut yq, &mut s, Kernel::Auto, false);
        fgemm(&x, batch, &fw, None, &mut yf, false);
        let scale = yf.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let max_err = yq.iter().zip(&yf).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_err < 0.02 * scale.max(1.0), "err {max_err} scale {scale}");
    }

    #[test]
    fn accumulate_fuses_two_matmuls() {
        let mut g = Gen::new(2);
        let (batch, k1, k2, out) = (2, 20, 12, 10);
        let x1 = g.vec_normal(batch * k1, 1.0);
        let x2 = g.vec_normal(batch * k2, 1.0);
        let w1f = g.vec_normal(k1 * out, 0.4);
        let w2f = g.vec_normal(k2 * out, 0.4);
        let w1 = QMatrix::from_f32_math_layout(&w1f, k1, out, Granularity::PerMatrix);
        let w2 = QMatrix::from_f32_math_layout(&w2f, k2, out, Granularity::PerMatrix);
        let mut s = QScratch::default();
        let mut y = vec![0f32; batch * out];
        qgemm(&x1, batch, &w1, None, &mut y, &mut s, Kernel::Auto, false);
        qgemm(&x2, batch, &w2, None, &mut y, &mut s, Kernel::Auto, true);
        let mut y1 = vec![0f32; batch * out];
        let mut y2 = vec![0f32; batch * out];
        qgemm(&x1, batch, &w1, None, &mut y1, &mut s, Kernel::Auto, false);
        qgemm(&x2, batch, &w2, None, &mut y2, &mut s, Kernel::Auto, false);
        for i in 0..y.len() {
            assert!((y[i] - (y1[i] + y2[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn any_granularity_matches_per_matrix_when_trivial() {
        let mut g = Gen::new(3);
        let (batch, in_dim, out_dim) = (2, 32, 8);
        let x = g.vec_normal(batch * in_dim, 1.0);
        let wf = g.vec_normal(in_dim * out_dim, 0.5);
        let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
        let mut y1 = vec![0f32; batch * out_dim];
        let mut y2 = vec![0f32; batch * out_dim];
        let mut s = QScratch::default();
        qgemm(&x, batch, &w, None, &mut y1, &mut s, Kernel::Scalar, false);
        qgemm_any_granularity(&x, batch, &w, None, &mut y2);
        assert_close(&y1, &y2, 1e-5);
    }

    #[test]
    fn dot_kernels_agree() {
        forall("dot kernels", 60, 0xBEEF, |g: &mut Gen| {
            let n = g.usize_in(0, 200);
            let a: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let want = dot_u8_scalar(&a, &b);
            assert_eq!(dot_u8_unrolled(&a, &b), want);
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                assert_eq!(unsafe { dot_u8_avx2(&a, &b) }, want);
            }
        });
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn dot4_agrees_with_scalar() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return;
        }
        forall("dot4", 50, 0xD04, |g: &mut Gen| {
            let n = g.usize_in(0, 150);
            let x: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let rows: Vec<Vec<u8>> = (0..4)
                .map(|_| (0..n).map(|_| g.usize_in(0, 255) as u8).collect())
                .collect();
            let got = unsafe {
                dot4_u8_avx2(&x, [&rows[0], &rows[1], &rows[2], &rows[3]])
            };
            for r in 0..4 {
                assert_eq!(got[r], dot_u8_scalar(&x, &rows[r]));
            }
        });
    }

    #[test]
    fn qgemm_lanes_bit_identical_to_solo_rows() {
        // The per-row quantization contract: a lane's output is a pure
        // function of its own input row — bit-identical whether the lane
        // runs alone, packed with co-riders, or via the batch entry point.
        forall("qgemm lanes invariance", 40, 0x1A7E5, |g: &mut Gen| {
            let max_lanes = g.usize_in(1, 8);
            let in_dim = g.usize_in(1, 60);
            let out_dim = g.usize_in(1, 30);
            let wf = g.vec_normal(in_dim * out_dim, 0.5);
            let bias = g.vec_normal(out_dim, 0.2);
            let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
            let x = g.vec_normal(max_lanes * in_dim, 1.0);
            // random non-empty active-lane subset
            let lanes: Vec<usize> =
                (0..max_lanes).filter(|_| g.bool()).collect();
            let lanes = if lanes.is_empty() { vec![g.usize_in(0, max_lanes - 1)] } else { lanes };
            let mut scratch = QScratch::default();
            let mut y = vec![f32::NAN; max_lanes * out_dim];
            qgemm_lanes(&x, max_lanes, &lanes, &w, Some(&bias), &mut y, &mut scratch, Kernel::Auto, false);
            for &lane in &lanes {
                // solo run of the same row through the batch-1 entry point
                let mut y1 = vec![0f32; out_dim];
                qgemm(
                    &x[lane * in_dim..(lane + 1) * in_dim],
                    1,
                    &w,
                    Some(&bias),
                    &mut y1,
                    &mut QScratch::default(),
                    Kernel::Auto,
                    false,
                );
                for o in 0..out_dim {
                    assert!(
                        y[lane * out_dim + o] == y1[o],
                        "lane {lane} o {o}: {} != {} (not bit-identical)",
                        y[lane * out_dim + o],
                        y1[o]
                    );
                }
            }
            // inactive lanes untouched
            for lane in 0..max_lanes {
                if !lanes.contains(&lane) {
                    assert!(y[lane * out_dim..(lane + 1) * out_dim]
                        .iter()
                        .all(|v| v.is_nan()));
                }
            }
        });
    }

    #[test]
    fn fgemm_lanes_bit_identical_to_batch() {
        forall("fgemm lanes", 40, 0xF1A7, |g: &mut Gen| {
            let max_lanes = g.usize_in(1, 6);
            let in_dim = g.usize_in(1, 64);
            let out_dim = g.usize_in(1, 24);
            let wf = g.vec_normal(in_dim * out_dim, 0.4);
            let w = FMatrix::from_math_layout(&wf, in_dim, out_dim);
            let x = g.vec_normal(max_lanes * in_dim, 1.0);
            let all: Vec<usize> = (0..max_lanes).collect();
            let mut y_lanes = vec![0f32; max_lanes * out_dim];
            let mut y_batch = vec![0f32; max_lanes * out_dim];
            fgemm_lanes(&x, max_lanes, &all, &w, None, &mut y_lanes, false);
            fgemm(&x, max_lanes, &w, None, &mut y_batch, false);
            assert_eq!(y_lanes, y_batch);
        });
    }

    #[test]
    fn f32_dot_kernels_agree() {
        forall("f32 dot", 40, 0xF00D, |g: &mut Gen| {
            let n = g.usize_in(0, 300);
            let a = g.vec_normal(n, 1.0);
            let b = g.vec_normal(n, 1.0);
            let want = dot_f32_scalar(&a, &b);
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("fma") {
                let got = unsafe { dot_f32_fma(&a, &b) };
                assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()));
            }
        });
    }
}
