//! GEMM kernels: the inference hot path.
//!
//! Quantized layer contract (paper Figure 1): `y = F(R(Q(x)·W') + b)` with
//! activation `F` applied by the caller.  The integer product uses the
//! offset algebra of eq. (1): with `V'' = V' + zp`,
//!
//! ```text
//! Σ_k (x'+zpx)(w'+zpw) = Σ x'w' + zpx·Σw'[o] + zpw·Σx'[i] + K·zpx·zpw
//! ```
//!
//! so the kernel only computes the u8·u8 dot `Σ x'w'`; `Σw'[o]` is
//! precomputed per weight row ([`QMatrix::row_sums`]) and `Σx'[i]` once per
//! input row.  Recovery divides by `qx·qw` (eq. 1) and adds the f32 bias.
//!
//! ## The kernel ladder
//!
//! [`Kernel::Auto`] resolves via runtime CPU feature detection (one-time,
//! overridable with the `QUANTASR_KERNEL` env var — used by the CI
//! kernel-matrix job to force every rung):
//!
//! **Row-dot kernels** walk the row-major [`QMatrix::data`] one output row
//! at a time (x is re-streamed per row; kept as baselines and as the
//! fallback for matrices without a packed mirror):
//! - `Scalar`   — straight loop: the bit-exactness reference
//! - `Unrolled` — 4-way unrolled u32 accumulation (autovectorizes)
//! - `Avx2`     — `cvtepu8→madd_epi16`, 4-row-blocked x reuse
//!
//! **Packed-panel kernels** stream a [`PackedQMatrix`] — weights repacked
//! once at load into K-interleaved panels of `NR = 4` output rows (layout
//! docs on [`PackedQMatrix`]) — so each 16-byte input chunk is loaded and
//! widened once per 4 outputs and the whole matrix is one sequential read:
//! - `PackedScalar`  — portable reference for the packed layout
//! - `PackedAvx2`    — `cvtepi8→madd_epi16` over interleaved panels
//! - `PackedVnni`    — AVX-512-VNNI `vpdpbusd`, 64 MACs/instruction
//!   (cargo feature `vnni`: needs a toolchain with stable AVX-512
//!   intrinsics; off by default so tier-1 builds never depend on it)
//! - `PackedNeonDot` — aarch64 `vdotq_u32` (`dotprod`-detected)
//!
//! Each packed rung has an **int4 twin** selected by the matrix, not the
//! ladder: when the panel mirror is 4-bit (`PackedQMatrix::bits == 4`,
//! built by the `PerChannelI4` requantization scheme), [`packed_micro`]
//! routes the same `Kernel` rung to the nibble microkernels
//! (`packed_dot4_i4_scalar` / `_avx2` / `_neon_dot`), which unpack two
//! weights per byte with one mask and one shift — no shuffles — and dot
//! them against the same u8 activations.
//!
//! ## Weight granularity (requantization schemes)
//!
//! `qgemm*` accepts per-matrix **and** per-row (per-output-channel)
//! quantized weights.  Per-matrix keeps the seed finish above,
//! bit-for-bit.  Per-row weights (the `PerChannelU8` / `PerChannelI4`
//! schemes, see [`crate::quant::QuantScheme`]) use the per-channel finish:
//! with `a = Σx' + K·zpx` hoisted per input row,
//!
//! ```text
//! full[o] = Σ x'w' + zpx·Σw'[o] + zpw[o]·a   (exact in i64)
//! y[o]    = (full[o]·(1/qx)) · (1/qw[o])     (two f64 mults, per-row scale)
//! ```
//!
//! The integer part is the same eq. (1) algebra with the zpw terms
//! regrouped per output row; the float finish multiplies by the
//! precomputed [`QMatrix::inv_q`] row instead of one hoisted scale.  Both
//! finishes are single definitions shared by every rung (row-dot and
//! packed), so the bit-exactness contract below holds per scheme.
//!
//! ## Bit-exactness contract
//!
//! Every kernel — every packed variant included, at any thread count —
//! must produce outputs **bit-identical** to `Scalar`.  All integer dots
//! are exact (no saturation: u8×u8 products fit `madd`'s i16×i16→i32, and
//! the packed x86 layout stores `w−128` as i8 so `Σ x·w` is recovered
//! exactly as `Σ x·(w−128) + 128·Σx`), and the float finish applies the
//! same operations in the same order on every path.  This is what makes
//! the serving engine's batch-invariance guarantee survive kernel and
//! layout changes; property tests below enforce it for all K tails,
//! panel remainders and lane subsets.
//!
//! ## Parallel panel execution
//!
//! Packed GEMMs above a work threshold ([`packed_threads`]) fan their
//! panels out over the **persistent worker pool**
//! ([`crate::util::pool::WorkerPool`]): workers park between calls, so
//! dispatch costs a few µs instead of the tens-of-µs scoped-thread spawn
//! the old path paid — which is why the parallel threshold sits at ~256K
//! MACs (batch-1 GEMVs at serving shapes now use multiple cores).
//! Panels own disjoint output columns, so the split is race-free and —
//! since each output is computed by exactly one executor with identical
//! arithmetic, wherever a chunk happens to run — bit-identical at any
//! thread count.  `QUANTASR_GEMM_THREADS` forces a count (1 = serial,
//! 0/unset = auto).
//!
//! ## Input quantization (and the activation cache)
//!
//! Per-row input quantization (the min/max scan + eq. 2 quantize) runs on
//! the SIMD elementwise rungs ([`crate::quant::elementwise`]) and is
//! bit-identical to the scalar loop.  [`QActRows`] caches a buffer's
//! quantized rows with per-row dirty tracking, so a vector consumed by
//! two quantized GEMMs in one tick (an LSTM layer's `h` feeding its own
//! `Wh` next step and the next layer's `Wx`) is scanned and quantized
//! once — `qgemm_cached`/`qgemm_lanes_cached` consume the cache and are
//! bit-identical to the uncached entry points.
//!
//! Plus f32 baselines (`f32` scalar / FMA) for the paper's int8-vs-float
//! speedup claim (experiment E1).

use std::sync::OnceLock;

use crate::quant::elementwise::{self, EwKernel};
use crate::quant::qmatrix::{Granularity, PackedQMatrix, QMatrix};
use crate::quant::scheme::QuantParams;
use crate::util::pool::{forced_gemm_threads, WorkerPool};

/// Kernel selection for the integer GEMM (see the module docs for the
/// full ladder and the bit-exactness contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    Scalar,
    Unrolled,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// Packed-panel path with the portable scalar microkernel.
    PackedScalar,
    /// Packed-panel path, `madd_epi16` microkernel (runtime-detected).
    #[cfg(target_arch = "x86_64")]
    PackedAvx2,
    /// Packed-panel path, AVX-512-VNNI `vpdpbusd` microkernel.
    #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
    PackedVnni,
    /// Packed-panel path, NEON `vdotq_u32` microkernel.
    #[cfg(target_arch = "aarch64")]
    PackedNeonDot,
    /// Best available on this CPU.
    Auto,
}

/// Runtime detection for the AVX2 rungs (results are cached by std).
#[cfg(target_arch = "x86_64")]
#[inline]
pub fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Runtime detection for the AVX-512-VNNI rung — the **single** predicate
/// gating the unsafe `vpdpbusd` dispatch.  Add any newly required feature
/// here and every dispatch/test/bench site inherits it.
#[cfg(all(target_arch = "x86_64", feature = "vnni"))]
#[inline]
pub fn vnni_available() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx512bw")
        && std::arch::is_x86_feature_detected!("avx512vnni")
}

/// Runtime detection for the NEON `dot` rung.
#[cfg(target_arch = "aarch64")]
#[inline]
pub fn neon_dot_available() -> bool {
    std::arch::is_aarch64_feature_detected!("dotprod")
}

impl Kernel {
    /// Resolve `Auto` to the best kernel this CPU supports (honoring a
    /// `QUANTASR_KERNEL` override); explicit choices pass through.
    pub fn resolve(self) -> Kernel {
        match self {
            Kernel::Auto => {
                if let Some(k) = forced_kernel() {
                    return k;
                }
                Kernel::best_available()
            }
            k => k,
        }
    }

    /// The top of the ladder for this CPU.
    fn best_available() -> Kernel {
        #[cfg(target_arch = "x86_64")]
        {
            #[cfg(feature = "vnni")]
            {
                if vnni_available() {
                    return Kernel::PackedVnni;
                }
            }
            if avx2_available() {
                return Kernel::PackedAvx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if neon_dot_available() {
                return Kernel::PackedNeonDot;
            }
        }
        Kernel::Unrolled
    }

    /// Does this kernel run the packed-panel path?
    // match (not matches!): the SIMD arms are cfg-gated per arch/feature.
    #[allow(clippy::match_like_matches_macro)]
    pub fn is_packed(self) -> bool {
        match self {
            Kernel::PackedScalar => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::PackedAvx2 => true,
            #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
            Kernel::PackedVnni => true,
            #[cfg(target_arch = "aarch64")]
            Kernel::PackedNeonDot => true,
            _ => false,
        }
    }

    /// Clamp an **explicitly requested** SIMD kernel to what this CPU can
    /// actually execute (a forced bench/test may name a rung the host
    /// lacks).  This is the soundness gate that lets the safe `qgemm*`
    /// entry points call `#[target_feature]` microkernels: every kernel
    /// that reaches a dispatch table has passed either the detection in
    /// [`Kernel::best_available`]/[`forced_kernel`] or this check.
    /// Detection results are cached by std, so this costs a couple of
    /// relaxed loads per GEMM call.
    fn checked(self) -> Kernel {
        match self {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 if !avx2_available() => Kernel::Unrolled,
            #[cfg(target_arch = "x86_64")]
            Kernel::PackedAvx2 if !avx2_available() => Kernel::PackedScalar,
            #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
            Kernel::PackedVnni if !vnni_available() => Kernel::PackedScalar,
            #[cfg(target_arch = "aarch64")]
            Kernel::PackedNeonDot if !neon_dot_available() => Kernel::PackedScalar,
            k => k,
        }
    }
}

/// Row-dot kernel used when a packed kernel was selected but the matrix
/// has no packed mirror (the ablation constructors leave per-row and
/// sub-block grids unpacked; scheme-built matrices always pack).
fn demote_packed(k: Kernel) -> Kernel {
    if !k.is_packed() {
        return k;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            return Kernel::Avx2;
        }
    }
    Kernel::Unrolled
}

/// `QUANTASR_KERNEL` override (parsed once): forces the named rung of the
/// ladder wherever `Kernel::Auto` is used — the CI kernel-matrix job runs
/// the full quant/nn test suite once per rung this way.  Unknown names or
/// kernels this CPU/build can't run fall back to auto with a warning.
fn forced_kernel() -> Option<Kernel> {
    static FORCED: OnceLock<Option<Kernel>> = OnceLock::new();
    *FORCED.get_or_init(|| {
        let v = std::env::var("QUANTASR_KERNEL").ok()?;
        match v.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => None,
            "scalar" => Some(Kernel::Scalar),
            "unrolled" => Some(Kernel::Unrolled),
            "packed-scalar" => Some(Kernel::PackedScalar),
            #[cfg(target_arch = "x86_64")]
            "avx2" if avx2_available() => Some(Kernel::Avx2),
            #[cfg(target_arch = "x86_64")]
            "packed-avx2" if avx2_available() => Some(Kernel::PackedAvx2),
            #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
            "packed-vnni" if vnni_available() => Some(Kernel::PackedVnni),
            #[cfg(target_arch = "aarch64")]
            "packed-neon-dot" if neon_dot_available() => Some(Kernel::PackedNeonDot),
            other => {
                eprintln!(
                    "QUANTASR_KERNEL='{other}' unknown or unavailable on this CPU/build; \
                     falling back to auto dispatch"
                );
                None
            }
        }
    })
}

/// Reusable scratch buffers — keeps the hot loop allocation-free.
#[derive(Default, Clone)]
pub struct QScratch {
    pub xq: Vec<u8>,
    pub xrow_sums: Vec<i32>,
    /// Per-input-row quantization params.
    pub xparams: Vec<QuantParams>,
    /// Zero-padded copies of the quantized rows (`[rows, k_padded]`) the
    /// packed microkernels stream — padding bytes are zero so padded
    /// products contribute nothing (exactness invariant).
    pub xpad: Vec<u8>,
    /// Hoisted per-row constants for the packed path (reused per call).
    pub(crate) rowctx: Vec<RowCtx>,
}

/// Quantize the input batch on the fly (eq. 2), **per row**: each batch row
/// (= each stream in cross-stream serving) gets its own (Q, zp), so results
/// are independent of batch composition — running a stream alone or packed
/// with co-riders yields identical numerics.  At batch 1 this coincides
/// with the per-tensor quantization of the JAX reference.
pub fn quantize_input(x: &[f32], batch: usize, in_dim: usize, s: &mut QScratch, ew: EwKernel) {
    debug_assert_eq!(x.len(), batch * in_dim);
    s.xq.resize(x.len(), 0);
    s.xrow_sums.clear();
    s.xparams.clear();
    for i in 0..batch {
        let (p, sum) = quantize_row(
            &x[i * in_dim..(i + 1) * in_dim],
            &mut s.xq[i * in_dim..(i + 1) * in_dim],
            ew,
        );
        s.xrow_sums.push(sum);
        s.xparams.push(p);
    }
}

/// Quantize one input row (eq. 2) and return its (params, integer row sum)
/// — the single definition of per-row input quantization shared by every
/// entry point (batch-contiguous, lane-strided, and the [`QActRows`]
/// cache), so they cannot drift.  The scan and the quantize run on the
/// SIMD elementwise rungs, which are bit-identical to the scalar
/// [`QuantParams`] loop (see `quant::elementwise`).
fn quantize_row(row: &[f32], out: &mut [u8], ew: EwKernel) -> (QuantParams, i32) {
    let (vmin, vmax) = elementwise::minmax(row, ew);
    // from_minmax owns the degenerate/non-finite fallback — the same
    // definition `QuantParams::from_slice` uses, so the SIMD scan path
    // cannot drift from the scheme.
    let p = QuantParams::from_minmax(vmin, vmax);
    let sum = elementwise::quantize_slice_sum(&p, row, out, ew);
    (p, sum)
}

/// Lane-masked input quantization over a **lane-resident** buffer
/// `x [max_lanes, in_dim]`: only the rows listed in `lanes` are quantized
/// (scratch entries are lane-indexed; inactive lanes keep stale data that
/// is never read).  The per-row contract of [`quantize_input`] holds
/// unchanged — a lane's (Q, zp) depends on its own row only, so posteriors
/// are bit-identical whether a stream runs alone or packed with co-riders.
pub fn quantize_input_lanes(
    x: &[f32],
    max_lanes: usize,
    lanes: &[usize],
    in_dim: usize,
    s: &mut QScratch,
    ew: EwKernel,
) {
    debug_assert_eq!(x.len(), max_lanes * in_dim);
    s.xq.resize(x.len(), 0);
    s.xrow_sums.resize(max_lanes, 0);
    s.xparams.resize(max_lanes, QuantParams::from_range(0.0, 1.0));
    for &lane in lanes {
        debug_assert!(lane < max_lanes);
        let (p, sum) = quantize_row(
            &x[lane * in_dim..(lane + 1) * in_dim],
            &mut s.xq[lane * in_dim..(lane + 1) * in_dim],
            ew,
        );
        s.xrow_sums[lane] = sum;
        s.xparams[lane] = p;
    }
}

/// Prequantized activation rows with per-row dirty tracking: one
/// buffer's quantized bytes, integer row sums and (Q, zp) params, shared
/// by every quantized GEMM that consumes the buffer.  In the LSTM stack a
/// layer's `h` output feeds *two* quantized GEMMs — its own `Wh` on the
/// next step and the next layer's `Wx` on the same tick — so caching the
/// quantization halves the per-tick scan cost.  Rows are re-quantized
/// lazily: producers call [`QActRows::invalidate_row`] (or
/// `invalidate_prefix`) after rewriting a row, consumers call
/// [`QActRows::ensure_batch`]/[`QActRows::ensure_lanes`] before the GEMM.
/// Cached rows go through the same `quantize_row` as the uncached path,
/// so `qgemm_cached` is **bit-identical** to `qgemm` on the same floats.
#[derive(Default, Clone)]
pub struct QActRows {
    xq: Vec<u8>,
    sums: Vec<i32>,
    params: Vec<QuantParams>,
    dirty: Vec<bool>,
    rows: usize,
    in_dim: usize,
}

impl QActRows {
    /// Pre-size for `rows` rows of `in_dim` (all rows start dirty).
    pub fn sized(rows: usize, in_dim: usize) -> QActRows {
        let mut c = QActRows::default();
        c.ensure_shape(rows, in_dim);
        c
    }

    fn ensure_shape(&mut self, rows: usize, in_dim: usize) {
        if self.in_dim == in_dim && self.rows >= rows {
            return;
        }
        let rows = if self.in_dim == in_dim { rows.max(self.rows) } else { rows };
        self.rows = rows;
        self.in_dim = in_dim;
        self.xq.clear();
        self.xq.resize(rows * in_dim, 0);
        self.sums.clear();
        self.sums.resize(rows, 0);
        self.params.clear();
        self.params.resize(rows, QuantParams::from_range(0.0, 1.0));
        self.dirty.clear();
        self.dirty.resize(rows, true);
    }

    /// Mark rows `0..rows` stale (their source vector was rewritten).
    pub fn invalidate_prefix(&mut self, rows: usize) {
        for d in self.dirty.iter_mut().take(rows) {
            *d = true;
        }
    }

    /// Mark one row stale.
    pub fn invalidate_row(&mut self, row: usize) {
        if row < self.dirty.len() {
            self.dirty[row] = true;
        }
    }

    /// Re-quantize the stale rows among `0..batch` of `x [batch, in_dim]`.
    pub fn ensure_batch(&mut self, x: &[f32], batch: usize, in_dim: usize, ew: EwKernel) {
        self.ensure_shape(batch, in_dim);
        debug_assert!(x.len() >= batch * in_dim);
        for i in 0..batch {
            if self.dirty[i] {
                self.requant_row(x, i, ew);
            }
        }
    }

    /// Re-quantize the stale rows among the listed lanes of
    /// `x [max_rows, in_dim]`.
    pub fn ensure_lanes(
        &mut self,
        x: &[f32],
        max_rows: usize,
        lanes: &[usize],
        in_dim: usize,
        ew: EwKernel,
    ) {
        self.ensure_shape(max_rows, in_dim);
        debug_assert!(x.len() >= max_rows * in_dim);
        for &lane in lanes {
            debug_assert!(lane < max_rows);
            if self.dirty[lane] {
                self.requant_row(x, lane, ew);
            }
        }
    }

    fn requant_row(&mut self, x: &[f32], i: usize, ew: EwKernel) {
        let k = self.in_dim;
        let (p, sum) =
            quantize_row(&x[i * k..(i + 1) * k], &mut self.xq[i * k..(i + 1) * k], ew);
        self.params[i] = p;
        self.sums[i] = sum;
        self.dirty[i] = false;
    }
}

/// Integer GEMM: `y[b, o] (+)= recover(Q(x)·Wᵀ) + bias[o]`.
///
/// `accumulate` adds into `y` instead of overwriting — used by the LSTM
/// step to fuse `x·Wx + h·Wh` without an intermediate buffer.
/// `Granularity::PerMatrix` (the paper's deployment choice) and
/// `Granularity::PerRow` (the per-channel requantization schemes) weight
/// matrices are accepted here; sub-block granularity goes through
/// [`qgemm_any_granularity`] (ablation path).
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    x: &[f32],
    batch: usize,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &mut QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    assert_eq!(x.len(), batch * w.in_dim);
    assert_eq!(y.len(), batch * w.out_dim);
    assert!(
        matches!(w.granularity, Granularity::PerMatrix | Granularity::PerRow),
        "qgemm requires per-matrix or per-row granularity"
    );
    quantize_input(x, batch, w.in_dim, scratch, EwKernel::for_gemm(kernel));
    qgemm_prequantized(batch, w, bias, y, scratch, kernel, accumulate);
}

/// Integer GEMM on an already-quantized input (scratch holds xq/row sums/
/// per-row params).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_prequantized(
    batch: usize,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &mut QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    let QScratch { xq, xrow_sums, xparams, xpad, rowctx } = scratch;
    qgemm_quantized_rows(
        xq, xrow_sums, xparams, batch, 0..batch, w, bias, y, xpad, rowctx, kernel, accumulate,
    );
}

/// Integer GEMM over a [`QActRows`] cache's prequantized rows `0..batch`
/// — bit-identical to [`qgemm`] on the floats the cache was built from.
/// The listed rows must be clean (see [`QActRows::ensure_batch`]).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_cached(
    cache: &QActRows,
    batch: usize,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &mut QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    assert_eq!(cache.in_dim, w.in_dim, "cache/weight in_dim mismatch");
    assert!(cache.rows >= batch, "cache holds fewer rows than the batch");
    assert_eq!(y.len(), batch * w.out_dim);
    assert!(
        matches!(w.granularity, Granularity::PerMatrix | Granularity::PerRow),
        "qgemm requires per-matrix or per-row granularity"
    );
    debug_assert!(
        cache.dirty.iter().take(batch).all(|d| !d),
        "qgemm_cached on stale rows: call ensure_batch first"
    );
    let QScratch { xpad, rowctx, .. } = scratch;
    qgemm_quantized_rows(
        &cache.xq,
        &cache.sums,
        &cache.params,
        batch,
        0..batch,
        w,
        bias,
        y,
        xpad,
        rowctx,
        kernel,
        accumulate,
    );
}

/// Lane-masked integer GEMM over a [`QActRows`] cache — the cached twin
/// of [`qgemm_lanes`].  The listed lanes must be clean
/// (see [`QActRows::ensure_lanes`]).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_lanes_cached(
    cache: &QActRows,
    max_lanes: usize,
    lanes: &[usize],
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &mut QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    assert_eq!(cache.in_dim, w.in_dim, "cache/weight in_dim mismatch");
    assert!(cache.rows >= max_lanes, "cache holds fewer rows than max_lanes");
    assert_eq!(y.len(), max_lanes * w.out_dim);
    assert!(
        matches!(w.granularity, Granularity::PerMatrix | Granularity::PerRow),
        "qgemm requires per-matrix or per-row granularity"
    );
    debug_assert!(
        lanes.iter().all(|&l| !cache.dirty[l]),
        "qgemm_lanes_cached on stale lanes: call ensure_lanes first"
    );
    let QScratch { xpad, rowctx, .. } = scratch;
    qgemm_quantized_rows(
        &cache.xq,
        &cache.sums,
        &cache.params,
        max_lanes,
        lanes.iter().copied(),
        w,
        bias,
        y,
        xpad,
        rowctx,
        kernel,
        accumulate,
    );
}

/// The shared quantized-row driver: packed-panel path when the kernel and
/// matrix support it, row-dot fallback otherwise.  `xq`/`sums`/`params`
/// are row-indexed by the values `rows` yields (whether they come from
/// `QScratch` or a [`QActRows`] cache — the arithmetic cannot drift
/// between the cached and uncached paths because this is the only
/// implementation).
#[allow(clippy::too_many_arguments)]
fn qgemm_quantized_rows(
    xq: &[u8],
    sums: &[i32],
    params: &[QuantParams],
    total_rows: usize,
    rows: impl Iterator<Item = usize> + Clone,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    xpad: &mut Vec<u8>,
    rowctx: &mut Vec<RowCtx>,
    kernel: Kernel,
    accumulate: bool,
) {
    let k = w.in_dim;
    let kernel = kernel.resolve().checked();
    if kernel.is_packed() {
        if let Some(pk) = w.packed.as_deref() {
            build_xpad(xq, xpad, k, pk.k_padded, total_rows, rows.clone());
            build_rowctx(rowctx, rows, sums, params, w, pk);
            qgemm_packed(w, pk, bias, rowctx, xpad, y, kernel, accumulate);
            return;
        }
    }
    let kernel = demote_packed(kernel);
    for i in rows {
        qgemm_input_row(
            w,
            bias,
            &xq[i * k..(i + 1) * k],
            &params[i],
            sums[i] as i64,
            &mut y[i * w.out_dim..(i + 1) * w.out_dim],
            kernel,
            accumulate,
        );
    }
}

/// Lane-masked integer GEMM over a lane-resident `x [max_lanes, in_dim]`
/// buffer: only rows listed in `lanes` are quantized, multiplied and
/// written into the matching rows of `y [max_lanes, out_dim]`.  Inactive
/// lanes cost nothing — this is the serving engine's in-place hot path
/// (no gather into a packed batch, no scatter back).  The packed-panel
/// path parallelizes across panels *and* computes every active lane per
/// panel pass, so lane count scales the same way batch does.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_lanes(
    x: &[f32],
    max_lanes: usize,
    lanes: &[usize],
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    scratch: &mut QScratch,
    kernel: Kernel,
    accumulate: bool,
) {
    assert_eq!(x.len(), max_lanes * w.in_dim);
    assert_eq!(y.len(), max_lanes * w.out_dim);
    assert!(
        matches!(w.granularity, Granularity::PerMatrix | Granularity::PerRow),
        "qgemm requires per-matrix or per-row granularity"
    );
    quantize_input_lanes(x, max_lanes, lanes, w.in_dim, scratch, EwKernel::for_gemm(kernel));
    let QScratch { xq, xrow_sums, xparams, xpad, rowctx } = scratch;
    qgemm_quantized_rows(
        xq,
        xrow_sums,
        xparams,
        max_lanes,
        lanes.iter().copied(),
        w,
        bias,
        y,
        xpad,
        rowctx,
        kernel,
        accumulate,
    );
}

/// One quantized input row × every weight row → one output row (row-dot
/// path).  Shared by the batch-contiguous and lane-strided entry points;
/// `kernel` must already be resolved to a non-packed rung.
#[allow(clippy::too_many_arguments)]
fn qgemm_input_row(
    w: &QMatrix,
    bias: Option<&[f32]>,
    xrow: &[u8],
    xp: &QuantParams,
    xsum: i64,
    yrow: &mut [f32],
    kernel: Kernel,
    accumulate: bool,
) {
    // Monomorphize the bias/accumulate/granularity combination once per
    // input row so the per-output finish carries no branches (hoisted
    // constants below).
    let pc = matches!(w.granularity, Granularity::PerRow);
    match (bias, accumulate, pc) {
        (Some(b), false, false) => {
            qgemm_input_row_mono::<true, false, false>(w, b, xrow, xp, xsum, yrow, kernel)
        }
        (Some(b), true, false) => {
            qgemm_input_row_mono::<true, true, false>(w, b, xrow, xp, xsum, yrow, kernel)
        }
        (None, false, false) => {
            qgemm_input_row_mono::<false, false, false>(w, &[], xrow, xp, xsum, yrow, kernel)
        }
        (None, true, false) => {
            qgemm_input_row_mono::<false, true, false>(w, &[], xrow, xp, xsum, yrow, kernel)
        }
        (Some(b), false, true) => {
            qgemm_input_row_mono::<true, false, true>(w, b, xrow, xp, xsum, yrow, kernel)
        }
        (Some(b), true, true) => {
            qgemm_input_row_mono::<true, true, true>(w, b, xrow, xp, xsum, yrow, kernel)
        }
        (None, false, true) => {
            qgemm_input_row_mono::<false, false, true>(w, &[], xrow, xp, xsum, yrow, kernel)
        }
        (None, true, true) => {
            qgemm_input_row_mono::<false, true, true>(w, &[], xrow, xp, xsum, yrow, kernel)
        }
    }
}

/// The eq. (1) recovery core — THE single definition of the integer→float
/// arithmetic, shared by the row-dot and packed-panel finishes so every
/// path applies the identical operations in the identical order
/// (bit-exactness contract; a change here changes all paths together).
#[inline(always)]
fn recover_output(raw: i64, row_sum: i32, zpx: i64, base: i64, inv: f64) -> f32 {
    let full = raw + zpx * row_sum as i64 + base;
    (full as f64 * inv) as f32
}

/// The per-channel twin of [`recover_output`] — THE single definition of
/// the per-row-granularity finish, shared by the row-dot and packed-panel
/// paths.  `a = Σx' + K·zpx` is hoisted per input row; `base` carries the
/// packed signed-storage compensation `w_offset·Σx'` (0 on the row-dot
/// path and for unsigned panels).  The integer part is exact in i64; the
/// float finish is two multiplications — by the input row's `1/qx` and
/// the output row's precomputed `1/qw[o]` ([`QMatrix::inv_q`]).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn recover_output_pc(
    raw: i64,
    row_sum: i32,
    zpx: i64,
    a: i64,
    base: i64,
    wzp: i64,
    inv_x: f64,
    inv_qo: f64,
) -> f32 {
    let full = raw + zpx * row_sum as i64 + wzp * a + base;
    ((full as f64 * inv_x) * inv_qo) as f32
}

/// Per-output finish for the row-dot monomorphs.  `PC` selects the
/// per-channel (per-row-granularity) recovery; the unused scalar set for
/// each arm is zero.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn finish_output<const HAS_BIAS: bool, const ACC: bool, const PC: bool>(
    o: usize,
    raw: i64,
    yrow: &mut [f32],
    w: &QMatrix,
    zpx: i64,
    base: i64,
    inv: f64,
    a: i64,
    bias: &[f32],
) {
    let mut v = if PC {
        recover_output_pc(raw, w.row_sums[o], zpx, a, 0, w.params[o].zp, inv, w.inv_q[o])
    } else {
        recover_output(raw, w.row_sums[o], zpx, base, inv)
    };
    if HAS_BIAS {
        v += bias[o];
    }
    if ACC {
        yrow[o] += v;
    } else {
        yrow[o] = v;
    }
}

#[allow(clippy::too_many_arguments)]
fn qgemm_input_row_mono<const HAS_BIAS: bool, const ACC: bool, const PC: bool>(
    w: &QMatrix,
    bias: &[f32],
    xrow: &[u8],
    xp: &QuantParams,
    xsum: i64,
    yrow: &mut [f32],
    kernel: Kernel,
) {
    let k = w.in_dim;
    let zpx = xp.zp;
    // Per-input-row constants, hoisted once: the recovery scale(s) and
    // every eq. (1) term that does not depend on the output row.  The
    // per-matrix arm hoists the full offset `base` and the fused scale
    // `inv`; the per-channel arm hoists `a = Σx' + K·zpx` and the input
    // scale `1/qx` (per-output terms come from `w.params[o]`/`w.inv_q[o]`
    // inside the finish).
    let (inv, base, a) = if PC {
        (1.0 / (xp.q as f64), 0, xsum + k as i64 * zpx)
    } else {
        let wp = w.params[0];
        (1.0 / (xp.q as f64 * wp.q as f64), wp.zp * xsum + k as i64 * zpx * wp.zp, 0)
    };
    let mut o = 0;
    // 4-row blocked AVX2 path: x is loaded/widened once per 4 rows.
    #[cfg(target_arch = "x86_64")]
    if kernel == Kernel::Avx2 {
        while o + 4 <= w.out_dim {
            let raws = unsafe {
                dot4_u8_avx2(
                    xrow,
                    [
                        &w.data[o * k..(o + 1) * k],
                        &w.data[(o + 1) * k..(o + 2) * k],
                        &w.data[(o + 2) * k..(o + 3) * k],
                        &w.data[(o + 3) * k..(o + 4) * k],
                    ],
                )
            };
            for (d, &raw) in raws.iter().enumerate() {
                finish_output::<HAS_BIAS, ACC, PC>(
                    o + d,
                    raw as i64,
                    yrow,
                    w,
                    zpx,
                    base,
                    inv,
                    a,
                    bias,
                );
            }
            o += 4;
        }
    }
    while o < w.out_dim {
        let wrow = &w.data[o * k..(o + 1) * k];
        let raw = match kernel {
            Kernel::Scalar => dot_u8_scalar(xrow, wrow),
            Kernel::Unrolled => dot_u8_unrolled(xrow, wrow),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => unsafe { dot_u8_avx2(xrow, wrow) },
            _ => unreachable!("packed/auto kernels are handled before the row loop"),
        } as i64;
        finish_output::<HAS_BIAS, ACC, PC>(o, raw, yrow, w, zpx, base, inv, a, bias);
        o += 1;
    }
}

// ---------------------------------------------------------------------------
// Packed-panel execution (the serving hot path)
// ---------------------------------------------------------------------------

/// Per-input-row constants for the packed path, computed once per GEMM
/// (nothing here is re-derived per output element).
///
/// Per-matrix rows fold the signed-storage compensation `w_offset·Σx` and
/// every zpw term into `base`, and `inv` is the fused recovery scale
/// `1/(qx·qw)` (`a` is unused).  Per-channel rows carry only the storage
/// compensation in `base`, hoist `a = Σx' + K·zpx` for the per-output
/// `zpw[o]·a` term, and `inv` is the input scale `1/qx` (the weight-row
/// scale comes from [`QMatrix::inv_q`] in the finish).
#[derive(Clone)]
pub(crate) struct RowCtx {
    row: usize,
    zpx: i64,
    inv: f64,
    base: i64,
    a: i64,
}

/// Fill `rowctx` (reused across calls — no allocation in the steady
/// state) with the listed rows' hoisted constants.
fn build_rowctx(
    rowctx: &mut Vec<RowCtx>,
    rows: impl Iterator<Item = usize>,
    sums: &[i32],
    params: &[QuantParams],
    w: &QMatrix,
    pk: &PackedQMatrix,
) {
    rowctx.clear();
    if matches!(w.granularity, Granularity::PerRow) {
        rowctx.extend(rows.map(|i| {
            let xp = &params[i];
            let xsum = sums[i] as i64;
            RowCtx {
                row: i,
                zpx: xp.zp,
                inv: 1.0 / (xp.q as f64),
                base: pk.w_offset() * xsum,
                a: xsum + w.in_dim as i64 * xp.zp,
            }
        }));
        return;
    }
    let wp = w.params[0];
    rowctx.extend(rows.map(|i| {
        let xp = &params[i];
        let xsum = sums[i] as i64;
        RowCtx {
            row: i,
            zpx: xp.zp,
            inv: 1.0 / (xp.q as f64 * wp.q as f64),
            base: (pk.w_offset() + wp.zp) * xsum + w.in_dim as i64 * xp.zp * wp.zp,
            a: 0,
        }
    }));
}

/// Copy each listed quantized row into the zero-padded `[rows, k_padded]`
/// scratch the microkernels stream (padding bytes stay zero — exactness).
fn build_xpad(
    xq: &[u8],
    xpad: &mut Vec<u8>,
    k: usize,
    k_padded: usize,
    total_rows: usize,
    rows: impl Iterator<Item = usize>,
) {
    xpad.resize(total_rows * k_padded, 0);
    for i in rows {
        let src = &xq[i * k..(i + 1) * k];
        let dst = &mut xpad[i * k_padded..(i + 1) * k_padded];
        dst[..k].copy_from_slice(src);
        dst[k..].fill(0);
    }
}

/// Raw output pointer shared across panel threads.  Sound because panels
/// own disjoint output-column spans (see [`packed_panel_range`]).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Everything a panel-range worker needs, by reference.
struct PackedCtx<'a> {
    w: &'a QMatrix,
    pk: &'a PackedQMatrix,
    bias: &'a [f32],
    rowctx: &'a [RowCtx],
    xpad: &'a [u8],
    micro: fn(&[u8], &[u8]) -> [i32; 4],
}

/// Execute panels `p0..p1` for every row in `ctx.rowctx`.
///
/// # Safety
/// `y` must be valid for writes at `row·out_dim + o` for every listed row
/// and every live output `o` of panels `p0..p1`.  Distinct panel ranges
/// write disjoint `o` spans, so concurrent calls over a partition of the
/// panel space are race-free.
unsafe fn packed_panel_range<const HAS_BIAS: bool, const ACC: bool, const PC: bool>(
    ctx: &PackedCtx<'_>,
    y: SendPtr,
    p0: usize,
    p1: usize,
) {
    const NR: usize = PackedQMatrix::NR;
    let kp = ctx.pk.k_padded;
    let out_dim = ctx.w.out_dim;
    for p in p0..p1 {
        let panel = ctx.pk.panel(p);
        let o0 = p * NR;
        let live = NR.min(out_dim - o0);
        for rc in ctx.rowctx {
            let xpad = &ctx.xpad[rc.row * kp..(rc.row + 1) * kp];
            let raws = (ctx.micro)(xpad, panel);
            let ybase = y.0.add(rc.row * out_dim + o0);
            for (d, &raw) in raws.iter().take(live).enumerate() {
                let o = o0 + d;
                let mut v = if PC {
                    recover_output_pc(
                        raw as i64,
                        ctx.w.row_sums[o],
                        rc.zpx,
                        rc.a,
                        rc.base,
                        ctx.w.params[o].zp,
                        rc.inv,
                        ctx.w.inv_q[o],
                    )
                } else {
                    recover_output(raw as i64, ctx.w.row_sums[o], rc.zpx, rc.base, rc.inv)
                };
                if HAS_BIAS {
                    v += ctx.bias[o];
                }
                if ACC {
                    *ybase.add(d) += v;
                } else {
                    *ybase.add(d) = v;
                }
            }
        }
    }
}

/// How many threads a packed GEMM of `macs` multiply-accumulates over
/// `panels` panels should use.  The persistent [`WorkerPool`] makes
/// dispatch a few µs (workers are parked, not spawned), so the threshold
/// sits far below the old scoped-thread one: batch-1 GEMVs at serving
/// shapes (512×2048 ≈ 1M MACs) now fan out instead of waiting for a big
/// batch.  Tiny calls still stay serial — below ~256K MACs the work
/// doesn't dwarf even a parked-thread wake.
fn packed_threads(macs: usize, panels: usize) -> usize {
    const PAR_MIN_MACS: usize = 256 * 1024;
    if panels < 2 {
        return 1;
    }
    if let Some(n) = forced_gemm_threads() {
        return n.clamp(1, panels);
    }
    if macs < PAR_MIN_MACS {
        return 1;
    }
    // Auto caps at the pool's own ceiling so the executor budget the
    // pool spawns for is the budget dispatch actually uses.
    available_cpus().min(panels).min(crate::util::pool::MAX_POOL_THREADS)
}

fn available_cpus() -> usize {
    static CPUS: OnceLock<usize> = OnceLock::new();
    *CPUS.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Microkernel for a resolved packed kernel.  The SIMD arms are only
/// reachable after runtime feature detection — auto dispatch detects in
/// [`Kernel::best_available`]/[`forced_kernel`], and explicitly requested
/// kernels pass through [`Kernel::checked`] at the `qgemm*` entry points —
/// which is what makes the `unsafe` calls sound.
fn packed_micro(kernel: Kernel, pk: &PackedQMatrix) -> fn(&[u8], &[u8]) -> [i32; 4] {
    if pk.bits == 4 {
        // Int4 twins of the same ladder rungs (nibble-unpacking variants).
        // The VNNI rung maps onto the AVX2 int4 kernel: there is no
        // unsigned-nibble vpdpbusd shape, and every AVX-512 CPU has AVX2,
        // so the dispatch stays sound under the same detection.
        return match kernel {
            #[cfg(target_arch = "x86_64")]
            Kernel::PackedAvx2 => |x, p| unsafe { packed_dot4_i4_avx2(x, p) },
            #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
            Kernel::PackedVnni => |x, p| unsafe { packed_dot4_i4_avx2(x, p) },
            #[cfg(target_arch = "aarch64")]
            Kernel::PackedNeonDot => |x, p| unsafe { packed_dot4_i4_neon_dot(x, p) },
            _ => packed_dot4_i4_scalar,
        };
    }
    match kernel {
        #[cfg(target_arch = "x86_64")]
        Kernel::PackedAvx2 => |x, p| unsafe { packed_dot4_avx2(x, p) },
        #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
        Kernel::PackedVnni => |x, p| unsafe { packed_dot4_vnni(x, p) },
        #[cfg(target_arch = "aarch64")]
        Kernel::PackedNeonDot => |x, p| unsafe { packed_dot4_neon_dot(x, p) },
        _ => {
            if pk.signed {
                packed_dot4_scalar_s8
            } else {
                packed_dot4_scalar_u8
            }
        }
    }
}

/// Packed-panel GEMM over the listed rows: panel-major loop order (each
/// NR-row panel is streamed once and dotted against every input row while
/// it is cache-hot — at batch 8 the old row-dot path re-streamed the whole
/// matrix per row), parallelized across panels above the work threshold
/// via the persistent [`WorkerPool`] (parked threads, no per-call spawn).
#[allow(clippy::too_many_arguments)]
fn qgemm_packed(
    w: &QMatrix,
    pk: &PackedQMatrix,
    bias: Option<&[f32]>,
    rowctx: &[RowCtx],
    xpad: &[u8],
    y: &mut [f32],
    kernel: Kernel,
    accumulate: bool,
) {
    if rowctx.is_empty() || w.out_dim == 0 {
        return;
    }
    debug_assert_eq!(pk.signed, pk.bits == 8 && cfg!(target_arch = "x86_64"));
    debug_assert_eq!(pk.out_dim, w.out_dim);
    debug_assert_eq!(pk.in_dim, w.in_dim);
    let ctx = PackedCtx {
        w,
        pk,
        bias: bias.unwrap_or(&[]),
        rowctx,
        xpad,
        micro: packed_micro(kernel, pk),
    };
    let has_bias = bias.is_some();
    let pc = matches!(w.granularity, Granularity::PerRow);
    let panels = pk.panels;
    let macs = rowctx.len() * w.out_dim * w.in_dim;
    let nthreads = packed_threads(macs, panels);
    let yptr = SendPtr(y.as_mut_ptr());
    // SAFETY: every (row, output) cell is written by exactly one panel and
    // the panel ranges below partition [0, panels) — no write aliases, and
    // which executor runs a range cannot change its outputs (bit-identical
    // at any thread count).
    let run = |p0: usize, p1: usize| unsafe {
        match (has_bias, accumulate, pc) {
            (true, true, false) => packed_panel_range::<true, true, false>(&ctx, yptr, p0, p1),
            (true, false, false) => packed_panel_range::<true, false, false>(&ctx, yptr, p0, p1),
            (false, true, false) => packed_panel_range::<false, true, false>(&ctx, yptr, p0, p1),
            (false, false, false) => {
                packed_panel_range::<false, false, false>(&ctx, yptr, p0, p1)
            }
            (true, true, true) => packed_panel_range::<true, true, true>(&ctx, yptr, p0, p1),
            (true, false, true) => packed_panel_range::<true, false, true>(&ctx, yptr, p0, p1),
            (false, true, true) => packed_panel_range::<false, true, true>(&ctx, yptr, p0, p1),
            (false, false, true) => packed_panel_range::<false, false, true>(&ctx, yptr, p0, p1),
        }
    };
    if nthreads <= 1 {
        run(0, panels);
    } else {
        // Coarse chunks (a few per executor) claimed dynamically from the
        // pool's counter: load-balances panel tails without per-panel
        // sync traffic.
        let chunk = panels.div_ceil(nthreads * 4).max(1);
        let nchunks = panels.div_ceil(chunk);
        WorkerPool::global().run(nthreads, nchunks, &|ci| {
            run(ci * chunk, ((ci + 1) * chunk).min(panels));
        });
    }
}

/// Granularity-generic (slow) integer matmul for the E3 ablation: honors
/// per-row / sub-block params by recovering per element group.
pub fn qgemm_any_granularity(
    x: &[f32],
    batch: usize,
    w: &QMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
) {
    let k = w.in_dim;
    let mut xq = vec![0u8; x.len()];
    let xps: Vec<QuantParams> = (0..batch)
        .map(|i| {
            let p = QuantParams::from_slice(&x[i * k..(i + 1) * k]);
            p.quantize_slice(&x[i * k..(i + 1) * k], &mut xq[i * k..(i + 1) * k]);
            p
        })
        .collect();
    for i in 0..batch {
        let xp = &xps[i];
        for o in 0..w.out_dim {
            let mut acc = 0.0f64;
            // Group by parameter region so the integer dot stays exact
            // within each region (same structure as the paper's
            // sub-matrix granularity).
            match w.granularity {
                crate::quant::Granularity::PerMatrix | crate::quant::Granularity::PerRow => {
                    let wp = w.param_for(o, 0);
                    let mut raw: i64 = 0;
                    let mut wsum: i64 = 0;
                    let mut xsum: i64 = 0;
                    for c in 0..k {
                        let xv = xq[i * k + c] as i64;
                        let wv = w.data[o * k + c] as i64;
                        raw += xv * wv;
                        wsum += wv;
                        xsum += xv;
                    }
                    let full = raw
                        + xp.zp * wsum
                        + wp.zp * xsum
                        + k as i64 * xp.zp * wp.zp;
                    acc = full as f64 / (xp.q as f64 * wp.q as f64);
                }
                crate::quant::Granularity::SubBlock { size } => {
                    let mut c0 = 0;
                    while c0 < k {
                        let c1 = (c0 + size).min(k);
                        let wp = w.param_for(o, c0);
                        let mut raw: i64 = 0;
                        let mut wsum: i64 = 0;
                        let mut xsum: i64 = 0;
                        for c in c0..c1 {
                            let xv = xq[i * k + c] as i64;
                            let wv = w.data[o * k + c] as i64;
                            raw += xv * wv;
                            wsum += wv;
                            xsum += xv;
                        }
                        let full = raw
                            + xp.zp * wsum
                            + wp.zp * xsum
                            + (c1 - c0) as i64 * xp.zp * wp.zp;
                        acc += full as f64 / (xp.q as f64 * wp.q as f64);
                        c0 = c1;
                    }
                }
            }
            y[i * w.out_dim + o] = acc as f32 + bias.map_or(0.0, |b| b[o]);
        }
    }
}

// ---------------------------------------------------------------------------
// u8·u8 → i32 dot kernels (row-dot rungs)
// ---------------------------------------------------------------------------

#[inline]
pub fn dot_u8_scalar(a: &[u8], b: &[u8]) -> i32 {
    let mut acc: i32 = 0;
    for (&x, &w) in a.iter().zip(b) {
        acc += x as i32 * w as i32;
    }
    acc
}

/// 4-way unrolled variant — helps older LLVM autovectorize.
#[inline]
pub fn dot_u8_unrolled(a: &[u8], b: &[u8]) -> i32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as i32 * b[i] as i32;
        s1 += a[i + 1] as i32 * b[i + 1] as i32;
        s2 += a[i + 2] as i32 * b[i + 2] as i32;
        s3 += a[i + 3] as i32 * b[i + 3] as i32;
    }
    let mut acc = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        acc += a[i] as i32 * b[i] as i32;
    }
    acc
}

/// AVX2: 32 u8 lanes per step (2 × `cvtepu8_epi16` + `madd_epi16`, two
/// independent accumulators for ILP).  Exact: u8×u8 products fit
/// i16×i16→i32 madd without saturation.
///
/// # Safety
/// Caller must ensure AVX2 is available (see [`Kernel::resolve`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot_u8_avx2(a: &[u8], b: &[u8]) -> i32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm256_setzero_si256();
    let mut acc1 = _mm256_setzero_si256();
    let mut i = 0;
    while i + 32 <= n {
        let a0 = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let b0 = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        acc0 = _mm256_add_epi32(
            acc0,
            _mm256_madd_epi16(_mm256_cvtepu8_epi16(a0), _mm256_cvtepu8_epi16(b0)),
        );
        let a1 = _mm_loadu_si128(a.as_ptr().add(i + 16) as *const __m128i);
        let b1 = _mm_loadu_si128(b.as_ptr().add(i + 16) as *const __m128i);
        acc1 = _mm256_add_epi32(
            acc1,
            _mm256_madd_epi16(_mm256_cvtepu8_epi16(a1), _mm256_cvtepu8_epi16(b1)),
        );
        i += 32;
    }
    while i + 16 <= n {
        let av = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
        let bv = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
        acc0 = _mm256_add_epi32(
            acc0,
            _mm256_madd_epi16(_mm256_cvtepu8_epi16(av), _mm256_cvtepu8_epi16(bv)),
        );
        i += 16;
    }
    let acc = _mm256_add_epi32(acc0, acc1);
    // Horizontal sum of 8 × i32.
    let hi = _mm256_extracti128_si256(acc, 1);
    let lo = _mm256_castsi256_si128(acc);
    let s = _mm_add_epi32(hi, lo);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
    let mut total = _mm_cvtsi128_si32(s);
    while i < n {
        total += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    total
}

/// AVX2, 4 weight rows at once sharing the x loads/widening — the GEMV hot
/// path before panel packing (perf pass L3.2), kept as the fallback for
/// unpacked matrices: loading + widening x is half of the 1-row kernel's
/// work, so amortizing it over 4 output rows raises throughput.
///
/// # Safety
/// Caller must ensure AVX2 is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot4_u8_avx2(x: &[u8], w: [&[u8]; 4]) -> [i32; 4] {
    use std::arch::x86_64::*;
    let n = x.len();
    let mut acc = [_mm256_setzero_si256(); 4];
    let mut i = 0;
    while i + 16 <= n {
        let xv =
            _mm256_cvtepu8_epi16(_mm_loadu_si128(x.as_ptr().add(i) as *const __m128i));
        for r in 0..4 {
            let wv = _mm256_cvtepu8_epi16(_mm_loadu_si128(
                w[r].as_ptr().add(i) as *const __m128i
            ));
            acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(xv, wv));
        }
        i += 16;
    }
    let mut out = [0i32; 4];
    for r in 0..4 {
        let hi = _mm256_extracti128_si256(acc[r], 1);
        let lo = _mm256_castsi256_si128(acc[r]);
        let s = _mm_add_epi32(hi, lo);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        out[r] = _mm_cvtsi128_si32(s);
        for j in i..n {
            out[r] += x[j] as i32 * w[r][j] as i32;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Packed-panel microkernels: one input row × one NR-row panel → NR dots
// ---------------------------------------------------------------------------

/// Packed-panel scalar microkernel over **signed** (w−128 as i8) panels —
/// the portable reference every SIMD microkernel is property-tested
/// against.  `xpad` is the zero-padded quantized input row (`k_padded`
/// bytes); returns the 4 partial dots `Σ x·(w−128)` **without** the
/// `128·Σx` compensation (the caller's finish adds it via
/// [`PackedQMatrix::w_offset`]).
pub fn packed_dot4_scalar_s8(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    packed_dot4_scalar_impl::<true>(xpad, panel)
}

/// As [`packed_dot4_scalar_s8`] for **unsigned** panels (the non-x86
/// layout, where no compensation is needed).
pub fn packed_dot4_scalar_u8(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    packed_dot4_scalar_impl::<false>(xpad, panel)
}

fn packed_dot4_scalar_impl<const SIGNED: bool>(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    const NR: usize = PackedQMatrix::NR;
    const C: usize = PackedQMatrix::K_CHUNK;
    debug_assert_eq!(panel.len(), xpad.len() * NR);
    debug_assert_eq!(xpad.len() % C, 0);
    let mut acc = [0i32; NR];
    for (kb, xchunk) in xpad.chunks_exact(C).enumerate() {
        let block = &panel[kb * NR * C..(kb + 1) * NR * C];
        for (r, wrow) in block.chunks_exact(C).enumerate() {
            let mut s = 0i32;
            for (&xv, &wv) in xchunk.iter().zip(wrow) {
                let w = if SIGNED { wv as i8 as i32 } else { wv as i32 };
                s += xv as i32 * w;
            }
            acc[r] += s;
        }
    }
    acc
}

/// Packed-panel scalar microkernel for **int4** panels (`bits == 4`, the
/// nibble layout documented on [`PackedQMatrix`]) — the portable reference
/// the int4 SIMD microkernels are property-tested against.  Each 16-byte
/// panel-row chunk covers 32 K-values: low nibbles dot the first 16 input
/// bytes of the value block, high nibbles the next 16.  Nibbles are
/// unsigned on every architecture, so no compensation term is needed
/// beyond the caller's finish.
pub fn packed_dot4_i4_scalar(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    const NR: usize = PackedQMatrix::NR;
    const C: usize = PackedQMatrix::K_CHUNK;
    const CV: usize = PackedQMatrix::K_CHUNK_I4;
    debug_assert_eq!(panel.len() * 2, xpad.len() * NR);
    debug_assert_eq!(xpad.len() % CV, 0);
    let mut acc = [0i32; NR];
    for (kb, xchunk) in xpad.chunks_exact(CV).enumerate() {
        let block = &panel[kb * NR * C..(kb + 1) * NR * C];
        for (r, wrow) in block.chunks_exact(C).enumerate() {
            let mut s = 0i32;
            for (j, &b) in wrow.iter().enumerate() {
                s += xchunk[j] as i32 * (b & 0x0F) as i32;
                s += xchunk[C + j] as i32 * (b >> 4) as i32;
            }
            acc[r] += s;
        }
    }
    acc
}

/// Packed-panel AVX2 microkernel: per 64-byte block the 16 input bytes are
/// loaded and widened **once** (`cvtepu8`) and madd'ed against the four
/// interleaved signed weight rows (`cvtepi8` + `madd_epi16`).  Exact:
/// |x| ≤ 255 and |w−128| ≤ 128 keep every i16 product inside the
/// i16×i16→i32 madd — no saturation, bit-identical to the scalar rung.
///
/// # Safety
/// Caller must ensure AVX2 is available.  Packed invariants:
/// `panel.len() == 4·xpad.len()` and `xpad.len() % 16 == 0`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn packed_dot4_avx2(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    use std::arch::x86_64::*;
    debug_assert_eq!(panel.len(), xpad.len() * 4);
    debug_assert_eq!(xpad.len() % 16, 0);
    let kp = xpad.len();
    let mut acc = [_mm256_setzero_si256(); 4];
    let mut kb = 0;
    while kb < kp {
        let xv =
            _mm256_cvtepu8_epi16(_mm_loadu_si128(xpad.as_ptr().add(kb) as *const __m128i));
        let bp = panel.as_ptr().add(kb * 4);
        for (r, a) in acc.iter_mut().enumerate() {
            let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(bp.add(r * 16) as *const __m128i));
            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(xv, wv));
        }
        kb += 16;
    }
    let mut out = [0i32; 4];
    for (r, &a) in acc.iter().enumerate() {
        let hi = _mm256_extracti128_si256(a, 1);
        let lo = _mm256_castsi256_si128(a);
        let s = _mm_add_epi32(hi, lo);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        out[r] = _mm_cvtsi128_si32(s);
    }
    out
}

/// Packed-panel AVX-512-VNNI microkernel: one 64-byte block is one
/// `vpdpbusd` (u8 activations × s8 weights, 4-byte groups accumulated
/// straight into i32 lanes — 64 MACs per instruction, no widening, no
/// saturation).  The input chunk is broadcast to all four 128-bit lanes so
/// i32 lane group `r` accumulates panel row `r`.  Four independent
/// accumulator chains hide the instruction latency.
///
/// # Safety
/// Caller must ensure AVX-512 F/BW/VNNI are available.  Packed invariants
/// as in [`packed_dot4_avx2`].
#[cfg(all(target_arch = "x86_64", feature = "vnni"))]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn packed_dot4_vnni(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    use std::arch::x86_64::*;
    debug_assert_eq!(panel.len(), xpad.len() * 4);
    debug_assert_eq!(xpad.len() % 16, 0);
    let kp = xpad.len();
    let mut acc = [_mm512_setzero_si512(); 4];
    let mut kb = 0;
    while kb + 64 <= kp {
        for (u, a) in acc.iter_mut().enumerate() {
            let off = kb + u * 16;
            let xv = _mm512_broadcast_i32x4(_mm_loadu_si128(
                xpad.as_ptr().add(off) as *const __m128i
            ));
            let wv = std::ptr::read_unaligned(panel.as_ptr().add(off * 4) as *const __m512i);
            *a = _mm512_dpbusd_epi32(*a, xv, wv);
        }
        kb += 64;
    }
    while kb < kp {
        let xv = _mm512_broadcast_i32x4(_mm_loadu_si128(
            xpad.as_ptr().add(kb) as *const __m128i
        ));
        let wv = std::ptr::read_unaligned(panel.as_ptr().add(kb * 4) as *const __m512i);
        acc[0] = _mm512_dpbusd_epi32(acc[0], xv, wv);
        kb += 16;
    }
    let acc = _mm512_add_epi32(
        _mm512_add_epi32(acc[0], acc[1]),
        _mm512_add_epi32(acc[2], acc[3]),
    );
    // i32 lane group r (one 128-bit lane) holds panel row r's partials.
    let mut out = [0i32; 4];
    for (r, o) in out.iter_mut().enumerate() {
        let q = match r {
            0 => _mm512_extracti32x4_epi32(acc, 0),
            1 => _mm512_extracti32x4_epi32(acc, 1),
            2 => _mm512_extracti32x4_epi32(acc, 2),
            _ => _mm512_extracti32x4_epi32(acc, 3),
        };
        let s = _mm_add_epi32(q, _mm_shuffle_epi32(q, 0b00_01_10_11));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        *o = _mm_cvtsi128_si32(s);
    }
    out
}

/// Packed-panel NEON `dot`-product microkernel: `vdotq_u32` accumulates
/// 4-byte u8×u8 groups straight into u32 lanes (exact — all operands
/// non-negative and K·255² fits i32 at model scales; the aarch64 packed
/// layout stays unsigned precisely so `udot` applies).
///
/// # Safety
/// Caller must ensure the `dotprod` feature is available.  Packed
/// invariants as in [`packed_dot4_avx2`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "dotprod")]
pub unsafe fn packed_dot4_neon_dot(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    use std::arch::aarch64::*;
    debug_assert_eq!(panel.len(), xpad.len() * 4);
    debug_assert_eq!(xpad.len() % 16, 0);
    let kp = xpad.len();
    let mut acc = [vdupq_n_u32(0); 4];
    let mut kb = 0;
    while kb < kp {
        let xv = vld1q_u8(xpad.as_ptr().add(kb));
        let bp = panel.as_ptr().add(kb * 4);
        for (r, a) in acc.iter_mut().enumerate() {
            let wv = vld1q_u8(bp.add(r * 16));
            *a = vdotq_u32(*a, xv, wv);
        }
        kb += 16;
    }
    let mut out = [0i32; 4];
    for (r, o) in out.iter_mut().enumerate() {
        *o = vaddvq_u32(acc[r]) as i32;
    }
    out
}

/// Packed-panel AVX2 microkernel for **int4** panels: each 16-byte load
/// yields 32 weights — `and 0x0F` for the low-nibble half, `srli 4 + and`
/// for the high half (no shuffles) — madd'ed against the two matching
/// 16-byte input chunks.  Exact: x ≤ 255 and w ≤ 15 keep every product in
/// i16×i16→i32 madd range with large margin.  Also serves the VNNI rung
/// (no unsigned-nibble `vpdpbusd` shape exists; AVX-512 implies AVX2).
///
/// # Safety
/// Caller must ensure AVX2 is available.  Int4 packed invariants:
/// `2·panel.len() == 4·xpad.len()` and `xpad.len() % 32 == 0`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn packed_dot4_i4_avx2(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    use std::arch::x86_64::*;
    debug_assert_eq!(panel.len() * 2, xpad.len() * 4);
    debug_assert_eq!(xpad.len() % 32, 0);
    let kp = xpad.len();
    let mask = _mm_set1_epi8(0x0F);
    let mut acc = [_mm256_setzero_si256(); 4];
    let mut kb = 0;
    while kb < kp {
        let xlo =
            _mm256_cvtepu8_epi16(_mm_loadu_si128(xpad.as_ptr().add(kb) as *const __m128i));
        let xhi = _mm256_cvtepu8_epi16(_mm_loadu_si128(
            xpad.as_ptr().add(kb + 16) as *const __m128i
        ));
        let bp = panel.as_ptr().add(kb / 2 * 4);
        for (r, a) in acc.iter_mut().enumerate() {
            let wb = _mm_loadu_si128(bp.add(r * 16) as *const __m128i);
            let wlo = _mm256_cvtepu8_epi16(_mm_and_si128(wb, mask));
            let whi = _mm256_cvtepu8_epi16(_mm_and_si128(_mm_srli_epi16(wb, 4), mask));
            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(xlo, wlo));
            *a = _mm256_add_epi32(*a, _mm256_madd_epi16(xhi, whi));
        }
        kb += 32;
    }
    let mut out = [0i32; 4];
    for (r, &a) in acc.iter().enumerate() {
        let hi = _mm256_extracti128_si256(a, 1);
        let lo = _mm256_castsi256_si128(a);
        let s = _mm_add_epi32(hi, lo);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_01_10_11));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        out[r] = _mm_cvtsi128_si32(s);
    }
    out
}

/// Packed-panel NEON `dot` microkernel for **int4** panels: one 16-byte
/// load per panel row per 32-value block, nibbles unpacked with
/// `vandq_u8` / `vshrq_n_u8` and accumulated with two `vdotq_u32` (exact:
/// all operands non-negative and well inside u32/i32 range).
///
/// # Safety
/// Caller must ensure the `dotprod` feature is available.  Int4 packed
/// invariants as in [`packed_dot4_i4_avx2`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "dotprod")]
pub unsafe fn packed_dot4_i4_neon_dot(xpad: &[u8], panel: &[u8]) -> [i32; 4] {
    use std::arch::aarch64::*;
    debug_assert_eq!(panel.len() * 2, xpad.len() * 4);
    debug_assert_eq!(xpad.len() % 32, 0);
    let kp = xpad.len();
    let mask = vdupq_n_u8(0x0F);
    let mut acc = [vdupq_n_u32(0); 4];
    let mut kb = 0;
    while kb < kp {
        let xlo = vld1q_u8(xpad.as_ptr().add(kb));
        let xhi = vld1q_u8(xpad.as_ptr().add(kb + 16));
        let bp = panel.as_ptr().add(kb / 2 * 4);
        for (r, a) in acc.iter_mut().enumerate() {
            let wb = vld1q_u8(bp.add(r * 16));
            *a = vdotq_u32(*a, xlo, vandq_u8(wb, mask));
            *a = vdotq_u32(*a, xhi, vshrq_n_u8(wb, 4));
        }
        kb += 32;
    }
    let mut out = [0i32; 4];
    for (r, o) in out.iter_mut().enumerate() {
        *o = vaddvq_u32(acc[r]) as i32;
    }
    out
}

// ---------------------------------------------------------------------------
// f32 baseline (the 'match' path and the E1 comparison target)
// ---------------------------------------------------------------------------

/// Dense f32 matrix in the same transposed `[out, in]` layout.
#[derive(Clone, Debug)]
pub struct FMatrix {
    pub out_dim: usize,
    pub in_dim: usize,
    pub data: Vec<f32>,
}

impl FMatrix {
    /// From math layout `[in, out]` row-major.
    pub fn from_math_layout(w: &[f32], in_dim: usize, out_dim: usize) -> Self {
        assert_eq!(w.len(), in_dim * out_dim);
        let mut t = vec![0f32; w.len()];
        for i in 0..in_dim {
            for o in 0..out_dim {
                t[o * in_dim + i] = w[i * out_dim + o];
            }
        }
        FMatrix { out_dim, in_dim, data: t }
    }

    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// f32 GEMM `y = x·Wᵀ + b`, with optional accumulate (see [`qgemm`]).
pub fn fgemm(
    x: &[f32],
    batch: usize,
    w: &FMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(x.len(), batch * w.in_dim);
    assert_eq!(y.len(), batch * w.out_dim);
    let k = w.in_dim;
    let use_fma = f32_fma_available();
    for i in 0..batch {
        fgemm_input_row(
            w,
            bias,
            &x[i * k..(i + 1) * k],
            &mut y[i * w.out_dim..(i + 1) * w.out_dim],
            use_fma,
            accumulate,
        );
    }
}

/// Lane-masked f32 GEMM over a lane-resident `x [max_lanes, in_dim]`
/// buffer (the float twin of [`qgemm_lanes`]).
pub fn fgemm_lanes(
    x: &[f32],
    max_lanes: usize,
    lanes: &[usize],
    w: &FMatrix,
    bias: Option<&[f32]>,
    y: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(x.len(), max_lanes * w.in_dim);
    assert_eq!(y.len(), max_lanes * w.out_dim);
    let k = w.in_dim;
    let use_fma = f32_fma_available();
    for &lane in lanes {
        debug_assert!(lane < max_lanes);
        fgemm_input_row(
            w,
            bias,
            &x[lane * k..(lane + 1) * k],
            &mut y[lane * w.out_dim..(lane + 1) * w.out_dim],
            use_fma,
            accumulate,
        );
    }
}

#[inline]
fn f32_fma_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("fma")
            && std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// One f32 input row × every weight row → one output row (shared by the
/// batch-contiguous and lane-strided entry points).
fn fgemm_input_row(
    w: &FMatrix,
    bias: Option<&[f32]>,
    xrow: &[f32],
    yrow: &mut [f32],
    use_fma: bool,
    accumulate: bool,
) {
    #[cfg(not(target_arch = "x86_64"))]
    let _ = use_fma;
    let k = w.in_dim;
    for o in 0..w.out_dim {
        let wrow = &w.data[o * k..(o + 1) * k];
        #[cfg(target_arch = "x86_64")]
        let raw = if use_fma {
            unsafe { dot_f32_fma(xrow, wrow) }
        } else {
            dot_f32_scalar(xrow, wrow)
        };
        #[cfg(not(target_arch = "x86_64"))]
        let raw = dot_f32_scalar(xrow, wrow);
        let v = raw + bias.map_or(0.0, |b| b[o]);
        if accumulate {
            yrow[o] += v;
        } else {
            yrow[o] = v;
        }
    }
}

#[inline]
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut acc = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        acc += a[i] * b[i];
    }
    acc
}

/// # Safety
/// Requires AVX2 + FMA.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_f32_fma(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0;
    while i + 16 <= n {
        let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
        let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
        acc0 = _mm256_fmadd_ps(a0, b0, acc0);
        let a1 = _mm256_loadu_ps(a.as_ptr().add(i + 8));
        let b1 = _mm256_loadu_ps(b.as_ptr().add(i + 8));
        acc1 = _mm256_fmadd_ps(a1, b1, acc1);
        i += 16;
    }
    while i + 8 <= n {
        let a0 = _mm256_loadu_ps(a.as_ptr().add(i));
        let b0 = _mm256_loadu_ps(b.as_ptr().add(i));
        acc0 = _mm256_fmadd_ps(a0, b0, acc0);
        i += 8;
    }
    let acc = _mm256_add_ps(acc0, acc1);
    let hi = _mm256_extractf128_ps(acc, 1);
    let lo = _mm256_castps256_ps128(acc);
    let s = _mm_add_ps(hi, lo);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
    let mut total = _mm_cvtss_f32(s);
    while i < n {
        total += a[i] * b[i];
        i += 1;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Granularity, QuantScheme};
    use crate::util::prop::{forall, Gen};

    const SCHEMES: [QuantScheme; 3] =
        [QuantScheme::PerMatrixU8, QuantScheme::PerChannelU8, QuantScheme::PerChannelI4];

    /// Every kernel this CPU/build can actually run (the full ladder the
    /// CI kernel-matrix forces one rung at a time).
    fn available_kernels() -> Vec<Kernel> {
        let mut ks = vec![Kernel::Scalar, Kernel::Unrolled, Kernel::PackedScalar];
        #[cfg(target_arch = "x86_64")]
        if avx2_available() {
            ks.push(Kernel::Avx2);
            ks.push(Kernel::PackedAvx2);
        }
        #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
        if vnni_available() {
            ks.push(Kernel::PackedVnni);
        }
        #[cfg(target_arch = "aarch64")]
        if neon_dot_available() {
            ks.push(Kernel::PackedNeonDot);
        }
        ks.push(Kernel::Auto);
        ks
    }

    /// Float reference of the full quantized pipeline: recover weights and
    /// recovered-quantized inputs, multiply in f64.
    fn reference(x: &[f32], batch: usize, w: &QMatrix, bias: Option<&[f32]>) -> Vec<f32> {
        let k = w.in_dim;
        let mut y = vec![0f32; batch * w.out_dim];
        for i in 0..batch {
            let xp = QuantParams::from_slice(&x[i * k..(i + 1) * k]);
            for o in 0..w.out_dim {
                let mut acc = 0f64;
                for c in 0..k {
                    let xr = xp.shifted(xp.quantize(x[i * k + c])) as f64 / xp.q as f64;
                    let wr = w.param_for(o, c).recover(w.data[o * k + c]) as f64;
                    acc += xr * wr;
                }
                y[i * w.out_dim + o] = acc as f32 + bias.map_or(0.0, |b| b[o]);
            }
        }
        y
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn qgemm_matches_reference_all_kernels() {
        // The reference recovers the *quantized* grid in f64, so the
        // tolerance is about integer+finish rounding, not quantization
        // error — it holds for the int4 scheme too.
        forall("qgemm vs ref", 40, 0xD07, |g: &mut Gen| {
            let batch = g.usize_in(1, 6);
            let in_dim = g.usize_in(1, 70);
            let out_dim = g.usize_in(1, 40);
            let x = g.vec_normal(batch * in_dim, 1.0);
            let wf = g.vec_normal(in_dim * out_dim, 0.5);
            let bias = g.vec_normal(out_dim, 0.2);
            for scheme in SCHEMES {
                let w = QMatrix::from_f32_math_layout_scheme(&wf, in_dim, out_dim, scheme);
                let want = reference(&x, batch, &w, Some(&bias));
                let mut scratch = QScratch::default();
                for kern in available_kernels() {
                    let mut y = vec![0f32; batch * out_dim];
                    qgemm(&x, batch, &w, Some(&bias), &mut y, &mut scratch, kern, false);
                    assert_close(&y, &want, 1e-4);
                }
            }
        });
    }

    #[test]
    fn all_kernels_bit_identical_k_sweep() {
        // Satellite contract: every (scheme × rung) cell of the ladder —
        // packed variants included — must be bit-identical to Scalar for
        // every K in 0..=130 (crossing every chunk/unroll/nibble-block
        // tail boundary) and for out_dims leaving 1..=3 live rows in the
        // last packed panel.
        let kernels = available_kernels();
        let mut g = Gen::new(0x5EED);
        for k in 0..=130usize {
            for &out_dim in &[1usize, 3, 4, 5, 6, 9] {
                let batch = 2;
                let x = g.vec_normal(batch * k, 1.0);
                let wf = g.vec_normal(k * out_dim, 0.5);
                let bias = g.vec_normal(out_dim, 0.2);
                for scheme in SCHEMES {
                    let w = QMatrix::from_f32_math_layout_scheme(&wf, k, out_dim, scheme);
                    let mut s = QScratch::default();
                    let mut want = vec![0f32; batch * out_dim];
                    qgemm(&x, batch, &w, Some(&bias), &mut want, &mut s, Kernel::Scalar, false);
                    for &kern in &kernels {
                        let mut y = vec![0f32; batch * out_dim];
                        qgemm(&x, batch, &w, Some(&bias), &mut y, &mut s, kern, false);
                        assert!(
                            y == want,
                            "{scheme:?} kernel {kern:?} k={k} out={out_dim}: not bit-identical"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_microkernels_match_scalar_dot() {
        // Microkernel-level exactness: packed partial dots, plus the
        // w_offset·Σx compensation, reconstruct the u8 reference dot for
        // every panel (including K tails and remainder rows).
        forall("packed micro", 60, 0x9AC6, |g: &mut Gen| {
            let k = g.usize_in(0, 130);
            let out_dim = g.usize_in(1, 9);
            let wf = g.vec_normal(k * out_dim, 0.5);
            let w = QMatrix::from_f32_math_layout(&wf, k, out_dim, Granularity::PerMatrix);
            let pk = w.packed.as_deref().expect("PerMatrix packs");
            let x: Vec<u8> = (0..k).map(|_| g.usize_in(0, 255) as u8).collect();
            let xsum: i64 = x.iter().map(|&v| v as i64).sum();
            let mut xpad = vec![0u8; pk.k_padded];
            xpad[..k].copy_from_slice(&x);
            for p in 0..pk.panels {
                let panel = pk.panel(p);
                let scalar = if pk.signed {
                    packed_dot4_scalar_s8(&xpad, panel)
                } else {
                    packed_dot4_scalar_u8(&xpad, panel)
                };
                for (r, &got) in scalar.iter().enumerate() {
                    let o = p * PackedQMatrix::NR + r;
                    if o >= out_dim {
                        continue;
                    }
                    let want = dot_u8_scalar(&x, &w.data[o * k..(o + 1) * k]) as i64;
                    assert_eq!(
                        got as i64 + pk.w_offset() * xsum,
                        want,
                        "panel {p} row {r} (k={k})"
                    );
                }
                #[cfg(target_arch = "x86_64")]
                if avx2_available() {
                    assert_eq!(unsafe { packed_dot4_avx2(&xpad, panel) }, scalar);
                }
                #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
                if vnni_available() {
                    assert_eq!(unsafe { packed_dot4_vnni(&xpad, panel) }, scalar);
                }
                #[cfg(target_arch = "aarch64")]
                if neon_dot_available() {
                    assert_eq!(unsafe { packed_dot4_neon_dot(&xpad, panel) }, scalar);
                }
            }
        });
    }

    #[test]
    fn i4_packed_microkernels_match_scalar_dot() {
        // Int4 microkernel exactness: the nibble-unpacking scalar kernel
        // reconstructs the one-byte-grid reference dot for every panel
        // (K tails crossing the 32-value block boundary and remainder
        // rows included), and every int4 SIMD kernel equals the int4
        // scalar kernel bit-for-bit.
        forall("i4 packed micro", 60, 0x14D0, |g: &mut Gen| {
            let k = g.usize_in(0, 130);
            let out_dim = g.usize_in(1, 9);
            let wf = g.vec_normal(k * out_dim, 0.5);
            let w = QMatrix::from_f32_math_layout_scheme(
                &wf, k, out_dim, QuantScheme::PerChannelI4,
            );
            let pk = w.packed.as_deref().expect("i4 scheme packs");
            assert_eq!(pk.bits, 4);
            let x: Vec<u8> = (0..k).map(|_| g.usize_in(0, 255) as u8).collect();
            let mut xpad = vec![0u8; pk.k_padded];
            xpad[..k].copy_from_slice(&x);
            for p in 0..pk.panels {
                let panel = pk.panel(p);
                let scalar = packed_dot4_i4_scalar(&xpad, panel);
                for (r, &got) in scalar.iter().enumerate() {
                    let o = p * PackedQMatrix::NR + r;
                    if o >= out_dim {
                        continue;
                    }
                    let want = dot_u8_scalar(&x, &w.data[o * k..(o + 1) * k]);
                    assert_eq!(got, want, "panel {p} row {r} (k={k})");
                }
                #[cfg(target_arch = "x86_64")]
                if avx2_available() {
                    assert_eq!(unsafe { packed_dot4_i4_avx2(&xpad, panel) }, scalar);
                }
                #[cfg(target_arch = "aarch64")]
                if neon_dot_available() {
                    assert_eq!(unsafe { packed_dot4_i4_neon_dot(&xpad, panel) }, scalar);
                }
            }
        });
    }

    #[test]
    fn packed_parallel_matches_serial_bitwise() {
        // 4·512·2048 = 4M MACs — 16× the pool's panel-parallel threshold,
        // with clear margin so a threshold tweak can't silently demote
        // this back to a serial-path re-test.  The worker-pool split must
        // stay bit-identical to the scalar rung.
        let mut g = Gen::new(0x9A11);
        let (batch, k, out) = (4usize, 512usize, 2048usize);
        assert!(
            batch * k * out >= 2 * 256 * 1024,
            "shape no longer clears the parallel threshold with margin"
        );
        let x = g.vec_normal(batch * k, 1.0);
        let wf = g.vec_normal(k * out, 0.3);
        let bias = g.vec_normal(out, 0.2);
        for scheme in SCHEMES {
            let w = QMatrix::from_f32_math_layout_scheme(&wf, k, out, scheme);
            let mut s = QScratch::default();
            let mut y_scalar = vec![0f32; batch * out];
            qgemm(&x, batch, &w, Some(&bias), &mut y_scalar, &mut s, Kernel::Scalar, false);
            for kern in available_kernels() {
                let mut y = vec![0f32; batch * out];
                qgemm(&x, batch, &w, Some(&bias), &mut y, &mut s, kern, false);
                assert!(
                    y == y_scalar,
                    "{scheme:?} kernel {kern:?} diverged under panel parallelism"
                );
            }
        }
    }

    #[test]
    fn schemes_bit_identical_lanes_and_cache() {
        // Scheme × rung coverage for the serving entry points: lane-masked
        // GEMMs equal solo batch-1 runs of the same rows, and the
        // activation cache is numerically invisible — for every
        // requantization scheme.
        forall("scheme lanes+cache", 20, 0x5CA1E, |g: &mut Gen| {
            let max_lanes = g.usize_in(1, 6);
            let in_dim = g.usize_in(1, 60);
            let out_dim = g.usize_in(1, 30);
            let x = g.vec_normal(max_lanes * in_dim, 1.0);
            let wf = g.vec_normal(in_dim * out_dim, 0.5);
            let bias = g.vec_normal(out_dim, 0.2);
            let lanes: Vec<usize> = (0..max_lanes).filter(|_| g.bool()).collect();
            let lanes = if lanes.is_empty() { vec![0] } else { lanes };
            for scheme in SCHEMES {
                let w = QMatrix::from_f32_math_layout_scheme(&wf, in_dim, out_dim, scheme);
                for kern in available_kernels() {
                    let mut s = QScratch::default();
                    let mut y = vec![0f32; max_lanes * out_dim];
                    qgemm_lanes(
                        &x, max_lanes, &lanes, &w, Some(&bias), &mut y, &mut s, kern, false,
                    );
                    // lane outputs equal solo batch-1 runs
                    for &lane in &lanes {
                        let mut y1 = vec![0f32; out_dim];
                        qgemm(
                            &x[lane * in_dim..(lane + 1) * in_dim],
                            1,
                            &w,
                            Some(&bias),
                            &mut y1,
                            &mut QScratch::default(),
                            kern,
                            false,
                        );
                        assert!(
                            y[lane * out_dim..(lane + 1) * out_dim] == y1[..],
                            "{scheme:?} kernel {kern:?} lane {lane}: not bit-identical"
                        );
                    }
                    // cached batch path equals uncached
                    let mut cache = QActRows::sized(max_lanes, in_dim);
                    cache.ensure_batch(&x, max_lanes, in_dim, EwKernel::for_gemm(kern));
                    let mut want = vec![0f32; max_lanes * out_dim];
                    let mut got = vec![0f32; max_lanes * out_dim];
                    qgemm(&x, max_lanes, &w, Some(&bias), &mut want, &mut s, kern, false);
                    qgemm_cached(
                        &cache, max_lanes, &w, Some(&bias), &mut got, &mut s, kern, false,
                    );
                    assert!(got == want, "{scheme:?} kernel {kern:?} cached != uncached");
                }
            }
        });
    }

    #[test]
    fn cached_qgemm_bit_identical_to_uncached() {
        // The activation cache must be invisible to numerics: quantizing
        // once into QActRows and running N GEMMs off it equals quantizing
        // inside each qgemm call, bit for bit, on every rung — including
        // after dirty-row rewrites.
        forall("qact cache", 30, 0xCAC4E, |g: &mut Gen| {
            let batch = g.usize_in(1, 6);
            let in_dim = g.usize_in(1, 70);
            let out_dim = g.usize_in(1, 40);
            let mut x = g.vec_normal(batch * in_dim, 1.0);
            let wf = g.vec_normal(in_dim * out_dim, 0.5);
            let bias = g.vec_normal(out_dim, 0.2);
            let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
            let mut cache = QActRows::sized(batch, in_dim);
            for round in 0..3 {
                if round > 0 {
                    // rewrite one row and invalidate it (stale-row path)
                    let r = g.usize_in(0, batch - 1);
                    let fresh = g.vec_normal(in_dim, 1.0);
                    x[r * in_dim..(r + 1) * in_dim].copy_from_slice(&fresh);
                    cache.invalidate_row(r);
                }
                for kern in available_kernels() {
                    let mut s1 = QScratch::default();
                    let mut s2 = QScratch::default();
                    let mut want = vec![0f32; batch * out_dim];
                    qgemm(&x, batch, &w, Some(&bias), &mut want, &mut s1, kern, false);
                    cache.ensure_batch(&x, batch, in_dim, EwKernel::for_gemm(kern));
                    let mut got = vec![0f32; batch * out_dim];
                    qgemm_cached(&cache, batch, &w, Some(&bias), &mut got, &mut s2, kern, false);
                    assert!(got == want, "kernel {kern:?} cached != uncached");
                }
            }
        });
    }

    #[test]
    fn cached_lanes_bit_identical_to_uncached() {
        forall("qact cache lanes", 25, 0xCAC4F, |g: &mut Gen| {
            let max_lanes = g.usize_in(1, 6);
            let in_dim = g.usize_in(1, 50);
            let out_dim = g.usize_in(1, 30);
            let x = g.vec_normal(max_lanes * in_dim, 1.0);
            let wf = g.vec_normal(in_dim * out_dim, 0.5);
            let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
            let lanes: Vec<usize> = (0..max_lanes).filter(|_| g.bool()).collect();
            let lanes = if lanes.is_empty() { vec![0] } else { lanes };
            for kern in available_kernels() {
                let mut cache = QActRows::sized(max_lanes, in_dim);
                let mut s1 = QScratch::default();
                let mut s2 = QScratch::default();
                let mut want = vec![0f32; max_lanes * out_dim];
                qgemm_lanes(&x, max_lanes, &lanes, &w, None, &mut want, &mut s1, kern, false);
                cache.ensure_lanes(&x, max_lanes, &lanes, in_dim, EwKernel::for_gemm(kern));
                let mut got = vec![0f32; max_lanes * out_dim];
                qgemm_lanes_cached(
                    &cache, max_lanes, &lanes, &w, None, &mut got, &mut s2, kern, false,
                );
                for &lane in &lanes {
                    assert!(
                        got[lane * out_dim..(lane + 1) * out_dim]
                            == want[lane * out_dim..(lane + 1) * out_dim],
                        "kernel {kern:?} lane {lane}"
                    );
                }
            }
        });
    }

    #[test]
    fn qgemm_approximates_float_matmul() {
        // End-to-end quantization error must stay small relative to range.
        let mut g = Gen::new(1);
        let (batch, in_dim, out_dim) = (4, 128, 64);
        let x = g.vec_normal(batch * in_dim, 1.0);
        let wf = g.vec_normal(in_dim * out_dim, 0.3);
        let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
        let fw = FMatrix::from_math_layout(&wf, in_dim, out_dim);
        let mut yq = vec![0f32; batch * out_dim];
        let mut yf = vec![0f32; batch * out_dim];
        let mut s = QScratch::default();
        qgemm(&x, batch, &w, None, &mut yq, &mut s, Kernel::Auto, false);
        fgemm(&x, batch, &fw, None, &mut yf, false);
        let scale = yf.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        let max_err = yq.iter().zip(&yf).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_err < 0.02 * scale.max(1.0), "err {max_err} scale {scale}");
    }

    #[test]
    fn accumulate_fuses_two_matmuls() {
        let mut g = Gen::new(2);
        let (batch, k1, k2, out) = (2, 20, 12, 10);
        let x1 = g.vec_normal(batch * k1, 1.0);
        let x2 = g.vec_normal(batch * k2, 1.0);
        let w1f = g.vec_normal(k1 * out, 0.4);
        let w2f = g.vec_normal(k2 * out, 0.4);
        let w1 = QMatrix::from_f32_math_layout(&w1f, k1, out, Granularity::PerMatrix);
        let w2 = QMatrix::from_f32_math_layout(&w2f, k2, out, Granularity::PerMatrix);
        for kern in available_kernels() {
            let mut s = QScratch::default();
            let mut y = vec![0f32; batch * out];
            qgemm(&x1, batch, &w1, None, &mut y, &mut s, kern, false);
            qgemm(&x2, batch, &w2, None, &mut y, &mut s, kern, true);
            let mut y1 = vec![0f32; batch * out];
            let mut y2 = vec![0f32; batch * out];
            qgemm(&x1, batch, &w1, None, &mut y1, &mut s, kern, false);
            qgemm(&x2, batch, &w2, None, &mut y2, &mut s, kern, false);
            for i in 0..y.len() {
                assert!((y[i] - (y1[i] + y2[i])).abs() < 1e-5, "kernel {kern:?}");
            }
        }
    }

    #[test]
    fn any_granularity_matches_per_matrix_when_trivial() {
        let mut g = Gen::new(3);
        let (batch, in_dim, out_dim) = (2, 32, 8);
        let x = g.vec_normal(batch * in_dim, 1.0);
        let wf = g.vec_normal(in_dim * out_dim, 0.5);
        let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
        let mut y1 = vec![0f32; batch * out_dim];
        let mut y2 = vec![0f32; batch * out_dim];
        let mut s = QScratch::default();
        qgemm(&x, batch, &w, None, &mut y1, &mut s, Kernel::Scalar, false);
        qgemm_any_granularity(&x, batch, &w, None, &mut y2);
        assert_close(&y1, &y2, 1e-5);
    }

    #[test]
    fn dot_kernels_agree() {
        forall("dot kernels", 60, 0xBEEF, |g: &mut Gen| {
            let n = g.usize_in(0, 200);
            let a: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let want = dot_u8_scalar(&a, &b);
            assert_eq!(dot_u8_unrolled(&a, &b), want);
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                assert_eq!(unsafe { dot_u8_avx2(&a, &b) }, want);
            }
        });
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn dot4_agrees_with_scalar() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return;
        }
        forall("dot4", 50, 0xD04, |g: &mut Gen| {
            let n = g.usize_in(0, 150);
            let x: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
            let rows: Vec<Vec<u8>> = (0..4)
                .map(|_| (0..n).map(|_| g.usize_in(0, 255) as u8).collect())
                .collect();
            let got = unsafe {
                dot4_u8_avx2(&x, [&rows[0], &rows[1], &rows[2], &rows[3]])
            };
            for r in 0..4 {
                assert_eq!(got[r], dot_u8_scalar(&x, &rows[r]));
            }
        });
    }

    #[test]
    fn qgemm_lanes_bit_identical_to_solo_rows() {
        // The per-row quantization contract: a lane's output is a pure
        // function of its own input row — bit-identical whether the lane
        // runs alone, packed with co-riders, or via the batch entry point,
        // on every rung of the kernel ladder.
        forall("qgemm lanes invariance", 30, 0x1A7E5, |g: &mut Gen| {
            let max_lanes = g.usize_in(1, 8);
            let in_dim = g.usize_in(1, 60);
            let out_dim = g.usize_in(1, 30);
            let wf = g.vec_normal(in_dim * out_dim, 0.5);
            let bias = g.vec_normal(out_dim, 0.2);
            let w = QMatrix::from_f32_math_layout(&wf, in_dim, out_dim, Granularity::PerMatrix);
            let x = g.vec_normal(max_lanes * in_dim, 1.0);
            // random non-empty active-lane subset
            let lanes: Vec<usize> =
                (0..max_lanes).filter(|_| g.bool()).collect();
            let lanes = if lanes.is_empty() { vec![g.usize_in(0, max_lanes - 1)] } else { lanes };
            for kern in available_kernels() {
                let mut scratch = QScratch::default();
                let mut y = vec![f32::NAN; max_lanes * out_dim];
                qgemm_lanes(
                    &x, max_lanes, &lanes, &w, Some(&bias), &mut y, &mut scratch, kern, false,
                );
                for &lane in &lanes {
                    // solo run of the same row through the batch-1 entry point
                    let mut y1 = vec![0f32; out_dim];
                    qgemm(
                        &x[lane * in_dim..(lane + 1) * in_dim],
                        1,
                        &w,
                        Some(&bias),
                        &mut y1,
                        &mut QScratch::default(),
                        kern,
                        false,
                    );
                    for o in 0..out_dim {
                        assert!(
                            y[lane * out_dim + o] == y1[o],
                            "kernel {kern:?} lane {lane} o {o}: {} != {} (not bit-identical)",
                            y[lane * out_dim + o],
                            y1[o]
                        );
                    }
                }
                // inactive lanes untouched
                for lane in 0..max_lanes {
                    if !lanes.contains(&lane) {
                        assert!(y[lane * out_dim..(lane + 1) * out_dim]
                            .iter()
                            .all(|v| v.is_nan()));
                    }
                }
            }
        });
    }

    #[test]
    fn fgemm_lanes_bit_identical_to_batch() {
        forall("fgemm lanes", 40, 0xF1A7, |g: &mut Gen| {
            let max_lanes = g.usize_in(1, 6);
            let in_dim = g.usize_in(1, 64);
            let out_dim = g.usize_in(1, 24);
            let wf = g.vec_normal(in_dim * out_dim, 0.4);
            let w = FMatrix::from_math_layout(&wf, in_dim, out_dim);
            let x = g.vec_normal(max_lanes * in_dim, 1.0);
            let all: Vec<usize> = (0..max_lanes).collect();
            let mut y_lanes = vec![0f32; max_lanes * out_dim];
            let mut y_batch = vec![0f32; max_lanes * out_dim];
            fgemm_lanes(&x, max_lanes, &all, &w, None, &mut y_lanes, false);
            fgemm(&x, max_lanes, &w, None, &mut y_batch, false);
            assert_eq!(y_lanes, y_batch);
        });
    }

    #[test]
    fn f32_dot_kernels_agree() {
        forall("f32 dot", 40, 0xF00D, |g: &mut Gen| {
            let n = g.usize_in(0, 300);
            let a = g.vec_normal(n, 1.0);
            let b = g.vec_normal(n, 1.0);
            let want = dot_f32_scalar(&a, &b);
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("fma") {
                let got = unsafe { dot_f32_fma(&a, &b) };
                assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()));
            }
        });
    }
}
