//! Eqs. (1)–(3): the uniform linear quantizer (paper §3).
//!
//! Given values in `R = [vmin, vmax]` and scale `S = 255`:
//!
//! ```text
//! Q   = S / R                      quantization factor
//! zp  = round(Q · vmin)            integer zero point
//! V'  = round(Q·V) − zp            eq. (2)  (stored u8)
//! V   = (V' + zp) / Q              eq. (3)  (recovery)
//! V'' = V' + zp = round(Q·V)       offset-shifted integer (eq. 1 operand)
//! ```
//!
//! Using the *rounded* `zp` in both eq. (2) and eq. (3) makes the
//! quantize→recover error pure precision loss (zero-mean, ≤ ½ step); the
//! naive variant below floors and recovers with the unrounded offset, which
//! introduces the systematic bias the paper warns about (§3, "quantization
//! error and bias").

/// S = 2⁸ − 1.
pub const SCALE: f32 = 255.0;

/// S = 2⁴ − 1 (int4 weight grid; activations stay 8-bit).
pub const SCALE_I4: f32 = 15.0;

/// An **in-situ requantization** scheme: how a loaded model's weight
/// matrices are (re)quantized at load time, independent of what the
/// `.qam` artifact stores.  Selected per deployment via `--isq <scheme>`
/// or `QUANTASR_ISQ` (mistral.rs-style ISQ), so one trained artifact
/// serves at 8-bit or 4-bit without re-export.
///
/// | scheme | params | weight grid | packed panels |
/// |---|---|---|---|
/// | `PerMatrixU8` | one (Q, zp) per matrix | u8, S=255 | u8 (seed layout) |
/// | `PerChannelU8` | one (Q, zp) per output row | u8, S=255 | u8 |
/// | `PerChannelI4` | one (Q, zp) per output row | u8 grid on [0,15] | two nibbles per byte |
///
/// Every scheme runs on the same GEMM kernel ladder with the same
/// bit-exactness contract (any SIMD rung ≡ its scalar reference); only
/// the per-output finish arithmetic differs (see `quant::gemm`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantScheme {
    /// The paper's scheme (§3.1): one scale per weight matrix, 8-bit.
    /// Stored-u8 `.qam` grids are served untouched under this scheme.
    PerMatrixU8,
    /// One scale per output row (NVIDIA-style per-channel), 8-bit.
    PerChannelU8,
    /// Per-output-row scales with 4-bit weights (two per byte in the
    /// packed panels) and 8-bit activations.
    PerChannelI4,
}

impl QuantScheme {
    /// Canonical name (CLI/env spelling, registry rows, BENCH_quant.json).
    pub fn name(&self) -> &'static str {
        match self {
            QuantScheme::PerMatrixU8 => "per-matrix-u8",
            QuantScheme::PerChannelU8 => "per-channel-u8",
            QuantScheme::PerChannelI4 => "per-channel-i4",
        }
    }

    /// Parse a CLI/env spelling (canonical names plus short aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "per-matrix-u8" | "per-matrix" | "u8" | "q8" => Some(QuantScheme::PerMatrixU8),
            "per-channel-u8" | "per-channel" | "pc-u8" => Some(QuantScheme::PerChannelU8),
            "per-channel-i4" | "i4" | "int4" | "q4" => Some(QuantScheme::PerChannelI4),
            _ => None,
        }
    }

    /// Weight-grid scale `S = 2^bits − 1`.
    pub fn weight_scale(&self) -> f32 {
        match self {
            QuantScheme::PerChannelI4 => SCALE_I4,
            _ => SCALE,
        }
    }

    /// Weight bits (packed-panel storage width).
    pub fn weight_bits(&self) -> u32 {
        match self {
            QuantScheme::PerChannelI4 => 4,
            _ => 8,
        }
    }

    /// The process-wide `QUANTASR_ISQ` override, or [`PerMatrixU8`]
    /// (the seed scheme) when unset.  Parsed once; unknown values warn
    /// and fall back to the default rather than panic (same contract as
    /// `QUANTASR_KERNEL`).
    ///
    /// [`PerMatrixU8`]: QuantScheme::PerMatrixU8
    pub fn from_env_or_default() -> Self {
        use std::sync::OnceLock;
        static FORCED: OnceLock<QuantScheme> = OnceLock::new();
        *FORCED.get_or_init(|| {
            let Ok(v) = std::env::var("QUANTASR_ISQ") else {
                return QuantScheme::PerMatrixU8;
            };
            match QuantScheme::parse(&v) {
                Some(s) => s,
                None => {
                    eprintln!(
                        "warning: unknown QUANTASR_ISQ '{v}' \
                         (want per-matrix-u8 | per-channel-u8 | per-channel-i4); \
                         using per-matrix-u8"
                    );
                    QuantScheme::PerMatrixU8
                }
            }
        })
    }
}

/// Quantization parameters for one group of values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    /// Range minimum (kept for export/inspection).
    pub vmin: f32,
    /// Quantization factor `Q = S / (vmax − vmin)`.
    pub q: f32,
    /// Integer zero point `round(Q · vmin)` (i64: degenerate ranges can
    /// produce huge Q·vmin; arithmetic is f64 to match python's round()).
    pub zp: i64,
    /// Scale S (255 for the paper's 8 bits; smaller for the E5 bit-width
    /// ablation — storage stays u8).
    pub scale: f32,
}

impl QuantParams {
    /// Derive params from an explicit range.  The 1e-6 floor mirrors
    /// python quantlib.MIN_RANGE (degenerate ranges would give Q ~ 1e14
    /// and f32 cancellation on the python side).
    pub fn from_range(vmin: f32, vmax: f32) -> Self {
        Self::from_range_scaled(vmin, vmax, SCALE)
    }

    /// As [`from_range`] with an explicit scale `S = 2^bits − 1`.
    pub fn from_range_scaled(vmin: f32, vmax: f32, scale: f32) -> Self {
        let range = (vmax - vmin).max(1e-6);
        let q = scale / range;
        QuantParams { vmin, q, zp: (q as f64 * vmin as f64).round() as i64, scale }
    }

    /// Derive params from the min/max of a slice (per-tensor granularity).
    pub fn from_slice(v: &[f32]) -> Self {
        Self::from_slice_scaled(v, SCALE)
    }

    /// As [`from_slice`] with an explicit scale (E5 bit-width ablation).
    pub fn from_slice_scaled(v: &[f32], scale: f32) -> Self {
        let mut vmin = f32::INFINITY;
        let mut vmax = f32::NEG_INFINITY;
        for &x in v {
            vmin = vmin.min(x);
            vmax = vmax.max(x);
        }
        Self::from_minmax_scaled(vmin, vmax, scale)
    }

    /// Derive params from a precomputed range scan — the single
    /// definition of the degenerate/non-finite fallback, shared by
    /// [`QuantParams::from_slice`] and the SIMD min/max scan in
    /// `quant::elementwise` (so the two paths cannot drift).
    pub fn from_minmax(vmin: f32, vmax: f32) -> Self {
        Self::from_minmax_scaled(vmin, vmax, SCALE)
    }

    /// As [`from_minmax`] with an explicit scale.
    pub fn from_minmax_scaled(vmin: f32, vmax: f32, scale: f32) -> Self {
        if !vmin.is_finite() || !vmax.is_finite() {
            // Empty or non-finite input: degenerate unit range.
            return Self::from_range_scaled(0.0, 1.0, scale);
        }
        Self::from_range_scaled(vmin, vmax, scale)
    }

    /// Eq. (2): quantize one value to the integer grid [0, S].
    #[inline]
    pub fn quantize(&self, v: f32) -> u8 {
        let vq = (self.q as f64 * v as f64).round() as i64 - self.zp;
        vq.clamp(0, self.scale as i64) as u8
    }

    /// Eq. (3): recover one quantized value.
    #[inline]
    pub fn recover(&self, vq: u8) -> f32 {
        ((vq as i64 + self.zp) as f64 / self.q as f64) as f32
    }

    /// The offset-shifted integer `V'' = V' + zp` used in eq. (1).
    #[inline]
    pub fn shifted(&self, vq: u8) -> i64 {
        vq as i64 + self.zp
    }

    /// Quantize a slice into `out` (same length).
    pub fn quantize_slice(&self, v: &[f32], out: &mut [u8]) {
        debug_assert_eq!(v.len(), out.len());
        for (o, &x) in out.iter_mut().zip(v) {
            *o = self.quantize(x);
        }
    }

    /// Recover a slice of quantized values into `out`.
    pub fn recover_slice(&self, vq: &[u8], out: &mut [f32]) {
        debug_assert_eq!(vq.len(), out.len());
        let inv_q = 1.0 / self.q as f64;
        for (o, &x) in out.iter_mut().zip(vq) {
            *o = ((x as i64 + self.zp) as f64 * inv_q) as f32;
        }
    }

    /// Maximum precision-loss magnitude: half a quantization step.
    pub fn half_step(&self) -> f32 {
        0.5 / self.q
    }
}

/// The E2-ablation *naive* quantizer: truncation + unrounded offset.
/// Same storage format, biased numerics — exists to demonstrate why the
/// paper's rounding consistency matters.
#[derive(Clone, Copy, Debug)]
pub struct NaiveQuantParams {
    pub vmin: f32,
    pub q: f32,
}

impl NaiveQuantParams {
    pub fn from_slice(v: &[f32]) -> Self {
        let p = QuantParams::from_slice(v);
        NaiveQuantParams { vmin: p.vmin, q: p.q }
    }

    /// floor() of the shifted value — the classic truncating quantizer.
    /// Every value lands on the grid point *below* it, so recovery with the
    /// float offset keeps a systematic −½·step bias.
    #[inline]
    pub fn quantize(&self, v: f32) -> u8 {
        let vq = (self.q as f64 * (v - self.vmin) as f64).floor();
        vq.clamp(0.0, SCALE as f64) as u8
    }

    /// Recovery with the unrounded float offset — inconsistent with the
    /// integer arithmetic of eq. (1); introduces ~half-step bias.
    #[inline]
    pub fn recover(&self, vq: u8) -> f32 {
        vq as f32 / self.q + self.vmin
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        forall("quant roundtrip", 200, 0xC0FFEE, |g: &mut Gen| {
            let n = g.usize_in(2, 300);
            let lo = g.f32_in(-8.0, 0.0);
            let hi = lo + g.f32_in(0.01, 16.0);
            let v = g.vec_f32(n, lo, hi);
            let p = QuantParams::from_slice(&v);
            for &x in &v {
                let r = p.recover(p.quantize(x));
                assert!(
                    (r - x).abs() <= p.half_step() * 1.0001,
                    "x={x} r={r} step={}",
                    p.half_step()
                );
            }
        });
    }

    #[test]
    fn quantized_values_span_scale() {
        let v: Vec<f32> = (0..=100).map(|i| i as f32 / 100.0).collect();
        let p = QuantParams::from_slice(&v);
        assert_eq!(p.quantize(0.0), 0);
        assert_eq!(p.quantize(1.0), 255);
    }

    #[test]
    fn consistent_scheme_has_no_bias() {
        // Mean error over a dense grid must be ~0 for the consistent scheme
        // and visibly negative (half-step truncation) for the naive one.
        let v: Vec<f32> = (0..4096).map(|i| -1.0 + i as f32 * (2.0 / 4095.0)).collect();
        let p = QuantParams::from_slice(&v);
        let np = NaiveQuantParams::from_slice(&v);
        let bias = |f: &dyn Fn(f32) -> f32| -> f64 {
            v.iter().map(|&x| (f(x) - x) as f64).sum::<f64>() / v.len() as f64
        };
        let b_cons = bias(&|x| p.recover(p.quantize(x)));
        let b_naive = bias(&|x| np.recover(np.quantize(x)));
        assert!(b_cons.abs() < 2e-4, "consistent bias {b_cons}");
        assert!(b_naive.abs() > 5.0 * b_cons.abs().max(1e-5), "naive bias {b_naive}");
    }

    #[test]
    fn shifted_equals_round_qv() {
        let p = QuantParams::from_range(-2.0, 3.0);
        for &x in &[-2.0f32, -1.0, 0.0, 0.5, 2.9999] {
            let vq = p.quantize(x);
            assert_eq!(p.shifted(vq), (p.q as f64 * x as f64).round() as i64, "x={x}");
        }
    }

    #[test]
    fn degenerate_range_is_safe() {
        let v = vec![3.0f32; 7];
        let p = QuantParams::from_slice(&v);
        let r = p.recover(p.quantize(3.0));
        assert!((r - 3.0).abs() < 1e-3);
    }

    #[test]
    fn clamps_out_of_range() {
        let p = QuantParams::from_range(0.0, 1.0);
        assert_eq!(p.quantize(-5.0), 0);
        assert_eq!(p.quantize(9.0), 255);
    }
}
