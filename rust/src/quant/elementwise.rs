//! Vectorized elementwise kernels: the fused LSTM cell update and the
//! SIMD activation-quantization scan.
//!
//! After the packed-panel GEMM work, the per-tick serving cost was
//! dominated by everything *around* the GEMMs: a scalar gate loop calling
//! libm `sigmoid`/`tanh` 4·N times per cell per tick, and a scalar
//! min/max + quantize scan per GEMM input row.  This module retires both
//! with fixed-function approximations in the spirit of the paper's §3
//! ("efficient execution"): the nonlinearities are evaluated with an
//! exp2-based polynomial that vectorizes exactly, and the quantization
//! scan runs 8 lanes at a time.
//!
//! ## The elementwise kernel ladder
//!
//! [`EwKernel`] mirrors the GEMM [`Kernel`] ladder: a portable scalar
//! rung, an AVX2 rung, and a NEON rung, runtime-dispatched.  By default
//! the rung follows the GEMM kernel in use ([`EwKernel::for_gemm`], so
//! `QUANTASR_KERNEL=scalar` pins the whole pipeline scalar); the
//! `QUANTASR_EW_KERNEL` env var forces the elementwise rung independently
//! (the CI kernel matrix crosses the two).
//!
//! ## The scalar reference (and the bit-exactness contract)
//!
//! [`sigmoid_ref`]/[`tanh_ref`] are **the** reference semantics for the
//! elementwise path — *not* libm.  Every rung evaluates the *same*
//! polynomial with the *same* IEEE-754 single-precision operations in the
//! *same* order (no FMA contraction, division is exactly rounded, the
//! round-to-nearest-even argument reduction uses the shared magic-number
//! trick), so every rung is **bit-identical** to the scalar reference for
//! all finite inputs, at any batch size or lane subset.  SIMD rows handle
//! the `N % width` tail by falling back to the scalar code per element —
//! identical by construction.  NaN *gate* inputs are out of contract for
//! the cell-update kernels (rungs may disagree on NaN propagation); the
//! quantization scan below is stricter — NaN elements are ignored by the
//! range scan and quantize to `clamp(−zp)` identically on every rung, so
//! a diverged stream cannot make quantization rung-dependent.
//!
//! Accuracy versus libm is a separate, *documented* bound: the polynomial
//! stays within **1e-6 absolute** of the f64 libm `sigmoid`/`tanh`
//! everywhere (measured max ≈ 9.2e-8 / 1.4e-7; property-tested below), so
//! swapping the libm gate loop for this path moves posteriors by less
//! than quantization noise and leaves the WER eval unchanged.
//!
//! The math: `exp(-a)` is computed as `2^t` with `t = -a·log2(e)`,
//! `t = k + f` (`k` integer via round-to-nearest-even, `f ∈ [-½, ½]`),
//! `2^f` a degree-7 Taylor/Horner polynomial, and the `2^k` scale applied
//! by integer exponent arithmetic.  Then `sigmoid(x) = 1/(1+e)` (mirrored
//! via `e/(1+e)` for negative `x` — no cancellation on either side) and
//! `tanh(x) = sign(x)·(1−e)/(1+e)` with `e = exp(-2|x|)`.  Inputs are
//! clamped to the saturation range first, which also keeps the exponent
//! arithmetic away from denormals.

use crate::quant::gemm::Kernel;
use crate::quant::scheme::QuantParams;
use std::sync::OnceLock;

/// Elementwise kernel selection (see the module docs for the ladder and
/// the bit-exactness contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EwKernel {
    /// Portable scalar reference — the bit-exactness anchor.
    Scalar,
    /// 8-lane AVX2 rung (runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 4-lane NEON rung (baseline on aarch64).
    #[cfg(target_arch = "aarch64")]
    Neon,
    /// Best available on this CPU.
    Auto,
}

impl EwKernel {
    /// Resolve `Auto` (honoring a `QUANTASR_EW_KERNEL` override) and clamp
    /// explicitly requested SIMD rungs the CPU lacks back to scalar — the
    /// soundness gate for the `#[target_feature]` dispatch below.
    pub fn resolve(self) -> EwKernel {
        let k = match self {
            EwKernel::Auto => forced_ew_kernel().unwrap_or_else(Self::best_available),
            k => k,
        };
        #[cfg(target_arch = "x86_64")]
        if k == EwKernel::Avx2 && !crate::quant::gemm::avx2_available() {
            return EwKernel::Scalar;
        }
        k
    }

    fn best_available() -> EwKernel {
        #[allow(unused_mut)]
        let mut k = EwKernel::Scalar;
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            k = EwKernel::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        {
            k = EwKernel::Neon;
        }
        k
    }

    /// The elementwise rung that rides along with a GEMM kernel choice —
    /// SIMD GEMM rungs get the SIMD elementwise rung, scalar rungs stay
    /// scalar (so `QUANTASR_KERNEL=scalar` pins the whole pipeline).  A
    /// `QUANTASR_EW_KERNEL` override wins over the mapping.
    pub fn for_gemm(k: Kernel) -> EwKernel {
        if let Some(f) = forced_ew_kernel() {
            return f;
        }
        match k.resolve() {
            Kernel::Scalar | Kernel::Unrolled | Kernel::PackedScalar => EwKernel::Scalar,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 | Kernel::PackedAvx2 => EwKernel::Avx2,
            #[cfg(all(target_arch = "x86_64", feature = "vnni"))]
            Kernel::PackedVnni => EwKernel::Avx2,
            #[cfg(target_arch = "aarch64")]
            Kernel::PackedNeonDot => EwKernel::Neon,
            // `Kernel::resolve` never returns `Auto`, but the compiler
            // cannot know that; scalar is always safe.
            Kernel::Auto => EwKernel::Scalar,
        }
    }
}

/// `QUANTASR_EW_KERNEL` override (parsed once): forces the elementwise
/// rung independently of the GEMM kernel.  Unknown names or rungs this
/// CPU can't run fall back to auto with a warning.
fn forced_ew_kernel() -> Option<EwKernel> {
    static FORCED: OnceLock<Option<EwKernel>> = OnceLock::new();
    *FORCED.get_or_init(|| {
        let v = std::env::var("QUANTASR_EW_KERNEL").ok()?;
        match v.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => None,
            "scalar" => Some(EwKernel::Scalar),
            #[cfg(target_arch = "x86_64")]
            "avx2" if crate::quant::gemm::avx2_available() => Some(EwKernel::Avx2),
            #[cfg(target_arch = "aarch64")]
            "neon" => Some(EwKernel::Neon),
            other => {
                eprintln!(
                    "QUANTASR_EW_KERNEL='{other}' unknown or unavailable on this CPU; \
                     falling back to auto dispatch"
                );
                None
            }
        }
    })
}

// ---------------------------------------------------------------------------
// Scalar reference: the polynomial sigmoid/tanh and their shared exp2 core
// ---------------------------------------------------------------------------

/// log2(e), rounded to f32.
const LOG2E: f32 = 1.442_695_f32;
/// −2·log2(e), rounded to f32 (tanh argument folding).
const N2LOG2E: f32 = -2.885_39_f32;
/// 1.5·2²³ — adding and subtracting this rounds to the nearest integer
/// (ties to even) identically in scalar and SIMD arithmetic.
const MAGIC: f32 = 12_582_912.0;
/// `sigmoid(±30)` saturates to 1.0/9.4e-14 in f32; clamping here also
/// bounds the exp2 exponent far away from denormals.
const SIG_CLAMP: f32 = 30.0;
/// `tanh(±15)` saturates to ±1 in f32.
const TANH_CLAMP: f32 = 15.0;

/// Degree-7 coefficients of 2^f on [-½, ½] (Taylor: (ln2)^k / k!).
const C1: f32 = 0.693_147_2_f32;
const C2: f32 = 0.240_226_5_f32;
const C3: f32 = 0.055_504_11_f32;
const C4: f32 = 0.009_618_129_f32;
const C5: f32 = 0.001_333_355_8_f32;
const C6: f32 = 1.540_353e-4_f32;
const C7: f32 = 1.525_273_4e-5_f32;

/// `if a < b { a } else { b }` — the exact SIMD `min` semantics
/// (`_mm256_min_ps(a, b)` returns `b` on NaN/equal), used for the
/// activation clamps so the scalar reference mirrors the SIMD rungs.
#[inline(always)]
fn min_simd(a: f32, b: f32) -> f32 {
    if a < b {
        a
    } else {
        b
    }
}

/// 2^t for t ∈ [−126, 0], bit-identically reproducible in SIMD: magic
/// round-to-nearest-even, plain (non-FMA) Horner, integer exponent scale.
#[inline(always)]
fn exp2m_ref(t: f32) -> f32 {
    let kf = (t + MAGIC) - MAGIC;
    let f = t - kf;
    let mut p = C7;
    p = p * f + C6;
    p = p * f + C5;
    p = p * f + C4;
    p = p * f + C3;
    p = p * f + C2;
    p = p * f + C1;
    p = p * f + 1.0;
    // kf is exactly integral, so truncation == nearest == the SIMD cvt.
    let k = kf as i32;
    let scale = f32::from_bits(((k + 127) as u32) << 23);
    p * scale
}

/// Scalar-reference logistic sigmoid (the elementwise path's reference
/// semantics — within 1e-6 absolute of libm; see module docs).
#[inline(always)]
pub fn sigmoid_ref(x: f32) -> f32 {
    let ax = min_simd(f32::from_bits(x.to_bits() & 0x7FFF_FFFF), SIG_CLAMP);
    let e = exp2m_ref(-ax * LOG2E);
    let sp = 1.0 / (1.0 + e);
    if x < 0.0 {
        e * sp
    } else {
        sp
    }
}

/// Scalar-reference tanh (within 1e-6 absolute of libm).
#[inline(always)]
pub fn tanh_ref(x: f32) -> f32 {
    let ax = min_simd(f32::from_bits(x.to_bits() & 0x7FFF_FFFF), TANH_CLAMP);
    let e = exp2m_ref(N2LOG2E * ax);
    let q = 1.0 / (1.0 + e);
    let r = (1.0 - e) * q;
    f32::from_bits(r.to_bits() | (x.to_bits() & 0x8000_0000))
}

/// Scalar fused cell update for elements `j0..j1` of one row — also the
/// tail handler for the SIMD rows (bit-identical by construction).
/// Layout: `g` is the `[i | f | g | o]` gate row (4·n), `c`/`h` are the
/// n-element cell/output rows.
fn lstm_cell_row_scalar(g: &[f32], c: &mut [f32], h: &mut [f32], n: usize, j0: usize, j1: usize) {
    for j in j0..j1 {
        let i_g = sigmoid_ref(g[j]);
        let f_g = sigmoid_ref(g[n + j]);
        let g_g = tanh_ref(g[2 * n + j]);
        let o_g = sigmoid_ref(g[3 * n + j]);
        let c_new = f_g * c[j] + i_g * g_g;
        c[j] = c_new;
        h[j] = o_g * tanh_ref(c_new);
    }
}

// ---------------------------------------------------------------------------
// AVX2 rung
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn exp2m(t: __m256) -> __m256 {
        let magic = _mm256_set1_ps(MAGIC);
        let kf = _mm256_sub_ps(_mm256_add_ps(t, magic), magic);
        let f = _mm256_sub_ps(t, kf);
        let mut p = _mm256_set1_ps(C7);
        p = _mm256_add_ps(_mm256_mul_ps(p, f), _mm256_set1_ps(C6));
        p = _mm256_add_ps(_mm256_mul_ps(p, f), _mm256_set1_ps(C5));
        p = _mm256_add_ps(_mm256_mul_ps(p, f), _mm256_set1_ps(C4));
        p = _mm256_add_ps(_mm256_mul_ps(p, f), _mm256_set1_ps(C3));
        p = _mm256_add_ps(_mm256_mul_ps(p, f), _mm256_set1_ps(C2));
        p = _mm256_add_ps(_mm256_mul_ps(p, f), _mm256_set1_ps(C1));
        p = _mm256_add_ps(_mm256_mul_ps(p, f), _mm256_set1_ps(1.0));
        let k = _mm256_cvtps_epi32(kf);
        let scale = _mm256_castsi256_ps(_mm256_slli_epi32(
            _mm256_add_epi32(k, _mm256_set1_epi32(127)),
            23,
        ));
        _mm256_mul_ps(p, scale)
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn sigmoid(x: __m256) -> __m256 {
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let ax = _mm256_min_ps(_mm256_and_ps(x, absmask), _mm256_set1_ps(SIG_CLAMP));
        let e = exp2m(_mm256_mul_ps(ax, _mm256_set1_ps(-LOG2E)));
        let one = _mm256_set1_ps(1.0);
        let sp = _mm256_div_ps(one, _mm256_add_ps(one, e));
        let sn = _mm256_mul_ps(e, sp);
        let neg = _mm256_cmp_ps(x, _mm256_setzero_ps(), _CMP_LT_OQ);
        _mm256_blendv_ps(sp, sn, neg)
    }

    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn tanh(x: __m256) -> __m256 {
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
        let ax = _mm256_min_ps(_mm256_and_ps(x, absmask), _mm256_set1_ps(TANH_CLAMP));
        let e = exp2m(_mm256_mul_ps(ax, _mm256_set1_ps(N2LOG2E)));
        let one = _mm256_set1_ps(1.0);
        let q = _mm256_div_ps(one, _mm256_add_ps(one, e));
        let r = _mm256_mul_ps(_mm256_sub_ps(one, e), q);
        // tanh is odd and r >= 0: OR the argument's sign bit back in.
        let sign = _mm256_andnot_ps(absmask, x);
        _mm256_or_ps(r, sign)
    }

    /// Fused cell update over one row, 8 lanes at a time (scalar tail).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available; slice lengths as in
    /// [`lstm_cell_row_scalar`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn lstm_cell_row(g: &[f32], c: &mut [f32], h: &mut [f32], n: usize) {
        let mut j = 0;
        while j + 8 <= n {
            let i_g = sigmoid(_mm256_loadu_ps(g.as_ptr().add(j)));
            let f_g = sigmoid(_mm256_loadu_ps(g.as_ptr().add(n + j)));
            let g_g = tanh(_mm256_loadu_ps(g.as_ptr().add(2 * n + j)));
            let o_g = sigmoid(_mm256_loadu_ps(g.as_ptr().add(3 * n + j)));
            let cv = _mm256_loadu_ps(c.as_ptr().add(j));
            let c_new = _mm256_add_ps(_mm256_mul_ps(f_g, cv), _mm256_mul_ps(i_g, g_g));
            _mm256_storeu_ps(c.as_mut_ptr().add(j), c_new);
            let hv = _mm256_mul_ps(o_g, tanh(c_new));
            _mm256_storeu_ps(h.as_mut_ptr().add(j), hv);
            j += 8;
        }
        if j < n {
            lstm_cell_row_scalar(g, c, h, n, j, n);
        }
    }

    /// Vector min/max scan.  NaN elements are **ignored** on every rung —
    /// `_mm256_min_ps(x, acc)` returns `acc` (the second operand) when
    /// `x` is NaN, the same semantics as the `f32::min` fold the scalar
    /// rung uses — so the derived quantization range is identical across
    /// rungs even for non-finite rows (the historical
    /// `QuantParams::from_slice` behavior).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn minmax(v: &[f32]) -> (f32, f32) {
        let n = v.len();
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        let mut i = 0;
        if n >= 8 {
            let mut vmn = _mm256_set1_ps(f32::INFINITY);
            let mut vmx = _mm256_set1_ps(f32::NEG_INFINITY);
            while i + 8 <= n {
                let x = _mm256_loadu_ps(v.as_ptr().add(i));
                // x first: NaN lanes keep the accumulator (NaN-ignoring)
                vmn = _mm256_min_ps(x, vmn);
                vmx = _mm256_max_ps(x, vmx);
                i += 8;
            }
            let mut lanes = [0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), vmn);
            for &l in &lanes {
                mn = mn.min(l);
            }
            _mm256_storeu_ps(lanes.as_mut_ptr(), vmx);
            for &l in &lanes {
                mx = mx.max(l);
            }
        }
        while i < n {
            mn = mn.min(v[i]);
            mx = mx.max(v[i]);
            i += 1;
        }
        (mn, mx)
    }

    /// Exact round-half-away-from-zero on non-negative doubles: candidate
    /// `trunc(a + ½)` can only overshoot by one (when `a + ½` rounds up
    /// across an integer), detected by the exact compare `a < r − ½`
    /// (`r − ½` is exact for r < 2⁵²).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn round_half_away_abs(a: __m256d, half: __m256d, one: __m256d) -> __m256d {
        let r = _mm256_round_pd(_mm256_add_pd(a, half), _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC);
        let over = _mm256_cmp_pd(a, _mm256_sub_pd(r, half), _CMP_LT_OQ);
        _mm256_sub_pd(r, _mm256_and_pd(over, one))
    }

    /// Quantize 4 f64 lanes: `clamp(round_half_away(q·x) − zp, 0, scale)`
    /// as exact integer-valued f64 arithmetic, then an exact cvt to i32.
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available.
    #[inline]
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn quant4(
        x: __m256d,
        q: __m256d,
        zp: __m256d,
        cap: __m256d,
        zero: __m256d,
        half: __m256d,
        one: __m256d,
        absmask: __m256d,
    ) -> __m128i {
        let t = _mm256_mul_pd(q, x);
        // Zero NaN lanes up front: the scalar `(NaN).round() as i64` is 0,
        // so rounding 0.0 here keeps NaN inputs bit-identical to scalar.
        let t = _mm256_and_pd(t, _mm256_cmp_pd(t, t, _CMP_ORD_Q));
        let a = _mm256_and_pd(t, absmask);
        let r = round_half_away_abs(a, half, one);
        // restore the sign (r >= 0, so OR-ing the sign bit negates)
        let r = _mm256_or_pd(r, _mm256_andnot_pd(absmask, t));
        let d = _mm256_min_pd(_mm256_max_pd(_mm256_sub_pd(r, zp), zero), cap);
        _mm256_cvtpd_epi32(d)
    }

    /// Quantize a slice against `p` and return the integer sum —
    /// bit-identical to the scalar [`QuantParams::quantize`] loop (the
    /// f64 product, the round-half-away, the zero-point subtraction and
    /// the clamp are all reproduced exactly; the caller's dispatch gate
    /// bounds |zp| so the f64 arithmetic stays exact).
    ///
    /// # Safety
    /// Caller must ensure AVX2 is available; `src.len() == dst.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_slice_sum(p: &QuantParams, src: &[f32], dst: &mut [u8]) -> i32 {
        let n = src.len();
        let q = _mm256_set1_pd(p.q as f64);
        let zp = _mm256_set1_pd(p.zp as f64);
        let cap = _mm256_set1_pd(p.scale as f64);
        let zero = _mm256_setzero_pd();
        let half = _mm256_set1_pd(0.5);
        let one = _mm256_set1_pd(1.0);
        let absmask = _mm256_castsi256_pd(_mm256_set1_epi64x(0x7FFF_FFFF_FFFF_FFFFu64 as i64));
        let mut sumv = _mm_setzero_si128();
        let mut i = 0;
        while i + 8 <= n {
            let x8 = _mm256_loadu_ps(src.as_ptr().add(i));
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(x8));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps(x8, 1));
            let qlo = quant4(lo, q, zp, cap, zero, half, one, absmask);
            let qhi = quant4(hi, q, zp, cap, zero, half, one, absmask);
            let w16 = _mm_packs_epi32(qlo, qhi);
            let b8 = _mm_packus_epi16(w16, w16);
            _mm_storel_epi64(dst.as_mut_ptr().add(i) as *mut __m128i, b8);
            sumv = _mm_add_epi32(sumv, _mm_add_epi32(qlo, qhi));
            i += 8;
        }
        let s = _mm_add_epi32(sumv, _mm_shuffle_epi32(sumv, 0b00_01_10_11));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b00_00_00_01));
        let mut sum = _mm_cvtsi128_si32(s);
        while i < n {
            let v = p.quantize(src[i]);
            dst[i] = v;
            sum += v as i32;
            i += 1;
        }
        sum
    }
}

// ---------------------------------------------------------------------------
// NEON rung (aarch64; NEON is baseline, no runtime detection needed)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::*;
    use std::arch::aarch64::*;

    /// # Safety
    /// aarch64 only (NEON is a baseline feature there).
    #[inline]
    unsafe fn exp2m(t: float32x4_t) -> float32x4_t {
        let magic = vdupq_n_f32(MAGIC);
        let kf = vsubq_f32(vaddq_f32(t, magic), magic);
        let f = vsubq_f32(t, kf);
        let mut p = vdupq_n_f32(C7);
        p = vaddq_f32(vmulq_f32(p, f), vdupq_n_f32(C6));
        p = vaddq_f32(vmulq_f32(p, f), vdupq_n_f32(C5));
        p = vaddq_f32(vmulq_f32(p, f), vdupq_n_f32(C4));
        p = vaddq_f32(vmulq_f32(p, f), vdupq_n_f32(C3));
        p = vaddq_f32(vmulq_f32(p, f), vdupq_n_f32(C2));
        p = vaddq_f32(vmulq_f32(p, f), vdupq_n_f32(C1));
        p = vaddq_f32(vmulq_f32(p, f), vdupq_n_f32(1.0));
        let k = vcvtq_s32_f32(kf); // kf integral: truncation is exact
        let scale = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(k, vdupq_n_s32(127))));
        vmulq_f32(p, scale)
    }

    /// `a < b ? a : b` per lane — matches the scalar [`min_simd`] (and the
    /// x86 `min_ps`) semantics exactly, unlike `vminq_f32` on NaN.
    #[inline]
    unsafe fn min_sel(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vbslq_f32(vcltq_f32(a, b), a, b)
    }

    #[inline]
    unsafe fn max_sel(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vbslq_f32(vcgtq_f32(a, b), a, b)
    }

    #[inline]
    unsafe fn sigmoid(x: float32x4_t) -> float32x4_t {
        let ax = min_sel(vabsq_f32(x), vdupq_n_f32(SIG_CLAMP));
        let e = exp2m(vmulq_f32(ax, vdupq_n_f32(-LOG2E)));
        let one = vdupq_n_f32(1.0);
        let sp = vdivq_f32(one, vaddq_f32(one, e));
        let sn = vmulq_f32(e, sp);
        let neg = vcltq_f32(x, vdupq_n_f32(0.0));
        vbslq_f32(neg, sn, sp)
    }

    #[inline]
    unsafe fn tanh(x: float32x4_t) -> float32x4_t {
        let ax = min_sel(vabsq_f32(x), vdupq_n_f32(TANH_CLAMP));
        let e = exp2m(vmulq_f32(ax, vdupq_n_f32(N2LOG2E)));
        let one = vdupq_n_f32(1.0);
        let q = vdivq_f32(one, vaddq_f32(one, e));
        let r = vmulq_f32(vsubq_f32(one, e), q);
        let sign = vandq_u32(vreinterpretq_u32_f32(x), vdupq_n_u32(0x8000_0000));
        vreinterpretq_f32_u32(vorrq_u32(vreinterpretq_u32_f32(r), sign))
    }

    /// Fused cell update over one row, 4 lanes at a time (scalar tail).
    ///
    /// # Safety
    /// aarch64 only; slice lengths as in [`lstm_cell_row_scalar`].
    pub unsafe fn lstm_cell_row(g: &[f32], c: &mut [f32], h: &mut [f32], n: usize) {
        let mut j = 0;
        while j + 4 <= n {
            let i_g = sigmoid(vld1q_f32(g.as_ptr().add(j)));
            let f_g = sigmoid(vld1q_f32(g.as_ptr().add(n + j)));
            let g_g = tanh(vld1q_f32(g.as_ptr().add(2 * n + j)));
            let o_g = sigmoid(vld1q_f32(g.as_ptr().add(3 * n + j)));
            let cv = vld1q_f32(c.as_ptr().add(j));
            let c_new = vaddq_f32(vmulq_f32(f_g, cv), vmulq_f32(i_g, g_g));
            vst1q_f32(c.as_mut_ptr().add(j), c_new);
            let hv = vmulq_f32(o_g, tanh(c_new));
            vst1q_f32(h.as_mut_ptr().add(j), hv);
            j += 4;
        }
        if j < n {
            lstm_cell_row_scalar(g, c, h, n, j, n);
        }
    }

    /// Vector min/max scan.  NaN elements are ignored (the accumulator
    /// wins the select when the comparison is unordered), matching the
    /// scalar `f32::min`/`f32::max` fold on every rung.
    ///
    /// # Safety
    /// aarch64 only.
    pub unsafe fn minmax(v: &[f32]) -> (f32, f32) {
        let n = v.len();
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        let mut i = 0;
        if n >= 4 {
            let mut vmn = vdupq_n_f32(f32::INFINITY);
            let mut vmx = vdupq_n_f32(f32::NEG_INFINITY);
            while i + 4 <= n {
                let x = vld1q_f32(v.as_ptr().add(i));
                // x first: NaN lanes keep the accumulator (NaN-ignoring)
                vmn = min_sel(x, vmn);
                vmx = max_sel(x, vmx);
                i += 4;
            }
            let mut lanes = [0f32; 4];
            vst1q_f32(lanes.as_mut_ptr(), vmn);
            for &l in &lanes {
                mn = mn.min(l);
            }
            vst1q_f32(lanes.as_mut_ptr(), vmx);
            for &l in &lanes {
                mx = mx.max(l);
            }
        }
        while i < n {
            mn = mn.min(v[i]);
            mx = mx.max(v[i]);
            i += 1;
        }
        (mn, mx)
    }
}

// ---------------------------------------------------------------------------
// Dispatch entry points
// ---------------------------------------------------------------------------

/// Fused LSTM cell update over contiguous batch rows: one pass over the
/// `[batch, 4n]` gate buffer computing `i,f,g,o` nonlinearities, the cell
/// update `c = f·c + i·g` and the pre-projection output `h = o·tanh(c)`
/// written straight into `h [batch, n]` — the gate buffer is only read.
pub fn lstm_cell_batch(
    gates: &[f32],
    c: &mut [f32],
    h: &mut [f32],
    batch: usize,
    n: usize,
    kernel: EwKernel,
) {
    debug_assert!(gates.len() >= batch * 4 * n);
    debug_assert!(c.len() >= batch * n);
    debug_assert!(h.len() >= batch * n);
    let kernel = kernel.resolve();
    for r in 0..batch {
        lstm_cell_row_dispatch(
            &gates[r * 4 * n..(r + 1) * 4 * n],
            &mut c[r * n..(r + 1) * n],
            &mut h[r * n..(r + 1) * n],
            n,
            kernel,
        );
    }
}

/// Lane-masked fused cell update over lane-resident buffers: only the
/// rows listed in `lanes` are read and updated.  Per lane, bit-identical
/// to [`lstm_cell_batch`] on that row alone.
pub fn lstm_cell_lanes(
    gates: &[f32],
    c: &mut [f32],
    h: &mut [f32],
    max_lanes: usize,
    lanes: &[usize],
    n: usize,
    kernel: EwKernel,
) {
    debug_assert!(gates.len() >= max_lanes * 4 * n);
    debug_assert!(c.len() >= max_lanes * n);
    debug_assert!(h.len() >= max_lanes * n);
    let kernel = kernel.resolve();
    for &r in lanes {
        debug_assert!(r < max_lanes);
        lstm_cell_row_dispatch(
            &gates[r * 4 * n..(r + 1) * 4 * n],
            &mut c[r * n..(r + 1) * n],
            &mut h[r * n..(r + 1) * n],
            n,
            kernel,
        );
    }
}

/// `kernel` must already be resolved.
fn lstm_cell_row_dispatch(g: &[f32], c: &mut [f32], h: &mut [f32], n: usize, kernel: EwKernel) {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `EwKernel::resolve` clamps Avx2 to Scalar when the CPU
        // lacks it, so this arm is only reachable with AVX2 present.
        EwKernel::Avx2 => unsafe { avx2::lstm_cell_row(g, c, h, n) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is an aarch64 baseline feature.
        EwKernel::Neon => unsafe { neon::lstm_cell_row(g, c, h, n) },
        _ => lstm_cell_row_scalar(g, c, h, n, 0, n),
    }
}

/// Min/max of a slice — the quantization range scan (eq. 2).  Every rung
/// reproduces the `f32::min`/`f32::max` fold of the historical
/// `QuantParams::from_slice` — including its NaN-ignoring behavior — so
/// derived quantization params can never depend on the rung, even for
/// non-finite rows.  Returns `(+inf, −inf)` for an empty slice.
pub fn minmax(v: &[f32], kernel: EwKernel) -> (f32, f32) {
    match kernel.resolve() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolve() guarantees AVX2 here.
        EwKernel::Avx2 => unsafe { avx2::minmax(v) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is an aarch64 baseline feature.
        EwKernel::Neon => unsafe { neon::minmax(v) },
        _ => {
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for &x in v {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            (mn, mx)
        }
    }
}

/// Quantize `src` against `p` into `dst` and return the integer row sum —
/// the single (eq. 2) definition shared by every GEMM input-quantization
/// path.  The AVX2 rung reproduces [`QuantParams::quantize`] bit-exactly
/// (f64 product, round-half-away, zero-point, clamp); it is only
/// dispatched when `|zp| < 2⁵¹` so all intermediate f64 integers stay
/// exact (degenerate ranges fall back to the scalar loop).
pub fn quantize_slice_sum(p: &QuantParams, src: &[f32], dst: &mut [u8], kernel: EwKernel) -> i32 {
    debug_assert_eq!(src.len(), dst.len());
    match kernel.resolve() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolve() guarantees AVX2 here.
        EwKernel::Avx2 if p.zp.unsigned_abs() < (1u64 << 51) => unsafe {
            avx2::quantize_slice_sum(p, src, dst)
        },
        _ => {
            let mut sum = 0i32;
            for (o, &x) in dst.iter_mut().zip(src) {
                let v = p.quantize(x);
                *o = v;
                sum += v as i32;
            }
            sum
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    fn available_rungs() -> Vec<EwKernel> {
        let mut ks = vec![EwKernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            ks.push(EwKernel::Avx2);
        }
        #[cfg(target_arch = "aarch64")]
        ks.push(EwKernel::Neon);
        ks.push(EwKernel::Auto);
        ks
    }

    #[test]
    fn reference_activations_within_1e6_of_libm() {
        // The documented accuracy bound: ≤ 1e-6 absolute vs f64 libm,
        // swept over a dense grid crossing both saturation knees.
        let mut x = -40.0f64;
        while x <= 40.0 {
            let xf = x as f32;
            let sig = 1.0 / (1.0 + (-x).exp());
            let th = x.tanh();
            assert!(
                (sigmoid_ref(xf) as f64 - sig).abs() <= 1e-6,
                "sigmoid({xf}): {} vs {sig}",
                sigmoid_ref(xf)
            );
            assert!(
                (tanh_ref(xf) as f64 - th).abs() <= 1e-6,
                "tanh({xf}): {} vs {th}",
                tanh_ref(xf)
            );
            x += 1.37e-3;
        }
        // extremes saturate and stay finite
        assert_eq!(sigmoid_ref(1e10), 1.0);
        assert!(sigmoid_ref(-1e10) >= 0.0 && sigmoid_ref(-1e10) < 1e-12);
        assert_eq!(tanh_ref(1e10), 1.0);
        assert_eq!(tanh_ref(-1e10), -1.0);
        assert_eq!(tanh_ref(0.0), 0.0);
    }

    #[test]
    fn fused_rungs_bit_identical_to_scalar_all_widths() {
        // Odd cell dims crossing every SIMD tail boundary (1..=33 covers
        // n % 8 and n % 4 remainders), random gates/state.
        for n in 1..=33usize {
            let mut g = Gen::new(0xE11 + n as u64);
            let batch = 3;
            let gates = g.vec_normal(batch * 4 * n, 3.0);
            let c0 = g.vec_normal(batch * n, 1.0);
            let mut c_ref = c0.clone();
            let mut h_ref = vec![0f32; batch * n];
            lstm_cell_batch(&gates, &mut c_ref, &mut h_ref, batch, n, EwKernel::Scalar);
            for &k in &available_rungs() {
                let mut c = c0.clone();
                let mut h = vec![0f32; batch * n];
                lstm_cell_batch(&gates, &mut c, &mut h, batch, n, k);
                assert_eq!(c, c_ref, "rung {k:?} n={n} diverged (c)");
                assert_eq!(h, h_ref, "rung {k:?} n={n} diverged (h)");
            }
        }
    }

    #[test]
    fn fused_lanes_bit_identical_to_batch_rows() {
        forall("ew lanes", 40, 0x1A4E5, |g: &mut Gen| {
            let max_lanes = g.usize_in(1, 6);
            let n = g.usize_in(1, 40);
            let gates = g.vec_normal(max_lanes * 4 * n, 3.0);
            let c0 = g.vec_normal(max_lanes * n, 1.0);
            let lanes: Vec<usize> = (0..max_lanes).filter(|_| g.bool()).collect();
            let lanes =
                if lanes.is_empty() { vec![g.usize_in(0, max_lanes - 1)] } else { lanes };
            for &k in &available_rungs() {
                let mut c_full = c0.clone();
                let mut h_full = vec![0f32; max_lanes * n];
                lstm_cell_batch(&gates, &mut c_full, &mut h_full, max_lanes, n, k);
                let mut c = c0.clone();
                let mut h = vec![f32::NAN; max_lanes * n];
                lstm_cell_lanes(&gates, &mut c, &mut h, max_lanes, &lanes, n, k);
                for lane in 0..max_lanes {
                    if lanes.contains(&lane) {
                        assert_eq!(
                            c[lane * n..(lane + 1) * n],
                            c_full[lane * n..(lane + 1) * n],
                            "rung {k:?}"
                        );
                        assert_eq!(
                            h[lane * n..(lane + 1) * n],
                            h_full[lane * n..(lane + 1) * n],
                            "rung {k:?}"
                        );
                    } else {
                        // inactive lanes untouched
                        assert_eq!(c[lane * n..(lane + 1) * n], c0[lane * n..(lane + 1) * n]);
                        assert!(h[lane * n..(lane + 1) * n].iter().all(|v| v.is_nan()));
                    }
                }
            }
        });
    }

    #[test]
    fn minmax_matches_scalar_fold() {
        forall("minmax", 60, 0x3147, |g: &mut Gen| {
            let n = g.usize_in(0, 200);
            let mut v = g.vec_normal(n, 5.0);
            // NaN elements must be *ignored* identically on every rung
            // (the f32::min/f32::max fold semantics QuantParams::from_slice
            // always had) — a diverged stream's NaN row must not make
            // quantization params rung-dependent.
            if n >= 3 && g.bool() {
                v[g.usize_in(0, n - 1)] = f32::NAN;
            }
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for &x in &v {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            for &k in &available_rungs() {
                let (a, b) = minmax(&v, k);
                if n == 0 {
                    assert!(a.is_infinite() && b.is_infinite());
                } else {
                    assert_eq!((a, b), (mn, mx), "rung {k:?}");
                }
            }
        });
    }

    #[test]
    fn quantize_rungs_bit_identical_to_scheme() {
        // Every rung must reproduce QuantParams::quantize exactly —
        // including values sitting on round-half boundaries and inputs
        // outside the derived range (clamping).
        forall("quantize simd", 60, 0x9B172, |g: &mut Gen| {
            let n = g.usize_in(0, 130);
            let lo = g.f32_in(-8.0, 0.0);
            let hi = lo + g.f32_in(1e-5, 16.0);
            let mut v = g.vec_f32(n, lo, hi);
            // adversarial: exact range ends, out-of-range values, and a
            // NaN (params ignore it; its quantized byte is clamp(−zp) on
            // every rung — determinism must survive diverged streams)
            if n >= 5 {
                v[0] = lo;
                v[1] = hi;
                v[2] = lo - 1.0;
                v[3] = hi + 1.0;
                v[4] = f32::NAN;
            }
            let p = QuantParams::from_slice(&v);
            let mut want = vec![0u8; n];
            let mut want_sum = 0i32;
            for (o, &x) in want.iter_mut().zip(&v) {
                *o = p.quantize(x);
                want_sum += *o as i32;
            }
            for &k in &available_rungs() {
                let mut got = vec![0u8; n];
                let sum = quantize_slice_sum(&p, &v, &mut got, k);
                assert_eq!(got, want, "rung {k:?}");
                assert_eq!(sum, want_sum, "rung {k:?}");
            }
        });
    }

    #[test]
    fn quantize_grid_halfway_points_exact() {
        // A uniform grid lands many products exactly on n + 0.5 — the
        // adversarial case for the SIMD round emulation.
        let p = QuantParams::from_range(0.0, 255.0);
        let v: Vec<f32> = (0..511).map(|i| i as f32 * 0.5).collect();
        let mut want = vec![0u8; v.len()];
        let mut want_sum = 0i32;
        for (o, &x) in want.iter_mut().zip(&v) {
            *o = p.quantize(x);
            want_sum += *o as i32;
        }
        for &k in &available_rungs() {
            let mut got = vec![0u8; v.len()];
            let sum = quantize_slice_sum(&p, &v, &mut got, k);
            assert_eq!(got, want, "rung {k:?}");
            assert_eq!(sum, want_sum, "rung {k:?}");
        }
    }

    #[test]
    fn forced_gemm_mapping_is_consistent() {
        // Scalar GEMM rungs ride with the scalar elementwise rung (unless
        // QUANTASR_EW_KERNEL overrides, which tests must not set).
        if std::env::var("QUANTASR_EW_KERNEL").is_ok()
            || std::env::var("QUANTASR_KERNEL").is_ok()
        {
            return; // forced environment: mapping intentionally differs
        }
        assert_eq!(EwKernel::for_gemm(Kernel::Scalar), EwKernel::Scalar);
        assert_eq!(EwKernel::for_gemm(Kernel::PackedScalar), EwKernel::Scalar);
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            assert_eq!(EwKernel::for_gemm(Kernel::PackedAvx2), EwKernel::Avx2);
        }
        // Auto resolves to something concrete.
        assert_ne!(EwKernel::Auto.resolve(), EwKernel::Auto);
    }
}
