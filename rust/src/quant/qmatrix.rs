//! Quantized weight matrices.
//!
//! Storage layout is **transposed** relative to the math: for a layer
//! computing `y = x·W` with `W: [in, out]`, the [`QMatrix`] stores `Wᵀ`
//! row-major as `[out, in]` so each output neuron's weights are contiguous
//! — the natural layout for the GEMV-style inner loops of streaming
//! inference (batch 1–16).
//!
//! On top of the row-major grid, per-matrix-granularity weights also carry
//! a [`PackedQMatrix`] — a panel-packed mirror built **once** at
//! load/quantization time that the register-blocked GEMM microkernels in
//! [`crate::quant::gemm`] stream instead of walking rows one dot product
//! at a time (gemmlowp-style packing; see the layout docs on
//! [`PackedQMatrix`]).
//!
//! Granularity (paper §3.1 "our scheme can be applied at a given level of
//! granularity"): the paper settles on per-weight-matrix; [`Granularity`]
//! also implements per-row (per output neuron) and fixed sub-blocks for the
//! E3 ablation.

use crate::quant::scheme::{QuantParams, QuantScheme, SCALE};

/// Quantization granularity for a weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One (Q, zp) for the whole matrix — the paper's choice.
    PerMatrix,
    /// One (Q, zp) per output row (finer; more metadata).
    PerRow,
    /// One (Q, zp) per `size × size` block of the stored layout.
    SubBlock { size: usize },
}

/// A u8-quantized matrix in `[out, in]` (transposed) layout.
#[derive(Clone, Debug)]
pub struct QMatrix {
    pub out_dim: usize,
    pub in_dim: usize,
    pub granularity: Granularity,
    /// V' values (eq. 2), row-major `[out, in]`.
    pub data: Vec<u8>,
    /// Quant params; length depends on granularity (1, out_dim, or #blocks).
    pub params: Vec<QuantParams>,
    /// `1.0 / params[i].q` precomputed in f64 — the per-channel GEMM
    /// finish multiplies by this per output row instead of dividing.
    pub inv_q: Vec<f64>,
    /// Per output row: Σ_k V'[o, k] — precomputed for the eq. (1) offset
    /// algebra in the integer GEMM.
    pub row_sums: Vec<i32>,
    /// Panel-packed serving mirror, built once at construction so the hot
    /// path never repacks.  Present for the serving schemes (PerMatrix,
    /// and per-row when built through [`QMatrix::from_f32_transposed_scheme`]);
    /// `None` for the ablation-only granularities, which run the slow
    /// path anyway.
    pub packed: Option<Box<PackedQMatrix>>,
}

/// Which packed mirror a constructor should build.
#[derive(Clone, Copy, PartialEq, Eq)]
enum PackMode {
    /// No packed mirror (ablation granularities; row-dot fallback only).
    None,
    /// One byte per weight (8-bit grids).
    U8,
    /// Two 4-bit weights per byte (int4 grids).
    I4,
}

/// Packed-panel mirror of a [`QMatrix`] for the register-blocked GEMM
/// microkernels.  Built **once** (model load / post-hoc quantization);
/// the row-major grid in [`QMatrix::data`] stays authoritative for
/// recovery, serialization and the granularity ablations.
///
/// # Layout
///
/// Output rows are grouped into panels of [`PackedQMatrix::NR`] rows and K
/// is zero-padded up to a multiple of [`PackedQMatrix::K_CHUNK`], then
/// interleaved K-major within each panel:
///
/// ```text
/// panel p  (rows o0 = p·NR .. o0+NR), K-block kb (K_CHUNK columns):
///   w'[o0+0][kb..kb+16] | w'[o0+1][kb..kb+16] | w'[o0+2][kb..kb+16] | w'[o0+3][kb..kb+16]
/// ```
///
/// Each 64-byte block is exactly one microkernel step — a single zmm load
/// for the AVX-512-VNNI `vpdpbusd` kernel, four xmm loads for the AVX2
/// `madd_epi16` and NEON `dot` kernels — and successive blocks (and
/// successive panels) are contiguous, so the whole weight matrix streams
/// through the kernel as one hardware-prefetch-friendly pass.  K-blocking
/// is the interleave unit: an input row's padded K bytes stay L1-resident
/// while a panel streams by, so no second-level blocking is needed at the
/// GEMV/small-batch shapes this engine serves.
///
/// # Signedness
///
/// On x86_64, 8-bit grids store `w' = w − 128` as i8 (`signed == true`):
/// both `madd_epi16` (cvtepi8 widening) and `vpdpbusd` (u8×s8) consume a
/// signed B operand.  The GEMM adds the exact integer compensation
/// `128·Σx` back (see `quant::gemm`), so packed results are
/// **bit-identical** to the u8 reference kernels.  On other architectures
/// `w' = w` is kept unsigned (`signed == false`, compensation 0) — the
/// NEON `vdot` kernel is u8×u8.  Int4 grids are unsigned on every
/// architecture: nibbles already fit the u8×u8 paths with headroom.
///
/// # Int4 nibble layout (`bits == 4`)
///
/// K is padded to a multiple of `2·K_CHUNK = 32`, and each panel row
/// stores one 32-value K-block as 16 bytes: byte `j` holds
/// `w'[kb + j]` in its **low** nibble and `w'[kb + 16 + j]` in its
/// **high** nibble.  Unpacking is therefore shuffle-free SIMD:
/// `b & 0x0F` yields values `kb..kb+16` and `b >> 4` yields
/// `kb+16..kb+32`, each aligned with a contiguous 16-byte slice of the
/// padded input row.  Block and panel successions stay contiguous, so
/// the mirror still streams as one pass at half the bytes of u8.
///
/// Zero padding (K tail and panel-remainder rows) is exact: padded input
/// bytes are zero, so padded products contribute nothing, and panel
/// remainder outputs are computed in registers but never written back.
#[derive(Clone, Debug)]
pub struct PackedQMatrix {
    pub out_dim: usize,
    pub in_dim: usize,
    /// `in_dim` rounded up to a multiple of [`Self::K_CHUNK`] (8-bit) or
    /// `2·K_CHUNK` (4-bit).
    pub k_padded: usize,
    /// Number of NR-row panels (`out_dim.div_ceil(NR)`).
    pub panels: usize,
    /// true ⇒ bytes hold `(w − 128)` as i8; false ⇒ the raw unsigned grid.
    pub signed: bool,
    /// Weight width: 8 (one byte per value) or 4 (two values per byte).
    pub bits: u32,
    /// `panels · NR · k_padded · bits / 8` bytes in the layout above.
    pub data: Vec<u8>,
}

impl PackedQMatrix {
    /// Output rows per panel (microkernel register-block height).
    pub const NR: usize = 4;
    /// K-interleave unit in bytes (one 128-bit lane of input).
    pub const K_CHUNK: usize = 16;
    /// K-interleave unit in *values* for 4-bit panels (32 values = 16 bytes).
    pub const K_CHUNK_I4: usize = 2 * Self::K_CHUNK;

    /// Pack a PerMatrix-quantized matrix (one-time conversion).
    pub fn pack(m: &QMatrix) -> Self {
        let (out_dim, in_dim) = (m.out_dim, m.in_dim);
        let signed = cfg!(target_arch = "x86_64");
        let k_padded = in_dim.div_ceil(Self::K_CHUNK) * Self::K_CHUNK;
        let panels = out_dim.div_ceil(Self::NR);
        let mut data = vec![0u8; panels * Self::NR * k_padded];
        for p in 0..panels {
            let base = p * Self::NR * k_padded;
            for kb in (0..k_padded).step_by(Self::K_CHUNK) {
                for r in 0..Self::NR {
                    let o = p * Self::NR + r;
                    if o >= out_dim {
                        continue; // remainder rows stay zero
                    }
                    let k_end = in_dim.min(kb + Self::K_CHUNK);
                    if k_end <= kb {
                        continue; // K tail stays zero
                    }
                    let dst = base + kb * Self::NR + r * Self::K_CHUNK;
                    let src = &m.data[o * in_dim + kb..o * in_dim + k_end];
                    for (d, &w) in data[dst..dst + (k_end - kb)].iter_mut().zip(src) {
                        *d = if signed { w ^ 0x80 } else { w };
                    }
                }
            }
        }
        PackedQMatrix { out_dim, in_dim, k_padded, panels, signed, bits: 8, data }
    }

    /// Pack an int4 matrix (values on `[0, 15]`, one per byte in
    /// [`QMatrix::data`]) into the nibble layout documented above.
    pub fn pack_i4(m: &QMatrix) -> Self {
        let (out_dim, in_dim) = (m.out_dim, m.in_dim);
        let k_padded = in_dim.div_ceil(Self::K_CHUNK_I4) * Self::K_CHUNK_I4;
        let panels = out_dim.div_ceil(Self::NR);
        let mut data = vec![0u8; panels * Self::NR * k_padded / 2];
        for p in 0..panels {
            let base = p * Self::NR * k_padded / 2;
            for kb in (0..k_padded).step_by(Self::K_CHUNK_I4) {
                for r in 0..Self::NR {
                    let o = p * Self::NR + r;
                    if o >= out_dim {
                        continue; // remainder rows stay zero
                    }
                    let dst = base + (kb / 2) * Self::NR + r * Self::K_CHUNK;
                    for j in 0..Self::K_CHUNK {
                        let at = |k: usize| -> u8 {
                            if k < in_dim {
                                let w = m.data[o * in_dim + k];
                                debug_assert!(w <= 15, "int4 grid value {w} out of range");
                                w
                            } else {
                                0 // K tail stays zero
                            }
                        };
                        data[dst + j] = at(kb + j) | (at(kb + Self::K_CHUNK + j) << 4);
                    }
                }
            }
        }
        PackedQMatrix { out_dim, in_dim, k_padded, panels, signed: false, bits: 4, data }
    }

    /// The integer the GEMM must add back per output as `w_offset · Σx`
    /// to recover the true u8 dot from a packed (possibly shifted) dot.
    #[inline]
    pub fn w_offset(&self) -> i64 {
        if self.signed {
            128
        } else {
            0
        }
    }

    /// One panel's byte stride (`NR · k_padded` for u8, half that for i4).
    #[inline]
    pub fn panel_stride(&self) -> usize {
        Self::NR * self.k_padded * self.bits as usize / 8
    }

    /// One panel's bytes.
    #[inline]
    pub fn panel(&self, p: usize) -> &[u8] {
        let stride = self.panel_stride();
        &self.data[p * stride..(p + 1) * stride]
    }

    /// Bytes held by the packed mirror.
    pub fn storage_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Transpose a math-layout `[in, out]` matrix into `[out, in]`.
fn transpose_math(w: &[f32], in_dim: usize, out_dim: usize) -> Vec<f32> {
    assert_eq!(w.len(), in_dim * out_dim);
    let mut t = vec![0f32; w.len()];
    for i in 0..in_dim {
        for o in 0..out_dim {
            t[o * in_dim + i] = w[i * out_dim + o];
        }
    }
    t
}

impl QMatrix {
    /// Quantize a float matrix given in **math layout** `[in, out]`
    /// row-major (the .qam / numpy layout), transposing into `[out, in]`.
    pub fn from_f32_math_layout(
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        granularity: Granularity,
    ) -> Self {
        let t = transpose_math(w, in_dim, out_dim);
        Self::from_f32_transposed(&t, in_dim, out_dim, granularity)
    }

    /// Quantize a **math layout** `[in, out]` float matrix under an
    /// in-situ requantization scheme (see [`QuantScheme`]).
    pub fn from_f32_math_layout_scheme(
        w: &[f32],
        in_dim: usize,
        out_dim: usize,
        scheme: QuantScheme,
    ) -> Self {
        let t = transpose_math(w, in_dim, out_dim);
        Self::from_f32_transposed_scheme(&t, in_dim, out_dim, scheme)
    }

    /// Quantize an already-transposed `[out, in]` float matrix under an
    /// in-situ requantization scheme.  All three schemes build a packed
    /// serving mirror; `PerMatrixU8` is byte-identical to
    /// [`QMatrix::from_f32_transposed`] at [`Granularity::PerMatrix`].
    pub fn from_f32_transposed_scheme(
        t: &[f32],
        in_dim: usize,
        out_dim: usize,
        scheme: QuantScheme,
    ) -> Self {
        let (granularity, pack) = match scheme {
            QuantScheme::PerMatrixU8 => (Granularity::PerMatrix, PackMode::U8),
            QuantScheme::PerChannelU8 => (Granularity::PerRow, PackMode::U8),
            QuantScheme::PerChannelI4 => (Granularity::PerRow, PackMode::I4),
        };
        Self::build(t, in_dim, out_dim, granularity, scheme.weight_scale(), pack)
    }

    /// Quantize from an already-transposed `[out, in]` float matrix.
    pub fn from_f32_transposed(
        t: &[f32],
        in_dim: usize,
        out_dim: usize,
        granularity: Granularity,
    ) -> Self {
        Self::from_f32_transposed_scaled(t, in_dim, out_dim, granularity, SCALE)
    }

    /// As [`from_f32_transposed`] with an explicit scale `S = 2^bits − 1`
    /// (E5 bit-width ablation; storage stays u8).
    pub fn from_f32_transposed_scaled(
        t: &[f32],
        in_dim: usize,
        out_dim: usize,
        granularity: Granularity,
        scale: f32,
    ) -> Self {
        // Historical packing policy: the seed scheme (PerMatrix) packs,
        // the ablation granularities don't.  Scheme-built matrices pack
        // per-row grids too — see `from_f32_transposed_scheme`.
        let pack =
            if granularity == Granularity::PerMatrix { PackMode::U8 } else { PackMode::None };
        Self::build(t, in_dim, out_dim, granularity, scale, pack)
    }

    fn build(
        t: &[f32],
        in_dim: usize,
        out_dim: usize,
        granularity: Granularity,
        scale: f32,
        pack: PackMode,
    ) -> Self {
        assert_eq!(t.len(), in_dim * out_dim);
        let mut data = vec![0u8; t.len()];
        let params = match granularity {
            Granularity::PerMatrix => {
                let p = QuantParams::from_slice_scaled(t, scale);
                p.quantize_slice(t, &mut data);
                vec![p]
            }
            Granularity::PerRow => (0..out_dim)
                .map(|o| {
                    let row = &t[o * in_dim..(o + 1) * in_dim];
                    let p = QuantParams::from_slice_scaled(row, scale);
                    p.quantize_slice(row, &mut data[o * in_dim..(o + 1) * in_dim]);
                    p
                })
                .collect(),
            Granularity::SubBlock { size } => {
                let blocks_r = out_dim.div_ceil(size);
                let blocks_c = in_dim.div_ceil(size);
                let mut ps = Vec::with_capacity(blocks_r * blocks_c);
                for br in 0..blocks_r {
                    for bc in 0..blocks_c {
                        let r0 = br * size;
                        let r1 = (r0 + size).min(out_dim);
                        let c0 = bc * size;
                        let c1 = (c0 + size).min(in_dim);
                        let mut vals = Vec::with_capacity((r1 - r0) * (c1 - c0));
                        for r in r0..r1 {
                            vals.extend_from_slice(&t[r * in_dim + c0..r * in_dim + c1]);
                        }
                        let p = QuantParams::from_slice_scaled(&vals, scale);
                        for r in r0..r1 {
                            for c in c0..c1 {
                                data[r * in_dim + c] = p.quantize(t[r * in_dim + c]);
                            }
                        }
                        ps.push(p);
                    }
                }
                ps
            }
        };
        let row_sums = (0..out_dim)
            .map(|o| {
                data[o * in_dim..(o + 1) * in_dim]
                    .iter()
                    .map(|&v| v as i32)
                    .sum()
            })
            .collect();
        let inv_q = params.iter().map(|p| 1.0 / p.q as f64).collect();
        let mut m =
            QMatrix { out_dim, in_dim, granularity, data, params, inv_q, row_sums, packed: None };
        m.packed = match pack {
            PackMode::None => None,
            PackMode::U8 => Some(Box::new(PackedQMatrix::pack(&m))),
            PackMode::I4 => Some(Box::new(PackedQMatrix::pack_i4(&m))),
        };
        m
    }

    /// Build directly from pre-quantized V' bytes (as stored in .qam files;
    /// math layout `[in, out]`) with explicit params — no re-quantization,
    /// so the rust engine computes on exactly the trained/stored grid.
    pub fn from_stored(
        vq: &[u8],
        in_dim: usize,
        out_dim: usize,
        params: QuantParams,
    ) -> Self {
        assert_eq!(vq.len(), in_dim * out_dim);
        let mut data = vec![0u8; vq.len()];
        for i in 0..in_dim {
            for o in 0..out_dim {
                data[o * in_dim + i] = vq[i * out_dim + o];
            }
        }
        let row_sums = (0..out_dim)
            .map(|o| {
                data[o * in_dim..(o + 1) * in_dim]
                    .iter()
                    .map(|&v| v as i32)
                    .sum()
            })
            .collect();
        let mut m = QMatrix {
            out_dim,
            in_dim,
            granularity: Granularity::PerMatrix,
            data,
            params: vec![params],
            inv_q: vec![1.0 / params.q as f64],
            row_sums,
            packed: None,
        };
        m.packed = Some(Box::new(PackedQMatrix::pack(&m)));
        m
    }

    /// Recover to float, **math layout** `[in, out]` (for cross-checks).
    pub fn recover_math_layout(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.data.len()];
        for o in 0..self.out_dim {
            for i in 0..self.in_dim {
                let p = self.param_for(o, i);
                out[i * self.out_dim + o] = p.recover(self.data[o * self.in_dim + i]);
            }
        }
        out
    }

    /// Params governing element (out_row, in_col).
    #[inline]
    pub fn param_for(&self, o: usize, i: usize) -> &QuantParams {
        match self.granularity {
            Granularity::PerMatrix => &self.params[0],
            Granularity::PerRow => &self.params[o],
            Granularity::SubBlock { size } => {
                let blocks_c = self.in_dim.div_ceil(size);
                &self.params[(o / size) * blocks_c + i / size]
            }
        }
    }

    /// Weight-storage bytes (the paper's 4× memory claim: u8 data + params).
    /// The packed serving mirror is reported separately via
    /// [`QMatrix::packed_bytes`] — it is a derived runtime artifact, not
    /// part of the serialized model.
    pub fn storage_bytes(&self) -> usize {
        self.data.len()
            + self.params.len() * std::mem::size_of::<QuantParams>()
            + self.row_sums.len() * 4
    }

    /// Bytes held by the packed-panel serving mirror (0 if unpacked).
    pub fn packed_bytes(&self) -> usize {
        self.packed.as_ref().map_or(0, |p| p.storage_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    fn max_abs_err(w: &[f32], r: &[f32]) -> f32 {
        w.iter().zip(r).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn per_matrix_roundtrip_within_half_step() {
        forall("qmatrix roundtrip", 50, 0xAB, |g: &mut Gen| {
            let in_dim = g.usize_in(1, 40);
            let out_dim = g.usize_in(1, 40);
            let w = g.vec_normal(in_dim * out_dim, 0.5);
            let m = QMatrix::from_f32_math_layout(&w, in_dim, out_dim, Granularity::PerMatrix);
            let r = m.recover_math_layout();
            let step = m.params[0].half_step();
            assert!(max_abs_err(&w, &r) <= step * 1.0001);
        });
    }

    #[test]
    fn finer_granularity_reduces_error() {
        let mut g = Gen::new(77);
        // Heterogeneous rows: one row has 10× the magnitude of the others,
        // which is exactly where per-row granularity wins.
        let (in_dim, out_dim) = (64, 16);
        let mut w = g.vec_normal(in_dim * out_dim, 0.1);
        for i in 0..in_dim {
            w[i * out_dim] *= 10.0;
        }
        let errs: Vec<f32> = [
            Granularity::PerMatrix,
            Granularity::SubBlock { size: 16 },
            Granularity::PerRow,
        ]
        .iter()
        .map(|&gr| {
            let m = QMatrix::from_f32_math_layout(&w, in_dim, out_dim, gr);
            let r = m.recover_math_layout();
            let sum: f32 = w.iter().zip(&r).map(|(a, b)| (a - b) * (a - b)).sum();
            (sum / w.len() as f32).sqrt()
        })
        .collect();
        assert!(errs[2] < errs[1] && errs[1] <= errs[0] * 1.05, "{errs:?}");
    }

    #[test]
    fn stored_roundtrip_is_exact() {
        // from_stored must preserve the exact V' grid (no re-quantization).
        let mut g = Gen::new(3);
        let (in_dim, out_dim) = (10, 6);
        let w = g.vec_normal(in_dim * out_dim, 1.0);
        let m1 = QMatrix::from_f32_math_layout(&w, in_dim, out_dim, Granularity::PerMatrix);
        // Serialize to math-layout V' (as export.py does)
        let mut vq_math = vec![0u8; w.len()];
        for o in 0..out_dim {
            for i in 0..in_dim {
                vq_math[i * out_dim + o] = m1.data[o * in_dim + i];
            }
        }
        let m2 = QMatrix::from_stored(&vq_math, in_dim, out_dim, m1.params[0]);
        assert_eq!(m1.data, m2.data);
        assert_eq!(m1.row_sums, m2.row_sums);
    }

    #[test]
    fn storage_is_about_4x_smaller() {
        let w = vec![0.5f32; 256 * 256];
        let m = QMatrix::from_f32_math_layout(&w, 256, 256, Granularity::PerMatrix);
        let f32_bytes = w.len() * 4;
        assert!((m.storage_bytes() as f64) < f32_bytes as f64 / 3.5);
    }

    /// Read one packed element back through the documented panel layout.
    fn packed_at(p: &PackedQMatrix, o: usize, k: usize) -> u8 {
        let panel = o / PackedQMatrix::NR;
        let r = o % PackedQMatrix::NR;
        let kb = (k / PackedQMatrix::K_CHUNK) * PackedQMatrix::K_CHUNK;
        let base = panel * PackedQMatrix::NR * p.k_padded;
        p.data[base + kb * PackedQMatrix::NR + r * PackedQMatrix::K_CHUNK + (k - kb)]
    }

    #[test]
    fn packed_layout_roundtrips_every_element() {
        forall("packed layout", 60, 0x9AC4, |g: &mut Gen| {
            let in_dim = g.usize_in(0, 70);
            let out_dim = g.usize_in(0, 30);
            let w = g.vec_normal(in_dim * out_dim, 0.5);
            let m = QMatrix::from_f32_math_layout(&w, in_dim, out_dim, Granularity::PerMatrix);
            let p = m.packed.as_deref().expect("PerMatrix must pack");
            assert_eq!(p.k_padded % PackedQMatrix::K_CHUNK, 0);
            assert!(p.k_padded >= in_dim && p.k_padded < in_dim + PackedQMatrix::K_CHUNK);
            assert_eq!(p.panels, out_dim.div_ceil(PackedQMatrix::NR));
            assert_eq!(p.data.len(), p.panels * PackedQMatrix::NR * p.k_padded);
            for o in 0..out_dim {
                for k in 0..in_dim {
                    let want = if p.signed {
                        m.data[o * in_dim + k] ^ 0x80
                    } else {
                        m.data[o * in_dim + k]
                    };
                    assert_eq!(packed_at(p, o, k), want, "o={o} k={k}");
                }
                // K tail padding is zero
                for k in in_dim..p.k_padded {
                    assert_eq!(packed_at(p, o, k), 0, "tail o={o} k={k}");
                }
            }
            // panel-remainder rows are zero
            for o in out_dim..p.panels * PackedQMatrix::NR {
                for k in 0..p.k_padded {
                    assert_eq!(packed_at(p, o, k), 0, "pad row o={o} k={k}");
                }
            }
        });
    }

    #[test]
    fn packing_policy_per_granularity() {
        let mut g = Gen::new(21);
        let w = g.vec_normal(20 * 10, 0.5);
        let pm = QMatrix::from_f32_math_layout(&w, 20, 10, Granularity::PerMatrix);
        assert!(pm.packed.is_some() && pm.packed_bytes() > 0);
        let pr = QMatrix::from_f32_math_layout(&w, 20, 10, Granularity::PerRow);
        assert!(pr.packed.is_none() && pr.packed_bytes() == 0);
        let sb = QMatrix::from_f32_math_layout(&w, 20, 10, Granularity::SubBlock { size: 4 });
        assert!(sb.packed.is_none());
    }

    /// Read one int4 packed element back through the documented nibble
    /// layout.
    fn packed_i4_at(p: &PackedQMatrix, o: usize, k: usize) -> u8 {
        let panel = o / PackedQMatrix::NR;
        let r = o % PackedQMatrix::NR;
        let kb = (k / PackedQMatrix::K_CHUNK_I4) * PackedQMatrix::K_CHUNK_I4;
        let base = panel * PackedQMatrix::NR * p.k_padded / 2;
        let off = k - kb;
        let b = p.data
            [base + (kb / 2) * PackedQMatrix::NR + r * PackedQMatrix::K_CHUNK + off % PackedQMatrix::K_CHUNK];
        if off < PackedQMatrix::K_CHUNK {
            b & 0x0F
        } else {
            b >> 4
        }
    }

    #[test]
    fn i4_pack_unpack_roundtrips_every_element() {
        forall("i4 packed layout", 60, 0x14AC, |g: &mut Gen| {
            let in_dim = g.usize_in(0, 70);
            let out_dim = g.usize_in(0, 30);
            let w = g.vec_normal(in_dim * out_dim, 0.5);
            let m = QMatrix::from_f32_math_layout_scheme(
                &w, in_dim, out_dim, QuantScheme::PerChannelI4,
            );
            assert_eq!(m.granularity, Granularity::PerRow);
            assert!(m.data.iter().all(|&v| v <= 15), "int4 grid escaped [0,15]");
            let p = m.packed.as_deref().expect("i4 scheme must pack");
            assert_eq!(p.bits, 4);
            assert!(!p.signed, "int4 panels are unsigned on every arch");
            assert_eq!(p.k_padded % PackedQMatrix::K_CHUNK_I4, 0);
            assert!(p.k_padded >= in_dim && p.k_padded < in_dim + PackedQMatrix::K_CHUNK_I4);
            assert_eq!(p.panels, out_dim.div_ceil(PackedQMatrix::NR));
            assert_eq!(p.data.len(), p.panels * PackedQMatrix::NR * p.k_padded / 2);
            assert_eq!(p.panel_stride(), PackedQMatrix::NR * p.k_padded / 2);
            for o in 0..out_dim {
                for k in 0..in_dim {
                    assert_eq!(packed_i4_at(p, o, k), m.data[o * in_dim + k], "o={o} k={k}");
                }
                for k in in_dim..p.k_padded {
                    assert_eq!(packed_i4_at(p, o, k), 0, "tail o={o} k={k}");
                }
            }
            for o in out_dim..p.panels * PackedQMatrix::NR {
                for k in 0..p.k_padded {
                    assert_eq!(packed_i4_at(p, o, k), 0, "pad row o={o} k={k}");
                }
            }
        });
    }

    #[test]
    fn scheme_constructors_build_expected_shapes() {
        let mut g = Gen::new(0x5C4E);
        let (in_dim, out_dim) = (37, 11);
        let w = g.vec_normal(in_dim * out_dim, 0.6);
        // PerMatrixU8 is byte-identical to the seed constructor.
        let seed = QMatrix::from_f32_math_layout(&w, in_dim, out_dim, Granularity::PerMatrix);
        let pm = QMatrix::from_f32_math_layout_scheme(&w, in_dim, out_dim, QuantScheme::PerMatrixU8);
        assert_eq!(seed.data, pm.data);
        assert_eq!(seed.row_sums, pm.row_sums);
        assert_eq!(seed.packed.as_ref().unwrap().data, pm.packed.as_ref().unwrap().data);
        // PerChannelU8: per-row params on the u8 grid, packed mirror present.
        let pc = QMatrix::from_f32_math_layout_scheme(&w, in_dim, out_dim, QuantScheme::PerChannelU8);
        assert_eq!(pc.granularity, Granularity::PerRow);
        assert_eq!(pc.params.len(), out_dim);
        assert_eq!(pc.inv_q.len(), out_dim);
        let pk = pc.packed.as_deref().expect("per-channel-u8 packs");
        assert_eq!(pk.bits, 8);
        assert_eq!(pk.signed, cfg!(target_arch = "x86_64"));
        // The per-row grid matches the plain PerRow quantization.
        let pr = QMatrix::from_f32_math_layout(&w, in_dim, out_dim, Granularity::PerRow);
        assert_eq!(pc.data, pr.data);
        // The i4 mirror halves the packed bytes of the u8 mirror (same
        // panel geometry, two values per byte; padding differs by ≤16
        // columns).
        let i4 = QMatrix::from_f32_math_layout_scheme(&w, in_dim, out_dim, QuantScheme::PerChannelI4);
        let i4p = i4.packed.as_deref().unwrap();
        assert!(i4p.storage_bytes() <= pk.storage_bytes());
    }

    #[test]
    fn row_sums_match_data() {
        let mut g = Gen::new(11);
        let m = QMatrix::from_f32_math_layout(
            &g.vec_normal(12 * 5, 1.0), 12, 5, Granularity::PerMatrix,
        );
        for o in 0..5 {
            let s: i32 = m.data[o * 12..(o + 1) * 12].iter().map(|&v| v as i32).sum();
            assert_eq!(s, m.row_sums[o]);
        }
    }
}
