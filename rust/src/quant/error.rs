//! Quantization error analysis (experiments E2 and E3).
//!
//! The paper (§3) distinguishes **precision loss** (unavoidable, zero-mean,
//! small variance impact) from **bias error** (avoidable via the
//! rounding-consistent zero point of eqs. 2–3).  These helpers measure both
//! for the consistent and the naive scheme, plus the granularity sweep.

use crate::quant::qmatrix::{Granularity, QMatrix};
use crate::quant::scheme::{NaiveQuantParams, QuantParams, QuantScheme};

/// First/second moments of the quantization error `recover(quantize(x)) − x`.
#[derive(Clone, Copy, Debug)]
pub struct ErrorStats {
    pub bias: f64,
    pub rms: f64,
    pub max_abs: f64,
}

pub fn stats_consistent(v: &[f32]) -> ErrorStats {
    let p = QuantParams::from_slice(v);
    collect(v.iter().map(|&x| (p.recover(p.quantize(x)) - x) as f64))
}

pub fn stats_naive(v: &[f32]) -> ErrorStats {
    let p = NaiveQuantParams::from_slice(v);
    collect(v.iter().map(|&x| (p.recover(p.quantize(x)) - x) as f64))
}

fn collect(errs: impl Iterator<Item = f64>) -> ErrorStats {
    let mut n = 0usize;
    let (mut sum, mut sq, mut mx) = (0.0, 0.0, 0.0f64);
    for e in errs {
        n += 1;
        sum += e;
        sq += e * e;
        mx = mx.max(e.abs());
    }
    let n = n.max(1) as f64;
    ErrorStats { bias: sum / n, rms: (sq / n).sqrt(), max_abs: mx }
}

/// Variance preservation check (paper §3 cites [22]: the variance of V and
/// V' differs only slightly).  Returns (var_in, var_recovered).
pub fn variance_ratio(v: &[f32]) -> (f64, f64) {
    let p = QuantParams::from_slice(v);
    let mean = |s: &mut dyn Iterator<Item = f64>| -> (f64, f64) {
        let xs: Vec<f64> = s.collect();
        let m = xs.iter().sum::<f64>() / xs.len().max(1) as f64;
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len().max(1) as f64;
        (m, var)
    };
    let (_, var_in) = mean(&mut v.iter().map(|&x| x as f64));
    let (_, var_out) = mean(&mut v.iter().map(|&x| p.recover(p.quantize(x)) as f64));
    (var_in, var_out)
}

/// RMS weight-matrix reconstruction error per granularity (E3).
///
/// The per-row entry is built through the real [`QuantScheme::PerChannelU8`]
/// serving constructor (not an ad-hoc per-row split), so the sweep measures
/// exactly the matrix `--isq per-channel-u8` would execute; the trailing
/// per-channel-i4 row prices the 4-bit weight grid the same way.
pub fn granularity_sweep(w: &[f32], in_dim: usize, out_dim: usize) -> Vec<(String, f64, usize)> {
    let rms_of = |m: &QMatrix| {
        let r = m.recover_math_layout();
        (w.iter().zip(&r).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / w.len() as f64)
            .sqrt()
    };
    let mut rows = Vec::with_capacity(5);
    for (name, g) in [
        ("per-tensor(matrix)", Granularity::PerMatrix),
        ("per-row", Granularity::PerRow),
        ("block-64", Granularity::SubBlock { size: 64 }),
        ("block-16", Granularity::SubBlock { size: 16 }),
    ] {
        let m = match g {
            Granularity::PerRow => {
                QMatrix::from_f32_math_layout_scheme(w, in_dim, out_dim, QuantScheme::PerChannelU8)
            }
            g => QMatrix::from_f32_math_layout(w, in_dim, out_dim, g),
        };
        rows.push((name.to_string(), rms_of(&m), m.storage_bytes()));
    }
    let i4 = QMatrix::from_f32_math_layout_scheme(w, in_dim, out_dim, QuantScheme::PerChannelI4);
    // The byte-grid `data` is scaffolding for i4 — what serves (and what
    // storage should price) is the nibble-packed panel mirror.
    let i4_bytes = i4.packed_bytes()
        + i4.params.len() * std::mem::size_of::<QuantParams>()
        + i4.row_sums.len() * 4;
    rows.push(("per-channel-i4".to_string(), rms_of(&i4), i4_bytes));
    rows
}

/// Bias accumulation in a dot product of length `k` (why eq. 2/3 matter):
/// returns (consistent_err, naive_err) of `Σ q(x)·q(w)` vs `Σ x·w`.
pub fn dot_bias_experiment(x: &[f32], w: &[f32]) -> (f64, f64) {
    let exact: f64 = x.iter().zip(w).map(|(&a, &b)| a as f64 * b as f64).sum();
    let px = QuantParams::from_slice(x);
    let pw = QuantParams::from_slice(w);
    let cons: f64 = x
        .iter()
        .zip(w)
        .map(|(&a, &b)| px.recover(px.quantize(a)) as f64 * pw.recover(pw.quantize(b)) as f64)
        .sum();
    let nx = NaiveQuantParams::from_slice(x);
    let nw = NaiveQuantParams::from_slice(w);
    let naive: f64 = x
        .iter()
        .zip(w)
        .map(|(&a, &b)| nx.recover(nx.quantize(a)) as f64 * nw.recover(nw.quantize(b)) as f64)
        .sum();
    ((cons - exact).abs(), (naive - exact).abs())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn consistent_bias_much_smaller_than_naive() {
        forall("bias e2", 30, 0xE2, |g: &mut Gen| {
            let n = g.usize_in(512, 4096);
            let v = g.vec_normal(n, 1.0);
            let c = stats_consistent(&v);
            let na = stats_naive(&v);
            // consistent: |bias| ≪ rms; naive: bias comparable to step/2.
            assert!(c.bias.abs() < 0.2 * c.rms + 1e-6, "c={c:?}");
            assert!(na.bias.abs() > 2.0 * c.bias.abs().max(1e-6), "c={c:?} n={na:?}");
        });
    }

    #[test]
    fn variance_nearly_preserved() {
        let mut g = Gen::new(4);
        let v = g.vec_normal(8192, 0.7);
        let (vi, vo) = variance_ratio(&v);
        assert!((vi / vo - 1.0).abs() < 0.01, "{vi} vs {vo}");
    }

    #[test]
    fn granularity_sweep_monotone_error() {
        let mut g = Gen::new(5);
        let w = g.vec_normal(128 * 96, 0.4);
        let sweep = granularity_sweep(&w, 128, 96);
        let per_matrix = sweep[0].1;
        let per_row = sweep[1].1;
        assert!(per_row <= per_matrix * 1.01, "{sweep:?}");
        // storage grows with granularity
        assert!(sweep[1].2 >= sweep[0].2);
        // the trailing i4 row: coarser grid (more error), packed nibbles
        // (less storage than the per-row u8 grid)
        let (ref name, i4_rms, i4_bytes) = sweep[4];
        assert_eq!(name, "per-channel-i4");
        assert!(i4_rms > per_row, "{sweep:?}");
        assert!(i4_bytes < sweep[1].2, "{sweep:?}");
    }

    #[test]
    fn dot_bias_consistent_wins_on_average() {
        let mut g = Gen::new(6);
        let (mut wins, n) = (0, 40);
        for _ in 0..n {
            let k = g.usize_in(64, 512);
            let x = g.vec_normal(k, 1.0);
            let w = g.vec_normal(k, 0.5);
            let (c, na) = dot_bias_experiment(&x, &w);
            if c <= na {
                wins += 1;
            }
        }
        assert!(wins * 10 >= n * 6, "consistent won only {wins}/{n}");
    }
}
