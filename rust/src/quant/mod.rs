//! The paper's §3 quantization scheme and its integer execution kernels.
//!
//! - [`scheme`] — eqs. (1)–(3): the uniform linear quantizer with the
//!   rounding-consistent zero point that cancels bias error, plus the
//!   deliberately *inconsistent* naive variant used by the E2 ablation.
//! - [`qmatrix`] — quantized weight matrices at the paper's granularity
//!   choices (per-matrix, per-row, sub-block), plus the packed-panel
//!   serving mirror ([`PackedQMatrix`]) built once at load.
//! - [`gemm`] — the hot path: f32 GEMM baseline and the u8×u8→i32 integer
//!   kernel ladder (scalar/unrolled/AVX2 row-dot rungs and the
//!   packed-panel `madd_epi16` / AVX-512-VNNI `vpdpbusd` / NEON `dot`
//!   microkernels with runtime dispatch and worker-pool panel
//!   parallelism), plus the [`gemm::QActRows`] activation-quantization
//!   cache.
//! - [`elementwise`] — the vectorized elementwise ladder: the fused
//!   SIMD LSTM cell update (polynomial sigmoid/tanh with a scalar
//!   reference every rung matches bit-for-bit) and the SIMD min/max +
//!   quantize scan behind input quantization.
//! - [`error`] — precision/bias error measurement (E2/E3 experiments).

pub mod elementwise;
pub mod error;
pub mod gemm;
pub mod qmatrix;
pub mod scheme;

pub use elementwise::EwKernel;
pub use qmatrix::{Granularity, PackedQMatrix, QMatrix};
pub use scheme::{QuantParams, QuantScheme, SCALE, SCALE_I4};
