//! The paper's §3 quantization scheme and its integer execution kernels.
//!
//! - [`scheme`] — eqs. (1)–(3): the uniform linear quantizer with the
//!   rounding-consistent zero point that cancels bias error, plus the
//!   deliberately *inconsistent* naive variant used by the E2 ablation.
//! - [`qmatrix`] — quantized weight matrices at the paper's granularity
//!   choices (per-matrix, per-row, sub-block).
//! - [`gemm`] — the hot path: f32 GEMM baseline and u8×u8→i32 integer
//!   GEMM (scalar, blocked, and AVX2 `maddubs` kernels).
//! - [`error`] — precision/bias error measurement (E2/E3 experiments).

pub mod error;
pub mod gemm;
pub mod qmatrix;
pub mod scheme;

pub use qmatrix::{Granularity, QMatrix};
pub use scheme::{QuantParams, SCALE};
