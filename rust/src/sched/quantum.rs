//! Time-sliced preemption policy: tick quanta + victim selection.
//!
//! Model of the world at one tick boundary: some streams **hold** arena
//! lanes and would step this tick; some ready streams are **waiting**
//! lane-less.  Every holder carries `quantum_used`, the number of ticks it
//! has stepped since it last (re)acquired its lane.  A waiter may take a
//! holder's lane when the holder is *preemptible* for that waiter:
//!
//! - the holder has consumed its quantum (`quantum_used ≥ quantum_ticks`),
//!   or
//! - the holder's QoS class is strictly lower than the waiter's
//!   ([`Priority::rank`]), so interactive traffic does not queue behind
//!   bulk holders mid-quantum.
//!
//! Among preemptible holders the victim is the lowest priority class
//! first, then the most consumed quantum, then the lowest stream id
//! (determinism).  Preemption happens at a tick boundary through the
//! backend's exact `save_lane`/`load_lane` round trip, so a preempted
//! stream's outputs are bit-identical to an unpreempted run — the policy
//! decides *when* frames are computed, never *what* they compute.
//!
//! **Bounded wait.**  A holder that never goes idle steps every tick, so
//! its `quantum_used` reaches the quantum within `quantum_ticks` ticks of
//! a waiter arriving; the waiter therefore acquires a lane within at most
//! `quantum_ticks` ticks (property `waiter_admitted_within_one_quantum`
//! below simulates exactly the saturation scenario that used to starve:
//! every lane held by a never-idle stream).  In a weighted multi-model
//! fleet ([`crate::sched::weights`]) a holder consumes quantum only on
//! the lane-steps the budget grants it, so the bound is counted in *that
//! holder's granted steps*: weights (and lane counts) dilate the
//! wall-clock bound by the model's share, they never void it — the DRR's
//! own progress property guarantees granted steps keep coming.
//!
//! Pure decision logic — no clocks, no locks, no arenas:
//!
//! ```
//! use quantasr::runtime::backend::LaneTag;
//! use quantasr::sched::{HolderView, Priority, QuantumPolicy};
//!
//! let policy = QuantumPolicy { quantum_ticks: 4 };
//! let holders = [
//!     // Mid-quantum interactive holder: protected from same-class waiters.
//!     HolderView {
//!         stream: 1,
//!         priority: Priority::Interactive,
//!         quantum_used: 2,
//!         tag: LaneTag { model: 0, lane: 0 },
//!     },
//!     // Bulk holder: preemptible by an interactive waiter immediately.
//!     HolderView {
//!         stream: 2,
//!         priority: Priority::Bulk,
//!         quantum_used: 0,
//!         tag: LaneTag { model: 0, lane: 1 },
//!     },
//! ];
//! assert_eq!(policy.select_victim(&holders, Priority::Interactive), Some(1));
//! assert_eq!(policy.select_victim(&holders, Priority::Bulk), None);
//! ```

use crate::runtime::backend::LaneTag;
use crate::sched::Priority;

/// `quantum_ticks` sentinel requesting runtime auto-tuning: the engine's
/// AM worker replaces it with ~[`QuantumPolicy::AUTO_SLO_SECS`] worth of
/// *measured* flush ticks at startup, so the preemption rotation tracks a
/// wall-clock SLO regardless of machine speed or batch shape.  A policy
/// used standalone treats the sentinel as 1 (see
/// [`QuantumPolicy::quantum`]).
pub const AUTO_QUANTUM: u32 = 0;

/// The time-slice configuration for lane preemption.
#[derive(Clone, Copy, Debug)]
pub struct QuantumPolicy {
    /// Ticks an admitted stream is guaranteed to step before it becomes
    /// preemptible by an equal-or-lower-priority waiter.  Floored at 1
    /// when used directly (a zero quantum would let a stream be preempted
    /// before it ever stepped); [`AUTO_QUANTUM`] (0) asks the engine to
    /// derive the value from the measured tick rate.  Overridable via
    /// `QUANTASR_QUANTUM_TICKS` (0 = explicit auto).
    pub quantum_ticks: u32,
}

impl Default for QuantumPolicy {
    /// Auto by default: the engine measures its flush-tick interval at
    /// startup and sets the quantum to ~[`QuantumPolicy::AUTO_SLO_SECS`]
    /// of wall clock (the old fixed default of 25 ticks assumed the
    /// 20 ms frame rate; a fast simulator tick made that rotate lanes
    /// thousands of times a second).  `QUANTASR_QUANTUM_TICKS` pins a
    /// fixed tick count instead.
    fn default() -> Self {
        QuantumPolicy { quantum_ticks: env_quantum().unwrap_or(AUTO_QUANTUM) }
    }
}

/// `QUANTASR_QUANTUM_TICKS` override, parsed once per process (`0` =
/// explicit auto-tune).  A malformed value warns and falls back to the
/// built-in default — tuning knobs must never panic a serving process.
fn env_quantum() -> Option<u32> {
    static ONCE: std::sync::OnceLock<Option<u32>> = std::sync::OnceLock::new();
    *ONCE.get_or_init(|| {
        let v = std::env::var("QUANTASR_QUANTUM_TICKS").ok()?;
        match v.trim().parse::<u32>() {
            Ok(n) => Some(n),
            _ => {
                eprintln!(
                    "QUANTASR_QUANTUM_TICKS='{v}' is not a tick count \
                     (u32; 0 = auto); using the built-in default"
                );
                None
            }
        }
    })
}

/// A lane holder as the scheduler sees it at a tick boundary: a stream
/// that owns `tag` and would step this tick.
#[derive(Clone, Copy, Debug)]
pub struct HolderView {
    pub stream: u64,
    pub priority: Priority,
    /// Ticks stepped since the holder last (re)acquired its lane.
    pub quantum_used: u32,
    /// Which model's arena, and which lane row in it.
    pub tag: LaneTag,
}

impl QuantumPolicy {
    /// Wall-clock target between preemption rotations when the quantum is
    /// auto-derived ([`AUTO_QUANTUM`]): the engine sets `quantum_ticks`
    /// to roughly this many seconds of measured flush ticks.
    pub const AUTO_SLO_SECS: f64 = 0.5;

    /// True when the engine should derive the quantum from the measured
    /// tick rate at startup ([`AUTO_QUANTUM`] sentinel).
    pub fn is_auto(&self) -> bool {
        self.quantum_ticks == AUTO_QUANTUM
    }

    /// Effective quantum (the configured value, floored at 1 tick).
    pub fn quantum(&self) -> u32 {
        self.quantum_ticks.max(1)
    }

    /// May `holder` be preempted on behalf of a waiter of class `waiter`?
    pub fn preemptible(&self, holder: &HolderView, waiter: Priority) -> bool {
        holder.quantum_used >= self.quantum() || holder.priority.rank() > waiter.rank()
    }

    /// Pick the preemption victim for one waiter among `holders` (the
    /// streams that would otherwise step this tick): lowest priority
    /// class first, then most consumed quantum, then lowest stream id.
    /// Returns an index into `holders`; `None` when no holder is
    /// preemptible (the waiter keeps waiting — bounded by the quantum).
    pub fn select_victim(&self, holders: &[HolderView], waiter: Priority) -> Option<usize> {
        holders
            .iter()
            .enumerate()
            .filter(|(_, h)| self.preemptible(h, waiter))
            .max_by(|(_, a), (_, b)| {
                a.priority
                    .rank()
                    .cmp(&b.priority.rank())
                    .then(a.quantum_used.cmp(&b.quantum_used))
                    .then(b.stream.cmp(&a.stream))
            })
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    fn h(stream: u64, priority: Priority, quantum_used: u32) -> HolderView {
        let tag = LaneTag { model: 0, lane: stream as usize };
        HolderView { stream, priority, quantum_used, tag }
    }

    fn gen_priority(g: &mut Gen) -> Priority {
        if g.bool() { Priority::Interactive } else { Priority::Bulk }
    }

    #[test]
    fn no_victim_while_everyone_is_mid_quantum() {
        let p = QuantumPolicy { quantum_ticks: 10 };
        let holders = [h(1, Priority::Interactive, 3), h(2, Priority::Interactive, 9)];
        assert_eq!(p.select_victim(&holders, Priority::Interactive), None);
        // ...but a bulk holder yields to an interactive waiter immediately.
        let holders = [h(1, Priority::Interactive, 3), h(2, Priority::Bulk, 0)];
        assert_eq!(p.select_victim(&holders, Priority::Interactive), Some(1));
        // A bulk waiter cannot preempt it mid-quantum.
        assert_eq!(p.select_victim(&holders, Priority::Bulk), None);
    }

    #[test]
    fn exhausted_holder_with_most_quantum_is_picked() {
        let p = QuantumPolicy { quantum_ticks: 4 };
        let holders = [h(1, Priority::Interactive, 4), h(2, Priority::Interactive, 9)];
        assert_eq!(p.select_victim(&holders, Priority::Interactive), Some(1));
        // Class beats quantum: an exhausted bulk holder is preferred over
        // a more-exhausted interactive one.
        let holders = [h(1, Priority::Interactive, 30), h(2, Priority::Bulk, 4)];
        assert_eq!(p.select_victim(&holders, Priority::Interactive), Some(1));
    }

    #[test]
    fn auto_sentinel_is_detected_and_floored() {
        let p = QuantumPolicy { quantum_ticks: AUTO_QUANTUM };
        assert!(p.is_auto());
        assert_eq!(p.quantum(), 1, "standalone use of the sentinel still progresses");
        assert!(!QuantumPolicy { quantum_ticks: 8 }.is_auto());
    }

    #[test]
    fn zero_quantum_is_floored_to_one() {
        let p = QuantumPolicy { quantum_ticks: 0 };
        assert_eq!(p.quantum(), 1);
        // A just-admitted holder (0 ticks stepped) is never preemptible by
        // its own class, even at quantum 0 — guarantees progress.
        let holders = [h(1, Priority::Interactive, 0)];
        assert_eq!(p.select_victim(&holders, Priority::Interactive), None);
        let holders = [h(1, Priority::Interactive, 1)];
        assert_eq!(p.select_victim(&holders, Priority::Interactive), Some(0));
    }

    #[test]
    fn victim_is_always_eligible_and_minimal_class() {
        // Whatever the mix, the selected victim (a) satisfies the
        // preemptibility rule and (b) no eligible holder has a strictly
        // lower scheduling claim (higher class rank) than the victim.
        forall("quantum victim sound", 300, 0x5CED, |g: &mut Gen| {
            let p = QuantumPolicy { quantum_ticks: g.usize_in(1, 8) as u32 };
            let n = g.usize_in(1, 8);
            let holders: Vec<HolderView> = (0..n)
                .map(|i| h(i as u64, gen_priority(g), g.usize_in(0, 12) as u32))
                .collect();
            let waiter = gen_priority(g);
            match p.select_victim(&holders, waiter) {
                None => {
                    for hv in &holders {
                        assert!(!p.preemptible(hv, waiter), "missed eligible victim {hv:?}");
                    }
                }
                Some(i) => {
                    let v = &holders[i];
                    assert!(p.preemptible(v, waiter), "ineligible victim {v:?}");
                    for hv in &holders {
                        if p.preemptible(hv, waiter) {
                            assert!(
                                hv.priority.rank() <= v.priority.rank(),
                                "victim {v:?} outranks eligible {hv:?}"
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn waiter_admitted_within_one_quantum() {
        // The starvation scenario the scheduler exists to fix: every lane
        // held by a never-idle stream.  Simulate ticks (each holder steps,
        // consuming quantum); the waiter must get a lane within
        // quantum_ticks ticks of arriving, for any initial quantum state.
        forall("bounded wait", 200, 0xB0DD, |g: &mut Gen| {
            let p = QuantumPolicy { quantum_ticks: g.usize_in(1, 10) as u32 };
            let lanes = g.usize_in(1, 6);
            let waiter = gen_priority(g);
            let mut holders: Vec<HolderView> = (0..lanes)
                .map(|i| {
                    let used = g.usize_in(0, p.quantum() as usize - 1) as u32;
                    h(i as u64, gen_priority(g), used)
                })
                .collect();
            let mut waited = 0u32;
            loop {
                if let Some(i) = p.select_victim(&holders, waiter) {
                    // The waiter takes the victim's lane with a fresh
                    // quantum; victim re-queues as a waiter.
                    holders[i] = h(100, waiter, 0);
                    break;
                }
                // Never-idle holders all step this tick.
                for hv in holders.iter_mut() {
                    hv.quantum_used += 1;
                }
                waited += 1;
                assert!(
                    waited <= p.quantum(),
                    "waiter starved: {waited} ticks > quantum {}",
                    p.quantum()
                );
            }
            assert!(waited <= p.quantum());
        });
    }
}
