//! The lane-placement scheduler: *which stream steps in which arena lane
//! this tick, across N loaded models* — all policy, no mechanism.
//!
//! Before this module existed, placement policy was ad-hoc logic inside
//! `coordinator::engine`: ready streams that held a lane rode for free,
//! lane-less streams waited for a free lane or evicted an *idle* holder,
//! and a holder that never went idle could starve newcomers forever under
//! full saturation.  The scheduler closes that hole and extends the
//! serving spine to multiple models:
//!
//! - [`quantum`] — **time-sliced preemption**: every admitted stream gets
//!   a tick quantum; once a holder has consumed it and lane-less streams
//!   are waiting, the holder is preempted through the existing
//!   `save_lane`/`load_lane` parking path (bit-identical round trip, see
//!   [`crate::runtime::AmBackend`]), so a newcomer's wait is bounded by
//!   one quantum instead of by the holder's goodwill.  The paper's int8
//!   quantization is what makes this affordable: per-lane recurrent state
//!   is small, so parking a lane is a few cache lines, not a tensor
//!   migration.
//! - [`Priority`] — QoS classes carried on stream admission.  They feed
//!   both preemption victim selection (`Bulk` holders are preempted
//!   before `Interactive` ones) and batch-formation order
//!   ([`crate::coordinator::batcher::schedule_cmp`]).
//! - [`admission`] — a bounded live-stream set with reject-with-reason
//!   backpressure instead of unbounded parked-stream growth.  Admission
//!   validates the target model's lifecycle state ([`ModelStatus`]), so a
//!   draining model refuses new streams while its survivors finish.
//! - [`budget`] — byte-accounted admission: a pure ledger prices every
//!   arena and parked-lane blob against `--mem-budget-bytes`, so model
//!   loads that don't fit are refused with a reason and stream admission
//!   backpressures (`RejectReason::MemoryPressure`) instead of letting
//!   churn grow parked state without bound.
//! - [`registry`] — N loaded models behind one engine: lanes are
//!   addressed by [`crate::runtime::backend::LaneTag`] (model, lane), the
//!   scheduler keeps per-model lane accounting, and one AM worker steps
//!   every model's planned lanes each tick.  The boot-time registry is
//!   the seed of a *dynamic* model table: models can be hot-loaded and
//!   drained out at runtime
//!   ([`crate::coordinator::Engine::load_model`] /
//!   [`crate::coordinator::Engine::unload_model`]).
//! - [`weights`] — deficit-weighted round-robin over a per-tick lane-step
//!   budget: heterogeneous fleets (one hot Interactive model, several
//!   Bulk ones) get tick bandwidth in proportion to configured per-model
//!   weights, with work conservation and bounded per-model wait.
//!
//! Everything here is pure decision logic — no clocks, locks or arenas —
//! so the policies are property-testable in isolation; the engine owns
//! the mechanism (arenas, condvars, worker threads).  The system-level
//! picture (who calls what, in which order, under which lock) is drawn in
//! `docs/ARCHITECTURE.md`.

pub mod admission;
pub mod budget;
pub mod quantum;
pub mod registry;
pub mod weights;

pub use admission::{AdmissionConfig, AdmissionController, ModelStatus, RejectReason};
pub use budget::{BudgetLedger, ModelBytes};
pub use quantum::{HolderView, QuantumPolicy, AUTO_QUANTUM};
pub use registry::ModelRegistry;
pub use weights::{DrrState, ModelParams};

/// QoS class carried on stream admission.
///
/// `Interactive` streams sort first in batch formation and are preempted
/// last; `Bulk` streams fill leftover lanes and are the first preemption
/// victims.  The class never affects numerics — only *when* a stream's
/// frames are computed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive (live dictation): served first, preempted last.
    #[default]
    Interactive,
    /// Throughput traffic (batch transcription): fills leftover capacity.
    Bulk,
}

impl Priority {
    /// Number of distinct QoS classes (sizes rank-indexed tables such as
    /// the priority-aware decode queue,
    /// [`crate::coordinator::batcher::ClassQueue`]).
    pub const NUM_CLASSES: usize = 2;

    /// Scheduling rank: lower ranks are served first and preempted last.
    pub fn rank(self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Bulk => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Bulk => "bulk",
        }
    }

    /// Parse a CLI/config spelling (`"interactive"`, `"bulk"`, or the
    /// wire ranks `"0"`/`"1"`).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" | "0" => Some(Priority::Interactive),
            "bulk" | "1" => Some(Priority::Bulk),
            _ => None,
        }
    }

    /// Wire encoding for the TCP protocol's `'P'` message.
    pub fn to_wire(self) -> u8 {
        self.rank()
    }

    pub fn from_wire(b: u8) -> Option<Priority> {
        match b {
            0 => Some(Priority::Interactive),
            1 => Some(Priority::Bulk),
            _ => None,
        }
    }
}

/// Admission-time options for a new stream (see
/// [`crate::coordinator::Engine::try_open_stream`]).  `Default` is the
/// single-model interactive stream every pre-scheduler caller expects.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamOptions {
    /// Index of the loaded model ([`ModelRegistry`] registration order).
    pub model: usize,
    /// QoS class for preemption and batch-formation order.
    pub priority: Priority,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_ranks_and_wire_roundtrip() {
        assert!(Priority::Interactive.rank() < Priority::Bulk.rank());
        for p in [Priority::Interactive, Priority::Bulk] {
            assert_eq!(Priority::from_wire(p.to_wire()), Some(p));
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::from_wire(7), None);
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Interactive);
    }
}
