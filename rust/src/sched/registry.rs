//! Multi-model registry: N loaded acoustic models behind one engine.
//!
//! The engine used to be welded to exactly one model per process; serving
//! a second language or a second model size meant a second engine, a
//! second decode pool and a second TCP port.  The registry holds N
//! [`AmBackend`]s (registration order = model index, the id carried by
//! [`crate::sched::StreamOptions::model`]); the engine allocates one
//! lane-tagged arena per model and a single scheduler + AM worker + decode
//! pool serves all of them, with per-model lane accounting in
//! [`crate::coordinator::metrics::Metrics`] and tick-level fairness (every
//! model's planned lanes step every flush — a saturated model cannot
//! monopolize the worker).
//!
//! Models may differ in input dimension and label count — per-stream I/O
//! is sized per model by the engine — but every model's lanes obey the
//! same [`AmBackend`] contract, so preemption and eviction work uniformly.
//!
//! **Lifecycle.**  The registry is the *boot-time seed* of the engine's
//! dynamic model table: `Engine::start_registry` consumes it into
//! index-stable slots, and from then on models are hot-loaded
//! (`Engine::load_model` — arena + lane allocator created on the AM
//! worker thread) and hot-unloaded (`Engine::unload_model` — the slot
//! drains: survivors finish, newcomers are rejected with
//! [`crate::sched::RejectReason::ModelDraining`], and the arena is torn
//! down at a tick boundary once the last lane empties).  Invariants the
//! table preserves across churn:
//!
//! 1. a model id (slot index) never changes while the model is loaded —
//!    streams carry the id for their whole life;
//! 2. an unloaded slot is only reused after its teardown completes, so a
//!    new model never inherits live lanes, allocator state or scheduler
//!    credit ([`crate::sched::DrrState`] resets idle slots);
//! 3. no tick ever steps a lane of a torn-down model — teardown happens
//!    under the engine lock between ticks.
//!
//! ```
//! use quantasr::nn::AcousticModel;
//! use quantasr::sched::ModelRegistry;
//!
//! let r = ModelRegistry::<AcousticModel>::new();
//! assert!(r.is_empty());
//! assert_eq!(r.len(), 0);
//! assert!(r.get(0).is_none());
//! ```

use std::sync::Arc;

use crate::runtime::backend::AmBackend;

/// An ordered set of loaded models.  Index = model id.
pub struct ModelRegistry<B: AmBackend> {
    entries: Vec<(String, Arc<B>)>,
}

impl<B: AmBackend> Default for ModelRegistry<B> {
    fn default() -> Self {
        Self::new()
    }
}

impl<B: AmBackend> ModelRegistry<B> {
    pub fn new() -> Self {
        ModelRegistry { entries: Vec::new() }
    }

    /// The single-model registry every pre-scheduler call site uses.
    pub fn single(backend: Arc<B>) -> Self {
        let mut r = Self::new();
        r.register(backend);
        r
    }

    /// Register a model under its self-reported name
    /// ([`AmBackend::model_name`]); returns its model id.
    pub fn register(&mut self, backend: Arc<B>) -> usize {
        let name = backend.model_name();
        self.register_named(name, backend)
    }

    /// Register a model under an explicit name; returns its model id.
    pub fn register_named(&mut self, name: impl Into<String>, backend: Arc<B>) -> usize {
        self.entries.push((name.into(), backend));
        self.entries.len() - 1
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, model: usize) -> Option<&Arc<B>> {
        self.entries.get(model).map(|(_, b)| b)
    }

    pub fn name(&self, model: usize) -> Option<&str> {
        self.entries.get(model).map(|(n, _)| n.as_str())
    }

    /// Consume the registry into parallel (names, backends) vectors —
    /// the engine's internal layout.
    pub fn into_parts(self) -> (Vec<String>, Vec<Arc<B>>) {
        self.entries.into_iter().unzip()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{AcousticModel, ExecMode};
    use crate::util::prop::Gen;

    fn model(seed: u64) -> Arc<AcousticModel> {
        let mut g = Gen::new(seed);
        let qam = crate::nn::model::random_qam(2, 8, Some(4), 6, 7, &mut g);
        Arc::new(AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap())
    }

    #[test]
    fn registration_order_is_model_id() {
        let mut r = ModelRegistry::new();
        assert!(r.is_empty());
        let a = r.register_named("am-en", model(1));
        let b = r.register_named("am-de", model(2));
        assert_eq!((a, b), (0, 1));
        assert_eq!(r.len(), 2);
        assert_eq!(r.name(0), Some("am-en"));
        assert_eq!(r.name(1), Some("am-de"));
        assert!(r.get(1).is_some());
        assert!(r.get(2).is_none());
        let (names, backends) = r.into_parts();
        assert_eq!(names, vec!["am-en".to_string(), "am-de".to_string()]);
        assert_eq!(backends.len(), 2);
    }

    #[test]
    fn single_uses_the_model_name() {
        let r = ModelRegistry::single(model(3));
        assert_eq!(r.len(), 1);
        // random_qam names the model by its shape.
        assert!(r.name(0).is_some());
        assert_eq!(r.name(0), Some(r.get(0).unwrap().model_name().as_str()));
    }
}
