//! Admission control: a bounded live-stream set with reject-with-reason
//! backpressure.
//!
//! Without a bound, every accepted connection grows the engine's stream
//! map (and its parked-state memory) without limit — under overload the
//! process slows for *everyone* instead of telling *someone* to retry.
//! The controller caps the number of live (admitted, not yet drained)
//! streams across all models; the cap bounds the lane-less parked queue
//! too, since parked streams are a subset of live ones.  Rejections carry
//! a machine-readable [`RejectReason`] that the TCP server forwards to
//! the client verbatim (`'R'` frame), so callers can distinguish
//! "saturated, retry later" from "you asked for a model that isn't
//! loaded".
//!
//! Pure policy — the engine supplies the current occupancy under its own
//! lock and applies the verdict atomically with the insert.

use std::fmt;

/// Admission limits.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Maximum live (admitted, not yet drained) streams across all
    /// models.  Bounds both memory (parked state is O(live streams)) and
    /// the worst-case parked-queue wait.
    pub max_live_streams: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        // Generous by default: lanes bound *compute* fairness via the
        // quantum scheduler; this bound is the memory/latency backstop.
        AdmissionConfig { max_live_streams: 1024 }
    }
}

/// Why a stream was refused admission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The live-stream cap is reached — retry after streams drain.
    Saturated { live: usize, cap: usize },
    /// The requested model index is not registered in this engine.
    UnknownModel { model: usize, loaded: usize },
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::Saturated { live, cap } => {
                write!(f, "saturated: {live} live streams at cap {cap}; retry later")
            }
            RejectReason::UnknownModel { model, loaded } => {
                write!(f, "unknown model {model}: engine has {loaded} model(s) loaded")
            }
        }
    }
}

impl std::error::Error for RejectReason {}

/// The admission decision procedure.
#[derive(Clone, Debug, Default)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController { cfg }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Decide whether a stream targeting `model` may be admitted given
    /// `live` currently-admitted streams and `loaded` registered models.
    pub fn admit(&self, live: usize, model: usize, loaded: usize) -> Result<(), RejectReason> {
        if model >= loaded {
            return Err(RejectReason::UnknownModel { model, loaded });
        }
        if live >= self.cfg.max_live_streams {
            return Err(RejectReason::Saturated { live, cap: self.cfg.max_live_streams });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_below_cap_rejects_at_cap() {
        let c = AdmissionController::new(AdmissionConfig { max_live_streams: 2 });
        assert!(c.admit(0, 0, 1).is_ok());
        assert!(c.admit(1, 0, 1).is_ok());
        assert_eq!(
            c.admit(2, 0, 1),
            Err(RejectReason::Saturated { live: 2, cap: 2 })
        );
        assert_eq!(
            c.admit(5, 0, 1),
            Err(RejectReason::Saturated { live: 5, cap: 2 })
        );
    }

    #[test]
    fn unknown_model_wins_over_saturation() {
        let c = AdmissionController::new(AdmissionConfig { max_live_streams: 0 });
        assert_eq!(
            c.admit(9, 3, 2),
            Err(RejectReason::UnknownModel { model: 3, loaded: 2 })
        );
    }

    #[test]
    fn reasons_render_for_the_wire() {
        let s = RejectReason::Saturated { live: 8, cap: 8 }.to_string();
        assert!(s.contains("saturated") && s.contains('8'), "{s}");
        let u = RejectReason::UnknownModel { model: 2, loaded: 1 }.to_string();
        assert!(u.contains("unknown model 2"), "{u}");
    }
}
