//! Admission control: a bounded live-stream set with reject-with-reason
//! backpressure.
//!
//! Without a bound, every accepted connection grows the engine's stream
//! map (and its parked-state memory) without limit — under overload the
//! process slows for *everyone* instead of telling *someone* to retry.
//! The controller caps the number of live (admitted, not yet drained)
//! streams across all models; the cap bounds the lane-less parked queue
//! too, since parked streams are a subset of live ones.  Rejections carry
//! a machine-readable [`RejectReason`] that the TCP server forwards to
//! the client verbatim (`'R'` frame, see `docs/PROTOCOL.md`), so callers
//! can distinguish "saturated, retry later" from "you asked for a model
//! that isn't loaded" from "that model is draining out".
//!
//! **Invariants.**  (1) The live-stream set never exceeds
//! `max_live_streams` — the engine applies the verdict atomically with
//! the insert under its own lock.  (2) A stream is only ever admitted to
//! a model in the [`ModelStatus::Loaded`] state, which is what lets hot
//! unload drain safely: marking a model `Draining` closes the front door
//! while the streams already inside finish.  (3) Rejection is total — for
//! every input the controller returns either an admit or a reason, never
//! a hang.
//!
//! Pure policy — the engine supplies the current occupancy and the
//! target model's lifecycle state under its own lock and applies the
//! verdict atomically with the insert:
//!
//! ```
//! use quantasr::sched::{AdmissionConfig, AdmissionController, ModelStatus, RejectReason};
//!
//! let c = AdmissionController::new(AdmissionConfig { max_live_streams: 2 });
//! assert!(c.admit(1, 0, ModelStatus::Loaded, 1).is_ok());
//! // At the cap: reject with a retryable reason.
//! assert!(matches!(
//!     c.admit(2, 0, ModelStatus::Loaded, 1),
//!     Err(RejectReason::Saturated { live: 2, cap: 2 })
//! ));
//! // A draining model refuses new streams even with capacity to spare.
//! assert!(matches!(
//!     c.admit(0, 0, ModelStatus::Draining, 1),
//!     Err(RejectReason::ModelDraining { model: 0 })
//! ));
//! ```

use std::fmt;

/// Admission limits.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Maximum live (admitted, not yet drained) streams across all
    /// models.  Bounds both memory (parked state is O(live streams)) and
    /// the worst-case parked-queue wait.
    pub max_live_streams: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        // Generous by default: lanes bound *compute* fairness via the
        // quantum scheduler; this bound is the memory/latency backstop.
        AdmissionConfig { max_live_streams: 1024 }
    }
}

/// Lifecycle state of the model a stream asks for, as seen by the
/// engine's dynamic model table at admission time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelStatus {
    /// Registered and serving: streams may be admitted.
    Loaded,
    /// Unload requested: survivors finish, newcomers are rejected.
    Draining,
    /// Poisoned by a backend panic: quarantined until unloaded.
    Quarantined,
    /// No model at that index (never loaded, or already torn down).
    Unknown,
}

/// Why a stream was refused admission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The live-stream cap is reached — retry after streams drain.
    Saturated { live: usize, cap: usize },
    /// The requested model index is not registered in this engine.
    UnknownModel { model: usize, loaded: usize },
    /// The requested model is draining out (hot unload in progress).
    ModelDraining { model: usize },
    /// The requested model was quarantined after a backend fault.
    ModelQuarantined { model: usize },
    /// Admitting the stream would push resident bytes (arenas + parked
    /// reservations, see [`crate::sched::BudgetLedger`]) past the
    /// configured `--mem-budget-bytes` — retry after streams drain.
    MemoryPressure { resident: usize, budget: usize },
    /// The engine is in brownout (sustained tick-deadline overrun) and is
    /// shedding load — retry once it recovers.
    Brownout,
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::Saturated { live, cap } => {
                write!(f, "saturated: {live} live streams at cap {cap}; retry later")
            }
            RejectReason::UnknownModel { model, loaded } => {
                write!(f, "unknown model {model}: engine has {loaded} model(s) loaded")
            }
            RejectReason::ModelDraining { model } => {
                write!(f, "model {model} is draining; pick another model")
            }
            RejectReason::ModelQuarantined { model } => {
                write!(
                    f,
                    "model {model} is quarantined after a fault; unload it or pick another model"
                )
            }
            RejectReason::MemoryPressure { resident, budget } => {
                write!(
                    f,
                    "memory pressure: {resident} resident bytes at budget {budget}; retry later"
                )
            }
            RejectReason::Brownout => {
                write!(f, "brownout: engine is shedding load; retry later")
            }
        }
    }
}

impl RejectReason {
    /// Stable numeric code for the trace plane: [`crate::obs`] records a
    /// reject event whose `arg` is this code, so traces can be grouped
    /// by reason without parsing display strings.  Codes are append-only
    /// (same additive rule as the wire protocol) — never renumber.
    pub fn code(&self) -> u64 {
        match self {
            RejectReason::Saturated { .. } => 1,
            RejectReason::UnknownModel { .. } => 2,
            RejectReason::ModelDraining { .. } => 3,
            RejectReason::ModelQuarantined { .. } => 4,
            RejectReason::MemoryPressure { .. } => 5,
            RejectReason::Brownout => 6,
        }
    }
}

impl std::error::Error for RejectReason {}

/// The admission decision procedure.
#[derive(Clone, Debug, Default)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController { cfg }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Decide whether a stream targeting `model` may be admitted given
    /// `live` currently-admitted streams, the target model's lifecycle
    /// `status`, and `loaded` registered models (reported in the
    /// unknown-model reason).  Model identity outranks capacity: asking
    /// for a missing or draining model is a caller error and is reported
    /// as such even when the engine is also saturated.
    pub fn admit(
        &self,
        live: usize,
        model: usize,
        status: ModelStatus,
        loaded: usize,
    ) -> Result<(), RejectReason> {
        match status {
            ModelStatus::Unknown => return Err(RejectReason::UnknownModel { model, loaded }),
            ModelStatus::Draining => return Err(RejectReason::ModelDraining { model }),
            ModelStatus::Quarantined => return Err(RejectReason::ModelQuarantined { model }),
            ModelStatus::Loaded => {}
        }
        if live >= self.cfg.max_live_streams {
            return Err(RejectReason::Saturated { live, cap: self.cfg.max_live_streams });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_below_cap_rejects_at_cap() {
        let c = AdmissionController::new(AdmissionConfig { max_live_streams: 2 });
        assert!(c.admit(0, 0, ModelStatus::Loaded, 1).is_ok());
        assert!(c.admit(1, 0, ModelStatus::Loaded, 1).is_ok());
        assert_eq!(
            c.admit(2, 0, ModelStatus::Loaded, 1),
            Err(RejectReason::Saturated { live: 2, cap: 2 })
        );
        assert_eq!(
            c.admit(5, 0, ModelStatus::Loaded, 1),
            Err(RejectReason::Saturated { live: 5, cap: 2 })
        );
    }

    #[test]
    fn model_state_wins_over_saturation() {
        let c = AdmissionController::new(AdmissionConfig { max_live_streams: 0 });
        assert_eq!(
            c.admit(9, 3, ModelStatus::Unknown, 2),
            Err(RejectReason::UnknownModel { model: 3, loaded: 2 })
        );
        assert_eq!(
            c.admit(9, 1, ModelStatus::Draining, 2),
            Err(RejectReason::ModelDraining { model: 1 })
        );
        assert_eq!(
            c.admit(9, 1, ModelStatus::Quarantined, 2),
            Err(RejectReason::ModelQuarantined { model: 1 })
        );
    }

    #[test]
    fn reasons_render_for_the_wire() {
        let s = RejectReason::Saturated { live: 8, cap: 8 }.to_string();
        assert!(s.contains("saturated") && s.contains('8'), "{s}");
        let u = RejectReason::UnknownModel { model: 2, loaded: 1 }.to_string();
        assert!(u.contains("unknown model 2"), "{u}");
        let d = RejectReason::ModelDraining { model: 3 }.to_string();
        assert!(d.contains("model 3") && d.contains("draining"), "{d}");
        let q = RejectReason::ModelQuarantined { model: 4 }.to_string();
        assert!(q.contains("model 4") && q.contains("quarantined"), "{q}");
        let m = RejectReason::MemoryPressure { resident: 900, budget: 1000 }.to_string();
        assert!(m.starts_with("memory pressure:") && m.contains("900"), "{m}");
        let b = RejectReason::Brownout.to_string();
        assert!(b.starts_with("brownout:"), "{b}");
    }

    #[test]
    fn trace_codes_are_distinct_and_stable() {
        let reasons = [
            RejectReason::Saturated { live: 1, cap: 1 },
            RejectReason::UnknownModel { model: 0, loaded: 0 },
            RejectReason::ModelDraining { model: 0 },
            RejectReason::ModelQuarantined { model: 0 },
            RejectReason::MemoryPressure { resident: 1, budget: 1 },
            RejectReason::Brownout,
        ];
        let codes: Vec<u64> = reasons.iter().map(|r| r.code()).collect();
        assert_eq!(codes, vec![1, 2, 3, 4, 5, 6], "codes are append-only; never renumber");
    }
}
