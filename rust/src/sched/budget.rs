//! Byte-accounted resource budget for the serving plane.
//!
//! The paper's 8-bit representation exists so acoustic models fit tight
//! memory budgets; this module makes the serving engine honor one.  The
//! only admission bound before it was a *stream count*
//! ([`crate::sched::AdmissionConfig::max_live_streams`]) — nothing
//! stopped `load_model` from allocating an arena that blows the host's
//! memory envelope, and nothing bounded the parked-lane blobs that
//! eviction/preemption/drain create under churn.
//!
//! [`BudgetLedger`] is pure accounting — no clocks, locks or arenas, per
//! the `sched` charter — driven by the engine at every byte-moving event:
//!
//! - **Arena residency**: charged when a model's arena is built
//!   ([`crate::runtime::AmBackend::arena_bytes`]), released at unload
//!   teardown.  `load_model` asks [`BudgetLedger::fits`] *before*
//!   allocating, so an oversized model is rejected, not OOM-killed.
//! - **Stream reservation**: every admitted stream charges one parked
//!   blob's worth of bytes ([`crate::runtime::AmBackend::parked_bytes`])
//!   up front, released when the stream is removed.  A stream's recurrent
//!   state lives either in its arena lane (already priced into the arena)
//!   or in a [`crate::nn::model::ParkedLane`] copy; reserving the copy at
//!   admission means eviction/preemption can always park without asking —
//!   the budget can never be exceeded by a scheduling decision, only
//!   refused at an admission edge.  Since every parked blob belongs to a
//!   live stream slot, `parked ≤ reserved` is an invariant.
//! - **Parked observability**: actual parked-blob bytes are counted
//!   separately per model (they do not affect the budget check — the
//!   reservation already covers them) so `Metrics`/`'Q'` can show
//!   operators what is parked *right now* versus what is reserved.
//!
//! Conservation invariants (property-tested in
//! `tests/sched_integration.rs`): counters never go negative, resident
//! bytes never exceed the budget when every charge is guarded by
//! [`BudgetLedger::fits`], and everything returns to zero once all models
//! and streams are gone.

/// Per-model byte totals, as the ledger sees them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModelBytes {
    /// Resident arena bytes (0 until the arena is built, 0 after unload).
    pub arena: usize,
    /// Reserved stream bytes: live streams × one parked blob each.
    pub reserved: usize,
    /// Bytes actually sitting in parked blobs right now (≤ `reserved`).
    pub parked: usize,
}

impl ModelBytes {
    /// What this model counts against the budget.
    pub fn resident(&self) -> usize {
        self.arena + self.reserved
    }
}

/// The engine-wide byte ledger.  `budget: None` means unlimited (the
/// default): everything is still tracked for observability, but
/// [`BudgetLedger::fits`] always says yes.
#[derive(Debug)]
pub struct BudgetLedger {
    budget: Option<usize>,
    rows: Vec<ModelBytes>,
}

impl BudgetLedger {
    pub fn new(budget: Option<usize>) -> Self {
        BudgetLedger { budget, rows: Vec::new() }
    }

    /// The configured budget, if any.
    pub fn budget(&self) -> Option<usize> {
        self.budget
    }

    /// Total bytes counted against the budget (arenas + reservations).
    pub fn resident(&self) -> usize {
        self.rows.iter().map(ModelBytes::resident).sum()
    }

    /// Total bytes in actual parked blobs (observability only).
    pub fn parked(&self) -> usize {
        self.rows.iter().map(|r| r.parked).sum()
    }

    /// Would charging `extra` more bytes stay within budget?
    pub fn fits(&self, extra: usize) -> bool {
        match self.budget {
            None => true,
            Some(b) => self.resident().saturating_add(extra) <= b,
        }
    }

    /// Per-model snapshot (zeroes for never-seen slots).
    pub fn model(&self, m: usize) -> ModelBytes {
        self.rows.get(m).copied().unwrap_or_default()
    }

    /// True once nothing is charged anywhere (the conservation check).
    pub fn is_empty(&self) -> bool {
        self.rows.iter().all(|r| *r == ModelBytes::default())
    }

    fn row(&mut self, m: usize) -> &mut ModelBytes {
        if m >= self.rows.len() {
            self.rows.resize(m + 1, ModelBytes::default());
        }
        &mut self.rows[m]
    }

    /// Model `m`'s arena was built at `bytes` resident.
    pub fn charge_arena(&mut self, m: usize, bytes: usize) {
        let r = self.row(m);
        debug_assert_eq!(r.arena, 0, "model {m} arena double-charged");
        r.arena = bytes;
    }

    /// Model `m`'s arena was dropped (unload teardown).
    pub fn release_arena(&mut self, m: usize) {
        self.row(m).arena = 0;
    }

    /// A stream was admitted on model `m`, reserving one parked blob.
    pub fn charge_stream(&mut self, m: usize, bytes: usize) {
        self.row(m).reserved += bytes;
    }

    /// A stream on model `m` ended (its reservation — and any parked blob
    /// it still held — is gone with its slot).
    pub fn release_stream(&mut self, m: usize, bytes: usize, was_parked: bool) {
        let r = self.row(m);
        debug_assert!(r.reserved >= bytes, "model {m} reservation underflow");
        r.reserved = r.reserved.saturating_sub(bytes);
        if was_parked {
            debug_assert!(r.parked >= bytes, "model {m} parked underflow");
            r.parked = r.parked.saturating_sub(bytes);
        }
        debug_assert!(r.parked <= r.reserved, "model {m}: parked exceeds reserved");
    }

    /// A lane was parked (eviction/preemption/cancel/drain) on model `m`.
    pub fn note_parked(&mut self, m: usize, bytes: usize) {
        let r = self.row(m);
        r.parked += bytes;
        debug_assert!(r.parked <= r.reserved, "model {m}: parked exceeds reserved");
    }

    /// A parked blob was restored into a lane (re-admission) on model `m`.
    pub fn note_unparked(&mut self, m: usize, bytes: usize) {
        let r = self.row(m);
        debug_assert!(r.parked >= bytes, "model {m} parked underflow");
        r.parked = r.parked.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_ledger_always_fits_but_still_tracks() {
        let mut l = BudgetLedger::new(None);
        assert!(l.fits(usize::MAX));
        l.charge_arena(0, 1000);
        l.charge_stream(0, 64);
        assert_eq!(l.resident(), 1064);
        assert!(l.fits(usize::MAX));
        l.release_stream(0, 64, false);
        l.release_arena(0);
        assert!(l.is_empty());
    }

    #[test]
    fn fits_is_exact_at_the_boundary() {
        let mut l = BudgetLedger::new(Some(100));
        assert!(l.fits(100));
        assert!(!l.fits(101));
        l.charge_arena(0, 60);
        assert!(l.fits(40));
        assert!(!l.fits(41));
        l.charge_stream(0, 40);
        assert!(l.fits(0));
        assert!(!l.fits(1));
    }

    #[test]
    fn park_unpark_does_not_move_the_budget_needle() {
        let mut l = BudgetLedger::new(Some(100));
        l.charge_arena(0, 50);
        l.charge_stream(0, 20);
        let before = l.resident();
        l.note_parked(0, 20);
        assert_eq!(l.resident(), before, "parking converts a reservation");
        assert_eq!(l.parked(), 20);
        l.note_unparked(0, 20);
        assert_eq!(l.parked(), 0);
        assert_eq!(l.resident(), before);
    }

    #[test]
    fn stream_release_drops_parked_blob_with_the_slot() {
        let mut l = BudgetLedger::new(Some(100));
        l.charge_stream(1, 30);
        l.note_parked(1, 30);
        l.release_stream(1, 30, true);
        assert!(l.is_empty());
        assert_eq!(l.model(1), ModelBytes::default());
    }

    #[test]
    fn per_model_rows_are_independent() {
        let mut l = BudgetLedger::new(Some(1000));
        l.charge_arena(0, 100);
        l.charge_arena(2, 200);
        l.charge_stream(2, 10);
        assert_eq!(l.model(0).arena, 100);
        assert_eq!(l.model(1), ModelBytes::default());
        assert_eq!(l.model(2).resident(), 210);
        assert_eq!(l.resident(), 310);
        l.release_arena(2);
        l.release_stream(2, 10, false);
        assert_eq!(l.model(2), ModelBytes::default());
        assert_eq!(l.resident(), 100);
    }
}
