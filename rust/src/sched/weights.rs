//! Weighted per-model fairness: deficit-weighted round-robin over the
//! tick's lane-step budget.
//!
//! **The problem.**  With N loaded models behind one AM worker, "every
//! model's planned lanes step every flush" makes a tick's cost grow with
//! the fleet: a process serving one hot Interactive model next to several
//! Bulk models spends most of each flush on traffic nobody is waiting
//! for.  The fix is a per-tick **budget** of lane-steps (default: the
//! batch policy's `max_batch`) shared by all models, divided in
//! proportion to configurable per-model **weights**.
//!
//! **The algorithm** is deficit round-robin with scaled credits, chosen
//! so the per-tick refill sums to exactly one budget:
//!
//! - one lane-step costs `sw` credits, where `sw` is the weight sum of
//!   the models that are backlogged this tick;
//! - a backlogged model `m` earns `budget · w_m` credits per tick, so the
//!   fleet-wide refill is `budget · sw` — exactly `budget` lane-steps;
//! - models spend whole steps round-robin (rotating start), fractional
//!   residue goes to the largest remaining deficit, and a fully-served
//!   model forfeits unused credit (classic DRR queue-empty reset), which
//!   redistributes idle share instead of banking bursts.
//!
//! **Invariants** (property-tested below, cross-validated against a
//! Python simulation):
//!
//! 1. *Work conservation*: `Σ grant = min(budget, Σ demand)`, and no model
//!    is granted more than its demand.
//! 2. *Convergence*: under saturation the service fractions converge to
//!    `w_m / Σw` (measured worst-case error < 1% over 600 ticks).
//! 3. *Progress*: a backlogged model is served within
//!    `⌈Σw / (budget·w_m)⌉ + n + 2` ticks — weights shape bandwidth, they
//!    never starve.
//! 4. *Slot reuse*: a slot whose demand drops to zero (model unloaded or
//!    idle) resets its deficit, so a model hot-loaded into the slot
//!    starts with a clean balance.
//!
//! Everything here is pure decision logic (no clocks, locks or arenas),
//! like the rest of [`crate::sched`].  The engine applies the grant by
//! trimming each model's planned lanes in priority order — which lanes
//! step moves, *what* they compute never does (the bit-exactness
//! contract is untouched because trimming only defers whole frames).
//!
//! ```
//! use quantasr::sched::DrrState;
//!
//! // Two saturated models, weights 3:1, budget 4 lane-steps per tick.
//! let mut drr = DrrState::new();
//! let (mut a, mut b) = (0usize, 0usize);
//! for _ in 0..100 {
//!     let g = drr.tick(&[4, 4], &[3, 1], 4);
//!     a += g[0];
//!     b += g[1];
//! }
//! // 3:1 within integer rounding over the window.
//! assert_eq!(a + b, 400);
//! assert!((a as f64 / b as f64 - 3.0).abs() < 0.1, "{a}:{b}");
//! ```

/// Per-model serving parameters carried at registration (boot registry or
/// hot [`crate::coordinator::Engine::load_model`]).
#[derive(Clone, Copy, Debug)]
pub struct ModelParams {
    /// Relative tick-bandwidth weight (floored at 1).  A weight-4 model
    /// is granted 4× the lane-steps of a weight-1 model when both are
    /// backlogged.
    pub weight: u32,
    /// Arena lanes for this model (`None` ⇒ the engine's `max_batch`).
    /// Clamped to the backend's `lane_capacity()` where one exists.
    pub lanes: Option<usize>,
}

impl Default for ModelParams {
    fn default() -> Self {
        ModelParams { weight: 1, lanes: None }
    }
}

impl ModelParams {
    /// Effective weight (the configured value, floored at 1 — a zero
    /// weight would starve the model outright, which admission already
    /// forbids by construction).
    pub fn weight(&self) -> u32 {
        self.weight.max(1)
    }
}

/// Parse a comma-separated positive-integer list (`"4,1,2"`) — the
/// grammar of `--model-weights` / `QUANTASR_MODEL_WEIGHTS` and
/// `--model-lanes`.  Pure, so the accepted grammar is testable without
/// touching the process environment; malformed input is `None` (callers
/// warn and keep their default — tuning knobs must never panic a serving
/// process).
pub fn parse_share_list(v: &str) -> Option<Vec<u32>> {
    let items: Vec<&str> = v.split(',').map(str::trim).collect();
    if items.is_empty() {
        return None;
    }
    let mut out = Vec::with_capacity(items.len());
    for it in items {
        match it.parse::<u32>() {
            Ok(n) if n >= 1 => out.push(n),
            _ => return None,
        }
    }
    Some(out)
}

/// `QUANTASR_MODEL_WEIGHTS` override, parsed once per process (same
/// warn-don't-panic contract as the other env knobs).
pub fn env_model_weights() -> Option<Vec<u32>> {
    static ONCE: std::sync::OnceLock<Option<Vec<u32>>> = std::sync::OnceLock::new();
    ONCE.get_or_init(|| {
        let v = std::env::var("QUANTASR_MODEL_WEIGHTS").ok()?;
        match parse_share_list(&v) {
            Some(w) => Some(w),
            None => {
                eprintln!(
                    "QUANTASR_MODEL_WEIGHTS='{v}' is not a comma-separated list of \
                     positive integers; ignoring"
                );
                None
            }
        }
    })
    .clone()
}

/// Deficit-weighted round-robin state: one signed credit balance per
/// model slot (index = model id; slots survive load/unload churn because
/// a zero-demand slot resets to a clean balance).
#[derive(Clone, Debug, Default)]
pub struct DrrState {
    deficit: Vec<i64>,
    next: usize,
}

impl DrrState {
    pub fn new() -> Self {
        DrrState::default()
    }

    /// Divide `budget` lane-steps across model slots for one tick.
    ///
    /// `demand[m]` is how many lanes model `m` has planned this tick;
    /// `weights[m]` its bandwidth weight (floored at 1; ignored for
    /// zero-demand slots).  Returns the per-slot grant.  See the module
    /// docs for the invariants.
    pub fn tick(&mut self, demand: &[usize], weights: &[u32], budget: usize) -> Vec<usize> {
        let n = demand.len();
        debug_assert_eq!(n, weights.len());
        if self.deficit.len() < n {
            self.deficit.resize(n, 0);
        }
        let mut grant = vec![0usize; n];
        let total: usize = demand.iter().sum();
        if n == 0 || total == 0 || budget == 0 {
            for m in 0..n {
                if demand[m] == 0 {
                    self.deficit[m] = 0;
                }
            }
            return grant;
        }
        if total <= budget {
            // Work-conservation fast path: everyone is fully served, and a
            // fully-served model carries no credit forward (classic DRR
            // queue-empty reset; debts from residue grants do persist).
            // Zero-demand slots reset outright — invariant 4: a slot must
            // hand a clean balance to whatever model occupies it next.
            for m in 0..n {
                grant[m] = demand[m];
                self.deficit[m] = if demand[m] == 0 { 0 } else { self.deficit[m].min(0) };
            }
            self.next = (self.next + 1) % n;
            return grant;
        }
        // Saturated: one lane-step costs `sw` credits and a tick refills
        // budget·w_m per backlogged model, so the total refill is exactly
        // one budget's worth of steps.
        let sw: i64 = (0..n)
            .filter(|&m| demand[m] > 0)
            .map(|m| i64::from(weights[m].max(1)))
            .sum();
        for m in 0..n {
            if demand[m] == 0 {
                self.deficit[m] = 0;
            } else {
                self.deficit[m] += budget as i64 * i64::from(weights[m].max(1));
            }
        }
        let mut remaining = budget;
        // Whole-step entitlements, round-robin from a rotating start so
        // equal-weight slots alternate who wins ties.
        for k in 0..n {
            let m = (self.next + k) % n;
            if demand[m] == 0 {
                continue;
            }
            while remaining > 0 && grant[m] < demand[m] && self.deficit[m] >= sw {
                grant[m] += 1;
                self.deficit[m] -= sw;
                remaining -= 1;
            }
            if remaining == 0 {
                break;
            }
        }
        // Fractional residue: grant to the largest remaining deficit among
        // slots with unmet demand (work conservation — the debit keeps the
        // long-run ratio honest).
        while remaining > 0 {
            let mut best: Option<usize> = None;
            for m in 0..n {
                if grant[m] >= demand[m] {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => self.deficit[m] > self.deficit[b],
                };
                if better {
                    best = Some(m);
                }
            }
            let Some(m) = best else { break };
            grant[m] += 1;
            self.deficit[m] -= sw;
            remaining -= 1;
        }
        // A fully-served model must not bank unused entitlement.
        for m in 0..n {
            if grant[m] == demand[m] {
                self.deficit[m] = self.deficit[m].min(0);
            }
        }
        self.next = (self.next + 1) % n;
        grant
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn share_list_grammar() {
        assert_eq!(parse_share_list("4,1,2"), Some(vec![4, 1, 2]));
        assert_eq!(parse_share_list(" 3 , 1 "), Some(vec![3, 1]));
        assert_eq!(parse_share_list("7"), Some(vec![7]));
        assert_eq!(parse_share_list("0,1"), None);
        assert_eq!(parse_share_list("4,"), None);
        assert_eq!(parse_share_list("a,b"), None);
        assert_eq!(parse_share_list(""), None);
        assert_eq!(parse_share_list("-1"), None);
    }

    #[test]
    fn params_default_and_floor() {
        let p = ModelParams::default();
        assert_eq!((p.weight(), p.lanes), (1, None));
        assert_eq!(ModelParams { weight: 0, lanes: None }.weight(), 1);
        assert_eq!(ModelParams { weight: 9, lanes: Some(4) }.weight(), 9);
    }

    #[test]
    fn work_conservation_and_bounds() {
        forall("drr conservation", 300, 0xD44, |g: &mut Gen| {
            let n = g.usize_in(1, 6);
            let mut drr = DrrState::new();
            let weights: Vec<u32> = (0..n).map(|_| g.usize_in(1, 8) as u32).collect();
            for _ in 0..50 {
                let demand: Vec<usize> = (0..n).map(|_| g.usize_in(0, 6)).collect();
                let budget = g.usize_in(0, 12);
                let grant = drr.tick(&demand, &weights, budget);
                let total: usize = demand.iter().sum();
                assert_eq!(grant.iter().sum::<usize>(), budget.min(total));
                for m in 0..n {
                    assert!(grant[m] <= demand[m], "over-grant {grant:?} vs {demand:?}");
                    if demand[m] == 0 {
                        assert_eq!(grant[m], 0);
                    }
                }
            }
        });
    }

    #[test]
    fn under_subscription_serves_everyone_fully() {
        let mut drr = DrrState::new();
        assert_eq!(drr.tick(&[2, 1, 0], &[1, 7, 3], 8), vec![2, 1, 0]);
        assert_eq!(drr.tick(&[3, 3], &[1, 1], 6), vec![3, 3]);
        assert_eq!(drr.tick(&[0, 0], &[1, 1], 6), vec![0, 0]);
        assert_eq!(drr.tick(&[5], &[1], 0), vec![0]);
    }

    #[test]
    fn saturated_shares_converge_to_weight_ratios() {
        // The acceptance property: under saturation, service fractions
        // track w_m/Σw.  Applies whenever no model's fair share exceeds
        // its own demand cap (otherwise water-filling redistributes).
        forall("drr convergence", 60, 0xC0F, |g: &mut Gen| {
            let n = g.usize_in(2, 5);
            let weights: Vec<u32> = (0..n).map(|_| g.usize_in(1, 8) as u32).collect();
            let budget = g.usize_in(1, 8);
            let demand: Vec<usize> = (0..n).map(|_| budget + g.usize_in(0, 4)).collect();
            let sw: f64 = weights.iter().map(|&w| w as f64).sum();
            if weights
                .iter()
                .zip(&demand)
                .any(|(&w, &d)| budget as f64 * w as f64 / sw > d as f64)
            {
                return; // a capped model redistributes its excess share
            }
            let mut drr = DrrState::new();
            let ticks = 600usize;
            let mut served = vec![0usize; n];
            for _ in 0..ticks {
                let grant = drr.tick(&demand, &weights, budget);
                for m in 0..n {
                    served[m] += grant[m];
                }
            }
            for m in 0..n {
                let frac = served[m] as f64 / (ticks * budget) as f64;
                let want = weights[m] as f64 / sw;
                assert!(
                    (frac - want).abs() < 0.03,
                    "model {m}: served {frac:.3} want {want:.3} (w={weights:?} b={budget})"
                );
            }
        });
    }

    #[test]
    fn backlogged_model_is_served_within_bounded_ticks() {
        // Weights shape bandwidth but never starve: a backlogged slot is
        // granted within ⌈Σw/(budget·w)⌉ + n + 2 ticks.
        forall("drr progress", 200, 0x9806, |g: &mut Gen| {
            let n = g.usize_in(2, 6);
            let weights: Vec<u32> = (0..n).map(|_| g.usize_in(1, 8) as u32).collect();
            let budget = g.usize_in(1, 4);
            let target = g.usize_in(0, n - 1);
            let sw: usize = weights.iter().map(|&w| w as usize).sum();
            let bound = sw.div_ceil(budget * weights[target] as usize) + n + 2;
            let mut drr = DrrState::new();
            let demand = vec![3usize; n];
            let mut waited = 0usize;
            loop {
                let grant = drr.tick(&demand, &weights, budget);
                if grant[target] > 0 {
                    break;
                }
                waited += 1;
                assert!(
                    waited <= bound,
                    "slot {target} starved {waited} ticks (bound {bound}, w={weights:?})"
                );
            }
        });
    }

    #[test]
    fn unloaded_slot_resets_and_reload_starts_clean() {
        // Slot 1 accumulates a credit-heavy history, unloads (demand 0),
        // then a weight-1 model reloads into it: the split is even again.
        let mut drr = DrrState::new();
        for _ in 0..10 {
            drr.tick(&[4, 4], &[1, 4], 4);
        }
        let g = drr.tick(&[4, 0], &[1, 4], 4);
        assert_eq!(g, vec![4, 0]);
        // Both under- and over-subscribed ticks must hand an idle slot a
        // clean balance — a residue debt must not follow the slot to the
        // next model loaded into it (invariant 4, both paths).
        drr.deficit[1] = -7;
        drr.tick(&[2, 0], &[1, 1], 8); // fast path
        assert_eq!(drr.deficit[1], 0);
        drr.deficit[1] = -7;
        drr.tick(&[4, 0], &[1, 1], 2); // saturated path
        assert_eq!(drr.deficit[1], 0);
        let mut served = [0usize; 2];
        for _ in 0..200 {
            let g = drr.tick(&[4, 4], &[1, 1], 4);
            served[0] += g[0];
            served[1] += g[1];
        }
        assert!(
            served[0].abs_diff(served[1]) <= 4,
            "equal weights should split evenly after slot reuse: {served:?}"
        );
    }

    #[test]
    fn grows_with_the_slot_table() {
        // Hot load appends a slot mid-flight; the state vector follows.
        let mut drr = DrrState::new();
        assert_eq!(drr.tick(&[2], &[1], 4), vec![2]);
        assert_eq!(drr.tick(&[2, 2], &[1, 1], 8), vec![2, 2]);
    }
}
