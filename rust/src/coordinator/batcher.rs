//! Dynamic-batching flush policy + lane bookkeeping.
//!
//! The acoustic-model worker asks, each tick: *given which streams have a
//! frame ready and how long the oldest has waited, do I run a batch now or
//! wait for more?*  Policy (vLLM-router-ish, scaled to RNN streaming):
//!
//! - flush immediately when `ready ≥ max_batch`;
//! - otherwise flush when the oldest ready frame has waited ≥ `deadline`;
//! - otherwise wait (the worker parks on a condvar with a timeout).
//!
//! [`LaneAllocator`] tracks which arena lanes (stable per-stream slots in
//! the backend's [`crate::nn::model::BatchArena`]) are occupied.  Batch
//! formation order is priority-aware ([`schedule_cmp`]: QoS class first,
//! then longest wait — see [`crate::sched::Priority`]).  All of it is
//! pure decision logic — no clocks or locks — so it is property-testable.

use std::cmp::Ordering;
use std::time::Duration;

use crate::sched::Priority;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum streams per batched step.
    pub max_batch: usize,
    /// Longest a ready frame may wait for co-riders.
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 32 lanes (was 8): lanes are O(max_batch) pre-allocated state and
        // the packed-panel GEMM computes every active lane per panel pass,
        // so wider batches amortize weight streaming instead of re-reading
        // the matrix per stream — bench_e2e records the scaling curve in
        // BENCH_engine.json (ROADMAP "Bigger batches").
        BatchPolicy { max_batch: 32, deadline: default_deadline() }
    }
}

/// Parse a `QUANTASR_BATCH_DEADLINE_MS`-style value: non-negative, finite
/// milliseconds (fractions allowed).  Pure, so the accepted grammar is
/// testable without touching the process environment.
pub fn parse_deadline_ms(v: &str) -> Option<Duration> {
    match v.trim().parse::<f64>() {
        Ok(ms) if ms.is_finite() && ms >= 0.0 => Some(Duration::from_secs_f64(ms / 1e3)),
        _ => None,
    }
}

/// The built-in 5 ms deadline, overridable via `QUANTASR_BATCH_DEADLINE_MS`
/// (parsed once per process).  A malformed value warns and falls back —
/// tuning knobs must never panic a serving process.
fn default_deadline() -> Duration {
    static ONCE: std::sync::OnceLock<Duration> = std::sync::OnceLock::new();
    *ONCE.get_or_init(|| {
        let base = Duration::from_millis(5);
        match std::env::var("QUANTASR_BATCH_DEADLINE_MS") {
            Ok(v) => parse_deadline_ms(&v).unwrap_or_else(|| {
                eprintln!(
                    "QUANTASR_BATCH_DEADLINE_MS='{v}' is not a non-negative number of \
                     milliseconds; using the built-in 5 ms"
                );
                base
            }),
            Err(_) => base,
        }
    })
}

/// Batch-formation order for ready streams: QoS class first (Interactive
/// before Bulk), then longest wait.  The engine sorts its ready list with
/// this before planning lanes, so priorities shape both who rides a batch
/// when lanes are scarce and who gets to preempt first.
pub fn schedule_cmp(a: &(Priority, Duration), b: &(Priority, Duration)) -> Ordering {
    a.0.rank().cmp(&b.0.rank()).then(b.1.cmp(&a.1))
}

/// The decision for the current tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Run a batch over (up to max_batch) ready streams now.
    Flush,
    /// Park for at most this long, then re-evaluate.
    Wait(Duration),
    /// Nothing ready and nothing pending — park until woken.
    Idle,
}

impl BatchPolicy {
    /// `ready` = number of streams with a frame queued;
    /// `oldest_wait` = how long the longest-queued frame has waited.
    pub fn decide(&self, ready: usize, oldest_wait: Duration) -> Decision {
        if ready == 0 {
            return Decision::Idle;
        }
        if ready >= self.max_batch || oldest_wait >= self.deadline {
            return Decision::Flush;
        }
        Decision::Wait(self.deadline - oldest_wait)
    }
}

/// Occupancy tracking for the backend arena's lanes.
///
/// A stream acquires a lane when it is first scheduled, keeps it while it
/// lives in the arena (its recurrent state is lane-resident), and the lane
/// is released when the stream drains — or handed directly to another
/// stream on eviction (the allocator's occupancy doesn't change then).
/// Invariants (property-tested below): an acquired lane is `< capacity`
/// and never double-assigned; release of a free lane panics (double-free
/// is an engine bug, not a recoverable condition); no lanes leak.
#[derive(Clone, Debug)]
pub struct LaneAllocator {
    free: Vec<usize>,
    occupied: Vec<bool>,
}

impl LaneAllocator {
    pub fn new(capacity: usize) -> Self {
        LaneAllocator {
            // Pop order: lane 0 first (cosmetic, keeps traces readable).
            free: (0..capacity).rev().collect(),
            occupied: vec![false; capacity],
        }
    }

    pub fn capacity(&self) -> usize {
        self.occupied.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    /// Claim a free lane, if any.
    pub fn acquire(&mut self) -> Option<usize> {
        let lane = self.free.pop()?;
        debug_assert!(!self.occupied[lane]);
        self.occupied[lane] = true;
        Some(lane)
    }

    /// Return a lane to the free pool.  Panics on double-release or on a
    /// lane that was never handed out — both are engine logic errors.
    pub fn release(&mut self, lane: usize) {
        assert!(
            self.occupied.get(lane).copied().unwrap_or(false),
            "release of unoccupied lane {lane}"
        );
        self.occupied[lane] = false;
        self.free.push(lane);
    }
}

/// A FIFO per QoS class: `pop` serves the lowest [`Priority::rank`] with
/// work first, FIFO within a class.
///
/// Used for the decode queue: finalization (CTC beam + LM rescore) is the
/// heavy per-utterance tail, and a plain FIFO let an `Interactive`
/// finalize queue behind an arbitrary `Bulk` backlog — the one stage of
/// the pipeline where QoS didn't apply.  Starvation is not a concern the
/// way it is for lanes: decode jobs are finite (one per utterance) and
/// the pool drains them to completion, so bulk jobs are delayed, never
/// dropped.
#[derive(Debug)]
pub struct ClassQueue<T> {
    lanes: Vec<std::collections::VecDeque<T>>,
}

impl<T> Default for ClassQueue<T> {
    fn default() -> Self {
        ClassQueue {
            lanes: (0..Priority::NUM_CLASSES).map(|_| std::collections::VecDeque::new()).collect(),
        }
    }
}

impl<T> ClassQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, priority: Priority, item: T) {
        self.lanes[priority.rank() as usize].push_back(item);
    }

    /// Highest class first, FIFO within a class.
    pub fn pop(&mut self) -> Option<T> {
        self.lanes.iter_mut().find_map(|q| q.pop_front())
    }

    /// Pop up to `max` items in [`pop`](Self::pop) order — the shape the
    /// batched decode pool consumes (one flush's worth of finished
    /// utterances decoded together, sharing trie/LM lookup state).
    pub fn pop_up_to(&mut self, max: usize) -> Vec<T> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.pop() {
                Some(item) => out.push(item),
                None => break,
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|q| q.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn full_batch_flushes_immediately() {
        let p = BatchPolicy { max_batch: 4, deadline: Duration::from_millis(10) };
        assert_eq!(p.decide(4, Duration::ZERO), Decision::Flush);
        assert_eq!(p.decide(9, Duration::ZERO), Decision::Flush);
    }

    #[test]
    fn deadline_forces_flush() {
        let p = BatchPolicy { max_batch: 8, deadline: Duration::from_millis(5) };
        assert_eq!(p.decide(1, Duration::from_millis(5)), Decision::Flush);
        assert_eq!(p.decide(1, Duration::from_millis(50)), Decision::Flush);
    }

    #[test]
    fn partial_batch_waits_out_remaining_deadline() {
        let p = BatchPolicy { max_batch: 8, deadline: Duration::from_millis(10) };
        match p.decide(3, Duration::from_millis(4)) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_millis(6)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn empty_is_idle() {
        let p = BatchPolicy::default();
        assert_eq!(p.decide(0, Duration::ZERO), Decision::Idle);
        assert_eq!(p.decide(0, Duration::from_secs(1)), Decision::Idle);
    }

    #[test]
    fn decision_is_monotone_in_ready_and_wait() {
        // If (ready, wait) flushes, any (ready+, wait+) must also flush.
        forall("batcher monotone", 200, 0xBA7C, |g: &mut Gen| {
            let p = BatchPolicy {
                max_batch: g.usize_in(1, 16),
                deadline: Duration::from_micros(g.usize_in(0, 20_000) as u64),
            };
            let ready = g.usize_in(0, 20);
            let wait = Duration::from_micros(g.usize_in(0, 30_000) as u64);
            if p.decide(ready, wait) == Decision::Flush {
                assert_eq!(p.decide(ready + 1, wait), Decision::Flush);
                assert_eq!(
                    p.decide(ready, wait + Duration::from_millis(1)),
                    Decision::Flush
                );
            }
        });
    }

    #[test]
    fn lane_allocator_no_reuse_while_occupied_no_leaks() {
        forall("lane allocator", 200, 0x1A9E5, |g: &mut Gen| {
            let cap = g.usize_in(1, 16);
            let mut a = LaneAllocator::new(cap);
            let mut held: Vec<usize> = Vec::new();
            let ops = g.usize_in(1, 64);
            for _ in 0..ops {
                if held.is_empty() || g.bool() {
                    match a.acquire() {
                        Some(l) => {
                            assert!(l < cap, "lane {l} out of range");
                            assert!(!held.contains(&l), "lane {l} reused while occupied");
                            held.push(l);
                        }
                        None => assert_eq!(held.len(), cap, "acquire failed with free lanes"),
                    }
                } else {
                    let i = g.usize_in(0, held.len() - 1);
                    let l = held.swap_remove(i);
                    a.release(l);
                }
                assert_eq!(a.in_use(), held.len());
                assert_eq!(a.capacity(), cap);
            }
            // No leaks: after releasing everything, the full capacity is
            // acquirable exactly once.
            for l in held.drain(..) {
                a.release(l);
            }
            assert_eq!(a.in_use(), 0);
            let mut all: Vec<usize> = (0..cap).map(|_| a.acquire().unwrap()).collect();
            all.sort_unstable();
            assert_eq!(all, (0..cap).collect::<Vec<usize>>());
            assert!(a.acquire().is_none());
        });
    }

    #[test]
    #[should_panic(expected = "release of unoccupied lane")]
    fn lane_allocator_double_release_panics() {
        let mut a = LaneAllocator::new(2);
        let l = a.acquire().unwrap();
        a.release(l);
        a.release(l);
    }

    #[test]
    fn deadline_grammar() {
        assert_eq!(parse_deadline_ms("5"), Some(Duration::from_millis(5)));
        assert_eq!(parse_deadline_ms(" 2.5 "), Some(Duration::from_micros(2500)));
        assert_eq!(parse_deadline_ms("0"), Some(Duration::ZERO));
        assert_eq!(parse_deadline_ms("-1"), None);
        assert_eq!(parse_deadline_ms("NaN"), None);
        assert_eq!(parse_deadline_ms("inf"), None);
        assert_eq!(parse_deadline_ms("5ms"), None);
        assert_eq!(parse_deadline_ms(""), None);
    }

    #[test]
    fn schedule_order_is_class_then_wait() {
        use crate::sched::Priority::{Bulk, Interactive};
        let ms = Duration::from_millis;
        let mut v = vec![
            (Bulk, ms(50)),
            (Interactive, ms(1)),
            (Bulk, ms(2)),
            (Interactive, ms(30)),
        ];
        v.sort_by(schedule_cmp);
        assert_eq!(
            v,
            vec![
                (Interactive, ms(30)),
                (Interactive, ms(1)),
                (Bulk, ms(50)),
                (Bulk, ms(2)),
            ]
        );
        // Total order sanity under random inputs: interactive never sorts
        // after bulk, and within a class longer waits sort first.
        forall("schedule_cmp order", 200, 0x0DE5, |g: &mut Gen| {
            let n = g.usize_in(2, 12);
            let mut v: Vec<(crate::sched::Priority, Duration)> = (0..n)
                .map(|_| {
                    let p = if g.bool() { Interactive } else { Bulk };
                    (p, Duration::from_micros(g.usize_in(0, 10_000) as u64))
                })
                .collect();
            v.sort_by(schedule_cmp);
            for w in v.windows(2) {
                assert!(w[0].0.rank() <= w[1].0.rank());
                if w[0].0 == w[1].0 {
                    assert!(w[0].1 >= w[1].1);
                }
            }
        });
    }

    #[test]
    fn interactive_finalize_jumps_a_bulk_backlog() {
        // The decode-queue regression test (ROADMAP "priority-aware
        // decode queue"): an interactive job pushed behind a bulk backlog
        // pops first; within a class order stays FIFO.
        use crate::sched::Priority::{Bulk, Interactive};
        let mut q = ClassQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        q.push(Bulk, 10);
        q.push(Bulk, 11);
        q.push(Interactive, 1);
        q.push(Bulk, 12);
        q.push(Interactive, 2);
        assert_eq!(q.len(), 5);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), Some(12));
        assert!(q.is_empty());
    }

    #[test]
    fn pop_up_to_preserves_class_then_fifo_order() {
        use crate::sched::Priority::{Bulk, Interactive};
        let mut q = ClassQueue::new();
        q.push(Bulk, 10);
        q.push(Interactive, 1);
        q.push(Bulk, 11);
        q.push(Interactive, 2);
        q.push(Bulk, 12);
        assert_eq!(q.pop_up_to(3), vec![1, 2, 10]);
        assert_eq!(q.pop_up_to(0), Vec::<usize>::new());
        assert_eq!(q.pop_up_to(9), vec![11, 12]);
        assert!(q.is_empty());
    }

    #[test]
    fn class_queue_conserves_items() {
        forall("class queue conservation", 200, 0xC1A5, |g: &mut Gen| {
            use crate::sched::Priority::{Bulk, Interactive};
            let mut q = ClassQueue::new();
            let n = g.usize_in(0, 24);
            let mut pushed_ia = Vec::new();
            let mut pushed_bulk = Vec::new();
            for i in 0..n {
                if g.bool() {
                    q.push(Interactive, i);
                    pushed_ia.push(i);
                } else {
                    q.push(Bulk, i);
                    pushed_bulk.push(i);
                }
            }
            assert_eq!(q.len(), n);
            let mut popped = Vec::new();
            while let Some(v) = q.pop() {
                popped.push(v);
            }
            // All interactive items first (their FIFO order), then bulk.
            let want: Vec<usize> =
                pushed_ia.iter().chain(pushed_bulk.iter()).copied().collect();
            assert_eq!(popped, want);
        });
    }

    #[test]
    fn wait_never_exceeds_deadline() {
        forall("batcher wait bound", 200, 0xBA7D, |g: &mut Gen| {
            let p = BatchPolicy {
                max_batch: g.usize_in(2, 16),
                deadline: Duration::from_micros(g.usize_in(1, 20_000) as u64),
            };
            let ready = g.usize_in(1, p.max_batch - 1);
            let wait = Duration::from_micros(g.usize_in(0, 20_000) as u64);
            if let Decision::Wait(d) = p.decide(ready, wait) {
                assert!(d <= p.deadline);
                assert!(wait + d >= p.deadline);
            }
        });
    }
}
