//! Dynamic-batching flush policy + lane bookkeeping.
//!
//! The acoustic-model worker asks, each tick: *given which streams have a
//! frame ready and how long the oldest has waited, do I run a batch now or
//! wait for more?*  Policy (vLLM-router-ish, scaled to RNN streaming):
//!
//! - flush immediately when `ready ≥ max_batch`;
//! - otherwise flush when the oldest ready frame has waited ≥ `deadline`;
//! - otherwise wait (the worker parks on a condvar with a timeout).
//!
//! [`LaneAllocator`] tracks which arena lanes (stable per-stream slots in
//! the backend's [`crate::nn::model::BatchArena`]) are occupied.  Both are
//! pure decision logic — no clocks or locks — so they are
//! property-testable.

use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum streams per batched step.
    pub max_batch: usize,
    /// Longest a ready frame may wait for co-riders.
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 32 lanes (was 8): lanes are O(max_batch) pre-allocated state and
        // the packed-panel GEMM computes every active lane per panel pass,
        // so wider batches amortize weight streaming instead of re-reading
        // the matrix per stream — bench_e2e records the scaling curve in
        // BENCH_engine.json (ROADMAP "Bigger batches").
        BatchPolicy { max_batch: 32, deadline: Duration::from_millis(5) }
    }
}

/// The decision for the current tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Run a batch over (up to max_batch) ready streams now.
    Flush,
    /// Park for at most this long, then re-evaluate.
    Wait(Duration),
    /// Nothing ready and nothing pending — park until woken.
    Idle,
}

impl BatchPolicy {
    /// `ready` = number of streams with a frame queued;
    /// `oldest_wait` = how long the longest-queued frame has waited.
    pub fn decide(&self, ready: usize, oldest_wait: Duration) -> Decision {
        if ready == 0 {
            return Decision::Idle;
        }
        if ready >= self.max_batch || oldest_wait >= self.deadline {
            return Decision::Flush;
        }
        Decision::Wait(self.deadline - oldest_wait)
    }
}

/// Occupancy tracking for the backend arena's lanes.
///
/// A stream acquires a lane when it is first scheduled, keeps it while it
/// lives in the arena (its recurrent state is lane-resident), and the lane
/// is released when the stream drains — or handed directly to another
/// stream on eviction (the allocator's occupancy doesn't change then).
/// Invariants (property-tested below): an acquired lane is `< capacity`
/// and never double-assigned; release of a free lane panics (double-free
/// is an engine bug, not a recoverable condition); no lanes leak.
#[derive(Clone, Debug)]
pub struct LaneAllocator {
    free: Vec<usize>,
    occupied: Vec<bool>,
}

impl LaneAllocator {
    pub fn new(capacity: usize) -> Self {
        LaneAllocator {
            // Pop order: lane 0 first (cosmetic, keeps traces readable).
            free: (0..capacity).rev().collect(),
            occupied: vec![false; capacity],
        }
    }

    pub fn capacity(&self) -> usize {
        self.occupied.len()
    }

    pub fn in_use(&self) -> usize {
        self.capacity() - self.free.len()
    }

    /// Claim a free lane, if any.
    pub fn acquire(&mut self) -> Option<usize> {
        let lane = self.free.pop()?;
        debug_assert!(!self.occupied[lane]);
        self.occupied[lane] = true;
        Some(lane)
    }

    /// Return a lane to the free pool.  Panics on double-release or on a
    /// lane that was never handed out — both are engine logic errors.
    pub fn release(&mut self, lane: usize) {
        assert!(
            self.occupied.get(lane).copied().unwrap_or(false),
            "release of unoccupied lane {lane}"
        );
        self.occupied[lane] = false;
        self.free.push(lane);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn full_batch_flushes_immediately() {
        let p = BatchPolicy { max_batch: 4, deadline: Duration::from_millis(10) };
        assert_eq!(p.decide(4, Duration::ZERO), Decision::Flush);
        assert_eq!(p.decide(9, Duration::ZERO), Decision::Flush);
    }

    #[test]
    fn deadline_forces_flush() {
        let p = BatchPolicy { max_batch: 8, deadline: Duration::from_millis(5) };
        assert_eq!(p.decide(1, Duration::from_millis(5)), Decision::Flush);
        assert_eq!(p.decide(1, Duration::from_millis(50)), Decision::Flush);
    }

    #[test]
    fn partial_batch_waits_out_remaining_deadline() {
        let p = BatchPolicy { max_batch: 8, deadline: Duration::from_millis(10) };
        match p.decide(3, Duration::from_millis(4)) {
            Decision::Wait(d) => assert_eq!(d, Duration::from_millis(6)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn empty_is_idle() {
        let p = BatchPolicy::default();
        assert_eq!(p.decide(0, Duration::ZERO), Decision::Idle);
        assert_eq!(p.decide(0, Duration::from_secs(1)), Decision::Idle);
    }

    #[test]
    fn decision_is_monotone_in_ready_and_wait() {
        // If (ready, wait) flushes, any (ready+, wait+) must also flush.
        forall("batcher monotone", 200, 0xBA7C, |g: &mut Gen| {
            let p = BatchPolicy {
                max_batch: g.usize_in(1, 16),
                deadline: Duration::from_micros(g.usize_in(0, 20_000) as u64),
            };
            let ready = g.usize_in(0, 20);
            let wait = Duration::from_micros(g.usize_in(0, 30_000) as u64);
            if p.decide(ready, wait) == Decision::Flush {
                assert_eq!(p.decide(ready + 1, wait), Decision::Flush);
                assert_eq!(
                    p.decide(ready, wait + Duration::from_millis(1)),
                    Decision::Flush
                );
            }
        });
    }

    #[test]
    fn lane_allocator_no_reuse_while_occupied_no_leaks() {
        forall("lane allocator", 200, 0x1A9E5, |g: &mut Gen| {
            let cap = g.usize_in(1, 16);
            let mut a = LaneAllocator::new(cap);
            let mut held: Vec<usize> = Vec::new();
            let ops = g.usize_in(1, 64);
            for _ in 0..ops {
                if held.is_empty() || g.bool() {
                    match a.acquire() {
                        Some(l) => {
                            assert!(l < cap, "lane {l} out of range");
                            assert!(!held.contains(&l), "lane {l} reused while occupied");
                            held.push(l);
                        }
                        None => assert_eq!(held.len(), cap, "acquire failed with free lanes"),
                    }
                } else {
                    let i = g.usize_in(0, held.len() - 1);
                    let l = held.swap_remove(i);
                    a.release(l);
                }
                assert_eq!(a.in_use(), held.len());
                assert_eq!(a.capacity(), cap);
            }
            // No leaks: after releasing everything, the full capacity is
            // acquirable exactly once.
            for l in held.drain(..) {
                a.release(l);
            }
            assert_eq!(a.in_use(), 0);
            let mut all: Vec<usize> = (0..cap).map(|_| a.acquire().unwrap()).collect();
            all.sort_unstable();
            assert_eq!(all, (0..cap).collect::<Vec<usize>>());
            assert!(a.acquire().is_none());
        });
    }

    #[test]
    #[should_panic(expected = "release of unoccupied lane")]
    fn lane_allocator_double_release_panics() {
        let mut a = LaneAllocator::new(2);
        let l = a.acquire().unwrap();
        a.release(l);
        a.release(l);
    }

    #[test]
    fn wait_never_exceeds_deadline() {
        forall("batcher wait bound", 200, 0xBA7D, |g: &mut Gen| {
            let p = BatchPolicy {
                max_batch: g.usize_in(2, 16),
                deadline: Duration::from_micros(g.usize_in(1, 20_000) as u64),
            };
            let ready = g.usize_in(1, p.max_batch - 1);
            let wait = Duration::from_micros(g.usize_in(0, 20_000) as u64);
            if let Decision::Wait(d) = p.decide(ready, wait) {
                assert!(d <= p.deadline);
                assert!(wait + d >= p.deadline);
            }
        });
    }
}
