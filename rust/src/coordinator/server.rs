//! TCP streaming protocol: one recognition stream per connection.
//!
//! Little-endian framing, client → server:
//! ```text
//! 'P' u8               QoS class (0 = interactive, 1 = bulk); optional,
//!                      must precede the first audio chunk
//! 'A' u32 n  f32×n     audio chunk (PCM at 8 kHz)
//! 'E'                  end of audio
//! ```
//! server → client:
//! ```text
//! 'F' u32 n  u32×n  u32 m  u32×m  f32 latency_ms
//!     final words, greedy phones, finalize latency
//! 'R' u32 n  bytes×n
//!     admission rejected (reason text); the connection then closes
//! ```
//!
//! A thread per connection feeds the shared [`Engine`] — batching happens
//! across connections inside the engine, not per socket.  The stream is
//! opened lazily at the first `'A'`/`'E'` so the `'P'` class can ride the
//! admission request; when the engine's admission controller rejects
//! (live-stream cap, see [`crate::sched::admission`]), the client gets an
//! `'R'` frame with the [`crate::sched::RejectReason`] text instead of a
//! hung connection.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::engine::{Engine, FinalResult};
use crate::runtime::backend::AmBackend;
use crate::sched::{Priority, StreamOptions};

/// Serve until `stop` is set.  Returns the bound local address via the
/// callback (useful with port 0 in tests).  Generic over the engine's
/// execution backend — batching happens across connections inside the
/// engine regardless of what executes the model.
pub fn serve<B: AmBackend>(
    engine: Arc<Engine<B>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut handles = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let eng = engine.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(eng, stream) {
                        eprintln!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn<B: AmBackend>(engine: Arc<Engine<B>>, mut sock: TcpStream) -> Result<()> {
    sock.set_nodelay(true).ok();
    let mut opened: Option<(u64, Receiver<FinalResult>)> = None;
    let r = conn_loop(&engine, &mut sock, &mut opened);
    // Whatever ended the loop (peer vanished, protocol error, engine
    // error), never leak a live stream: one left open here would hold an
    // admission slot forever, and enough broken connections would wedge
    // the engine at its live-stream cap.  Finishing drains it.
    if let Some((id, rx)) = opened {
        let _ = engine.finish_stream(id);
        let _ = rx.recv();
    }
    r
}

fn conn_loop<B: AmBackend>(
    engine: &Arc<Engine<B>>,
    sock: &mut TcpStream,
    opened: &mut Option<(u64, Receiver<FinalResult>)>,
) -> Result<()> {
    let mut opts = StreamOptions::default();
    // A rejected connection keeps draining the client's audio (discarded)
    // and delivers the 'R' frame at 'E' — writing it mid-stream and
    // closing would race the client's in-flight sends into a broken pipe
    // and the reason would be lost with the connection reset.
    let mut rejected: Option<String> = None;
    loop {
        let mut tag = [0u8; 1];
        if sock.read_exact(&mut tag).is_err() {
            // peer vanished: the caller finishes what we have
            return Ok(());
        }
        // Open lazily so a preceding 'P' can set the admission class.
        if matches!(tag[0], b'A' | b'E') && opened.is_none() && rejected.is_none() {
            match engine.try_open_stream(opts) {
                Ok(o) => *opened = Some(o),
                Err(reason) => rejected = Some(reason.to_string()),
            }
        }
        match tag[0] {
            b'P' => {
                let mut class = [0u8; 1];
                sock.read_exact(&mut class)?;
                if opened.is_some() {
                    bail!("'P' after the stream was opened");
                }
                match Priority::from_wire(class[0]) {
                    Some(p) => opts.priority = p,
                    None => bail!("unknown priority class {}", class[0]),
                }
            }
            b'A' => {
                let n = read_u32(sock)? as usize;
                if n > 10_000_000 {
                    bail!("oversized audio chunk ({n})");
                }
                let mut raw = vec![0u8; n * 4];
                sock.read_exact(&mut raw)?;
                if rejected.is_some() {
                    continue; // drained, not served
                }
                let pcm: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let (id, _) = opened.as_ref().unwrap();
                engine.push_audio(*id, &pcm)?;
            }
            b'E' => {
                if let Some(reason) = rejected {
                    write_reject(sock, &reason)?;
                    return Ok(());
                }
                let (id, rx) = opened.take().unwrap();
                engine.finish_stream(id)?;
                let result = rx.recv()?;
                write_final(sock, &result)?;
                return Ok(());
            }
            other => bail!("unknown message tag {other:#x}"),
        }
    }
}

fn write_final(sock: &mut TcpStream, r: &FinalResult) -> Result<()> {
    let mut buf = Vec::with_capacity(16 + 4 * (r.words.len() + r.phones.len()));
    buf.push(b'F');
    buf.extend_from_slice(&(r.words.len() as u32).to_le_bytes());
    for w in &r.words {
        buf.extend_from_slice(&w.to_le_bytes());
    }
    buf.extend_from_slice(&(r.phones.len() as u32).to_le_bytes());
    for p in &r.phones {
        buf.extend_from_slice(&p.to_le_bytes());
    }
    buf.extend_from_slice(&((r.finalize_latency.as_secs_f64() * 1e3) as f32).to_le_bytes());
    sock.write_all(&buf)?;
    Ok(())
}

fn write_reject(sock: &mut TcpStream, reason: &str) -> Result<()> {
    let bytes = reason.as_bytes();
    let mut buf = Vec::with_capacity(5 + bytes.len());
    buf.push(b'R');
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    sock.write_all(&buf)?;
    Ok(())
}

fn read_u32(sock: &mut TcpStream) -> Result<u32> {
    let mut b = [0u8; 4];
    sock.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Blocking client for the protocol above (used by examples/benches).
pub struct Client {
    sock: TcpStream,
}

/// Client-side view of a final result.
#[derive(Clone, Debug)]
pub struct ClientResult {
    pub words: Vec<u32>,
    pub phones: Vec<u32>,
    pub server_latency_ms: f32,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let sock = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        sock.set_nodelay(true).ok();
        Ok(Client { sock })
    }

    /// Declare the stream's QoS class.  Must precede the first audio
    /// chunk (the class rides the admission request).
    pub fn set_priority(&mut self, p: Priority) -> Result<()> {
        self.sock.write_all(&[b'P', p.to_wire()])?;
        Ok(())
    }

    pub fn send_audio(&mut self, pcm: &[f32]) -> Result<()> {
        let mut buf = Vec::with_capacity(5 + pcm.len() * 4);
        buf.push(b'A');
        buf.extend_from_slice(&(pcm.len() as u32).to_le_bytes());
        for v in pcm {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.sock.write_all(&buf)?;
        Ok(())
    }

    /// End the stream and read the final result.  An admission rejection
    /// ('R' frame) surfaces as an error carrying the server's reason.
    pub fn finish(mut self) -> Result<ClientResult> {
        self.sock.write_all(b"E")?;
        let mut tag = [0u8; 1];
        self.sock.read_exact(&mut tag)?;
        if tag[0] == b'R' {
            let n = read_u32(&mut self.sock)? as usize;
            if n > 65536 {
                bail!("oversized reject reason ({n})");
            }
            let mut raw = vec![0u8; n];
            self.sock.read_exact(&mut raw)?;
            bail!("admission rejected: {}", String::from_utf8_lossy(&raw));
        }
        if tag[0] != b'F' {
            bail!("expected final frame, got {:#x}", tag[0]);
        }
        let n = read_u32(&mut self.sock)? as usize;
        let mut words = Vec::with_capacity(n);
        for _ in 0..n {
            words.push(read_u32(&mut self.sock)?);
        }
        let m = read_u32(&mut self.sock)? as usize;
        let mut phones = Vec::with_capacity(m);
        for _ in 0..m {
            phones.push(read_u32(&mut self.sock)?);
        }
        let mut lat = [0u8; 4];
        self.sock.read_exact(&mut lat)?;
        Ok(ClientResult {
            words,
            phones,
            server_latency_ms: f32::from_le_bytes(lat),
        })
    }
}
