//! TCP streaming protocol: one recognition stream per connection.
//!
//! Little-endian framing, client → server:
//! ```text
//! 'A' u32 n  f32×n     audio chunk (PCM at 8 kHz)
//! 'E'                  end of audio
//! ```
//! server → client:
//! ```text
//! 'F' u32 n  u32×n  u32 m  u32×m  f32 latency_ms
//!     final words, greedy phones, finalize latency
//! ```
//!
//! A thread per connection feeds the shared [`Engine`] — batching happens
//! across connections inside the engine, not per socket.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::engine::{Engine, FinalResult};
use crate::runtime::backend::AmBackend;

/// Serve until `stop` is set.  Returns the bound local address via the
/// callback (useful with port 0 in tests).  Generic over the engine's
/// execution backend — batching happens across connections inside the
/// engine regardless of what executes the model.
pub fn serve<B: AmBackend>(
    engine: Arc<Engine<B>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut handles = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let eng = engine.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(eng, stream) {
                        eprintln!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn<B: AmBackend>(engine: Arc<Engine<B>>, mut sock: TcpStream) -> Result<()> {
    sock.set_nodelay(true).ok();
    let (id, rx) = engine.open_stream();
    loop {
        let mut tag = [0u8; 1];
        if sock.read_exact(&mut tag).is_err() {
            // peer vanished: finish what we have
            engine.finish_stream(id)?;
            let _ = rx.recv();
            return Ok(());
        }
        match tag[0] {
            b'A' => {
                let n = read_u32(&mut sock)? as usize;
                if n > 10_000_000 {
                    bail!("oversized audio chunk ({n})");
                }
                let mut raw = vec![0u8; n * 4];
                sock.read_exact(&mut raw)?;
                let pcm: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                engine.push_audio(id, &pcm)?;
            }
            b'E' => {
                engine.finish_stream(id)?;
                let result = rx.recv()?;
                write_final(&mut sock, &result)?;
                return Ok(());
            }
            other => bail!("unknown message tag {other:#x}"),
        }
    }
}

fn write_final(sock: &mut TcpStream, r: &FinalResult) -> Result<()> {
    let mut buf = Vec::with_capacity(16 + 4 * (r.words.len() + r.phones.len()));
    buf.push(b'F');
    buf.extend_from_slice(&(r.words.len() as u32).to_le_bytes());
    for w in &r.words {
        buf.extend_from_slice(&w.to_le_bytes());
    }
    buf.extend_from_slice(&(r.phones.len() as u32).to_le_bytes());
    for p in &r.phones {
        buf.extend_from_slice(&p.to_le_bytes());
    }
    buf.extend_from_slice(&((r.finalize_latency.as_secs_f64() * 1e3) as f32).to_le_bytes());
    sock.write_all(&buf)?;
    Ok(())
}

fn read_u32(sock: &mut TcpStream) -> Result<u32> {
    let mut b = [0u8; 4];
    sock.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Blocking client for the protocol above (used by examples/benches).
pub struct Client {
    sock: TcpStream,
}

/// Client-side view of a final result.
#[derive(Clone, Debug)]
pub struct ClientResult {
    pub words: Vec<u32>,
    pub phones: Vec<u32>,
    pub server_latency_ms: f32,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let sock = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        sock.set_nodelay(true).ok();
        Ok(Client { sock })
    }

    pub fn send_audio(&mut self, pcm: &[f32]) -> Result<()> {
        let mut buf = Vec::with_capacity(5 + pcm.len() * 4);
        buf.push(b'A');
        buf.extend_from_slice(&(pcm.len() as u32).to_le_bytes());
        for v in pcm {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.sock.write_all(&buf)?;
        Ok(())
    }

    /// End the stream and read the final result.
    pub fn finish(mut self) -> Result<ClientResult> {
        self.sock.write_all(b"E")?;
        let mut tag = [0u8; 1];
        self.sock.read_exact(&mut tag)?;
        if tag[0] != b'F' {
            bail!("expected final frame, got {:#x}", tag[0]);
        }
        let n = read_u32(&mut self.sock)? as usize;
        let mut words = Vec::with_capacity(n);
        for _ in 0..n {
            words.push(read_u32(&mut self.sock)?);
        }
        let m = read_u32(&mut self.sock)? as usize;
        let mut phones = Vec::with_capacity(m);
        for _ in 0..m {
            phones.push(read_u32(&mut self.sock)?);
        }
        let mut lat = [0u8; 4];
        self.sock.read_exact(&mut lat)?;
        Ok(ClientResult {
            words,
            phones,
            server_latency_ms: f32::from_le_bytes(lat),
        })
    }
}
