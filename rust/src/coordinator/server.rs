//! TCP streaming + fleet-admin protocol.
//!
//! **The normative wire specification lives in `docs/PROTOCOL.md`** —
//! frame layouts, reject-reason codes, the lazy-stream-open handshake and
//! the admin-frame lifecycle are defined there; this header is only a
//! summary.  Little-endian framing, client → server:
//!
//! ```text
//! 'P' u8               QoS class (0 = interactive, 1 = bulk); optional,
//!                      must precede the first audio chunk
//! 'M' u32              target model id; optional, must precede the
//!                      first audio chunk (default model 0)
//! 'A' u32 n  f32×n     audio chunk (PCM at 8 kHz)
//! 'E'                  end of audio
//! 'L' u32 w  u32 l  u32 n  bytes×n
//!                      admin: hot-load the model at path (weight w,
//!                      lanes l, 0 = engine default)
//! 'U' u32 id           admin: drain + unload model id
//! 'Q'                  admin: query the live registry
//! ```
//! server → client:
//! ```text
//! 'F' u32 n  u32×n  u32 m  u32×m  f32 latency_ms
//!     final words, greedy phones, finalize latency
//! 'R' u32 n  bytes×n
//!     rejection/failure reason text.  After a stream-admission reject
//!     (delivered at 'E') the connection closes; after an admin failure
//!     the connection stays usable.
//! 'O' u32 v
//!     admin success (the loaded/unloaded model id)
//! 'Q' u32 count  { u32 id  u8 draining  u32 weight  u32 lanes
//!                  u32 live  u32 n  bytes×n }×count
//!     registry snapshot
//! ```
//!
//! A thread per connection feeds the shared [`Engine`] — batching happens
//! across connections inside the engine, not per socket.  The stream is
//! opened lazily at the first `'A'`/`'E'` so the `'P'`/`'M'` options can
//! ride the admission request; when the engine's admission controller
//! rejects (live-stream cap, unknown or draining model — see
//! [`crate::sched::admission`]), the client gets an `'R'` frame with the
//! [`crate::sched::RejectReason`] text instead of a hung connection.
//! The mutating admin frames (`'L'`/`'U'`) are only valid before a
//! stream opens on the connection; the read-only `'Q'` is valid at any
//! time.  `'L'` requires the server to have been started with a
//! [`ModelLoader`] ([`serve_with_loader`]), `'U'` blocks its connection
//! thread until the model's drain completes (a never-finishing stream
//! holds it indefinitely — close that stream's connection to unstick).
//!
//! **Trust model.**  Admin frames share the serving socket and are
//! unauthenticated: anyone who can open a stream can also load/unload
//! models.  Keep the listener on a trusted interface (the default bind
//! is loopback) or front it with network policy; a separate
//! authenticated admin socket is a ROADMAP follow-on.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::engine::{Engine, FinalResult, ModelInfo};
use crate::runtime::backend::AmBackend;
use crate::sched::{ModelParams, Priority, StreamOptions};

/// Backend factory for the `'L'` admin frame: maps the client-supplied
/// model path/spec to a loaded backend.  Servers that don't install one
/// reject `'L'` with a reason (the rest of the protocol is unaffected).
pub type ModelLoader<B> = Arc<dyn Fn(&str) -> Result<Arc<B>> + Send + Sync>;

/// Serve until `stop` is set, with hot model loading disabled (`'L'`
/// frames are rejected with a reason; `'U'`/`'Q'` still work).  Returns
/// the bound local address via the callback (useful with port 0 in
/// tests).  Generic over the engine's execution backend — batching
/// happens across connections inside the engine regardless of what
/// executes the model.
pub fn serve<B: AmBackend>(
    engine: Arc<Engine<B>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_with_loader(engine, addr, stop, None, on_bound)
}

/// [`serve`], plus a [`ModelLoader`] that backs the `'L'` hot-load admin
/// frame.
pub fn serve_with_loader<B: AmBackend>(
    engine: Arc<Engine<B>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    loader: Option<ModelLoader<B>>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut handles = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let eng = engine.clone();
                let ldr = loader.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(eng, ldr, stream) {
                        eprintln!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn<B: AmBackend>(
    engine: Arc<Engine<B>>,
    loader: Option<ModelLoader<B>>,
    mut sock: TcpStream,
) -> Result<()> {
    sock.set_nodelay(true).ok();
    let mut opened: Option<(u64, Receiver<FinalResult>)> = None;
    let r = conn_loop(&engine, &loader, &mut sock, &mut opened);
    // Whatever ended the loop (peer vanished, protocol error, engine
    // error), never leak a live stream: one left open here would hold an
    // admission slot forever, and enough broken connections would wedge
    // the engine at its live-stream cap.  Finishing drains it.
    if let Some((id, rx)) = opened {
        let _ = engine.finish_stream(id);
        let _ = rx.recv();
    }
    r
}

fn conn_loop<B: AmBackend>(
    engine: &Arc<Engine<B>>,
    loader: &Option<ModelLoader<B>>,
    sock: &mut TcpStream,
    opened: &mut Option<(u64, Receiver<FinalResult>)>,
) -> Result<()> {
    let mut opts = StreamOptions::default();
    // A rejected connection keeps draining the client's audio (discarded)
    // and delivers the 'R' frame at 'E' — writing it mid-stream and
    // closing would race the client's in-flight sends into a broken pipe
    // and the reason would be lost with the connection reset.
    let mut rejected: Option<String> = None;
    loop {
        let mut tag = [0u8; 1];
        if sock.read_exact(&mut tag).is_err() {
            // peer vanished: the caller finishes what we have
            return Ok(());
        }
        // Open lazily so preceding 'P'/'M' can set the admission options.
        if matches!(tag[0], b'A' | b'E') && opened.is_none() && rejected.is_none() {
            match engine.try_open_stream(opts) {
                Ok(o) => *opened = Some(o),
                Err(reason) => rejected = Some(reason.to_string()),
            }
        }
        match tag[0] {
            b'P' => {
                let mut class = [0u8; 1];
                sock.read_exact(&mut class)?;
                if opened.is_some() {
                    bail!("'P' after the stream was opened");
                }
                match Priority::from_wire(class[0]) {
                    Some(p) => opts.priority = p,
                    None => bail!("unknown priority class {}", class[0]),
                }
            }
            b'M' => {
                let model = read_u32(sock)? as usize;
                if opened.is_some() {
                    bail!("'M' after the stream was opened");
                }
                // Validity is the admission controller's call (unknown /
                // draining models reject at open with a reason).
                opts.model = model;
            }
            b'A' => {
                let n = read_u32(sock)? as usize;
                if n > 10_000_000 {
                    bail!("oversized audio chunk ({n})");
                }
                let mut raw = vec![0u8; n * 4];
                sock.read_exact(&mut raw)?;
                if rejected.is_some() {
                    continue; // drained, not served
                }
                let pcm: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let (id, _) = opened.as_ref().unwrap();
                engine.push_audio(*id, &pcm)?;
            }
            b'E' => {
                if let Some(reason) = rejected {
                    write_reject(sock, &reason)?;
                    return Ok(());
                }
                let (id, rx) = opened.take().unwrap();
                engine.finish_stream(id)?;
                let result = rx.recv()?;
                write_final(sock, &result)?;
                return Ok(());
            }
            b'L' => {
                let weight = read_u32(sock)?;
                let lanes = read_u32(sock)? as usize;
                let n = read_u32(sock)? as usize;
                if n > 4096 {
                    bail!("oversized model path ({n})");
                }
                let mut raw = vec![0u8; n];
                sock.read_exact(&mut raw)?;
                if opened.is_some() {
                    bail!("'L' after the stream was opened");
                }
                let path = String::from_utf8_lossy(&raw).to_string();
                let outcome = match loader {
                    None => Err("no model loader configured on this server".to_string()),
                    Some(load) => match load.as_ref()(&path) {
                        Ok(backend) => {
                            let params = ModelParams {
                                weight,
                                lanes: if lanes == 0 { None } else { Some(lanes) },
                            };
                            engine.load_model(backend, params)
                        }
                        Err(e) => Err(format!("load '{path}': {e:#}")),
                    },
                };
                match outcome {
                    Ok(id) => write_ok(sock, id as u32)?,
                    Err(reason) => write_reject(sock, &reason)?,
                }
            }
            b'U' => {
                let id = read_u32(sock)? as usize;
                if opened.is_some() {
                    bail!("'U' after the stream was opened");
                }
                // Blocks this connection thread until the drain completes
                // (the engine keeps serving everyone else meanwhile).
                match engine.unload_model(id) {
                    Ok(()) => write_ok(sock, id as u32)?,
                    Err(reason) => write_reject(sock, &reason)?,
                }
            }
            b'Q' => {
                write_registry(sock, &engine.registry())?;
            }
            other => bail!("unknown message tag {other:#x}"),
        }
    }
}

fn write_final(sock: &mut TcpStream, r: &FinalResult) -> Result<()> {
    let mut buf = Vec::with_capacity(16 + 4 * (r.words.len() + r.phones.len()));
    buf.push(b'F');
    buf.extend_from_slice(&(r.words.len() as u32).to_le_bytes());
    for w in &r.words {
        buf.extend_from_slice(&w.to_le_bytes());
    }
    buf.extend_from_slice(&(r.phones.len() as u32).to_le_bytes());
    for p in &r.phones {
        buf.extend_from_slice(&p.to_le_bytes());
    }
    buf.extend_from_slice(&((r.finalize_latency.as_secs_f64() * 1e3) as f32).to_le_bytes());
    sock.write_all(&buf)?;
    Ok(())
}

fn write_reject(sock: &mut TcpStream, reason: &str) -> Result<()> {
    let bytes = reason.as_bytes();
    let mut buf = Vec::with_capacity(5 + bytes.len());
    buf.push(b'R');
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    sock.write_all(&buf)?;
    Ok(())
}

fn write_ok(sock: &mut TcpStream, v: u32) -> Result<()> {
    let mut buf = Vec::with_capacity(5);
    buf.push(b'O');
    buf.extend_from_slice(&v.to_le_bytes());
    sock.write_all(&buf)?;
    Ok(())
}

fn write_registry(sock: &mut TcpStream, entries: &[ModelInfo]) -> Result<()> {
    let mut buf = vec![b'Q'];
    buf.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for e in entries {
        buf.extend_from_slice(&(e.id as u32).to_le_bytes());
        buf.push(e.draining as u8);
        buf.extend_from_slice(&e.weight.to_le_bytes());
        buf.extend_from_slice(&(e.lanes as u32).to_le_bytes());
        buf.extend_from_slice(&(e.live_streams as u32).to_le_bytes());
        let nb = e.name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
    }
    sock.write_all(&buf)?;
    Ok(())
}

fn read_u32(sock: &mut TcpStream) -> Result<u32> {
    let mut b = [0u8; 4];
    sock.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read an 'R' frame's reason text (the tag byte already consumed).
fn read_reject_text(sock: &mut TcpStream) -> Result<String> {
    let n = read_u32(sock)? as usize;
    if n > 65536 {
        bail!("oversized reject reason ({n})");
    }
    let mut raw = vec![0u8; n];
    sock.read_exact(&mut raw)?;
    Ok(String::from_utf8_lossy(&raw).to_string())
}

/// Blocking client for the protocol above (used by examples/benches and
/// the admin CLI).
pub struct Client {
    sock: TcpStream,
}

/// Client-side view of a final result.
#[derive(Clone, Debug)]
pub struct ClientResult {
    pub words: Vec<u32>,
    pub phones: Vec<u32>,
    pub server_latency_ms: f32,
}

/// Client-side view of one `'Q'` registry row.
#[derive(Clone, Debug)]
pub struct RegistryEntry {
    pub id: u32,
    pub draining: bool,
    pub weight: u32,
    pub lanes: u32,
    pub live_streams: u32,
    pub name: String,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let sock = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        sock.set_nodelay(true).ok();
        Ok(Client { sock })
    }

    /// Declare the stream's QoS class.  Must precede the first audio
    /// chunk (the class rides the admission request).
    pub fn set_priority(&mut self, p: Priority) -> Result<()> {
        self.sock.write_all(&[b'P', p.to_wire()])?;
        Ok(())
    }

    /// Pick the model this stream targets.  Must precede the first audio
    /// chunk; an unknown or draining model rejects at stream open.
    pub fn set_model(&mut self, model: u32) -> Result<()> {
        let mut buf = Vec::with_capacity(5);
        buf.push(b'M');
        buf.extend_from_slice(&model.to_le_bytes());
        self.sock.write_all(&buf)?;
        Ok(())
    }

    pub fn send_audio(&mut self, pcm: &[f32]) -> Result<()> {
        let mut buf = Vec::with_capacity(5 + pcm.len() * 4);
        buf.push(b'A');
        buf.extend_from_slice(&(pcm.len() as u32).to_le_bytes());
        for v in pcm {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.sock.write_all(&buf)?;
        Ok(())
    }

    /// Admin: hot-load the model at `path` with DRR weight `weight` and
    /// `lanes` arena lanes (`0` = engine default).  Returns the new model
    /// id; an `'R'` response surfaces as an error and leaves the
    /// connection usable.
    pub fn load_model(&mut self, path: &str, weight: u32, lanes: u32) -> Result<u32> {
        let pb = path.as_bytes();
        let mut buf = Vec::with_capacity(13 + pb.len());
        buf.push(b'L');
        buf.extend_from_slice(&weight.to_le_bytes());
        buf.extend_from_slice(&lanes.to_le_bytes());
        buf.extend_from_slice(&(pb.len() as u32).to_le_bytes());
        buf.extend_from_slice(pb);
        self.sock.write_all(&buf)?;
        self.read_admin_ok()
    }

    /// Admin: drain and unload model `id`.  Blocks until the server-side
    /// teardown completes.
    pub fn unload_model(&mut self, id: u32) -> Result<()> {
        let mut buf = Vec::with_capacity(5);
        buf.push(b'U');
        buf.extend_from_slice(&id.to_le_bytes());
        self.sock.write_all(&buf)?;
        self.read_admin_ok()?;
        Ok(())
    }

    /// Admin: snapshot the server's live model registry.
    pub fn query_registry(&mut self) -> Result<Vec<RegistryEntry>> {
        self.sock.write_all(b"Q")?;
        let mut tag = [0u8; 1];
        self.sock.read_exact(&mut tag)?;
        if tag[0] != b'Q' {
            bail!("expected registry frame, got {:#x}", tag[0]);
        }
        let count = read_u32(&mut self.sock)? as usize;
        if count > 65536 {
            bail!("oversized registry ({count})");
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let id = read_u32(&mut self.sock)?;
            let mut flag = [0u8; 1];
            self.sock.read_exact(&mut flag)?;
            let weight = read_u32(&mut self.sock)?;
            let lanes = read_u32(&mut self.sock)?;
            let live_streams = read_u32(&mut self.sock)?;
            let n = read_u32(&mut self.sock)? as usize;
            if n > 4096 {
                bail!("oversized model name ({n})");
            }
            let mut raw = vec![0u8; n];
            self.sock.read_exact(&mut raw)?;
            out.push(RegistryEntry {
                id,
                draining: flag[0] != 0,
                weight,
                lanes,
                live_streams,
                name: String::from_utf8_lossy(&raw).to_string(),
            });
        }
        Ok(out)
    }

    /// Read an admin response: `'O' u32` on success, `'R'` reason as an
    /// error.
    fn read_admin_ok(&mut self) -> Result<u32> {
        let mut tag = [0u8; 1];
        self.sock.read_exact(&mut tag)?;
        match tag[0] {
            b'O' => read_u32(&mut self.sock),
            b'R' => {
                let reason = read_reject_text(&mut self.sock)?;
                bail!("admin rejected: {reason}");
            }
            other => bail!("expected admin response, got {other:#x}"),
        }
    }

    /// End the stream and read the final result.  An admission rejection
    /// ('R' frame) surfaces as an error carrying the server's reason.
    pub fn finish(mut self) -> Result<ClientResult> {
        self.sock.write_all(b"E")?;
        let mut tag = [0u8; 1];
        self.sock.read_exact(&mut tag)?;
        if tag[0] == b'R' {
            let reason = read_reject_text(&mut self.sock)?;
            bail!("admission rejected: {reason}");
        }
        if tag[0] != b'F' {
            bail!("expected final frame, got {:#x}", tag[0]);
        }
        let n = read_u32(&mut self.sock)? as usize;
        let mut words = Vec::with_capacity(n);
        for _ in 0..n {
            words.push(read_u32(&mut self.sock)?);
        }
        let m = read_u32(&mut self.sock)? as usize;
        let mut phones = Vec::with_capacity(m);
        for _ in 0..m {
            phones.push(read_u32(&mut self.sock)?);
        }
        let mut lat = [0u8; 4];
        self.sock.read_exact(&mut lat)?;
        Ok(ClientResult {
            words,
            phones,
            server_latency_ms: f32::from_le_bytes(lat),
        })
    }
}
