//! TCP streaming + fleet-admin protocol.
//!
//! **The normative wire specification lives in `docs/PROTOCOL.md`** —
//! frame layouts, reject-reason codes, the lazy-stream-open handshake and
//! the admin-frame lifecycle are defined there; this header is only a
//! summary.  Little-endian framing, client → server:
//!
//! ```text
//! 'P' u8               QoS class (0 = interactive, 1 = bulk); optional,
//!                      must precede the first audio chunk
//! 'M' u32              target model id; optional, must precede the
//!                      first audio chunk (default model 0)
//! 'A' u32 n  f32×n     audio chunk (PCM at 8 kHz)
//! 'E'                  end of audio
//! 'L' u32 w  u32 l  u32 n  bytes×n
//!                      admin: hot-load the model at path (weight w,
//!                      lanes l, 0 = engine default)
//! 'U' u32 id           admin: drain + unload model id
//! 'D' u32 id  u32 deadline_ms  u8 force
//!                      admin: bounded-wait unload — wait at most
//!                      deadline_ms for the drain; on expiry either give
//!                      up with a reason (force = 0) or cancel the
//!                      survivors and tear down (force != 0)
//! 'S' u32 old  u32 w  u32 l  u32 n  bytes×n
//!                      admin: zero-downtime swap — load the model at
//!                      path as the replacement for model old, canary it,
//!                      then redirect newcomers while old drains
//! 'Q'                  admin: query the live registry
//! 'T'                  admin: Prometheus text metrics snapshot
//! 'X'                  admin: Chrome-trace JSON flight-recorder export
//! ```
//! server → client:
//! ```text
//! 'F' u32 n  u32×n  u32 m  u32×m  f32 latency_ms  u64 trace
//!     final words, greedy phones, finalize latency, trace id
//! 'C' u32 n  bytes×n  u64 trace
//!     stream cancelled by the engine (idle/deadline reap, forced
//!     unload, model quarantine) with the reason text; terminal
//! 'E' u32 n  bytes×n  u64 trace
//!     the utterance's own processing failed (e.g. a quarantined decode
//!     panic) with the reason text; terminal, engine keeps serving
//! 'R' u32 n  bytes×n  u64 trace
//!     rejection/failure reason text.  After a stream-admission reject
//!     (delivered at 'E') the connection closes; after an admin failure
//!     the connection stays usable (trace = 0: no admission attempt)
//! 'O' u32 v
//!     admin success (the loaded/unloaded model id)
//! 'Q' u8 brownout  u64 resident  u64 budget  u32 count
//!     { u32 id  u8 status  u32 weight  u32 lanes  u32 live
//!       u64 arena  u64 reserved  u64 parked  u32 n  bytes×n }×count
//!     registry snapshot; brownout: 0 = normal, 1 = shedding,
//!     2 = rejecting; status: 0 = loaded, 1 = draining, 2 = quarantined
//! 'T' u32 n  bytes×n
//!     Prometheus text-exposition metrics snapshot
//! 'X' u32 n  bytes×n
//!     Chrome-trace JSON array (this engine's flight-recorder snapshot)
//! ```
//!
//! Every terminal frame carries the stream's flight-recorder trace id
//! (`crate::obs`, minted at admission attempt) as a trailing `u64`, so
//! client logs can be joined to server traces; `0` means "untraced".
//!
//! A thread per connection feeds the shared [`Engine`] — batching happens
//! across connections inside the engine, not per socket.  The stream is
//! opened lazily at the first `'A'`/`'E'` so the `'P'`/`'M'` options can
//! ride the admission request; when the engine's admission controller
//! rejects (live-stream cap, unknown / draining / quarantined model — see
//! [`crate::sched::admission`]), the client gets an `'R'` frame with the
//! [`crate::sched::RejectReason`] text instead of a hung connection.
//! The mutating admin frames (`'L'`/`'U'`/`'D'`/`'S'`) are only valid
//! before a stream opens on the connection; the read-only `'Q'`/`'T'`
//! are valid at any time.  `'L'`/`'S'` require the server to have been
//! started with a [`ModelLoader`] ([`serve_with_loader`]); `'U'` blocks
//! its connection
//! thread until the model's drain completes — use `'D'` with a deadline
//! (and `force` if the survivors must not pin the unload) to bound that
//! wait.
//!
//! **Hardening.**  Every byte off the socket flows through the typed
//! frame parsers ([`read_client_frame`], [`read_server_frame`]): length
//! prefixes are bounded *before* allocation, unknown tags and malformed
//! bodies surface as [`ServeError`] values (never a panic), and audio
//! payloads are read in [`AUDIO_READ_CHUNK`]-sized pieces so a hostile
//! length prefix cannot trigger a huge up-front allocation.  Connections
//! carry socket read/write timeouts (`QUANTASR_SOCK_TIMEOUT_MS`, 0 =
//! disabled); between client frames the server polls the open stream's
//! result channel so engine-initiated endings — the stream reaper, forced
//! unload, model quarantine — reach a silent client as a terminal `'C'`
//! frame instead of leaving both sides hung.  The accept loop backs off
//! exponentially (bounded) when idle instead of spinning.
//!
//! **Trust model.**  Admin frames share the serving socket and are
//! unauthenticated: anyone who can open a stream can also load/unload
//! models.  Keep the listener on a trusted interface (the default bind
//! is loopback) or front it with network policy; a separate
//! authenticated admin socket is a ROADMAP follow-on.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::batcher::parse_deadline_ms;
use crate::coordinator::engine::{Engine, FinalResult, ModelInfo, OverloadInfo, StreamEnd};
use crate::obs;
use crate::runtime::backend::AmBackend;
use crate::sched::{ModelParams, Priority, StreamOptions};
use crate::util::fault::{self, FaultPlan, FaultPoint};

/// Hard cap on one `'A'` frame's sample count (~21 minutes at 8 kHz —
/// far beyond any real utterance chunk; a bigger prefix is an attack or
/// corruption, not audio).
pub const MAX_AUDIO_SAMPLES: usize = 10_000_000;
/// Hard cap on a model path / model name / reason text length.
pub const MAX_TEXT_BYTES: usize = 65_536;
/// Hard cap on a `'T'` metrics exposition a client will accept (larger
/// than [`MAX_TEXT_BYTES`]: the per-model sample families grow with the
/// registry).
pub const MAX_METRICS_BYTES: usize = 1 << 20;
/// Hard cap on `'Q'` registry rows a client will accept.
pub const MAX_REGISTRY_ROWS: usize = 65_536;
/// Hard cap on an `'X'` Chrome-trace export (rings are bounded, but a
/// large `QUANTASR_TRACE` capacity across many threads adds up).
pub const MAX_TRACE_BYTES: usize = 16 << 20;
/// Hard cap on words/phones per `'F'` frame a client will accept.
pub const MAX_RESULT_TOKENS: usize = 1 << 20;
/// Audio payloads are read (and bounds-checked) in pieces of this many
/// bytes, so the declared length never sizes a single allocation.
pub const AUDIO_READ_CHUNK: usize = 64 * 1024;

/// How often a connection with an open stream checks the engine for an
/// engine-initiated ending while waiting for the next client frame.
const POLL: Duration = Duration::from_millis(50);
/// Default socket read/write timeout (`QUANTASR_SOCK_TIMEOUT_MS`
/// overrides; 0 disables).  A peer silent for this long is dead.
const DEFAULT_SOCK_TIMEOUT: Duration = Duration::from_secs(30);
/// Client-side default I/O timeout — generous because `'U'` legitimately
/// blocks for a whole model drain.
const CLIENT_SOCK_TIMEOUT: Duration = Duration::from_secs(120);
/// Accept-loop backoff bounds: start fast, never spin slower than this.
const ACCEPT_BACKOFF_MIN: Duration = Duration::from_millis(1);
const ACCEPT_BACKOFF_MAX: Duration = Duration::from_millis(50);

/// Structured error for everything that can go wrong on the untrusted
/// serving path.  Wire-frame parsing and the connection loop return
/// these instead of panicking (or stringly-typed `anyhow` chains), so
/// the server can tell protocol abuse from I/O loss from engine-side
/// failures — and so the property/chaos tests can assert "errors, never
/// panics" over arbitrary byte streams.
#[derive(Debug)]
pub enum ServeError {
    /// The peer violated the frame grammar (unknown tag, bad enum value,
    /// frame out of sequence).
    Protocol { detail: String },
    /// A length prefix exceeded its hard bound — refused before any
    /// allocation or read of the body.
    Oversized { what: &'static str, size: usize, limit: usize },
    /// The socket failed or timed out mid-frame.
    Io(io::Error),
    /// The engine refused or lost the stream.
    Engine(String),
}

impl ServeError {
    fn protocol(detail: impl Into<String>) -> Self {
        ServeError::Protocol { detail: detail.into() }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Protocol { detail } => write!(f, "protocol violation: {detail}"),
            ServeError::Oversized { what, size, limit } => {
                write!(f, "oversized {what}: {size} exceeds the {limit} limit")
            }
            ServeError::Io(e) => write!(f, "socket error: {e}"),
            ServeError::Engine(detail) => write!(f, "engine error: {detail}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for ServeError {
    fn from(e: io::Error) -> Self {
        ServeError::Io(e)
    }
}

/// One parsed client → server frame (see the module header / PROTOCOL.md
/// for the byte layout each variant corresponds to).
#[derive(Clone, Debug, PartialEq)]
pub enum ClientFrame {
    /// `'P'`: QoS class for the admission request.
    Priority(Priority),
    /// `'M'`: target model id.
    Model(u32),
    /// `'A'`: one PCM chunk.
    Audio(Vec<f32>),
    /// `'E'`: end of audio.
    End,
    /// `'L'`: hot-load admin request.
    Load { weight: u32, lanes: u32, path: String },
    /// `'U'`: unbounded drain + unload.
    Unload(u32),
    /// `'D'`: bounded-wait unload, optionally forcing survivor
    /// cancellation at the deadline.
    UnloadDeadline { id: u32, deadline_ms: u32, force: bool },
    /// `'S'`: zero-downtime swap — load the model at `path` as the
    /// replacement for model `old`, canary it, redirect on success.
    Swap { old: u32, weight: u32, lanes: u32, path: String },
    /// `'Q'`: registry snapshot request.
    Query,
    /// `'T'`: Prometheus text metrics request.
    Metrics,
    /// `'X'`: Chrome-trace flight-recorder export request.
    Trace,
}

/// One parsed server → client frame.
#[derive(Clone, Debug, PartialEq)]
pub enum ServerFrame {
    /// `'F'`: the stream finalized normally.
    Final(ClientResult),
    /// `'R'`: admission reject / admin failure reason, plus the trace id
    /// (0 for admin failures — no admission attempt happened).
    Reject(String, u64),
    /// `'O'`: admin success value.
    AdminOk(u32),
    /// `'C'`: the engine cancelled the stream (reason text, trace id).
    Cancelled(String, u64),
    /// `'E'`: the utterance's processing failed (reason text, trace id).
    Failed(String, u64),
    /// `'Q'`: registry snapshot.
    Registry(RegistrySnapshot),
    /// `'T'`: Prometheus text metrics snapshot.
    MetricsText(String),
    /// `'X'`: Chrome-trace JSON flight-recorder export.
    TraceJson(String),
}

impl ServerFrame {
    /// Human tag for "expected X, got Y" errors.
    pub fn kind(&self) -> &'static str {
        match self {
            ServerFrame::Final(_) => "final ('F')",
            ServerFrame::Reject(..) => "reject ('R')",
            ServerFrame::AdminOk(_) => "admin-ok ('O')",
            ServerFrame::Cancelled(..) => "cancelled ('C')",
            ServerFrame::Failed(..) => "failed ('E')",
            ServerFrame::Registry(_) => "registry ('Q')",
            ServerFrame::MetricsText(_) => "metrics ('T')",
            ServerFrame::TraceJson(_) => "trace ('X')",
        }
    }
}

/// Read one client → server frame (tag + body).  Returns `Ok(None)` on a
/// clean end-of-stream at the tag boundary; every malformed input maps
/// to `Err`, never a panic — the wire property test drives this with
/// arbitrary byte streams.
pub fn read_client_frame(r: &mut impl Read) -> Result<Option<ClientFrame>, ServeError> {
    let mut tag = [0u8; 1];
    match r.read_exact(&mut tag) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    read_client_frame_body(tag[0], r).map(Some)
}

/// Parse a client frame's body given its already-consumed tag byte.
/// Length prefixes are checked against their hard bounds *before* any
/// allocation; audio is read in [`AUDIO_READ_CHUNK`] pieces.
pub fn read_client_frame_body(tag: u8, r: &mut impl Read) -> Result<ClientFrame, ServeError> {
    match tag {
        b'P' => {
            let mut class = [0u8; 1];
            r.read_exact(&mut class)?;
            match Priority::from_wire(class[0]) {
                Some(p) => Ok(ClientFrame::Priority(p)),
                None => Err(ServeError::protocol(format!("unknown priority class {}", class[0]))),
            }
        }
        b'M' => Ok(ClientFrame::Model(read_u32(r)?)),
        b'A' => {
            let n = read_u32(r)? as usize;
            if n > MAX_AUDIO_SAMPLES {
                return Err(ServeError::Oversized {
                    what: "audio chunk",
                    size: n,
                    limit: MAX_AUDIO_SAMPLES,
                });
            }
            let mut remaining = n * 4;
            let mut raw = vec![0u8; AUDIO_READ_CHUNK.min(remaining)];
            let mut pcm = Vec::with_capacity(n.min(AUDIO_READ_CHUNK));
            while remaining > 0 {
                let take = AUDIO_READ_CHUNK.min(remaining);
                r.read_exact(&mut raw[..take])?;
                pcm.extend(
                    raw[..take]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
                );
                remaining -= take;
            }
            Ok(ClientFrame::Audio(pcm))
        }
        b'E' => Ok(ClientFrame::End),
        b'L' => {
            let weight = read_u32(r)?;
            let lanes = read_u32(r)?;
            let path = read_text(r, "model path")?;
            Ok(ClientFrame::Load { weight, lanes, path })
        }
        b'U' => Ok(ClientFrame::Unload(read_u32(r)?)),
        b'D' => {
            let id = read_u32(r)?;
            let deadline_ms = read_u32(r)?;
            let mut force = [0u8; 1];
            r.read_exact(&mut force)?;
            Ok(ClientFrame::UnloadDeadline { id, deadline_ms, force: force[0] != 0 })
        }
        b'S' => {
            let old = read_u32(r)?;
            let weight = read_u32(r)?;
            let lanes = read_u32(r)?;
            let path = read_text(r, "model path")?;
            Ok(ClientFrame::Swap { old, weight, lanes, path })
        }
        b'Q' => Ok(ClientFrame::Query),
        b'T' => Ok(ClientFrame::Metrics),
        b'X' => Ok(ClientFrame::Trace),
        other => Err(ServeError::protocol(format!("unknown client tag {other:#x}"))),
    }
}

/// Read one server → client frame (tag + body).  Same contract as
/// [`read_client_frame_body`]: bounded, total, panic-free.
pub fn read_server_frame(r: &mut impl Read) -> Result<ServerFrame, ServeError> {
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    match tag[0] {
        b'F' => {
            let words = read_u32_vec(r, "final words")?;
            let phones = read_u32_vec(r, "final phones")?;
            let mut lat = [0u8; 4];
            r.read_exact(&mut lat)?;
            let trace = read_u64(r)?;
            Ok(ServerFrame::Final(ClientResult {
                words,
                phones,
                server_latency_ms: f32::from_le_bytes(lat),
                trace,
            }))
        }
        b'R' => {
            let reason = read_text(r, "reject reason")?;
            Ok(ServerFrame::Reject(reason, read_u64(r)?))
        }
        b'O' => Ok(ServerFrame::AdminOk(read_u32(r)?)),
        b'C' => {
            let why = read_text(r, "cancel reason")?;
            Ok(ServerFrame::Cancelled(why, read_u64(r)?))
        }
        b'E' => {
            let why = read_text(r, "failure reason")?;
            Ok(ServerFrame::Failed(why, read_u64(r)?))
        }
        b'Q' => {
            let mut brownout = [0u8; 1];
            r.read_exact(&mut brownout)?;
            if brownout[0] > 2 {
                return Err(ServeError::protocol(format!(
                    "unknown brownout stage byte {}",
                    brownout[0]
                )));
            }
            let resident_bytes = read_u64(r)?;
            let budget_bytes = read_u64(r)?;
            let count = read_u32(r)? as usize;
            if count > MAX_REGISTRY_ROWS {
                return Err(ServeError::Oversized {
                    what: "registry",
                    size: count,
                    limit: MAX_REGISTRY_ROWS,
                });
            }
            let mut models = Vec::with_capacity(count.min(1024));
            for _ in 0..count {
                let id = read_u32(r)?;
                let mut status = [0u8; 1];
                r.read_exact(&mut status)?;
                if status[0] > 2 {
                    return Err(ServeError::protocol(format!(
                        "unknown model status byte {}",
                        status[0]
                    )));
                }
                let weight = read_u32(r)?;
                let lanes = read_u32(r)?;
                let live_streams = read_u32(r)?;
                let arena_bytes = read_u64(r)?;
                let reserved_bytes = read_u64(r)?;
                let parked_bytes = read_u64(r)?;
                let name = read_text(r, "model name")?;
                let scheme = read_text(r, "model scheme")?;
                models.push(RegistryEntry {
                    id,
                    draining: status[0] == 1,
                    quarantined: status[0] == 2,
                    weight,
                    lanes,
                    live_streams,
                    arena_bytes,
                    reserved_bytes,
                    parked_bytes,
                    name,
                    scheme,
                });
            }
            Ok(ServerFrame::Registry(RegistrySnapshot {
                brownout_stage: brownout[0],
                resident_bytes,
                budget_bytes,
                models,
            }))
        }
        b'T' => {
            let n = read_u32(r)? as usize;
            if n > MAX_METRICS_BYTES {
                return Err(ServeError::Oversized {
                    what: "metrics exposition",
                    size: n,
                    limit: MAX_METRICS_BYTES,
                });
            }
            let mut raw = vec![0u8; n];
            r.read_exact(&mut raw)?;
            Ok(ServerFrame::MetricsText(String::from_utf8_lossy(&raw).to_string()))
        }
        b'X' => {
            let n = read_u32(r)? as usize;
            if n > MAX_TRACE_BYTES {
                return Err(ServeError::Oversized {
                    what: "trace export",
                    size: n,
                    limit: MAX_TRACE_BYTES,
                });
            }
            // Read in bounded pieces like audio: the declared length
            // never sizes a single up-front allocation.
            let mut raw = Vec::with_capacity(n.min(AUDIO_READ_CHUNK));
            let mut chunk = [0u8; 4096];
            let mut remaining = n;
            while remaining > 0 {
                let take = chunk.len().min(remaining);
                r.read_exact(&mut chunk[..take])?;
                raw.extend_from_slice(&chunk[..take]);
                remaining -= take;
            }
            Ok(ServerFrame::TraceJson(String::from_utf8_lossy(&raw).to_string()))
        }
        other => Err(ServeError::protocol(format!("unknown server tag {other:#x}"))),
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32, ServeError> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64, ServeError> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Length-prefixed text, bounded by [`MAX_TEXT_BYTES`] before the read.
fn read_text(r: &mut impl Read, what: &'static str) -> Result<String, ServeError> {
    let n = read_u32(r)? as usize;
    if n > MAX_TEXT_BYTES {
        return Err(ServeError::Oversized { what, size: n, limit: MAX_TEXT_BYTES });
    }
    let mut raw = vec![0u8; n];
    r.read_exact(&mut raw)?;
    Ok(String::from_utf8_lossy(&raw).to_string())
}

/// Length-prefixed u32 sequence, bounded by [`MAX_RESULT_TOKENS`].
fn read_u32_vec(r: &mut impl Read, what: &'static str) -> Result<Vec<u32>, ServeError> {
    let n = read_u32(r)? as usize;
    if n > MAX_RESULT_TOKENS {
        return Err(ServeError::Oversized { what, size: n, limit: MAX_RESULT_TOKENS });
    }
    let mut out = Vec::with_capacity(n.min(AUDIO_READ_CHUNK));
    for _ in 0..n {
        out.push(read_u32(r)?);
    }
    Ok(out)
}

/// Server-side socket read/write timeout: `QUANTASR_SOCK_TIMEOUT_MS`
/// (fractions allowed, 0 disables), defaulting to 30 s.  Malformed
/// values warn and fall back — tuning knobs must never panic a serving
/// process.
fn sock_timeout() -> Option<Duration> {
    static ONCE: OnceLock<Option<Duration>> = OnceLock::new();
    *ONCE.get_or_init(|| match std::env::var("QUANTASR_SOCK_TIMEOUT_MS") {
        Ok(v) => match parse_deadline_ms(&v) {
            Some(d) if d.is_zero() => None,
            Some(d) => Some(d),
            None => {
                eprintln!(
                    "QUANTASR_SOCK_TIMEOUT_MS='{v}' is not a non-negative number of \
                     milliseconds; using the built-in {} ms",
                    DEFAULT_SOCK_TIMEOUT.as_millis()
                );
                Some(DEFAULT_SOCK_TIMEOUT)
            }
        },
        Err(_) => Some(DEFAULT_SOCK_TIMEOUT),
    })
}

/// Backend factory for the `'L'` admin frame: maps the client-supplied
/// model path/spec to a loaded backend.  Servers that don't install one
/// reject `'L'` with a reason (the rest of the protocol is unaffected).
pub type ModelLoader<B> = Arc<dyn Fn(&str) -> Result<Arc<B>> + Send + Sync>;

/// Serve until `stop` is set, with hot model loading disabled (`'L'`
/// frames are rejected with a reason; `'U'`/`'D'`/`'Q'` still work).
/// Returns the bound local address via the callback (useful with port 0
/// in tests).  Generic over the engine's execution backend — batching
/// happens across connections inside the engine regardless of what
/// executes the model.
pub fn serve<B: AmBackend>(
    engine: Arc<Engine<B>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_with_loader(engine, addr, stop, None, on_bound)
}

/// [`serve`], plus a [`ModelLoader`] that backs the `'L'` hot-load admin
/// frame.
pub fn serve_with_loader<B: AmBackend>(
    engine: Arc<Engine<B>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    loader: Option<ModelLoader<B>>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut handles = Vec::new();
    // Bounded exponential backoff while idle: quick to notice a new
    // connection after a burst, never a busy-spin while quiet.
    let mut backoff = ACCEPT_BACKOFF_MIN;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                backoff = ACCEPT_BACKOFF_MIN;
                let eng = engine.clone();
                let ldr = loader.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(eng, ldr, stream) {
                        eprintln!("connection error: {e}");
                    }
                }));
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(ACCEPT_BACKOFF_MAX);
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn<B: AmBackend>(
    engine: Arc<Engine<B>>,
    loader: Option<ModelLoader<B>>,
    mut sock: TcpStream,
) -> Result<(), ServeError> {
    sock.set_nodelay(true).ok();
    let mut opened: Option<(u64, Receiver<FinalResult>)> = None;
    let r = conn_loop(&engine, &loader, &mut sock, &mut opened);
    // Whatever ended the loop (peer vanished, protocol error, engine
    // error), never leak a live stream: one left open here would hold an
    // admission slot forever, and enough broken connections would wedge
    // the engine at its live-stream cap.  Finishing drains it; if the
    // engine already ended it (reaper, quarantine) the finish fails
    // harmlessly and the receiver is already resolved or disconnected.
    if let Some((id, rx)) = opened {
        let _ = engine.finish_stream(id);
        let _ = rx.recv();
    }
    r
}

fn conn_loop<B: AmBackend>(
    engine: &Arc<Engine<B>>,
    loader: &Option<ModelLoader<B>>,
    sock: &mut TcpStream,
    opened: &mut Option<(u64, Receiver<FinalResult>)>,
) -> Result<(), ServeError> {
    let faults = engine.fault_plan();
    let timeout = sock_timeout();
    sock.set_write_timeout(timeout).ok();
    let mut opts = StreamOptions::default();
    // A rejected connection keeps draining the client's audio (discarded)
    // and delivers the 'R' frame at 'E' — writing it mid-stream and
    // closing would race the client's in-flight sends into a broken pipe
    // and the reason would be lost with the connection reset.  The trace
    // id minted for the admission attempt rides along so the reject can
    // be joined to its flight-recorder event.
    let mut rejected: Option<(String, u64)> = None;
    let mut last_frame = Instant::now();
    loop {
        // Poll for the tag so engine-initiated stream endings (reaper
        // cancel, forced unload, quarantine) reach a silent client as a
        // terminal frame instead of waiting for it to speak — a stalled
        // client must never pin an unload past its deadline.
        sock.set_read_timeout(Some(POLL)).ok();
        let mut tag = [0u8; 1];
        match sock.read_exact(&mut tag) {
            Ok(()) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                let ended = match opened.as_ref() {
                    Some((_, rx)) => rx.try_recv().ok(),
                    None => None,
                };
                if let Some(result) = ended {
                    opened.take();
                    write_terminal(sock, &result, &faults)?;
                    drain_until_close(sock);
                    return Ok(());
                }
                if let Some(t) = timeout {
                    if last_frame.elapsed() > t {
                        return Err(ServeError::Io(io::Error::new(
                            io::ErrorKind::TimedOut,
                            format!("no frame for {} ms; peer presumed dead", t.as_millis()),
                        )));
                    }
                }
                continue;
            }
            Err(_) => return Ok(()), // peer vanished: the caller finishes what we have
        }
        // The body of a frame is one logical unit: a peer stalling
        // mid-frame for the full socket timeout is treated as dead.
        sock.set_read_timeout(timeout).ok();
        let frame = read_client_frame_body(tag[0], sock)?;
        last_frame = Instant::now();
        // Open lazily so preceding 'P'/'M' can set the admission options.
        if matches!(frame, ClientFrame::Audio(_) | ClientFrame::End)
            && opened.is_none()
            && rejected.is_none()
        {
            // Mint the flight-recorder trace id here, not in the engine:
            // a reject never gets an engine stream id, but its 'R' frame
            // (and Reject trace event) still needs a joinable identity.
            let trace = obs::next_trace_id();
            match engine.try_open_stream_traced(opts, trace) {
                Ok(o) => *opened = Some(o),
                Err(reason) => rejected = Some((reason.to_string(), trace)),
            }
        }
        match frame {
            ClientFrame::Priority(p) => {
                if opened.is_some() {
                    return Err(ServeError::protocol("'P' after the stream was opened"));
                }
                opts.priority = p;
            }
            ClientFrame::Model(model) => {
                if opened.is_some() {
                    return Err(ServeError::protocol("'M' after the stream was opened"));
                }
                // Validity is the admission controller's call (unknown /
                // draining / quarantined models reject at open).
                opts.model = model as usize;
            }
            ClientFrame::Audio(pcm) => {
                if rejected.is_some() {
                    continue; // drained, not served
                }
                let id = opened.as_ref().expect("stream opened above").0;
                if let Err(e) = engine.push_audio(id, &pcm) {
                    // The engine may have ended the stream between frames
                    // (reap, quarantine): deliver its terminal result if
                    // one is waiting, else surface the engine error.
                    let ended = opened.take().and_then(|(_, rx)| rx.try_recv().ok());
                    return match ended {
                        Some(result) => {
                            write_terminal(sock, &result, &faults)?;
                            drain_until_close(sock);
                            Ok(())
                        }
                        None => Err(ServeError::Engine(format!("{e:#}"))),
                    };
                }
            }
            ClientFrame::End => {
                if let Some((reason, trace)) = rejected.take() {
                    write_reject_traced(sock, &reason, trace)?;
                    return Ok(());
                }
                let (id, rx) = opened.take().expect("stream opened above");
                let result = match engine.finish_stream(id) {
                    Ok(()) => rx.recv().map_err(|_| {
                        ServeError::Engine("engine dropped the stream result".into())
                    })?,
                    // The engine already ended the stream (a cancel raced
                    // the 'E'): its terminal result is in the channel.
                    Err(_) => rx.try_recv().map_err(|_| {
                        ServeError::Engine("stream ended without a result".into())
                    })?,
                };
                write_terminal(sock, &result, &faults)?;
                return Ok(());
            }
            ClientFrame::Load { weight, lanes, path } => {
                if opened.is_some() {
                    return Err(ServeError::protocol("'L' after the stream was opened"));
                }
                let outcome = match loader {
                    None => Err("no model loader configured on this server".to_string()),
                    Some(load) => match load.as_ref()(&path) {
                        Ok(backend) => {
                            let params = ModelParams {
                                weight,
                                lanes: if lanes == 0 { None } else { Some(lanes as usize) },
                            };
                            engine.load_model(backend, params)
                        }
                        Err(e) => Err(format!("load '{path}': {e:#}")),
                    },
                };
                match outcome {
                    Ok(id) => write_ok(sock, id as u32)?,
                    Err(reason) => write_reject(sock, &reason)?,
                }
            }
            ClientFrame::Unload(id) => {
                if opened.is_some() {
                    return Err(ServeError::protocol("'U' after the stream was opened"));
                }
                // Blocks this connection thread until the drain completes
                // (the engine keeps serving everyone else meanwhile).
                match engine.unload_model(id as usize) {
                    Ok(()) => write_ok(sock, id)?,
                    Err(reason) => write_reject(sock, &reason)?,
                }
            }
            ClientFrame::UnloadDeadline { id, deadline_ms, force } => {
                if opened.is_some() {
                    return Err(ServeError::protocol("'D' after the stream was opened"));
                }
                let deadline = Duration::from_millis(u64::from(deadline_ms));
                match engine.unload_model_deadline(id as usize, deadline, force) {
                    Ok(()) => write_ok(sock, id)?,
                    Err(reason) => write_reject(sock, &reason)?,
                }
            }
            ClientFrame::Swap { old, weight, lanes, path } => {
                if opened.is_some() {
                    return Err(ServeError::protocol("'S' after the stream was opened"));
                }
                let outcome = match loader {
                    None => Err("no model loader configured on this server".to_string()),
                    Some(load) => match load.as_ref()(&path) {
                        Ok(backend) => {
                            let params = ModelParams {
                                weight,
                                lanes: if lanes == 0 { None } else { Some(lanes as usize) },
                            };
                            // Blocks this connection thread through the
                            // canary utterance; on failure the engine has
                            // already rolled back (new slot unloaded, old
                            // still serving) and the reason says so.
                            engine.swap_model(old as usize, backend, params)
                        }
                        Err(e) => Err(format!("load '{path}': {e:#}")),
                    },
                };
                match outcome {
                    Ok(id) => write_ok(sock, id as u32)?,
                    Err(reason) => write_reject(sock, &reason)?,
                }
            }
            ClientFrame::Query => {
                write_registry(sock, &engine.overload_info(), &engine.registry())?;
            }
            ClientFrame::Metrics => {
                sock.write_all(&text_frame(b'T', &engine.metrics().prometheus()))?;
            }
            ClientFrame::Trace => {
                let mut json = engine.trace_json();
                if json.len() > MAX_TRACE_BYTES {
                    // Never ship a frame the client is contractually
                    // required to refuse; an empty array is still valid
                    // Chrome trace.
                    eprintln!(
                        "trace export of {} bytes exceeds the {} wire cap; sending empty",
                        json.len(),
                        MAX_TRACE_BYTES
                    );
                    json = "[]".to_string();
                }
                sock.write_all(&text_frame(b'X', &json))?;
            }
        }
    }
}

/// After an engine-initiated terminal frame, half-close the write side
/// and drain (briefly) whatever the client was still sending — closing
/// outright would RST the connection and could discard the terminal
/// frame from the peer's receive buffer before it reads it.
fn drain_until_close(sock: &mut TcpStream) {
    let _ = sock.shutdown(std::net::Shutdown::Write);
    sock.set_read_timeout(Some(POLL)).ok();
    let budget = Instant::now();
    let mut scratch = [0u8; 4096];
    while budget.elapsed() < Duration::from_secs(2) {
        match sock.read(&mut scratch) {
            Ok(0) => return, // peer closed cleanly
            Ok(_) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {}
            Err(_) => return,
        }
    }
}

/// Serialize a stream's terminal frame: `'F'` for a normal finalize,
/// `'C'` for an engine cancel, `'E'` for a quarantined failure.  The
/// corrupt-frame fault point (keyed by stream id) flips the tag byte so
/// chaos tests can prove the client surfaces a structured error instead
/// of hanging or panicking.
fn write_terminal(
    sock: &mut TcpStream,
    r: &FinalResult,
    faults: &Option<Arc<FaultPlan>>,
) -> Result<(), ServeError> {
    let mut buf = match &r.end {
        StreamEnd::Complete => {
            let mut buf = Vec::with_capacity(24 + 4 * (r.words.len() + r.phones.len()));
            buf.push(b'F');
            buf.extend_from_slice(&(r.words.len() as u32).to_le_bytes());
            for w in &r.words {
                buf.extend_from_slice(&w.to_le_bytes());
            }
            buf.extend_from_slice(&(r.phones.len() as u32).to_le_bytes());
            for p in &r.phones {
                buf.extend_from_slice(&p.to_le_bytes());
            }
            buf.extend_from_slice(&((r.finalize_latency.as_secs_f64() * 1e3) as f32).to_le_bytes());
            buf
        }
        StreamEnd::Cancelled(why) => text_frame(b'C', why),
        StreamEnd::Failed(why) => text_frame(b'E', why),
    };
    // Every terminal frame ends with the stream's trace id (additive
    // field, see PROTOCOL.md's versioning rule).
    buf.extend_from_slice(&r.trace.to_le_bytes());
    if fault::fire(faults, FaultPoint::CorruptFrame, r.stream_id) {
        buf[0] ^= 0xFF;
    }
    sock.write_all(&buf)?;
    Ok(())
}

fn text_frame(tag: u8, text: &str) -> Vec<u8> {
    let bytes = text.as_bytes();
    let mut buf = Vec::with_capacity(5 + bytes.len());
    buf.push(tag);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    buf
}

/// Admin-failure reject: trace id 0 (no admission attempt happened).
fn write_reject(sock: &mut TcpStream, reason: &str) -> Result<(), ServeError> {
    write_reject_traced(sock, reason, 0)
}

/// Admission reject carrying the trace id minted for the attempt.
fn write_reject_traced(sock: &mut TcpStream, reason: &str, trace: u64) -> Result<(), ServeError> {
    let mut buf = text_frame(b'R', reason);
    buf.extend_from_slice(&trace.to_le_bytes());
    sock.write_all(&buf)?;
    Ok(())
}

fn write_ok(sock: &mut TcpStream, v: u32) -> Result<(), ServeError> {
    let mut buf = Vec::with_capacity(5);
    buf.push(b'O');
    buf.extend_from_slice(&v.to_le_bytes());
    sock.write_all(&buf)?;
    Ok(())
}

fn write_registry(
    sock: &mut TcpStream,
    overload: &OverloadInfo,
    entries: &[ModelInfo],
) -> Result<(), ServeError> {
    let mut buf = vec![b'Q'];
    buf.push(overload.brownout_stage);
    buf.extend_from_slice(&(overload.resident_bytes as u64).to_le_bytes());
    buf.extend_from_slice(&(overload.budget_bytes as u64).to_le_bytes());
    buf.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for e in entries {
        buf.extend_from_slice(&(e.id as u32).to_le_bytes());
        let status: u8 = if e.quarantined {
            2
        } else if e.draining {
            1
        } else {
            0
        };
        buf.push(status);
        buf.extend_from_slice(&e.weight.to_le_bytes());
        buf.extend_from_slice(&(e.lanes as u32).to_le_bytes());
        buf.extend_from_slice(&(e.live_streams as u32).to_le_bytes());
        buf.extend_from_slice(&(e.arena_bytes as u64).to_le_bytes());
        buf.extend_from_slice(&(e.reserved_bytes as u64).to_le_bytes());
        buf.extend_from_slice(&(e.parked_bytes as u64).to_le_bytes());
        let nb = e.name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        let sb = e.scheme.as_bytes();
        buf.extend_from_slice(&(sb.len() as u32).to_le_bytes());
        buf.extend_from_slice(sb);
    }
    sock.write_all(&buf)?;
    Ok(())
}

/// Blocking client for the protocol above (used by examples/benches and
/// the admin CLI).
pub struct Client {
    sock: TcpStream,
    /// Fault plan for the client-side injection points (chaos tests).
    faults: Option<Arc<FaultPlan>>,
    /// Audio chunks sent so far — the `client_stall` fault key.
    audio_chunks: u64,
}

/// Client-side view of a final result.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientResult {
    pub words: Vec<u32>,
    pub phones: Vec<u32>,
    pub server_latency_ms: f32,
    /// Server-side flight-recorder trace id (0 = untraced) — quote it
    /// when filing a "what happened to my stream" report.
    pub trace: u64,
}

/// Client-side view of one `'Q'` registry row.
#[derive(Clone, Debug, PartialEq)]
pub struct RegistryEntry {
    pub id: u32,
    pub draining: bool,
    pub quarantined: bool,
    pub weight: u32,
    pub lanes: u32,
    pub live_streams: u32,
    /// Resident lane-arena bytes charged to this model.
    pub arena_bytes: u64,
    /// Parked-blob bytes reserved by the model's admitted streams.
    pub reserved_bytes: u64,
    /// Reserved bytes currently materialized as parked state (≤ reserved).
    pub parked_bytes: u64,
    pub name: String,
    /// Requantization scheme the model executes under (`"per-matrix-u8"`,
    /// `"per-channel-u8"`, `"per-channel-i4"`, or `"float"`).
    pub scheme: String,
}

/// Client-side view of the full `'Q'` response: the overload-control
/// header plus the per-model rows.
#[derive(Clone, Debug, PartialEq)]
pub struct RegistrySnapshot {
    /// Brownout stage: 0 = normal, 1 = shedding Bulk, 2 = rejecting all.
    pub brownout_stage: u8,
    /// Ledger-resident bytes (arenas + stream reservations) engine-wide.
    pub resident_bytes: u64,
    /// Configured `--mem-budget-bytes` (0 = unlimited).
    pub budget_bytes: u64,
    pub models: Vec<RegistryEntry>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let sock = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        sock.set_nodelay(true).ok();
        // Generous defaults — 'U' legitimately blocks for a whole drain —
        // but never unbounded: a dead server must surface as an error.
        sock.set_read_timeout(Some(CLIENT_SOCK_TIMEOUT)).ok();
        sock.set_write_timeout(Some(CLIENT_SOCK_TIMEOUT)).ok();
        Ok(Client { sock, faults: None, audio_chunks: 0 })
    }

    /// Override the default I/O timeout (`None` waits forever).
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.sock.set_read_timeout(timeout)?;
        self.sock.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Install a fault plan for the client-side injection points
    /// (`client_stall`, keyed by the 1-based audio-chunk ordinal).
    pub fn set_fault_plan(&mut self, plan: Option<Arc<FaultPlan>>) {
        self.faults = plan;
    }

    /// Declare the stream's QoS class.  Must precede the first audio
    /// chunk (the class rides the admission request).
    pub fn set_priority(&mut self, p: Priority) -> Result<()> {
        self.sock.write_all(&[b'P', p.to_wire()])?;
        Ok(())
    }

    /// Pick the model this stream targets.  Must precede the first audio
    /// chunk; an unknown, draining, or quarantined model rejects at
    /// stream open.
    pub fn set_model(&mut self, model: u32) -> Result<()> {
        let mut buf = Vec::with_capacity(5);
        buf.push(b'M');
        buf.extend_from_slice(&model.to_le_bytes());
        self.sock.write_all(&buf)?;
        Ok(())
    }

    pub fn send_audio(&mut self, pcm: &[f32]) -> Result<()> {
        self.audio_chunks += 1;
        if fault::fire(&self.faults, FaultPoint::ClientStall, self.audio_chunks) {
            std::thread::sleep(Duration::from_millis(fault::CLIENT_STALL_MS));
        }
        let mut buf = Vec::with_capacity(5 + pcm.len() * 4);
        buf.push(b'A');
        buf.extend_from_slice(&(pcm.len() as u32).to_le_bytes());
        for v in pcm {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.sock.write_all(&buf)?;
        Ok(())
    }

    /// Admin: hot-load the model at `path` with DRR weight `weight` and
    /// `lanes` arena lanes (`0` = engine default).  Returns the new model
    /// id; an `'R'` response surfaces as an error and leaves the
    /// connection usable.
    pub fn load_model(&mut self, path: &str, weight: u32, lanes: u32) -> Result<u32> {
        let pb = path.as_bytes();
        let mut buf = Vec::with_capacity(13 + pb.len());
        buf.push(b'L');
        buf.extend_from_slice(&weight.to_le_bytes());
        buf.extend_from_slice(&lanes.to_le_bytes());
        buf.extend_from_slice(&(pb.len() as u32).to_le_bytes());
        buf.extend_from_slice(pb);
        self.sock.write_all(&buf)?;
        self.read_admin_ok()
    }

    /// Admin: drain and unload model `id`.  Blocks until the server-side
    /// teardown completes (see [`Client::unload_model_deadline`] for the
    /// bounded variant).
    pub fn unload_model(&mut self, id: u32) -> Result<()> {
        let mut buf = Vec::with_capacity(5);
        buf.push(b'U');
        buf.extend_from_slice(&id.to_le_bytes());
        self.sock.write_all(&buf)?;
        self.read_admin_ok()?;
        Ok(())
    }

    /// Admin: drain and unload model `id`, waiting at most `deadline`.
    /// On expiry the server either reports the surviving stream count as
    /// an error (`force = false`) or cancels the survivors and completes
    /// the teardown (`force = true`).
    pub fn unload_model_deadline(
        &mut self,
        id: u32,
        deadline: Duration,
        force: bool,
    ) -> Result<()> {
        let ms = u32::try_from(deadline.as_millis()).unwrap_or(u32::MAX);
        let mut buf = Vec::with_capacity(10);
        buf.push(b'D');
        buf.extend_from_slice(&id.to_le_bytes());
        buf.extend_from_slice(&ms.to_le_bytes());
        buf.push(u8::from(force));
        self.sock.write_all(&buf)?;
        self.read_admin_ok()?;
        Ok(())
    }

    /// Admin: snapshot the server's live model registry (rows only — see
    /// [`Client::query_snapshot`] for the overload-control header too).
    pub fn query_registry(&mut self) -> Result<Vec<RegistryEntry>> {
        Ok(self.query_snapshot()?.models)
    }

    /// Admin: snapshot the registry plus the overload-control header
    /// (brownout stage, resident bytes, budget).
    pub fn query_snapshot(&mut self) -> Result<RegistrySnapshot> {
        self.sock.write_all(b"Q")?;
        match read_server_frame(&mut self.sock)? {
            ServerFrame::Registry(snap) => Ok(snap),
            ServerFrame::Reject(reason, _) => bail!("registry query rejected: {reason}"),
            other => bail!("expected registry frame, got {}", other.kind()),
        }
    }

    /// Admin: zero-downtime swap — load the model at `path` as the
    /// replacement for model `old`, let the server canary it, and on
    /// success redirect newcomers to the returned new id while `old`
    /// drains.  On canary failure the server rolls back (unloads the
    /// replacement, keeps `old` serving) and the error says why.
    pub fn swap_model(&mut self, old: u32, path: &str, weight: u32, lanes: u32) -> Result<u32> {
        let pb = path.as_bytes();
        let mut buf = Vec::with_capacity(17 + pb.len());
        buf.push(b'S');
        buf.extend_from_slice(&old.to_le_bytes());
        buf.extend_from_slice(&weight.to_le_bytes());
        buf.extend_from_slice(&lanes.to_le_bytes());
        buf.extend_from_slice(&(pb.len() as u32).to_le_bytes());
        buf.extend_from_slice(pb);
        self.sock.write_all(&buf)?;
        self.read_admin_ok()
    }

    /// Admin: fetch the server's Prometheus text-exposition metrics.
    pub fn metrics_text(&mut self) -> Result<String> {
        self.sock.write_all(b"T")?;
        match read_server_frame(&mut self.sock)? {
            ServerFrame::MetricsText(text) => Ok(text),
            ServerFrame::Reject(reason, _) => bail!("metrics query rejected: {reason}"),
            other => bail!("expected metrics frame, got {}", other.kind()),
        }
    }

    /// Admin: fetch the server's flight-recorder snapshot as a
    /// Chrome-trace / Perfetto JSON array (load it in `chrome://tracing`
    /// or <https://ui.perfetto.dev>).
    pub fn trace_json(&mut self) -> Result<String> {
        self.sock.write_all(b"X")?;
        match read_server_frame(&mut self.sock)? {
            ServerFrame::TraceJson(json) => Ok(json),
            ServerFrame::Reject(reason, _) => bail!("trace query rejected: {reason}"),
            other => bail!("expected trace frame, got {}", other.kind()),
        }
    }

    /// Read an admin response: `'O' u32` on success, `'R'` reason as an
    /// error.
    fn read_admin_ok(&mut self) -> Result<u32> {
        match read_server_frame(&mut self.sock)? {
            ServerFrame::AdminOk(v) => Ok(v),
            ServerFrame::Reject(reason, _) => bail!("admin rejected: {reason}"),
            other => bail!("expected admin response, got {}", other.kind()),
        }
    }

    /// End the stream and read the final result.  An admission rejection
    /// (`'R'`), an engine-initiated cancel (`'C'`), or a quarantined
    /// failure (`'E'`) each surface as an error carrying the server's
    /// reason.
    pub fn finish(mut self) -> Result<ClientResult> {
        self.sock.write_all(b"E")?;
        match read_server_frame(&mut self.sock)? {
            ServerFrame::Final(r) => Ok(r),
            ServerFrame::Reject(reason, trace) => {
                bail!("admission rejected: {reason} (trace {trace})")
            }
            ServerFrame::Cancelled(why, trace) => {
                bail!("stream cancelled by the server: {why} (trace {trace})")
            }
            ServerFrame::Failed(why, trace) => {
                bail!("stream failed on the server: {why} (trace {trace})")
            }
            other => bail!("expected final frame, got {}", other.kind()),
        }
    }

    /// Wait for the server's terminal frame *without* sending `'E'` —
    /// for observing engine-initiated endings (the reaper's `'C'`) on a
    /// stream the client intentionally abandoned mid-utterance.
    pub fn read_terminal(mut self) -> Result<ServerFrame> {
        Ok(read_server_frame(&mut self.sock)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn le(v: u32) -> [u8; 4] {
        v.to_le_bytes()
    }

    fn le64(v: u64) -> [u8; 8] {
        v.to_le_bytes()
    }

    #[test]
    fn client_frames_round_trip() {
        let mut c = Cursor::new(vec![b'P', 0u8]);
        assert!(matches!(read_client_frame(&mut c).unwrap(), Some(ClientFrame::Priority(_))));
        let mut b = vec![b'M'];
        b.extend_from_slice(&le(7));
        assert_eq!(read_client_frame(&mut Cursor::new(b)).unwrap(), Some(ClientFrame::Model(7)));
        let mut b = vec![b'A'];
        b.extend_from_slice(&le(2));
        b.extend_from_slice(&1.5f32.to_le_bytes());
        b.extend_from_slice(&(-0.25f32).to_le_bytes());
        match read_client_frame(&mut Cursor::new(b)).unwrap() {
            Some(ClientFrame::Audio(pcm)) => assert_eq!(pcm, vec![1.5, -0.25]),
            other => panic!("want audio, got {other:?}"),
        }
        let mut b = vec![b'D'];
        b.extend_from_slice(&le(3));
        b.extend_from_slice(&le(250));
        b.push(1);
        assert_eq!(
            read_client_frame(&mut Cursor::new(b)).unwrap(),
            Some(ClientFrame::UnloadDeadline { id: 3, deadline_ms: 250, force: true })
        );
        // 'S': swap request carries the old id plus the load triple.
        let mut b = vec![b'S'];
        b.extend_from_slice(&le(1)); // old
        b.extend_from_slice(&le(4)); // weight
        b.extend_from_slice(&le(0)); // lanes (engine default)
        b.extend_from_slice(&le(7));
        b.extend_from_slice(b"en-v2.q");
        assert_eq!(
            read_client_frame(&mut Cursor::new(b)).unwrap(),
            Some(ClientFrame::Swap { old: 1, weight: 4, lanes: 0, path: "en-v2.q".into() })
        );
        // 'T': bare metrics request.
        assert_eq!(
            read_client_frame(&mut Cursor::new(vec![b'T'])).unwrap(),
            Some(ClientFrame::Metrics)
        );
        // Clean EOF at the tag boundary is None, not an error.
        assert!(read_client_frame(&mut Cursor::new(Vec::new())).unwrap().is_none());
    }

    #[test]
    fn oversized_prefixes_error_before_reading() {
        // Audio length past the cap: refused from the prefix alone.
        let mut b = vec![b'A'];
        b.extend_from_slice(&le((MAX_AUDIO_SAMPLES + 1) as u32));
        match read_client_frame(&mut Cursor::new(b)) {
            Err(ServeError::Oversized { what: "audio chunk", .. }) => {}
            other => panic!("want oversized, got {other:?}"),
        }
        // Path length past the cap.
        let mut b = vec![b'L'];
        b.extend_from_slice(&le(1));
        b.extend_from_slice(&le(0));
        b.extend_from_slice(&le((MAX_TEXT_BYTES + 1) as u32));
        assert!(matches!(
            read_client_frame(&mut Cursor::new(b)),
            Err(ServeError::Oversized { .. })
        ));
    }

    #[test]
    fn truncated_and_unknown_frames_error_not_panic() {
        assert!(matches!(
            read_client_frame(&mut Cursor::new(vec![0x7fu8])),
            Err(ServeError::Protocol { .. })
        ));
        let mut b = vec![b'A'];
        b.extend_from_slice(&le(4));
        b.extend_from_slice(&[0u8; 7]); // 9 bytes short
        assert!(matches!(read_client_frame(&mut Cursor::new(b)), Err(ServeError::Io(_))));
        assert!(matches!(
            read_client_frame(&mut Cursor::new(vec![b'P', 9u8])),
            Err(ServeError::Protocol { .. })
        ));
    }

    #[test]
    fn server_frames_round_trip() {
        let mut b = text_frame(b'C', "idle past the timeout");
        b.extend_from_slice(&le64(77)); // trailing trace id
        match read_server_frame(&mut Cursor::new(b)).unwrap() {
            ServerFrame::Cancelled(why, trace) => {
                assert!(why.contains("idle"));
                assert_eq!(trace, 77);
            }
            other => panic!("want cancelled, got {other:?}"),
        }
        let mut b = text_frame(b'E', "decode panicked");
        b.extend_from_slice(&le64(5));
        assert!(matches!(
            read_server_frame(&mut Cursor::new(b)).unwrap(),
            ServerFrame::Failed(_, 5)
        ));
        // 'R' carries the trace id too (0 = admin failure, untraced).
        let mut b = text_frame(b'R', "saturated");
        b.extend_from_slice(&le64(0));
        assert!(matches!(
            read_server_frame(&mut Cursor::new(b)).unwrap(),
            ServerFrame::Reject(_, 0)
        ));
        // A truncated terminal frame (no trailing trace id) is an I/O
        // error, not a parse.
        let b = text_frame(b'C', "cut short");
        assert!(matches!(read_server_frame(&mut Cursor::new(b)), Err(ServeError::Io(_))));
        // 'F' ends with the trace id after the latency float.
        let mut b = vec![b'F'];
        b.extend_from_slice(&le(1)); // one word
        b.extend_from_slice(&le(42));
        b.extend_from_slice(&le(0)); // no phones
        b.extend_from_slice(&2.5f32.to_le_bytes());
        b.extend_from_slice(&le64(99));
        match read_server_frame(&mut Cursor::new(b)).unwrap() {
            ServerFrame::Final(r) => {
                assert_eq!(r.words, vec![42]);
                assert_eq!(r.trace, 99);
            }
            other => panic!("want final, got {other:?}"),
        }
        // 'X' trace export round-trips; an oversized prefix is refused.
        let b = text_frame(b'X', "[]");
        match read_server_frame(&mut Cursor::new(b)).unwrap() {
            ServerFrame::TraceJson(json) => assert_eq!(json, "[]"),
            other => panic!("want trace, got {other:?}"),
        }
        let mut b = vec![b'X'];
        b.extend_from_slice(&le((MAX_TRACE_BYTES + 1) as u32));
        assert!(matches!(
            read_server_frame(&mut Cursor::new(b)),
            Err(ServeError::Oversized { what: "trace export", .. })
        ));
        // 'X' as a client frame is a bare tag, like 'Q'/'T'.
        assert_eq!(
            read_client_frame(&mut Cursor::new(vec![b'X'])).unwrap(),
            Some(ClientFrame::Trace)
        );
        // 'Q' with the overload header and one quarantined row.
        let mut b = vec![b'Q'];
        b.push(1); // brownout: shedding
        b.extend_from_slice(&le64(4096)); // resident
        b.extend_from_slice(&le64(8192)); // budget
        b.extend_from_slice(&le(1)); // row count
        b.extend_from_slice(&le(4)); // id
        b.push(2); // status: quarantined
        b.extend_from_slice(&le(3)); // weight
        b.extend_from_slice(&le(2)); // lanes
        b.extend_from_slice(&le(1)); // live
        b.extend_from_slice(&le64(3000)); // arena bytes
        b.extend_from_slice(&le64(1024)); // reserved bytes
        b.extend_from_slice(&le64(512)); // parked bytes
        b.extend_from_slice(&le(2));
        b.extend_from_slice(b"en");
        b.extend_from_slice(&le(14)); // scheme text follows the name
        b.extend_from_slice(b"per-channel-i4");
        match read_server_frame(&mut Cursor::new(b)).unwrap() {
            ServerFrame::Registry(snap) => {
                assert_eq!(snap.brownout_stage, 1);
                assert_eq!(snap.resident_bytes, 4096);
                assert_eq!(snap.budget_bytes, 8192);
                assert_eq!(snap.models.len(), 1);
                let row = &snap.models[0];
                assert!(row.quarantined && !row.draining);
                assert_eq!(
                    (row.arena_bytes, row.reserved_bytes, row.parked_bytes),
                    (3000, 1024, 512)
                );
                assert_eq!(row.name, "en");
                assert_eq!(row.scheme, "per-channel-i4");
            }
            other => panic!("want registry, got {other:?}"),
        }
        // Unknown status byte is a protocol error, not a guess.
        let mut b = vec![b'Q'];
        b.push(0);
        b.extend_from_slice(&le64(0));
        b.extend_from_slice(&le64(0));
        b.extend_from_slice(&le(1));
        b.extend_from_slice(&le(0));
        b.push(3);
        assert!(matches!(
            read_server_frame(&mut Cursor::new(b)),
            Err(ServeError::Protocol { .. })
        ));
        // Unknown brownout stage byte is a protocol error too.
        let b = vec![b'Q', 9];
        assert!(matches!(
            read_server_frame(&mut Cursor::new(b)),
            Err(ServeError::Protocol { .. })
        ));
        // 'T' metrics text round-trips; an oversized prefix is refused
        // before allocation.
        let b = text_frame(b'T', "# HELP quantasr_streams_admitted_total x\n");
        match read_server_frame(&mut Cursor::new(b)).unwrap() {
            ServerFrame::MetricsText(text) => assert!(text.starts_with("# HELP")),
            other => panic!("want metrics, got {other:?}"),
        }
        let mut b = vec![b'T'];
        b.extend_from_slice(&le((MAX_METRICS_BYTES + 1) as u32));
        assert!(matches!(
            read_server_frame(&mut Cursor::new(b)),
            Err(ServeError::Oversized { what: "metrics exposition", .. })
        ));
    }
}
