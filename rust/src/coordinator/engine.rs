//! The serving engine: streams in, batched acoustic-model steps, final
//! lexicon+LM decodes out.
//!
//! Thread topology (std threads; the image has no tokio):
//!
//! ```text
//! callers ──push_audio──▶ per-stream Frontend ──▶ pending frame queues
//!                                                (bounded; backpressure)
//! AM worker ── BatchPolicy ──▶ pack states ▶ model.step(batch) ▶ scatter
//! decode workers ◀── finished streams' posteriors ──▶ FinalResult channel
//! ```
//!
//! The AM worker copies each participating stream's recurrent state into a
//! contiguous batch `ModelState`, runs one step, and copies states back —
//! the gather/scatter is O(batch·state) floats and is dwarfed by the GEMMs
//! (measured in `bench_e2e`).  Decoding (CTC beam + LM rescore) is heavier
//! and utterance-final, so it runs on its own worker pool.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::batcher::{BatchPolicy, Decision};
use crate::coordinator::metrics::Metrics;
use crate::decoder::Decoder;
use crate::frontend::{spec, Frontend};
use crate::nn::{AcousticModel, ModelState};

/// Engine configuration.
#[derive(Clone)]
pub struct EngineConfig {
    pub policy: BatchPolicy,
    pub decode_workers: usize,
    /// Per-stream pending-frame cap (backpressure bound).
    pub max_pending_frames: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: BatchPolicy::default(),
            decode_workers: 2,
            max_pending_frames: 256,
        }
    }
}

/// Final recognition result for one stream.
#[derive(Clone, Debug)]
pub struct FinalResult {
    pub stream_id: u64,
    pub words: Vec<u32>,
    /// Greedy phone sequence (diagnostic / LER).
    pub phones: Vec<u32>,
    pub num_frames: usize,
    /// finish() called → result ready.
    pub finalize_latency: Duration,
}

struct StreamSlot {
    frontend: Frontend,
    /// Feature frames awaiting the AM, flattened FEAT_DIM each.
    pending: VecDeque<Vec<f32>>,
    oldest_enqueue: Option<Instant>,
    /// Accumulated log-posteriors [frames_done, num_labels].
    posteriors: Vec<f32>,
    frames_done: usize,
    state: ModelState,
    finished: bool,
    finish_time: Option<Instant>,
    result_tx: Sender<FinalResult>,
}

struct DecodeJob {
    stream_id: u64,
    posteriors: Vec<f32>,
    num_frames: usize,
    finish_time: Instant,
    result_tx: Sender<FinalResult>,
}

struct Inner {
    streams: HashMap<u64, StreamSlot>,
    next_id: u64,
    decode_queue: VecDeque<DecodeJob>,
}

struct Shared {
    inner: Mutex<Inner>,
    /// Wakes the AM worker (new frames / finished streams).
    work_cv: Condvar,
    /// Wakes decode workers.
    decode_cv: Condvar,
    /// Wakes producers blocked on backpressure.
    space_cv: Condvar,
    metrics: Metrics,
    config: EngineConfig,
    shutdown: AtomicBool,
}

/// The streaming serving engine.
pub struct Engine {
    model: Arc<AcousticModel>,
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Engine {
    pub fn start(model: Arc<AcousticModel>, decoder: Arc<Decoder>, config: EngineConfig) -> Self {
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                streams: HashMap::new(),
                next_id: 0,
                decode_queue: VecDeque::new(),
            }),
            work_cv: Condvar::new(),
            decode_cv: Condvar::new(),
            space_cv: Condvar::new(),
            metrics: Metrics::default(),
            config,
            shutdown: AtomicBool::new(false),
        });
        let mut workers = Vec::new();
        {
            let s = shared.clone();
            let m = model.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("am-worker".into())
                    .spawn(move || am_worker(s, m))
                    .expect("spawn am worker"),
            );
        }
        for i in 0..shared.config.decode_workers {
            let s = shared.clone();
            let d = decoder.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("decode-{i}"))
                    .spawn(move || decode_worker(s, d))
                    .expect("spawn decode worker"),
            );
        }
        Engine { model, shared, workers }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// Open a new stream; returns its id and the final-result receiver.
    pub fn open_stream(&self) -> (u64, Receiver<FinalResult>) {
        let (tx, rx) = channel();
        let mut inner = self.shared.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.streams.insert(
            id,
            StreamSlot {
                frontend: Frontend::new(),
                pending: VecDeque::new(),
                oldest_enqueue: None,
                posteriors: Vec::new(),
                frames_done: 0,
                state: self.model.new_state(1),
                finished: false,
                finish_time: None,
                result_tx: tx,
            },
        );
        (id, rx)
    }

    /// Push PCM samples (blocks under backpressure).
    pub fn push_audio(&self, id: u64, pcm: &[f32]) -> Result<()> {
        self.shared.metrics.add_audio(pcm.len() as f64 / spec::SAMPLE_RATE as f64);
        let mut frames = Vec::new();
        {
            let mut inner = self.shared.inner.lock().unwrap();
            let slot = match inner.streams.get_mut(&id) {
                Some(s) => s,
                None => bail!("unknown stream {id}"),
            };
            if slot.finished {
                bail!("stream {id} already finished");
            }
            slot.frontend.push(pcm, &mut frames);
        }
        self.push_frames(id, &frames)
    }

    /// Push pre-computed feature frames (len = k·FEAT_DIM).
    pub fn push_frames(&self, id: u64, frames: &[f32]) -> Result<()> {
        let d = spec::FEAT_DIM;
        assert_eq!(frames.len() % d, 0);
        let mut offset = 0;
        while offset < frames.len() {
            let mut inner = self.shared.inner.lock().unwrap();
            // backpressure: wait for queue space
            loop {
                let slot = match inner.streams.get(&id) {
                    Some(s) => s,
                    None => bail!("unknown stream {id}"),
                };
                if slot.pending.len() < self.shared.config.max_pending_frames {
                    break;
                }
                inner = self.shared.space_cv.wait(inner).unwrap();
            }
            let cap = self.shared.config.max_pending_frames;
            let slot = inner.streams.get_mut(&id).unwrap();
            let now = Instant::now();
            while offset < frames.len() && slot.pending.len() < cap {
                slot.pending.push_back(frames[offset..offset + d].to_vec());
                offset += d;
            }
            slot.oldest_enqueue.get_or_insert(now);
            drop(inner);
            self.shared.work_cv.notify_all();
        }
        Ok(())
    }

    /// Signal end of audio; the final decode is delivered on the stream's
    /// receiver once all pending frames are processed.
    pub fn finish_stream(&self, id: u64) -> Result<()> {
        let mut inner = self.shared.inner.lock().unwrap();
        let slot = match inner.streams.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown stream {id}"),
        };
        slot.finished = true;
        slot.finish_time = Some(Instant::now());
        drop(inner);
        self.shared.work_cv.notify_all();
        Ok(())
    }

    /// Convenience: run one utterance synchronously through the engine.
    pub fn recognize(&self, pcm: &[f32]) -> Result<FinalResult> {
        let (id, rx) = self.open_stream();
        self.push_audio(id, pcm)?;
        self.finish_stream(id)?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn am_worker(s: Arc<Shared>, model: Arc<AcousticModel>) {
    let labels = model.num_labels();
    let d = model.input_dim();
    // Reusable batch buffers sized to max_batch.  Per-batch states are
    // rebuilt each flush (cache of states per batch size; see perf pass).
    let max_b = s.config.policy.max_batch;
    let mut state_cache: Vec<Option<ModelState>> = (0..=max_b).map(|_| None).collect();
    let mut xbuf = vec![0f32; max_b * d];
    let mut ybuf = vec![0f32; max_b * labels];

    loop {
        if s.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let mut inner = s.inner.lock().unwrap();
        // Streams can finish *after* their last frame was computed (the
        // finish() raced the final batch) or with no audio at all — drain
        // them to the decode queue every tick, before the policy decision.
        drain_finished(&mut inner, &s);
        // Evaluate policy.
        let now = Instant::now();
        let mut ready: Vec<(u64, Duration)> = inner
            .streams
            .iter()
            .filter(|(_, sl)| !sl.pending.is_empty())
            .map(|(&id, sl)| {
                (id, sl.oldest_enqueue.map(|t| now - t).unwrap_or_default())
            })
            .collect();
        ready.sort_by(|a, b| b.1.cmp(&a.1)); // oldest first
        let oldest = ready.first().map(|r| r.1).unwrap_or_default();
        match s.config.policy.decide(ready.len(), oldest) {
            Decision::Idle => {
                let (guard, _t) = s
                    .work_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                drop(guard);
                continue;
            }
            Decision::Wait(d) => {
                let (guard, _t) = s.work_cv.wait_timeout(inner, d).unwrap();
                drop(guard);
                continue;
            }
            Decision::Flush => {}
        }
        // Assemble the batch: pop one frame per ready stream (oldest first).
        let batch_ids: Vec<u64> =
            ready.iter().take(max_b).map(|&(id, _)| id).collect();
        let b = batch_ids.len();
        let mut batch_state = state_cache[b]
            .take()
            .unwrap_or_else(|| model.new_state(b));
        let mut enqueue_times = Vec::with_capacity(b);
        for (slot_idx, &id) in batch_ids.iter().enumerate() {
            let slot = inner.streams.get_mut(&id).unwrap();
            let frame = slot.pending.pop_front().unwrap();
            xbuf[slot_idx * d..(slot_idx + 1) * d].copy_from_slice(&frame);
            enqueue_times.push(slot.oldest_enqueue);
            slot.oldest_enqueue =
                if slot.pending.is_empty() { None } else { Some(now) };
            batch_state.copy_stream_from(&model, slot_idx, &slot.state, 0);
        }
        drop(inner);
        s.space_cv.notify_all();

        // Batched AM step (lock-free; states are private copies).
        let t0 = Instant::now();
        model.step(&xbuf[..b * d], &mut batch_state, &mut ybuf[..b * labels]);
        let dt = t0.elapsed();
        s.metrics.add_am_compute(dt.as_secs_f64(), b as u64);
        s.metrics.batch_size.record(b as f64);
        for t in &enqueue_times {
            if let Some(t0q) = t {
                s.metrics.frame_latency.record_duration(now - *t0q + dt);
            }
        }

        // Scatter results back; queue decodes for drained finished streams.
        let mut inner = s.inner.lock().unwrap();
        for (slot_idx, &id) in batch_ids.iter().enumerate() {
            if let Some(slot) = inner.streams.get_mut(&id) {
                slot.state.copy_stream_from(&model, 0, &batch_state, slot_idx);
                slot.posteriors
                    .extend_from_slice(&ybuf[slot_idx * labels..(slot_idx + 1) * labels]);
                slot.frames_done += 1;
            }
        }
        state_cache[b] = Some(batch_state);
        drain_finished(&mut inner, &s);
    }
}

/// Move every (finished && drained) stream to the decode queue.
fn drain_finished(inner: &mut Inner, s: &Arc<Shared>) {
    let done: Vec<u64> = inner
        .streams
        .iter()
        .filter(|(_, sl)| sl.finished && sl.pending.is_empty())
        .map(|(&id, _)| id)
        .collect();
    for id in done {
        let slot = inner.streams.remove(&id).unwrap();
        inner.decode_queue.push_back(DecodeJob {
            stream_id: id,
            posteriors: slot.posteriors,
            num_frames: slot.frames_done,
            finish_time: slot.finish_time.unwrap_or_else(Instant::now),
            result_tx: slot.result_tx,
        });
        s.decode_cv.notify_one();
    }
}

fn decode_worker(s: Arc<Shared>, decoder: Arc<Decoder>) {
    loop {
        let job = {
            let mut inner = s.inner.lock().unwrap();
            loop {
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(job) = inner.decode_queue.pop_front() {
                    break job;
                }
                let (guard, _t) = s
                    .decode_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                inner = guard;
            }
        };
        let labels = job.posteriors.len() / job.num_frames.max(1);
        let hyp = decoder.decode(&job.posteriors, labels.max(1));
        let phones = crate::decoder::ctc::greedy(&job.posteriors, labels.max(1));
        s.metrics.add_utterance();
        let latency = job.finish_time.elapsed();
        s.metrics.finalize_latency.record_duration(latency);
        let _ = job.result_tx.send(FinalResult {
            stream_id: job.stream_id,
            words: hyp.words,
            phones,
            num_frames: job.num_frames,
            finalize_latency: latency,
        });
    }
}
