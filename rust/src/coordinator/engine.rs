//! The serving engine: streams in, batched acoustic-model steps, final
//! lexicon+LM decodes out.  Generic over the execution backend
//! ([`AmBackend`]): the native int8 engine is the production path, the
//! PJRT/AOT graph (feature `pjrt`) is a one-line swap at [`Engine::start`].
//!
//! Thread topology (std threads; the image has no tokio):
//!
//! ```text
//! callers ──push_audio──▶ per-stream Frontend ──▶ pending frame queues
//!                                                (bounded; backpressure)
//! AM worker ── BatchPolicy ──▶ step active lanes of the arena, in place
//!   └── large packed GEMMs fan panels out to the persistent worker pool
//!       (util::pool; parked threads, QUANTASR_GEMM_THREADS caps them)
//! decode workers ◀── finished streams' posteriors ──▶ FinalResult channel
//! ```
//!
//! The AM step itself is allocation-free: the arena pre-sizes all scratch
//! (gates, projection buffer, per-layer activation-quantization caches)
//! at `Engine::start`, the fused SIMD elementwise kernel updates cell
//! state in one pass, and each layer output is quantized once per tick
//! (`quant::gemm::QActRows`) instead of once per consuming GEMM.
//!
//! **Lane-resident batching.**  Each live stream owns a stable *lane* in
//! the backend's pre-allocated arena (`[max_batch, state]` buffers); the
//! AM worker writes each scheduled stream's frame into its lane's row of a
//! lane-resident input buffer and steps the active lanes **in place** —
//! recurrent state never moves.  The previous design copied every
//! participating stream's state into a fresh contiguous batch and copied
//! it back after the step, an O(batch·state) gather/scatter per tick that
//! `bench_e2e` now shows eliminated.  Lane numerics are bit-identical to
//! running the stream alone (per-row quantization, `quant::gemm`), so lane
//! assignment is invisible to results.
//!
//! When live streams outnumber lanes, lane-less ready streams wait for a
//! free lane; if every lane is held but some holder is *idle* (no frame
//! pending), the holder is **evicted** — its lane state is parked on the
//! stream slot ([`AmBackend::save_lane`]) and restored when it is next
//! scheduled.  Eviction is the only remaining state copy and happens per
//! lane *transition*, not per tick.  A stream that never goes idle cannot
//! be evicted; under full saturation newcomers therefore wait for a
//! holder to drain (fair preemption is a ROADMAP follow-on).
//!
//! Decoding (CTC beam + LM rescore) is heavier and utterance-final, so it
//! runs on its own worker pool.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::batcher::{BatchPolicy, Decision, LaneAllocator};
use crate::coordinator::metrics::Metrics;
use crate::decoder::Decoder;
use crate::frontend::{spec, Frontend};
use crate::nn::AcousticModel;
use crate::runtime::backend::AmBackend;

/// Engine configuration.
#[derive(Clone)]
pub struct EngineConfig {
    pub policy: BatchPolicy,
    pub decode_workers: usize,
    /// Per-stream pending-frame cap (backpressure bound).
    pub max_pending_frames: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: BatchPolicy::default(),
            decode_workers: 2,
            max_pending_frames: 256,
        }
    }
}

/// Final recognition result for one stream.
#[derive(Clone, Debug)]
pub struct FinalResult {
    pub stream_id: u64,
    pub words: Vec<u32>,
    /// Greedy phone sequence (diagnostic / LER).
    pub phones: Vec<u32>,
    pub num_frames: usize,
    /// finish() called → result ready.
    pub finalize_latency: Duration,
}

struct StreamSlot<B: AmBackend> {
    frontend: Frontend,
    /// Feature frames awaiting the AM, flattened FEAT_DIM each.
    pending: VecDeque<Vec<f32>>,
    oldest_enqueue: Option<Instant>,
    /// Accumulated log-posteriors [frames_done, num_labels].
    posteriors: Vec<f32>,
    frames_done: usize,
    /// Arena lane holding this stream's recurrent state, if admitted.
    lane: Option<usize>,
    /// State parked outside the arena (evicted / not yet admitted).
    /// `None` with `lane: None` ⇒ fresh zero state.
    parked: Option<B::Parked>,
    finished: bool,
    finish_time: Option<Instant>,
    result_tx: Sender<FinalResult>,
}

struct DecodeJob {
    stream_id: u64,
    posteriors: Vec<f32>,
    num_frames: usize,
    finish_time: Instant,
    result_tx: Sender<FinalResult>,
}

struct Inner<B: AmBackend> {
    streams: HashMap<u64, StreamSlot<B>>,
    lanes: LaneAllocator,
    next_id: u64,
    decode_queue: VecDeque<DecodeJob>,
}

struct Shared<B: AmBackend> {
    inner: Mutex<Inner<B>>,
    /// Wakes the AM worker (new frames / finished streams).
    work_cv: Condvar,
    /// Wakes decode workers.
    decode_cv: Condvar,
    /// Wakes producers blocked on backpressure.
    space_cv: Condvar,
    metrics: Metrics,
    config: EngineConfig,
    shutdown: AtomicBool,
}

/// The streaming serving engine, generic over the execution backend
/// (defaults to the native [`AcousticModel`]).
pub struct Engine<B: AmBackend = AcousticModel> {
    backend: Arc<B>,
    shared: Arc<Shared<B>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<B: AmBackend> Engine<B> {
    pub fn start(backend: Arc<B>, decoder: Arc<Decoder>, mut config: EngineConfig) -> Self {
        // Lane-capped backends (e.g. an AOT graph lowered at a fixed batch)
        // bound the arena: clamp rather than panic so the raised default
        // `max_batch` (32) still works against a smaller fixed-batch graph.
        if let Some(cap) = backend.lane_capacity() {
            if config.policy.max_batch > cap {
                eprintln!(
                    "engine: backend '{}' supports {cap} lanes; clamping max_batch {} -> {cap}",
                    backend.backend_name(),
                    config.policy.max_batch
                );
                config.policy.max_batch = cap;
            }
        }
        let max_lanes = config.policy.max_batch;
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                streams: HashMap::new(),
                lanes: LaneAllocator::new(max_lanes),
                next_id: 0,
                decode_queue: VecDeque::new(),
            }),
            work_cv: Condvar::new(),
            decode_cv: Condvar::new(),
            space_cv: Condvar::new(),
            metrics: Metrics::default(),
            config,
            shutdown: AtomicBool::new(false),
        });
        let mut workers = Vec::new();
        {
            let s = shared.clone();
            let b = backend.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("am-worker".into())
                    .spawn(move || am_worker(s, b))
                    .expect("spawn am worker"),
            );
        }
        for i in 0..shared.config.decode_workers {
            let s = shared.clone();
            let d = decoder.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("decode-{i}"))
                    .spawn(move || decode_worker(s, d))
                    .expect("spawn decode worker"),
            );
        }
        Engine { backend, shared, workers }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// The execution backend this engine drives.
    pub fn backend(&self) -> &Arc<B> {
        &self.backend
    }

    /// Open a new stream; returns its id and the final-result receiver.
    /// The stream is admitted to an arena lane lazily, when it is first
    /// scheduled into a batch.
    pub fn open_stream(&self) -> (u64, Receiver<FinalResult>) {
        let (tx, rx) = channel();
        let mut inner = self.shared.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.streams.insert(
            id,
            StreamSlot {
                frontend: Frontend::new(),
                pending: VecDeque::new(),
                oldest_enqueue: None,
                posteriors: Vec::new(),
                frames_done: 0,
                lane: None,
                parked: None,
                finished: false,
                finish_time: None,
                result_tx: tx,
            },
        );
        (id, rx)
    }

    /// Push PCM samples (blocks under backpressure).
    pub fn push_audio(&self, id: u64, pcm: &[f32]) -> Result<()> {
        self.shared.metrics.add_audio(pcm.len() as f64 / spec::SAMPLE_RATE as f64);
        let mut frames = Vec::new();
        {
            let mut inner = self.shared.inner.lock().unwrap();
            let slot = match inner.streams.get_mut(&id) {
                Some(s) => s,
                None => bail!("unknown stream {id}"),
            };
            if slot.finished {
                bail!("stream {id} already finished");
            }
            slot.frontend.push(pcm, &mut frames);
        }
        self.push_frames(id, &frames)
    }

    /// Push pre-computed feature frames (len = k·input_dim).
    pub fn push_frames(&self, id: u64, frames: &[f32]) -> Result<()> {
        let d = self.backend.input_dim();
        assert_eq!(frames.len() % d, 0);
        let mut offset = 0;
        while offset < frames.len() {
            let mut inner = self.shared.inner.lock().unwrap();
            // backpressure: wait for queue space
            loop {
                let slot = match inner.streams.get(&id) {
                    Some(s) => s,
                    None => bail!("unknown stream {id}"),
                };
                if slot.pending.len() < self.shared.config.max_pending_frames {
                    break;
                }
                inner = self.shared.space_cv.wait(inner).unwrap();
            }
            let cap = self.shared.config.max_pending_frames;
            let slot = inner.streams.get_mut(&id).unwrap();
            let now = Instant::now();
            while offset < frames.len() && slot.pending.len() < cap {
                slot.pending.push_back(frames[offset..offset + d].to_vec());
                offset += d;
            }
            slot.oldest_enqueue.get_or_insert(now);
            drop(inner);
            self.shared.work_cv.notify_all();
        }
        Ok(())
    }

    /// Signal end of audio; the final decode is delivered on the stream's
    /// receiver once all pending frames are processed.
    pub fn finish_stream(&self, id: u64) -> Result<()> {
        let mut inner = self.shared.inner.lock().unwrap();
        let slot = match inner.streams.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown stream {id}"),
        };
        slot.finished = true;
        slot.finish_time = Some(Instant::now());
        drop(inner);
        self.shared.work_cv.notify_all();
        Ok(())
    }

    /// Convenience: run one utterance synchronously through the engine.
    pub fn recognize(&self, pcm: &[f32]) -> Result<FinalResult> {
        let (id, rx) = self.open_stream();
        self.push_audio(id, pcm)?;
        self.finish_stream(id)?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<B: AmBackend> Drop for Engine<B> {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn am_worker<B: AmBackend>(s: Arc<Shared<B>>, backend: Arc<B>) {
    let labels = backend.num_labels();
    let d = backend.input_dim();
    let max_lanes = s.config.policy.max_batch;
    // The persistent arena: every live stream's recurrent state lives in
    // its lane for the engine's lifetime.  Allocated once, stepped in
    // place — zero per-tick state copies.
    let mut arena = backend.alloc_arena(max_lanes);
    // Lane-resident I/O buffers (row `lane` belongs to that lane's stream).
    let mut xbuf = vec![0f32; max_lanes * d];
    let mut ybuf = vec![0f32; max_lanes * labels];

    loop {
        if s.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let mut inner = s.inner.lock().unwrap();
        // Streams can finish *after* their last frame was computed (the
        // finish() raced the final batch) or with no audio at all — drain
        // them to the decode queue every tick, before the policy decision.
        drain_finished(&mut inner, &s);
        // Evaluate policy.
        let now = Instant::now();
        let mut ready: Vec<(u64, Duration)> = inner
            .streams
            .iter()
            .filter(|(_, sl)| !sl.pending.is_empty())
            .map(|(&id, sl)| {
                (id, sl.oldest_enqueue.map(|t| now - t).unwrap_or_default())
            })
            .collect();
        ready.sort_by(|a, b| b.1.cmp(&a.1)); // oldest first
        let oldest = ready.first().map(|r| r.1).unwrap_or_default();
        match s.config.policy.decide(ready.len(), oldest) {
            Decision::Idle => {
                let (guard, _t) = s
                    .work_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                drop(guard);
                continue;
            }
            Decision::Wait(d) => {
                let (guard, _t) = s.work_cv.wait_timeout(inner, d).unwrap();
                drop(guard);
                continue;
            }
            Decision::Flush => {}
        }
        // Plan the batch.  Pass 1: ready streams that already hold a lane
        // ride for free.  Pass 2: admit lane-less ready streams (oldest
        // first) into free lanes, evicting idle holders when none are
        // free.  At most `max_lanes` streams step per tick by
        // construction (there are only `max_lanes` lanes).
        let mut planned: Vec<(u64, usize)> = Vec::with_capacity(max_lanes);
        for &(id, _) in &ready {
            if let Some(lane) = inner.streams[&id].lane {
                planned.push((id, lane));
            }
        }
        for &(id, _) in &ready {
            if planned.len() == max_lanes {
                break;
            }
            if inner.streams[&id].lane.is_some() {
                continue;
            }
            let lane = match inner.lanes.acquire() {
                Some(l) => Some(l),
                None => {
                    // Evict an idle lane holder (no pending frame ⇒ not in
                    // `ready` ⇒ not planned this tick).  The lane changes
                    // hands without passing through the allocator.
                    let victim = inner
                        .streams
                        .iter()
                        .find(|(_, vs)| vs.lane.is_some() && vs.pending.is_empty())
                        .map(|(&vid, _)| vid);
                    victim.map(|vid| {
                        let vslot = inner.streams.get_mut(&vid).unwrap();
                        let l = vslot.lane.take().unwrap();
                        vslot.parked = Some(backend.save_lane(&arena, l));
                        s.metrics.add_eviction();
                        l
                    })
                }
            };
            // No free lane and no idle holder: every lane is stepping this
            // tick; the remaining ready streams wait for a drain/idle.
            let Some(lane) = lane else { break };
            let slot = inner.streams.get_mut(&id).unwrap();
            match slot.parked.take() {
                Some(p) => backend.load_lane(&mut arena, lane, &p),
                None => backend.reset_lane(&mut arena, lane),
            }
            slot.lane = Some(lane);
            planned.push((id, lane));
        }
        // Unreachable with max_batch > 0 (a ready stream either holds a
        // lane, or a lane is free, or some holder is idle) — but parking
        // beats a busy-spin if that invariant ever breaks.
        if planned.is_empty() {
            let (guard, _t) = s
                .work_cv
                .wait_timeout(inner, Duration::from_millis(20))
                .unwrap();
            drop(guard);
            continue;
        }
        // Pop one frame per planned stream into its lane's input row.
        let mut lanes_list: Vec<usize> = Vec::with_capacity(planned.len());
        let mut enqueue_times = Vec::with_capacity(planned.len());
        for &(id, lane) in &planned {
            let slot = inner.streams.get_mut(&id).unwrap();
            let frame = slot.pending.pop_front().unwrap();
            xbuf[lane * d..(lane + 1) * d].copy_from_slice(&frame);
            enqueue_times.push(slot.oldest_enqueue);
            slot.oldest_enqueue =
                if slot.pending.is_empty() { None } else { Some(now) };
            lanes_list.push(lane);
        }
        let b = planned.len();
        s.metrics
            .lane_occupancy
            .record(inner.lanes.in_use() as f64 / max_lanes.max(1) as f64);
        drop(inner);
        s.space_cv.notify_all();

        // Batched AM step over the active lanes, in place (lock-free; the
        // arena is worker-local and lane rows belong to planned streams).
        let t0 = Instant::now();
        if let Err(e) = backend.step_lanes(&mut arena, &lanes_list, &xbuf, &mut ybuf) {
            // Backend failure (only fallible for the PJRT path): surface
            // loudly, put the popped frames back at the head of their
            // queues (no silent truncation of posteriors), and back off
            // before retrying so a persistently-dead backend applies
            // backpressure instead of busy-looping through the audio.
            eprintln!("am backend '{}' step failed: {e:#}", backend.backend_name());
            let mut inner = s.inner.lock().unwrap();
            let now_err = Instant::now();
            for &(id, lane) in &planned {
                if let Some(slot) = inner.streams.get_mut(&id) {
                    slot.pending.push_front(xbuf[lane * d..(lane + 1) * d].to_vec());
                    slot.oldest_enqueue.get_or_insert(now_err);
                }
            }
            drain_finished(&mut inner, &s);
            drop(inner);
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }
        let dt = t0.elapsed();
        s.metrics.add_am_compute(dt.as_secs_f64(), b as u64);
        s.metrics.batch_size.record(b as f64);
        for t in &enqueue_times {
            if let Some(t0q) = t {
                s.metrics.frame_latency.record_duration(now - *t0q + dt);
            }
        }

        // Append each lane's posteriors to its stream; queue decodes for
        // drained finished streams.  (This is result delivery, not state
        // movement — recurrent state stayed in the arena.)
        let mut inner = s.inner.lock().unwrap();
        for &(id, lane) in &planned {
            if let Some(slot) = inner.streams.get_mut(&id) {
                slot.posteriors
                    .extend_from_slice(&ybuf[lane * labels..(lane + 1) * labels]);
                slot.frames_done += 1;
            }
        }
        drain_finished(&mut inner, &s);
    }
}

/// Move every (finished && drained) stream to the decode queue, releasing
/// its arena lane.
fn drain_finished<B: AmBackend>(inner: &mut Inner<B>, s: &Shared<B>) {
    let done: Vec<u64> = inner
        .streams
        .iter()
        .filter(|(_, sl)| sl.finished && sl.pending.is_empty())
        .map(|(&id, _)| id)
        .collect();
    for id in done {
        let slot = inner.streams.remove(&id).unwrap();
        if let Some(lane) = slot.lane {
            inner.lanes.release(lane);
        }
        inner.decode_queue.push_back(DecodeJob {
            stream_id: id,
            posteriors: slot.posteriors,
            num_frames: slot.frames_done,
            finish_time: slot.finish_time.unwrap_or_else(Instant::now),
            result_tx: slot.result_tx,
        });
        s.decode_cv.notify_one();
    }
}

fn decode_worker<B: AmBackend>(s: Arc<Shared<B>>, decoder: Arc<Decoder>) {
    loop {
        let job = {
            let mut inner = s.inner.lock().unwrap();
            loop {
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(job) = inner.decode_queue.pop_front() {
                    break job;
                }
                let (guard, _t) = s
                    .decode_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                inner = guard;
            }
        };
        let labels = job.posteriors.len() / job.num_frames.max(1);
        let hyp = decoder.decode(&job.posteriors, labels.max(1));
        let phones = crate::decoder::ctc::greedy(&job.posteriors, labels.max(1));
        s.metrics.add_utterance();
        let latency = job.finish_time.elapsed();
        s.metrics.finalize_latency.record_duration(latency);
        let _ = job.result_tx.send(FinalResult {
            stream_id: job.stream_id,
            words: hyp.words,
            phones,
            num_frames: job.num_frames,
            finalize_latency: latency,
        });
    }
}
