//! The serving engine: streams in, batched acoustic-model steps, final
//! lexicon+LM decodes out.  Generic over the execution backend
//! ([`AmBackend`]): the native int8 engine is the production path, the
//! PJRT/AOT graph (feature `pjrt`) is a one-line swap at
//! [`Engine::start`].  The system-level map (layers, locks, the life of
//! one tick) lives in `docs/ARCHITECTURE.md`.
//!
//! Thread topology (std threads; the image has no tokio):
//!
//! ```text
//! callers ──push_audio──▶ per-stream Frontend ──▶ pending frame queues
//!                                                (bounded; backpressure)
//! AM worker ── BatchPolicy + sched ──▶ step each model's granted lanes
//!   ├── admin queue: hot model load/unload at tick boundaries
//!   └── large packed GEMMs fan panels out to the persistent worker pool
//!       (util::pool; parked threads, QUANTASR_GEMM_THREADS caps them)
//! decode workers ◀── priority decode queue ◀── finished streams
//! ```
//!
//! **Lane-resident batching.**  Each live stream owns a stable *lane* in
//! its model's pre-allocated arena (`[lanes, state]` buffers); the AM
//! worker writes each scheduled stream's frame into its lane's row of a
//! lane-resident input buffer and steps the granted lanes **in place** —
//! recurrent state never moves per tick.  Lane numerics are bit-identical
//! to running the stream alone (per-row quantization, `quant::gemm`), so
//! lane assignment is invisible to results.
//!
//! **Scheduling** is owned by [`crate::sched`]; the engine is mechanism.
//! When live streams outnumber lanes, lane-less ready streams are placed
//! in priority order ([`schedule_cmp`]): a free lane if any, else an
//! *idle* holder is **evicted** (state parked on the stream slot via
//! [`AmBackend::save_lane`]), else an active holder that has consumed its
//! tick quantum — or holds a lower QoS class than the waiter — is
//! **preempted** through the same exact parking path
//! ([`QuantumPolicy::select_victim`]).  Preemption happens at tick
//! boundaries only, so a preempted stream's outputs are bit-identical to
//! an unpreempted run; a newcomer's wait is bounded by one quantum even
//! when every holder streams continuously.  Admission is bounded
//! ([`crate::sched::admission`]): beyond the live-stream cap,
//! [`Engine::try_open_stream`] rejects with a reason instead of growing
//! without limit.
//!
//! **Dynamic multi-model serving.**  [`Engine::start_registry`] seeds an
//! index-stable model table ([`ModelRegistry`]); each model gets its own
//! lane-tagged arena and allocator, one scheduler places streams per
//! model, and one AM worker steps every model's granted lanes.  The table
//! is *dynamic*: [`Engine::load_model`] registers a new model at runtime
//! (its arena and allocator are created **on the AM worker thread**, at a
//! tick boundary, so no tick ever observes a half-built model) and
//! [`Engine::unload_model`] drains one out (newcomers are rejected with
//! [`RejectReason::ModelDraining`], survivors finish bit-exactly, and the
//! arena is torn down at a tick boundary once the last lane empties — no
//! tick ever mixes a dying model's lanes with its teardown).
//!
//! **Weighted fairness.**  Each tick has a lane-step budget
//! ([`EngineConfig::tick_budget`], default `max_batch`) divided across
//! models by deficit-weighted round-robin ([`crate::sched::weights`]):
//! per-model weights shape tick bandwidth proportionally, with work
//! conservation (an idle model's share redistributes) and bounded
//! per-model wait.  Trimming only defers whole frames, so it composes
//! with the bit-exactness contract.
//!
//! Decoding (CTC beam + LM rescore) is heavier and utterance-final, so it
//! runs on its own worker pool, ordered by a priority decode queue
//! ([`ClassQueue`]): an `Interactive` finalize jumps a `Bulk` backlog.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::batcher::{schedule_cmp, BatchPolicy, ClassQueue, Decision, LaneAllocator};
use crate::obs::{self, EventKind, Meta};
use crate::util::fault::{self, FaultPlan, FaultPoint};
use crate::coordinator::metrics::Metrics;
use crate::decoder::Decoder;
use crate::frontend::{spec, Frontend};
use crate::nn::AcousticModel;
use crate::runtime::backend::{AmBackend, LaneTag};
use crate::sched::weights::{env_model_weights, parse_share_list};
use crate::sched::{
    AdmissionConfig, AdmissionController, BudgetLedger, DrrState, HolderView, ModelParams,
    ModelRegistry, ModelStatus, Priority, QuantumPolicy, RejectReason, StreamOptions,
};

/// Engine configuration.
#[derive(Clone)]
pub struct EngineConfig {
    pub policy: BatchPolicy,
    pub decode_workers: usize,
    /// Per-stream pending-frame cap (backpressure bound).
    pub max_pending_frames: usize,
    /// Time-slice preemption policy (lane quanta).  Defaults to the
    /// [`crate::sched::AUTO_QUANTUM`] sentinel: the AM worker measures
    /// its flush-tick interval at startup and sets the quantum to
    /// ~[`QuantumPolicy::AUTO_SLO_SECS`] of wall clock.  `--quantum N` /
    /// `QUANTASR_QUANTUM_TICKS` pin a fixed tick count (0 = explicit
    /// auto).
    pub quantum: QuantumPolicy,
    /// Live-stream admission bound.
    pub admission: AdmissionConfig,
    /// Per-tick lane-step budget shared by all models and divided by the
    /// deficit-weighted round-robin (`0` ⇒ `policy.max_batch`).
    /// Overridable via `QUANTASR_TICK_BUDGET` / `--tick-budget`.
    pub tick_budget: usize,
    /// Positional per-model DRR weights for the boot registry (missing
    /// entries default to 1).  `QUANTASR_MODEL_WEIGHTS` /
    /// `--model-weights 4,1`.  Hot loads carry their own weight in
    /// [`ModelParams`].
    pub model_weights: Vec<u32>,
    /// Positional per-model arena lane counts for the boot registry
    /// (missing entries default to `policy.max_batch`).
    /// `--model-lanes 32,8`.
    pub model_lanes: Vec<usize>,
    /// Reap a stream whose client has gone quiet: no frames arrived (and
    /// none are pending) for this long ⇒ cancelled with a `C` reason at
    /// the next tick boundary, freeing its admission slot and lane.
    /// `None` = no idle reaping.  `--stream-idle-ms` /
    /// `QUANTASR_STREAM_IDLE_MS` (0 = disabled).
    pub stream_idle: Option<Duration>,
    /// Hard cap on one utterance's wall-clock lifetime, open → finish.
    /// Streams past it are cancelled at the next tick boundary (streams
    /// already finalizing are left to finish normally).  `None` = no
    /// deadline.  `--stream-deadline-ms` / `QUANTASR_STREAM_DEADLINE_MS`
    /// (0 = disabled).
    pub stream_deadline: Option<Duration>,
    /// Deterministic fault-injection plan (chaos testing).  Defaults to
    /// the process-wide `QUANTASR_FAULTS` plan; tests install their own
    /// per-engine plan for isolation.  `None` ⇒ every injection point is
    /// a single branch.
    pub faults: Option<Arc<FaultPlan>>,
    /// Byte budget for resident model state: arenas plus one parked-blob
    /// reservation per live stream (the [`crate::sched::BudgetLedger`]
    /// accounting).  Model loads that don't fit are rejected, and stream
    /// admission backpressures with [`RejectReason::MemoryPressure`].
    /// `None` = unlimited (tracked for observability only).
    /// `--mem-budget-bytes` / `QUANTASR_MEM_BUDGET` (0 = unlimited).
    pub mem_budget: Option<usize>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: BatchPolicy::default(),
            decode_workers: 2,
            max_pending_frames: 256,
            quantum: QuantumPolicy::default(),
            admission: AdmissionConfig::default(),
            tick_budget: env_tick_budget().unwrap_or(0),
            model_weights: env_model_weights().unwrap_or_default(),
            model_lanes: Vec::new(),
            stream_idle: env_stream_ms("QUANTASR_STREAM_IDLE_MS", &ENV_IDLE),
            stream_deadline: env_stream_ms("QUANTASR_STREAM_DEADLINE_MS", &ENV_DEADLINE),
            faults: fault::env_fault_plan(),
            mem_budget: env_mem_budget(),
        }
    }
}

/// `QUANTASR_MEM_BUDGET` override (bytes), parsed once per process.
/// `0` = unlimited; a malformed value warns and disables the budget —
/// capacity knobs must never panic a serving process.
fn env_mem_budget() -> Option<usize> {
    static ONCE: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    *ONCE.get_or_init(|| {
        let v = std::env::var("QUANTASR_MEM_BUDGET").ok()?;
        match v.trim().parse::<usize>() {
            Ok(0) => None,
            Ok(n) => Some(n),
            Err(_) => {
                eprintln!("QUANTASR_MEM_BUDGET='{v}' is not a byte count; budget disabled");
                None
            }
        }
    })
}

static ENV_IDLE: std::sync::OnceLock<Option<Duration>> = std::sync::OnceLock::new();
static ENV_DEADLINE: std::sync::OnceLock<Option<Duration>> = std::sync::OnceLock::new();

/// Shared parser for the stream-lifetime env knobs, once per process:
/// the value goes through the validated [`parse_deadline_ms`] grammar
/// (finite, non-negative milliseconds — `Duration::from_secs_f64` would
/// panic on `inf`), `0` disables the limit, and a malformed value warns
/// and disables — lifetime knobs must never panic a serving process.
///
/// [`parse_deadline_ms`]: crate::coordinator::batcher::parse_deadline_ms
fn env_stream_ms(
    var: &'static str,
    once: &'static std::sync::OnceLock<Option<Duration>>,
) -> Option<Duration> {
    *once.get_or_init(|| {
        let v = std::env::var(var).ok()?;
        match crate::coordinator::batcher::parse_deadline_ms(&v) {
            Some(d) if !d.is_zero() => Some(d),
            Some(_) => None, // explicit 0 = disabled
            None => {
                eprintln!("{var}='{v}' is not a non-negative number of milliseconds; disabled");
                None
            }
        }
    })
}

/// `QUANTASR_TICK_BUDGET` override, parsed once per process.  A malformed
/// value warns and falls back — tuning knobs must never panic a serving
/// process.
fn env_tick_budget() -> Option<usize> {
    static ONCE: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    *ONCE.get_or_init(|| {
        let v = std::env::var("QUANTASR_TICK_BUDGET").ok()?;
        match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => {
                eprintln!(
                    "QUANTASR_TICK_BUDGET='{v}' is not a positive integer; \
                     using the batch size"
                );
                None
            }
        }
    })
}

impl EngineConfig {
    /// Apply the shared serving CLI flags (`--max-batch`, `--deadline-ms`,
    /// `--quantum`, `--max-streams`, `--tick-budget`, `--model-weights`,
    /// `--model-lanes`, `--stream-idle-ms`, `--stream-deadline-ms`),
    /// warn-don't-panic: the deadline and stream-lifetime flags go
    /// through the validated [`parse_deadline_ms`] grammar (finite,
    /// non-negative — `Duration::from_secs_f64` would panic on `inf`),
    /// the quantum parses directly as `u32`, and the share lists go
    /// through the validated [`parse_share_list`] grammar.  Absent flags
    /// fall through to the env-overridable defaults
    /// (`QUANTASR_BATCH_DEADLINE_MS`, `QUANTASR_QUANTUM_TICKS`,
    /// `QUANTASR_TICK_BUDGET`, `QUANTASR_MODEL_WEIGHTS`,
    /// `QUANTASR_STREAM_IDLE_MS`, `QUANTASR_STREAM_DEADLINE_MS`).
    ///
    /// [`parse_deadline_ms`]: crate::coordinator::batcher::parse_deadline_ms
    pub fn apply_cli_flags(&mut self, args: &crate::util::cli::Args) {
        self.policy.max_batch = args.get_usize_warn("max-batch", self.policy.max_batch);
        if let Some(v) = args.get("deadline-ms") {
            match crate::coordinator::batcher::parse_deadline_ms(v) {
                Some(d) => self.policy.deadline = d,
                None => eprintln!(
                    "--deadline-ms '{v}' is not a non-negative number of milliseconds; \
                     keeping {:.1} ms",
                    self.policy.deadline.as_secs_f64() * 1e3
                ),
            }
        }
        if let Some(v) = args.get("quantum") {
            match v.parse::<u32>() {
                Ok(q) => self.quantum.quantum_ticks = q,
                Err(_) => eprintln!(
                    "--quantum '{v}' is not a tick count (u32); keeping {}",
                    self.quantum.quantum_ticks
                ),
            }
        }
        self.admission.max_live_streams =
            args.get_usize_warn("max-streams", self.admission.max_live_streams);
        self.tick_budget = args.get_usize_warn("tick-budget", self.tick_budget);
        if let Some(v) = args.get("model-weights") {
            match parse_share_list(v) {
                Some(w) => self.model_weights = w,
                None => eprintln!(
                    "--model-weights '{v}' is not a comma-separated list of positive \
                     integers; keeping the defaults"
                ),
            }
        }
        if let Some(v) = args.get("model-lanes") {
            match parse_share_list(v) {
                Some(l) => self.model_lanes = l.into_iter().map(|x| x as usize).collect(),
                None => eprintln!(
                    "--model-lanes '{v}' is not a comma-separated list of positive \
                     integers; keeping the defaults"
                ),
            }
        }
        let cur_budget = self.mem_budget.unwrap_or(0);
        let budget = args.get_usize_warn("mem-budget-bytes", cur_budget);
        self.mem_budget = (budget > 0).then_some(budget);
        for (flag, field) in [
            ("stream-idle-ms", &mut self.stream_idle),
            ("stream-deadline-ms", &mut self.stream_deadline),
        ] {
            if let Some(v) = args.get(flag) {
                match crate::coordinator::batcher::parse_deadline_ms(v) {
                    Some(d) if !d.is_zero() => *field = Some(d),
                    Some(_) => *field = None, // explicit 0 = disabled
                    None => eprintln!(
                        "--{flag} '{v}' is not a non-negative number of milliseconds; \
                         keeping the current setting"
                    ),
                }
            }
        }
    }
}

/// How a stream's lifetime ended.  Anything but [`StreamEnd::Complete`]
/// means `words`/`phones` are empty; the server maps the three arms to
/// the wire's `F` / `C` / `E` result frames (see `docs/PROTOCOL.md`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamEnd {
    /// Finalized normally: the result carries the decode.
    Complete,
    /// Cancelled by the engine (reaper, forced unload, quarantine sweep)
    /// with a human-readable reason.  The stream's slot and lane were
    /// released; survivors are unaffected.
    Cancelled(String),
    /// The utterance's own processing failed (e.g. a decode panic was
    /// quarantined).  The engine and every other stream keep serving.
    Failed(String),
}

/// Final recognition result for one stream.
#[derive(Clone, Debug)]
pub struct FinalResult {
    pub stream_id: u64,
    pub words: Vec<u32>,
    /// Greedy phone sequence (diagnostic / LER).
    pub phones: Vec<u32>,
    pub num_frames: usize,
    /// finish() called → result ready.
    pub finalize_latency: Duration,
    /// Completed, cancelled, or failed (see [`StreamEnd`]).
    pub end: StreamEnd,
    /// The stream's trace id ([`crate::obs::next_trace_id`]), stamped on
    /// its flight-recorder events and echoed in the terminal wire frames
    /// so client logs join server traces.
    pub trace: u64,
}

/// One row of the live registry snapshot ([`Engine::registry`], also
/// serialized over the TCP `'Q'` admin frame — see `docs/PROTOCOL.md`).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Slot index = model id (stable for the model's whole residency).
    pub id: usize,
    pub name: String,
    /// Requantization scheme / numerics the backend executes under
    /// ([`crate::runtime::AmBackend::scheme_name`]): `"per-matrix-u8"`,
    /// `"per-channel-u8"`, `"per-channel-i4"`, or `"float"`.
    pub scheme: String,
    /// DRR tick-bandwidth weight.
    pub weight: u32,
    /// Arena lanes allocated to this model.
    pub lanes: usize,
    /// Live (admitted, not yet drained) streams on this model.
    pub live_streams: usize,
    /// Unload in progress: survivors finishing, newcomers rejected.
    pub draining: bool,
    /// Poisoned by a backend panic: quarantined until unloaded.
    pub quarantined: bool,
    /// Bytes held by this model's arena (budget-ledger accounting).
    pub arena_bytes: usize,
    /// Parked-blob bytes reserved by this model's live streams.
    pub reserved_bytes: usize,
    /// Bytes actually sitting in parked blobs right now (⊆ reserved).
    pub parked_bytes: usize,
}

/// Engine-wide overload-control snapshot ([`Engine::overload_info`],
/// also serialized in the TCP `'Q'` frame header — see
/// `docs/PROTOCOL.md`).
#[derive(Clone, Copy, Debug)]
pub struct OverloadInfo {
    /// 0 = normal, 1 = brownout shedding Bulk streams, 2 = brownout
    /// rejecting all new admissions.
    pub brownout_stage: u8,
    /// Resident bytes (arenas + per-stream parked reservations).
    pub resident_bytes: usize,
    /// Configured byte budget (0 = unlimited).
    pub budget_bytes: usize,
}

struct StreamSlot<B: AmBackend> {
    frontend: Frontend,
    /// Which loaded model serves this stream (index into the model table).
    model: usize,
    /// QoS class: preemption victim selection + batch-formation order.
    priority: Priority,
    /// Ticks stepped since the stream last (re)acquired a lane.
    quantum_used: u32,
    opened_at: Instant,
    /// Last client activity (frames or finish signal) — the idle-reaper
    /// clock.
    last_activity: Instant,
    /// Feature frames awaiting the AM, flattened input_dim each.
    pending: VecDeque<Vec<f32>>,
    oldest_enqueue: Option<Instant>,
    /// Accumulated log-posteriors [frames_done, num_labels].
    posteriors: Vec<f32>,
    frames_done: usize,
    /// Arena lane (in the stream's model's arena) holding this stream's
    /// recurrent state, if admitted.
    lane: Option<usize>,
    /// State parked outside the arena (evicted / preempted / not yet
    /// admitted).  `None` with `lane: None` ⇒ fresh zero state.
    parked: Option<B::Parked>,
    /// Parked-blob size reserved for this stream in the budget ledger at
    /// admission ([`AmBackend::parked_bytes`]); released when the stream
    /// leaves the map.
    state_bytes: usize,
    finished: bool,
    finish_time: Option<Instant>,
    result_tx: Sender<FinalResult>,
    /// Flight-recorder trace id (see [`FinalResult::trace`]).
    trace: u64,
}

struct DecodeJob {
    stream_id: u64,
    model: usize,
    posteriors: Vec<f32>,
    num_frames: usize,
    finish_time: Instant,
    result_tx: Sender<FinalResult>,
    trace: u64,
}

/// One loaded model's shared bookkeeping (index in `Inner::models` =
/// model id).  The worker-side execution state (arena, I/O buffers) lives
/// on the AM worker thread in a parallel `LaneIo` table.
struct ModelSlot<B: AmBackend> {
    backend: Arc<B>,
    name: String,
    /// DRR tick-bandwidth weight.
    weight: u32,
    /// Lane occupancy for this model's arena.
    lanes: LaneAllocator,
    /// Unload requested: no new admissions; slot torn down when the last
    /// live stream drains.
    draining: bool,
    /// Poisoned by a backend panic: no admissions, no steps; unload tears
    /// it down as usual (its streams were cancelled when it tripped).
    quarantined: bool,
    /// A bounded-deadline unload expired: the reaper cancels every
    /// surviving stream at the next tick boundary (one-shot; cleared
    /// after the sweep).
    force_cancel: bool,
    /// Fired (one per concurrent `unload_model` caller) at teardown.
    unload_acks: Vec<Sender<()>>,
}

impl<B: AmBackend> ModelSlot<B> {
    /// A freshly-registered (boot or hot-loaded) serving slot — one
    /// constructor so both registration paths share defaults.
    fn new(backend: Arc<B>, name: String, weight: u32, lanes: usize) -> Self {
        ModelSlot {
            backend,
            name,
            weight,
            lanes: LaneAllocator::new(lanes),
            draining: false,
            quarantined: false,
            force_cancel: false,
            unload_acks: Vec::new(),
        }
    }
}

/// Admin commands processed by the AM worker at tick boundaries, so model
/// arrival/departure is serialized with lane planning.
enum AdminCmd<B: AmBackend> {
    Load {
        name: String,
        backend: Arc<B>,
        params: ModelParams,
        ack: Sender<Result<usize, String>>,
    },
}

struct Inner<B: AmBackend> {
    /// Index-stable model table; `None` = free slot (reused by later
    /// loads, never while a model still occupies it).
    models: Vec<Option<ModelSlot<B>>>,
    streams: HashMap<u64, StreamSlot<B>>,
    next_id: u64,
    /// Finished utterances awaiting decode, highest QoS class first.
    decode_queue: ClassQueue<DecodeJob>,
    /// Pending hot loads (worker-owned arenas must be built on the
    /// worker thread).
    admin: VecDeque<AdminCmd<B>>,
    /// Byte ledger for arenas + per-stream parked reservations, checked
    /// at the admission and load edges (never mid-schedule — parking is
    /// pre-reserved, so the scheduler can always park without asking).
    budget: BudgetLedger,
    /// Published brownout stage (0 normal / 1 shedding / 2 rejecting) —
    /// written by the AM worker's overload controller, read by admission
    /// and the `'Q'` snapshot.
    brownout_stage: u8,
    /// Swap redirect table: streams opened against a replaced model id
    /// land on its replacement ([`Engine::swap_model`]).  An entry
    /// outlives the old slot's teardown (clients keep using the old id)
    /// and is cleared only when the old slot id is reused by a fresh
    /// load.
    redirects: HashMap<usize, usize>,
}

/// Follow swap redirects from a client-supplied model id to the slot
/// currently serving it (hop-bounded: a redirect cycle — swap a→b then
/// b→a — must not hang admission).
fn resolve_model<B: AmBackend>(inner: &Inner<B>, mut model: usize) -> usize {
    for _ in 0..8 {
        match inner.redirects.get(&model) {
            Some(&next) => model = next,
            None => break,
        }
    }
    model
}

struct Shared<B: AmBackend> {
    inner: Mutex<Inner<B>>,
    /// Wakes the AM worker (new frames / finished streams / admin).
    work_cv: Condvar,
    /// Wakes decode workers.
    decode_cv: Condvar,
    /// Wakes producers blocked on backpressure.
    space_cv: Condvar,
    metrics: Metrics,
    admission: AdmissionController,
    config: EngineConfig,
    shutdown: AtomicBool,
    /// Flight-recorder engine id (`Event.engine` / Chrome `pid`): scopes
    /// this engine's events apart from other engines in the process.
    obs: u16,
}

/// Clamp a model slot index into the trace event's `u16` model field
/// (hostile client model ids can exceed it; the trace is diagnostic).
fn obs_model(m: usize) -> u16 {
    m.min(u16::MAX as usize) as u16
}

/// Clamp a lane index into the trace event's `u16` lane field (lane
/// counts are bounded by `max_batch`, far below `u16::MAX` in practice).
fn obs_lane(l: usize) -> u16 {
    l.min(u16::MAX as usize) as u16
}

/// The streaming serving engine, generic over the execution backend
/// (defaults to the native [`AcousticModel`]).
pub struct Engine<B: AmBackend = AcousticModel> {
    shared: Arc<Shared<B>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Frames a swap canary pushes through the replacement before it may
/// take traffic (short: the gate is "serves at all", not WER).
const CANARY_FRAMES: usize = 8;
/// How long a swap canary waits for its end-to-end decode before the
/// swap is rolled back.
const CANARY_TIMEOUT: Duration = Duration::from_secs(10);

/// Effective lane count for a model: the explicit request (or the
/// engine-wide `max_batch`), clamped to the backend's capacity where one
/// exists (e.g. an AOT graph lowered at a fixed batch), floored at 1.
fn effective_lanes<B: AmBackend>(backend: &B, requested: Option<usize>, max_batch: usize) -> usize {
    let mut lanes = requested.filter(|&l| l > 0).unwrap_or(max_batch).max(1);
    if let Some(cap) = backend.lane_capacity() {
        if lanes > cap {
            eprintln!(
                "engine: backend '{}' supports {cap} lanes; clamping {lanes} -> {cap}",
                backend.backend_name()
            );
            lanes = cap.max(1);
        }
    }
    lanes
}

impl<B: AmBackend> Engine<B> {
    /// Start a single-model engine (the pre-registry surface; equivalent
    /// to `start_registry(ModelRegistry::single(backend), …)`).
    pub fn start(backend: Arc<B>, decoder: Arc<Decoder>, config: EngineConfig) -> Self {
        Self::start_registry(ModelRegistry::single(backend), decoder, config)
    }

    /// Start an engine serving every model in `registry` through one
    /// scheduler, AM worker and decode pool.  Per-model weights and lane
    /// counts come positionally from
    /// [`EngineConfig::model_weights`]/[`EngineConfig::model_lanes`];
    /// models hot-loaded later carry their own [`ModelParams`].
    pub fn start_registry(
        registry: ModelRegistry<B>,
        decoder: Arc<Decoder>,
        mut config: EngineConfig,
    ) -> Self {
        let (names, backends) = registry.into_parts();
        assert!(!backends.is_empty(), "ModelRegistry has no models");
        let max_batch = config.policy.max_batch.max(1);
        if config.tick_budget == 0 {
            config.tick_budget = max_batch;
        }
        let mut slots: Vec<Option<ModelSlot<B>>> = Vec::with_capacity(backends.len());
        for (m, (name, backend)) in names.into_iter().zip(backends).enumerate() {
            let weight = config.model_weights.get(m).copied().unwrap_or(1).max(1);
            let lanes = effective_lanes(
                backend.as_ref(),
                config.model_lanes.get(m).copied(),
                max_batch,
            );
            slots.push(Some(ModelSlot::new(backend, name, weight, lanes)));
        }
        let admission = AdmissionController::new(config.admission);
        // Charge boot arenas against the ledger.  Boot models are the
        // operator's explicit choice, so an over-budget boot set warns
        // loudly instead of refusing to start — the budget gates
        // *runtime* growth (hot loads, stream admission).
        let mut budget = BudgetLedger::new(config.mem_budget);
        for (m, slot) in slots.iter().enumerate() {
            let slot = slot.as_ref().unwrap();
            let need = slot.backend.arena_bytes(slot.lanes.capacity());
            if !budget.fits(need) {
                eprintln!(
                    "engine: boot model {m} ('{}') pushes resident bytes past \
                     --mem-budget-bytes ({} + {need} > {}); serving anyway",
                    slot.name,
                    budget.resident(),
                    budget.budget().unwrap_or(0),
                );
            }
            budget.charge_arena(m, need);
        }
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                models: slots,
                streams: HashMap::new(),
                next_id: 0,
                decode_queue: ClassQueue::new(),
                admin: VecDeque::new(),
                budget,
                brownout_stage: 0,
                redirects: HashMap::new(),
            }),
            work_cv: Condvar::new(),
            decode_cv: Condvar::new(),
            space_cv: Condvar::new(),
            metrics: Metrics::default(),
            admission,
            config,
            shutdown: AtomicBool::new(false),
            obs: obs::next_engine_id(),
        });
        {
            let inner = shared.inner.lock().unwrap();
            shared.metrics.set_budget_bytes(inner.budget.budget().unwrap_or(0));
            for (m, slot) in inner.models.iter().enumerate() {
                let slot = slot.as_ref().unwrap();
                shared.metrics.set_model(m, &slot.name, slot.lanes.capacity(), slot.weight);
                publish_bytes(&shared, &inner, m);
            }
        }
        let mut workers = Vec::new();
        {
            let s = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("am-worker".into())
                    .spawn(move || am_worker(s))
                    .expect("spawn am worker"),
            );
        }
        for i in 0..shared.config.decode_workers {
            let s = shared.clone();
            let d = decoder.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("decode-{i}"))
                    .spawn(move || decode_worker(s, d))
                    .expect("spawn decode worker"),
            );
        }
        Engine { shared, workers }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// This engine's flight-recorder id ([`crate::obs::Event::engine`],
    /// the Chrome `pid`): filter [`crate::obs::snapshot`] by it to scope
    /// a trace to this engine when several share a process.
    pub fn obs_id(&self) -> u16 {
        self.shared.obs
    }

    /// This engine's events as a Chrome-trace / Perfetto JSON array —
    /// what the `'X'` admin frame serves and `--trace-out` writes.
    pub fn trace_json(&self) -> String {
        obs::chrome_trace_json(&obs::snapshot_engine(self.shared.obs))
    }

    /// Snapshot of the live model table (loaded + draining slots).  One
    /// pass over the stream map (it is reachable by any client via the
    /// TCP `'Q'` frame, and it holds the engine lock — keep it cheap).
    pub fn registry(&self) -> Vec<ModelInfo> {
        let inner = self.shared.inner.lock().unwrap();
        let mut live = vec![0usize; inner.models.len()];
        for slot in inner.streams.values() {
            if let Some(n) = live.get_mut(slot.model) {
                *n += 1;
            }
        }
        inner
            .models
            .iter()
            .enumerate()
            .filter_map(|(id, m)| {
                m.as_ref().map(|slot| {
                    let row = inner.budget.model(id);
                    ModelInfo {
                        id,
                        name: slot.name.clone(),
                        scheme: slot.backend.scheme_name().to_string(),
                        weight: slot.weight,
                        lanes: slot.lanes.capacity(),
                        live_streams: live[id],
                        draining: slot.draining,
                        quarantined: slot.quarantined,
                        arena_bytes: row.arena,
                        reserved_bytes: row.reserved,
                        parked_bytes: row.parked,
                    }
                })
            })
            .collect()
    }

    /// Engine-wide overload snapshot: brownout stage plus the budget
    /// ledger's resident total (serialized in the `'Q'` frame header).
    pub fn overload_info(&self) -> OverloadInfo {
        let inner = self.shared.inner.lock().unwrap();
        OverloadInfo {
            brownout_stage: inner.brownout_stage,
            resident_bytes: inner.budget.resident(),
            budget_bytes: inner.budget.budget().unwrap_or(0),
        }
    }

    /// Hot-load a model under its self-reported name
    /// ([`AmBackend::model_name`]); returns its model id once the AM
    /// worker has built the arena (blocks for at most ~one tick).
    pub fn load_model(&self, backend: Arc<B>, params: ModelParams) -> Result<usize, String> {
        let name = backend.model_name();
        self.load_model_named(name, backend, params)
    }

    /// Hot-load a model under an explicit name.  The arena and lane
    /// allocator are created **on the AM worker thread** at a tick
    /// boundary — no tick ever observes a half-registered model.  The
    /// returned id is a slot index: stable while the model stays loaded,
    /// reusable after an unload completes.
    pub fn load_model_named(
        &self,
        name: impl Into<String>,
        backend: Arc<B>,
        params: ModelParams,
    ) -> Result<usize, String> {
        let (ack, rx) = channel();
        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.admin.push_back(AdminCmd::Load { name: name.into(), backend, params, ack });
        }
        self.shared.work_cv.notify_all();
        match rx.recv() {
            Ok(result) => result,
            Err(_) => Err("engine shut down before the load was processed".into()),
        }
    }

    /// Hot-unload a model: new streams targeting it are rejected with
    /// [`RejectReason::ModelDraining`], live streams finish normally
    /// (their outputs stay bit-identical — drain changes *when* nothing
    /// computes, never *what*), and once the last one drains the AM
    /// worker tears the arena down at a tick boundary.  Blocks until the
    /// teardown; a model with an endless stream drains only when that
    /// stream finishes.
    pub fn unload_model(&self, model: usize) -> Result<(), String> {
        let rx = {
            let mut inner = self.shared.inner.lock().unwrap();
            match inner.models.get_mut(model) {
                Some(Some(slot)) => {
                    let (ack, rx) = channel();
                    slot.draining = true;
                    slot.unload_acks.push(ack);
                    rx
                }
                _ => return Err(format!("model {model} is not loaded")),
            }
        };
        self.shared.work_cv.notify_all();
        rx.recv()
            .map_err(|_| "engine shut down before the drain completed".to_string())
    }

    /// [`Engine::unload_model`] with a bounded wait: if the drain has not
    /// completed within `deadline`, either give up with an error
    /// (`force = false` — the model keeps draining in the background) or
    /// cancel every surviving stream through the reaper's parking path
    /// (`force = true` — each survivor's client gets a `C` cancel with a
    /// reason, the per-model `forced_cancels` metric counts them) and
    /// block only for the now-unpinned teardown.  This is what keeps a
    /// stalled client from pinning an operator's unload forever.
    pub fn unload_model_deadline(
        &self,
        model: usize,
        deadline: Duration,
        force: bool,
    ) -> Result<(), String> {
        let rx = {
            let mut inner = self.shared.inner.lock().unwrap();
            match inner.models.get_mut(model) {
                Some(Some(slot)) => {
                    let (ack, rx) = channel();
                    slot.draining = true;
                    slot.unload_acks.push(ack);
                    rx
                }
                _ => return Err(format!("model {model} is not loaded")),
            }
        };
        self.shared.work_cv.notify_all();
        match rx.recv_timeout(deadline) {
            Ok(()) => Ok(()),
            Err(RecvTimeoutError::Disconnected) => {
                Err("engine shut down before the drain completed".into())
            }
            Err(RecvTimeoutError::Timeout) if !force => {
                let inner = self.shared.inner.lock().unwrap();
                let live = inner.streams.values().filter(|sl| sl.model == model).count();
                Err(format!(
                    "model {model} still has {live} live stream(s) after \
                     {} ms; still draining (retry with force to cancel them)",
                    deadline.as_millis()
                ))
            }
            Err(RecvTimeoutError::Timeout) => {
                {
                    let mut inner = self.shared.inner.lock().unwrap();
                    if let Some(Some(slot)) = inner.models.get_mut(model) {
                        slot.force_cancel = true;
                    }
                }
                self.shared.work_cv.notify_all();
                rx.recv()
                    .map_err(|_| "engine shut down before the forced drain completed".to_string())
            }
        }
    }

    /// The engine's fault-injection plan (for the serving layer's own
    /// injection points, e.g. the TCP server's corrupt-frame fault).
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.shared.config.faults.clone()
    }

    /// Zero-downtime model swap: load `backend` as the replacement for
    /// model `old`, health-check it with a canary utterance, and only
    /// then redirect traffic.
    ///
    /// 1. The replacement is hot-loaded through the normal (budget-
    ///    checked) path — a swap transiently needs both arenas resident.
    /// 2. A canary runs **before the redirect**: a scratch-arena step
    ///    pass asserts finite posteriors, then one real utterance goes
    ///    through the full serving path on the new slot and must decode
    ///    to completion.  The `canary_fail` fault point (keyed by the
    ///    replacement's slot id) injects failures deterministically.
    /// 3. On canary failure the swap **rolls back**: the new slot is
    ///    unloaded, `old` keeps serving untouched, and the error is
    ///    returned (counted in `swap_rollbacks`).
    /// 4. On success the redirect table sends newcomers targeting `old`
    ///    to the new slot atomically, and `old` starts a normal bounded
    ///    drain: survivors finish bit-exactly on the old weights, and
    ///    the old arena is torn down once the last one drains.
    ///
    /// Returns the replacement's model id.  The redirect entry outlives
    /// the old slot (clients keep dialing the old id) and is recycled
    /// only when the old slot id is reused by a fresh load.
    pub fn swap_model(
        &self,
        old: usize,
        backend: Arc<B>,
        params: ModelParams,
    ) -> Result<usize, String> {
        {
            let inner = self.shared.inner.lock().unwrap();
            if !matches!(inner.models.get(old), Some(Some(_))) {
                return Err(format!("model {old} is not loaded"));
            }
        }
        let name = backend.model_name();
        let new_id = self.load_model_named(name, backend, params)?;
        if let Err(why) = self.run_canary(new_id) {
            // Roll back: the canary stream (if any) has drained, so the
            // new slot unpins immediately; `old` was never touched.
            let _ = self.unload_model(new_id);
            self.shared.metrics.add_swap(true);
            return Err(format!("swap of model {old} rolled back: {why}"));
        }
        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.redirects.insert(old, new_id);
            if let Some(Some(slot)) = inner.models.get_mut(old) {
                slot.draining = true;
            }
        }
        self.shared.work_cv.notify_all();
        self.shared.metrics.add_swap(false);
        Ok(new_id)
    }

    /// The swap health check: prove the freshly-loaded slot `new_id` can
    /// serve before any traffic is redirected to it.  Two gates — a
    /// scratch-arena step pass that must produce finite posteriors (a
    /// model with corrupted weights fails here without involving the
    /// serving plane), then one end-to-end utterance through the real
    /// admission → AM worker → decode pipeline that must complete.
    fn run_canary(&self, new_id: usize) -> Result<(), String> {
        if fault::fire(&self.shared.config.faults, FaultPoint::CanaryFail, new_id as u64) {
            return Err("injected canary failure".into());
        }
        let backend = {
            let inner = self.shared.inner.lock().unwrap();
            match inner.models.get(new_id) {
                Some(Some(slot)) => slot.backend.clone(),
                _ => return Err(format!("replacement slot {new_id} vanished before canary")),
            }
        };
        let dim = backend.input_dim();
        let labels = backend.num_labels();
        let frames: Vec<f32> = (0..CANARY_FRAMES * dim)
            .map(|i| (i as f32 * 0.37).sin() * 0.1)
            .collect();
        // Gate 1: finite posteriors on a throwaway single-lane arena
        // (transient scratch, freed before any ledger-visible state).
        // A panicking replacement must roll back, not kill the caller.
        let finite = catch_unwind(AssertUnwindSafe(|| -> Result<(), String> {
            let mut arena = backend.alloc_arena(1);
            let mut ybuf = vec![0f32; labels];
            for t in 0..CANARY_FRAMES {
                backend
                    .step_lanes(&mut arena, &[0], &frames[t * dim..(t + 1) * dim], &mut ybuf)
                    .map_err(|e| format!("canary step failed at frame {t}: {e:#}"))?;
                if ybuf.iter().any(|v| !v.is_finite()) {
                    return Err(format!("canary produced non-finite posteriors at frame {t}"));
                }
            }
            Ok(())
        }));
        match finite {
            Ok(Ok(())) => {}
            Ok(Err(why)) => return Err(why),
            Err(_) => return Err("canary step panicked".into()),
        }
        // Gate 2: one utterance through the full serving path.
        let (id, rx) = self
            .try_open_stream(StreamOptions { model: new_id, priority: Priority::Interactive })
            .map_err(|r| format!("canary admission failed: {r}"))?;
        self.push_frames(id, &frames).map_err(|e| format!("canary push failed: {e:#}"))?;
        self.finish_stream(id).map_err(|e| format!("canary finish failed: {e:#}"))?;
        match rx.recv_timeout(CANARY_TIMEOUT) {
            Ok(r) if r.end == StreamEnd::Complete => Ok(()),
            Ok(r) => Err(format!("canary stream ended abnormally: {:?}", r.end)),
            Err(_) => Err(format!(
                "canary decode did not complete within {} ms",
                CANARY_TIMEOUT.as_millis()
            )),
        }
    }

    /// Open a new default stream (model 0, `Priority::Interactive`);
    /// returns its id and the final-result receiver.  The stream is
    /// admitted to an arena lane lazily, when it is first scheduled into
    /// a batch.  Panics if admission control rejects — callers that can
    /// handle backpressure should use [`Engine::try_open_stream`].
    pub fn open_stream(&self) -> (u64, Receiver<FinalResult>) {
        self.try_open_stream(StreamOptions::default())
            .expect("stream admission rejected")
    }

    /// Open a stream with explicit model/priority, subject to admission
    /// control: beyond the live-stream cap — or for a model that is
    /// unknown or draining — the stream is rejected with a reason instead
    /// of queued unboundedly.
    pub fn try_open_stream(
        &self,
        opts: StreamOptions,
    ) -> Result<(u64, Receiver<FinalResult>), RejectReason> {
        self.try_open_stream_traced(opts, obs::next_trace_id())
    }

    /// [`Engine::try_open_stream`] with a caller-supplied trace id (the
    /// TCP server mints one per open attempt and echoes it back in the
    /// stream's terminal frames — see `docs/PROTOCOL.md`).  The id lands
    /// on the admit/reject flight-recorder event and in
    /// [`FinalResult::trace`], so server traces join client logs.
    pub fn try_open_stream_traced(
        &self,
        opts: StreamOptions,
        trace: u64,
    ) -> Result<(u64, Receiver<FinalResult>), RejectReason> {
        let (tx, rx) = channel();
        let mut inner = self.shared.inner.lock().unwrap();
        // Swap indirection: a stream dialing a replaced model id lands on
        // its replacement.
        let model = resolve_model(&inner, opts.model);
        let status = match inner.models.get(model) {
            Some(Some(slot)) if slot.quarantined => ModelStatus::Quarantined,
            Some(Some(slot)) if slot.draining => ModelStatus::Draining,
            Some(Some(_)) => ModelStatus::Loaded,
            _ => ModelStatus::Unknown,
        };
        let loaded = inner.models.iter().filter(|m| m.is_some()).count();
        if let Err(reason) =
            self.shared.admission.admit(inner.streams.len(), model, status, loaded)
        {
            self.shared.metrics.add_admission_reject();
            self.obs_reject(model, trace, &reason);
            return Err(reason);
        }
        // Brownout gate: in the rejecting stage every newcomer is turned
        // away with a retryable reason (model identity errors above still
        // outrank it — they are caller bugs, not load).
        if inner.brownout_stage >= 2 {
            self.shared.metrics.add_brownout_reject();
            self.obs_reject(model, trace, &RejectReason::Brownout);
            return Err(RejectReason::Brownout);
        }
        // Byte budget: reserve one parked blob up front so every later
        // park (eviction/preemption/cancel) is pre-paid and scheduling
        // never has to ask.  The `mem_pressure` fault point (keyed by
        // model id) pretends the ledger is full.
        let state_bytes = inner.models[model]
            .as_ref()
            .expect("admitted to a missing model")
            .backend
            .parked_bytes();
        let forced =
            fault::fire(&self.shared.config.faults, FaultPoint::MemPressure, model as u64);
        if forced || !inner.budget.fits(state_bytes) {
            let resident = inner.budget.resident();
            let budget = inner.budget.budget().unwrap_or(0);
            self.shared.metrics.add_mem_pressure_reject();
            let reason = RejectReason::MemoryPressure { resident, budget };
            self.obs_reject(model, trace, &reason);
            return Err(reason);
        }
        inner.budget.charge_stream(model, state_bytes);
        publish_bytes(&self.shared, &inner, model);
        let id = inner.next_id;
        inner.next_id += 1;
        obs::instant(
            EventKind::Admit,
            Meta {
                engine: self.shared.obs,
                model: obs_model(model),
                stream: id,
                arg: trace,
                ..Meta::default()
            },
        );
        inner.streams.insert(
            id,
            StreamSlot {
                frontend: Frontend::new(),
                model,
                priority: opts.priority,
                quantum_used: 0,
                opened_at: Instant::now(),
                last_activity: Instant::now(),
                pending: VecDeque::new(),
                oldest_enqueue: None,
                posteriors: Vec::new(),
                frames_done: 0,
                lane: None,
                parked: None,
                state_bytes,
                finished: false,
                finish_time: None,
                result_tx: tx,
                trace,
            },
        );
        Ok((id, rx))
    }

    /// Record one admission-reject trace event: `stream` carries the
    /// trace id (the stream never got an engine id) and `arg` the stable
    /// [`RejectReason::code`].
    fn obs_reject(&self, model: usize, trace: u64, reason: &RejectReason) {
        obs::instant(
            EventKind::Reject,
            Meta {
                engine: self.shared.obs,
                model: obs_model(model),
                stream: trace,
                arg: reason.code(),
                ..Meta::default()
            },
        );
    }

    /// Push PCM samples (blocks under backpressure).
    pub fn push_audio(&self, id: u64, pcm: &[f32]) -> Result<()> {
        self.shared.metrics.add_audio(pcm.len() as f64 / spec::SAMPLE_RATE as f64);
        let mut frames = Vec::new();
        {
            let mut inner = self.shared.inner.lock().unwrap();
            let slot = match inner.streams.get_mut(&id) {
                Some(s) => s,
                None => bail!("unknown stream {id}"),
            };
            if slot.finished {
                bail!("stream {id} already finished");
            }
            let t0 = Instant::now();
            slot.last_activity = t0;
            // The frontend is a context-free layer: hand it this stream's
            // identity so its FrontendPush spans carry engine/stream/model.
            let prev = obs::set_ctx(self.shared.obs, id, obs_model(slot.model));
            slot.frontend.push(pcm, &mut frames);
            obs::restore_ctx(prev);
            self.shared.metrics.add_frontend_compute(t0.elapsed().as_secs_f64());
        }
        self.push_frames(id, &frames)
    }

    /// Push pre-computed feature frames (len = k·input_dim of the
    /// stream's model).
    pub fn push_frames(&self, id: u64, frames: &[f32]) -> Result<()> {
        let d = {
            let inner = self.shared.inner.lock().unwrap();
            match inner.streams.get(&id) {
                Some(slot) => inner.models[slot.model]
                    .as_ref()
                    .expect("live stream on a torn-down model")
                    .backend
                    .input_dim(),
                None => bail!("unknown stream {id}"),
            }
        };
        assert_eq!(frames.len() % d, 0);
        let mut offset = 0;
        while offset < frames.len() {
            let mut inner = self.shared.inner.lock().unwrap();
            // backpressure: wait for queue space
            loop {
                let slot = match inner.streams.get(&id) {
                    Some(s) => s,
                    None => bail!("unknown stream {id}"),
                };
                if slot.pending.len() < self.shared.config.max_pending_frames {
                    break;
                }
                inner = self.shared.space_cv.wait(inner).unwrap();
            }
            let cap = self.shared.config.max_pending_frames;
            let slot = inner.streams.get_mut(&id).unwrap();
            let now = Instant::now();
            while offset < frames.len() && slot.pending.len() < cap {
                slot.pending.push_back(frames[offset..offset + d].to_vec());
                offset += d;
            }
            slot.oldest_enqueue.get_or_insert(now);
            slot.last_activity = now;
            drop(inner);
            self.shared.work_cv.notify_all();
        }
        Ok(())
    }

    /// Signal end of audio; the final decode is delivered on the stream's
    /// receiver once all pending frames are processed.
    pub fn finish_stream(&self, id: u64) -> Result<()> {
        let mut inner = self.shared.inner.lock().unwrap();
        let slot = match inner.streams.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown stream {id}"),
        };
        slot.finished = true;
        slot.finish_time = Some(Instant::now());
        slot.last_activity = Instant::now();
        drop(inner);
        self.shared.work_cv.notify_all();
        Ok(())
    }

    /// Convenience: run one utterance synchronously through the engine.
    pub fn recognize(&self, pcm: &[f32]) -> Result<FinalResult> {
        let (id, rx) = self.open_stream();
        self.push_audio(id, pcm)?;
        self.finish_stream(id)?;
        let r = rx.recv()?;
        match &r.end {
            StreamEnd::Complete => Ok(r),
            StreamEnd::Cancelled(why) => bail!("stream {id} cancelled: {why}"),
            StreamEnd::Failed(why) => bail!("stream {id} failed: {why}"),
        }
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<B: AmBackend> Drop for Engine<B> {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// AM-worker-local execution state for one loaded model: the arena the
/// lanes live in and the lane-resident I/O buffers.  Owned by the worker
/// thread (stepped outside the engine lock); created on load, dropped on
/// unload teardown.
struct LaneIo<B: AmBackend> {
    backend: Arc<B>,
    arena: B::Arena,
    /// Lane-resident input `[lanes, dim]`.
    xbuf: Vec<f32>,
    /// Lane-resident output `[lanes, labels]`.
    ybuf: Vec<f32>,
    dim: usize,
    labels: usize,
}

fn lane_io<B: AmBackend>(backend: Arc<B>, lanes: usize) -> LaneIo<B> {
    let dim = backend.input_dim();
    let labels = backend.num_labels();
    LaneIo {
        arena: backend.alloc_arena(lanes),
        xbuf: vec![0f32; lanes * dim],
        ybuf: vec![0f32; lanes * labels],
        dim,
        labels,
        backend,
    }
}

/// Process pending hot loads (worker thread, between ticks): build the
/// arena + I/O buffers **outside** the engine lock — they can be tens of
/// MB for a large model, and holding the lock through the allocation
/// would stall every stream on every already-serving model — then take
/// the lock only to install the finished slot atomically.  The worker is
/// the sole consumer of the admin queue and the sole writer of the slot
/// table, so the unlock window cannot race another load.
fn process_admin<B: AmBackend>(s: &Shared<B>, wm: &mut Vec<Option<LaneIo<B>>>) {
    loop {
        let cmd = s.inner.lock().unwrap().admin.pop_front();
        let Some(AdminCmd::Load { name, backend, params, ack }) = cmd else {
            return;
        };
        let weight = params.weight();
        let lanes = effective_lanes(backend.as_ref(), params.lanes, s.config.policy.max_batch);
        // Budget gate: price the arena analytically and reserve the slot
        // *and* the bytes atomically before the lock-free allocation, so
        // concurrent stream admissions cannot race the ledger past its
        // cap between check and charge.  The `mem_pressure` fault point
        // (keyed by the prospective slot id) pretends the ledger is full.
        let need = backend.arena_bytes(lanes);
        let reserved = {
            let mut inner = s.inner.lock().unwrap();
            let slot_id = inner
                .models
                .iter()
                .position(|m| m.is_none())
                .unwrap_or(inner.models.len());
            let forced = fault::fire(&s.config.faults, FaultPoint::MemPressure, slot_id as u64);
            if forced || !inner.budget.fits(need) {
                let resident = inner.budget.resident();
                let budget = inner.budget.budget().unwrap_or(0);
                Err(format!(
                    "memory pressure: model '{name}' needs {need} arena bytes, \
                     {resident} resident at budget {budget}; unload something first"
                ))
            } else {
                if slot_id == inner.models.len() {
                    inner.models.push(None);
                    wm.push(None);
                }
                inner.budget.charge_arena(slot_id, need);
                // A recycled slot id must not inherit a swap redirect
                // that used to send it elsewhere: the id is reborn as a
                // brand-new model.
                inner.redirects.remove(&slot_id);
                Ok(slot_id)
            }
        };
        let slot_id = match reserved {
            Ok(id) => id,
            Err(why) => {
                s.metrics.add_mem_pressure_reject();
                let _ = ack.send(Err(why));
                continue;
            }
        };
        let io = lane_io(backend.clone(), lanes); // lock-free allocation
        {
            let mut inner = s.inner.lock().unwrap();
            debug_assert!(wm[slot_id].is_none(), "slot reuse before teardown");
            wm[slot_id] = Some(io);
            inner.models[slot_id] = Some(ModelSlot::new(backend, name.clone(), weight, lanes));
        }
        s.metrics.set_model(slot_id, &name, lanes, weight);
        {
            let inner = s.inner.lock().unwrap();
            publish_bytes(s, &inner, slot_id);
        }
        let _ = ack.send(Ok(slot_id));
    }
}

/// Tear down draining models whose last live stream has drained (worker
/// thread, engine lock held, tick boundary): the arena drops here, after
/// the tick that stepped its last lane and never during one.
fn teardown_drained<B: AmBackend>(
    inner: &mut Inner<B>,
    wm: &mut [Option<LaneIo<B>>],
    s: &Shared<B>,
) {
    for m in 0..inner.models.len() {
        let dying = matches!(&inner.models[m], Some(slot) if slot.draining);
        if !dying || inner.streams.values().any(|sl| sl.model == m) {
            continue;
        }
        let slot = inner.models[m].take().unwrap();
        assert_eq!(slot.lanes.in_use(), 0, "teardown with lanes in use");
        wm[m] = None; // drops the arena and I/O buffers
        inner.budget.release_arena(m);
        debug_assert_eq!(
            inner.budget.model(m).reserved,
            0,
            "model {m} torn down with stream reservations outstanding"
        );
        publish_bytes(s, inner, m);
        s.metrics.retire_model(m);
        for ack in slot.unload_acks {
            let _ = ack.send(());
        }
    }
}

/// Flush ticks sampled before the auto quantum is fixed.
const QUANTUM_TUNE_SAMPLES: usize = 10;
/// Flush gaps longer than this are idle periods, not tick cost — they
/// are excluded from the auto-quantum measurement.
const QUANTUM_TUNE_MAX_GAP: Duration = Duration::from_millis(250);

/// EWMA smoothing factor for the brownout controller's flush-to-flush
/// overrun signal.
const BROWNOUT_ALPHA: f64 = 0.4;
/// Enter brownout when the overrun EWMA (flush gap ÷ batch deadline)
/// holds above this.
const BROWNOUT_ENTER: f64 = 3.0;
/// Leave brownout when the EWMA falls back below this (hysteresis: the
/// exit bar is lower than the entry bar, so the controller cannot
/// flap on a load level that sits exactly at one threshold).
const BROWNOUT_EXIT: f64 = 1.5;
/// Consecutive over-threshold flushes before entering brownout.
const BROWNOUT_ENTER_TICKS: u32 = 3;
/// Consecutive under-threshold flushes before recovering.
const BROWNOUT_EXIT_TICKS: u32 = 3;
/// Bulk streams shed per flush while in the shedding stage.
const BROWNOUT_SHED_PER_TICK: usize = 2;
/// Shedding flushes endured before escalating to rejecting admissions.
const BROWNOUT_ESCALATE_TICKS: u32 = 3;
/// Cancel reason delivered (verbatim over the `'C'` frame) to streams
/// shed by the brownout controller — the `shed:` prefix is the
/// wire-stable marker clients dispatch on (see `docs/PROTOCOL.md`).
const SHED_REASON: &str = "shed: brownout overload control; retry later";

/// Worker-local brownout state machine.  Stage 0 = normal; stage 1 =
/// shedding (cancel Bulk streams through the reaper's parking path,
/// Interactive survivors and newcomers untouched); stage 2 = rejecting
/// (admission turns everyone away until the overrun clears).  Stages
/// only escalate Bulk-first — Interactive work is never shed, only
/// deferred behind the admission gate.
struct BrownoutCtl {
    /// EWMA of flush-gap ÷ deadline (None until the first gap).
    ewma: Option<f64>,
    /// Wall time of the previous flush (the controller's own clock —
    /// `last_flush` belongs to auto-quantum and stops updating once its
    /// samples are collected).
    last: Option<Instant>,
    over_ticks: u32,
    under_ticks: u32,
    shed_ticks: u32,
    stage: u8,
}

impl BrownoutCtl {
    fn new() -> Self {
        BrownoutCtl { ewma: None, last: None, over_ticks: 0, under_ticks: 0, shed_ticks: 0, stage: 0 }
    }

    /// Feed one flush boundary into the controller; returns the updated
    /// stage.  `forced` (the `overload_tick` fault point) injects a
    /// deterministic overrun regardless of wall clock.
    fn observe(&mut self, now: Instant, deadline: Duration, forced: bool) -> u8 {
        let deadline_s = deadline.as_secs_f64().max(1e-6);
        let gap = self.last.map(|t| (now - t).as_secs_f64());
        self.last = Some(now);
        // Idle gaps (no flush pending for a long while) mean *no* load,
        // not slow ticks: count them as calm evidence.
        let ratio = if forced {
            BROWNOUT_ENTER * 3.0
        } else {
            match gap {
                Some(g) if g <= QUANTUM_TUNE_MAX_GAP.as_secs_f64() => g / deadline_s,
                _ => 0.0,
            }
        };
        let ewma = match self.ewma {
            None => ratio,
            Some(e) => BROWNOUT_ALPHA * ratio + (1.0 - BROWNOUT_ALPHA) * e,
        };
        self.ewma = Some(ewma);
        if ewma >= BROWNOUT_ENTER && self.stage == 0 {
            self.over_ticks += 1;
            if self.over_ticks >= BROWNOUT_ENTER_TICKS {
                self.stage = 1;
                self.shed_ticks = 0;
                self.under_ticks = 0;
            }
        } else if self.stage == 0 {
            self.over_ticks = 0;
        } else if ewma <= BROWNOUT_EXIT {
            self.under_ticks += 1;
            if self.under_ticks >= BROWNOUT_EXIT_TICKS {
                self.stage = 0;
                self.over_ticks = 0;
                self.under_ticks = 0;
            }
        } else {
            self.under_ticks = 0;
        }
        self.stage
    }
}

fn am_worker<B: AmBackend>(s: Arc<Shared<B>>) {
    // Ambient trace context for this worker thread: backend-level spans
    // (LaneSave/LaneLoad) pick up the engine id without the backend
    // trait knowing about engines.  Never restored — the thread is the
    // engine's for life.
    obs::set_ctx(s.obs, 0, obs::NO_MODEL);
    let budget = s.config.tick_budget.max(1);
    let mut drr = DrrState::new();
    // Worker-local effective quantum policy.  A config of AUTO_QUANTUM
    // (the default) starts from a provisional 25 ticks and is replaced
    // once enough flush-to-flush intervals are measured: the quantum
    // becomes ~AUTO_SLO_SECS of wall clock, so lane rotation under
    // saturation tracks a latency SLO instead of a hardcoded tick count
    // that means wildly different wall time on different machines.
    let mut qpolicy = s.config.quantum;
    let auto_quantum = qpolicy.is_auto();
    if auto_quantum {
        qpolicy.quantum_ticks = 25;
    }
    s.metrics.set_effective_quantum(qpolicy.quantum());
    let mut last_flush: Option<Instant> = None;
    let mut tick_samples: Vec<f64> = Vec::new();
    let mut brownout = BrownoutCtl::new();
    // Flush-tick ordinal, the slow-tick fault's deterministic key.
    let mut tick_no: u64 = 0;
    // Worker-local per-slot execution state.  Boot models' arenas are
    // allocated here — on the worker thread, like every later hot load.
    let mut wm: Vec<Option<LaneIo<B>>> = {
        let inner = s.inner.lock().unwrap();
        inner
            .models
            .iter()
            .map(|slot| slot.as_ref().map(|m| lane_io(m.backend.clone(), m.lanes.capacity())))
            .collect()
    };

    loop {
        if s.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Admin first: models arrive only between ticks (arena built
        // lock-free, slot installed atomically).
        process_admin(&s, &mut wm);
        let mut inner = s.inner.lock().unwrap();
        // Incident dumps triggered while the engine lock is held are
        // deferred to the next guard drop: a postmortem scans and sorts
        // every trace ring and may write a file — doing that under the
        // mutex would stall admissions, pushes and the reaper exactly
        // when the engine is overloaded.
        let mut pending_pms: Vec<&'static str> = Vec::new();
        // Streams can finish *after* their last frame was computed (the
        // finish() raced the final batch) or with no audio at all — drain
        // them to the decode queue every tick, before the policy decision.
        // The reaper runs next (expired lifetimes and forced unloads free
        // their slots at this same boundary), then any draining model
        // that just lost its last stream is torn down.
        drain_finished(&mut inner, &s);
        if reap_expired(&mut inner, &wm, &s) {
            // A forced unload cancelled live streams out from under
            // clients — freeze the surrounding activity for the record.
            pending_pms.push("forced_cancels");
        }
        teardown_drained(&mut inner, &mut wm, &s);
        let nm = inner.models.len();
        debug_assert_eq!(nm, wm.len());
        // Evaluate policy over every ready stream, all models.
        let now = Instant::now();
        let mut ready: Vec<(u64, usize, Priority, Duration)> = inner
            .streams
            .iter()
            .filter(|(_, sl)| !sl.pending.is_empty())
            .map(|(&id, sl)| {
                let wait = sl.oldest_enqueue.map(|t| now - t).unwrap_or_default();
                (id, sl.model, sl.priority, wait)
            })
            .collect();
        // Batch-formation order: QoS class first, then longest wait.
        ready.sort_by(|a, b| schedule_cmp(&(a.2, a.3), &(b.2, b.3)));
        let oldest = ready.iter().map(|r| r.3).max().unwrap_or_default();
        match s.config.policy.decide(ready.len(), oldest) {
            Decision::Idle => {
                let (guard, _t) = s
                    .work_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                drop(guard);
                fire_postmortems(s.obs, &mut pending_pms);
                continue;
            }
            Decision::Wait(d) => {
                let (guard, _t) = s.work_cv.wait_timeout(inner, d).unwrap();
                drop(guard);
                fire_postmortems(s.obs, &mut pending_pms);
                continue;
            }
            Decision::Flush => {}
        }
        // Auto-quantum: sample flush-to-flush intervals (skipping idle
        // gaps) until enough are seen, then fix the quantum at
        // ~AUTO_SLO_SECS worth of measured ticks.
        if auto_quantum && tick_samples.len() < QUANTUM_TUNE_SAMPLES {
            if let Some(t) = last_flush {
                let dt = now - t;
                if dt <= QUANTUM_TUNE_MAX_GAP {
                    tick_samples.push(dt.as_secs_f64());
                    if tick_samples.len() == QUANTUM_TUNE_SAMPLES {
                        let mean =
                            tick_samples.iter().sum::<f64>() / tick_samples.len() as f64;
                        let q = (QuantumPolicy::AUTO_SLO_SECS / mean.max(1e-6)).round();
                        qpolicy.quantum_ticks = (q as u32).clamp(5, 500);
                        s.metrics.set_effective_quantum(qpolicy.quantum());
                    }
                }
            }
            last_flush = Some(now);
        }
        // Brownout overload control: compare flush cadence to the batch
        // deadline; a sustained overrun first sheds Bulk streams through
        // the reaper's parking path, then (if still drowning, or with no
        // Bulk left to shed) gates new admissions until the EWMA clears.
        // The `overload_tick` fault point injects deterministic overruns.
        {
            let forced = fault::fire(&s.config.faults, FaultPoint::OverloadTick, tick_no + 1);
            let prev_stage = brownout.stage;
            brownout.observe(now, s.config.policy.deadline, forced);
            if brownout.stage == 1 && brownout.under_ticks == 0 {
                let mut victims: Vec<(u64, usize, usize)> = inner
                    .streams
                    .iter()
                    .filter(|(_, sl)| sl.priority == Priority::Bulk && !sl.finished)
                    .map(|(&id, sl)| (id, sl.model, sl.frames_done))
                    .collect();
                // Deterministic victim order: least progress lost first,
                // then the newest stream.
                victims.sort_by(|a, b| a.2.cmp(&b.2).then(b.0.cmp(&a.0)));
                victims.truncate(BROWNOUT_SHED_PER_TICK);
                for &(id, m, _) in &victims {
                    obs::instant(
                        EventKind::Shed,
                        Meta {
                            engine: s.obs,
                            model: obs_model(m),
                            stream: id,
                            tick: tick_no + 1,
                            ..Meta::default()
                        },
                    );
                    cancel_stream(&mut inner, &wm, s.as_ref(), id, SHED_REASON);
                    s.metrics.add_shed(m);
                }
                if !victims.is_empty() {
                    s.space_cv.notify_all();
                    ready.retain(|r| inner.streams.contains_key(&r.0));
                }
                brownout.shed_ticks += 1;
                if victims.is_empty() || brownout.shed_ticks >= BROWNOUT_ESCALATE_TICKS {
                    brownout.stage = 2;
                }
            }
            match (prev_stage, brownout.stage) {
                (0, new) if new > 0 => {
                    s.metrics.brownout_transition(true);
                    // Freeze the run-up: the ticks that *led into* the
                    // brownout are exactly what the postmortem is for.
                    obs::instant(
                        EventKind::Brownout,
                        Meta {
                            engine: s.obs,
                            tick: tick_no + 1,
                            arg: new as u64,
                            ..Meta::default()
                        },
                    );
                    pending_pms.push("brownout_entry");
                }
                (prev, 0) if prev > 0 => s.metrics.brownout_transition(false),
                _ => {}
            }
            inner.brownout_stage = brownout.stage;
        }
        // Shedding may have cancelled every ready stream — nothing left
        // to plan this flush (falling through would trip the
        // scheduler-stall assertion below).
        if ready.is_empty() {
            drop(inner);
            s.space_cv.notify_all();
            fire_postmortems(s.obs, &mut pending_pms);
            continue;
        }
        // Plan this tick's batch, per model.  Pass 1: ready streams that
        // already hold a lane ride for free (unless preempted below).
        let mut planned: Vec<Vec<(u64, usize)>> = vec![Vec::new(); nm];
        for &(id, m, _, _) in &ready {
            if let Some(lane) = inner.streams[&id].lane {
                planned[m].push((id, lane));
            }
        }
        // Pass 2: place lane-less ready streams in schedule order — a
        // free lane, else evict an idle holder, else preempt an active
        // holder that exhausted its quantum (or holds a lower QoS class).
        // A stream preempted *this tick* sits the tick out instead of
        // cascading (it could otherwise preempt another exhausted holder
        // later in the same pass — two state round trips where one
        // rotation sufficed); it re-enters as a normal waiter next tick.
        let mut displaced: Vec<u64> = Vec::new();
        for &(id, m, prio, _) in &ready {
            if inner.streams[&id].lane.is_some() || displaced.contains(&id) {
                continue;
            }
            // (a) a free lane in this model's allocator.
            let mut lane = inner.models[m]
                .as_mut()
                .expect("ready stream on a torn-down model")
                .lanes
                .acquire();
            // (b) evict an idle holder (no pending frame ⇒ not in `ready`
            // ⇒ not planned this tick).  The lane changes hands without
            // passing through the allocator.
            if lane.is_none() {
                let victim = inner
                    .streams
                    .iter()
                    .find(|(_, vs)| vs.model == m && vs.lane.is_some() && vs.pending.is_empty())
                    .map(|(&vid, _)| vid);
                if let Some(vid) = victim {
                    let vslot = inner.streams.get_mut(&vid).unwrap();
                    let l = vslot.lane.take().unwrap();
                    let io = wm[m].as_ref().expect("arena for a live model");
                    vslot.parked = Some(io.backend.save_lane(&io.arena, l));
                    let vb = vslot.state_bytes;
                    inner.budget.note_parked(m, vb);
                    s.metrics.add_eviction(m);
                    obs::instant(
                        EventKind::LaneEvict,
                        Meta {
                            engine: s.obs,
                            model: obs_model(m),
                            lane: obs_lane(l),
                            stream: vid,
                            tick: tick_no + 1,
                            ..Meta::default()
                        },
                    );
                    lane = Some(l);
                }
            }
            // (c) preempt: every lane of this model is held by a stream
            // stepping this tick — take one from a holder past its
            // quantum (lowest class first, then most consumed quantum).
            // Parking happens at the tick boundary, before the victim's
            // next frame is popped, so the round trip is bit-exact.
            if lane.is_none() {
                let holders: Vec<HolderView> = planned[m]
                    .iter()
                    .map(|&(hid, hlane)| {
                        let hs = &inner.streams[&hid];
                        HolderView {
                            stream: hid,
                            priority: hs.priority,
                            quantum_used: hs.quantum_used,
                            tag: LaneTag { model: m, lane: hlane },
                        }
                    })
                    .collect();
                if let Some(vi) = qpolicy.select_victim(&holders, prio) {
                    let vid = holders[vi].stream;
                    let l = holders[vi].tag.lane;
                    let pos = planned[m]
                        .iter()
                        .position(|&(pid, _)| pid == vid)
                        .expect("victim came from planned");
                    planned[m].remove(pos);
                    let vslot = inner.streams.get_mut(&vid).unwrap();
                    vslot.lane = None;
                    vslot.quantum_used = 0;
                    let io = wm[m].as_ref().expect("arena for a live model");
                    vslot.parked = Some(io.backend.save_lane(&io.arena, l));
                    let vb = vslot.state_bytes;
                    inner.budget.note_parked(m, vb);
                    displaced.push(vid);
                    s.metrics.add_preemption(m);
                    obs::instant(
                        EventKind::LanePreempt,
                        Meta {
                            engine: s.obs,
                            model: obs_model(m),
                            lane: obs_lane(l),
                            stream: vid,
                            tick: tick_no + 1,
                            arg: holders[vi].quantum_used as u64,
                            ..Meta::default()
                        },
                    );
                    lane = Some(l);
                }
            }
            // No free lane, no idle holder, nothing preemptible: this
            // stream keeps waiting — bounded by the quantum, since a
            // never-idle holder exhausts its quantum within quantum ticks.
            let Some(lane) = lane else { continue };
            let slot = inner.streams.get_mut(&id).unwrap();
            let parked = slot.parked.take();
            let restored = parked.is_some();
            let sb = slot.state_bytes;
            if restored {
                inner.budget.note_unparked(m, sb);
            }
            {
                let io = wm[m].as_mut().expect("arena for a live model");
                match parked {
                    Some(p) => io.backend.load_lane(&mut io.arena, lane, &p),
                    None => io.backend.reset_lane(&mut io.arena, lane),
                }
            }
            let slot = inner.streams.get_mut(&id).unwrap();
            slot.lane = Some(lane);
            slot.quantum_used = 0;
            // arg distinguishes a cold place (reset lane) from a restore
            // of parked state.
            obs::instant(
                EventKind::LanePlace,
                Meta {
                    engine: s.obs,
                    model: obs_model(m),
                    lane: obs_lane(lane),
                    stream: id,
                    tick: tick_no + 1,
                    arg: u64::from(restored),
                    ..Meta::default()
                },
            );
            planned[m].push((id, lane));
        }
        // Unreachable with max_batch > 0: the highest-priority ready
        // stream either holds a lane (⇒ planned), or a lane is free, or
        // some holder is idle, or every holder is an active planned
        // stream (⇒ planned non-empty).  If it ever happens, count it
        // loudly — a silent park here would hide scheduler regressions.
        if planned.iter().all(|p| p.is_empty()) {
            s.metrics.add_sched_stall();
            debug_assert!(
                false,
                "scheduler stall: {} ready streams but nothing placeable",
                ready.len()
            );
            let (guard, _t) = s
                .work_cv
                .wait_timeout(inner, Duration::from_millis(20))
                .unwrap();
            drop(guard);
            fire_postmortems(s.obs, &mut pending_pms);
            continue;
        }
        // Weighted fairness: divide the tick's lane-step budget across
        // models by deficit-weighted round-robin and defer the rest.
        // Deferral only postpones whole frames (the trimmed holders keep
        // their lanes and step on a later grant), so it composes with the
        // bit-exactness contract.  Trim keeps the highest scheduling
        // claim: QoS class, then longest wait.  Known cost: pass 2 above
        // runs before the grant is known, so on a zero-grant tick a
        // preemption's save/load round trip can be wholly deferred —
        // wasted copies, never wrong results (grant-aware placement is a
        // ROADMAP follow-on; demand isn't known until placement ran).
        let demand: Vec<usize> = planned.iter().map(|p| p.len()).collect();
        let drr_weights: Vec<u32> = inner
            .models
            .iter()
            .map(|m| m.as_ref().map_or(0, |slot| slot.weight))
            .collect();
        let grants = drr.tick(&demand, &drr_weights, budget);
        for m in 0..nm {
            if grants[m] >= planned[m].len() {
                continue;
            }
            s.metrics.add_deferrals(m, planned[m].len() - grants[m]);
            let mut keyed: Vec<(Priority, Duration, u64, usize)> = planned[m]
                .iter()
                .map(|&(id, lane)| {
                    let sl = &inner.streams[&id];
                    let wait = sl.oldest_enqueue.map(|t| now - t).unwrap_or_default();
                    (sl.priority, wait, id, lane)
                })
                .collect();
            keyed.sort_by(|a, b| schedule_cmp(&(a.0, a.1), &(b.0, b.1)).then(a.2.cmp(&b.2)));
            planned[m] = keyed
                .into_iter()
                .take(grants[m])
                .map(|(_, _, id, lane)| (id, lane))
                .collect();
        }
        // Pop one frame per granted stream into its lane's input row, and
        // charge the tick against the holder's quantum.
        let mut enqueue_times: Vec<Vec<Option<Instant>>> = vec![Vec::new(); nm];
        let mut total_b = 0usize;
        let mut lanes_in_use_total = 0usize;
        let mut total_lanes = 0usize;
        for m in 0..nm {
            let Some(io) = wm[m].as_mut() else {
                debug_assert!(planned[m].is_empty());
                continue;
            };
            let d = io.dim;
            for &(id, lane) in &planned[m] {
                let slot = inner.streams.get_mut(&id).unwrap();
                let frame = slot.pending.pop_front().unwrap();
                io.xbuf[lane * d..(lane + 1) * d].copy_from_slice(&frame);
                enqueue_times[m].push(slot.oldest_enqueue);
                slot.oldest_enqueue =
                    if slot.pending.is_empty() { None } else { Some(now) };
                slot.quantum_used = slot.quantum_used.saturating_add(1);
            }
            total_b += planned[m].len();
            let slot = inner.models[m].as_ref().expect("arena without a model slot");
            let in_use = slot.lanes.in_use();
            // Occupancy counts only models with holders — a hot-loaded
            // model that serves no traffic yet must not dilute the
            // saturation signal (mirrors record_model_tick's convention
            // of skipping idle models).
            if in_use > 0 {
                lanes_in_use_total += in_use;
                total_lanes += slot.lanes.capacity();
            }
            if !planned[m].is_empty() {
                s.metrics.record_model_tick(m, in_use, planned[m].len());
            }
        }
        s.metrics
            .lane_occupancy
            .record(lanes_in_use_total as f64 / total_lanes.max(1) as f64);
        drop(inner);
        s.space_cv.notify_all();
        fire_postmortems(s.obs, &mut pending_pms);
        tick_no += 1;
        if fault::fire(&s.config.faults, FaultPoint::SlowTick, tick_no) {
            std::thread::sleep(Duration::from_millis(fault::SLOW_TICK_MS));
        }

        // Batched AM step per model over its granted lanes, in place
        // (lock-free; arenas are worker-local and lane rows belong to
        // planned streams).
        let t0 = Instant::now();
        let mut any_failed = false;
        // Per-model step time: a model's frames are ready once *its* step
        // returns, so latency is charged per model, not the whole phase
        // (dt below) — two models stepping sequentially must not inflate
        // each other's frame_latency.
        let mut step_times: Vec<Duration> = vec![Duration::ZERO; nm];
        for m in 0..nm {
            if planned[m].is_empty() {
                continue;
            }
            let io = wm[m].as_mut().expect("granted lanes on an unloaded model");
            let tm = Instant::now();
            let t_obs = obs::span_begin();
            let lanes_list: Vec<usize> = planned[m].iter().map(|&(_, l)| l).collect();
            let faults = &s.config.faults;
            let step = catch_unwind(AssertUnwindSafe(|| {
                if fault::fire(faults, FaultPoint::BackendPanic, m as u64) {
                    panic!("injected backend panic (model {m})");
                }
                io.backend.step_lanes(&mut io.arena, &lanes_list, &io.xbuf, &mut io.ybuf)
            }));
            match step {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    // Backend failure (only fallible for the PJRT path):
                    // surface loudly, put the popped frames back at the
                    // head of their queues (no silent truncation of
                    // posteriors), and back off below so a
                    // persistently-dead backend applies backpressure
                    // instead of busy-looping.
                    eprintln!(
                        "am backend '{}' step failed: {e:#}",
                        io.backend.backend_name()
                    );
                    let d = io.dim;
                    let mut inner = s.inner.lock().unwrap();
                    let now_err = Instant::now();
                    for &(id, lane) in &planned[m] {
                        if let Some(slot) = inner.streams.get_mut(&id) {
                            slot.pending.push_front(io.xbuf[lane * d..(lane + 1) * d].to_vec());
                            slot.oldest_enqueue.get_or_insert(now_err);
                            slot.quantum_used = slot.quantum_used.saturating_sub(1);
                        }
                    }
                    drop(inner);
                    planned[m].clear();
                    any_failed = true;
                }
                Err(_) => {
                    // Panic quarantine: the model's arena may be
                    // half-written, so it can never step again — but the
                    // process and every other model keep serving.  The
                    // slot goes `Quarantined` (newcomers rejected with a
                    // reason), its streams are cancelled through the
                    // parking path, and an unload tears it down for slot
                    // reuse as usual.
                    eprintln!(
                        "am backend '{}' panicked while stepping model {m}; \
                         quarantining the model",
                        io.backend.backend_name()
                    );
                    let mut inner = s.inner.lock().unwrap();
                    if let Some(slot) = inner.models[m].as_mut() {
                        slot.quarantined = true;
                    }
                    let ids: Vec<u64> = inner
                        .streams
                        .iter()
                        .filter(|(_, sl)| sl.model == m)
                        .map(|(&id, _)| id)
                        .collect();
                    for id in ids {
                        cancel_stream(
                            &mut inner,
                            &wm,
                            s.as_ref(),
                            id,
                            "model quarantined after a backend panic",
                        );
                    }
                    s.metrics.add_quarantined_job();
                    s.metrics.set_quarantined(m);
                    drop(inner);
                    s.space_cv.notify_all();
                    obs::instant(
                        EventKind::Quarantine,
                        Meta {
                            engine: s.obs,
                            model: obs_model(m),
                            tick: tick_no,
                            ..Meta::default()
                        },
                    );
                    obs::postmortem(s.obs, "backend_panic_quarantine");
                    planned[m].clear();
                    any_failed = true;
                }
            }
            step_times[m] = tm.elapsed();
            // One span per stepped model: dur is the batched AM step,
            // arg the lane count it covered (a zero-lane model is
            // skipped above, so every AmTick span is real compute).
            obs::span_end(
                EventKind::AmTick,
                t_obs,
                Meta {
                    engine: s.obs,
                    model: obs_model(m),
                    tick: tick_no,
                    arg: lanes_list.len() as u64,
                    ..Meta::default()
                },
            );
        }
        let dt = t0.elapsed();
        let stepped: usize = planned.iter().map(|p| p.len()).sum();
        if stepped > 0 {
            s.metrics.add_am_compute(dt.as_secs_f64(), stepped as u64);
            s.metrics.batch_size.record(total_b as f64);
        }
        if any_failed && stepped == 0 {
            let mut inner = s.inner.lock().unwrap();
            drain_finished(&mut inner, &s);
            drop(inner);
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }

        // Append each lane's posteriors to its stream; queue decodes for
        // drained finished streams.  (This is result delivery, not state
        // movement — recurrent state stayed in the arena.)
        let mut inner = s.inner.lock().unwrap();
        for m in 0..nm {
            let Some(io) = wm[m].as_ref() else { continue };
            let l = io.labels;
            for (k, &(id, lane)) in planned[m].iter().enumerate() {
                if let Some(slot) = inner.streams.get_mut(&id) {
                    if slot.frames_done == 0 {
                        s.metrics
                            .first_frame_latency
                            .record_duration(slot.opened_at.elapsed());
                    }
                    slot.posteriors
                        .extend_from_slice(&io.ybuf[lane * l..(lane + 1) * l]);
                    slot.frames_done += 1;
                }
                if let Some(t0q) = enqueue_times[m][k] {
                    s.metrics.frame_latency.record_duration(now - t0q + step_times[m]);
                }
            }
        }
        drain_finished(&mut inner, &s);
    }
}

/// Cancel one live stream (worker thread, engine lock held, tick
/// boundary): park its lane state through the exact
/// [`AmBackend::save_lane`] path survivors' eviction/preemption uses —
/// so the cancellation is invisible to every co-rider's numerics — then
/// release the lane, free the admission slot, and deliver a
/// [`StreamEnd::Cancelled`] result with `reason`.  Producers blocked on
/// this stream's backpressure see "unknown stream" on their next
/// `space_cv` wakeup (the caller notifies after its sweep).
fn cancel_stream<B: AmBackend>(
    inner: &mut Inner<B>,
    wm: &[Option<LaneIo<B>>],
    s: &Shared<B>,
    id: u64,
    reason: &str,
) {
    let Some(mut slot) = inner.streams.remove(&id) else {
        return;
    };
    // Ledger: the reservation (and any blob already counted as parked)
    // leaves with the slot.  The transient park below is dropped with
    // `slot` at the end of this function and is never ledger-visible.
    let had_parked = slot.parked.is_some();
    inner.budget.release_stream(slot.model, slot.state_bytes, had_parked);
    publish_bytes(s, inner, slot.model);
    if let Some(lane) = slot.lane.take() {
        if let Some(io) = wm.get(slot.model).and_then(|w| w.as_ref()) {
            slot.parked = Some(io.backend.save_lane(&io.arena, lane));
        }
        if let Some(m) = inner.models.get_mut(slot.model).and_then(|m| m.as_mut()) {
            m.lanes.release(lane);
        }
    }
    obs::instant(
        EventKind::Cancel,
        Meta {
            engine: s.obs,
            model: obs_model(slot.model),
            stream: id,
            arg: slot.frames_done as u64,
            ..Meta::default()
        },
    );
    let _ = slot.result_tx.send(FinalResult {
        stream_id: id,
        words: Vec::new(),
        phones: Vec::new(),
        num_frames: slot.frames_done,
        finalize_latency: Duration::ZERO,
        end: StreamEnd::Cancelled(reason.to_string()),
        trace: slot.trace,
    });
}

/// Mirror one model's budget-ledger row into [`Metrics`] (per-model
/// `arena_bytes`/`reserved_bytes`/`parked_bytes`), so `report()`, the
/// `'Q'` snapshot and the Prometheus exposition agree with the ledger.
/// Called at every ledger-moving event — admission, cancel, drain,
/// load, teardown, park, unpark.
fn publish_bytes<B: AmBackend>(s: &Shared<B>, inner: &Inner<B>, m: usize) {
    let row = inner.budget.model(m);
    s.metrics.set_model_bytes(m, row.arena, row.reserved, row.parked);
}

/// The reaper (worker thread, engine lock held, tick boundary): enforce
/// stream lifetimes and expired force-unloads.
///
/// - **Forced unload** — a model whose bounded-deadline unload expired
///   with `force` has every surviving stream cancelled (per-model
///   `forced_cancels`), which unpins its teardown this same pass.
/// - **Utterance deadline** — a stream older than
///   [`EngineConfig::stream_deadline`] that has not signalled finish is
///   cancelled; streams already finalizing are left to finish normally.
/// - **Idle timeout** — a stream with no pending frames and no client
///   activity for [`EngineConfig::stream_idle`] is cancelled (a stream
///   with frames still queued is the engine's debt, not the client's).
///
/// Returns whether a forced unload cancelled live streams — the caller
/// owes a `forced_cancels` postmortem *after* it drops the engine lock
/// (a dump walks every ring and may hit the filesystem; doing that here
/// would stall admissions and pushes exactly when the engine is busy).
fn reap_expired<B: AmBackend>(
    inner: &mut Inner<B>,
    wm: &[Option<LaneIo<B>>],
    s: &Shared<B>,
) -> bool {
    let mut cancelled = false;
    let mut forced = false;
    for m in 0..inner.models.len() {
        if !matches!(&inner.models[m], Some(slot) if slot.force_cancel) {
            continue;
        }
        let ids: Vec<u64> =
            inner.streams.iter().filter(|(_, sl)| sl.model == m).map(|(&id, _)| id).collect();
        for id in ids {
            cancel_stream(inner, wm, s, id, "model unloading (forced)");
            s.metrics.add_forced_cancel(m);
            cancelled = true;
            forced = true;
        }
        if let Some(Some(slot)) = inner.models.get_mut(m) {
            slot.force_cancel = false;
        }
    }
    let (idle, deadline) = (s.config.stream_idle, s.config.stream_deadline);
    if idle.is_some() || deadline.is_some() {
        let now = Instant::now();
        let expired: Vec<(u64, String)> = inner
            .streams
            .iter()
            .filter(|(_, sl)| !sl.finished)
            .filter_map(|(&id, sl)| {
                if let Some(d) = deadline {
                    if now.duration_since(sl.opened_at) > d {
                        return Some((
                            id,
                            format!("utterance exceeded its deadline ({} ms)", d.as_millis()),
                        ));
                    }
                }
                if let Some(t) = idle {
                    if sl.pending.is_empty() && now.duration_since(sl.last_activity) > t {
                        return Some((
                            id,
                            format!("stream idle past the timeout ({} ms)", t.as_millis()),
                        ));
                    }
                }
                None
            })
            .collect();
        for (id, reason) in expired {
            cancel_stream(inner, wm, s, id, &reason);
            s.metrics.add_reaped();
            cancelled = true;
        }
    }
    if cancelled {
        s.space_cv.notify_all();
    }
    forced
}

/// Flush the postmortem triggers the am_worker deferred while it held
/// the engine lock — called only after the guard drops, so the ring
/// scan and dump write never block admissions or pushes.
fn fire_postmortems(engine: u16, pending: &mut Vec<&'static str>) {
    for trigger in pending.drain(..) {
        obs::postmortem(engine, trigger);
    }
}

/// Move every (finished && drained) stream to the decode queue, releasing
/// its arena lane to its model's allocator.  Queueing is QoS-ordered
/// ([`ClassQueue`]): an interactive finalize never waits behind a bulk
/// backlog.
fn drain_finished<B: AmBackend>(inner: &mut Inner<B>, s: &Shared<B>) {
    let done: Vec<u64> = inner
        .streams
        .iter()
        .filter(|(_, sl)| sl.finished && sl.pending.is_empty())
        .map(|(&id, _)| id)
        .collect();
    for id in done {
        let slot = inner.streams.remove(&id).unwrap();
        // Ledger: the reservation (and any parked blob the stream still
        // held — it finished while evicted) leaves with the slot.
        inner.budget.release_stream(slot.model, slot.state_bytes, slot.parked.is_some());
        publish_bytes(s, inner, slot.model);
        if let Some(lane) = slot.lane {
            inner.models[slot.model]
                .as_mut()
                .expect("live stream on a torn-down model")
                .lanes
                .release(lane);
        }
        obs::instant(
            EventKind::DecodeEnqueue,
            Meta {
                engine: s.obs,
                model: obs_model(slot.model),
                stream: id,
                arg: slot.frames_done as u64,
                ..Meta::default()
            },
        );
        inner.decode_queue.push(
            slot.priority,
            DecodeJob {
                stream_id: id,
                model: slot.model,
                posteriors: slot.posteriors,
                num_frames: slot.frames_done,
                finish_time: slot.finish_time.unwrap_or_else(Instant::now),
                result_tx: slot.result_tx,
                trace: slot.trace,
            },
        );
        s.decode_cv.notify_one();
    }
}

/// Finished utterances one decode worker pops per wakeup.  Jobs sharing a
/// flush decode together through [`Decoder::decode_batch`], so trie/LM
/// lookup state (the memoized word-boundary scores) is shared across the
/// batch instead of rebuilt per utterance.
const DECODE_POP_BATCH: usize = 8;

fn decode_worker<B: AmBackend>(s: Arc<Shared<B>>, decoder: Arc<Decoder>) {
    loop {
        let jobs = {
            let mut inner = s.inner.lock().unwrap();
            loop {
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let jobs = inner.decode_queue.pop_up_to(DECODE_POP_BATCH);
                if !jobs.is_empty() {
                    break jobs;
                }
                let (guard, _t) = s
                    .decode_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                inner = guard;
            }
        };
        let t0 = Instant::now();
        let t_obs = obs::span_begin();
        let batch: Vec<(&[f32], usize)> = jobs
            .iter()
            .map(|j| (j.posteriors.as_slice(), (j.posteriors.len() / j.num_frames.max(1)).max(1)))
            .collect();
        // The decoder is a context-free layer: hand it this worker's
        // engine identity so its search spans are attributable.
        let prev_ctx = obs::set_ctx(s.obs, 0, obs::NO_MODEL);
        // Panic quarantine, batch level: if the shared-LmCache batch path
        // unwinds, retry each job alone so one poisoned utterance fails
        // by itself instead of dragging its flush-mates down with it.
        let hyps: Vec<Option<_>> =
            match catch_unwind(AssertUnwindSafe(|| decoder.decode_batch(&batch))) {
                Ok(h) => h.into_iter().map(Some).collect(),
                Err(_) => batch
                    .iter()
                    .map(|&(p, l)| {
                        catch_unwind(AssertUnwindSafe(|| decoder.decode_batch(&[(p, l)]).pop()))
                            .ok()
                            .flatten()
                    })
                    .collect(),
            };
        obs::restore_ctx(prev_ctx);
        s.metrics.add_decode_compute(t0.elapsed().as_secs_f64());
        for (job, hyp) in jobs.into_iter().zip(hyps) {
            let injected = fault::fire(&s.config.faults, FaultPoint::DecodePanic, job.stream_id);
            // Panic quarantine, job level: the greedy phone pass (and the
            // injected panic) ride inside the guard — posteriors are
            // per-utterance data, so a panic here is this job's fault and
            // only this job fails.
            let finalized = catch_unwind(AssertUnwindSafe(|| {
                if injected {
                    panic!("injected decode panic (stream {})", job.stream_id);
                }
                let hyp = hyp.expect("batch decode panicked for this job");
                let labels = (job.posteriors.len() / job.num_frames.max(1)).max(1);
                let phones = crate::decoder::ctc::greedy(&job.posteriors, labels);
                (hyp.words, phones)
            }))
            .ok();
            s.metrics.add_utterance();
            let latency = job.finish_time.elapsed();
            s.metrics.finalize_latency.record_duration(latency);
            // Jobs in one flush share the batch-decode start: their
            // DecodeJob spans overlap on this worker's track by design.
            obs::span_end(
                EventKind::DecodeJob,
                t_obs,
                Meta {
                    engine: s.obs,
                    model: obs_model(job.model),
                    stream: job.stream_id,
                    arg: job.num_frames as u64,
                    ..Meta::default()
                },
            );
            let (words, phones, end) = match finalized {
                Some((words, phones)) => {
                    obs::instant(
                        EventKind::Finalize,
                        Meta {
                            engine: s.obs,
                            model: obs_model(job.model),
                            stream: job.stream_id,
                            arg: words.len() as u64,
                            ..Meta::default()
                        },
                    );
                    (words, phones, StreamEnd::Complete)
                }
                None => {
                    s.metrics.add_quarantined_job();
                    obs::instant(
                        EventKind::Quarantine,
                        Meta {
                            engine: s.obs,
                            model: obs_model(job.model),
                            stream: job.stream_id,
                            ..Meta::default()
                        },
                    );
                    obs::postmortem(s.obs, "decode_panic_quarantine");
                    let why =
                        format!("decode panicked for stream {}; utterance quarantined", job.stream_id);
                    (Vec::new(), Vec::new(), StreamEnd::Failed(why))
                }
            };
            let _ = job.result_tx.send(FinalResult {
                stream_id: job.stream_id,
                words,
                phones,
                num_frames: job.num_frames,
                finalize_latency: latency,
                end,
                trace: job.trace,
            });
        }
    }
}
