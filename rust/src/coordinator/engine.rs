//! The serving engine: streams in, batched acoustic-model steps, final
//! lexicon+LM decodes out.  Generic over the execution backend
//! ([`AmBackend`]): the native int8 engine is the production path, the
//! PJRT/AOT graph (feature `pjrt`) is a one-line swap at
//! [`Engine::start`].
//!
//! Thread topology (std threads; the image has no tokio):
//!
//! ```text
//! callers ──push_audio──▶ per-stream Frontend ──▶ pending frame queues
//!                                                (bounded; backpressure)
//! AM worker ── BatchPolicy + sched ──▶ step each model's active lanes
//!   └── large packed GEMMs fan panels out to the persistent worker pool
//!       (util::pool; parked threads, QUANTASR_GEMM_THREADS caps them)
//! decode workers ◀── finished streams' posteriors ──▶ FinalResult channel
//! ```
//!
//! **Lane-resident batching.**  Each live stream owns a stable *lane* in
//! its model's pre-allocated arena (`[max_batch, state]` buffers); the AM
//! worker writes each scheduled stream's frame into its lane's row of a
//! lane-resident input buffer and steps the active lanes **in place** —
//! recurrent state never moves per tick.  Lane numerics are bit-identical
//! to running the stream alone (per-row quantization, `quant::gemm`), so
//! lane assignment is invisible to results.
//!
//! **Scheduling** is owned by [`crate::sched`]; the engine is mechanism.
//! When live streams outnumber lanes, lane-less ready streams are placed
//! in priority order ([`schedule_cmp`]): a free lane if any, else an
//! *idle* holder is **evicted** (state parked on the stream slot via
//! [`AmBackend::save_lane`]), else an active holder that has consumed its
//! tick quantum — or holds a lower QoS class than the waiter — is
//! **preempted** through the same exact parking path
//! ([`QuantumPolicy::select_victim`]).  Preemption happens at tick
//! boundaries only, so a preempted stream's outputs are bit-identical to
//! an unpreempted run; a newcomer's wait is bounded by one quantum even
//! when every holder streams continuously (the starvation hole the
//! pre-scheduler engine documented).  Admission is bounded
//! ([`crate::sched::admission`]): beyond the live-stream cap,
//! [`Engine::try_open_stream`] rejects with a reason instead of growing
//! without limit.
//!
//! **Multi-model serving.**  [`Engine::start_registry`] loads N models
//! ([`ModelRegistry`]); each gets its own lane-tagged arena and allocator,
//! one scheduler places streams per model, and every flush steps each
//! model's planned lanes, so models share the AM worker and decode pool
//! fairly (per-model lane accounting in [`Metrics::per_model`]).
//!
//! Decoding (CTC beam + LM rescore) is heavier and utterance-final, so it
//! runs on its own worker pool.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::batcher::{schedule_cmp, BatchPolicy, Decision, LaneAllocator};
use crate::coordinator::metrics::Metrics;
use crate::decoder::Decoder;
use crate::frontend::{spec, Frontend};
use crate::nn::AcousticModel;
use crate::runtime::backend::{AmBackend, LaneTag};
use crate::sched::{
    AdmissionConfig, AdmissionController, HolderView, ModelRegistry, Priority, QuantumPolicy,
    RejectReason, StreamOptions,
};

/// Engine configuration.
#[derive(Clone)]
pub struct EngineConfig {
    pub policy: BatchPolicy,
    pub decode_workers: usize,
    /// Per-stream pending-frame cap (backpressure bound).
    pub max_pending_frames: usize,
    /// Time-slice preemption policy (lane quanta).
    pub quantum: QuantumPolicy,
    /// Live-stream admission bound.
    pub admission: AdmissionConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: BatchPolicy::default(),
            decode_workers: 2,
            max_pending_frames: 256,
            quantum: QuantumPolicy::default(),
            admission: AdmissionConfig::default(),
        }
    }
}

impl EngineConfig {
    /// Apply the shared serving CLI flags (`--max-batch`, `--deadline-ms`,
    /// `--quantum`, `--max-streams`), warn-don't-panic: the deadline goes
    /// through the validated [`parse_deadline_ms`] grammar (finite,
    /// non-negative — `Duration::from_secs_f64` would panic on `inf`) and
    /// the quantum parses directly as `u32` so out-of-range values warn
    /// instead of silently truncating.  Absent flags fall through to the
    /// env-overridable defaults (`QUANTASR_BATCH_DEADLINE_MS`,
    /// `QUANTASR_QUANTUM_TICKS`).
    pub fn apply_cli_flags(&mut self, args: &crate::util::cli::Args) {
        self.policy.max_batch = args.get_usize("max-batch", self.policy.max_batch);
        if let Some(v) = args.get("deadline-ms") {
            match crate::coordinator::batcher::parse_deadline_ms(v) {
                Some(d) => self.policy.deadline = d,
                None => eprintln!(
                    "--deadline-ms '{v}' is not a non-negative number of milliseconds; \
                     keeping {:.1} ms",
                    self.policy.deadline.as_secs_f64() * 1e3
                ),
            }
        }
        if let Some(v) = args.get("quantum") {
            match v.parse::<u32>() {
                Ok(q) => self.quantum.quantum_ticks = q,
                Err(_) => eprintln!(
                    "--quantum '{v}' is not a tick count (u32); keeping {}",
                    self.quantum.quantum_ticks
                ),
            }
        }
        self.admission.max_live_streams =
            args.get_usize_warn("max-streams", self.admission.max_live_streams);
    }
}

/// Final recognition result for one stream.
#[derive(Clone, Debug)]
pub struct FinalResult {
    pub stream_id: u64,
    pub words: Vec<u32>,
    /// Greedy phone sequence (diagnostic / LER).
    pub phones: Vec<u32>,
    pub num_frames: usize,
    /// finish() called → result ready.
    pub finalize_latency: Duration,
}

struct StreamSlot<B: AmBackend> {
    frontend: Frontend,
    /// Which loaded model serves this stream (index into `Engine::models`).
    model: usize,
    /// QoS class: preemption victim selection + batch-formation order.
    priority: Priority,
    /// Ticks stepped since the stream last (re)acquired a lane.
    quantum_used: u32,
    opened_at: Instant,
    /// Feature frames awaiting the AM, flattened input_dim each.
    pending: VecDeque<Vec<f32>>,
    oldest_enqueue: Option<Instant>,
    /// Accumulated log-posteriors [frames_done, num_labels].
    posteriors: Vec<f32>,
    frames_done: usize,
    /// Arena lane (in the stream's model's arena) holding this stream's
    /// recurrent state, if admitted.
    lane: Option<usize>,
    /// State parked outside the arena (evicted / preempted / not yet
    /// admitted).  `None` with `lane: None` ⇒ fresh zero state.
    parked: Option<B::Parked>,
    finished: bool,
    finish_time: Option<Instant>,
    result_tx: Sender<FinalResult>,
}

struct DecodeJob {
    stream_id: u64,
    posteriors: Vec<f32>,
    num_frames: usize,
    finish_time: Instant,
    result_tx: Sender<FinalResult>,
}

struct Inner<B: AmBackend> {
    streams: HashMap<u64, StreamSlot<B>>,
    /// One allocator per model (lane-tagged arenas).
    lanes: Vec<LaneAllocator>,
    next_id: u64,
    decode_queue: VecDeque<DecodeJob>,
}

struct Shared<B: AmBackend> {
    inner: Mutex<Inner<B>>,
    /// Wakes the AM worker (new frames / finished streams).
    work_cv: Condvar,
    /// Wakes decode workers.
    decode_cv: Condvar,
    /// Wakes producers blocked on backpressure.
    space_cv: Condvar,
    metrics: Metrics,
    admission: AdmissionController,
    config: EngineConfig,
    shutdown: AtomicBool,
}

/// The streaming serving engine, generic over the execution backend
/// (defaults to the native [`AcousticModel`]).
pub struct Engine<B: AmBackend = AcousticModel> {
    models: Vec<Arc<B>>,
    shared: Arc<Shared<B>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<B: AmBackend> Engine<B> {
    /// Start a single-model engine (the pre-registry surface; equivalent
    /// to `start_registry(ModelRegistry::single(backend), …)`).
    pub fn start(backend: Arc<B>, decoder: Arc<Decoder>, config: EngineConfig) -> Self {
        Self::start_registry(ModelRegistry::single(backend), decoder, config)
    }

    /// Start an engine serving every model in `registry` through one
    /// scheduler, AM worker and decode pool.
    pub fn start_registry(
        registry: ModelRegistry<B>,
        decoder: Arc<Decoder>,
        mut config: EngineConfig,
    ) -> Self {
        let (names, models) = registry.into_parts();
        assert!(!models.is_empty(), "ModelRegistry has no models");
        // Lane-capped backends (e.g. an AOT graph lowered at a fixed
        // batch) bound the arena: clamp rather than panic so the raised
        // default `max_batch` (32) still works against a smaller
        // fixed-batch graph.  The tightest model wins — lanes-per-model
        // is uniform so the scheduler's fairness math stays simple.
        for b in &models {
            if let Some(cap) = b.lane_capacity() {
                if config.policy.max_batch > cap {
                    eprintln!(
                        "engine: backend '{}' supports {cap} lanes; clamping max_batch {} -> {cap}",
                        b.backend_name(),
                        config.policy.max_batch
                    );
                    config.policy.max_batch = cap;
                }
            }
        }
        let max_lanes = config.policy.max_batch;
        let admission = AdmissionController::new(config.admission);
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                streams: HashMap::new(),
                lanes: (0..models.len()).map(|_| LaneAllocator::new(max_lanes)).collect(),
                next_id: 0,
                decode_queue: VecDeque::new(),
            }),
            work_cv: Condvar::new(),
            decode_cv: Condvar::new(),
            space_cv: Condvar::new(),
            metrics: Metrics::default(),
            admission,
            config,
            shutdown: AtomicBool::new(false),
        });
        shared.metrics.init_models(&names, max_lanes);
        let mut workers = Vec::new();
        {
            let s = shared.clone();
            let ms = models.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("am-worker".into())
                    .spawn(move || am_worker(s, ms))
                    .expect("spawn am worker"),
            );
        }
        for i in 0..shared.config.decode_workers {
            let s = shared.clone();
            let d = decoder.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("decode-{i}"))
                    .spawn(move || decode_worker(s, d))
                    .expect("spawn decode worker"),
            );
        }
        Engine { models, shared, workers }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// The first (or only) execution backend this engine drives.
    pub fn backend(&self) -> &Arc<B> {
        &self.models[0]
    }

    /// All loaded models, in registration order (index = model id).
    pub fn models(&self) -> &[Arc<B>] {
        &self.models
    }

    /// Open a new default stream (model 0, `Priority::Interactive`);
    /// returns its id and the final-result receiver.  The stream is
    /// admitted to an arena lane lazily, when it is first scheduled into
    /// a batch.  Panics if admission control rejects — callers that can
    /// handle backpressure should use [`Engine::try_open_stream`].
    pub fn open_stream(&self) -> (u64, Receiver<FinalResult>) {
        self.try_open_stream(StreamOptions::default())
            .expect("stream admission rejected")
    }

    /// Open a stream with explicit model/priority, subject to admission
    /// control: beyond the live-stream cap (or for an unknown model) the
    /// stream is rejected with a reason instead of queued unboundedly.
    pub fn try_open_stream(
        &self,
        opts: StreamOptions,
    ) -> Result<(u64, Receiver<FinalResult>), RejectReason> {
        let (tx, rx) = channel();
        let mut inner = self.shared.inner.lock().unwrap();
        if let Err(reason) =
            self.shared.admission.admit(inner.streams.len(), opts.model, self.models.len())
        {
            self.shared.metrics.add_admission_reject();
            return Err(reason);
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.streams.insert(
            id,
            StreamSlot {
                frontend: Frontend::new(),
                model: opts.model,
                priority: opts.priority,
                quantum_used: 0,
                opened_at: Instant::now(),
                pending: VecDeque::new(),
                oldest_enqueue: None,
                posteriors: Vec::new(),
                frames_done: 0,
                lane: None,
                parked: None,
                finished: false,
                finish_time: None,
                result_tx: tx,
            },
        );
        Ok((id, rx))
    }

    /// Push PCM samples (blocks under backpressure).
    pub fn push_audio(&self, id: u64, pcm: &[f32]) -> Result<()> {
        self.shared.metrics.add_audio(pcm.len() as f64 / spec::SAMPLE_RATE as f64);
        let mut frames = Vec::new();
        {
            let mut inner = self.shared.inner.lock().unwrap();
            let slot = match inner.streams.get_mut(&id) {
                Some(s) => s,
                None => bail!("unknown stream {id}"),
            };
            if slot.finished {
                bail!("stream {id} already finished");
            }
            slot.frontend.push(pcm, &mut frames);
        }
        self.push_frames(id, &frames)
    }

    /// Push pre-computed feature frames (len = k·input_dim of the
    /// stream's model).
    pub fn push_frames(&self, id: u64, frames: &[f32]) -> Result<()> {
        let d = {
            let inner = self.shared.inner.lock().unwrap();
            match inner.streams.get(&id) {
                Some(slot) => self.models[slot.model].input_dim(),
                None => bail!("unknown stream {id}"),
            }
        };
        assert_eq!(frames.len() % d, 0);
        let mut offset = 0;
        while offset < frames.len() {
            let mut inner = self.shared.inner.lock().unwrap();
            // backpressure: wait for queue space
            loop {
                let slot = match inner.streams.get(&id) {
                    Some(s) => s,
                    None => bail!("unknown stream {id}"),
                };
                if slot.pending.len() < self.shared.config.max_pending_frames {
                    break;
                }
                inner = self.shared.space_cv.wait(inner).unwrap();
            }
            let cap = self.shared.config.max_pending_frames;
            let slot = inner.streams.get_mut(&id).unwrap();
            let now = Instant::now();
            while offset < frames.len() && slot.pending.len() < cap {
                slot.pending.push_back(frames[offset..offset + d].to_vec());
                offset += d;
            }
            slot.oldest_enqueue.get_or_insert(now);
            drop(inner);
            self.shared.work_cv.notify_all();
        }
        Ok(())
    }

    /// Signal end of audio; the final decode is delivered on the stream's
    /// receiver once all pending frames are processed.
    pub fn finish_stream(&self, id: u64) -> Result<()> {
        let mut inner = self.shared.inner.lock().unwrap();
        let slot = match inner.streams.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown stream {id}"),
        };
        slot.finished = true;
        slot.finish_time = Some(Instant::now());
        drop(inner);
        self.shared.work_cv.notify_all();
        Ok(())
    }

    /// Convenience: run one utterance synchronously through the engine.
    pub fn recognize(&self, pcm: &[f32]) -> Result<FinalResult> {
        let (id, rx) = self.open_stream();
        self.push_audio(id, pcm)?;
        self.finish_stream(id)?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<B: AmBackend> Drop for Engine<B> {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.decode_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn am_worker<B: AmBackend>(s: Arc<Shared<B>>, models: Vec<Arc<B>>) {
    let nm = models.len();
    let max_lanes = s.config.policy.max_batch;
    let dims: Vec<usize> = models.iter().map(|m| m.input_dim()).collect();
    let labels: Vec<usize> = models.iter().map(|m| m.num_labels()).collect();
    // One persistent arena per model: every live stream's recurrent state
    // lives in its lane for the engine's lifetime.  Allocated once,
    // stepped in place — state moves only on eviction/preemption.
    let mut arenas: Vec<B::Arena> =
        models.iter().map(|m| m.alloc_arena(max_lanes)).collect();
    // Lane-resident I/O buffers per model (row `lane` belongs to that
    // lane's stream).
    let mut xbufs: Vec<Vec<f32>> = dims.iter().map(|&d| vec![0f32; max_lanes * d]).collect();
    let mut ybufs: Vec<Vec<f32>> =
        labels.iter().map(|&l| vec![0f32; max_lanes * l]).collect();

    loop {
        if s.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let mut inner = s.inner.lock().unwrap();
        // Streams can finish *after* their last frame was computed (the
        // finish() raced the final batch) or with no audio at all — drain
        // them to the decode queue every tick, before the policy decision.
        drain_finished(&mut inner, &s);
        // Evaluate policy over every ready stream, all models.
        let now = Instant::now();
        let mut ready: Vec<(u64, usize, Priority, Duration)> = inner
            .streams
            .iter()
            .filter(|(_, sl)| !sl.pending.is_empty())
            .map(|(&id, sl)| {
                let wait = sl.oldest_enqueue.map(|t| now - t).unwrap_or_default();
                (id, sl.model, sl.priority, wait)
            })
            .collect();
        // Batch-formation order: QoS class first, then longest wait.
        ready.sort_by(|a, b| schedule_cmp(&(a.2, a.3), &(b.2, b.3)));
        let oldest = ready.iter().map(|r| r.3).max().unwrap_or_default();
        match s.config.policy.decide(ready.len(), oldest) {
            Decision::Idle => {
                let (guard, _t) = s
                    .work_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                drop(guard);
                continue;
            }
            Decision::Wait(d) => {
                let (guard, _t) = s.work_cv.wait_timeout(inner, d).unwrap();
                drop(guard);
                continue;
            }
            Decision::Flush => {}
        }
        // Plan this tick's batch, per model.  Pass 1: ready streams that
        // already hold a lane ride for free (unless preempted below).
        let mut planned: Vec<Vec<(u64, usize)>> = vec![Vec::new(); nm];
        for &(id, m, _, _) in &ready {
            if let Some(lane) = inner.streams[&id].lane {
                planned[m].push((id, lane));
            }
        }
        // Pass 2: place lane-less ready streams in schedule order — a
        // free lane, else evict an idle holder, else preempt an active
        // holder that exhausted its quantum (or holds a lower QoS class).
        // A stream preempted *this tick* sits the tick out instead of
        // cascading (it could otherwise preempt another exhausted holder
        // later in the same pass — two state round trips where one
        // rotation sufficed); it re-enters as a normal waiter next tick.
        let mut displaced: Vec<u64> = Vec::new();
        for &(id, m, prio, _) in &ready {
            if inner.streams[&id].lane.is_some() || displaced.contains(&id) {
                continue;
            }
            // (a) a free lane in this model's allocator.
            let mut lane = inner.lanes[m].acquire();
            // (b) evict an idle holder (no pending frame ⇒ not in `ready`
            // ⇒ not planned this tick).  The lane changes hands without
            // passing through the allocator.
            if lane.is_none() {
                let victim = inner
                    .streams
                    .iter()
                    .find(|(_, vs)| vs.model == m && vs.lane.is_some() && vs.pending.is_empty())
                    .map(|(&vid, _)| vid);
                if let Some(vid) = victim {
                    let vslot = inner.streams.get_mut(&vid).unwrap();
                    let l = vslot.lane.take().unwrap();
                    vslot.parked = Some(models[m].save_lane(&arenas[m], l));
                    s.metrics.add_eviction(m);
                    lane = Some(l);
                }
            }
            // (c) preempt: every lane of this model is held by a stream
            // stepping this tick — take one from a holder past its
            // quantum (lowest class first, then most consumed quantum).
            // Parking happens at the tick boundary, before the victim's
            // next frame is popped, so the round trip is bit-exact.
            if lane.is_none() {
                let holders: Vec<HolderView> = planned[m]
                    .iter()
                    .map(|&(hid, hlane)| {
                        let hs = &inner.streams[&hid];
                        HolderView {
                            stream: hid,
                            priority: hs.priority,
                            quantum_used: hs.quantum_used,
                            tag: LaneTag { model: m, lane: hlane },
                        }
                    })
                    .collect();
                if let Some(vi) = s.config.quantum.select_victim(&holders, prio) {
                    let vid = holders[vi].stream;
                    let l = holders[vi].tag.lane;
                    let pos = planned[m]
                        .iter()
                        .position(|&(pid, _)| pid == vid)
                        .expect("victim came from planned");
                    planned[m].remove(pos);
                    let vslot = inner.streams.get_mut(&vid).unwrap();
                    vslot.lane = None;
                    vslot.quantum_used = 0;
                    vslot.parked = Some(models[m].save_lane(&arenas[m], l));
                    displaced.push(vid);
                    s.metrics.add_preemption(m);
                    lane = Some(l);
                }
            }
            // No free lane, no idle holder, nothing preemptible: this
            // stream keeps waiting — bounded by the quantum, since a
            // never-idle holder exhausts its quantum within quantum ticks.
            let Some(lane) = lane else { continue };
            let slot = inner.streams.get_mut(&id).unwrap();
            match slot.parked.take() {
                Some(p) => models[m].load_lane(&mut arenas[m], lane, &p),
                None => models[m].reset_lane(&mut arenas[m], lane),
            }
            slot.lane = Some(lane);
            slot.quantum_used = 0;
            planned[m].push((id, lane));
            debug_assert!(planned[m].len() <= max_lanes);
        }
        // Unreachable with max_batch > 0: the highest-priority ready
        // stream either holds a lane (⇒ planned), or a lane is free, or
        // some holder is idle, or every holder is an active planned
        // stream (⇒ planned non-empty).  If it ever happens, count it
        // loudly — a silent park here would hide scheduler regressions.
        if planned.iter().all(|p| p.is_empty()) {
            s.metrics.add_sched_stall();
            debug_assert!(
                false,
                "scheduler stall: {} ready streams but nothing placeable",
                ready.len()
            );
            let (guard, _t) = s
                .work_cv
                .wait_timeout(inner, Duration::from_millis(20))
                .unwrap();
            drop(guard);
            continue;
        }
        // Pop one frame per planned stream into its lane's input row, and
        // charge the tick against the holder's quantum.
        let mut enqueue_times: Vec<Vec<Option<Instant>>> = vec![Vec::new(); nm];
        let mut total_b = 0usize;
        let mut lanes_in_use_total = 0usize;
        for m in 0..nm {
            let d = dims[m];
            for &(id, lane) in &planned[m] {
                let slot = inner.streams.get_mut(&id).unwrap();
                let frame = slot.pending.pop_front().unwrap();
                xbufs[m][lane * d..(lane + 1) * d].copy_from_slice(&frame);
                enqueue_times[m].push(slot.oldest_enqueue);
                slot.oldest_enqueue =
                    if slot.pending.is_empty() { None } else { Some(now) };
                slot.quantum_used = slot.quantum_used.saturating_add(1);
            }
            total_b += planned[m].len();
            let in_use = inner.lanes[m].in_use();
            lanes_in_use_total += in_use;
            if !planned[m].is_empty() {
                s.metrics.record_model_tick(m, in_use, planned[m].len());
            }
        }
        s.metrics
            .lane_occupancy
            .record(lanes_in_use_total as f64 / (nm * max_lanes).max(1) as f64);
        drop(inner);
        s.space_cv.notify_all();

        // Batched AM step per model over its active lanes, in place
        // (lock-free; arenas are worker-local and lane rows belong to
        // planned streams).  Every model with planned lanes steps every
        // flush — a saturated model cannot monopolize the worker.
        let t0 = Instant::now();
        let mut any_failed = false;
        // Per-model step time: a model's frames are ready once *its* step
        // returns, so latency is charged per model, not the whole phase
        // (dt below) — two models stepping sequentially must not inflate
        // each other's frame_latency.
        let mut step_times: Vec<Duration> = vec![Duration::ZERO; nm];
        for m in 0..nm {
            if planned[m].is_empty() {
                continue;
            }
            let tm = Instant::now();
            let lanes_list: Vec<usize> = planned[m].iter().map(|&(_, l)| l).collect();
            if let Err(e) =
                models[m].step_lanes(&mut arenas[m], &lanes_list, &xbufs[m], &mut ybufs[m])
            {
                // Backend failure (only fallible for the PJRT path):
                // surface loudly, put the popped frames back at the head
                // of their queues (no silent truncation of posteriors),
                // and back off below so a persistently-dead backend
                // applies backpressure instead of busy-looping.
                eprintln!(
                    "am backend '{}' step failed: {e:#}",
                    models[m].backend_name()
                );
                let d = dims[m];
                let mut inner = s.inner.lock().unwrap();
                let now_err = Instant::now();
                for &(id, lane) in &planned[m] {
                    if let Some(slot) = inner.streams.get_mut(&id) {
                        slot.pending.push_front(xbufs[m][lane * d..(lane + 1) * d].to_vec());
                        slot.oldest_enqueue.get_or_insert(now_err);
                        slot.quantum_used = slot.quantum_used.saturating_sub(1);
                    }
                }
                drop(inner);
                planned[m].clear();
                any_failed = true;
            }
            step_times[m] = tm.elapsed();
        }
        let dt = t0.elapsed();
        let stepped: usize = planned.iter().map(|p| p.len()).sum();
        if stepped > 0 {
            s.metrics.add_am_compute(dt.as_secs_f64(), stepped as u64);
            s.metrics.batch_size.record(total_b as f64);
        }
        if any_failed && stepped == 0 {
            let mut inner = s.inner.lock().unwrap();
            drain_finished(&mut inner, &s);
            drop(inner);
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }

        // Append each lane's posteriors to its stream; queue decodes for
        // drained finished streams.  (This is result delivery, not state
        // movement — recurrent state stayed in the arena.)
        let mut inner = s.inner.lock().unwrap();
        for m in 0..nm {
            let l = labels[m];
            for (k, &(id, lane)) in planned[m].iter().enumerate() {
                if let Some(slot) = inner.streams.get_mut(&id) {
                    if slot.frames_done == 0 {
                        s.metrics
                            .first_frame_latency
                            .record_duration(slot.opened_at.elapsed());
                    }
                    slot.posteriors
                        .extend_from_slice(&ybufs[m][lane * l..(lane + 1) * l]);
                    slot.frames_done += 1;
                }
                if let Some(t0q) = enqueue_times[m][k] {
                    s.metrics.frame_latency.record_duration(now - t0q + step_times[m]);
                }
            }
        }
        drain_finished(&mut inner, &s);
    }
}

/// Move every (finished && drained) stream to the decode queue, releasing
/// its arena lane to its model's allocator.
fn drain_finished<B: AmBackend>(inner: &mut Inner<B>, s: &Shared<B>) {
    let done: Vec<u64> = inner
        .streams
        .iter()
        .filter(|(_, sl)| sl.finished && sl.pending.is_empty())
        .map(|(&id, _)| id)
        .collect();
    for id in done {
        let slot = inner.streams.remove(&id).unwrap();
        if let Some(lane) = slot.lane {
            inner.lanes[slot.model].release(lane);
        }
        inner.decode_queue.push_back(DecodeJob {
            stream_id: id,
            posteriors: slot.posteriors,
            num_frames: slot.frames_done,
            finish_time: slot.finish_time.unwrap_or_else(Instant::now),
            result_tx: slot.result_tx,
        });
        s.decode_cv.notify_one();
    }
}

fn decode_worker<B: AmBackend>(s: Arc<Shared<B>>, decoder: Arc<Decoder>) {
    loop {
        let job = {
            let mut inner = s.inner.lock().unwrap();
            loop {
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(job) = inner.decode_queue.pop_front() {
                    break job;
                }
                let (guard, _t) = s
                    .decode_cv
                    .wait_timeout(inner, Duration::from_millis(20))
                    .unwrap();
                inner = guard;
            }
        };
        let labels = job.posteriors.len() / job.num_frames.max(1);
        let hyp = decoder.decode(&job.posteriors, labels.max(1));
        let phones = crate::decoder::ctc::greedy(&job.posteriors, labels.max(1));
        s.metrics.add_utterance();
        let latency = job.finish_time.elapsed();
        s.metrics.finalize_latency.record_duration(latency);
        let _ = job.result_tx.send(FinalResult {
            stream_id: job.stream_id,
            words: hyp.words,
            phones,
            num_frames: job.num_frames,
            finalize_latency: latency,
        });
    }
}
