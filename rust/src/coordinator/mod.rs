//! The streaming ASR serving coordinator (L3).
//!
//! The paper targets *embedded* recognition — one utterance, lowest
//! latency/power — but its quantized engine is exactly what a server-side
//! deployment batches across streams.  This module provides both shapes:
//! single-stream synchronous decoding (embedded, see [`crate::eval`]) and a
//! thread-based streaming server with **lane-resident cross-stream
//! batching**: each live stream owns a stable lane in its model's
//! pre-allocated [`crate::nn::model::BatchArena`], and every
//! deadline-bounded tick steps the active lanes in place — recurrent state
//! never moves between per-stream and batch buffers.  The engine is
//! generic over [`crate::runtime::AmBackend`], so the native int8 engine
//! and the PJRT/AOT graph (feature `pjrt`) serve through the same spine.
//!
//! Lane-placement *policy* lives in [`crate::sched`]: time-sliced quantum
//! preemption (no stream can starve newcomers under saturation), QoS
//! priority classes, bounded admission with reject-with-reason
//! backpressure, a *dynamic* multi-model registry (models hot-load and
//! drain out at runtime without a restart), and weighted per-model tick
//! bandwidth for heterogeneous fleets.  The system-level map is drawn in
//! `docs/ARCHITECTURE.md`; the wire protocol is specified in
//! `docs/PROTOCOL.md`.
//!
//! - [`batcher`] — flush policy, priority-aware batch-formation order,
//!   lane allocator, QoS-class queue (pure, property-tested).
//! - [`engine`]  — streams, lane scheduling mechanism, hot model
//!   load/unload, workers, lifecycle.
//! - [`metrics`] — latency/throughput/occupancy + per-model accounting
//!   across load/unload churn.
//! - [`server`]  — length-prefixed TCP protocol (QoS class, model
//!   selection, admission rejects, admin frames) + client helper.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{Engine, EngineConfig, FinalResult, ModelInfo, StreamEnd};
