//! The streaming ASR serving coordinator (L3).
//!
//! The paper targets *embedded* recognition — one utterance, lowest
//! latency/power — but its quantized engine is exactly what a server-side
//! deployment batches across streams.  This module provides both shapes:
//! single-stream synchronous decoding (embedded, see [`crate::eval`]) and a
//! thread-based streaming server with **lane-resident cross-stream
//! batching**: each live stream owns a stable lane in the execution
//! backend's pre-allocated [`crate::nn::model::BatchArena`], and every
//! deadline-bounded tick steps the active lanes in place — recurrent state
//! never moves between per-stream and batch buffers.  The engine is
//! generic over [`crate::runtime::AmBackend`], so the native int8 engine
//! and the PJRT/AOT graph (feature `pjrt`) serve through the same spine.
//!
//! - [`batcher`] — flush policy + lane allocator (pure, property-tested).
//! - [`engine`]  — streams, lane scheduling/eviction, workers, lifecycle.
//! - [`metrics`] — latency/throughput/occupancy instrumentation.
//! - [`server`]  — length-prefixed TCP protocol + client helper.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{Engine, EngineConfig, FinalResult};
