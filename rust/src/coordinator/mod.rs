//! The streaming ASR serving coordinator (L3).
//!
//! The paper targets *embedded* recognition — one utterance, lowest
//! latency/power — but its quantized engine is exactly what a server-side
//! deployment batches across streams.  This module provides both shapes:
//! single-stream synchronous decoding (embedded, see [`crate::eval`]) and a
//! thread-based streaming server with **lane-resident cross-stream
//! batching**: each live stream owns a stable lane in its model's
//! pre-allocated [`crate::nn::model::BatchArena`], and every
//! deadline-bounded tick steps the active lanes in place — recurrent state
//! never moves between per-stream and batch buffers.  The engine is
//! generic over [`crate::runtime::AmBackend`], so the native int8 engine
//! and the PJRT/AOT graph (feature `pjrt`) serve through the same spine.
//!
//! Lane-placement *policy* lives in [`crate::sched`]: time-sliced quantum
//! preemption (no stream can starve newcomers under saturation), QoS
//! priority classes, bounded admission with reject-with-reason
//! backpressure, and a multi-model registry so one engine process serves
//! N loaded models with per-model lane accounting.
//!
//! - [`batcher`] — flush policy, priority-aware batch-formation order,
//!   lane allocator (pure, property-tested).
//! - [`engine`]  — streams, lane scheduling mechanism, workers, lifecycle.
//! - [`metrics`] — latency/throughput/occupancy + per-model accounting.
//! - [`server`]  — length-prefixed TCP protocol (QoS class, admission
//!   rejects) + client helper.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{Engine, EngineConfig, FinalResult};
