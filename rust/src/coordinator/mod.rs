//! The streaming ASR serving coordinator (L3).
//!
//! The paper targets *embedded* recognition — one utterance, lowest
//! latency/power — but its quantized engine is exactly what a server-side
//! deployment batches across streams.  This module provides both shapes:
//! single-stream synchronous decoding (embedded, see [`crate::eval`]) and a
//! thread-based streaming server with **cross-stream dynamic batching**:
//! frames from concurrent streams are gathered each tick into one batched
//! acoustic-model step (deadline-bounded), then scattered back to
//! per-stream decoders.
//!
//! - [`batcher`] — the flush policy (pure logic, property-tested).
//! - [`engine`]  — streams, state packing, workers, lifecycle.
//! - [`metrics`] — latency/throughput instrumentation.
//! - [`server`]  — length-prefixed TCP protocol + client helper.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{Engine, EngineConfig, FinalResult};
