//! Serving metrics: latency percentiles, throughput, real-time factor.

use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Log-bucket growth factor: 2^(1/4), four buckets per octave.  A
/// percentile read off the buckets is at most one bucket width above the
/// exact sample — ≤ 19% relative error for O(1) memory.
pub const GROWTH: f64 = 1.189_207_115_002_721;
/// Smallest finite bucket upper bound (everything at or below lands in
/// the first bucket).  In ms this spans sub-µs ticks…
const LO: f64 = 1e-3;
/// …up to 100-second outliers; beyond that is the +Inf overflow bucket.
const HI: f64 = 1e5;

/// Shared bucket upper bounds: LO·GROWTH^i until ≥ HI (~108 bounds).
/// One static table serves every histogram in the process.
fn bucket_bounds() -> &'static [f64] {
    static B: OnceLock<Vec<f64>> = OnceLock::new();
    B.get_or_init(|| {
        let mut v = vec![LO];
        while *v.last().unwrap() < HI {
            let next = v.last().unwrap() * GROWTH;
            v.push(next);
        }
        v
    })
}

#[derive(Default)]
struct HistInner {
    /// `bucket_bounds().len() + 1` slots; the last is the +Inf overflow
    /// bucket.  Allocated on first record, fixed-size after.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Bounded log-bucketed histogram: memory is O(1) in the number of
/// observations (a fixed ~109-slot count table), count/sum/min/max are
/// exact, and percentiles are read from bucket upper bounds with at most
/// one bucket width (factor [`GROWTH`]) of error.  Replaces the seed's
/// exact-sample histogram, which kept every observation in a `Vec` and
/// grew without bound over long serving runs.
#[derive(Default)]
pub struct Histogram {
    inner: Mutex<HistInner>,
}

impl Histogram {
    pub fn record(&self, v: f64) {
        let b = bucket_bounds();
        // First bound ≥ v; b.len() means the +Inf overflow slot.
        let idx = b.partition_point(|&ub| ub < v);
        let mut h = self.inner.lock().unwrap();
        if h.counts.is_empty() {
            h.counts = vec![0; b.len() + 1];
        }
        h.counts[idx] += 1;
        if h.count == 0 {
            h.min = v;
            h.max = v;
        } else {
            h.min = h.min.min(v);
            h.max = h.max.max(v);
        }
        h.count += 1;
        h.sum += v;
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64() * 1e3); // ms
    }

    /// Cumulative `(upper_bound, count ≤ upper_bound)` pairs for the
    /// non-empty finite buckets, plus the exact total count and sum —
    /// what the Prometheus histogram exposition emits.
    pub fn cumulative(&self) -> (Vec<(f64, u64)>, u64, f64) {
        let b = bucket_bounds();
        let h = self.inner.lock().unwrap();
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in h.counts.iter().enumerate() {
            if i == b.len() {
                break; // overflow is the +Inf line, emitted from `count`
            }
            if c > 0 {
                cum += c;
                out.push((b[i], cum));
            }
        }
        (out, h.count, h.sum)
    }

    pub fn summary(&self) -> HistSummary {
        let b = bucket_bounds();
        let h = self.inner.lock().unwrap();
        if h.count == 0 {
            return HistSummary::default();
        }
        // Same rank the seed's exact histogram took from its sorted
        // samples; the value is the containing bucket's upper bound,
        // clamped to the observed [min, max] (which also keeps the
        // underflow bucket honest for ≤ 0 samples).
        let pct = |p: f64| {
            let rank = ((h.count as f64 * p) as u64).min(h.count - 1);
            let mut cum = 0u64;
            for (i, &c) in h.counts.iter().enumerate() {
                cum += c;
                if cum > rank {
                    return if i == b.len() { h.max } else { b[i].clamp(h.min, h.max) };
                }
            }
            h.max
        };
        HistSummary {
            count: h.count as usize,
            mean: h.sum / h.count as f64,
            p50: pct(0.50),
            p90: pct(0.90),
            p99: pct(0.99),
            max: h.max,
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct HistSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl HistSummary {
    pub fn fmt_ms(&self, name: &str) -> String {
        format!(
            "{name:<22} n={:<5} mean={:7.2}ms p50={:7.2}ms p90={:7.2}ms p99={:7.2}ms max={:7.2}ms",
            self.count, self.mean, self.p50, self.p90, self.p99, self.max
        )
    }
}

/// Per-model serving counters (one entry per model *slot*, index = model
/// id).  Slots are dynamic: a hot load resets its slot's row, a hot
/// unload retires it (`loaded = false`, live accounting back at zero) —
/// a reused slot never inherits a dead model's numbers.
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    pub name: String,
    /// Lanes in this model's arena.
    pub max_lanes: usize,
    /// DRR tick-bandwidth weight ([`crate::sched::weights`]).
    pub weight: u32,
    /// False once the model has been unloaded (row kept for postmortem
    /// until the slot is reused).
    pub loaded: bool,
    /// AM frames computed for this model.
    pub frames: u64,
    /// Flush ticks in which this model stepped at least one lane.
    pub ticks: u64,
    /// Sum over those ticks of lanes in use (holders, idle included).
    pub lanes_in_use_sum: u64,
    /// Idle holders parked to admit waiting streams.
    pub evictions: u64,
    /// Active holders preempted at a quantum boundary.
    pub preemptions: u64,
    /// Planned lane-steps deferred to a later tick by the weighted
    /// budget (demand the DRR grant didn't cover this tick).
    pub deferrals: u64,
    /// Surviving streams cancelled by an expired force-unload deadline.
    pub forced_cancels: u64,
    /// Bulk streams cancelled by brownout load shedding.
    pub shed_streams: u64,
    /// Resident arena bytes (what the budget ledger charged for the
    /// arena; 0 after unload teardown).
    pub arena_bytes: u64,
    /// Reserved stream bytes (live streams × one parked blob each).
    pub reserved_bytes: u64,
    /// Bytes actually sitting in parked blobs right now.
    pub parked_bytes: u64,
    /// Poisoned by a backend panic (cleared when the slot is reused).
    pub quarantined: bool,
}

impl ModelStats {
    /// Mean lane occupancy over the ticks this model stepped.
    pub fn occupancy(&self) -> f64 {
        if self.ticks == 0 || self.max_lanes == 0 {
            return 0.0;
        }
        self.lanes_in_use_sum as f64 / (self.ticks as f64 * self.max_lanes as f64)
    }
}

/// Engine-wide counters + histograms.
#[derive(Default)]
pub struct Metrics {
    /// end-to-end: stream finish requested → final result ready (ms)
    pub finalize_latency: Histogram,
    /// per-frame: frame ready → logits produced (ms)
    pub frame_latency: Histogram,
    /// stream admitted → its first posterior frame computed (ms)
    pub first_frame_latency: Histogram,
    /// batched-step batch sizes
    pub batch_size: Histogram,
    /// arena lane occupancy at each flush (lanes in use / lanes total)
    pub lane_occupancy: Histogram,
    /// audio seconds processed
    pub audio_seconds: Mutex<f64>,
    /// wall seconds of AM compute
    pub am_compute_seconds: Mutex<f64>,
    /// wall seconds spent in final decodes (CTC beam + LM rescore)
    pub decode_seconds: Mutex<f64>,
    /// wall seconds spent in the frontend (PCM → feature frames)
    pub frontend_seconds: Mutex<f64>,
    /// effective tick quantum (fixed config, or the auto-tuned value the
    /// AM worker derived from its measured tick rate; 0 = not yet set)
    pub effective_quantum: Mutex<u32>,
    pub frames_processed: Mutex<u64>,
    pub utterances: Mutex<u64>,
    /// idle streams parked out of the arena to admit waiting streams
    pub evictions: Mutex<u64>,
    /// active streams preempted at a quantum boundary (sched::quantum)
    pub preemptions: Mutex<u64>,
    /// streams refused admission (sched::admission backpressure)
    pub admission_rejects: Mutex<u64>,
    /// models hot-loaded into the registry (boot models included)
    pub model_loads: Mutex<u64>,
    /// models drained out and torn down
    pub model_unloads: Mutex<u64>,
    /// flush ticks where ready streams existed but none could be placed —
    /// a scheduler invariant violation (debug builds also assert)
    pub sched_stalls: Mutex<u64>,
    /// streams cancelled by the lifetime reaper (idle timeout or
    /// utterance deadline)
    pub reaped_streams: Mutex<u64>,
    /// streams cancelled by an expired force-unload deadline (sum of the
    /// per-model rows)
    pub forced_cancels: Mutex<u64>,
    /// panics quarantined instead of taking the engine down (decode jobs
    /// + backend steps)
    pub quarantined_jobs: Mutex<u64>,
    /// admissions refused for memory pressure (budget ledger full)
    pub mem_pressure_rejects: Mutex<u64>,
    /// admissions refused while the engine was in brownout
    pub brownout_rejects: Mutex<u64>,
    /// times the AM worker entered brownout (sustained deadline overrun)
    pub brownout_entries: Mutex<u64>,
    /// times the AM worker recovered from brownout
    pub brownout_exits: Mutex<u64>,
    /// Bulk streams cancelled by brownout load shedding (sum of the
    /// per-model rows)
    pub shed_streams: Mutex<u64>,
    /// completed zero-downtime model swaps (canary passed, table flipped)
    pub model_swaps: Mutex<u64>,
    /// swaps rolled back because the replacement's canary failed
    pub swap_rollbacks: Mutex<u64>,
    /// configured byte budget (0 = unlimited) — gauge for the exposition
    pub budget_bytes: Mutex<u64>,
    /// per-model lane accounting (index = model id)
    pub per_model: Mutex<Vec<ModelStats>>,
}

impl Metrics {
    /// Install (or reset) the stat row for model slot `id` — called at
    /// engine start for boot models and on every hot load.  Resetting on
    /// load is what makes "metrics return to zero after unload"
    /// observable: a reused slot starts a fresh row.
    pub fn set_model(&self, id: usize, name: &str, max_lanes: usize, weight: u32) {
        let mut pm = self.per_model.lock().unwrap();
        if pm.len() <= id {
            pm.resize_with(id + 1, ModelStats::default);
        }
        pm[id] = ModelStats {
            name: name.to_string(),
            max_lanes,
            weight,
            loaded: true,
            ..Default::default()
        };
        *self.model_loads.lock().unwrap() += 1;
    }

    /// Retire model slot `id` after its unload drain completes: the row
    /// stays visible for postmortem but reads as not loaded.
    pub fn retire_model(&self, id: usize) {
        if let Some(m) = self.per_model.lock().unwrap().get_mut(id) {
            m.loaded = false;
        }
        *self.model_unloads.lock().unwrap() += 1;
    }

    pub fn add_audio(&self, secs: f64) {
        *self.audio_seconds.lock().unwrap() += secs;
    }

    pub fn add_am_compute(&self, secs: f64, frames: u64) {
        *self.am_compute_seconds.lock().unwrap() += secs;
        *self.frames_processed.lock().unwrap() += frames;
    }

    pub fn add_decode_compute(&self, secs: f64) {
        *self.decode_seconds.lock().unwrap() += secs;
    }

    pub fn add_frontend_compute(&self, secs: f64) {
        *self.frontend_seconds.lock().unwrap() += secs;
    }

    /// Record the quantum the AM worker actually runs (config value, or
    /// the auto-tuned one once measurement completes).
    pub fn set_effective_quantum(&self, q: u32) {
        *self.effective_quantum.lock().unwrap() = q;
    }

    /// Wall seconds per tick stage: (AM step, decode, frontend).  The
    /// stages run on different threads, so shares are of summed stage
    /// time, not of wall clock.
    pub fn tick_breakdown(&self) -> (f64, f64, f64) {
        (
            *self.am_compute_seconds.lock().unwrap(),
            *self.decode_seconds.lock().unwrap(),
            *self.frontend_seconds.lock().unwrap(),
        )
    }

    pub fn add_utterance(&self) {
        *self.utterances.lock().unwrap() += 1;
    }

    pub fn add_eviction(&self, model: usize) {
        *self.evictions.lock().unwrap() += 1;
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.evictions += 1;
        }
    }

    pub fn add_preemption(&self, model: usize) {
        *self.preemptions.lock().unwrap() += 1;
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.preemptions += 1;
        }
    }

    pub fn add_admission_reject(&self) {
        *self.admission_rejects.lock().unwrap() += 1;
    }

    pub fn add_sched_stall(&self) {
        *self.sched_stalls.lock().unwrap() += 1;
    }

    /// One stream cancelled by the lifetime reaper.
    pub fn add_reaped(&self) {
        *self.reaped_streams.lock().unwrap() += 1;
    }

    /// One surviving stream of `model` cancelled by an expired
    /// force-unload deadline.
    pub fn add_forced_cancel(&self, model: usize) {
        *self.forced_cancels.lock().unwrap() += 1;
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.forced_cancels += 1;
        }
    }

    /// One panic caught and quarantined (a decode job failed alone, or a
    /// backend step poisoned its model slot) instead of killing the
    /// engine.
    pub fn add_quarantined_job(&self) {
        *self.quarantined_jobs.lock().unwrap() += 1;
    }

    /// Mark `model`'s row quarantined after a backend panic.  Cleared by
    /// the next [`Metrics::set_model`] into the slot.
    pub fn set_quarantined(&self, model: usize) {
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.quarantined = true;
        }
    }

    /// One admission refused for memory pressure.
    pub fn add_mem_pressure_reject(&self) {
        *self.mem_pressure_rejects.lock().unwrap() += 1;
        *self.admission_rejects.lock().unwrap() += 1;
    }

    /// One admission refused while the engine was in brownout.
    pub fn add_brownout_reject(&self) {
        *self.brownout_rejects.lock().unwrap() += 1;
        *self.admission_rejects.lock().unwrap() += 1;
    }

    /// The AM worker entered (`true`) or recovered from (`false`)
    /// brownout.
    pub fn brownout_transition(&self, entering: bool) {
        if entering {
            *self.brownout_entries.lock().unwrap() += 1;
        } else {
            *self.brownout_exits.lock().unwrap() += 1;
        }
    }

    /// One Bulk stream of `model` cancelled by brownout load shedding.
    pub fn add_shed(&self, model: usize) {
        *self.shed_streams.lock().unwrap() += 1;
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.shed_streams += 1;
        }
    }

    /// One zero-downtime swap completed (`rolled_back = false`) or
    /// rolled back on canary failure (`rolled_back = true`).
    pub fn add_swap(&self, rolled_back: bool) {
        if rolled_back {
            *self.swap_rollbacks.lock().unwrap() += 1;
        } else {
            *self.model_swaps.lock().unwrap() += 1;
        }
    }

    /// Publish the byte-ledger view of model `model` (what the budget
    /// sees: arena residency, stream reservations, actual parked blobs).
    pub fn set_model_bytes(&self, model: usize, arena: usize, reserved: usize, parked: usize) {
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.arena_bytes = arena as u64;
            m.reserved_bytes = reserved as u64;
            m.parked_bytes = parked as u64;
        }
    }

    /// Publish the configured byte budget (0 = unlimited).
    pub fn set_budget_bytes(&self, budget: usize) {
        *self.budget_bytes.lock().unwrap() = budget as u64;
    }

    /// Record lane-steps model `model` had planned but the weighted
    /// per-tick budget deferred (sched::weights DRR trim).
    pub fn add_deferrals(&self, model: usize, n: usize) {
        if n == 0 {
            return;
        }
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.deferrals += n as u64;
        }
    }

    /// Record one flush tick for `model`: `lanes_in_use` holders (idle
    /// included), `frames` lanes actually stepped.
    pub fn record_model_tick(&self, model: usize, lanes_in_use: usize, frames: usize) {
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.ticks += 1;
            m.lanes_in_use_sum += lanes_in_use as u64;
            m.frames += frames as u64;
        }
    }

    /// Real-time factor of the AM stage: compute seconds per audio second
    /// (< 1 means faster than real time).
    pub fn rtf(&self) -> f64 {
        let audio = *self.audio_seconds.lock().unwrap();
        let compute = *self.am_compute_seconds.lock().unwrap();
        if audio <= 0.0 {
            return 0.0;
        }
        compute / audio
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.finalize_latency.summary().fmt_ms("finalize_latency"));
        out.push('\n');
        out.push_str(&self.frame_latency.summary().fmt_ms("frame_latency"));
        out.push('\n');
        out.push_str(&self.first_frame_latency.summary().fmt_ms("first_frame_latency"));
        out.push('\n');
        let bs = self.batch_size.summary();
        out.push_str(&format!(
            "batch_size             n={:<5} mean={:5.2}  p50={:4.0}  p99={:4.0}\n",
            bs.count, bs.mean, bs.p50, bs.p99
        ));
        let lo = self.lane_occupancy.summary();
        out.push_str(&format!(
            "lane_occupancy         n={:<5} mean={:5.2}  p50={:4.2}  p99={:4.2}\n",
            lo.count, lo.mean, lo.p50, lo.p99
        ));
        // Take each value before formatting: std::sync::Mutex is not
        // reentrant, and rtf() locks two of these again.
        let utts = *self.utterances.lock().unwrap();
        let frames = *self.frames_processed.lock().unwrap();
        let audio = *self.audio_seconds.lock().unwrap();
        let compute = *self.am_compute_seconds.lock().unwrap();
        let evictions = *self.evictions.lock().unwrap();
        let preemptions = *self.preemptions.lock().unwrap();
        let rejects = *self.admission_rejects.lock().unwrap();
        let stalls = *self.sched_stalls.lock().unwrap();
        let reaped = *self.reaped_streams.lock().unwrap();
        let forced = *self.forced_cancels.lock().unwrap();
        let quarantined = *self.quarantined_jobs.lock().unwrap();
        let loads = *self.model_loads.lock().unwrap();
        let unloads = *self.model_unloads.lock().unwrap();
        let decode = *self.decode_seconds.lock().unwrap();
        let frontend = *self.frontend_seconds.lock().unwrap();
        let equantum = *self.effective_quantum.lock().unwrap();
        let rtf = if audio > 0.0 { compute / audio } else { 0.0 };
        out.push_str(&format!(
            "utterances={utts}  frames={frames}  audio={audio:.1}s  \
             am_compute={compute:.2}s  RTF={rtf:.4}  evictions={evictions}\n",
        ));
        let stages = compute + decode + frontend;
        if stages > 0.0 {
            out.push_str(&format!(
                "tick_breakdown: am={compute:.3}s ({:.0}%)  decode={decode:.3}s ({:.0}%)  \
                 frontend={frontend:.3}s ({:.0}%)\n",
                100.0 * compute / stages,
                100.0 * decode / stages,
                100.0 * frontend / stages,
            ));
        }
        out.push_str(&format!(
            "preemptions={preemptions}  admission_rejects={rejects}  sched_stalls={stalls}  \
             model_loads={loads}  model_unloads={unloads}  effective_quantum={equantum}\n",
        ));
        out.push_str(&format!(
            "reaped_streams={reaped}  forced_cancels={forced}  quarantined_jobs={quarantined}\n",
        ));
        let shed = *self.shed_streams.lock().unwrap();
        let b_in = *self.brownout_entries.lock().unwrap();
        let b_out = *self.brownout_exits.lock().unwrap();
        let b_rej = *self.brownout_rejects.lock().unwrap();
        let mp = *self.mem_pressure_rejects.lock().unwrap();
        let swaps = *self.model_swaps.lock().unwrap();
        let rollbacks = *self.swap_rollbacks.lock().unwrap();
        let budget = *self.budget_bytes.lock().unwrap();
        let pm = self.per_model.lock().unwrap();
        let resident: u64 = pm.iter().map(|m| m.arena_bytes + m.reserved_bytes).sum();
        out.push_str(&format!(
            "shed_streams={shed}  brownout_entries={b_in}  brownout_exits={b_out}  \
             brownout_rejects={b_rej}  mem_pressure_rejects={mp}\n",
        ));
        out.push_str(&format!(
            "model_swaps={swaps}  swap_rollbacks={rollbacks}  \
             resident_bytes={resident}  budget_bytes={budget}\n",
        ));
        if pm.len() > 1 || pm.iter().any(|m| m.preemptions + m.evictions > 0) {
            for (id, m) in pm.iter().enumerate() {
                out.push_str(&format!(
                    "model[{id}] {:<14} {} w={} lanes={} frames={} ticks={} occupancy={:.2} \
                     evictions={} preemptions={} deferrals={} forced_cancels={} sheds={} \
                     arena_bytes={} parked_bytes={}\n",
                    m.name,
                    if m.quarantined && m.loaded {
                        "quarantined"
                    } else if m.loaded {
                        "loaded"
                    } else {
                        "retired"
                    },
                    m.weight,
                    m.max_lanes,
                    m.frames,
                    m.ticks,
                    m.occupancy(),
                    m.evictions,
                    m.preemptions,
                    m.deferrals,
                    m.forced_cancels,
                    m.shed_streams,
                    m.arena_bytes,
                    m.parked_bytes,
                ));
            }
        }
        out
    }

    /// Prometheus text-exposition dump (`text/plain; version=0.0.4`):
    /// every engine-wide counter/gauge plus the per-model rows with
    /// `model`/`name` labels.  Served verbatim by the TCP `'T'` admin
    /// frame (see `docs/PROTOCOL.md`) so a sidecar can scrape-and-relay
    /// without parsing the human report.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP quantasr_{name} {help}\n# TYPE quantasr_{name} counter\nquantasr_{name} {v}\n"
            ));
        };
        counter(
            "frames_processed_total",
            "AM frames computed",
            *self.frames_processed.lock().unwrap(),
        );
        counter("utterances_total", "utterances finalized", *self.utterances.lock().unwrap());
        counter("evictions_total", "idle holders parked", *self.evictions.lock().unwrap());
        counter(
            "preemptions_total",
            "holders preempted at a quantum boundary",
            *self.preemptions.lock().unwrap(),
        );
        counter(
            "admission_rejects_total",
            "streams refused admission",
            *self.admission_rejects.lock().unwrap(),
        );
        counter(
            "mem_pressure_rejects_total",
            "admissions refused for memory pressure",
            *self.mem_pressure_rejects.lock().unwrap(),
        );
        counter(
            "brownout_rejects_total",
            "admissions refused during brownout",
            *self.brownout_rejects.lock().unwrap(),
        );
        counter(
            "brownout_entries_total",
            "brownout entries (sustained tick-deadline overrun)",
            *self.brownout_entries.lock().unwrap(),
        );
        counter(
            "brownout_exits_total",
            "brownout recoveries",
            *self.brownout_exits.lock().unwrap(),
        );
        counter(
            "shed_streams_total",
            "Bulk streams cancelled by brownout shedding",
            *self.shed_streams.lock().unwrap(),
        );
        counter(
            "model_loads_total",
            "models hot-loaded (boot included)",
            *self.model_loads.lock().unwrap(),
        );
        counter(
            "model_unloads_total",
            "models drained out and torn down",
            *self.model_unloads.lock().unwrap(),
        );
        counter(
            "model_swaps_total",
            "zero-downtime swaps completed",
            *self.model_swaps.lock().unwrap(),
        );
        counter(
            "swap_rollbacks_total",
            "swaps rolled back on canary failure",
            *self.swap_rollbacks.lock().unwrap(),
        );
        counter(
            "reaped_streams_total",
            "streams cancelled by the lifetime reaper",
            *self.reaped_streams.lock().unwrap(),
        );
        counter(
            "forced_cancels_total",
            "streams cancelled by force-unload deadlines",
            *self.forced_cancels.lock().unwrap(),
        );
        counter(
            "quarantined_jobs_total",
            "panics quarantined instead of fatal",
            *self.quarantined_jobs.lock().unwrap(),
        );
        counter(
            "sched_stalls_total",
            "flush ticks with ready streams but no placement",
            *self.sched_stalls.lock().unwrap(),
        );
        let mut gauge = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP quantasr_{name} {help}\n# TYPE quantasr_{name} gauge\nquantasr_{name} {v}\n"
            ));
        };
        let pm_snapshot = self.per_model.lock().unwrap().clone();
        let resident: u64 =
            pm_snapshot.iter().map(|m| m.arena_bytes + m.reserved_bytes).sum();
        gauge("resident_bytes", "bytes the budget ledger counts resident", resident as f64);
        gauge(
            "budget_bytes",
            "configured byte budget (0 = unlimited)",
            *self.budget_bytes.lock().unwrap() as f64,
        );
        gauge(
            "effective_quantum_ticks",
            "tick quantum in effect (config or auto-tuned)",
            *self.effective_quantum.lock().unwrap() as f64,
        );
        gauge("audio_seconds", "audio seconds processed", *self.audio_seconds.lock().unwrap());
        gauge(
            "am_compute_seconds",
            "wall seconds of AM compute",
            *self.am_compute_seconds.lock().unwrap(),
        );
        gauge(
            "decode_seconds",
            "wall seconds of final decode",
            *self.decode_seconds.lock().unwrap(),
        );
        gauge(
            "frontend_seconds",
            "wall seconds of frontend",
            *self.frontend_seconds.lock().unwrap(),
        );
        // Latency histograms as Prometheus histograms: cumulative
        // `_bucket{le=}` lines for the non-empty log buckets, then the
        // +Inf bucket, exact sum, and exact count.
        for (name, h) in [
            ("finalize_latency_ms", &self.finalize_latency),
            ("frame_latency_ms", &self.frame_latency),
            ("first_frame_latency_ms", &self.first_frame_latency),
        ] {
            let (cum, count, sum) = h.cumulative();
            out.push_str(&format!(
                "# HELP quantasr_{name} latency histogram\n# TYPE quantasr_{name} histogram\n"
            ));
            for (le, c) in cum {
                out.push_str(&format!("quantasr_{name}_bucket{{le=\"{le}\"}} {c}\n"));
            }
            out.push_str(&format!("quantasr_{name}_bucket{{le=\"+Inf\"}} {count}\n"));
            out.push_str(&format!("quantasr_{name}_sum {sum}\n"));
            out.push_str(&format!("quantasr_{name}_count {count}\n"));
        }
        // Per-model rows, labelled by slot id + model name.
        let mut per_model = |name: &str, help: &str, f: &dyn Fn(&ModelStats) -> f64| {
            out.push_str(&format!(
                "# HELP quantasr_model_{name} {help}\n# TYPE quantasr_model_{name} gauge\n"
            ));
            for (id, m) in pm_snapshot.iter().enumerate() {
                out.push_str(&format!(
                    "quantasr_model_{name}{{model=\"{id}\",name=\"{}\"}} {}\n",
                    m.name.replace('"', "_"),
                    f(m)
                ));
            }
        };
        per_model("loaded", "1 if the slot is serving", &|m| u64::from(m.loaded) as f64);
        per_model("frames_total", "AM frames computed", &|m| m.frames as f64);
        per_model("lanes", "arena lanes", &|m| m.max_lanes as f64);
        per_model("occupancy", "mean lane occupancy", &|m| m.occupancy());
        per_model("evictions_total", "idle holders parked", &|m| m.evictions as f64);
        per_model("preemptions_total", "quantum preemptions", &|m| m.preemptions as f64);
        per_model("shed_streams_total", "brownout sheds", &|m| m.shed_streams as f64);
        per_model("arena_bytes", "resident arena bytes", &|m| m.arena_bytes as f64);
        per_model("reserved_bytes", "reserved stream bytes", &|m| m.reserved_bytes as f64);
        per_model("parked_bytes", "bytes in parked blobs", &|m| m.parked_bytes as f64);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::default();
        for i in 0..100 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, 99.0);
        assert!((s.mean - 49.5).abs() < 1e-9);
        // Log buckets: each percentile within one bucket width (factor
        // GROWTH) above the exact order statistic.
        assert!(s.p50 >= 50.0 && s.p50 <= 50.0 * GROWTH, "p50={}", s.p50);
        assert!(s.p99 >= 99.0 && s.p99 <= 99.0 * GROWTH, "p99={}", s.p99);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.max, 0.0);
        assert_eq!(h.cumulative(), (vec![], 0, 0.0));
    }

    #[test]
    fn histogram_memory_is_bounded_and_extremes_exact() {
        // The O(1)-memory contract: count/sum/min/max stay exact while
        // the bucket table never grows, whatever lands in it — zeros,
        // negatives, and +Inf-bucket outliers included.
        let h = Histogram::default();
        for i in 0..10_000 {
            h.record(i as f64 * 0.013 - 2.0);
        }
        h.record(1e9); // overflow bucket
        let s = h.summary();
        assert_eq!(s.count, 10_001);
        assert_eq!(s.max, 1e9);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        let inner = h.inner.lock().unwrap();
        assert_eq!(inner.counts.len(), bucket_bounds().len() + 1);
        assert_eq!(inner.counts.iter().sum::<u64>(), 10_001);
        assert_eq!(inner.min, -2.0);
    }

    #[test]
    fn bucketed_percentiles_track_exact_reference() {
        // Property: against the seed's exact sorted-sample percentile,
        // every bucketed percentile is within one bucket width —
        // exact ≤ bucketed ≤ exact × GROWTH — and count/mean/max are
        // exact.  Samples span the finite bucket range.
        crate::util::prop::forall("histogram vs exact reference", 60, 0xB0C4E7, |g| {
            let n = g.usize_in(1, 400);
            let h = Histogram::default();
            let mut samples = Vec::with_capacity(n);
            for _ in 0..n {
                let v = 10f64.powf(g.f64_in(-2.5, 4.5));
                samples.push(v);
                h.record(v);
            }
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let exact = |p: f64| sorted[((n as f64 * p) as usize).min(n - 1)];
            let s = h.summary();
            for (got, p) in [(s.p50, 0.50), (s.p90, 0.90), (s.p99, 0.99)] {
                let want = exact(p);
                assert!(got >= want * (1.0 - 1e-12), "p{p}: bucketed {got} < exact {want}");
                assert!(
                    got <= want * GROWTH * (1.0 + 1e-12),
                    "p{p}: bucketed {got} > exact {want} + one bucket"
                );
            }
            assert_eq!(s.count, n);
            assert_eq!(s.max, sorted[n - 1]);
            let mean = samples.iter().sum::<f64>() / n as f64;
            assert!((s.mean - mean).abs() <= 1e-9 * mean.abs().max(1.0));
            // Cumulative exposition view: monotone, ends at the total.
            let (cum, count, sum) = h.cumulative();
            assert_eq!(count, n as u64);
            assert!((sum - samples.iter().sum::<f64>()).abs() <= 1e-9 * sum.abs().max(1.0));
            assert!(cum.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 < w[1].1));
            assert_eq!(cum.last().map(|&(_, c)| c), Some(n as u64));
        });
    }

    #[test]
    fn per_model_accounting() {
        let m = Metrics::default();
        m.set_model(0, "en", 4, 1);
        m.set_model(1, "de", 4, 3);
        m.record_model_tick(0, 2, 2);
        m.record_model_tick(0, 4, 3);
        m.record_model_tick(1, 1, 1);
        m.add_eviction(0);
        m.add_preemption(1);
        m.add_preemption(7); // out of range: global counter only, no panic
        m.add_deferrals(1, 2);
        m.add_deferrals(0, 0); // no-op
        let pm = m.per_model.lock().unwrap();
        assert_eq!(pm[0].frames, 5);
        assert_eq!(pm[0].ticks, 2);
        assert!((pm[0].occupancy() - 6.0 / 8.0).abs() < 1e-12);
        assert_eq!(pm[0].evictions, 1);
        assert_eq!((pm[0].weight, pm[0].loaded, pm[0].deferrals), (1, true, 0));
        assert_eq!(pm[1].preemptions, 1);
        assert_eq!(pm[1].frames, 1);
        assert_eq!((pm[1].weight, pm[1].deferrals), (3, 2));
        drop(pm);
        assert_eq!(*m.preemptions.lock().unwrap(), 2);
        assert_eq!(*m.model_loads.lock().unwrap(), 2);
        let report = m.report();
        assert!(report.contains("model[0] en"), "{report}");
        assert!(report.contains("model[1] de"), "{report}");
        assert!(report.contains("preemptions=2"), "{report}");
    }

    #[test]
    fn slot_reuse_resets_and_retire_keeps_history() {
        // Hot-unload retires the row; a hot load into the same slot (or
        // a later one) starts from zero — churn metrics never bleed
        // across model generations.
        let m = Metrics::default();
        m.set_model(0, "base", 4, 1);
        m.set_model(2, "sparse-slot", 2, 1); // grows the table past a gap
        m.record_model_tick(2, 2, 2);
        m.retire_model(2);
        {
            let pm = m.per_model.lock().unwrap();
            assert_eq!(pm.len(), 3);
            assert!(!pm[2].loaded);
            assert_eq!(pm[2].frames, 2, "postmortem row keeps its history");
            assert!(pm[0].loaded);
        }
        m.set_model(2, "replacement", 8, 5);
        let pm = m.per_model.lock().unwrap();
        assert_eq!(pm[2].name, "replacement");
        assert_eq!(pm[2].frames, 0, "reused slot must start clean");
        assert_eq!((pm[2].max_lanes, pm[2].weight, pm[2].loaded), (8, 5, true));
        drop(pm);
        assert_eq!(*m.model_loads.lock().unwrap(), 3);
        assert_eq!(*m.model_unloads.lock().unwrap(), 1);
        m.retire_model(9); // out of range: counter only, no panic
        assert_eq!(*m.model_unloads.lock().unwrap(), 2);
    }

    #[test]
    fn robustness_counters_report() {
        let m = Metrics::default();
        m.set_model(0, "en", 4, 1);
        m.set_model(1, "de", 4, 1);
        m.add_reaped();
        m.add_reaped();
        m.add_forced_cancel(1);
        m.add_forced_cancel(9); // out of range: global counter only, no panic
        m.add_quarantined_job();
        m.set_quarantined(0);
        m.set_quarantined(9); // out of range: no panic
        {
            let pm = m.per_model.lock().unwrap();
            assert!(pm[0].quarantined && !pm[1].quarantined);
            assert_eq!((pm[0].forced_cancels, pm[1].forced_cancels), (0, 1));
        }
        let r = m.report();
        assert!(r.contains("reaped_streams=2"), "{r}");
        assert!(r.contains("forced_cancels=2"), "{r}");
        assert!(r.contains("quarantined_jobs=1"), "{r}");
        assert!(
            r.lines().any(|l| l.starts_with("model[0] en") && l.contains("quarantined w=")),
            "{r}"
        );
        // A reused slot starts clean, quarantine flag included.
        m.set_model(0, "fresh", 4, 1);
        assert!(!m.per_model.lock().unwrap()[0].quarantined);
    }

    #[test]
    fn overload_counters_and_bytes_report() {
        let m = Metrics::default();
        m.set_model(0, "en", 4, 1);
        m.set_model(1, "de", 4, 1);
        m.brownout_transition(true);
        m.brownout_transition(false);
        m.add_shed(1);
        m.add_shed(9); // out of range: global counter only, no panic
        m.add_brownout_reject();
        m.add_mem_pressure_reject();
        m.add_swap(false);
        m.add_swap(true);
        m.set_budget_bytes(4096);
        m.set_model_bytes(0, 1024, 256, 128);
        m.set_model_bytes(9, 1, 1, 1); // out of range: no panic
        {
            let pm = m.per_model.lock().unwrap();
            assert_eq!(pm[1].shed_streams, 1);
            assert_eq!(
                (pm[0].arena_bytes, pm[0].reserved_bytes, pm[0].parked_bytes),
                (1024, 256, 128)
            );
        }
        assert_eq!(*m.admission_rejects.lock().unwrap(), 2, "rejects roll up");
        let r = m.report();
        assert!(r.contains("shed_streams=2"), "{r}");
        assert!(r.contains("brownout_entries=1") && r.contains("brownout_exits=1"), "{r}");
        assert!(r.contains("mem_pressure_rejects=1"), "{r}");
        assert!(r.contains("model_swaps=1") && r.contains("swap_rollbacks=1"), "{r}");
        assert!(r.contains("resident_bytes=1280") && r.contains("budget_bytes=4096"), "{r}");
        assert!(
            r.lines().any(|l| {
                l.starts_with("model[0] en") && l.contains("arena_bytes=1024")
                    && l.contains("parked_bytes=128")
            }),
            "{r}"
        );
    }

    #[test]
    fn prometheus_exposition_wellformed() {
        let m = Metrics::default();
        m.set_model(0, "en", 4, 1);
        m.add_am_compute(2.0, 10);
        m.finalize_latency.record(5.0);
        m.set_budget_bytes(1000);
        m.set_model_bytes(0, 100, 50, 25);
        m.add_shed(0);
        let p = m.prometheus();
        // Every sample line's metric must have HELP + TYPE preambles.
        for line in p.lines() {
            if line.starts_with('#') {
                continue;
            }
            let metric = line
                .split(|c| c == '{' || c == ' ')
                .next()
                .unwrap();
            assert!(
                p.contains(&format!("# TYPE {metric} ")) || metric.ends_with("_sum")
                    || metric.ends_with("_count")
                    || metric.ends_with("_bucket"),
                "no TYPE for {metric}"
            );
            assert!(line.starts_with("quantasr_"), "{line}");
        }
        assert!(p.contains("quantasr_frames_processed_total 10"), "{p}");
        assert!(p.contains("quantasr_resident_bytes 150"), "{p}");
        assert!(p.contains("quantasr_budget_bytes 1000"), "{p}");
        assert!(
            p.contains("quantasr_model_shed_streams_total{model=\"0\",name=\"en\"} 1"),
            "{p}"
        );
        // Histogram exposition: a finite bucket covering the 5ms sample,
        // the +Inf bucket, and exact sum/count.
        assert!(p.contains("# TYPE quantasr_finalize_latency_ms histogram"), "{p}");
        let has_finite_bucket = p
            .lines()
            .any(|l| {
                l.starts_with("quantasr_finalize_latency_ms_bucket{le=\"")
                    && !l.contains("+Inf")
                    && l.ends_with(" 1")
            });
        assert!(has_finite_bucket, "{p}");
        assert!(p.contains("quantasr_finalize_latency_ms_bucket{le=\"+Inf\"} 1"), "{p}");
        assert!(p.contains("quantasr_finalize_latency_ms_sum 5"), "{p}");
        assert!(p.contains("quantasr_finalize_latency_ms_count 1"), "{p}");
    }

    #[test]
    fn empty_model_stats_safe() {
        let s = ModelStats::default();
        assert_eq!(s.occupancy(), 0.0);
    }

    #[test]
    fn tick_breakdown_accumulates_and_reports() {
        let m = Metrics::default();
        m.add_am_compute(2.0, 10);
        m.add_decode_compute(1.0);
        m.add_decode_compute(0.5);
        m.add_frontend_compute(0.5);
        m.set_effective_quantum(40);
        assert_eq!(m.tick_breakdown(), (2.0, 1.5, 0.5));
        let r = m.report();
        assert!(r.contains("tick_breakdown:"), "{r}");
        assert!(r.contains("effective_quantum=40"), "{r}");
    }

    #[test]
    fn rtf_math() {
        let m = Metrics::default();
        m.add_audio(10.0);
        m.add_am_compute(2.0, 500);
        assert!((m.rtf() - 0.2).abs() < 1e-12);
        assert!(m.report().contains("RTF=0.2000"));
    }
}
