//! Serving metrics: latency percentiles, throughput, real-time factor.

use std::sync::Mutex;
use std::time::Duration;

/// Reservoir-free exact histogram (serving runs are small enough to keep
/// every sample; sorts on read).
#[derive(Default)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
}

impl Histogram {
    pub fn record(&self, v: f64) {
        self.samples.lock().unwrap().push(v);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64() * 1e3); // ms
    }

    pub fn summary(&self) -> HistSummary {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return HistSummary::default();
        }
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let pct = |p: f64| s[((n as f64 * p) as usize).min(n - 1)];
        HistSummary {
            count: n,
            mean: s.iter().sum::<f64>() / n as f64,
            p50: pct(0.50),
            p90: pct(0.90),
            p99: pct(0.99),
            max: s[n - 1],
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct HistSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl HistSummary {
    pub fn fmt_ms(&self, name: &str) -> String {
        format!(
            "{name:<22} n={:<5} mean={:7.2}ms p50={:7.2}ms p90={:7.2}ms p99={:7.2}ms max={:7.2}ms",
            self.count, self.mean, self.p50, self.p90, self.p99, self.max
        )
    }
}

/// Per-model serving counters (one entry per model *slot*, index = model
/// id).  Slots are dynamic: a hot load resets its slot's row, a hot
/// unload retires it (`loaded = false`, live accounting back at zero) —
/// a reused slot never inherits a dead model's numbers.
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    pub name: String,
    /// Lanes in this model's arena.
    pub max_lanes: usize,
    /// DRR tick-bandwidth weight ([`crate::sched::weights`]).
    pub weight: u32,
    /// False once the model has been unloaded (row kept for postmortem
    /// until the slot is reused).
    pub loaded: bool,
    /// AM frames computed for this model.
    pub frames: u64,
    /// Flush ticks in which this model stepped at least one lane.
    pub ticks: u64,
    /// Sum over those ticks of lanes in use (holders, idle included).
    pub lanes_in_use_sum: u64,
    /// Idle holders parked to admit waiting streams.
    pub evictions: u64,
    /// Active holders preempted at a quantum boundary.
    pub preemptions: u64,
    /// Planned lane-steps deferred to a later tick by the weighted
    /// budget (demand the DRR grant didn't cover this tick).
    pub deferrals: u64,
    /// Surviving streams cancelled by an expired force-unload deadline.
    pub forced_cancels: u64,
    /// Poisoned by a backend panic (cleared when the slot is reused).
    pub quarantined: bool,
}

impl ModelStats {
    /// Mean lane occupancy over the ticks this model stepped.
    pub fn occupancy(&self) -> f64 {
        if self.ticks == 0 || self.max_lanes == 0 {
            return 0.0;
        }
        self.lanes_in_use_sum as f64 / (self.ticks as f64 * self.max_lanes as f64)
    }
}

/// Engine-wide counters + histograms.
#[derive(Default)]
pub struct Metrics {
    /// end-to-end: stream finish requested → final result ready (ms)
    pub finalize_latency: Histogram,
    /// per-frame: frame ready → logits produced (ms)
    pub frame_latency: Histogram,
    /// stream admitted → its first posterior frame computed (ms)
    pub first_frame_latency: Histogram,
    /// batched-step batch sizes
    pub batch_size: Histogram,
    /// arena lane occupancy at each flush (lanes in use / lanes total)
    pub lane_occupancy: Histogram,
    /// audio seconds processed
    pub audio_seconds: Mutex<f64>,
    /// wall seconds of AM compute
    pub am_compute_seconds: Mutex<f64>,
    /// wall seconds spent in final decodes (CTC beam + LM rescore)
    pub decode_seconds: Mutex<f64>,
    /// wall seconds spent in the frontend (PCM → feature frames)
    pub frontend_seconds: Mutex<f64>,
    /// effective tick quantum (fixed config, or the auto-tuned value the
    /// AM worker derived from its measured tick rate; 0 = not yet set)
    pub effective_quantum: Mutex<u32>,
    pub frames_processed: Mutex<u64>,
    pub utterances: Mutex<u64>,
    /// idle streams parked out of the arena to admit waiting streams
    pub evictions: Mutex<u64>,
    /// active streams preempted at a quantum boundary (sched::quantum)
    pub preemptions: Mutex<u64>,
    /// streams refused admission (sched::admission backpressure)
    pub admission_rejects: Mutex<u64>,
    /// models hot-loaded into the registry (boot models included)
    pub model_loads: Mutex<u64>,
    /// models drained out and torn down
    pub model_unloads: Mutex<u64>,
    /// flush ticks where ready streams existed but none could be placed —
    /// a scheduler invariant violation (debug builds also assert)
    pub sched_stalls: Mutex<u64>,
    /// streams cancelled by the lifetime reaper (idle timeout or
    /// utterance deadline)
    pub reaped_streams: Mutex<u64>,
    /// streams cancelled by an expired force-unload deadline (sum of the
    /// per-model rows)
    pub forced_cancels: Mutex<u64>,
    /// panics quarantined instead of taking the engine down (decode jobs
    /// + backend steps)
    pub quarantined_jobs: Mutex<u64>,
    /// per-model lane accounting (index = model id)
    pub per_model: Mutex<Vec<ModelStats>>,
}

impl Metrics {
    /// Install (or reset) the stat row for model slot `id` — called at
    /// engine start for boot models and on every hot load.  Resetting on
    /// load is what makes "metrics return to zero after unload"
    /// observable: a reused slot starts a fresh row.
    pub fn set_model(&self, id: usize, name: &str, max_lanes: usize, weight: u32) {
        let mut pm = self.per_model.lock().unwrap();
        if pm.len() <= id {
            pm.resize_with(id + 1, ModelStats::default);
        }
        pm[id] = ModelStats {
            name: name.to_string(),
            max_lanes,
            weight,
            loaded: true,
            ..Default::default()
        };
        *self.model_loads.lock().unwrap() += 1;
    }

    /// Retire model slot `id` after its unload drain completes: the row
    /// stays visible for postmortem but reads as not loaded.
    pub fn retire_model(&self, id: usize) {
        if let Some(m) = self.per_model.lock().unwrap().get_mut(id) {
            m.loaded = false;
        }
        *self.model_unloads.lock().unwrap() += 1;
    }

    pub fn add_audio(&self, secs: f64) {
        *self.audio_seconds.lock().unwrap() += secs;
    }

    pub fn add_am_compute(&self, secs: f64, frames: u64) {
        *self.am_compute_seconds.lock().unwrap() += secs;
        *self.frames_processed.lock().unwrap() += frames;
    }

    pub fn add_decode_compute(&self, secs: f64) {
        *self.decode_seconds.lock().unwrap() += secs;
    }

    pub fn add_frontend_compute(&self, secs: f64) {
        *self.frontend_seconds.lock().unwrap() += secs;
    }

    /// Record the quantum the AM worker actually runs (config value, or
    /// the auto-tuned one once measurement completes).
    pub fn set_effective_quantum(&self, q: u32) {
        *self.effective_quantum.lock().unwrap() = q;
    }

    /// Wall seconds per tick stage: (AM step, decode, frontend).  The
    /// stages run on different threads, so shares are of summed stage
    /// time, not of wall clock.
    pub fn tick_breakdown(&self) -> (f64, f64, f64) {
        (
            *self.am_compute_seconds.lock().unwrap(),
            *self.decode_seconds.lock().unwrap(),
            *self.frontend_seconds.lock().unwrap(),
        )
    }

    pub fn add_utterance(&self) {
        *self.utterances.lock().unwrap() += 1;
    }

    pub fn add_eviction(&self, model: usize) {
        *self.evictions.lock().unwrap() += 1;
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.evictions += 1;
        }
    }

    pub fn add_preemption(&self, model: usize) {
        *self.preemptions.lock().unwrap() += 1;
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.preemptions += 1;
        }
    }

    pub fn add_admission_reject(&self) {
        *self.admission_rejects.lock().unwrap() += 1;
    }

    pub fn add_sched_stall(&self) {
        *self.sched_stalls.lock().unwrap() += 1;
    }

    /// One stream cancelled by the lifetime reaper.
    pub fn add_reaped(&self) {
        *self.reaped_streams.lock().unwrap() += 1;
    }

    /// One surviving stream of `model` cancelled by an expired
    /// force-unload deadline.
    pub fn add_forced_cancel(&self, model: usize) {
        *self.forced_cancels.lock().unwrap() += 1;
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.forced_cancels += 1;
        }
    }

    /// One panic caught and quarantined (a decode job failed alone, or a
    /// backend step poisoned its model slot) instead of killing the
    /// engine.
    pub fn add_quarantined_job(&self) {
        *self.quarantined_jobs.lock().unwrap() += 1;
    }

    /// Mark `model`'s row quarantined after a backend panic.  Cleared by
    /// the next [`Metrics::set_model`] into the slot.
    pub fn set_quarantined(&self, model: usize) {
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.quarantined = true;
        }
    }

    /// Record lane-steps model `model` had planned but the weighted
    /// per-tick budget deferred (sched::weights DRR trim).
    pub fn add_deferrals(&self, model: usize, n: usize) {
        if n == 0 {
            return;
        }
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.deferrals += n as u64;
        }
    }

    /// Record one flush tick for `model`: `lanes_in_use` holders (idle
    /// included), `frames` lanes actually stepped.
    pub fn record_model_tick(&self, model: usize, lanes_in_use: usize, frames: usize) {
        if let Some(m) = self.per_model.lock().unwrap().get_mut(model) {
            m.ticks += 1;
            m.lanes_in_use_sum += lanes_in_use as u64;
            m.frames += frames as u64;
        }
    }

    /// Real-time factor of the AM stage: compute seconds per audio second
    /// (< 1 means faster than real time).
    pub fn rtf(&self) -> f64 {
        let audio = *self.audio_seconds.lock().unwrap();
        let compute = *self.am_compute_seconds.lock().unwrap();
        if audio <= 0.0 {
            return 0.0;
        }
        compute / audio
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.finalize_latency.summary().fmt_ms("finalize_latency"));
        out.push('\n');
        out.push_str(&self.frame_latency.summary().fmt_ms("frame_latency"));
        out.push('\n');
        out.push_str(&self.first_frame_latency.summary().fmt_ms("first_frame_latency"));
        out.push('\n');
        let bs = self.batch_size.summary();
        out.push_str(&format!(
            "batch_size             n={:<5} mean={:5.2}  p50={:4.0}  p99={:4.0}\n",
            bs.count, bs.mean, bs.p50, bs.p99
        ));
        let lo = self.lane_occupancy.summary();
        out.push_str(&format!(
            "lane_occupancy         n={:<5} mean={:5.2}  p50={:4.2}  p99={:4.2}\n",
            lo.count, lo.mean, lo.p50, lo.p99
        ));
        // Take each value before formatting: std::sync::Mutex is not
        // reentrant, and rtf() locks two of these again.
        let utts = *self.utterances.lock().unwrap();
        let frames = *self.frames_processed.lock().unwrap();
        let audio = *self.audio_seconds.lock().unwrap();
        let compute = *self.am_compute_seconds.lock().unwrap();
        let evictions = *self.evictions.lock().unwrap();
        let preemptions = *self.preemptions.lock().unwrap();
        let rejects = *self.admission_rejects.lock().unwrap();
        let stalls = *self.sched_stalls.lock().unwrap();
        let reaped = *self.reaped_streams.lock().unwrap();
        let forced = *self.forced_cancels.lock().unwrap();
        let quarantined = *self.quarantined_jobs.lock().unwrap();
        let loads = *self.model_loads.lock().unwrap();
        let unloads = *self.model_unloads.lock().unwrap();
        let decode = *self.decode_seconds.lock().unwrap();
        let frontend = *self.frontend_seconds.lock().unwrap();
        let equantum = *self.effective_quantum.lock().unwrap();
        let rtf = if audio > 0.0 { compute / audio } else { 0.0 };
        out.push_str(&format!(
            "utterances={utts}  frames={frames}  audio={audio:.1}s  \
             am_compute={compute:.2}s  RTF={rtf:.4}  evictions={evictions}\n",
        ));
        let stages = compute + decode + frontend;
        if stages > 0.0 {
            out.push_str(&format!(
                "tick_breakdown: am={compute:.3}s ({:.0}%)  decode={decode:.3}s ({:.0}%)  \
                 frontend={frontend:.3}s ({:.0}%)\n",
                100.0 * compute / stages,
                100.0 * decode / stages,
                100.0 * frontend / stages,
            ));
        }
        out.push_str(&format!(
            "preemptions={preemptions}  admission_rejects={rejects}  sched_stalls={stalls}  \
             model_loads={loads}  model_unloads={unloads}  effective_quantum={equantum}\n",
        ));
        out.push_str(&format!(
            "reaped_streams={reaped}  forced_cancels={forced}  quarantined_jobs={quarantined}\n",
        ));
        let pm = self.per_model.lock().unwrap();
        if pm.len() > 1 || pm.iter().any(|m| m.preemptions + m.evictions > 0) {
            for (id, m) in pm.iter().enumerate() {
                out.push_str(&format!(
                    "model[{id}] {:<14} {} w={} lanes={} frames={} ticks={} occupancy={:.2} \
                     evictions={} preemptions={} deferrals={} forced_cancels={}\n",
                    m.name,
                    if m.quarantined && m.loaded {
                        "quarantined"
                    } else if m.loaded {
                        "loaded"
                    } else {
                        "retired"
                    },
                    m.weight,
                    m.max_lanes,
                    m.frames,
                    m.ticks,
                    m.occupancy(),
                    m.evictions,
                    m.preemptions,
                    m.deferrals,
                    m.forced_cancels,
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::default();
        for i in 0..100 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, 99.0);
        assert!((s.mean - 49.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.max, 0.0);
    }

    #[test]
    fn per_model_accounting() {
        let m = Metrics::default();
        m.set_model(0, "en", 4, 1);
        m.set_model(1, "de", 4, 3);
        m.record_model_tick(0, 2, 2);
        m.record_model_tick(0, 4, 3);
        m.record_model_tick(1, 1, 1);
        m.add_eviction(0);
        m.add_preemption(1);
        m.add_preemption(7); // out of range: global counter only, no panic
        m.add_deferrals(1, 2);
        m.add_deferrals(0, 0); // no-op
        let pm = m.per_model.lock().unwrap();
        assert_eq!(pm[0].frames, 5);
        assert_eq!(pm[0].ticks, 2);
        assert!((pm[0].occupancy() - 6.0 / 8.0).abs() < 1e-12);
        assert_eq!(pm[0].evictions, 1);
        assert_eq!((pm[0].weight, pm[0].loaded, pm[0].deferrals), (1, true, 0));
        assert_eq!(pm[1].preemptions, 1);
        assert_eq!(pm[1].frames, 1);
        assert_eq!((pm[1].weight, pm[1].deferrals), (3, 2));
        drop(pm);
        assert_eq!(*m.preemptions.lock().unwrap(), 2);
        assert_eq!(*m.model_loads.lock().unwrap(), 2);
        let report = m.report();
        assert!(report.contains("model[0] en"), "{report}");
        assert!(report.contains("model[1] de"), "{report}");
        assert!(report.contains("preemptions=2"), "{report}");
    }

    #[test]
    fn slot_reuse_resets_and_retire_keeps_history() {
        // Hot-unload retires the row; a hot load into the same slot (or
        // a later one) starts from zero — churn metrics never bleed
        // across model generations.
        let m = Metrics::default();
        m.set_model(0, "base", 4, 1);
        m.set_model(2, "sparse-slot", 2, 1); // grows the table past a gap
        m.record_model_tick(2, 2, 2);
        m.retire_model(2);
        {
            let pm = m.per_model.lock().unwrap();
            assert_eq!(pm.len(), 3);
            assert!(!pm[2].loaded);
            assert_eq!(pm[2].frames, 2, "postmortem row keeps its history");
            assert!(pm[0].loaded);
        }
        m.set_model(2, "replacement", 8, 5);
        let pm = m.per_model.lock().unwrap();
        assert_eq!(pm[2].name, "replacement");
        assert_eq!(pm[2].frames, 0, "reused slot must start clean");
        assert_eq!((pm[2].max_lanes, pm[2].weight, pm[2].loaded), (8, 5, true));
        drop(pm);
        assert_eq!(*m.model_loads.lock().unwrap(), 3);
        assert_eq!(*m.model_unloads.lock().unwrap(), 1);
        m.retire_model(9); // out of range: counter only, no panic
        assert_eq!(*m.model_unloads.lock().unwrap(), 2);
    }

    #[test]
    fn robustness_counters_report() {
        let m = Metrics::default();
        m.set_model(0, "en", 4, 1);
        m.set_model(1, "de", 4, 1);
        m.add_reaped();
        m.add_reaped();
        m.add_forced_cancel(1);
        m.add_forced_cancel(9); // out of range: global counter only, no panic
        m.add_quarantined_job();
        m.set_quarantined(0);
        m.set_quarantined(9); // out of range: no panic
        {
            let pm = m.per_model.lock().unwrap();
            assert!(pm[0].quarantined && !pm[1].quarantined);
            assert_eq!((pm[0].forced_cancels, pm[1].forced_cancels), (0, 1));
        }
        let r = m.report();
        assert!(r.contains("reaped_streams=2"), "{r}");
        assert!(r.contains("forced_cancels=2"), "{r}");
        assert!(r.contains("quarantined_jobs=1"), "{r}");
        assert!(
            r.lines().any(|l| l.starts_with("model[0] en") && l.contains("quarantined w=")),
            "{r}"
        );
        // A reused slot starts clean, quarantine flag included.
        m.set_model(0, "fresh", 4, 1);
        assert!(!m.per_model.lock().unwrap()[0].quarantined);
    }

    #[test]
    fn empty_model_stats_safe() {
        let s = ModelStats::default();
        assert_eq!(s.occupancy(), 0.0);
    }

    #[test]
    fn tick_breakdown_accumulates_and_reports() {
        let m = Metrics::default();
        m.add_am_compute(2.0, 10);
        m.add_decode_compute(1.0);
        m.add_decode_compute(0.5);
        m.add_frontend_compute(0.5);
        m.set_effective_quantum(40);
        assert_eq!(m.tick_breakdown(), (2.0, 1.5, 0.5));
        let r = m.report();
        assert!(r.contains("tick_breakdown:"), "{r}");
        assert!(r.contains("effective_quantum=40"), "{r}");
    }

    #[test]
    fn rtf_math() {
        let m = Metrics::default();
        m.add_audio(10.0);
        m.add_am_compute(2.0, 500);
        assert!((m.rtf() - 0.2).abs() < 1e-12);
        assert!(m.report().contains("RTF=0.2000"));
    }
}
