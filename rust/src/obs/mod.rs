//! The flight-recorder trace plane: lock-free per-thread event rings,
//! Chrome-trace export, and bounded postmortem dumps.
//!
//! Aggregate counters ([`crate::coordinator::metrics`]) answer "how much";
//! this module answers "*which* streams, ticks and decode jobs led up to
//! the incident".  Every thread that serves traffic writes fixed-size
//! [`Event`]s into its own bounded ring ([`ring::Ring`]) — an always-on
//! flight recorder whose cost contract is:
//!
//! - **Disabled** (`QUANTASR_TRACE=0`): one relaxed atomic load per
//!   emission site, nothing else.
//! - **Enabled** (the default): one monotonic clock read plus one seqlock
//!   slot write per event — no allocation, no locks, no syscalls on the
//!   hot path.  The ring is allocated once, the first time a thread
//!   emits.
//!
//! Readers ([`snapshot`]) race the writers deliberately: each slot is a
//! seqlock (odd sequence while the writer is mid-copy, even generation
//! when stable), so a torn read is *detected and discarded* rather than
//! prevented — the writer never waits on anyone.
//!
//! Three consumers sit on top:
//!
//! 1. [`chrome_trace_json`] renders a snapshot as a Chrome-trace /
//!    Perfetto JSON array (`chrome://tracing`, <https://ui.perfetto.dev>).
//!    Served over the wire by the `'X'` admin frame and written by
//!    `--trace-out` (see `docs/PROTOCOL.md`, `src/main.rs`).
//! 2. [`postmortem`] freezes the last-N-events window when something
//!    goes wrong (panic quarantine, brownout entry, forced cancels) into
//!    a bounded in-memory deque — and, if `QUANTASR_POSTMORTEM_DIR` is
//!    set, a JSON file per incident.
//! 3. The trace-id plumbing ([`next_trace_id`]): every admission attempt
//!    gets a process-unique id that is stamped on its events *and* echoed
//!    in the stream's terminal wire frames, so client logs can be joined
//!    to server traces.
//!
//! Event taxonomy, ring sizing and the overhead contract are documented
//! in `docs/ARCHITECTURE.md` ("Observability").

pub mod ring;

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU16, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use ring::Ring;

/// Default per-thread ring capacity (events) when `QUANTASR_TRACE` is
/// unset.  At ~48 bytes/event this is ~200 KB per serving thread — a few
/// seconds of saturated history, which is what a postmortem needs.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Events kept per postmortem dump (the "last-N window").
pub const POSTMORTEM_EVENTS: usize = 512;

/// Postmortem dumps retained in memory (oldest dropped first).  Sized so
/// a burst of incidents across many engines (a test process runs dozens)
/// cannot evict a dump before anyone reads it, while staying O(1): at
/// most `KEEP × EVENTS` events live here.
pub const POSTMORTEM_KEEP: usize = 32;

/// Rings whose writer thread has exited, retained so their recent
/// history stays snapshotable (an engine's trace is exported *after*
/// its workers shut down).  Beyond this bound the oldest retired ring
/// is dropped and its tid recycled, so a server spawning a thread per
/// connection stays O(1) in memory under connection churn.
pub const RETIRED_RINGS_KEEP: usize = 32;

/// What happened.  The discriminants are the wire/JSON encoding — append
/// new kinds, never renumber (same additive rule as `docs/PROTOCOL.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// A stream was admitted (`arg` = trace id).
    Admit = 0,
    /// Admission refused (`arg` = [`crate::sched::RejectReason::code`];
    /// `stream` holds the *trace id* — the stream never got an engine id).
    Reject = 1,
    /// A lane-less ready stream was placed into a lane (`arg` = 1 if
    /// parked state was restored, 0 for a fresh zero state).
    LanePlace = 2,
    /// An idle holder's state was parked and its lane handed over.
    LaneEvict = 3,
    /// An active holder past its quantum was preempted (`arg` = quantum
    /// ticks it had consumed).
    LanePreempt = 4,
    /// One batched AM step for one model (span; `arg` = lanes stepped).
    AmTick = 5,
    /// Frontend PCM push (span; `arg` = feature frames emitted).
    FrontendPush = 6,
    /// One utterance's decode-pool job (span; `arg` = frames decoded).
    DecodeJob = 7,
    /// A finished stream was queued for decode (`arg` = frames awaiting
    /// decode).
    DecodeEnqueue = 8,
    /// A stream finalized normally (`arg` = words emitted).
    Finalize = 9,
    /// The engine cancelled a stream (`arg` = frames processed).
    Cancel = 10,
    /// The brownout controller shed a Bulk stream.
    Shed = 11,
    /// A model slot was quarantined after a backend panic.
    Quarantine = 12,
    /// Brownout stage change (`arg` = new stage).
    Brownout = 13,
    /// One lane's state saved out of the arena (span; park/evict path).
    LaneSave = 14,
    /// One parked state restored into a lane (span).
    LaneLoad = 15,
    /// One batched beam search inside the decoder (span; `arg` =
    /// utterances in the batch) — the search itself, as opposed to the
    /// whole [`EventKind::DecodeJob`] which includes per-job finalize.
    BeamSearch = 16,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Reject => "reject",
            EventKind::LanePlace => "lane_place",
            EventKind::LaneEvict => "lane_evict",
            EventKind::LanePreempt => "lane_preempt",
            EventKind::AmTick => "am_tick",
            EventKind::FrontendPush => "frontend_push",
            EventKind::DecodeJob => "decode_job",
            EventKind::DecodeEnqueue => "decode_enqueue",
            EventKind::Finalize => "finalize",
            EventKind::Cancel => "cancel",
            EventKind::Shed => "shed",
            EventKind::Quarantine => "quarantine",
            EventKind::Brownout => "brownout",
            EventKind::LaneSave => "lane_save",
            EventKind::LaneLoad => "lane_load",
            EventKind::BeamSearch => "beam_search",
        }
    }

    pub fn from_u8(v: u8) -> Option<EventKind> {
        Some(match v {
            0 => EventKind::Admit,
            1 => EventKind::Reject,
            2 => EventKind::LanePlace,
            3 => EventKind::LaneEvict,
            4 => EventKind::LanePreempt,
            5 => EventKind::AmTick,
            6 => EventKind::FrontendPush,
            7 => EventKind::DecodeJob,
            8 => EventKind::DecodeEnqueue,
            9 => EventKind::Finalize,
            10 => EventKind::Cancel,
            11 => EventKind::Shed,
            12 => EventKind::Quarantine,
            13 => EventKind::Brownout,
            14 => EventKind::LaneSave,
            15 => EventKind::LaneLoad,
            16 => EventKind::BeamSearch,
            _ => return None,
        })
    }
}

/// One structured trace event.  Fixed-size and `Copy` — the ring stores
/// these inline, so recording never allocates.  `dur_us == 0` means an
/// instant; spans carry their start in `ts_us` and their length in
/// `dur_us` (Chrome `"X"` complete-event semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Microseconds since the process trace epoch (monotonic).
    pub ts_us: u64,
    /// Span duration in µs; 0 for instants.
    pub dur_us: u32,
    pub kind: EventKind,
    /// Which engine emitted this (test processes run several at once).
    pub engine: u16,
    /// Writer-thread ordinal (Chrome `tid`).
    pub tid: u16,
    /// Model slot, or [`NO_MODEL`].
    pub model: u16,
    /// Arena lane, or [`NO_LANE`].
    pub lane: u16,
    /// Engine stream id, 0 if not stream-scoped.
    pub stream: u64,
    /// AM-worker flush ordinal, 0 if not tick-scoped.
    pub tick: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub arg: u64,
}

/// Sentinel: the event has no model / lane coordinate.
pub const NO_MODEL: u16 = u16::MAX;
pub const NO_LANE: u16 = u16::MAX;

impl Event {
    /// Render as one Chrome-trace JSON object (no trailing comma).
    pub fn to_json(&self) -> String {
        let ph = if self.dur_us == 0 { "i" } else { "X" };
        let mut s = format!(
            "{{\"name\":\"{}\",\"ph\":\"{ph}\",\"ts\":{},\"pid\":{},\"tid\":{}",
            self.kind.name(),
            self.ts_us,
            self.engine,
            self.tid
        );
        if self.dur_us > 0 {
            s.push_str(&format!(",\"dur\":{}", self.dur_us));
        } else {
            s.push_str(",\"s\":\"t\"");
        }
        s.push_str(&format!(
            ",\"args\":{{\"kind\":{},\"model\":{},\"lane\":{},\"stream\":{},\"tick\":{},\"arg\":{}}}}}",
            self.kind as u8, self.model, self.lane, self.stream, self.tick, self.arg
        ));
        s
    }

    /// Parse one Chrome-trace object produced by [`Event::to_json`] back
    /// into an `Event` — the round-trip the serialization proptest pins.
    pub fn from_json(j: &crate::io::json::Json) -> Option<Event> {
        let args = j.get("args")?;
        let kind = EventKind::from_u8(u8::try_from(args.int("kind")?).ok()?)?;
        Some(Event {
            ts_us: j.int("ts")? as u64,
            dur_us: j.int("dur").unwrap_or(0) as u32,
            kind,
            engine: u16::try_from(j.int("pid")?).ok()?,
            tid: u16::try_from(j.int("tid")?).ok()?,
            model: u16::try_from(args.int("model")?).ok()?,
            lane: u16::try_from(args.int("lane")?).ok()?,
            stream: args.int("stream")? as u64,
            tick: args.int("tick")? as u64,
            arg: args.int("arg")? as u64,
        })
    }
}

/// The coordinates an emission site supplies.  Everything defaults to
/// "absent" so call sites name only what they know.
#[derive(Clone, Copy, Debug)]
pub struct Meta {
    pub engine: u16,
    pub model: u16,
    pub lane: u16,
    pub stream: u64,
    pub tick: u64,
    pub arg: u64,
}

impl Default for Meta {
    fn default() -> Self {
        Meta { engine: 0, model: NO_MODEL, lane: NO_LANE, stream: 0, tick: 0, arg: 0 }
    }
}

/// The ring registry: live writers, the bounded pool of dead writers'
/// history, and the tid allocator.  One mutex, never touched on the
/// event hot path (only at thread birth/death and by snapshot readers).
struct Registry {
    /// Rings whose writer thread is alive.
    active: Vec<Arc<Ring>>,
    /// Rings whose writer thread exited, oldest first.  Bounded at
    /// [`RETIRED_RINGS_KEEP`]; eviction drops the history and returns
    /// the tid to `free_tids`.
    retired: VecDeque<Arc<Ring>>,
    /// tids whose ring (and therefore whole event history) is gone —
    /// reused before the counter grows, so tids stay bounded by the peak
    /// live + retired ring count rather than total threads ever spawned.
    free_tids: Vec<u16>,
    /// Monotonic fallback allocator; saturates at `u16::MAX` (the shared
    /// overflow tid) rather than wrapping onto live writers.
    next_tid: u32,
}

impl Registry {
    fn alloc_tid(&mut self) -> u16 {
        self.free_tids.pop().unwrap_or_else(|| {
            let t = self.next_tid.min(u16::MAX as u32) as u16;
            self.next_tid = self.next_tid.saturating_add(1);
            t
        })
    }

    /// Move a ring from the active set to the bounded retired pool
    /// (called from the owning thread's exit).  An empty ring has no
    /// history worth keeping: its tid is recycled immediately.
    fn retire(&mut self, ring: &Arc<Ring>) {
        let Some(i) = self.active.iter().position(|r| Arc::ptr_eq(r, ring)) else {
            return;
        };
        let ring = self.active.swap_remove(i);
        if ring.pushed() == 0 {
            self.free_tids.push(ring.tid());
            return;
        }
        self.retired.push_back(ring);
        while self.retired.len() > RETIRED_RINGS_KEEP {
            let dead = self.retired.pop_front().expect("len > KEEP implies non-empty");
            self.free_tids.push(dead.tid());
        }
    }
}

/// The process-wide recorder: the ring registry plus the enabled switch.
struct Recorder {
    enabled: AtomicBool,
    capacity: usize,
    registry: Mutex<Registry>,
    epoch: Instant,
}

fn recorder() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| {
        // QUANTASR_TRACE: 0 disables, N sets the per-thread ring capacity
        // (events), unset = DEFAULT_RING_CAPACITY.  Malformed values warn
        // and keep the default — knobs never panic a serving process.
        let (enabled, capacity) = match std::env::var("QUANTASR_TRACE") {
            Err(_) => (true, DEFAULT_RING_CAPACITY),
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(0) => (false, DEFAULT_RING_CAPACITY),
                Ok(n) => (true, n),
                Err(_) => {
                    eprintln!(
                        "QUANTASR_TRACE='{v}' is not a ring capacity (0 disables); \
                         using {DEFAULT_RING_CAPACITY}"
                    );
                    (true, DEFAULT_RING_CAPACITY)
                }
            },
        };
        Recorder {
            enabled: AtomicBool::new(enabled),
            capacity,
            registry: Mutex::new(Registry {
                active: Vec::new(),
                retired: VecDeque::new(),
                free_tids: Vec::new(),
                next_tid: 1,
            }),
            epoch: Instant::now(),
        }
    })
}

/// Is the recorder on?  One relaxed load — every emission site checks
/// this first, so a disabled recorder costs a branch and nothing else.
#[inline]
pub fn enabled() -> bool {
    recorder().enabled.load(Ordering::Relaxed)
}

/// Flip the recorder at runtime (the overhead bench measures off vs on
/// in one process).  Rings already registered keep their history.
pub fn set_enabled(on: bool) {
    recorder().enabled.store(on, Ordering::Relaxed);
}

/// Microseconds since the trace epoch (first recorder touch).
#[inline]
pub fn now_us() -> u64 {
    recorder().epoch.elapsed().as_micros() as u64
}

/// Owns a thread's ring registration: its `Drop` (the thread-local
/// destructor at thread exit) moves the ring from the registry's active
/// set into the bounded retired pool, so connection-per-thread servers
/// don't accrete a dead ring per connection.
struct ThreadRing(Arc<Ring>);

impl Drop for ThreadRing {
    fn drop(&mut self) {
        // A poisoned registry means some reader panicked mid-scan and
        // the process is already dying — skip rather than double-panic
        // inside a TLS destructor.
        if let Ok(mut reg) = recorder().registry.lock() {
            reg.retire(&self.0);
        }
    }
}

thread_local! {
    /// This thread's ring, created and registered on first emission and
    /// retired by the guard's destructor at thread exit.
    static RING: RefCell<Option<ThreadRing>> = const { RefCell::new(None) };
    /// Ambient coordinates for layers that don't carry engine/stream ids
    /// (frontend, decoder): (engine, stream, model).
    static CTX: Cell<(u16, u64, u16)> = const { Cell::new((0, 0, NO_MODEL)) };
}

/// Set this thread's ambient (engine, stream, model) context, returning
/// the previous value.  The engine brackets calls into context-free
/// layers (frontend push, decode jobs) with this so their spans carry
/// stream coordinates without the layers knowing about the engine.
pub fn set_ctx(engine: u16, stream: u64, model: u16) -> (u16, u64, u16) {
    CTX.with(|c| c.replace((engine, stream, model)))
}

/// Restore a context previously returned by [`set_ctx`].
pub fn restore_ctx(prev: (u16, u64, u16)) {
    CTX.with(|c| c.set(prev));
}

/// Run `f` against this thread's ring, registering it on first use.
/// Events emitted while the thread-local is being torn down (another
/// TLS destructor tracing after `RING` was dropped) are silently lost —
/// re-registering there would leak the new ring.
#[inline]
fn with_ring(f: impl FnOnce(&Ring)) {
    let _ = RING.try_with(|slot| {
        let mut slot = slot.borrow_mut();
        let tr = slot.get_or_insert_with(|| {
            let rec = recorder();
            let mut reg = rec.registry.lock().unwrap();
            let tid = reg.alloc_tid();
            let ring = Arc::new(Ring::new(rec.capacity.max(2), tid));
            reg.active.push(ring.clone());
            ThreadRing(ring)
        });
        f(&tr.0);
    });
}

/// Record an instant event.
#[inline]
pub fn instant(kind: EventKind, m: Meta) {
    if !enabled() {
        return;
    }
    let ts_us = now_us();
    with_ring(|ring| {
        ring.push(Event {
            ts_us,
            dur_us: 0,
            kind,
            engine: m.engine,
            tid: ring.tid(),
            model: m.model,
            lane: m.lane,
            stream: m.stream,
            tick: m.tick,
            arg: m.arg,
        })
    });
}

/// Start a span: returns the start timestamp to hand to [`span_end`].
/// Cheap enough to call unconditionally; pairs with a possibly-disabled
/// `span_end` (the recorder may be flipped mid-span — the span is
/// simply dropped, never torn).  `0` means "span not started" (the
/// recorder was off); a real start is floored to 1 µs so the sentinel
/// never collides with an event in the first microsecond of the epoch.
#[inline]
pub fn span_begin() -> u64 {
    if !enabled() {
        return 0;
    }
    now_us().max(1)
}

/// Close a span opened by [`span_begin`] and record it.  A span that
/// never started (`t0_us == 0`: the recorder was off at [`span_begin`]
/// and flipped on since) is dropped — recording it would fabricate an
/// epoch-to-now span.
#[inline]
pub fn span_end(kind: EventKind, t0_us: u64, m: Meta) {
    if t0_us == 0 || !enabled() {
        return;
    }
    let now = now_us();
    with_ring(|ring| {
        ring.push(Event {
            ts_us: t0_us,
            // A span shorter than the clock tick still happened: floor at
            // 1 µs so Chrome renders it and `dur_us == 0` stays "instant".
            dur_us: (now.saturating_sub(t0_us)).clamp(1, u32::MAX as u64) as u32,
            kind,
            engine: m.engine,
            tid: ring.tid(),
            model: m.model,
            lane: m.lane,
            stream: m.stream,
            tick: m.tick,
            arg: m.arg,
        })
    });
}

/// [`span_end`] taking the ambient thread context for engine/stream/
/// model (frontend + decoder emission sites).
#[inline]
pub fn span_end_ctx(kind: EventKind, t0_us: u64, arg: u64) {
    if t0_us == 0 || !enabled() {
        return;
    }
    let (engine, stream, model) = CTX.with(|c| c.get());
    span_end(kind, t0_us, Meta { engine, stream, model, arg, ..Meta::default() });
}

/// Snapshot every ring's currently-valid events, oldest first — live
/// writers plus the retired pool (recently-exited threads).  Torn slots
/// (a writer mid-copy) are discarded, not waited for.
pub fn snapshot() -> Vec<Event> {
    let rings: Vec<Arc<Ring>> = {
        let reg = recorder().registry.lock().unwrap();
        reg.active.iter().chain(reg.retired.iter()).cloned().collect()
    };
    let mut out = Vec::new();
    for ring in rings {
        ring.drain_valid(&mut out);
    }
    out.sort_by_key(|e| (e.ts_us, e.tid));
    out
}

/// `(live, retired)` ring counts — a diagnostics surface, and what the
/// reclamation tests pin: thread exit moves a ring from live to the
/// bounded retired pool instead of leaking it.
pub fn ring_counts() -> (usize, usize) {
    let reg = recorder().registry.lock().unwrap();
    (reg.active.len(), reg.retired.len())
}

/// [`snapshot`] filtered to one engine's events (test processes run many
/// engines; the export/postmortem surfaces scope to one).
pub fn snapshot_engine(engine: u16) -> Vec<Event> {
    let mut v = snapshot();
    v.retain(|e| e.engine == engine);
    v
}

/// Render events as a Chrome-trace / Perfetto JSON array.  The output is
/// the "JSON array format": `[ {event}, {event}, … ]`, loadable by
/// `chrome://tracing` and Perfetto as-is.
pub fn chrome_trace_json(events: &[Event]) -> String {
    let mut s = String::with_capacity(events.len() * 160 + 2);
    s.push('[');
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push('\n');
        s.push_str(&e.to_json());
    }
    s.push_str("\n]");
    s
}

/// Engine ids (Chrome `pid`s): one per [`crate::coordinator::Engine`],
/// so traces from engines sharing a process never interleave.
pub fn next_engine_id() -> u16 {
    static NEXT: AtomicU16 = AtomicU16::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Stream-scoped trace ids, process-unique and never 0 (0 = "untraced"
/// on the wire).
pub fn next_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// One frozen incident window.
#[derive(Clone, Debug)]
pub struct Postmortem {
    /// Which engine hit the incident.
    pub engine: u16,
    /// Why the dump was taken (e.g. `backend_panic_quarantine`).
    pub trigger: String,
    /// Process-unique dump ordinal.
    pub seq: u64,
    /// The last [`POSTMORTEM_EVENTS`] events of that engine, oldest
    /// first, as of the trigger.
    pub events: Vec<Event>,
}

fn postmortem_store() -> &'static Mutex<VecDeque<Postmortem>> {
    static S: OnceLock<Mutex<VecDeque<Postmortem>>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(VecDeque::new()))
}

/// Freeze the last-N-events window for `engine` under `trigger`.  Bounded
/// both ways: at most [`POSTMORTEM_KEEP`] dumps retained, at most
/// [`POSTMORTEM_EVENTS`] events each.  If `QUANTASR_POSTMORTEM_DIR` is
/// set, the dump is also written there as
/// `postmortem-<seq>-<trigger>.json` (Chrome-trace array); file errors
/// warn and never propagate — a postmortem must not create a second
/// incident.
pub fn postmortem(engine: u16, trigger: &str) {
    if !enabled() {
        return;
    }
    static SEQ: AtomicU64 = AtomicU64::new(1);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let mut events = snapshot_engine(engine);
    if events.len() > POSTMORTEM_EVENTS {
        events.drain(..events.len() - POSTMORTEM_EVENTS);
    }
    let pm = Postmortem { engine, trigger: trigger.to_string(), seq, events };
    if let Ok(dir) = std::env::var("QUANTASR_POSTMORTEM_DIR") {
        if !dir.is_empty() {
            let path = std::path::Path::new(&dir)
                .join(format!("postmortem-{seq}-{trigger}.json"));
            if let Err(e) = std::fs::write(&path, chrome_trace_json(&pm.events)) {
                eprintln!("postmortem write {} failed: {e}", path.display());
            }
        }
    }
    let mut store = postmortem_store().lock().unwrap();
    store.push_back(pm);
    while store.len() > POSTMORTEM_KEEP {
        store.pop_front();
    }
}

/// The retained in-memory postmortem dumps, oldest first.
pub fn postmortems() -> Vec<Postmortem> {
    postmortem_store().lock().unwrap().iter().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::json::Json;
    use crate::util::prop::{forall, Gen};

    fn ev(g: &mut Gen) -> Event {
        Event {
            ts_us: g.usize_in(0, 1 << 40) as u64,
            dur_us: if g.bool() { g.usize_in(1, 1 << 30) as u32 } else { 0 },
            kind: EventKind::from_u8(g.usize_in(0, 16) as u8).unwrap(),
            engine: g.usize_in(0, u16::MAX as usize) as u16,
            tid: g.usize_in(0, u16::MAX as usize) as u16,
            model: g.usize_in(0, u16::MAX as usize) as u16,
            lane: g.usize_in(0, u16::MAX as usize) as u16,
            stream: g.usize_in(0, 1 << 48) as u64,
            tick: g.usize_in(0, 1 << 48) as u64,
            arg: g.usize_in(0, 1 << 48) as u64,
        }
    }

    #[test]
    fn event_json_round_trips() {
        forall("trace event json round-trip", 300, 0x0B5E_11, |g| {
            let e = ev(g);
            let j = Json::parse(&e.to_json()).expect("event renders valid JSON");
            let back = Event::from_json(&j).expect("rendered event parses back");
            assert_eq!(back, e);
        });
    }

    #[test]
    fn chrome_trace_is_wellformed_array_of_events() {
        let mut g = Gen::new(0xC402);
        let events: Vec<Event> = (0..50).map(|_| ev(&mut g)).collect();
        let s = chrome_trace_json(&events);
        let j = Json::parse(&s).expect("chrome trace parses");
        let arr = j.as_arr().expect("top level is an array");
        assert_eq!(arr.len(), events.len());
        for (o, e) in arr.iter().zip(&events) {
            // Schema check: the keys chrome://tracing / Perfetto require.
            assert!(o.str_field("name").is_some());
            let ph = o.str_field("ph").unwrap();
            assert!(ph == "X" || ph == "i", "ph={ph}");
            assert!(o.int("ts").is_some() && o.int("pid").is_some() && o.int("tid").is_some());
            if ph == "X" {
                assert!(o.int("dur").unwrap() > 0);
            }
            assert_eq!(Event::from_json(o).unwrap(), *e);
        }
        // Empty traces are still a valid array.
        assert_eq!(Json::parse(&chrome_trace_json(&[])).unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn instants_and_spans_land_in_the_snapshot() {
        set_enabled(true);
        let engine = next_engine_id();
        instant(EventKind::Admit, Meta { engine, stream: 7, arg: 42, ..Meta::default() });
        let t0 = span_begin();
        std::thread::sleep(std::time::Duration::from_millis(2));
        span_end(
            EventKind::AmTick,
            t0,
            Meta { engine, model: 1, tick: 3, arg: 4, ..Meta::default() },
        );
        let snap = snapshot_engine(engine);
        assert_eq!(snap.len(), 2);
        let admit = snap.iter().find(|e| e.kind == EventKind::Admit).unwrap();
        assert_eq!((admit.stream, admit.arg, admit.dur_us), (7, 42, 0));
        let tick = snap.iter().find(|e| e.kind == EventKind::AmTick).unwrap();
        assert!(tick.dur_us >= 1000, "2ms span measured {}us", tick.dur_us);
        assert_eq!((tick.model, tick.tick, tick.arg), (1, 3, 4));
    }

    #[test]
    fn ctx_propagates_to_context_free_layers() {
        set_enabled(true);
        let engine = next_engine_id();
        let prev = set_ctx(engine, 99, 2);
        let t0 = span_begin();
        span_end_ctx(EventKind::FrontendPush, t0, 13);
        restore_ctx(prev);
        let snap = snapshot_engine(engine);
        let e = snap.iter().find(|e| e.kind == EventKind::FrontendPush).unwrap();
        assert_eq!((e.stream, e.model, e.arg), (99, 2, 13));
    }

    #[test]
    fn span_started_while_disabled_never_records() {
        let engine = next_engine_id();
        set_enabled(false);
        let t0 = span_begin();
        assert_eq!(t0, 0, "disabled span_begin returns the not-started sentinel");
        set_enabled(true);
        // The recorder flipped on between begin and end: recording now
        // would fabricate an epoch-to-now span.
        span_end(EventKind::AmTick, t0, Meta { engine, ..Meta::default() });
        span_end_ctx(EventKind::FrontendPush, t0, 9);
        assert!(snapshot_engine(engine).is_empty());
    }

    #[test]
    fn thread_exit_retires_ring_and_registry_stays_bounded() {
        set_enabled(true);

        // One emitting thread exits: its history must survive into the
        // retired pool.  (Checked before the churn below, which is
        // allowed to evict it.)
        let engine = next_engine_id();
        std::thread::spawn(move || {
            instant(EventKind::Admit, Meta { engine, stream: 31, ..Meta::default() });
        })
        .join()
        .unwrap();
        let snap = snapshot_engine(engine);
        assert_eq!(snap.len(), 1, "dead thread's history must stay snapshotable");
        assert_eq!(snap[0].stream, 31);

        // Thread churn (a connection-per-thread server): the retired
        // pool stays bounded and tids recycle instead of exhausting u16.
        let spawned = 3 * RETIRED_RINGS_KEEP;
        let mut tids = std::collections::HashSet::new();
        for i in 0..spawned {
            let tid = std::thread::spawn(move || {
                instant(EventKind::Admit, Meta { engine, stream: i as u64, ..Meta::default() });
                // try_with cannot fail here (the TLS is live mid-thread);
                // report the tid this thread's ring registered under.
                let mut tid = 0;
                with_ring(|r| tid = r.tid());
                tid
            })
            .join()
            .unwrap();
            tids.insert(tid);
            assert!(
                ring_counts().1 <= RETIRED_RINGS_KEEP,
                "retired pool exceeded its bound at churn step {i}"
            );
        }
        // Evicted rings hand their tids back: far fewer distinct tids
        // than threads ever spawned (no u16 exhaustion under churn).
        assert!(
            tids.len() < spawned,
            "{} threads used {} distinct tids — tids are not being recycled",
            spawned,
            tids.len()
        );
    }

    #[test]
    fn disabled_recorder_drops_events() {
        let engine = next_engine_id();
        set_enabled(false);
        instant(EventKind::Cancel, Meta { engine, stream: 1, ..Meta::default() });
        set_enabled(true);
        assert!(snapshot_engine(engine).is_empty());
    }

    #[test]
    fn postmortems_are_bounded_and_scoped() {
        set_enabled(true);
        let engine = next_engine_id();
        let other = next_engine_id();
        instant(EventKind::Quarantine, Meta { engine, model: 0, ..Meta::default() });
        instant(EventKind::Admit, Meta { engine: other, stream: 5, ..Meta::default() });
        for i in 0..POSTMORTEM_KEEP + 3 {
            postmortem(engine, if i == 0 { "first" } else { "later" });
        }
        let pms = postmortems();
        assert!(pms.len() <= POSTMORTEM_KEEP, "{} dumps retained", pms.len());
        // The oldest dumps were evicted; every retained one is scoped to
        // the engine it was taken for.
        assert!(pms.iter().all(|p| p.trigger != "first" || p.engine != engine));
        let mine: Vec<_> = pms.iter().filter(|p| p.engine == engine).collect();
        assert!(!mine.is_empty());
        for p in mine {
            assert!(p.events.iter().all(|e| e.engine == engine));
            assert!(p.events.len() <= POSTMORTEM_EVENTS);
            assert!(p.events.iter().any(|e| e.kind == EventKind::Quarantine));
        }
    }
}
