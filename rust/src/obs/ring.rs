//! Seqlock event ring: single writer, any number of racing readers.
//!
//! Each serving thread owns exactly one [`Ring`] and is its only writer
//! (enforced by the thread-local registration in [`super`]); snapshot
//! readers may arrive at any moment from other threads.  The classic
//! seqlock discipline makes that race safe without ever blocking the
//! writer:
//!
//! - Every slot carries a sequence word.  The writer bumps it to an
//!   **odd** value, copies the event in, then bumps it to the next
//!   **even** value.  The fencing is the crossbeam/Boehm seqlock
//!   pattern, chosen for weakly-ordered hardware (AArch64), not just
//!   x86-TSO: the odd store is `Relaxed` but followed by a `Release`
//!   fence so the payload writes cannot be hoisted above it, and the
//!   even store is `Release` so the payload writes cannot sink below
//!   it.
//! - A reader loads the sequence (`Acquire`), skips the slot if it is
//!   odd (mid-write) or zero (never written), copies the payload out
//!   with volatile reads, issues an `Acquire` fence (so the payload
//!   reads cannot sink below the re-check), then re-loads the sequence:
//!   if it changed, the copy may be torn and is discarded.
//!
//! The payload copy itself is a data race in the C++11 sense, which is
//! why the slot data lives in `UnsafeCell` and is moved with
//! `ptr::read_volatile` / `ptr::write_volatile` — the sequence check
//! validates the bytes *after* the fact instead of preventing the race.
//! A torn read is therefore detected, never observed.
//!
//! Capacity is fixed at construction; the writer overwrites the oldest
//! slot on wrap.  `head` counts pushes forever (never wraps in practice:
//! 2^64 events), so readers can recover write order without timestamps.

use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::Event;

struct Slot {
    /// 0 = never written; odd = write in progress; even 2n = generation
    /// n committed.
    seq: AtomicU64,
    data: UnsafeCell<Event>,
}

/// Bounded single-writer event ring (see module docs for the protocol).
pub struct Ring {
    slots: Vec<Slot>,
    /// Total pushes ever; `head % slots.len()` is the next write index.
    head: AtomicU64,
    tid: u16,
}

// The UnsafeCell is only ever written by the owning thread and read via
// the validated seqlock protocol above.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    pub fn new(capacity: usize, tid: u16) -> Ring {
        assert!(capacity >= 2, "ring capacity must be at least 2");
        let zero = Event {
            ts_us: 0,
            dur_us: 0,
            kind: super::EventKind::Admit,
            engine: 0,
            tid: 0,
            model: 0,
            lane: 0,
            stream: 0,
            tick: 0,
            arg: 0,
        };
        let slots = (0..capacity)
            .map(|_| Slot { seq: AtomicU64::new(0), data: UnsafeCell::new(zero) })
            .collect();
        Ring { slots, head: AtomicU64::new(0), tid }
    }

    /// The writer-thread ordinal this ring was registered under.
    pub fn tid(&self) -> u16 {
        self.tid
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever pushed (not just currently resident).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Append an event, overwriting the oldest on wrap.  Writer side of
    /// the seqlock; must only be called from the owning thread.
    pub fn push(&self, e: Event) {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(head % self.slots.len() as u64) as usize];
        let gen = head / self.slots.len() as u64 + 1;
        // Odd: readers arriving now will skip or retry this slot.  The
        // Release *fence* (not the store's own ordering — Release on a
        // store only orders what precedes it) is what keeps the payload
        // write below from being hoisted above the odd mark on
        // weakly-ordered hardware.
        slot.seq.store(2 * gen - 1, Ordering::Relaxed);
        fence(Ordering::Release);
        unsafe { std::ptr::write_volatile(slot.data.get(), e) };
        // Even: Release orders the payload copy before the publish.
        slot.seq.store(2 * gen, Ordering::Release);
        self.head.store(head + 1, Ordering::Release);
    }

    /// Copy out every currently-valid event, oldest first, appending to
    /// `out`.  Slots that are mid-write or get overwritten during the
    /// copy are skipped — the snapshot is best-effort by design.
    pub fn drain_valid(&self, out: &mut Vec<Event>) {
        let cap = self.slots.len() as u64;
        let head = self.head.load(Ordering::Acquire);
        let start = head.saturating_sub(cap);
        for i in start..head {
            let slot = &self.slots[(i % cap) as usize];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let e = unsafe { std::ptr::read_volatile(slot.data.get()) };
            // The Acquire fence pins the payload copy above the
            // re-check; an Acquire on the s2 load alone would only
            // order what *follows* it, letting the copy sink past s2 on
            // weakly-ordered hardware and defeating the torn-read test.
            fence(Ordering::Acquire);
            let s2 = slot.seq.load(Ordering::Relaxed);
            if s1 == s2 {
                out.push(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Event, EventKind, Meta};
    use super::*;
    use std::sync::Arc;

    fn mk(i: u64) -> Event {
        let m = Meta::default();
        Event {
            ts_us: i,
            dur_us: 0,
            kind: EventKind::Admit,
            engine: 1,
            tid: 1,
            model: m.model,
            lane: m.lane,
            stream: i,
            tick: 0,
            arg: i * 3,
        }
    }

    #[test]
    fn wraparound_keeps_newest_in_order() {
        let ring = Ring::new(8, 1);
        for i in 0..20u64 {
            ring.push(mk(i));
        }
        let mut out = Vec::new();
        ring.drain_valid(&mut out);
        // Single-threaded: every resident slot is valid, so exactly the
        // newest `capacity` events survive, in push order.
        assert_eq!(out.len(), 8);
        let streams: Vec<u64> = out.iter().map(|e| e.stream).collect();
        assert_eq!(streams, (12..20).collect::<Vec<u64>>());
        assert_eq!(ring.pushed(), 20);
    }

    #[test]
    fn partial_fill_returns_everything() {
        let ring = Ring::new(16, 1);
        for i in 0..5u64 {
            ring.push(mk(i));
        }
        let mut out = Vec::new();
        ring.drain_valid(&mut out);
        assert_eq!(out.iter().map(|e| e.stream).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn racing_reader_never_sees_torn_events() {
        // One writer hammers a tiny ring while readers snapshot
        // concurrently; every event carries stream == ts and
        // arg == 3*stream, so any torn copy is detectable.
        let ring = Arc::new(Ring::new(4, 1));
        let stop = Arc::new(AtomicU64::new(0));
        let writer = {
            let ring = ring.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while stop.load(Ordering::Relaxed) == 0 {
                    ring.push(mk(i));
                    i += 1;
                }
                i
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let ring = ring.clone();
                std::thread::spawn(move || {
                    let mut seen = 0u64;
                    for _ in 0..2000 {
                        let mut out = Vec::new();
                        ring.drain_valid(&mut out);
                        for e in &out {
                            assert_eq!(e.ts_us, e.stream, "torn event: {e:?}");
                            assert_eq!(e.arg, e.stream * 3, "torn event: {e:?}");
                        }
                        seen += out.len() as u64;
                    }
                    seen
                })
            })
            .collect();
        let mut total_seen = 0;
        for r in readers {
            total_seen += r.join().unwrap();
        }
        stop.store(1, Ordering::Relaxed);
        let pushed = writer.join().unwrap();
        assert!(pushed > 0);
        assert!(total_seen > 0, "readers should observe at least some valid events");
    }
}
