//! Execution backends for the serving coordinator.
//!
//! - [`backend`] — the [`AmBackend`] trait: the single, lane-resident
//!   execution interface `coordinator::engine` is generic over.  The
//!   native int8 engine ([`crate::nn::AcousticModel`]) implements it as
//!   the production hot path.
//! - [`model_exec`] *(feature `pjrt`)* — load AOT artifacts (HLO text
//!   lowered by `python/compile/aot.py`) and execute them via PJRT.  This
//!   is the L2 path of the three-layer architecture — the JAX model graph
//!   (with the Pallas kernels lowered into it) compiled once by XLA and
//!   driven from rust.  `ModelExecutable` also implements [`AmBackend`],
//!   so the native-vs-PJRT cross-check is a one-line swap at
//!   `Engine::start`.  The feature is off by default because the real
//!   `xla` bindings need a prebuilt xla_extension library; the default
//!   build links an offline stub (see `rust/vendor/xla`).

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod model_exec;

pub use backend::AmBackend;
#[cfg(feature = "pjrt")]
pub use model_exec::{Manifest, ModelExecutable, PjrtState, Runtime};
