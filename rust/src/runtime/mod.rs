//! PJRT runtime: load AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) and execute them from rust.
//!
//! This is the L2 execution path of the three-layer architecture — the JAX
//! model graph (with the Pallas kernels lowered into it) compiled once by
//! XLA and driven from the rust coordinator.  The native engine
//! ([`crate::nn`]) is the production hot path; the PJRT path exists to
//! (a) prove the AOT bridge works end-to-end and (b) cross-check numerics
//! between the handwritten int8 kernels and the JAX/Pallas reference
//! (test `rust/tests/native_vs_pjrt.rs`).

pub mod model_exec;

pub use model_exec::{Manifest, ModelExecutable, PjrtState, Runtime};
