//! [`AmBackend`] — the one execution interface the serving coordinator
//! speaks.
//!
//! The engine used to be welded to the native [`AcousticModel`], with the
//! PJRT/AOT path (`runtime::model_exec`) living behind a disjoint API.
//! This trait makes `coordinator::engine` generic over *how* a batched
//! acoustic-model step executes, so the native int8 engine and the
//! AOT-compiled XLA graph are a one-line swap at `Engine::start`, and
//! future backends (sharded, remote, GPU) land on the same interface.
//!
//! The interface is **lane-resident** (see [`crate::nn::model::BatchArena`]):
//! a backend allocates an arena of `max_lanes` recurrent-state lanes once,
//! and every step updates the listed active lanes **in place** over
//! lane-resident `[max_lanes, dim]` I/O buffers.  The contract that makes
//! serving correct:
//!
//! 1. **Lane isolation** — a step must read/write only the listed lanes.
//! 2. **Batch invariance** — a lane's outputs and state trajectory must be
//!    independent of which other lanes are active (the native engine makes
//!    this *bit-exact* via per-row input quantization; see `quant::gemm`).
//! 3. **Parkability** — `save_lane`/`load_lane` round-trip a lane's state
//!    exactly, so the engine can evict idle streams, preempt active ones,
//!    and drain a model out for hot unload, all through one path.
//!
//! Arenas have a dynamic lifecycle since the registry went hot: the AM
//! worker builds one per model at load ([`AmBackend::alloc_arena`], on
//! the worker thread at a tick boundary) and drops it at unload teardown
//! — see `docs/ARCHITECTURE.md` for the full tick walk-through.
//!
//! The native backend's step executes on the packed-panel kernel ladder
//! (`quant::gemm`): weights are panel-packed once at load, the microkernel
//! is runtime-dispatched, and large lane-masked GEMMs parallelize across
//! weight panels.  None of that is visible here — the bit-exactness
//! contract of the kernel ladder is what lets the execution strategy
//! change underneath a stable `AmBackend` surface.

use anyhow::Result;

use crate::nn::model::{BatchArena, ParkedLane};
use crate::nn::AcousticModel;
use crate::obs::{self, EventKind};

/// A lane address in a multi-model engine: which loaded model's arena
/// (registration order in [`crate::sched::ModelRegistry`]) and which lane
/// row within it.  The scheduler (`crate::sched`) places streams at
/// `LaneTag` granularity; single-model engines always use `model == 0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LaneTag {
    pub model: usize,
    pub lane: usize,
}

/// A batched, lane-resident acoustic-model execution backend.
pub trait AmBackend: Send + Sync + 'static {
    /// Lane-resident recurrent state for `max_lanes` streams.
    type Arena: Send + 'static;
    /// One lane's state parked outside the arena (eviction).
    type Parked: Send + 'static;

    /// Feature dimension of one input frame.
    fn input_dim(&self) -> usize;

    /// Output posterior dimension.
    fn num_labels(&self) -> usize;

    /// Upper bound on `max_lanes`, if the backend has one (e.g. an AOT
    /// graph lowered at a fixed batch size).  `None` ⇒ any size.
    fn lane_capacity(&self) -> Option<usize> {
        None
    }

    /// Allocate an arena with all lanes zeroed.
    fn alloc_arena(&self, max_lanes: usize) -> Self::Arena;

    /// Resident bytes an arena of `max_lanes` lanes occupies (recurrent
    /// state + per-lane caches/staging).  Must be deterministic and
    /// computable **without** allocating, so admission can price a model
    /// load against the byte budget before committing to it.  Backends
    /// that cannot size themselves may return 0 (unaccounted: the budget
    /// ledger then tracks only what it can see).
    fn arena_bytes(&self, max_lanes: usize) -> usize {
        let _ = max_lanes;
        0
    }

    /// Heap bytes of one [`Self::Parked`] blob produced by
    /// [`Self::save_lane`].  Same determinism contract as
    /// [`Self::arena_bytes`]; every parked lane of one backend is the
    /// same size (recurrent state has fixed per-stream shape).
    fn parked_bytes(&self) -> usize {
        0
    }

    /// One timestep for the listed active lanes, in place.  `x` and `out`
    /// are lane-resident `[max_lanes, input_dim]` / `[max_lanes,
    /// num_labels]`; only rows in `lanes` are read/written.  `out` rows
    /// receive log-posteriors.
    fn step_lanes(
        &self,
        arena: &mut Self::Arena,
        lanes: &[usize],
        x: &[f32],
        out: &mut [f32],
    ) -> Result<()>;

    /// Zero one lane's recurrent state (new stream admitted to the lane).
    fn reset_lane(&self, arena: &mut Self::Arena, lane: usize);

    /// Copy one lane's state out of the arena (evicting its stream).
    fn save_lane(&self, arena: &Self::Arena, lane: usize) -> Self::Parked;

    /// Restore a parked state into a lane (re-admitting its stream).
    fn load_lane(&self, arena: &mut Self::Arena, lane: usize, parked: &Self::Parked);

    /// Short human-readable backend name (metrics / logs).
    fn backend_name(&self) -> &'static str;

    /// Human-readable identity of the *model* this backend executes, for
    /// multi-model registries and per-model metrics.  Defaults to the
    /// backend name; backends that know their loaded model should report
    /// it (the native engine reports the `.qam` header name).
    fn model_name(&self) -> String {
        self.backend_name().to_string()
    }

    /// Numeric representation this backend executes under, for the serving
    /// registry (`'Q'` frame) and per-model metrics: a
    /// [`crate::quant::QuantScheme`] name (`"per-matrix-u8"`, …) or
    /// `"float"`.  Backends that don't requantize report their native
    /// numerics.
    fn scheme_name(&self) -> &'static str {
        "float"
    }
}

/// The native int8/f32 engine — the production hot path.  `Arena` is the
/// pre-allocated [`BatchArena`]; stepping is allocation-free and in place.
impl AmBackend for AcousticModel {
    type Arena = BatchArena;
    type Parked = ParkedLane;

    fn input_dim(&self) -> usize {
        self.header.input_dim
    }

    fn num_labels(&self) -> usize {
        self.header.num_labels
    }

    fn alloc_arena(&self, max_lanes: usize) -> BatchArena {
        self.new_arena(max_lanes)
    }

    fn arena_bytes(&self, max_lanes: usize) -> usize {
        AcousticModel::arena_bytes(self, max_lanes)
    }

    fn parked_bytes(&self) -> usize {
        self.lane_state_bytes()
    }

    fn step_lanes(
        &self,
        arena: &mut BatchArena,
        lanes: &[usize],
        x: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        self.arena_step(arena, lanes, x, out);
        Ok(())
    }

    fn reset_lane(&self, arena: &mut BatchArena, lane: usize) {
        arena.reset_lane(lane);
    }

    fn save_lane(&self, arena: &BatchArena, lane: usize) -> ParkedLane {
        // The park/restore round trip is the cost of every eviction and
        // preemption — record it as a span (ambient ctx: the AM worker
        // sets its engine id at thread start).
        let t0 = obs::span_begin();
        let p = arena.save_lane(lane);
        obs::span_end_ctx(EventKind::LaneSave, t0, lane as u64);
        p
    }

    fn load_lane(&self, arena: &mut BatchArena, lane: usize, parked: &ParkedLane) {
        let t0 = obs::span_begin();
        arena.load_lane(lane, parked);
        obs::span_end_ctx(EventKind::LaneLoad, t0, lane as u64);
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn model_name(&self) -> String {
        self.header.name.clone()
    }

    fn scheme_name(&self) -> &'static str {
        AcousticModel::scheme_name(self)
    }
}

/// PJRT backend: the AOT-compiled XLA step function drives the same engine
/// (the cross-check path — numerics over throughput).  The graph is
/// lowered at a fixed batch size, so `lane_capacity` is `Some(batch)` and
/// every step executes the full batch; lane state is mirrored on the host
/// so lanes can be reset/parked without device-side scatter support.
#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use anyhow::Result;

    use super::AmBackend;
    use crate::runtime::model_exec::ModelExecutable;

    /// Host-mirrored lane state for the fixed-batch AOT step function.
    pub struct PjrtLanes {
        max_lanes: usize,
        /// One host vector per state tensor (ordered c0, h0, c1, h1, …),
        /// each `[manifest.batch, dim]` row-major.
        host: Vec<Vec<f32>>,
        /// Row dim of each state tensor.
        dims: Vec<usize>,
        /// Fixed-batch input staging buffer `[manifest.batch, input_dim]`.
        xfull: Vec<f32>,
    }

    /// One lane's rows across all state tensors.
    pub struct PjrtParked {
        rows: Vec<Vec<f32>>,
    }

    impl AmBackend for ModelExecutable {
        type Arena = PjrtLanes;
        type Parked = PjrtParked;

        fn input_dim(&self) -> usize {
            self.manifest.input_dim
        }

        fn num_labels(&self) -> usize {
            self.manifest.num_labels
        }

        fn lane_capacity(&self) -> Option<usize> {
            Some(self.manifest.batch)
        }

        fn arena_bytes(&self, _max_lanes: usize) -> usize {
            // The host mirror is always sized at the lowered batch
            // (alloc_arena asserts max_lanes <= manifest.batch), so the
            // resident cost is batch-shaped regardless of max_lanes.
            let m = &self.manifest;
            m.batch * (m.num_layers * (m.cell_dim + m.rec_dim) + m.input_dim) * 4
        }

        fn parked_bytes(&self) -> usize {
            let m = &self.manifest;
            m.num_layers * (m.cell_dim + m.rec_dim) * 4
        }

        fn alloc_arena(&self, max_lanes: usize) -> PjrtLanes {
            let m = &self.manifest;
            assert!(
                max_lanes <= m.batch,
                "AOT graph was lowered at batch {}, cannot serve {max_lanes} lanes",
                m.batch
            );
            let mut host = Vec::with_capacity(2 * m.num_layers);
            let mut dims = Vec::with_capacity(2 * m.num_layers);
            for _ in 0..m.num_layers {
                host.push(vec![0f32; m.batch * m.cell_dim]);
                dims.push(m.cell_dim);
                host.push(vec![0f32; m.batch * m.rec_dim]);
                dims.push(m.rec_dim);
            }
            PjrtLanes { max_lanes, host, dims, xfull: vec![0f32; m.batch * m.input_dim] }
        }

        fn step_lanes(
            &self,
            arena: &mut PjrtLanes,
            lanes: &[usize],
            x: &[f32],
            out: &mut [f32],
        ) -> Result<()> {
            let m = &self.manifest;
            let (d, l) = (m.input_dim, m.num_labels);
            debug_assert_eq!(x.len(), arena.max_lanes * d);
            debug_assert_eq!(out.len(), arena.max_lanes * l);
            // Lanes map 1:1 onto batch rows; inactive rows step on zeros
            // and their results/state updates are discarded below, so an
            // idle-but-occupied lane's state never advances (the trait's
            // lane-isolation contract).
            arena.xfull.iter_mut().for_each(|v| *v = 0.0);
            for &lane in lanes {
                arena.xfull[lane * d..(lane + 1) * d]
                    .copy_from_slice(&x[lane * d..(lane + 1) * d]);
            }
            let mut state = self.state_from_host(&arena.host);
            let lp = self.step(&arena.xfull, &mut state)?;
            // Write back only the listed lanes' state rows.
            let new_host = self.state_to_host(&state)?;
            for ((t, new_t), &dim) in
                arena.host.iter_mut().zip(new_host.iter()).zip(arena.dims.iter())
            {
                for &lane in lanes {
                    t[lane * dim..(lane + 1) * dim]
                        .copy_from_slice(&new_t[lane * dim..(lane + 1) * dim]);
                }
            }
            for &lane in lanes {
                out[lane * l..(lane + 1) * l].copy_from_slice(&lp[lane * l..(lane + 1) * l]);
            }
            Ok(())
        }

        fn reset_lane(&self, arena: &mut PjrtLanes, lane: usize) {
            for (t, &dim) in arena.host.iter_mut().zip(arena.dims.iter()) {
                t[lane * dim..(lane + 1) * dim].fill(0.0);
            }
        }

        fn save_lane(&self, arena: &PjrtLanes, lane: usize) -> PjrtParked {
            PjrtParked {
                rows: arena
                    .host
                    .iter()
                    .zip(arena.dims.iter())
                    .map(|(t, &dim)| t[lane * dim..(lane + 1) * dim].to_vec())
                    .collect(),
            }
        }

        fn load_lane(&self, arena: &mut PjrtLanes, lane: usize, parked: &PjrtParked) {
            for ((t, &dim), row) in
                arena.host.iter_mut().zip(arena.dims.iter()).zip(parked.rows.iter())
            {
                t[lane * dim..(lane + 1) * dim].copy_from_slice(row);
            }
        }

        fn backend_name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::{PjrtLanes, PjrtParked};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ExecMode;
    use crate::util::prop::Gen;

    #[test]
    fn native_backend_roundtrips_through_trait() {
        // Drive the native model exclusively through the trait object
        // surface the engine uses, and check lane behavior end to end.
        let mut g = Gen::new(44);
        let qam = crate::nn::model::random_qam(2, 8, Some(4), 6, 7, &mut g);
        let m = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
        let ml = 3;
        let mut arena = AmBackend::alloc_arena(&m, ml);
        let mut x = vec![0f32; ml * 6];
        let mut out = vec![0f32; ml * 7];
        for v in x.iter_mut() {
            *v = g.f32_in(-1.0, 1.0);
        }
        AmBackend::step_lanes(&m, &mut arena, &[0, 2], &x, &mut out).unwrap();
        let parked = AmBackend::save_lane(&m, &arena, 2);
        AmBackend::reset_lane(&m, &mut arena, 2);
        AmBackend::load_lane(&m, &mut arena, 2, &parked);
        AmBackend::step_lanes(&m, &mut arena, &[2], &x, &mut out).unwrap();
        assert_eq!(AmBackend::input_dim(&m), 6);
        assert_eq!(AmBackend::num_labels(&m), 7);
        assert!(AmBackend::lane_capacity(&m).is_none());
        assert_eq!(m.backend_name(), "native");
        assert_eq!(AmBackend::scheme_name(&m), "per-matrix-u8");
    }

    #[test]
    fn byte_sizing_matches_what_save_lane_produces() {
        // The ledger charges parked_bytes() per parked blob; it must be
        // exactly what save_lane actually allocates, and the arena price
        // must cover every lane's state share.
        let mut g = Gen::new(46);
        let qam = crate::nn::model::random_qam(2, 8, Some(4), 6, 7, &mut g);
        let m = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
        let arena = AmBackend::alloc_arena(&m, 4);
        let parked = AmBackend::save_lane(&m, &arena, 0);
        assert_eq!(parked.bytes(), AmBackend::parked_bytes(&m));
        // Per layer one cell row (8 f32) + one output row (4 f32).
        assert_eq!(AmBackend::parked_bytes(&m), 2 * (8 + 4) * 4);
        assert!(AmBackend::arena_bytes(&m, 4) >= 4 * AmBackend::parked_bytes(&m));
        assert_eq!(AmBackend::arena_bytes(&m, 0), 0);
    }

    #[test]
    fn native_backend_results_independent_of_kernel_rung() {
        // Forcing different rungs of the GEMM kernel ladder through the
        // trait surface must not change a single output bit — the
        // execution-strategy-invisibility contract in the module docs.
        let mut g = Gen::new(45);
        let qam = crate::nn::model::random_qam(2, 8, Some(4), 6, 7, &mut g);
        let mut x = vec![0f32; 3 * 6];
        for v in x.iter_mut() {
            *v = g.f32_in(-1.0, 1.0);
        }
        let run = |kernel| {
            let mut m = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
            m.kernel = kernel;
            let mut arena = AmBackend::alloc_arena(&m, 3);
            let mut out = vec![0f32; 3 * 7];
            for _ in 0..3 {
                AmBackend::step_lanes(&m, &mut arena, &[0, 2], &x, &mut out).unwrap();
            }
            out
        };
        use crate::quant::gemm::Kernel;
        let want = run(Kernel::Scalar);
        assert_eq!(run(Kernel::PackedScalar), want);
        assert_eq!(run(Kernel::Auto), want);
    }

    #[test]
    fn preemption_roundtrip_bit_identical_at_any_tick_boundary() {
        // The scheduler's correctness contract: a stream preempted
        // (save_lane) and re-admitted (load_lane) at *arbitrary* tick
        // boundaries — possibly into a different lane, with different
        // co-riders — produces output bit-identical to an unpreempted
        // run, on every kernel rung.
        use crate::quant::gemm::Kernel;
        use crate::util::prop::forall;
        forall("preemption bit-exact", 20, 0x9EE7, |g: &mut Gen| {
            let qam = crate::nn::model::random_qam(2, 10, Some(5), 6, 7, g);
            let ticks = g.usize_in(3, 10);
            let xs: Vec<Vec<f32>> = (0..ticks)
                .map(|_| (0..3 * 6).map(|_| g.f32_in(-1.0, 1.0)).collect())
                .collect();
            // Preempt at a random subset of tick boundaries.
            let preempt_at: Vec<bool> = (0..ticks).map(|_| g.bool()).collect();
            for kernel in [Kernel::Scalar, Kernel::PackedScalar, Kernel::Auto] {
                let mut m = AcousticModel::from_qam(&qam, ExecMode::Quant).unwrap();
                m.kernel = kernel;
                // Reference: the stream runs alone in lane 0, never moved.
                let mut ref_arena = AmBackend::alloc_arena(&m, 3);
                let mut ref_out = vec![0f32; 3 * 7];
                let mut want = Vec::new();
                for x in &xs {
                    AmBackend::step_lanes(&m, &mut ref_arena, &[0], x, &mut ref_out).unwrap();
                    want.extend_from_slice(&ref_out[0..7]);
                }
                // Preempted run: the stream hops lanes 0→1→2→0…, parked
                // between hops, sharing the arena with a decoy lane that
                // steps alongside it.
                let mut arena = AmBackend::alloc_arena(&m, 3);
                let mut out = vec![0f32; 3 * 7];
                let mut lane = 0usize;
                let mut got = Vec::new();
                for (t, x) in xs.iter().enumerate() {
                    // The stream's frame must live in its lane's row.
                    let mut xrow = vec![0f32; 3 * 6];
                    xrow[lane * 6..(lane + 1) * 6].copy_from_slice(&x[0..6]);
                    // Decoy stream in a different lane, random input.
                    let decoy = (lane + 1) % 3;
                    for v in xrow[decoy * 6..(decoy + 1) * 6].iter_mut() {
                        *v = g.f32_in(-1.0, 1.0);
                    }
                    AmBackend::step_lanes(&m, &mut arena, &[lane, decoy], &xrow, &mut out)
                        .unwrap();
                    got.extend_from_slice(&out[lane * 7..(lane + 1) * 7]);
                    if preempt_at[t] {
                        let parked = AmBackend::save_lane(&m, &arena, lane);
                        AmBackend::reset_lane(&m, &mut arena, lane);
                        lane = (lane + 1) % 3;
                        AmBackend::load_lane(&m, &mut arena, lane, &parked);
                    }
                }
                assert_eq!(got, want, "kernel {kernel:?}: preemption changed numerics");
            }
        });
    }
}
