//! Compile + execute one AOT-lowered acoustic-model step function.
//!
//! Artifacts come in pairs:
//! `<tag>.<variant>.b<B>.hlo.txt` (HLO text — the interchange format the
//! image's xla_extension 0.5.1 accepts, see aot.py) and
//! `<tag>.<variant>.b<B>.json` (I/O manifest).
//!
//! Step signature (from the manifest):
//!   inputs : `x [B, input_dim]`, then per layer `c_l [B, N]`, `h_l [B, rec]`
//!   outputs: tuple `(log_probs [B, L], c_0', h_0', …)`

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::io::json::Json;

/// Artifact I/O manifest (written by aot.py next to each .hlo.txt).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub variant: String,
    pub batch: usize,
    pub input_dim: usize,
    pub num_labels: usize,
    pub num_layers: usize,
    pub cell_dim: usize,
    pub rec_dim: usize,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading manifest {}", path.as_ref().display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        let need = |k: &str| j.int(k).with_context(|| format!("manifest missing '{k}'"));
        Ok(Manifest {
            model: j.str_field("model").unwrap_or("?").into(),
            variant: j.str_field("variant").unwrap_or("?").into(),
            batch: need("batch")? as usize,
            input_dim: need("input_dim")? as usize,
            num_labels: need("num_labels")? as usize,
            num_layers: need("num_layers")? as usize,
            cell_dim: need("cell_dim")? as usize,
            rec_dim: need("rec_dim")? as usize,
        })
    }
}

/// A PJRT CPU client (wraps the `xla` crate).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact pair by its base path (without extension).
    pub fn load_model(&self, base: impl AsRef<Path>) -> Result<ModelExecutable> {
        let base = base.as_ref();
        let hlo: PathBuf = PathBuf::from(format!("{}.hlo.txt", base.display()));
        let man: PathBuf = PathBuf::from(format!("{}.json", base.display()));
        let manifest = Manifest::load(&man)?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", hlo.display()))?;
        Ok(ModelExecutable { exe, manifest })
    }
}

/// Recurrent state held as PJRT literals between steps.
pub struct PjrtState {
    pub tensors: Vec<xla::Literal>,
}

/// One compiled step function.
pub struct ModelExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

impl ModelExecutable {
    /// Zero recurrent state matching the manifest layout.
    pub fn zero_state(&self) -> PjrtState {
        let m = &self.manifest;
        let mut tensors = Vec::with_capacity(2 * m.num_layers);
        for _ in 0..m.num_layers {
            tensors.push(literal_2d(&vec![0f32; m.batch * m.cell_dim], m.batch, m.cell_dim));
            tensors.push(literal_2d(&vec![0f32; m.batch * m.rec_dim], m.batch, m.rec_dim));
        }
        PjrtState { tensors }
    }

    /// One step: `x [batch, input_dim]` row-major → log-probs
    /// `[batch, num_labels]`; recurrent state updated in place.
    pub fn step(&self, x: &[f32], state: &mut PjrtState) -> Result<Vec<f32>> {
        let m = &self.manifest;
        if x.len() != m.batch * m.input_dim {
            bail!("step input len {} != {}x{}", x.len(), m.batch, m.input_dim);
        }
        let x_lit = literal_2d(x, m.batch, m.input_dim);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + state.tensors.len());
        args.push(&x_lit);
        for t in &state.tensors {
            args.push(t);
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let mut parts = out.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        if parts.len() != 1 + state.tensors.len() {
            bail!("expected {} outputs, got {}", 1 + state.tensors.len(), parts.len());
        }
        let new_state = parts.split_off(1);
        state.tensors = new_state;
        let log_probs = parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("read log_probs: {e:?}"))?;
        Ok(log_probs)
    }

    /// Build a [`PjrtState`] from host-layout rows: one vector per state
    /// tensor, ordered `c0, h0, c1, h1, …`, each `[batch, dim]` row-major
    /// (the layout [`ModelExecutable::zero_state`] uses).  Used by the
    /// [`crate::runtime::backend::AmBackend`] impl, which mirrors lane
    /// state on the host.
    pub fn state_from_host(&self, host: &[Vec<f32>]) -> PjrtState {
        let m = &self.manifest;
        debug_assert_eq!(host.len(), 2 * m.num_layers);
        let mut tensors = Vec::with_capacity(host.len());
        for (i, t) in host.iter().enumerate() {
            let dim = if i % 2 == 0 { m.cell_dim } else { m.rec_dim };
            debug_assert_eq!(t.len(), m.batch * dim);
            tensors.push(literal_2d(t, m.batch, dim));
        }
        PjrtState { tensors }
    }

    /// Download a [`PjrtState`] into host vectors (inverse of
    /// [`ModelExecutable::state_from_host`]).
    pub fn state_to_host(&self, state: &PjrtState) -> Result<Vec<Vec<f32>>> {
        state
            .tensors
            .iter()
            .map(|t| t.to_vec::<f32>().map_err(|e| anyhow::anyhow!("read state: {e:?}")))
            .collect()
    }

    /// Run a full utterance at batch 1 (repeating the frame across the
    /// batch if the artifact was lowered with batch > 1 — row 0 is used).
    pub fn forward_utt(&self, feats: &[f32], num_frames: usize) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let d = m.input_dim;
        let l = m.num_labels;
        let mut state = self.zero_state();
        let mut out = Vec::with_capacity(num_frames * l);
        let mut xbuf = vec![0f32; m.batch * d];
        for t in 0..num_frames {
            for b in 0..m.batch {
                xbuf[b * d..(b + 1) * d].copy_from_slice(&feats[t * d..(t + 1) * d]);
            }
            let lp = self.step(&xbuf, &mut state)?;
            out.extend_from_slice(&lp[..l]);
        }
        Ok(out)
    }
}

fn literal_2d(data: &[f32], rows: usize, cols: usize) -> xla::Literal {
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .expect("reshape literal")
}

// Integration tests against real artifacts live in rust/tests/ (they need
// `make artifacts` to have run).
