//! Interpolated n-gram language model over word ids.
//!
//! Substitute for the paper's production LMs (§4: a 69.5K-n-gram first-pass
//! LM + a larger 5-gram rescoring LM).  Trained on the synthetic text
//! corpus with Jelinek–Mercer interpolation:
//!
//! ```text
//! p(w | h) = λ·p_ML(w | h) + (1−λ)·p(w | shorter h)     (down to uniform)
//! ```
//!
//! [`NGramLm::small`] builds the pruned first-pass bigram;
//! [`NGramLm::large`] the trigram rescorer.

use std::collections::HashMap;

pub const BOS: u32 = u32::MAX; // sentence-start pseudo-word

/// Longest supported n-gram order.  Scoring keys live in stack buffers of
/// this size so the beam-search hot path never allocates; 8 is far beyond
/// any LM this simulator trains (first-pass bigram, trigram rescorer).
pub const MAX_ORDER: usize = 8;

/// Interpolated n-gram LM.
pub struct NGramLm {
    pub order: usize,
    pub vocab: usize,
    lambda: f64,
    /// counts[k]: (k+1)-gram counts keyed by [context..., word]
    counts: Vec<HashMap<Vec<u32>, u32>>,
    /// context totals per level (sum over final word)
    totals: Vec<HashMap<Vec<u32>, u32>>,
}

impl NGramLm {
    /// Train an `order`-gram LM on sentences.  `prune_min` drops n-grams
    /// (n ≥ 2) seen fewer times (the "small first-pass LM" knob).
    pub fn train(
        sentences: &[Vec<u32>],
        order: usize,
        vocab: usize,
        lambda: f64,
        prune_min: u32,
    ) -> Self {
        assert!(order >= 1);
        assert!(order <= MAX_ORDER, "n-gram order {order} exceeds MAX_ORDER {MAX_ORDER}");
        let mut counts = vec![HashMap::new(); order];
        let mut totals = vec![HashMap::new(); order];
        for s in sentences {
            let padded: Vec<u32> =
                std::iter::repeat(BOS).take(order - 1).chain(s.iter().copied()).collect();
            for i in (order - 1)..padded.len() {
                for k in 0..order {
                    let ctx_start = i - k;
                    let key: Vec<u32> = padded[ctx_start..=i].to_vec();
                    *counts[k].entry(key).or_insert(0) += 1;
                    let ctx: Vec<u32> = padded[ctx_start..i].to_vec();
                    *totals[k].entry(ctx).or_insert(0) += 1;
                }
            }
        }
        // prune rare higher-order n-grams
        for k in 1..order {
            let removed: Vec<Vec<u32>> = counts[k]
                .iter()
                .filter(|(_, &c)| c < prune_min)
                .map(|(k2, _)| k2.clone())
                .collect();
            for key in removed {
                let c = counts[k].remove(&key).unwrap();
                let ctx = key[..key.len() - 1].to_vec();
                if let Some(t) = totals[k].get_mut(&ctx) {
                    *t -= c.min(*t);
                }
            }
        }
        NGramLm { order, vocab, lambda, counts, totals }
    }

    /// Convenience: the small pruned first-pass bigram.
    pub fn small(sentences: &[Vec<u32>], vocab: usize) -> Self {
        Self::train(sentences, 2, vocab, 0.7, 3)
    }

    /// Convenience: the larger trigram rescoring LM.
    pub fn large(sentences: &[Vec<u32>], vocab: usize) -> Self {
        Self::train(sentences, 3, vocab, 0.8, 1)
    }

    /// log p(word | history).  `history` = previously emitted words
    /// (most recent last); BOS padding is implicit.  Only the last
    /// `order - 1` history words matter, so beam-search callers may pass a
    /// truncated tail and score identically.  Alloc-free: context and key
    /// live in stack buffers (see [`MAX_ORDER`]).
    pub fn log_prob(&self, history: &[u32], word: u32) -> f64 {
        let n = self.order - 1;
        let mut ctx = [BOS; MAX_ORDER];
        let take = history.len().min(n);
        ctx[n - take..n].copy_from_slice(&history[history.len() - take..]);
        self.interp(&ctx[..n], word).ln()
    }

    fn interp(&self, ctx: &[u32], word: u32) -> f64 {
        // level k uses the last k context words
        let uniform = 1.0 / self.vocab as f64;
        let mut p = uniform;
        let mut key = [0u32; MAX_ORDER];
        for k in 0..self.order {
            if k > ctx.len() {
                break;
            }
            let c_start = ctx.len() - k;
            let tail = &ctx[c_start..];
            key[..k].copy_from_slice(tail);
            key[k] = word;
            // `HashMap<Vec<u32>, _>` lookups go through `Borrow<[u32]>`, so
            // the stack slices need no Vec allocation.
            let num = *self.counts[k].get(&key[..=k]).unwrap_or(&0) as f64;
            let den = *self.totals[k].get(tail).unwrap_or(&0) as f64;
            if den > 0.0 {
                let ml = num / den;
                p = self.lambda * ml + (1.0 - self.lambda) * p;
            }
        }
        p.max(1e-12)
    }

    /// Number of stored n-grams (model size metric).
    pub fn num_ngrams(&self) -> usize {
        self.counts.iter().map(HashMap::len).sum()
    }

    /// Per-word perplexity on held-out sentences.
    pub fn perplexity(&self, sentences: &[Vec<u32>]) -> f64 {
        let mut lp = 0.0;
        let mut n = 0usize;
        for s in sentences {
            let mut hist: Vec<u32> = Vec::new();
            for &w in s {
                lp += self.log_prob(&hist, w);
                hist.push(w);
                n += 1;
            }
        }
        (-lp / n.max(1) as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dataset::text_corpus;
    use crate::sim::World;

    fn corpus(n: usize, seed: u64) -> Vec<Vec<u32>> {
        text_corpus(n, seed, &World::new())
    }

    #[test]
    fn probabilities_normalize() {
        let c = corpus(400, 1);
        let lm = NGramLm::train(&c, 2, 200, 0.7, 1);
        for hist in [vec![], vec![3u32], vec![7, 11]] {
            let total: f64 = (0..200u32).map(|w| lm.log_prob(&hist, w).exp()).sum();
            assert!((total - 1.0).abs() < 1e-6, "hist {hist:?} total {total}");
        }
    }

    #[test]
    fn trained_lm_beats_uniform() {
        let train = corpus(2000, 2);
        let held = corpus(200, 3);
        let lm = NGramLm::large(&train, 200);
        let ppl = lm.perplexity(&held);
        assert!(ppl < 170.0, "ppl {ppl} vs uniform 200");
    }

    #[test]
    fn higher_order_helps() {
        let train = corpus(3000, 4);
        let held = corpus(300, 5);
        let uni = NGramLm::train(&train, 1, 200, 0.9, 1);
        let tri = NGramLm::train(&train, 3, 200, 0.8, 1);
        assert!(
            tri.perplexity(&held) < uni.perplexity(&held),
            "tri {} vs uni {}",
            tri.perplexity(&held),
            uni.perplexity(&held)
        );
    }

    #[test]
    fn pruning_shrinks_model() {
        let train = corpus(2000, 6);
        let full = NGramLm::train(&train, 2, 200, 0.7, 1);
        let pruned = NGramLm::train(&train, 2, 200, 0.7, 5);
        assert!(pruned.num_ngrams() < full.num_ngrams());
    }

    #[test]
    fn tail_history_scores_identically() {
        // the SoA beam search passes only the last order-1 words
        let train = corpus(1000, 9);
        for lm in [NGramLm::small(&train, 200), NGramLm::large(&train, 200)] {
            let hist = [5u32, 9, 13, 2, 7];
            let tail = &hist[hist.len() - (lm.order - 1)..];
            for w in [0u32, 3, 42, 199] {
                assert_eq!(lm.log_prob(&hist, w), lm.log_prob(tail, w));
            }
        }
    }

    #[test]
    fn bos_context_matters() {
        let train = corpus(2000, 7);
        let lm = NGramLm::small(&train, 200);
        // sentence-initial distribution is Zipf-heavy → word 0 should be
        // much likelier than word 199 at BOS
        assert!(lm.log_prob(&[], 0) > lm.log_prob(&[], 199));
    }
}
