//! Word-level CTC beam search: lexicon trie × first-pass LM, with
//! on-the-fly rescoring by the large LM (paper §4's decoding setup).
//!
//! Time-synchronous prefix beam search where each hypothesis tracks its
//! position in the lexicon trie.  Phone expansions are constrained to trie
//! arcs; when an arc completes a word, a boundary hypothesis is spawned
//! with the word emitted, the small (first-pass) LM score added to the
//! pruning score, and the large-LM score accumulated on the side.  Final
//! ranking uses the large LM — the on-the-fly rescoring pass.

use std::collections::HashMap;

use crate::decoder::lm::NGramLm;
use crate::decoder::trie::LexTrie;

const NEG_INF: f64 = -1e30;
const BLANK: usize = 0;

#[inline]
fn lse(a: f64, b: f64) -> f64 {
    if a < b {
        b + (1.0 + (a - b).exp()).ln()
    } else if a == NEG_INF {
        NEG_INF
    } else {
        a + (1.0 + (b - a).exp()).ln()
    }
}

/// Search hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct DecoderConfig {
    /// Max live hypotheses per frame.
    pub beam: usize,
    /// Weight of the small first-pass LM in the pruning score.
    pub lm_weight_small: f64,
    /// Weight of the large rescoring LM in the final score.
    pub lm_weight_large: f64,
    /// Per-word bonus (>0 fights deletion bias of LM-weighted search).
    pub word_insertion_bonus: f64,
    /// Skip phones with log-posterior below this (per frame).
    pub phone_floor: f64,
}

impl Default for DecoderConfig {
    fn default() -> Self {
        DecoderConfig {
            beam: 24,
            lm_weight_small: 0.8,
            lm_weight_large: 1.0,
            word_insertion_bonus: 0.5,
            phone_floor: -12.0,
        }
    }
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct Key {
    node: u32,
    last: u32,
    words: Vec<u32>,
}

#[derive(Clone)]
struct Entry {
    lb: f64,
    lnb: f64,
    lm_small: f64,
    lm_large: f64,
}

impl Entry {
    fn new() -> Self {
        Entry { lb: NEG_INF, lnb: NEG_INF, lm_small: 0.0, lm_large: 0.0 }
    }

    fn acoustic(&self) -> f64 {
        lse(self.lb, self.lnb)
    }
}

/// The assembled decoder.
pub struct Decoder {
    pub trie: LexTrie,
    pub lm_small: NGramLm,
    pub lm_large: NGramLm,
    pub config: DecoderConfig,
}

/// A decode result with score breakdown.
#[derive(Clone, Debug, Default)]
pub struct Hypothesis {
    pub words: Vec<u32>,
    pub acoustic: f64,
    pub lm_small: f64,
    pub lm_large: f64,
}

impl Decoder {
    pub fn new(trie: LexTrie, lm_small: NGramLm, lm_large: NGramLm, config: DecoderConfig) -> Self {
        Decoder { trie, lm_small, lm_large, config }
    }

    /// Decode `[t, num_labels]` log-posteriors into the best word sequence.
    pub fn decode(&self, log_probs: &[f32], num_labels: usize) -> Hypothesis {
        let beams = self.run_beams_impl(log_probs, num_labels);
        let cfg = &self.config;
        // Final: prefer complete hypotheses (trie at root); rescore with
        // the large LM.
        let score = |k: &Key, e: &Entry| {
            e.acoustic()
                + cfg.lm_weight_large * e.lm_large
                + cfg.word_insertion_bonus * k.words.len() as f64
        };
        let best = beams
            .iter()
            .filter(|(k, _)| k.node == 0)
            .max_by(|a, b| score(a.0, a.1).partial_cmp(&score(b.0, b.1)).unwrap())
            .or_else(|| {
                beams
                    .iter()
                    .max_by(|a, b| score(a.0, a.1).partial_cmp(&score(b.0, b.1)).unwrap())
            });
        match best {
            Some((k, e)) => Hypothesis {
                words: k.words.clone(),
                acoustic: e.acoustic(),
                lm_small: e.lm_small,
                lm_large: e.lm_large,
            },
            None => Hypothesis::default(),
        }
    }

    /// Time-synchronous beam propagation (the core of decode/decode_nbest).
    fn run_beams_impl(&self, log_probs: &[f32], num_labels: usize) -> HashMap<Key, Entry> {
        let cfg = &self.config;
        let t = log_probs.len() / num_labels.max(1);
        let mut beams: HashMap<Key, Entry> = HashMap::new();
        beams.insert(
            Key { node: 0, last: BLANK as u32, words: Vec::new() },
            Entry { lb: 0.0, lnb: NEG_INF, lm_small: 0.0, lm_large: 0.0 },
        );

        for i in 0..t {
            let row = &log_probs[i * num_labels..(i + 1) * num_labels];
            let mut next: HashMap<Key, Entry> = HashMap::new();
            for (key, e) in &beams {
                let total = e.acoustic();
                // 1) blank: state unchanged.
                {
                    let n = next.entry(key.clone()).or_insert_with(Entry::new);
                    let v = total + row[BLANK] as f64;
                    if v > n.lb {
                        n.lm_small = e.lm_small;
                        n.lm_large = e.lm_large;
                    }
                    n.lb = lse(n.lb, v);
                }
                // 2) repeat last emitted phone (stays in the same prefix).
                if key.last != BLANK as u32 && e.lnb > NEG_INF {
                    let n = next.entry(key.clone()).or_insert_with(Entry::new);
                    let v = e.lnb + row[key.last as usize] as f64;
                    if v > n.lnb {
                        n.lm_small = e.lm_small;
                        n.lm_large = e.lm_large;
                    }
                    n.lnb = lse(n.lnb, v);
                }
                // 3) extend along trie arcs.
                for &(phone, child) in self.trie.exits(key.node) {
                    let p_s = row[phone as usize] as f64;
                    if p_s < cfg.phone_floor {
                        continue;
                    }
                    let base = if phone == key.last { e.lb } else { total };
                    if base <= NEG_INF {
                        continue;
                    }
                    let v = base + p_s;
                    // 3a) continue inside the word.
                    let k_cont =
                        Key { node: child, last: phone, words: key.words.clone() };
                    {
                        let n = next.entry(k_cont).or_insert_with(Entry::new);
                        if v > n.lnb {
                            n.lm_small = e.lm_small;
                            n.lm_large = e.lm_large;
                        }
                        n.lnb = lse(n.lnb, v);
                    }
                    // 3b) word boundary: emit every word ending here.
                    for &w in self.trie.words_at(child) {
                        let mut words = key.words.clone();
                        let ls = self.lm_small.log_prob(&words, w);
                        let ll = self.lm_large.log_prob(&words, w);
                        words.push(w);
                        let k_end = Key { node: 0, last: phone, words };
                        let n = next.entry(k_end).or_insert_with(Entry::new);
                        if v > n.lnb {
                            n.lm_small = e.lm_small + ls;
                            n.lm_large = e.lm_large + ll;
                        }
                        n.lnb = lse(n.lnb, v);
                    }
                }
            }
            // Prune by acoustic + small-LM + insertion bonus.
            let mut items: Vec<(Key, Entry)> = next.into_iter().collect();
            items.sort_by(|a, b| {
                let sa = a.1.acoustic()
                    + cfg.lm_weight_small * a.1.lm_small
                    + cfg.word_insertion_bonus * a.0.words.len() as f64;
                let sb = b.1.acoustic()
                    + cfg.lm_weight_small * b.1.lm_small
                    + cfg.word_insertion_bonus * b.0.words.len() as f64;
                sb.partial_cmp(&sa).unwrap()
            });
            items.truncate(cfg.beam);
            beams = items.into_iter().collect();
        }
        beams
    }

    /// N-best list (rescored, deduplicated by word sequence, best first).
    /// The sequence-discriminative training recipes (MWER/sMBR) and
    /// confidence estimation consume these.
    pub fn decode_nbest(
        &self,
        log_probs: &[f32],
        num_labels: usize,
        n: usize,
    ) -> Vec<Hypothesis> {
        let beams = self.run_beams_impl(log_probs, num_labels);
        let cfg = &self.config;
        let mut items: Vec<Hypothesis> = beams
            .into_iter()
            .filter(|(k, _)| k.node == 0)
            .map(|(k, e)| Hypothesis {
                words: k.words,
                acoustic: e.acoustic(),
                lm_small: e.lm_small,
                lm_large: e.lm_large,
            })
            .collect();
        items.sort_by(|a, b| {
            let sa = a.acoustic
                + cfg.lm_weight_large * a.lm_large
                + cfg.word_insertion_bonus * a.words.len() as f64;
            let sb = b.acoustic
                + cfg.lm_weight_large * b.lm_large
                + cfg.word_insertion_bonus * b.words.len() as f64;
            sb.partial_cmp(&sa).unwrap()
        });
        items.dedup_by(|a, b| a.words == b.words);
        items.truncate(n);
        items
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoder::trie::LexTrie;
    use crate::sim::dataset::text_corpus;
    use crate::sim::World;

    fn decoder(beam: usize) -> (Decoder, World) {
        let world = World::new();
        let corpus = text_corpus(1500, 77, &world);
        let trie = LexTrie::from_world(&world);
        let lm_s = NGramLm::small(&corpus, 200);
        let lm_l = NGramLm::large(&corpus, 200);
        let cfg = DecoderConfig { beam, ..Default::default() };
        (Decoder::new(trie, lm_s, lm_l, cfg), world)
    }

    /// Synthesize ideal peaked posteriors for a phone sequence: each phone
    /// lasts 3 frames then 1 blank frame.
    fn ideal_posteriors(phones: &[u32], num_labels: usize) -> Vec<f32> {
        let mut rows: Vec<f32> = Vec::new();
        let mut push = |id: u32| {
            let mut r = vec![-8.0f32; num_labels];
            r[id as usize] = 0.0;
            // renormalize roughly (log-softmax-ish): fine for tests
            rows.extend(r);
        };
        push(0);
        for &p in phones {
            for _ in 0..3 {
                push(p);
            }
            push(0);
        }
        rows
    }

    #[test]
    fn decodes_clean_word_sequence() {
        let (dec, world) = decoder(24);
        let words = vec![3u32, 17, 42];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let lp = ideal_posteriors(&phones, 41);
        let hyp = dec.decode(&lp, 41);
        assert_eq!(hyp.words, words, "phones {phones:?}");
    }

    #[test]
    fn empty_input_gives_empty_hyp() {
        let (dec, _) = decoder(8);
        let hyp = dec.decode(&[], 41);
        assert!(hyp.words.is_empty());
    }

    #[test]
    fn lexicon_constraint_repairs_minor_corruption() {
        // Corrupt one phone frame of a word; the trie + LM should still
        // recover the intended words since no other word matches better.
        let (dec, world) = decoder(32);
        let words = vec![7u32, 19];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let mut lp = ideal_posteriors(&phones, 41);
        // soften frames of the middle phone occurrence
        let frames = lp.len() / 41;
        let mid = frames / 2;
        for f in mid..(mid + 1).min(frames) {
            for v in lp[f * 41..(f + 1) * 41].iter_mut() {
                *v = -3.7; // ~uniform
            }
        }
        let hyp = dec.decode(&lp, 41);
        assert_eq!(hyp.words, words);
    }

    #[test]
    fn nbest_first_equals_decode_best() {
        let (dec, world) = decoder(24);
        let words = vec![3u32, 17, 42];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let lp = ideal_posteriors(&phones, 41);
        let best = dec.decode(&lp, 41);
        let nbest = dec.decode_nbest(&lp, 41, 5);
        assert!(!nbest.is_empty());
        assert_eq!(nbest[0].words, best.words);
        // list is sorted and deduplicated
        for w in nbest.windows(2) {
            assert_ne!(w[0].words, w[1].words);
        }
    }

    #[test]
    fn bigger_beam_never_scores_worse() {
        let (dec_small, world) = decoder(2);
        let (dec_big, _) = decoder(32);
        let words = vec![11u32, 3, 90];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let lp = ideal_posteriors(&phones, 41);
        let h_small = dec_small.decode(&lp, 41);
        let h_big = dec_big.decode(&lp, 41);
        let score = |h: &Hypothesis| {
            h.acoustic + h.lm_large + 0.5 * h.words.len() as f64
        };
        assert!(score(&h_big) >= score(&h_small) - 1e-9);
        assert_eq!(h_big.words, words);
    }
}
