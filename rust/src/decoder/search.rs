//! Word-level CTC beam search: lexicon trie × first-pass LM, with
//! on-the-fly rescoring by the large LM (paper §4's decoding setup).
//!
//! Time-synchronous prefix beam search where each hypothesis tracks its
//! position in the lexicon trie.  Phone expansions are constrained to trie
//! arcs; when an arc completes a word, a boundary hypothesis is spawned
//! with the word emitted, the small (first-pass) LM score added to the
//! pruning score, and the large-LM score accumulated on the side.  Final
//! ranking uses the large LM — the on-the-fly rescoring pass.
//!
//! # Two engines, one semantics
//!
//! The search runs on the kernel ladder of [`crate::decoder::kernel`]:
//!
//! - **Reference** — the original per-hypothesis `HashMap` search, kept
//!   verbatim as the semantic definition ([`Decoder::decode_with_kernel`]
//!   with [`DecodeKernel::Reference`]).
//! - **SoA** (`Scalar`/`Avx2`/`Neon`) — beam lanes as parallel arrays
//!   (trie node / last phone / prefix handle / blank & non-blank mass),
//!   word prefixes interned in a parent-pointer arena so hypothesis
//!   identity is a `u32` handle instead of a `Vec<u32>` clone+hash per
//!   expansion, the trie walked through its CSR view, LM lookups
//!   memoized per flush, and pruning done with a partial select instead
//!   of a full sort.  The SIMD rungs vectorize the posterior-row prep
//!   (f64 widening + phone-floor mask) with exact operations only, so
//!   all SoA rungs are bit-identical; they match the reference to ≤1e-9
//!   (`HashMap` iteration order makes the reference's log-sum-exp
//!   accumulation order arbitrary — see `decoder/kernel.rs`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use crate::decoder::kernel::{self, DecodeKernel};
use crate::decoder::lm::{self, NGramLm, BOS};
use crate::decoder::trie::{LexTrie, TrieCsr};

const NEG_INF: f64 = -1e30;
const BLANK: usize = 0;

#[inline]
fn lse(a: f64, b: f64) -> f64 {
    if a < b {
        b + (a - b).exp().ln_1p()
    } else if a == NEG_INF {
        NEG_INF
    } else {
        a + (b - a).exp().ln_1p()
    }
}

/// FxHash-style multiply-rotate hasher for the small fixed-width keys the
/// SoA search uses (lane keys, prefix-arena edges, LM memo entries).
/// SipHash's DoS resistance buys nothing on internal u32 tuples and costs
/// a measurable slice of the decode tick.
#[derive(Default)]
struct FxHasher(u64);

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.mix(b as u64);
        }
    }
    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.mix(v as u64);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.mix(v);
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.mix(v as u64);
    }
}

impl FxHasher {
    #[inline]
    fn mix(&mut self, v: u64) {
        self.0 = (self.0.rotate_left(5) ^ v).wrapping_mul(0x517c_c1b7_2722_0a95);
    }
}

type FxMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

/// Search hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct DecoderConfig {
    /// Max live hypotheses per frame.
    pub beam: usize,
    /// Weight of the small first-pass LM in the pruning score.
    pub lm_weight_small: f64,
    /// Weight of the large rescoring LM in the final score.
    pub lm_weight_large: f64,
    /// Per-word bonus (>0 fights deletion bias of LM-weighted search).
    pub word_insertion_bonus: f64,
    /// Skip phones with log-posterior below this (per frame).
    pub phone_floor: f64,
}

impl Default for DecoderConfig {
    fn default() -> Self {
        DecoderConfig {
            beam: 24,
            lm_weight_small: 0.8,
            lm_weight_large: 1.0,
            word_insertion_bonus: 0.5,
            phone_floor: -12.0,
        }
    }
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct Key {
    node: u32,
    last: u32,
    words: Vec<u32>,
}

#[derive(Clone)]
struct Entry {
    lb: f64,
    lnb: f64,
    lm_small: f64,
    lm_large: f64,
}

impl Entry {
    fn new() -> Self {
        Entry { lb: NEG_INF, lnb: NEG_INF, lm_small: 0.0, lm_large: 0.0 }
    }

    fn acoustic(&self) -> f64 {
        lse(self.lb, self.lnb)
    }
}

/// A surviving beam at the end of an utterance, engine-agnostic: both the
/// reference and SoA searches produce these for final ranking.
struct RawBeam {
    node: u32,
    words: Vec<u32>,
    lb: f64,
    lnb: f64,
    lm_small: f64,
    lm_large: f64,
}

impl RawBeam {
    fn acoustic(&self) -> f64 {
        lse(self.lb, self.lnb)
    }
}

/// The assembled decoder.
pub struct Decoder {
    pub trie: LexTrie,
    pub lm_small: NGramLm,
    pub lm_large: NGramLm,
    pub config: DecoderConfig,
    /// CSR view of `trie` for the SoA search (kept in lockstep by `new`).
    csr: TrieCsr,
    /// Rung used by `decode`/`decode_batch`; `Auto` honors
    /// `QUANTASR_DECODE_KERNEL`.
    kernel: DecodeKernel,
}

/// A decode result with score breakdown.
#[derive(Clone, Debug, Default)]
pub struct Hypothesis {
    pub words: Vec<u32>,
    pub acoustic: f64,
    pub lm_small: f64,
    pub lm_large: f64,
}

// ---------------------------------------------------------------------------
// SoA search internals
// ---------------------------------------------------------------------------

/// Interned word prefixes: a parent-pointer arena where handle equality is
/// sequence equality (each (parent, word) edge is created exactly once via
/// `edges`).  Hypothesis keys carry the `u32` handle, so beam expansion
/// never clones or hashes a `Vec<u32>`.
#[derive(Default)]
struct PrefixArena {
    parent: Vec<u32>,
    word: Vec<u32>,
    depth: Vec<u32>,
    edges: FxMap<(u32, u32), u32>,
}

const ROOT: u32 = 0;

impl PrefixArena {
    fn reset(&mut self) {
        self.parent.clear();
        self.word.clear();
        self.depth.clear();
        self.edges.clear();
        self.parent.push(ROOT);
        self.word.push(u32::MAX);
        self.depth.push(0);
    }

    /// Handle of `prefix + [w]`, interning it on first use.
    #[inline]
    fn child(&mut self, prefix: u32, w: u32) -> u32 {
        if let Some(&h) = self.edges.get(&(prefix, w)) {
            return h;
        }
        let h = self.parent.len() as u32;
        self.parent.push(prefix);
        self.word.push(w);
        self.depth.push(self.depth[prefix as usize] + 1);
        self.edges.insert((prefix, w), h);
        h
    }

    /// Last `h` words of `prefix` (most recent last) into `buf`; returns
    /// how many were written.  This is all the n-gram LMs ever look at.
    #[inline]
    fn tail(&self, mut prefix: u32, buf: &mut [u32], h: usize) -> usize {
        let mut tmp = [0u32; lm::MAX_ORDER];
        let mut n = 0;
        while prefix != ROOT && n < h {
            tmp[n] = self.word[prefix as usize];
            prefix = self.parent[prefix as usize];
            n += 1;
        }
        for i in 0..n {
            buf[i] = tmp[n - 1 - i];
        }
        n
    }

    /// Full word sequence of `prefix` (utterance end only).
    fn words_of(&self, mut prefix: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.depth[prefix as usize] as usize);
        while prefix != ROOT {
            out.push(self.word[prefix as usize]);
            prefix = self.parent[prefix as usize];
        }
        out.reverse();
        out
    }
}

/// Beam lanes as parallel arrays — the structure the tentpole is named
/// after.  A lane is one hypothesis: (trie node, last phone, prefix
/// handle) identity plus blank/non-blank log mass and LM side scores.
#[derive(Default)]
struct Lanes {
    node: Vec<u32>,
    last: Vec<u32>,
    pref: Vec<u32>,
    lb: Vec<f64>,
    lnb: Vec<f64>,
    lms: Vec<f64>,
    lml: Vec<f64>,
}

impl Lanes {
    fn clear(&mut self) {
        self.node.clear();
        self.last.clear();
        self.pref.clear();
        self.lb.clear();
        self.lnb.clear();
        self.lms.clear();
        self.lml.clear();
    }

    fn len(&self) -> usize {
        self.node.len()
    }

    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn push(&mut self, node: u32, last: u32, pref: u32, lb: f64, lnb: f64, lms: f64, lml: f64) -> u32 {
        self.node.push(node);
        self.last.push(last);
        self.pref.push(pref);
        self.lb.push(lb);
        self.lnb.push(lnb);
        self.lms.push(lms);
        self.lml.push(lml);
        (self.node.len() - 1) as u32
    }

    fn gather_from(&mut self, src: &Lanes, idx: &[u32]) {
        self.clear();
        for &i in idx {
            let i = i as usize;
            self.push(
                src.node[i],
                src.last[i],
                src.pref[i],
                src.lb[i],
                src.lnb[i],
                src.lms[i],
                src.lml[i],
            );
        }
    }
}

/// Lane index for `(node, last, prefix)` in `nxt`, appending an empty lane
/// on first sight.  Free function so the caller can keep disjoint borrows
/// on the rest of the scratch.
#[inline]
fn upsert(slot: &mut FxMap<(u32, u32, u32), u32>, lanes: &mut Lanes, node: u32, last: u32, pref: u32) -> usize {
    *slot
        .entry((node, last, pref))
        .or_insert_with(|| lanes.push(node, last, pref, NEG_INF, NEG_INF, 0.0, 0.0)) as usize
}

/// Reusable per-thread allocations for the SoA search.
#[derive(Default)]
struct SoaScratch {
    row64: Vec<f64>,
    active: Vec<bool>,
    cur: Lanes,
    nxt: Lanes,
    slot: FxMap<(u32, u32, u32), u32>,
    arena: PrefixArena,
    score: Vec<f64>,
    order: Vec<u32>,
}

thread_local! {
    static SCRATCH: RefCell<SoaScratch> = RefCell::new(SoaScratch::default());
}

/// Per-flush memo of `(history tail, word) → (small, large)` LM scores.
/// Streams decoded in the same flush overwhelmingly share recent word
/// contexts, so one lookup pays for every stream that reaches the same
/// boundary.  Keys are BOS-padded right-aligned tails, unambiguous across
/// depths because BOS is not a real word.
#[derive(Default)]
struct LmCache {
    map: FxMap<([u32; 3], u32), (f64, f64)>,
}

impl LmCache {
    /// Caches up to trigram contexts; longer tails would need wider keys.
    const MAX_TAIL: usize = 3;

    #[inline]
    fn score(&mut self, small: &NGramLm, large: &NGramLm, tail: &[u32], w: u32) -> (f64, f64) {
        if tail.len() > Self::MAX_TAIL {
            return (small.log_prob(tail, w), large.log_prob(tail, w));
        }
        let mut key = [BOS; Self::MAX_TAIL];
        key[Self::MAX_TAIL - tail.len()..].copy_from_slice(tail);
        *self
            .map
            .entry((key, w))
            .or_insert_with(|| (small.log_prob(tail, w), large.log_prob(tail, w)))
    }
}

impl Decoder {
    pub fn new(trie: LexTrie, lm_small: NGramLm, lm_large: NGramLm, config: DecoderConfig) -> Self {
        let csr = trie.to_csr();
        Decoder { trie, lm_small, lm_large, config, csr, kernel: DecodeKernel::Auto }
    }

    /// Rung used by [`decode`](Self::decode) / [`decode_batch`](Self::decode_batch).
    pub fn kernel(&self) -> DecodeKernel {
        self.kernel
    }

    /// Override the default `Auto` rung (benches and tests pin rungs per
    /// instance because `QUANTASR_DECODE_KERNEL` is parsed once per
    /// process).
    pub fn with_kernel(mut self, kernel: DecodeKernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Decode `[t, num_labels]` log-posteriors into the best word sequence.
    pub fn decode(&self, log_probs: &[f32], num_labels: usize) -> Hypothesis {
        self.decode_with_kernel(log_probs, num_labels, self.kernel)
    }

    /// [`decode`](Self::decode) on an explicit kernel rung.
    pub fn decode_with_kernel(
        &self,
        log_probs: &[f32],
        num_labels: usize,
        kernel: DecodeKernel,
    ) -> Hypothesis {
        let beams = self.run_beams(log_probs, num_labels, kernel, &mut LmCache::default());
        self.pick_best(&beams)
    }

    /// Decode a flush of utterances, sharing the LM memo (and per-thread
    /// scratch) across them — the batched form the decode pool calls.
    /// Each job is `(log_probs, num_labels)`.
    pub fn decode_batch(&self, jobs: &[(&[f32], usize)]) -> Vec<Hypothesis> {
        self.decode_batch_with_kernel(jobs, self.kernel)
    }

    /// [`decode_batch`](Self::decode_batch) on an explicit kernel rung.
    pub fn decode_batch_with_kernel(
        &self,
        jobs: &[(&[f32], usize)],
        kernel: DecodeKernel,
    ) -> Vec<Hypothesis> {
        // Pure search time as a trace span (the decode pool brackets the
        // call with its engine context; standalone callers trace under
        // engine 0).
        let t_obs = crate::obs::span_begin();
        let mut cache = LmCache::default();
        let hyps: Vec<Hypothesis> = jobs
            .iter()
            .map(|&(lp, labels)| {
                let beams = self.run_beams(lp, labels, kernel, &mut cache);
                self.pick_best(&beams)
            })
            .collect();
        crate::obs::span_end_ctx(crate::obs::EventKind::BeamSearch, t_obs, jobs.len() as u64);
        hyps
    }

    fn pick_best(&self, beams: &[RawBeam]) -> Hypothesis {
        let cfg = &self.config;
        // Final: prefer complete hypotheses (trie at root); rescore with
        // the large LM.
        let score = |b: &RawBeam| {
            b.acoustic()
                + cfg.lm_weight_large * b.lm_large
                + cfg.word_insertion_bonus * b.words.len() as f64
        };
        let best = beams
            .iter()
            .filter(|b| b.node == 0)
            .max_by(|a, b| score(a).partial_cmp(&score(b)).unwrap())
            .or_else(|| beams.iter().max_by(|a, b| score(a).partial_cmp(&score(b)).unwrap()));
        match best {
            Some(b) => Hypothesis {
                words: b.words.clone(),
                acoustic: b.acoustic(),
                lm_small: b.lm_small,
                lm_large: b.lm_large,
            },
            None => Hypothesis::default(),
        }
    }

    fn run_beams(
        &self,
        log_probs: &[f32],
        num_labels: usize,
        kernel: DecodeKernel,
        cache: &mut LmCache,
    ) -> Vec<RawBeam> {
        match kernel.resolve() {
            DecodeKernel::Reference => self.run_beams_reference(log_probs, num_labels),
            k => SCRATCH.with(|s| {
                self.run_beams_soa(log_probs, num_labels, k, &mut s.borrow_mut(), cache)
            }),
        }
    }

    /// The seed per-hypothesis HashMap search — the reference rung.
    fn run_beams_reference(&self, log_probs: &[f32], num_labels: usize) -> Vec<RawBeam> {
        let cfg = &self.config;
        let t = log_probs.len() / num_labels.max(1);
        let mut beams: HashMap<Key, Entry> = HashMap::new();
        beams.insert(
            Key { node: 0, last: BLANK as u32, words: Vec::new() },
            Entry { lb: 0.0, lnb: NEG_INF, lm_small: 0.0, lm_large: 0.0 },
        );

        for i in 0..t {
            let row = &log_probs[i * num_labels..(i + 1) * num_labels];
            let mut next: HashMap<Key, Entry> = HashMap::new();
            for (key, e) in &beams {
                let total = e.acoustic();
                // 1) blank: state unchanged.
                {
                    let n = next.entry(key.clone()).or_insert_with(Entry::new);
                    let v = total + row[BLANK] as f64;
                    if v > n.lb {
                        n.lm_small = e.lm_small;
                        n.lm_large = e.lm_large;
                    }
                    n.lb = lse(n.lb, v);
                }
                // 2) repeat last emitted phone (stays in the same prefix).
                if key.last != BLANK as u32 && e.lnb > NEG_INF {
                    let n = next.entry(key.clone()).or_insert_with(Entry::new);
                    let v = e.lnb + row[key.last as usize] as f64;
                    if v > n.lnb {
                        n.lm_small = e.lm_small;
                        n.lm_large = e.lm_large;
                    }
                    n.lnb = lse(n.lnb, v);
                }
                // 3) extend along trie arcs.
                for &(phone, child) in self.trie.exits(key.node) {
                    let p_s = row[phone as usize] as f64;
                    if p_s < cfg.phone_floor {
                        continue;
                    }
                    let base = if phone == key.last { e.lb } else { total };
                    if base <= NEG_INF {
                        continue;
                    }
                    let v = base + p_s;
                    // 3a) continue inside the word.
                    let k_cont = Key { node: child, last: phone, words: key.words.clone() };
                    {
                        let n = next.entry(k_cont).or_insert_with(Entry::new);
                        if v > n.lnb {
                            n.lm_small = e.lm_small;
                            n.lm_large = e.lm_large;
                        }
                        n.lnb = lse(n.lnb, v);
                    }
                    // 3b) word boundary: emit every word ending here.
                    for &w in self.trie.words_at(child) {
                        let mut words = key.words.clone();
                        let ls = self.lm_small.log_prob(&words, w);
                        let ll = self.lm_large.log_prob(&words, w);
                        words.push(w);
                        let k_end = Key { node: 0, last: phone, words };
                        let n = next.entry(k_end).or_insert_with(Entry::new);
                        if v > n.lnb {
                            n.lm_small = e.lm_small + ls;
                            n.lm_large = e.lm_large + ll;
                        }
                        n.lnb = lse(n.lnb, v);
                    }
                }
            }
            // Prune by acoustic + small-LM + insertion bonus.
            let mut items: Vec<(Key, Entry)> = next.into_iter().collect();
            items.sort_by(|a, b| {
                let sa = a.1.acoustic()
                    + cfg.lm_weight_small * a.1.lm_small
                    + cfg.word_insertion_bonus * a.0.words.len() as f64;
                let sb = b.1.acoustic()
                    + cfg.lm_weight_small * b.1.lm_small
                    + cfg.word_insertion_bonus * b.0.words.len() as f64;
                sb.partial_cmp(&sa).unwrap()
            });
            items.truncate(cfg.beam);
            beams = items.into_iter().collect();
        }
        beams
            .into_iter()
            .map(|(k, e)| RawBeam {
                node: k.node,
                words: k.words,
                lb: e.lb,
                lnb: e.lnb,
                lm_small: e.lm_small,
                lm_large: e.lm_large,
            })
            .collect()
    }

    /// The SoA engine: same recurrence as the reference, expressed over
    /// beam lanes.  Deterministic by construction — lanes are visited in
    /// insertion order, so log-sum-exp accumulation order is fixed and
    /// every SoA rung produces bit-identical results.
    fn run_beams_soa(
        &self,
        log_probs: &[f32],
        num_labels: usize,
        kernel: DecodeKernel,
        s: &mut SoaScratch,
        cache: &mut LmCache,
    ) -> Vec<RawBeam> {
        let cfg = &self.config;
        let t = log_probs.len() / num_labels.max(1);
        let hmax = (self.lm_small.order.max(self.lm_large.order) - 1).min(lm::MAX_ORDER - 1);

        s.arena.reset();
        s.cur.clear();
        s.cur.push(0, BLANK as u32, ROOT, 0.0, NEG_INF, 0.0, 0.0);

        for i in 0..t {
            let row = &log_probs[i * num_labels..(i + 1) * num_labels];
            kernel::prep_row(kernel, row, cfg.phone_floor, &mut s.row64, &mut s.active);
            s.nxt.clear();
            s.slot.clear();

            for li in 0..s.cur.len() {
                let node = s.cur.node[li];
                let last = s.cur.last[li];
                let pref = s.cur.pref[li];
                let lb = s.cur.lb[li];
                let lnb = s.cur.lnb[li];
                let lms = s.cur.lms[li];
                let lml = s.cur.lml[li];
                let total = lse(lb, lnb);
                // 1) blank: state unchanged.
                {
                    let j = upsert(&mut s.slot, &mut s.nxt, node, last, pref);
                    let v = total + s.row64[BLANK];
                    if v > s.nxt.lb[j] {
                        s.nxt.lms[j] = lms;
                        s.nxt.lml[j] = lml;
                    }
                    s.nxt.lb[j] = lse(s.nxt.lb[j], v);
                }
                // 2) repeat last emitted phone (stays in the same prefix).
                if last != BLANK as u32 && lnb > NEG_INF {
                    let j = upsert(&mut s.slot, &mut s.nxt, node, last, pref);
                    let v = lnb + s.row64[last as usize];
                    if v > s.nxt.lnb[j] {
                        s.nxt.lms[j] = lms;
                        s.nxt.lml[j] = lml;
                    }
                    s.nxt.lnb[j] = lse(s.nxt.lnb[j], v);
                }
                // 3) extend along trie arcs (CSR walk, floor mask from
                //    prep_row instead of a per-hypothesis compare).
                let xlo = self.csr.exit_off[node as usize] as usize;
                let xhi = self.csr.exit_off[node as usize + 1] as usize;
                let mut tail_buf = [0u32; lm::MAX_ORDER];
                let mut tail_len = usize::MAX; // filled lazily at first boundary
                for x in xlo..xhi {
                    let phone = self.csr.exit_phone[x];
                    if !s.active[phone as usize] {
                        continue;
                    }
                    let base = if phone == last { lb } else { total };
                    if base <= NEG_INF {
                        continue;
                    }
                    let child = self.csr.exit_child[x];
                    let v = base + s.row64[phone as usize];
                    // 3a) continue inside the word.
                    {
                        let j = upsert(&mut s.slot, &mut s.nxt, child, phone, pref);
                        if v > s.nxt.lnb[j] {
                            s.nxt.lms[j] = lms;
                            s.nxt.lml[j] = lml;
                        }
                        s.nxt.lnb[j] = lse(s.nxt.lnb[j], v);
                    }
                    // 3b) word boundary: emit every word ending here.
                    let wlo = self.csr.word_off[child as usize] as usize;
                    let whi = self.csr.word_off[child as usize + 1] as usize;
                    for wi in wlo..whi {
                        let w = self.csr.word_id[wi];
                        if tail_len == usize::MAX {
                            tail_len = s.arena.tail(pref, &mut tail_buf, hmax);
                        }
                        let (ls, ll) =
                            cache.score(&self.lm_small, &self.lm_large, &tail_buf[..tail_len], w);
                        let npref = s.arena.child(pref, w);
                        let j = upsert(&mut s.slot, &mut s.nxt, 0, phone, npref);
                        if v > s.nxt.lnb[j] {
                            s.nxt.lms[j] = lms + ls;
                            s.nxt.lml[j] = lml + ll;
                        }
                        s.nxt.lnb[j] = lse(s.nxt.lnb[j], v);
                    }
                }
            }

            // Prune by acoustic + small-LM + insertion bonus: partial
            // select of the top `beam` lanes, then restore insertion order
            // so accumulation order stays deterministic next frame.
            let n = s.nxt.len();
            let k = cfg.beam.max(1).min(n);
            if n > k {
                s.score.clear();
                for j in 0..n {
                    s.score.push(
                        lse(s.nxt.lb[j], s.nxt.lnb[j])
                            + cfg.lm_weight_small * s.nxt.lms[j]
                            + cfg.word_insertion_bonus
                                * s.arena.depth[s.nxt.pref[j] as usize] as f64,
                    );
                }
                s.order.clear();
                s.order.extend(0..n as u32);
                let SoaScratch { ref mut order, ref score, .. } = *s;
                order.select_nth_unstable_by(k - 1, |&a, &b| {
                    score[b as usize].partial_cmp(&score[a as usize]).unwrap()
                });
                order.truncate(k);
                order.sort_unstable();
                s.cur.gather_from(&s.nxt, &s.order);
            } else {
                std::mem::swap(&mut s.cur, &mut s.nxt);
            }
        }

        (0..s.cur.len())
            .map(|li| RawBeam {
                node: s.cur.node[li],
                words: s.arena.words_of(s.cur.pref[li]),
                lb: s.cur.lb[li],
                lnb: s.cur.lnb[li],
                lm_small: s.cur.lms[li],
                lm_large: s.cur.lml[li],
            })
            .collect()
    }

    /// N-best list (rescored, deduplicated by word sequence, best first).
    /// The sequence-discriminative training recipes (MWER/sMBR) and
    /// confidence estimation consume these.
    pub fn decode_nbest(
        &self,
        log_probs: &[f32],
        num_labels: usize,
        n: usize,
    ) -> Vec<Hypothesis> {
        let beams =
            self.run_beams(log_probs, num_labels, self.kernel, &mut LmCache::default());
        let cfg = &self.config;
        let mut items: Vec<Hypothesis> = beams
            .into_iter()
            .filter(|b| b.node == 0)
            .map(|b| Hypothesis {
                acoustic: b.acoustic(),
                words: b.words,
                lm_small: b.lm_small,
                lm_large: b.lm_large,
            })
            .collect();
        items.sort_by(|a, b| {
            let sa = a.acoustic
                + cfg.lm_weight_large * a.lm_large
                + cfg.word_insertion_bonus * a.words.len() as f64;
            let sb = b.acoustic
                + cfg.lm_weight_large * b.lm_large
                + cfg.word_insertion_bonus * b.words.len() as f64;
            sb.partial_cmp(&sa).unwrap()
        });
        items.dedup_by(|a, b| a.words == b.words);
        items.truncate(n);
        items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoder::trie::LexTrie;
    use crate::sim::dataset::text_corpus;
    use crate::sim::World;
    use crate::util::prop::{forall, Gen};

    fn decoder(beam: usize) -> (Decoder, World) {
        let world = World::new();
        let corpus = text_corpus(1500, 77, &world);
        let trie = LexTrie::from_world(&world);
        let lm_s = NGramLm::small(&corpus, 200);
        let lm_l = NGramLm::large(&corpus, 200);
        let cfg = DecoderConfig { beam, ..Default::default() };
        (Decoder::new(trie, lm_s, lm_l, cfg), world)
    }

    /// Synthesize ideal peaked posteriors for a phone sequence: each phone
    /// lasts 3 frames then 1 blank frame.
    fn ideal_posteriors(phones: &[u32], num_labels: usize) -> Vec<f32> {
        let mut rows: Vec<f32> = Vec::new();
        let mut push = |id: u32| {
            let mut r = vec![-8.0f32; num_labels];
            r[id as usize] = 0.0;
            // renormalize roughly (log-softmax-ish): fine for tests
            rows.extend(r);
        };
        push(0);
        for &p in phones {
            for _ in 0..3 {
                push(p);
            }
            push(0);
        }
        rows
    }

    /// The SoA rungs available on this CPU (scalar always; SIMD if present).
    fn soa_rungs() -> Vec<DecodeKernel> {
        let mut r = vec![DecodeKernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        if crate::quant::gemm::avx2_available() {
            r.push(DecodeKernel::Avx2);
        }
        #[cfg(target_arch = "aarch64")]
        r.push(DecodeKernel::Neon);
        r
    }

    #[test]
    fn decodes_clean_word_sequence() {
        let (dec, world) = decoder(24);
        let words = vec![3u32, 17, 42];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let lp = ideal_posteriors(&phones, 41);
        for k in [DecodeKernel::Reference, DecodeKernel::Auto] {
            let hyp = dec.decode_with_kernel(&lp, 41, k);
            assert_eq!(hyp.words, words, "kernel {k:?}, phones {phones:?}");
        }
    }

    #[test]
    fn empty_input_gives_empty_hyp() {
        let (dec, _) = decoder(8);
        for k in [DecodeKernel::Reference, DecodeKernel::Auto] {
            let hyp = dec.decode_with_kernel(&[], 41, k);
            assert!(hyp.words.is_empty(), "kernel {k:?}");
        }
    }

    #[test]
    fn lexicon_constraint_repairs_minor_corruption() {
        // Corrupt one phone frame of a word; the trie + LM should still
        // recover the intended words since no other word matches better.
        let (dec, world) = decoder(32);
        let words = vec![7u32, 19];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let mut lp = ideal_posteriors(&phones, 41);
        // soften frames of the middle phone occurrence
        let frames = lp.len() / 41;
        let mid = frames / 2;
        for f in mid..(mid + 1).min(frames) {
            for v in lp[f * 41..(f + 1) * 41].iter_mut() {
                *v = -3.7; // ~uniform
            }
        }
        for k in [DecodeKernel::Reference, DecodeKernel::Auto] {
            let hyp = dec.decode_with_kernel(&lp, 41, k);
            assert_eq!(hyp.words, words, "kernel {k:?}");
        }
    }

    #[test]
    fn nbest_first_equals_decode_best() {
        let (dec, world) = decoder(24);
        let words = vec![3u32, 17, 42];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let lp = ideal_posteriors(&phones, 41);
        let best = dec.decode(&lp, 41);
        let nbest = dec.decode_nbest(&lp, 41, 5);
        assert!(!nbest.is_empty());
        assert_eq!(nbest[0].words, best.words);
        // list is sorted and deduplicated
        for w in nbest.windows(2) {
            assert_ne!(w[0].words, w[1].words);
        }
    }

    #[test]
    fn bigger_beam_never_scores_worse() {
        let (dec_small, world) = decoder(2);
        let (dec_big, _) = decoder(32);
        let words = vec![11u32, 3, 90];
        let phones: Vec<u32> =
            words.iter().flat_map(|&w| world.word_phones(w).to_vec()).collect();
        let lp = ideal_posteriors(&phones, 41);
        let h_small = dec_small.decode(&lp, 41);
        let h_big = dec_big.decode(&lp, 41);
        let score = |h: &Hypothesis| {
            h.acoustic + h.lm_large + 0.5 * h.words.len() as f64
        };
        assert!(score(&h_big) >= score(&h_small) - 1e-9);
        assert_eq!(h_big.words, words);
    }

    /// Random continuous posteriors for parity tests: normal noise around
    /// a mildly peaked phone path, so beams stay populated but scores are
    /// continuous (exact ties have ~zero probability — exact ties are the
    /// only case where reference HashMap order could pick differently).
    fn random_posteriors(g: &mut Gen, t: usize, num_labels: usize) -> Vec<f32> {
        let mut lp = Vec::with_capacity(t * num_labels);
        for _ in 0..t {
            let peak = g.usize_in(0, num_labels - 1);
            for l in 0..num_labels {
                let base = if l == peak { -0.5 } else { -6.0 };
                lp.push(base + g.rng.normal() as f32 * 1.5);
            }
        }
        lp
    }

    #[test]
    fn soa_matches_reference_on_random_posteriors() {
        // The tentpole property: identical 1-best word sequence and final
        // scores to ≤1e-9 (bit-equality is impossible — the reference's
        // HashMap iteration makes its own accumulation order arbitrary).
        let (dec, _world) = decoder(8);
        forall("soa vs reference", 25, 0xBEA7, |g: &mut Gen| {
            let t = g.usize_in(2, 30);
            let lp = random_posteriors(g, t, 41);
            let href = dec.decode_with_kernel(&lp, 41, DecodeKernel::Reference);
            let hsoa = dec.decode_with_kernel(&lp, 41, DecodeKernel::Scalar);
            assert_eq!(href.words, hsoa.words, "1-best diverged");
            assert!((href.acoustic - hsoa.acoustic).abs() <= 1e-9, "acoustic");
            assert!((href.lm_small - hsoa.lm_small).abs() <= 1e-9, "lm_small");
            assert!((href.lm_large - hsoa.lm_large).abs() <= 1e-9, "lm_large");
        });
    }

    #[test]
    fn soa_rungs_are_bit_identical() {
        // Scalar vs SIMD rungs share the deterministic lane order and use
        // exact vector ops only → bit-identical, not just close.
        let (dec, _world) = decoder(12);
        forall("soa ladder", 15, 0x51AD, |g: &mut Gen| {
            let t = g.usize_in(2, 40);
            let lp = random_posteriors(g, t, 41);
            let base = dec.decode_with_kernel(&lp, 41, DecodeKernel::Scalar);
            for k in soa_rungs() {
                let h = dec.decode_with_kernel(&lp, 41, k);
                assert_eq!(h.words, base.words, "{k:?}");
                assert_eq!(h.acoustic.to_bits(), base.acoustic.to_bits(), "{k:?}");
                assert_eq!(h.lm_small.to_bits(), base.lm_small.to_bits(), "{k:?}");
                assert_eq!(h.lm_large.to_bits(), base.lm_large.to_bits(), "{k:?}");
            }
        });
    }

    #[test]
    fn decode_batch_equals_sequential_decode() {
        // Sharing scratch + LM memo across a flush must not change values.
        let (dec, _world) = decoder(8);
        let mut g = Gen::new(0xBA7C);
        let jobs_data: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                let t = 3 + g.usize_in(0, 20);
                random_posteriors(&mut g, t, 41)
            })
            .collect();
        let jobs: Vec<(&[f32], usize)> = jobs_data.iter().map(|j| (j.as_slice(), 41)).collect();
        let batch = dec.decode_batch(&jobs);
        assert_eq!(batch.len(), jobs.len());
        for (i, &(lp, labels)) in jobs.iter().enumerate() {
            let single = dec.decode(lp, labels);
            assert_eq!(batch[i].words, single.words, "job {i}");
            assert_eq!(batch[i].acoustic.to_bits(), single.acoustic.to_bits(), "job {i}");
            assert_eq!(batch[i].lm_small.to_bits(), single.lm_small.to_bits(), "job {i}");
            assert_eq!(batch[i].lm_large.to_bits(), single.lm_large.to_bits(), "job {i}");
        }
    }

    #[test]
    fn prefix_arena_interns_uniquely() {
        let mut a = PrefixArena::default();
        a.reset();
        let p1 = a.child(ROOT, 7);
        let p2 = a.child(p1, 9);
        assert_eq!(a.child(ROOT, 7), p1);
        assert_eq!(a.child(p1, 9), p2);
        assert_ne!(a.child(ROOT, 9), p1);
        assert_eq!(a.words_of(p2), vec![7, 9]);
        assert_eq!(a.depth[p2 as usize], 2);
        let mut buf = [0u32; lm::MAX_ORDER];
        assert_eq!(a.tail(p2, &mut buf, 1), 1);
        assert_eq!(buf[0], 9);
        assert_eq!(a.tail(p2, &mut buf, 4), 2);
        assert_eq!(&buf[..2], &[7, 9]);
    }
}
