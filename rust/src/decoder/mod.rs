//! Decoding stack: CTC posteriors → words.
//!
//! Mirrors the paper's §4 decoding setup at simulator scale: a lexicon
//! transducer (here a phone-trie), a small first-pass n-gram LM, and
//! on-the-fly rescoring with a larger LM.
//!
//! - [`wer`]    — Levenshtein alignment, WER/LER scoring.
//! - [`lm`]     — interpolated n-gram language model (trained on the
//!   synthetic text corpus).
//! - [`trie`]   — lexicon prefix trie over phones (+ CSR view).
//! - [`ctc`]    — greedy + phone-level CTC prefix beam search.
//! - [`search`] — word-level lexicon+LM CTC beam search with rescoring,
//!   on the struct-of-arrays / reference kernel ladder.
//! - [`kernel`] — decode kernel rung selection (`QUANTASR_DECODE_KERNEL`).

pub mod ctc;
pub mod kernel;
pub mod lm;
pub mod search;
pub mod trie;
pub mod wer;

pub use kernel::DecodeKernel;
pub use search::{Decoder, DecoderConfig, Hypothesis};
