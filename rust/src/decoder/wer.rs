//! Word/label error rate scoring (Levenshtein alignment).

/// Edit-distance breakdown.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EditStats {
    pub substitutions: usize,
    pub deletions: usize,
    pub insertions: usize,
    pub ref_len: usize,
}

impl EditStats {
    pub fn errors(&self) -> usize {
        self.substitutions + self.deletions + self.insertions
    }

    pub fn rate(&self) -> f64 {
        self.errors() as f64 / self.ref_len.max(1) as f64
    }

    pub fn add(&mut self, o: &EditStats) {
        self.substitutions += o.substitutions;
        self.deletions += o.deletions;
        self.insertions += o.insertions;
        self.ref_len += o.ref_len;
    }
}

/// Full DP with back-trace to attribute S/D/I (hyp vs ref).
pub fn align(hyp: &[u32], r: &[u32]) -> EditStats {
    let (n, m) = (hyp.len(), r.len());
    // dp[i][j] = cost of aligning hyp[..i] with ref[..j]
    let mut dp = vec![0u32; (n + 1) * (m + 1)];
    let idx = |i: usize, j: usize| i * (m + 1) + j;
    for i in 0..=n {
        dp[idx(i, 0)] = i as u32;
    }
    for j in 0..=m {
        dp[idx(0, j)] = j as u32;
    }
    for i in 1..=n {
        for j in 1..=m {
            let sub = dp[idx(i - 1, j - 1)] + (hyp[i - 1] != r[j - 1]) as u32;
            let del = dp[idx(i, j - 1)] + 1; // ref word dropped
            let ins = dp[idx(i - 1, j)] + 1; // extra hyp word
            dp[idx(i, j)] = sub.min(del).min(ins);
        }
    }
    // backtrace
    let (mut i, mut j) = (n, m);
    let mut st = EditStats { ref_len: m, ..Default::default() };
    while i > 0 || j > 0 {
        if i > 0 && j > 0 && dp[idx(i, j)] == dp[idx(i - 1, j - 1)] + (hyp[i - 1] != r[j - 1]) as u32
        {
            if hyp[i - 1] != r[j - 1] {
                st.substitutions += 1;
            }
            i -= 1;
            j -= 1;
        } else if j > 0 && dp[idx(i, j)] == dp[idx(i, j - 1)] + 1 {
            st.deletions += 1;
            j -= 1;
        } else {
            st.insertions += 1;
            i -= 1;
        }
    }
    st
}

/// Plain edit distance (no breakdown).
pub fn edit_distance(a: &[u32], b: &[u32]) -> usize {
    align(a, b).errors()
}

/// Corpus-level error rate: Σ errors / Σ ref lengths.
pub fn corpus_rate<'a>(pairs: impl Iterator<Item = (&'a [u32], &'a [u32])>) -> f64 {
    let mut total = EditStats::default();
    for (h, r) in pairs {
        total.add(&align(h, r));
    }
    total.rate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn identity_and_simple_cases() {
        assert_eq!(align(&[1, 2, 3], &[1, 2, 3]).errors(), 0);
        assert_eq!(align(&[], &[1, 2]).errors(), 2); // 2 deletions
        assert_eq!(align(&[1, 2], &[]).errors(), 2); // 2 insertions
        let st = align(&[1, 9, 3], &[1, 2, 3]);
        assert_eq!(st.substitutions, 1);
        assert_eq!(st.errors(), 1);
    }

    #[test]
    fn breakdown_attribution() {
        // hyp=[1,3,4,4] vs ref=[1,2,3,4]: distance 2, reachable either as
        // {del 2, ins 4} or {sub 3→2, sub 4→3}; the backtrace picks one
        // optimal attribution — only the total is canonical.
        let st = align(&[1, 3, 4, 4], &[1, 2, 3, 4]);
        assert_eq!(st.errors(), 2);
        assert_eq!(st.substitutions + st.deletions + st.insertions, 2);
    }

    #[test]
    fn symmetric_distance() {
        forall("wer symmetric", 60, 0x3E, |g: &mut Gen| {
            let na = g.usize_in(0, 12);
            let a = g.vec_ids(na, 10);
            let nb = g.usize_in(0, 12);
            let b = g.vec_ids(nb, 10);
            assert_eq!(edit_distance(&a, &b), edit_distance(&b, &a));
        });
    }

    #[test]
    fn triangle_inequality() {
        forall("wer triangle", 40, 0x3F, |g: &mut Gen| {
            let na = g.usize_in(0, 10);
            let a = g.vec_ids(na, 8);
            let nb = g.usize_in(0, 10);
            let b = g.vec_ids(nb, 8);
            let nc = g.usize_in(0, 10);
            let c = g.vec_ids(nc, 8);
            assert!(
                edit_distance(&a, &c) <= edit_distance(&a, &b) + edit_distance(&b, &c)
            );
        });
    }

    #[test]
    fn corpus_rate_pools_lengths() {
        let h1: Vec<u32> = vec![1, 2];
        let r1: Vec<u32> = vec![1, 2];
        let h2: Vec<u32> = vec![9];
        let r2: Vec<u32> = vec![1, 2, 3];
        let rate = corpus_rate([(h1.as_slice(), r1.as_slice()), (h2.as_slice(), r2.as_slice())].into_iter());
        assert!((rate - 3.0 / 5.0).abs() < 1e-9);
    }
}
